//! Launcher helpers: assemble engines from a [`SystemConfig`].
//!
//! Used by the `shetm` binary, the examples and the benches so that every
//! entry point builds the platform the same way: pick the guest TM, pick
//! the device backend (PJRT artifacts when available, native mirrors
//! otherwise), wire the workload drivers into a [`RoundEngine`].

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::apps::memcached::{init_cache_words, McConfig, McCpu, McGpu, McWorld};
use crate::apps::synth::{SynthCpu, SynthGpu, SynthSpec};
use crate::cluster::{ClusterEngine, ShardMap};
use crate::config::{GuestKind, SystemConfig};
use crate::coordinator::round::{CostModel, EngineConfig, RoundEngine, Variant};
use crate::gpu::{Backend, GpuDevice};
use crate::runtime::ArtifactStore;
use crate::stm::htm::HtmEmu;
use crate::stm::norec::NorecStm;
use crate::stm::tinystm::TinyStm;
use crate::stm::{GlobalClock, GuestTm, SharedStmr};

/// Instantiate a guest TM over a shared commit clock.
pub fn build_guest(kind: GuestKind, clock: Arc<GlobalClock>) -> Arc<dyn GuestTm> {
    match kind {
        GuestKind::Tiny => Arc::new(TinyStm::with_clock(clock)),
        GuestKind::Norec => Arc::new(NorecStm::with_clock(clock)),
        GuestKind::Htm => Arc::new(HtmEmu::with_clock(clock)),
    }
}

/// Pick the device backend: PJRT when an artifact directory is configured
/// and loadable, native mirrors otherwise.
///
/// `prstm`, `validate`, `memcached` are artifact names (empty = unused).
pub fn build_backend(
    cfg: &SystemConfig,
    prstm: &str,
    validate: &str,
    memcached: &str,
) -> Result<Backend> {
    if cfg.artifacts_dir.is_empty() {
        return Ok(Backend::Native);
    }
    if !ArtifactStore::available(&cfg.artifacts_dir) {
        bail!(
            "artifacts dir {:?} is unavailable — run `make artifacts`, build \
             with the `pjrt` cargo feature, or unset runtime.artifacts",
            cfg.artifacts_dir
        );
    }
    let store = ArtifactStore::load(&cfg.artifacts_dir)?;
    Ok(Backend::Pjrt {
        store,
        prstm: prstm.to_string(),
        validate: validate.to_string(),
        memcached: memcached.to_string(),
    })
}

/// Engine config derived from the system config.
pub fn engine_config(cfg: &SystemConfig, variant: Variant) -> EngineConfig {
    EngineConfig {
        period_s: cfg.period_s,
        variant,
        early_validation: cfg.early_validation,
        early_points: ((1.0 / cfg.early_interval_frac).round() as usize).max(1) - 1,
        chunk_entries: crate::bus::chunking::LOG_CHUNK_ENTRIES,
        policy: cfg.policy,
        starvation_limit: cfg.gpu_starvation_limit,
    }
}

/// Cost model derived from the system config.
pub fn cost_model(cfg: &SystemConfig) -> CostModel {
    CostModel {
        bus_h2d: cfg.bus_h2d,
        bus_d2h: cfg.bus_d2h,
        gpu_kernel_latency_s: cfg.gpu_kernel_latency_s,
        gpu_txn_s: cfg.gpu_txn_s,
        gpu_validate_entry_s: cfg.gpu_validate_entry_s,
        ..CostModel::default()
    }
}

/// Assemble a synthetic-workload engine (paper §V-A..§V-C shapes).
///
/// `cpu_spec` and `gpu_spec` carry the per-device partitions / conflict
/// injection; `gpu_batch` must match the compiled artifact's `b` when the
/// PJRT backend is selected.
pub fn build_synth_engine(
    cfg: &SystemConfig,
    variant: Variant,
    cpu_spec: SynthSpec,
    gpu_spec: SynthSpec,
    gpu_batch: usize,
    backend: Backend,
) -> RoundEngine<SynthCpu, SynthGpu> {
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(cfg.n_words));
    let tm = build_guest(cfg.guest, clock);
    let cpu = SynthCpu::new(
        stmr,
        tm,
        cpu_spec,
        cfg.cpu_threads,
        cfg.cpu_txn_s,
        cfg.seed,
    );
    let gpu = SynthGpu::new(
        gpu_spec,
        gpu_batch,
        cfg.gpu_kernel_latency_s,
        cfg.gpu_txn_s,
        cfg.seed ^ 0x9E37_79B9,
    );
    let device = GpuDevice::new(cfg.n_words, cfg.bmp_shift, backend);
    let mut engine = RoundEngine::new(engine_config(cfg, variant), cost_model(cfg), device, cpu, gpu);
    engine.align_replicas();
    engine
}

/// Assemble a memcached engine (paper §V-D).
pub fn build_memcached_engine(
    cfg: &SystemConfig,
    variant: Variant,
    mc: McConfig,
    gpu_batch: usize,
    backend: Backend,
) -> RoundEngine<McCpu, McGpu> {
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(mc.n_words()));
    let mut words = vec![0; mc.n_words()];
    init_cache_words(&mut words, mc.n_sets);
    stmr.install_range(0, &words);

    let tm = build_guest(cfg.guest, clock);
    let world = McWorld::new(mc.clone(), cfg.seed, mc.steal_shift > 0.0);
    let cpu = McCpu::new(
        stmr,
        tm,
        world.clone(),
        mc.clone(),
        cfg.cpu_threads,
        cfg.cpu_txn_s,
    );
    let gpu = McGpu::new(
        world,
        mc.clone(),
        gpu_batch,
        cfg.gpu_kernel_latency_s,
        cfg.gpu_txn_s,
    );
    let device = GpuDevice::new(mc.n_words(), cfg.bmp_shift, backend);
    let mut engine = RoundEngine::new(engine_config(cfg, variant), cost_model(cfg), device, cpu, gpu);
    engine.align_replicas();
    engine
}

/// Shard map derived from the system config over an `n_words` region.
///
/// `cluster.shard_bits` is clamped down until every device owns at least
/// one block (tiny test regions stay usable at any `n_gpus`), and
/// `n_gpus` itself is capped at the region size — one word per device is
/// the hard floor — so absurd `--gpus` values degrade instead of
/// panicking in `ShardMap::new`.
pub fn shard_map(cfg: &SystemConfig, n_words: usize) -> ShardMap {
    let n_gpus = cfg.n_gpus.clamp(1, n_words.max(1));
    let mut bits = cfg.shard_bits;
    while bits > 0 && n_words < n_gpus << bits {
        bits -= 1;
    }
    ShardMap::new(n_words, n_gpus, bits)
}

/// Assemble a synthetic-workload cluster engine over `cluster.n_gpus`
/// devices.
///
/// `gpu_spec` is the per-device template: each device gets it
/// [`SynthSpec::homed`] onto its own shard (plus `cluster.cross_shard_prob`
/// injection when the cluster has more than one device). With
/// `cluster.n_gpus = 1` construction is element-for-element the same as
/// [`build_synth_engine`] — same seeds, same specs — so the run is
/// bit-identical to the single-device engine.
pub fn build_synth_cluster_engine(
    cfg: &SystemConfig,
    variant: Variant,
    cpu_spec: SynthSpec,
    gpu_spec: SynthSpec,
    gpu_batch: usize,
    backend: Backend,
) -> ClusterEngine<SynthCpu, SynthGpu> {
    let map = shard_map(cfg, cfg.n_words);
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(cfg.n_words));
    let tm = build_guest(cfg.guest, clock);
    let cpu = SynthCpu::new(
        stmr,
        tm,
        cpu_spec,
        cfg.cpu_threads,
        cfg.cpu_txn_s,
        cfg.seed,
    );
    let mut devices = Vec::with_capacity(map.n_shards());
    let mut gpus = Vec::with_capacity(map.n_shards());
    for d in 0..map.n_shards() {
        let mut spec = gpu_spec.clone().homed(map.clone(), d);
        if map.n_shards() > 1 {
            spec = spec.with_cross_shard(cfg.cross_shard_prob);
        }
        // Device 0 keeps the single-engine seed; later devices derive.
        let seed = cfg.seed ^ 0x9E37_79B9 ^ (d as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        gpus.push(SynthGpu::new(
            spec,
            gpu_batch,
            cfg.gpu_kernel_latency_s,
            cfg.gpu_txn_s,
            seed,
        ));
        devices.push(GpuDevice::new(cfg.n_words, cfg.bmp_shift, backend.clone()));
    }
    let mut engine = ClusterEngine::new(
        engine_config(cfg, variant),
        cost_model(cfg),
        map,
        devices,
        cpu,
        gpus,
    );
    engine.align_replicas();
    engine
}

/// Assemble a memcached cluster engine over `cluster.n_gpus` devices with
/// shard-aware request routing (arrivals go to the device owning their
/// cache set). Bit-identical to [`build_memcached_engine`] at
/// `cluster.n_gpus = 1`.
pub fn build_memcached_cluster_engine(
    cfg: &SystemConfig,
    variant: Variant,
    mc: McConfig,
    gpu_batch: usize,
    backend: Backend,
) -> ClusterEngine<McCpu, McGpu> {
    let map = shard_map(cfg, mc.n_words());
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(mc.n_words()));
    let mut words = vec![0; mc.n_words()];
    init_cache_words(&mut words, mc.n_sets);
    stmr.install_range(0, &words);

    let tm = build_guest(cfg.guest, clock);
    let world = McWorld::new_sharded(mc.clone(), cfg.seed, mc.steal_shift > 0.0, map.clone());
    let cpu = McCpu::new(
        stmr,
        tm,
        world.clone(),
        mc.clone(),
        cfg.cpu_threads,
        cfg.cpu_txn_s,
    );
    let mut devices = Vec::with_capacity(map.n_shards());
    let mut gpus = Vec::with_capacity(map.n_shards());
    for d in 0..map.n_shards() {
        gpus.push(
            McGpu::new(
                world.clone(),
                mc.clone(),
                gpu_batch,
                cfg.gpu_kernel_latency_s,
                cfg.gpu_txn_s,
            )
            .on_device(d),
        );
        devices.push(GpuDevice::new(mc.n_words(), cfg.bmp_shift, backend.clone()));
    }
    let mut engine = ClusterEngine::new(
        engine_config(cfg, variant),
        cost_model(cfg),
        map,
        devices,
        cpu,
        gpus,
    );
    engine.align_replicas();
    engine
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::from_raw(&crate::config::Raw::new()).unwrap();
        c.n_words = 1 << 14;
        c.cpu_txn_s = 2e-6;
        c.period_s = 0.004;
        c
    }

    #[test]
    fn synth_engine_round_trips() {
        let c = cfg();
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut e = build_synth_engine(
            &c,
            Variant::Optimized,
            cpu_spec,
            gpu_spec,
            256,
            Backend::Native,
        );
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 2, "partitioned => no conflicts");
        assert!(e.stats.throughput() > 0.0);
    }

    #[test]
    fn memcached_engine_round_trips() {
        let mut c = cfg();
        c.policy = PolicyKind::FavorCpu;
        let mc = McConfig::new(1 << 10);
        let mut e =
            build_memcached_engine(&c, Variant::Optimized, mc, 256, Backend::Native);
        e.run_rounds(2).unwrap();
        assert!(e.stats.cpu_commits > 0);
        assert!(e.stats.gpu_attempts > 0);
        // Balanced parity workload: rounds should commit.
        assert_eq!(e.stats.rounds_committed, 2);
    }

    #[test]
    fn synth_cluster_engine_round_trips() {
        let mut c = cfg();
        c.n_gpus = 2;
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut e = build_synth_cluster_engine(
            &c,
            Variant::Optimized,
            cpu_spec,
            gpu_spec,
            256,
            Backend::Native,
        );
        assert_eq!(e.n_gpus(), 2);
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 2, "partitioned => no conflicts");
        assert!(e.stats.throughput() > 0.0);
        assert!(e.cluster.per_device.iter().all(|d| d.commits > 0));
    }

    #[test]
    fn memcached_cluster_engine_round_trips() {
        let mut c = cfg();
        c.policy = PolicyKind::FavorCpu;
        c.n_gpus = 2;
        let mc = McConfig::new(1 << 10);
        let mut e =
            build_memcached_cluster_engine(&c, Variant::Optimized, mc, 256, Backend::Native);
        e.run_rounds(2).unwrap();
        assert!(e.stats.cpu_commits > 0);
        assert!(e.stats.gpu_attempts > 0);
        assert!(e.cluster.per_device.iter().all(|d| d.attempts > 0));
    }

    #[test]
    fn shard_map_clamps_bits_for_tiny_regions() {
        let mut c = cfg();
        c.n_gpus = 8;
        c.n_words = 1 << 10; // 8 << 12 would not fit
        let m = shard_map(&c, c.n_words);
        assert_eq!(m.n_shards(), 8);
        assert!(c.n_words >= 8 << m.shard_bits());
        for d in 0..8 {
            assert!(m.owned_words(d) > 0);
        }
    }

    #[test]
    fn engine_config_maps_early_points() {
        let mut c = cfg();
        c.early_interval_frac = 0.25;
        assert_eq!(engine_config(&c, Variant::Optimized).early_points, 3);
        c.early_interval_frac = 1.0;
        assert_eq!(engine_config(&c, Variant::Optimized).early_points, 0);
    }
}
