//! Launcher internals: assemble engines from a [`SystemConfig`].
//!
//! **Entry points construct through [`crate::session::Hetm`] now** — one
//! fluent builder over both engines, with the whole knob cross-product
//! validated up front.  This module keeps the shared plumbing the builder
//! runs on (guest/backend/config/shard-map derivation) plus the legacy
//! `build_*` engine constructors as deprecated shims: they remain the
//! independent reference the Session-vs-legacy golden equivalence suite
//! (`rust/tests/session_api.rs`) compares against, and they still return
//! the concrete engine types for code that needs them.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::apps::memcached::{init_cache_words, McConfig, McCpu, McGpu, McWorld};
use crate::apps::synth::{SynthCpu, SynthGpu, SynthSpec};
use crate::apps::workload::Workload;
use crate::cluster::{ClusterEngine, RebalanceCfg, ShardMap};
use crate::config::{GuestKind, SystemConfig};
use crate::coordinator::parallel::ParallelCpuDriver;
use crate::coordinator::round::{
    CostModel, CpuDriver, EngineConfig, GpuDriver, RoundEngine, Variant,
};
use crate::gpu::{Backend, GpuDevice};
use crate::runtime::ArtifactStore;
use crate::stm::htm::HtmEmu;
use crate::stm::norec::NorecStm;
use crate::stm::tinystm::TinyStm;
use crate::stm::{GlobalClock, GuestTm, SharedStmr};

/// Instantiate a guest TM over a shared commit clock.
pub fn build_guest(kind: GuestKind, clock: Arc<GlobalClock>) -> Arc<dyn GuestTm> {
    match kind {
        GuestKind::Tiny => Arc::new(TinyStm::with_clock(clock)),
        GuestKind::Norec => Arc::new(NorecStm::with_clock(clock)),
        GuestKind::Htm => Arc::new(HtmEmu::with_clock(clock)),
    }
}

/// Pick the device backend: PJRT when an artifact directory is configured
/// and loadable, native mirrors otherwise.
///
/// `prstm`, `validate`, `memcached` are artifact names (empty = unused).
pub fn build_backend(
    cfg: &SystemConfig,
    prstm: &str,
    validate: &str,
    memcached: &str,
) -> Result<Backend> {
    if cfg.artifacts_dir.is_empty() {
        return Ok(Backend::Native);
    }
    if !ArtifactStore::available(&cfg.artifacts_dir) {
        bail!(
            "artifacts dir {:?} is unavailable — run `make artifacts`, build \
             with the `pjrt` cargo feature, or unset runtime.artifacts",
            cfg.artifacts_dir
        );
    }
    let store = ArtifactStore::load(&cfg.artifacts_dir)?;
    Ok(Backend::Pjrt {
        store,
        prstm: prstm.to_string(),
        validate: validate.to_string(),
        memcached: memcached.to_string(),
    })
}

/// Engine config derived from the system config.
pub fn engine_config(cfg: &SystemConfig, variant: Variant) -> EngineConfig {
    EngineConfig {
        period_s: cfg.period_s,
        variant,
        early_validation: cfg.early_validation,
        early_points: ((1.0 / cfg.early_interval_frac).round() as usize).max(1) - 1,
        chunk_entries: crate::bus::chunking::LOG_CHUNK_ENTRIES,
        log_compaction: cfg.log_compaction,
        chunk_filter: cfg.chunk_filter,
        policy: cfg.policy,
        starvation_limit: cfg.gpu_starvation_limit,
    }
}

/// Cost model derived from the system config.
pub fn cost_model(cfg: &SystemConfig) -> CostModel {
    CostModel {
        bus_h2d: cfg.bus_h2d,
        bus_d2h: cfg.bus_d2h,
        gpu_kernel_latency_s: cfg.gpu_kernel_latency_s,
        gpu_txn_s: cfg.gpu_txn_s,
        gpu_validate_entry_s: cfg.gpu_validate_entry_s,
        gpu_sig_check_s: cfg.gpu_sig_check_s,
        ..CostModel::default()
    }
}

/// Assemble a synthetic-workload engine (paper §V-A..§V-C shapes).
///
/// `cpu_spec` and `gpu_spec` carry the per-device partitions / conflict
/// injection; `gpu_batch` must match the compiled artifact's `b` when the
/// PJRT backend is selected.
#[deprecated(note = "construct through `session::Hetm::builder().synth(...)` instead")]
pub fn build_synth_engine(
    cfg: &SystemConfig,
    variant: Variant,
    cpu_spec: SynthSpec,
    gpu_spec: SynthSpec,
    gpu_batch: usize,
    backend: Backend,
) -> RoundEngine<SynthCpu, SynthGpu> {
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(cfg.n_words));
    let tm = build_guest(cfg.guest, clock);
    let cpu = SynthCpu::new(
        stmr,
        tm,
        cpu_spec,
        cfg.cpu_threads,
        cfg.cpu_txn_s,
        cfg.seed,
    );
    let gpu = SynthGpu::new(
        gpu_spec,
        gpu_batch,
        cfg.gpu_kernel_latency_s,
        cfg.gpu_txn_s,
        cfg.seed ^ 0x9E37_79B9,
    );
    let device = GpuDevice::new(cfg.n_words, cfg.bmp_shift, backend);
    let mut engine = RoundEngine::new(engine_config(cfg, variant), cost_model(cfg), device, cpu, gpu);
    engine.align_replicas();
    engine
}

/// Assemble a memcached engine (paper §V-D).
#[deprecated(note = "construct through `session::Hetm::builder().memcached(...)` instead")]
pub fn build_memcached_engine(
    cfg: &SystemConfig,
    variant: Variant,
    mc: McConfig,
    gpu_batch: usize,
    backend: Backend,
) -> RoundEngine<McCpu, McGpu> {
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(mc.n_words()));
    let mut words = vec![0; mc.n_words()];
    init_cache_words(&mut words, mc.n_sets);
    stmr.install_range(0, &words);

    let tm = build_guest(cfg.guest, clock);
    let world = McWorld::new(mc.clone(), cfg.seed, mc.steal_shift > 0.0);
    let cpu = McCpu::new(
        stmr,
        tm,
        world.clone(),
        mc.clone(),
        cfg.cpu_threads,
        cfg.cpu_txn_s,
    );
    let gpu = McGpu::new(
        world,
        mc.clone(),
        gpu_batch,
        cfg.gpu_kernel_latency_s,
        cfg.gpu_txn_s,
    );
    let device = GpuDevice::new(mc.n_words(), cfg.bmp_shift, backend);
    let mut engine = RoundEngine::new(engine_config(cfg, variant), cost_model(cfg), device, cpu, gpu);
    engine.align_replicas();
    engine
}

/// Shard map derived from the system config over an `n_words` region.
///
/// `cluster.shard_bits` is clamped down until every device owns at least
/// one block (tiny test regions stay usable at any `n_gpus`), and
/// `n_gpus` itself is capped at the region size — one word per device is
/// the hard floor — so absurd `--gpus` values degrade instead of
/// panicking in `ShardMap::new`.
///
/// With `cluster.dev_speed` factors configured the initial layout is the
/// load-proportional [`ShardMap::proportional`] (a faster device starts
/// with proportionally more blocks); uniform factors reproduce the
/// default stripe exactly, so setting `dev_speed = "1,1,..,1"` is
/// bit-identical to leaving it unset.
pub fn shard_map(cfg: &SystemConfig, n_words: usize) -> ShardMap {
    let n_gpus = cfg.n_gpus.clamp(1, n_words.max(1));
    let fits = |bits: u32| {
        1usize
            .checked_shl(bits)
            .and_then(|block| n_gpus.checked_mul(block))
            .is_some_and(|span| span <= n_words)
    };
    let mut bits = cfg.shard_bits;
    while bits > 0 && !fits(bits) {
        bits -= 1;
    }
    if n_gpus > 1 && cfg.dev_speed.len() == n_gpus {
        ShardMap::proportional(n_words, n_gpus, bits, &cfg.dev_speed)
    } else {
        ShardMap::new(n_words, n_gpus, bits)
    }
}

/// Wire the cluster-only config knobs into a built engine: worker
/// threads, per-device speed factors (scaled cost models), and the
/// round-barrier rebalancer (DESIGN.md §14).  Speed factors are applied
/// only when their count matches the (possibly clamped) device count —
/// `shard_map` may have reduced `n_gpus` on tiny regions, and a stale
/// factor list must not panic the builder there.
pub fn apply_cluster_knobs<C: CpuDriver, G: GpuDriver + Send>(
    cfg: &SystemConfig,
    engine: &mut ClusterEngine<C, G>,
) {
    engine.set_threads(cfg.cluster_threads);
    if !cfg.dev_speed.is_empty() && cfg.dev_speed.len() == engine.n_gpus() {
        engine.set_dev_speeds(&cfg.dev_speed);
    }
    if cfg.rebalance {
        engine.set_rebalance(Some(RebalanceCfg {
            interval: cfg.rebalance_interval,
            threshold: cfg.rebalance_threshold,
            max_granules: cfg.rebalance_granules,
        }));
    }
}

/// Assemble a synthetic-workload cluster engine over `cluster.n_gpus`
/// devices.
///
/// `gpu_spec` is the per-device template: each device gets it
/// [`SynthSpec::homed`] onto its own shard (plus `cluster.cross_shard_prob`
/// injection when the cluster has more than one device). With
/// `cluster.n_gpus = 1` construction is element-for-element the same as
/// [`build_synth_engine`] — same seeds, same specs — so the run is
/// bit-identical to the single-device engine.
#[deprecated(note = "construct through `session::Hetm::builder().synth(...).gpus(n)` instead")]
pub fn build_synth_cluster_engine(
    cfg: &SystemConfig,
    variant: Variant,
    cpu_spec: SynthSpec,
    gpu_spec: SynthSpec,
    gpu_batch: usize,
    backend: Backend,
) -> ClusterEngine<SynthCpu, SynthGpu> {
    let map = shard_map(cfg, cfg.n_words);
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(cfg.n_words));
    let tm = build_guest(cfg.guest, clock);
    let cpu = SynthCpu::new(
        stmr,
        tm,
        cpu_spec,
        cfg.cpu_threads,
        cfg.cpu_txn_s,
        cfg.seed,
    );
    let mut devices = Vec::with_capacity(map.n_shards());
    let mut gpus = Vec::with_capacity(map.n_shards());
    for d in 0..map.n_shards() {
        let mut spec = gpu_spec.clone().homed(map.clone(), d);
        if map.n_shards() > 1 {
            spec = spec.with_cross_shard(cfg.cross_shard_prob);
        }
        // Device 0 keeps the single-engine seed; later devices derive.
        let seed = cfg.seed ^ 0x9E37_79B9 ^ (d as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        gpus.push(SynthGpu::new(
            spec,
            gpu_batch,
            cfg.gpu_kernel_latency_s,
            cfg.gpu_txn_s,
            seed,
        ));
        devices.push(GpuDevice::new(cfg.n_words, cfg.bmp_shift, backend.clone()));
    }
    let mut engine = ClusterEngine::new(
        engine_config(cfg, variant),
        cost_model(cfg),
        map,
        devices,
        cpu,
        gpus,
    );
    apply_cluster_knobs(cfg, &mut engine);
    engine.align_replicas();
    engine
}

/// Assemble a memcached cluster engine over `cluster.n_gpus` devices with
/// shard-aware request routing (arrivals go to the device owning their
/// cache set). Bit-identical to [`build_memcached_engine`] at
/// `cluster.n_gpus = 1`.
#[deprecated(note = "construct through `session::Hetm::builder().memcached(...).gpus(n)` instead")]
pub fn build_memcached_cluster_engine(
    cfg: &SystemConfig,
    variant: Variant,
    mc: McConfig,
    gpu_batch: usize,
    backend: Backend,
) -> ClusterEngine<McCpu, McGpu> {
    let map = shard_map(cfg, mc.n_words());
    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(mc.n_words()));
    let mut words = vec![0; mc.n_words()];
    init_cache_words(&mut words, mc.n_sets);
    stmr.install_range(0, &words);

    let tm = build_guest(cfg.guest, clock);
    let world = McWorld::new_sharded(mc.clone(), cfg.seed, mc.steal_shift > 0.0, map.clone());
    let cpu = McCpu::new(
        stmr,
        tm,
        world.clone(),
        mc.clone(),
        cfg.cpu_threads,
        cfg.cpu_txn_s,
    );
    let mut devices = Vec::with_capacity(map.n_shards());
    let mut gpus = Vec::with_capacity(map.n_shards());
    for d in 0..map.n_shards() {
        gpus.push(
            McGpu::new(
                world.clone(),
                mc.clone(),
                gpu_batch,
                cfg.gpu_kernel_latency_s,
                cfg.gpu_txn_s,
            )
            .on_device(d),
        );
        devices.push(GpuDevice::new(mc.n_words(), cfg.bmp_shift, backend.clone()));
    }
    let mut engine = ClusterEngine::new(
        engine_config(cfg, variant),
        cost_model(cfg),
        map,
        devices,
        cpu,
        gpus,
    );
    apply_cluster_knobs(cfg, &mut engine);
    engine.align_replicas();
    engine
}

/// A single-device engine over boxed workload drivers (`Send` so the
/// same driver objects can feed the threaded cluster engine).
pub type WorkloadEngine = RoundEngine<Box<dyn CpuDriver + Send>, Box<dyn GpuDriver + Send>>;

/// A cluster engine over boxed workload drivers.
pub type WorkloadClusterEngine =
    ClusterEngine<Box<dyn CpuDriver + Send>, Box<dyn GpuDriver + Send>>;

/// Shared workload-engine scaffolding: initialized STMR + guest TM +
/// drivers built through the [`Workload`] trait for `map`'s shard count.
///
/// Returns the STMR and guest-TM handles alongside the drivers so the
/// [`crate::session::Session`] facade can offer its `txn` entry point
/// over the same shared region and commit clock the CPU driver uses.
/// `epoch_limit` overrides the commit clock's per-round tick budget
/// (`None` = the default `i32::MAX`; tests force small epochs).
#[allow(clippy::type_complexity)]
pub(crate) fn workload_parts_full(
    cfg: &SystemConfig,
    w: &dyn Workload,
    map: &ShardMap,
    gpu_batch: usize,
    epoch_limit: Option<i32>,
) -> (
    Arc<SharedStmr>,
    Arc<dyn GuestTm>,
    Box<dyn CpuDriver + Send>,
    Vec<Box<dyn GpuDriver + Send>>,
) {
    let n = w.n_words();
    let stmr = Arc::new(SharedStmr::new(n));
    let mut words = vec![0; n];
    w.init_words(&mut words);
    stmr.install_range(0, &words);
    let clock = Arc::new(match epoch_limit {
        Some(l) => GlobalClock::with_epoch_limit(l),
        None => GlobalClock::new(),
    });
    let tm = build_guest(cfg.guest, clock);
    let (cpu, gpus) = w.build(stmr.clone(), tm.clone(), map, gpu_batch, cfg);
    assert_eq!(
        gpus.len(),
        map.n_shards(),
        "workload {} built {} GPU drivers for {} shards",
        w.name(),
        gpus.len(),
        map.n_shards()
    );
    (stmr, tm, cpu, gpus)
}

/// Assemble a single-device engine for any [`Workload`].
#[deprecated(note = "construct through `session::Hetm::builder().workload(...)` instead")]
pub fn build_workload_engine(
    cfg: &SystemConfig,
    variant: Variant,
    w: &dyn Workload,
    gpu_batch: usize,
    backend: Backend,
) -> WorkloadEngine {
    let map = ShardMap::solo(w.n_words());
    let (_, _, cpu, mut gpus) = workload_parts_full(cfg, w, &map, gpu_batch, None);
    let gpu = gpus.remove(0);
    let device = GpuDevice::new(w.n_words(), cfg.bmp_shift, backend);
    let mut engine =
        RoundEngine::new(engine_config(cfg, variant), cost_model(cfg), device, cpu, gpu);
    engine.align_replicas();
    engine
}

/// Assemble a cluster engine for any [`Workload`] over `cluster.n_gpus`
/// devices (bit-identical to [`build_workload_engine`] at `n_gpus = 1`:
/// a one-shard map makes every rehoming the identity and the cluster
/// machinery provably inert).
#[deprecated(note = "construct through `session::Hetm::builder().workload(...).gpus(n)` instead")]
pub fn build_workload_cluster_engine(
    cfg: &SystemConfig,
    variant: Variant,
    w: &dyn Workload,
    gpu_batch: usize,
    backend: Backend,
) -> WorkloadClusterEngine {
    let map = shard_map(cfg, w.n_words());
    let (_, _, cpu, gpus) = workload_parts_full(cfg, w, &map, gpu_batch, None);
    let devices = (0..map.n_shards())
        .map(|_| GpuDevice::new(w.n_words(), cfg.bmp_shift, backend.clone()))
        .collect();
    let mut engine = ClusterEngine::new(
        engine_config(cfg, variant),
        cost_model(cfg),
        map,
        devices,
        cpu,
        gpus,
    );
    apply_cluster_knobs(cfg, &mut engine);
    engine.align_replicas();
    engine
}

/// Build a [`ParallelCpuDriver`] worker set for the synthetic workload:
/// `cfg.cpu_threads` [`SynthCpu`] workers over one shared STMR, each
/// confined to its own contiguous slice of `cpu_spec.partition`, each
/// with its **own** guest-TM instance and commit clock, each modeling one
/// hardware thread (`threads = 1`, so the aggregate rate equals the
/// single-driver configuration's `cpu.threads / cpu.txn_ns`).
///
/// This satisfies the determinism contract of
/// [`crate::coordinator::parallel`]: disjoint partitions + per-worker
/// clocks ⇒ threaded and sequential execution are bit-identical.
pub fn build_parallel_synth_cpu(
    cfg: &SystemConfig,
    cpu_spec: &SynthSpec,
) -> ParallelCpuDriver<SynthCpu> {
    let n_workers = cfg.cpu_threads.max(1);
    assert!(
        cpu_spec.partition.len() >= n_workers,
        "partition of {} words cannot be split across {n_workers} workers",
        cpu_spec.partition.len()
    );
    let stmr = Arc::new(SharedStmr::new(cfg.n_words));
    let base = cpu_spec.partition.start;
    let span = (cpu_spec.partition.len() / n_workers).max(1);
    let workers = (0..n_workers)
        .map(|i| {
            let lo = (base + i * span).min(cpu_spec.partition.end - 1);
            let hi = if i + 1 == n_workers {
                cpu_spec.partition.end
            } else {
                (base + (i + 1) * span).min(cpu_spec.partition.end)
            };
            let mut spec = cpu_spec.clone();
            spec.partition = lo..hi.max(lo + 1);
            let tm = build_guest(cfg.guest, Arc::new(GlobalClock::new()));
            SynthCpu::new(
                stmr.clone(),
                tm,
                spec,
                1,
                cfg.cpu_txn_s,
                cfg.seed.wrapping_add(i as u64),
            )
        })
        .collect();
    ParallelCpuDriver::new(workers)
}

#[cfg(test)]
mod tests {
    // The deprecated engine constructors stay under direct test: they are
    // the independent reference the Session golden suite compares against.
    #![allow(deprecated)]

    use super::*;
    use crate::config::PolicyKind;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::from_raw(&crate::config::Raw::new()).unwrap();
        c.n_words = 1 << 14;
        c.cpu_txn_s = 2e-6;
        c.period_s = 0.004;
        c
    }

    #[test]
    fn synth_engine_round_trips() {
        let c = cfg();
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut e = build_synth_engine(
            &c,
            Variant::Optimized,
            cpu_spec,
            gpu_spec,
            256,
            Backend::Native,
        );
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 2, "partitioned => no conflicts");
        assert!(e.stats.throughput() > 0.0);
    }

    #[test]
    fn memcached_engine_round_trips() {
        let mut c = cfg();
        c.policy = PolicyKind::FavorCpu;
        let mc = McConfig::new(1 << 10);
        let mut e =
            build_memcached_engine(&c, Variant::Optimized, mc, 256, Backend::Native);
        e.run_rounds(2).unwrap();
        assert!(e.stats.cpu_commits > 0);
        assert!(e.stats.gpu_attempts > 0);
        // Balanced parity workload: rounds should commit.
        assert_eq!(e.stats.rounds_committed, 2);
    }

    #[test]
    fn synth_cluster_engine_round_trips() {
        let mut c = cfg();
        c.n_gpus = 2;
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut e = build_synth_cluster_engine(
            &c,
            Variant::Optimized,
            cpu_spec,
            gpu_spec,
            256,
            Backend::Native,
        );
        assert_eq!(e.n_gpus(), 2);
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 2, "partitioned => no conflicts");
        assert!(e.stats.throughput() > 0.0);
        assert!(e.cluster.per_device.iter().all(|d| d.commits > 0));
    }

    #[test]
    fn memcached_cluster_engine_round_trips() {
        let mut c = cfg();
        c.policy = PolicyKind::FavorCpu;
        c.n_gpus = 2;
        let mc = McConfig::new(1 << 10);
        let mut e =
            build_memcached_cluster_engine(&c, Variant::Optimized, mc, 256, Backend::Native);
        e.run_rounds(2).unwrap();
        assert!(e.stats.cpu_commits > 0);
        assert!(e.stats.gpu_attempts > 0);
        assert!(e.cluster.per_device.iter().all(|d| d.attempts > 0));
    }

    #[test]
    fn workload_engines_run_and_pass_oracles() {
        use crate::apps::workload::from_raw;
        use crate::config::Raw;
        let mut c = cfg();
        c.seed = 5;
        // Small regions: align shard stripes with the CPU/GPU half-split
        // so homed GPU traffic stays in its half.
        c.shard_bits = 6;
        for name in ["bank", "kmeans", "zipfkv"] {
            let raw = Raw::parse(
                "[bank]\naccounts = 4096\n[kmeans]\npoints = 2048\n[zipfkv]\nkeys = 2048\n",
            )
            .unwrap();
            // Single device.
            let w = from_raw(name, &raw, &c).unwrap();
            let mut e =
                build_workload_engine(&c, Variant::Optimized, w.as_ref(), 128, Backend::Native);
            e.run_rounds(2).unwrap();
            e.drain().unwrap();
            assert!(e.stats.cpu_commits > 0, "{name}");
            assert!(e.stats.gpu_attempts > 0, "{name}");
            w.check_invariants(e.cpu.stmr()).unwrap();
            // Two sharded devices.
            let mut c2 = c.clone();
            c2.n_gpus = 2;
            let w = from_raw(name, &raw, &c2).unwrap();
            let mut e = build_workload_cluster_engine(
                &c2,
                Variant::Optimized,
                w.as_ref(),
                128,
                Backend::Native,
            );
            assert_eq!(e.n_gpus(), 2, "{name}");
            e.run_rounds(2).unwrap();
            e.drain().unwrap();
            w.check_invariants(e.cpu.stmr()).unwrap();
        }
    }

    #[test]
    fn cluster_builders_apply_thread_knob() {
        let mut c = cfg();
        c.n_gpus = 2;
        c.cluster_threads = 2;
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut e = build_synth_cluster_engine(
            &c,
            Variant::Optimized,
            cpu_spec,
            gpu_spec,
            256,
            Backend::Native,
        );
        assert_eq!(e.threads(), 2);
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 2);
    }

    #[test]
    fn parallel_synth_cpu_drives_a_round_engine() {
        let mut c = cfg();
        c.cpu_threads = 4;
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let cpu = build_parallel_synth_cpu(&c, &cpu_spec);
        assert_eq!(cpu.n_workers(), 4);
        // Workers cover the CPU partition disjointly and aggregate to the
        // modeled 4-thread rate.
        let gpu = SynthGpu::new(gpu_spec, 256, c.gpu_kernel_latency_s, c.gpu_txn_s, 7);
        let device = GpuDevice::new(n, c.bmp_shift, Backend::Native);
        let mut e = RoundEngine::new(
            engine_config(&c, Variant::Optimized),
            cost_model(&c),
            device,
            cpu,
            gpu,
        );
        e.align_replicas();
        e.run_rounds(2).unwrap();
        e.drain().unwrap();
        assert_eq!(e.stats.rounds_committed, 3, "partitioned => clean rounds");
        assert!(e.stats.cpu_commits > 0);
    }

    // (The cpu.parallel × cluster.threads invariance test moved to
    // `session::tests`: the parallel engines are built through the
    // Session builder now.)

    #[test]
    fn shard_map_clamps_bits_for_tiny_regions() {
        let mut c = cfg();
        c.n_gpus = 8;
        c.n_words = 1 << 10; // 8 << 12 would not fit
        let m = shard_map(&c, c.n_words);
        assert_eq!(m.n_shards(), 8);
        assert!(c.n_words >= 8 << m.shard_bits());
        for d in 0..8 {
            assert!(m.owned_words(d) > 0);
        }
    }

    #[test]
    fn engine_config_maps_compaction_and_filter() {
        let mut c = cfg();
        c.log_compaction = true;
        c.chunk_filter = true;
        c.gpu_sig_check_s = 123e-9;
        let ec = engine_config(&c, Variant::Optimized);
        assert!(ec.log_compaction);
        assert!(ec.chunk_filter);
        assert!((cost_model(&c).gpu_sig_check_s - 123e-9).abs() < 1e-18);
        // Off by default, so existing traces are untouched.
        let ec = engine_config(&cfg(), Variant::Optimized);
        assert!(!ec.log_compaction);
        assert!(!ec.chunk_filter);
    }

    #[test]
    fn engine_config_maps_early_points() {
        let mut c = cfg();
        c.early_interval_frac = 0.25;
        assert_eq!(engine_config(&c, Variant::Optimized).early_points, 3);
        c.early_interval_frac = 1.0;
        assert_eq!(engine_config(&c, Variant::Optimized).early_points, 0);
    }
}
