//! SHeTM — Speculative Heterogeneous Transactional Memory.
//!
//! A reproduction of "HeTM: Transactional Memory for Heterogeneous Systems"
//! (Castro, Romano, Ilic, Khan — PACT 2019) as a three-layer Rust + JAX +
//! Pallas system: the Rust coordinator implements the paper's contribution
//! (speculative synchronization rounds, hierarchical conflict detection,
//! non-blocking inter-device synchronization, conflict-aware dispatching),
//! while the simulated accelerator's batch compute runs AOT-compiled
//! jax/Pallas kernels through PJRT.
//!
//! Start with [`session::Hetm`] — the fluent builder returning a
//! [`session::Session`], one facade over both engines with a
//! paper-faithful `txn` entry point (see `examples/quickstart.rs`) — or
//! the `shetm` binary (`rust/src/main.rs`).
//!
//! Layout (see DESIGN.md for the full inventory):
//! - [`stm`] — CPU guest TMs (TinySTM-like, NOrec-like, HTM emulation)
//! - [`gpu`] — the simulated accelerator device + kernel backends
//! - [`bus`] — the PCIe interconnect model
//! - [`runtime`] — PJRT artifact loading/execution
//! - [`coordinator`] — SHeTM itself: rounds, validation, merge, dispatch,
//!   plus [`coordinator::parallel`] (real CPU worker threads)
//! - [`cluster`] — the multi-GPU coordinator: sharded STMR across N
//!   devices, per-device pipelines on real OS threads (`cluster.threads`)
//! - [`apps`] — the [`apps::Workload`] trait + application suite
//!   (synthetic, memcached, bank, kmeans, zipf-kv), each with a built-in
//!   correctness oracle
//! - [`session`] — the public front door: the [`session::Hetm`] builder
//!   and the [`session::Session`] facade over both engines
//! - [`durability`] — round-boundary incremental checkpoints, the
//!   external-txn write-ahead journal, crash-point fault injection, and
//!   the replay-based `Session::recover` machinery (DESIGN.md §13)
//! - [`config`] — dependency-free config system
//! - [`util`] — RNG / Zipf / stats / property-test / bench harnesses
//!
//! Threading never changes results: the threaded cluster engine and the
//! [`coordinator::ParallelCpuDriver`] are bit-identical to their
//! sequential schedules on the same seed (DESIGN.md §8, enforced by
//! `rust/tests/cluster_equivalence.rs`).

#![warn(missing_docs)]
// Panic- and determinism-policy (DESIGN.md §15): the only unsafe block
// is the justified byte-reinterpretation in `runtime::exec` (PJRT
// literal construction), which carries a local `#[allow(unsafe_code)]`.
#![deny(unsafe_code)]

pub mod apps;
pub mod bus;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod durability;
pub mod gpu;
pub mod runtime;
pub mod session;
pub mod stm;
pub mod telemetry;
pub mod util;
pub mod launch;

pub use session::{Hetm, Session};
