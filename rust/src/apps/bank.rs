//! Bank: STAMP-style transfer workload with a conservation oracle.
//!
//! The classic TM correctness probe: `n_accounts` balances live in the
//! STMR (one word each) and every update transaction atomically moves an
//! amount between two accounts, so the **total balance is invariant** —
//! under every conflict-resolution policy, algorithm variant and cluster
//! size.  Any lost or double-applied write (a broken merge, rollback or
//! refresh path) shows up as created or destroyed money.
//!
//! Partitioning follows the synthetic workload: the CPU transfers within
//! the lower half, each GPU within its shard-homed slice of the upper
//! half.  Two contention knobs exist purely to stress the inter-device
//! machinery without ever breaking conservation:
//!
//! * `cross_prob` — a CPU transfer credits an account in the GPU half
//!   (the §V-C-style conflict injection; aborts rounds, conserves money);
//! * `cross_read_prob` — a GPU transfer additionally **reads** an account
//!   on another shard (exercises cross-shard detection; reads cannot
//!   unbalance anything, unlike cross-shard writes racing under favor-GPU
//!   install arbitration).
//!
//! GPU transfers use the device kernel's add mode (`op = 0`): the write
//! values are the transfer deltas (`-amt` / `+amt`), which commute with
//! any serializable interleaving and stay valid across host-side retries.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::workload::{gpu_seed, Workload};
use crate::cluster::shard::ShardMap;
use crate::config::{Raw, SystemConfig};
use crate::coordinator::round::{CpuDriver, CpuSlice, GpuDriver, GpuSlice};
use crate::gpu::{GpuDevice, TxnBatch};
use crate::stm::{GuestTm, SharedStmr, WriteEntry};
use crate::util::Rng;

/// Bank workload configuration (`[bank]` config section).
#[derive(Debug, Clone)]
pub struct BankConfig {
    /// Accounts (= STMR words).
    pub n_accounts: usize,
    /// Starting balance per account.
    pub initial_balance: i32,
    /// Transfer amounts are uniform in `1..=max_transfer`.
    pub max_transfer: i32,
    /// Fraction of transfer transactions (the rest are read-only audits).
    pub update_frac: f64,
    /// Accounts read per audit transaction.
    pub audit_reads: usize,
    /// Probability a CPU transfer credits an account in the GPU half
    /// (inter-device conflict injection).
    pub cross_prob: f64,
    /// Probability a GPU transfer reads an account on another shard
    /// (cross-shard detection stressor; cluster only).
    pub cross_read_prob: f64,
}

impl BankConfig {
    /// Defaults over `n_accounts`.
    pub fn new(n_accounts: usize) -> Self {
        BankConfig {
            n_accounts,
            initial_balance: 1_000,
            max_transfer: 100,
            update_frac: 0.9,
            audit_reads: 8,
            cross_prob: 0.0,
            cross_read_prob: 0.0,
        }
    }

    /// Parse the `[bank]` section.
    pub fn from_raw(raw: &Raw) -> Result<Self> {
        let d = BankConfig::new(raw.get_or("bank.accounts", 1usize << 14)?);
        Ok(BankConfig {
            n_accounts: d.n_accounts,
            initial_balance: raw.get_or("bank.balance", d.initial_balance)?,
            max_transfer: raw.get_or("bank.max_transfer", d.max_transfer)?,
            update_frac: raw.get_or("bank.update_frac", d.update_frac)?,
            audit_reads: raw.get_or("bank.audit_reads", d.audit_reads)?,
            cross_prob: raw.get_or("bank.cross_prob", d.cross_prob)?,
            cross_read_prob: raw.get_or("bank.cross_read_prob", d.cross_read_prob)?,
        })
    }

    /// The conserved quantity.
    pub fn total(&self) -> i64 {
        self.n_accounts as i64 * self.initial_balance as i64
    }
}

/// CPU-side bank driver: transfers + audits through the guest TM.
pub struct BankCpu {
    stmr: Arc<SharedStmr>,
    tm: Arc<dyn GuestTm>,
    cfg: BankConfig,
    /// Accounts this side transfers between.
    partition: Range<usize>,
    /// The other side's accounts (cross-injection targets).
    other: Range<usize>,
    /// Modeled worker threads.
    pub threads: usize,
    /// Per-transaction execution time per worker (virtual seconds).
    pub txn_s: f64,
    rng: Rng,
    read_only: bool,
    debt: f64,
}

impl BankCpu {
    /// Build a CPU driver over an initialized bank STMR.
    pub fn new(
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        cfg: BankConfig,
        partition: Range<usize>,
        other: Range<usize>,
        threads: usize,
        txn_s: f64,
        seed: u64,
    ) -> Self {
        assert_eq!(stmr.len(), cfg.n_accounts);
        assert!(partition.len() >= 2, "need two accounts to transfer");
        BankCpu {
            stmr,
            tm,
            cfg,
            partition,
            other,
            threads,
            txn_s,
            rng: Rng::new(seed),
            read_only: false,
            debt: 0.0,
        }
    }

    /// Transactions per virtual second at full tilt.
    pub fn rate(&self) -> f64 {
        self.threads as f64 / self.txn_s
    }

    fn run_one(&mut self, log: &mut Vec<WriteEntry>) -> u32 {
        let part_len = self.partition.len();
        let base = self.partition.start;
        let transfer = !self.read_only && self.rng.chance(self.cfg.update_frac);

        if transfer {
            // Pre-draw the access set (retries must replay it).
            let a = base + self.rng.below_usize(part_len);
            let b = if self.cfg.cross_prob > 0.0 && self.rng.chance(self.cfg.cross_prob) {
                self.other.start + self.rng.below_usize(self.other.len())
            } else {
                let mut b = base + self.rng.below_usize(part_len);
                while b == a {
                    b = base + self.rng.below_usize(part_len);
                }
                b
            };
            let amt = 1 + self.rng.below(self.cfg.max_transfer as u64) as i32;
            let r = self.tm.execute_into(
                &self.stmr,
                &mut |tx| {
                    let ra = tx.read(a)?;
                    let rb = tx.read(b)?;
                    tx.write(a, ra.wrapping_sub(amt))?;
                    tx.write(b, rb.wrapping_add(amt))?;
                    Ok(())
                },
                log,
            );
            r.retries + 1
        } else {
            // Audit: sum a handful of balances, write nothing.
            let reads: Vec<usize> = (0..self.cfg.audit_reads)
                .map(|_| base + self.rng.below_usize(part_len))
                .collect();
            let r = self.tm.execute_into(
                &self.stmr,
                &mut |tx| {
                    let mut acc = 0i64;
                    for &w in &reads {
                        acc += tx.read(w)? as i64;
                    }
                    let _ = acc;
                    Ok(())
                },
                log,
            );
            r.retries + 1
        }
    }
}

impl CpuDriver for BankCpu {
    fn epoch_reset(&mut self, base: i64) {
        self.tm.epoch_reset(base);
    }

    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        let want = dur_s * self.rate() + self.debt;
        let n = want.floor() as u64;
        self.debt = want - n as f64;
        let mut attempts = 0u64;
        for _ in 0..n {
            attempts += self.run_one(log) as u64;
        }
        CpuSlice {
            commits: n,
            attempts,
        }
    }

    fn stmr(&self) -> &SharedStmr {
        &self.stmr
    }

    fn set_read_only(&mut self, ro: bool) {
        self.read_only = ro;
    }
    // snapshot/rollback: the trait's default SharedStmr path — this driver
    // is the favor-GPU regression coverage for it.
}

#[derive(Debug, Clone)]
struct BankTxn {
    reads: Vec<i32>,
    writes: Vec<i32>,
    deltas: Vec<i32>,
    update: bool,
}

/// GPU-side bank driver: add-mode transfer batches over shard-homed
/// accounts, with host-side retry of priority-rule losers (deltas stay
/// valid across retries — adds commute).
pub struct BankGpu {
    cfg: BankConfig,
    partition: Range<usize>,
    map: ShardMap,
    dev: usize,
    /// Batch size.
    pub batch: usize,
    /// Kernel-activation latency (virtual seconds).
    pub kernel_latency_s: f64,
    /// Per-transaction device time (virtual seconds).
    pub txn_s: f64,
    rng: Rng,
    retry: Vec<BankTxn>,
    budget_carry: f64,
}

impl BankGpu {
    /// Build a GPU driver for shard `dev` of `map`.
    pub fn new(
        cfg: BankConfig,
        partition: Range<usize>,
        map: ShardMap,
        dev: usize,
        batch: usize,
        kernel_latency_s: f64,
        txn_s: f64,
        seed: u64,
    ) -> Self {
        assert!(dev < map.n_shards());
        assert!(partition.len() >= 2);
        BankGpu {
            cfg,
            partition,
            map,
            dev,
            batch,
            kernel_latency_s,
            txn_s,
            rng: Rng::new(seed),
            retry: Vec::new(),
            budget_carry: 0.0,
        }
    }

    /// Device seconds one kernel activation costs.
    pub fn batch_cost(&self) -> f64 {
        self.kernel_latency_s + self.batch as f64 * self.txn_s
    }

    /// Peak transactions per device second.
    pub fn rate(&self) -> f64 {
        self.batch as f64 / self.batch_cost()
    }

    fn home(&self, w: usize) -> usize {
        self.map.rehome(w, self.dev)
    }

    fn gen_txn(&mut self) -> BankTxn {
        let part_len = self.partition.len();
        let base = self.partition.start;
        let update = self.rng.chance(self.cfg.update_frac);
        if update {
            let a = self.home(base + self.rng.below_usize(part_len));
            // Rehoming can alias two draws onto one word; a == b would put
            // the same word twice in the scatter set, so redraw.
            let mut b = self.home(base + self.rng.below_usize(part_len));
            let mut guard = 0;
            while b == a && guard < 64 {
                b = self.home(base + self.rng.below_usize(part_len));
                guard += 1;
            }
            if b == a {
                // Pathologically tiny shard: degrade to a no-op transfer
                // on one account pair rather than corrupting the batch.
                return BankTxn {
                    reads: vec![a as i32],
                    writes: Vec::new(),
                    deltas: Vec::new(),
                    update: false,
                };
            }
            let amt = 1 + self.rng.below(self.cfg.max_transfer as u64) as i32;
            let mut reads = vec![a as i32, b as i32];
            if self.map.n_shards() > 1
                && self.cfg.cross_read_prob > 0.0
                && self.rng.chance(self.cfg.cross_read_prob)
            {
                // Cross-shard read: audit an account owned elsewhere.
                let r = self.rng.below((self.map.n_shards() - 1) as u64) as usize;
                let other = if r >= self.dev { r + 1 } else { r };
                reads.push(self.map.rehome(a as usize, other) as i32);
            }
            BankTxn {
                reads,
                writes: vec![a as i32, b as i32],
                deltas: vec![-amt, amt],
                update: true,
            }
        } else {
            let reads = (0..self.cfg.audit_reads)
                .map(|_| self.home(base + self.rng.below_usize(part_len)) as i32)
                .collect();
            BankTxn {
                reads,
                writes: Vec::new(),
                deltas: Vec::new(),
                update: false,
            }
        }
    }

    fn fill_batch(&mut self) -> (TxnBatch, Vec<BankTxn>) {
        let r = self.cfg.audit_reads.max(3);
        let w = 2;
        let mut batch = TxnBatch::empty(self.batch, r, w);
        let mut txns = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            let t = if let Some(t) = self.retry.pop() {
                t
            } else {
                self.gen_txn()
            };
            for (j, &a) in t.reads.iter().take(r).enumerate() {
                batch.read_idx[i * r + j] = a;
            }
            for (j, (&a, &d)) in t.writes.iter().zip(&t.deltas).enumerate() {
                batch.write_idx[i * w + j] = a;
                batch.write_val[i * w + j] = d;
            }
            batch.op[i] = 0; // add semantics: values are transfer deltas
            txns.push(t);
        }
        (batch, txns)
    }
}

impl GpuDriver for BankGpu {
    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
        let mut out = GpuSlice::default();
        let cost = self.batch_cost();
        let mut left = budget_s + self.budget_carry;
        while left >= cost {
            let (batch, txns) = self.fill_batch();
            let r = device.run_txn_batch(&batch)?;
            for (i, t) in txns.into_iter().enumerate() {
                if r.commit[i] == 0 && t.update {
                    self.retry.push(t); // PR-STM loser: host-side retry
                }
            }
            out.commits += r.n_commits as u64;
            out.attempts += self.batch as u64;
            out.batches += 1;
            out.busy_s += cost;
            left -= cost;
        }
        self.budget_carry = left;
        Ok(out)
    }

    fn on_round_end(&mut self, _committed: bool) {
        self.budget_carry = 0.0;
        // Round aborts undo the adds wholesale (shadow rollback), so the
        // conserved total is untouched either way; queued intra-batch
        // losers remain valid (deltas, not absolute values).
    }
}

/// Bank as a [`Workload`]: conservation oracle over the committed state.
pub struct BankWorkload {
    /// Workload configuration.
    pub cfg: BankConfig,
    seed: u64,
}

impl BankWorkload {
    /// Wrap a config; `seed` feeds the per-driver RNGs.
    pub fn new(cfg: BankConfig, seed: u64) -> Self {
        BankWorkload { cfg, seed }
    }
}

impl Workload for BankWorkload {
    fn name(&self) -> &str {
        "bank"
    }

    fn n_words(&self) -> usize {
        self.cfg.n_accounts
    }

    fn init_words(&self, words: &mut [i32]) {
        words.fill(self.cfg.initial_balance);
    }

    fn build(
        &self,
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        map: &ShardMap,
        gpu_batch: usize,
        cfg: &SystemConfig,
    ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>) {
        let n = self.cfg.n_accounts;
        let cpu = BankCpu::new(
            stmr,
            tm,
            self.cfg.clone(),
            0..n / 2,
            n / 2..n,
            cfg.cpu_threads,
            cfg.cpu_txn_s,
            self.seed,
        );
        let mut gpus: Vec<Box<dyn GpuDriver + Send>> = Vec::with_capacity(map.n_shards());
        for d in 0..map.n_shards() {
            gpus.push(Box::new(BankGpu::new(
                self.cfg.clone(),
                n / 2..n,
                map.clone(),
                d,
                gpu_batch,
                cfg.gpu_kernel_latency_s,
                cfg.gpu_txn_s,
                gpu_seed(self.seed, d),
            )));
        }
        (Box::new(cpu), gpus)
    }

    fn check_invariants(&self, stmr: &SharedStmr) -> Result<()> {
        if stmr.len() != self.cfg.n_accounts {
            bail!(
                "bank: STMR has {} words, expected {} accounts",
                stmr.len(),
                self.cfg.n_accounts
            );
        }
        let mut sum = 0i64;
        for w in 0..stmr.len() {
            sum += stmr.load(w) as i64;
        }
        let want = self.cfg.total();
        if sum != want {
            bail!(
                "bank: conservation violated — total balance {sum}, expected \
                 {want} (delta {})",
                sum - want
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Backend;
    use crate::stm::tinystm::TinyStm;
    use crate::stm::GlobalClock;

    fn bank_stmr(cfg: &BankConfig) -> Arc<SharedStmr> {
        let stmr = Arc::new(SharedStmr::new(cfg.n_accounts));
        let mut words = vec![0; cfg.n_accounts];
        words.fill(cfg.initial_balance);
        stmr.install_range(0, &words);
        stmr
    }

    #[test]
    fn cpu_transfers_conserve_total() {
        let cfg = BankConfig::new(1 << 10);
        let stmr = bank_stmr(&cfg);
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let n = cfg.n_accounts;
        let total = cfg.total();
        let mut cpu = BankCpu::new(stmr.clone(), tm, cfg, 0..n / 2, n / 2..n, 8, 2e-6, 1);
        let mut log = Vec::new();
        let s = cpu.run(0.005, &mut log);
        assert!(s.commits > 1_000);
        assert!(!log.is_empty(), "transfers must log write-sets");
        let sum: i64 = (0..n).map(|w| stmr.load(w) as i64).sum();
        assert_eq!(sum, total);
        // No cross injection: all writes in the CPU half.
        assert!(log.iter().all(|e| (e.addr as usize) < n / 2));
    }

    #[test]
    fn cpu_read_only_mode_audits_without_logging() {
        let cfg = BankConfig::new(1 << 10);
        let stmr = bank_stmr(&cfg);
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let n = cfg.n_accounts;
        let mut cpu = BankCpu::new(stmr, tm, cfg, 0..n / 2, n / 2..n, 8, 2e-6, 1);
        cpu.set_read_only(true);
        let mut log = Vec::new();
        let s = cpu.run(0.002, &mut log);
        assert!(s.commits > 0);
        assert!(log.is_empty());
    }

    #[test]
    fn cross_injection_writes_into_other_half() {
        let mut cfg = BankConfig::new(1 << 10);
        cfg.cross_prob = 1.0;
        let stmr = bank_stmr(&cfg);
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let n = cfg.n_accounts;
        let mut cpu = BankCpu::new(stmr, tm, cfg, 0..n / 2, n / 2..n, 8, 2e-6, 2);
        let mut log = Vec::new();
        cpu.run(0.002, &mut log);
        assert!(log.iter().any(|e| (e.addr as usize) >= n / 2));
    }

    #[test]
    fn gpu_transfers_conserve_total_on_device() {
        let cfg = BankConfig::new(1 << 10);
        let n = cfg.n_accounts;
        let total = cfg.total();
        let map = ShardMap::solo(n);
        let mut gpu = BankGpu::new(cfg.clone(), n / 2..n, map, 0, 128, 20e-6, 230e-9, 3);
        let mut d = GpuDevice::new(n, 0, Backend::Native);
        for w in 0..n {
            d.stmr_mut()[w] = cfg.initial_balance;
        }
        d.begin_round();
        let s = gpu.run(&mut d, 0.01).unwrap();
        assert!(s.batches > 0 && s.commits > 0);
        let sum: i64 = d.stmr().iter().map(|&v| v as i64).sum();
        assert_eq!(sum, total, "device-side adds conserve the total");
        // All GPU writes stay in the upper half (shift 0: granule == word).
        for w in d.ws_bmp().iter_marked() {
            assert!(w >= n / 2);
        }
    }

    #[test]
    fn sharded_gpu_writes_only_owned_accounts() {
        let cfg = BankConfig::new(1 << 12);
        let n = cfg.n_accounts;
        let map = ShardMap::new(n, 4, 8);
        for dev in 0..4 {
            let mut gpu = BankGpu::new(
                cfg.clone(),
                n / 2..n,
                map.clone(),
                dev,
                128,
                20e-6,
                230e-9,
                7 + dev as u64,
            );
            let mut d = GpuDevice::new(n, 0, Backend::Native);
            d.begin_round();
            gpu.run(&mut d, 0.005).unwrap();
            for (s, e) in d.ws_bmp().dirty_word_ranges() {
                for w in s..e {
                    assert_eq!(map.owner(w), dev, "device {dev} wrote foreign word {w}");
                }
            }
        }
    }

    #[test]
    fn workload_oracle_catches_lost_money() {
        let wl = BankWorkload::new(BankConfig::new(64), 1);
        let stmr = SharedStmr::new(64);
        let mut words = vec![0; 64];
        wl.init_words(&mut words);
        stmr.install_range(0, &words);
        wl.check_invariants(&stmr).unwrap();
        stmr.store(5, stmr.load(5) - 1);
        assert!(wl.check_invariants(&stmr).is_err());
    }
}
