//! Zipf-KV: skewed read/update key-value store with a version-monotonicity
//! oracle over the CPU write log.
//!
//! Each key owns two adjacent STMR words — `value` at `2k`, `version` at
//! `2k + 1` — and every update transaction bumps the version while
//! rewriting the value.  Key popularity is Zipfian with tunable `theta`,
//! so a handful of hot keys absorb most of the traffic (the pointer-ish,
//! skewed shape uniform synthetics cannot produce).  Keys are split
//! CPU-low / GPU-high like the other apps, GPU keys shard-homed through
//! the cluster's [`ShardMap`]; `hot_prob` sends a GPU update to a **hot
//! key of the GPU half regardless of owner** — deliberate cross-shard
//! write traffic for the inter-device detection machinery.
//!
//! **Oracle.** The CPU side records every write-log entry it generates
//! into a shared trace; at round end the trace's pending tail is promoted
//! iff the round's CPU commits survived (they always do except under
//! favor-GPU aborts, where the engine rolls the CPU back and truncates
//! the very same entries from its shipping log).  `check_invariants`
//! replays the surviving trace: for every version word the recorded
//! values must be non-decreasing in commit order, and the final committed
//! state must be at least as fresh as the last surviving record.  Any
//! misordered merge, lost rollback or stale-replica increment surfaces as
//! a version that went backwards.
//!
//! GPU updates precompute `version + 1` host-side from the device replica
//! (store mode) with both key words in the read set — sound per the
//! PR-STM priority-rule argument in [`super::kmeans`]'s module docs;
//! losers are regenerated, never replayed.

use std::ops::Range;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::workload::{gpu_seed, Workload};
use crate::cluster::shard::ShardMap;
use crate::config::{PolicyKind, Raw, SystemConfig};
use crate::coordinator::round::{CpuDriver, CpuSlice, GpuDriver, GpuSlice};
use crate::gpu::{GpuDevice, TxnBatch};
use crate::stm::{GuestTm, SharedStmr, WriteEntry};
use crate::util::{Rng, Zipf};

/// Zipf-KV workload configuration (`[zipfkv]` config section).
#[derive(Debug, Clone)]
pub struct ZipfKvConfig {
    /// Keys (two STMR words each).
    pub n_keys: usize,
    /// Zipf exponent over each side's key ranks (0 = uniform).
    pub theta: f64,
    /// Fraction of update transactions.
    pub update_frac: f64,
    /// Keys read per read-only transaction.
    pub reads: usize,
    /// Hot-key pool: the `hot_keys` most popular keys of the GPU half.
    pub hot_keys: usize,
    /// Probability a GPU update targets a hot key regardless of its owner
    /// shard (cross-shard write traffic; cluster only).
    pub hot_prob: f64,
    /// Probability a CPU update targets the CPU-side hot pool instead of
    /// the zipf draw (`0.0` = off, the default — the RNG stream is then
    /// untouched, preserving bit-identity with pre-knob runs).  The pool
    /// concentrates CPU write traffic — and with it the shipped-entry
    /// load the elastic rebalancer watches — onto few ownership blocks.
    pub cpu_hot_prob: f64,
    /// Key step between CPU hot-pool members (`0` = dense pool).  Setting
    /// it to `n_shards` blocks' worth of keys aliases the whole pool onto
    /// ONE device of a striped layout — the worst case the rebalancer
    /// exists to fix, since a migrated layout can spread those same
    /// blocks across devices.
    pub hot_stride: usize,
    /// Keys the CPU hot-pool base advances per synchronization round
    /// (`0` = stationary): the drifting hotspot of the rebalance bench.
    pub drift: usize,
}

impl ZipfKvConfig {
    /// Defaults over `n_keys`.
    pub fn new(n_keys: usize) -> Self {
        ZipfKvConfig {
            n_keys,
            theta: 0.8,
            update_frac: 0.2,
            reads: 4,
            hot_keys: 16,
            hot_prob: 0.0,
            cpu_hot_prob: 0.0,
            hot_stride: 0,
            drift: 0,
        }
    }

    /// Parse the `[zipfkv]` section.
    pub fn from_raw(raw: &Raw) -> Result<Self> {
        let d = ZipfKvConfig::new(raw.get_or("zipfkv.keys", 1usize << 13)?);
        Ok(ZipfKvConfig {
            n_keys: d.n_keys,
            theta: raw.get_or("zipfkv.theta", d.theta)?,
            update_frac: raw.get_or("zipfkv.update_frac", d.update_frac)?,
            reads: raw.get_or("zipfkv.reads", d.reads)?,
            hot_keys: raw.get_or("zipfkv.hot_keys", d.hot_keys)?,
            hot_prob: raw.get_or("zipfkv.hot_prob", d.hot_prob)?,
            cpu_hot_prob: raw.get_or("zipfkv.cpu_hot_prob", d.cpu_hot_prob)?,
            hot_stride: raw.get_or("zipfkv.hot_stride", d.hot_stride)?,
            drift: raw.get_or("zipfkv.drift", d.drift)?,
        })
    }

    /// STMR words.
    pub fn n_words(&self) -> usize {
        2 * self.n_keys
    }

    /// Word holding key `k`'s value.
    pub fn val_w(&self, k: usize) -> usize {
        2 * k
    }

    /// Word holding key `k`'s version.
    pub fn ver_w(&self, k: usize) -> usize {
        2 * k + 1
    }
}

/// The shared write-log trace behind the monotonicity oracle.
pub struct ZkTrace {
    /// Entries of the round in flight (fate unknown).
    pending: Vec<WriteEntry>,
    /// Entries whose round outcome kept the CPU's commits.
    committed: Vec<WriteEntry>,
    /// Under favor-GPU a failed round rolls the CPU back, so the pending
    /// tail must be discarded exactly when the engine truncates its log.
    cpu_loses_on_abort: bool,
    /// Rounds whose tail was promoted / discarded (diagnostics).
    pub rounds_promoted: u64,
    /// Rounds whose tail was discarded.
    pub rounds_discarded: u64,
}

impl ZkTrace {
    fn new(cpu_loses_on_abort: bool) -> Self {
        ZkTrace {
            pending: Vec::new(),
            committed: Vec::new(),
            cpu_loses_on_abort,
            rounds_promoted: 0,
            rounds_discarded: 0,
        }
    }

    fn record(&mut self, entries: &[WriteEntry]) {
        self.pending.extend_from_slice(entries);
    }

    /// Round boundary: promote or discard the pending tail.
    fn round_end(&mut self, committed: bool) {
        if committed || !self.cpu_loses_on_abort {
            self.committed.append(&mut self.pending);
            self.rounds_promoted += 1;
        } else {
            self.pending.clear();
            self.rounds_discarded += 1;
        }
    }

    /// Surviving entries recorded so far (pending tail excluded).
    pub fn surviving(&self) -> &[WriteEntry] {
        &self.committed
    }

    /// Post-recovery repair: the round in flight at the crash is gone, so
    /// its pending tail must never be promoted; and if the trace has no
    /// promoted history at all (recovery replay recorded nothing — e.g. a
    /// future snapshot-restore path), the recovered carried log is itself
    /// a sound oracle seed: every carried entry is a committed CPU write
    /// already reflected in the recovered STMR.
    fn on_recovered(&mut self, carried: &[WriteEntry]) {
        self.pending.clear();
        if self.committed.is_empty() {
            self.committed.extend_from_slice(carried);
        }
    }
}

/// CPU-side zipf-kv driver.
pub struct ZipfKvCpu {
    stmr: Arc<SharedStmr>,
    tm: Arc<dyn GuestTm>,
    cfg: ZipfKvConfig,
    trace: Arc<Mutex<ZkTrace>>,
    /// Key range this side serves.
    partition: Range<usize>,
    /// Modeled worker threads.
    pub threads: usize,
    /// Per-transaction execution time per worker (virtual seconds).
    pub txn_s: f64,
    rng: Rng,
    zipf: Zipf,
    read_only: bool,
    debt: f64,
    /// Current base of the CPU hot pool; advances by `cfg.drift` keys
    /// per synchronization round (the drifting hotspot).
    hot_base: usize,
}

impl ZipfKvCpu {
    /// Build a CPU driver over a zeroed zipf-kv STMR.
    pub fn new(
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        cfg: ZipfKvConfig,
        trace: Arc<Mutex<ZkTrace>>,
        partition: Range<usize>,
        threads: usize,
        txn_s: f64,
        seed: u64,
    ) -> Self {
        assert_eq!(stmr.len(), cfg.n_words());
        assert!(!partition.is_empty());
        let zipf = Zipf::new(partition.len() as u64, cfg.theta);
        ZipfKvCpu {
            stmr,
            tm,
            cfg,
            trace,
            partition,
            threads,
            txn_s,
            rng: Rng::new(seed),
            zipf,
            read_only: false,
            debt: 0.0,
            hot_base: 0,
        }
    }

    /// Transactions per virtual second at full tilt.
    pub fn rate(&self) -> f64 {
        self.threads as f64 / self.txn_s
    }

    fn sample_key(&mut self) -> usize {
        self.partition.start + self.zipf.sample(&mut self.rng) as usize
    }

    /// Draw from the CPU hot pool: `hot_keys` keys spaced `hot_stride`
    /// apart (dense when 0) starting at the drifting `hot_base`.
    fn hot_key(&mut self) -> usize {
        let len = self.partition.len();
        let pool = self.cfg.hot_keys.min(len).max(1);
        let i = self.rng.below_usize(pool);
        let step = self.cfg.hot_stride.max(1);
        self.partition.start + (self.hot_base + i * step) % len
    }

    fn run_one(&mut self, log: &mut Vec<WriteEntry>) -> u32 {
        let update = !self.read_only && self.rng.chance(self.cfg.update_frac);
        if update {
            // The `> 0.0` short-circuit keeps the RNG stream untouched at
            // the default, preserving bit-identity with pre-knob runs.
            let k = if self.cfg.cpu_hot_prob > 0.0 && self.rng.chance(self.cfg.cpu_hot_prob) {
                self.hot_key()
            } else {
                self.sample_key()
            };
            let (vw, verw) = (self.cfg.val_w(k), self.cfg.ver_w(k));
            let val = self.rng.below(1 << 20) as i32;
            let r = self.tm.execute_into(
                &self.stmr,
                &mut |tx| {
                    let _old = tx.read(vw)?;
                    let ver = tx.read(verw)?;
                    tx.write(vw, val)?;
                    tx.write(verw, ver.wrapping_add(1))?;
                    Ok(())
                },
                log,
            );
            r.retries + 1
        } else {
            let keys: Vec<usize> = (0..self.cfg.reads).map(|_| self.sample_key()).collect();
            let r = self.tm.execute_into(
                &self.stmr,
                &mut |tx| {
                    for &k in &keys {
                        let _v = tx.read(self.cfg.val_w(k))?;
                        let _ver = tx.read(self.cfg.ver_w(k))?;
                    }
                    Ok(())
                },
                log,
            );
            r.retries + 1
        }
    }
}

impl CpuDriver for ZipfKvCpu {
    fn epoch_reset(&mut self, base: i64) {
        self.tm.epoch_reset(base);
        if self.cfg.drift > 0 {
            self.hot_base = (self.hot_base + self.cfg.drift) % self.partition.len();
        }
    }

    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        let before = log.len();
        let want = dur_s * self.rate() + self.debt;
        let n = want.floor() as u64;
        self.debt = want - n as f64;
        let mut attempts = 0u64;
        for _ in 0..n {
            attempts += self.run_one(log) as u64;
        }
        // Feed the oracle's trace with exactly what this slice logged.
        if log.len() > before {
            crate::util::sync::lock(&self.trace).record(&log[before..]);
        }
        CpuSlice {
            commits: n,
            attempts,
        }
    }

    fn stmr(&self) -> &SharedStmr {
        &self.stmr
    }

    fn set_read_only(&mut self, ro: bool) {
        self.read_only = ro;
    }
    // snapshot/rollback: the trait's default SharedStmr path.
}

/// GPU-side zipf-kv driver (device `dev`, shard-homed keys).
pub struct ZipfKvGpu {
    cfg: ZipfKvConfig,
    trace: Arc<Mutex<ZkTrace>>,
    map: ShardMap,
    dev: usize,
    /// Key range the GPU side serves (before homing).
    partition: Range<usize>,
    /// Batch size.
    pub batch: usize,
    /// Kernel-activation latency (virtual seconds).
    pub kernel_latency_s: f64,
    /// Per-transaction device time (virtual seconds).
    pub txn_s: f64,
    rng: Rng,
    zipf: Zipf,
    budget_carry: f64,
}

impl ZipfKvGpu {
    /// Build the driver for shard `dev` of `map`.
    pub fn new(
        cfg: ZipfKvConfig,
        trace: Arc<Mutex<ZkTrace>>,
        map: ShardMap,
        dev: usize,
        partition: Range<usize>,
        batch: usize,
        kernel_latency_s: f64,
        txn_s: f64,
        seed: u64,
    ) -> Self {
        assert!(dev < map.n_shards());
        assert!(
            map.n_shards() == 1 || map.shard_bits() >= 1,
            "zipfkv needs >= 2-word shard blocks to keep key pairs whole"
        );
        let zipf = Zipf::new(partition.len() as u64, cfg.theta);
        ZipfKvGpu {
            cfg,
            trace,
            map,
            dev,
            partition,
            batch,
            kernel_latency_s,
            txn_s,
            rng: Rng::new(seed),
            zipf,
            budget_carry: 0.0,
        }
    }

    /// Device seconds one kernel activation costs.
    pub fn batch_cost(&self) -> f64 {
        self.kernel_latency_s + self.batch as f64 * self.txn_s
    }

    /// Home a key onto this device's shard (pairs stay whole because
    /// shard blocks are at least two words and stripe-aligned).
    fn home_key(&self, k: usize) -> usize {
        self.map.rehome(self.cfg.val_w(k), self.dev) / 2
    }

    fn sample_key(&mut self) -> usize {
        let k = self.partition.start + self.zipf.sample(&mut self.rng) as usize;
        self.home_key(k)
    }

    fn fill_batch(&mut self, stmr: &[i32]) -> TxnBatch {
        let r = (2 * self.cfg.reads).max(2);
        let w = 2;
        let mut batch = TxnBatch::empty(self.batch, r, w);
        for i in 0..self.batch {
            if self.rng.chance(self.cfg.update_frac) {
                let hot = self.map.n_shards() > 1
                    && self.cfg.hot_prob > 0.0
                    && self.rng.chance(self.cfg.hot_prob);
                let k = if hot {
                    // A hot key of the GPU half, wherever it is homed:
                    // deliberate cross-shard write traffic.
                    self.partition.start
                        + self
                            .rng
                            .below_usize(self.cfg.hot_keys.min(self.partition.len()))
                } else {
                    self.sample_key()
                };
                let (vw, verw) = (self.cfg.val_w(k), self.cfg.ver_w(k));
                batch.read_idx[i * r] = vw as i32;
                batch.read_idx[i * r + 1] = verw as i32;
                batch.write_idx[i * w] = vw as i32;
                batch.write_val[i * w] = self.rng.below(1 << 20) as i32;
                batch.write_idx[i * w + 1] = verw as i32;
                // Host-side RMW from the replica; the read-set entry above
                // makes PR-STM abort us if an earlier committer bumps it.
                batch.write_val[i * w + 1] = stmr[verw].wrapping_add(1);
            } else {
                for j in 0..self.cfg.reads {
                    let k = self.sample_key();
                    batch.read_idx[i * r + 2 * j] = self.cfg.val_w(k) as i32;
                    batch.read_idx[i * r + 2 * j + 1] = self.cfg.ver_w(k) as i32;
                }
            }
            batch.op[i] = 1; // store: absolute precomputed values
        }
        batch
    }
}

impl GpuDriver for ZipfKvGpu {
    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
        let mut out = GpuSlice::default();
        let cost = self.batch_cost();
        let mut left = budget_s + self.budget_carry;
        while left >= cost {
            let batch = self.fill_batch(device.stmr());
            let r = device.run_txn_batch(&batch)?;
            // Losers regenerate from fresh replica state (no verbatim
            // retry: their precomputed versions are stale).
            out.commits += r.n_commits as u64;
            out.attempts += self.batch as u64;
            out.batches += 1;
            out.busy_s += cost;
            left -= cost;
        }
        self.budget_carry = left;
        Ok(out)
    }

    fn on_round_end(&mut self, committed: bool) {
        self.budget_carry = 0.0;
        // Device 0 owns the round boundary of the oracle trace (every
        // device sees the same `committed` for a given round).
        if self.dev == 0 {
            crate::util::sync::lock(&self.trace).round_end(committed);
        }
    }
}

/// Zipf-KV as a [`Workload`].
pub struct ZipfKvWorkload {
    /// Workload configuration.
    pub cfg: ZipfKvConfig,
    seed: u64,
    trace: Arc<Mutex<ZkTrace>>,
}

impl ZipfKvWorkload {
    /// Wrap a config; the system config supplies the seed and the policy
    /// (which decides whether aborted rounds discard CPU log entries).
    pub fn new(cfg: ZipfKvConfig, sys: &SystemConfig) -> Self {
        let cpu_loses = sys.policy == PolicyKind::FavorGpu;
        ZipfKvWorkload {
            cfg,
            seed: sys.seed,
            trace: Arc::new(Mutex::new(ZkTrace::new(cpu_loses))),
        }
    }

    /// The shared oracle trace (tests peek at promotion counters).
    pub fn trace(&self) -> Arc<Mutex<ZkTrace>> {
        self.trace.clone()
    }
}

impl Workload for ZipfKvWorkload {
    fn name(&self) -> &str {
        "zipfkv"
    }

    fn n_words(&self) -> usize {
        self.cfg.n_words()
    }

    fn build(
        &self,
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        map: &ShardMap,
        gpu_batch: usize,
        cfg: &SystemConfig,
    ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>) {
        let nk = self.cfg.n_keys;
        let cpu = ZipfKvCpu::new(
            stmr,
            tm,
            self.cfg.clone(),
            self.trace.clone(),
            0..nk / 2,
            cfg.cpu_threads,
            cfg.cpu_txn_s,
            self.seed,
        );
        let mut gpus: Vec<Box<dyn GpuDriver + Send>> = Vec::with_capacity(map.n_shards());
        for d in 0..map.n_shards() {
            gpus.push(Box::new(ZipfKvGpu::new(
                self.cfg.clone(),
                self.trace.clone(),
                map.clone(),
                d,
                nk / 2..nk,
                gpu_batch,
                cfg.gpu_kernel_latency_s,
                cfg.gpu_txn_s,
                gpu_seed(self.seed, d),
            )));
        }
        (Box::new(cpu), gpus)
    }

    fn check_invariants(&self, stmr: &SharedStmr) -> Result<()> {
        if stmr.len() != self.cfg.n_words() {
            bail!("zipfkv: STMR size mismatch");
        }
        let trace = crate::util::sync::lock(&self.trace);
        // Per-key version monotonicity over the surviving CPU write log
        // (record order == the guest TM's commit order).
        // BTreeMap, not HashMap: the oracle iterates `last` below, and a
        // Default-hashed order would make the first-reported failure (and
        // any diagnostic output) vary run to run.
        let mut last: std::collections::BTreeMap<u32, (i32, i32)> = Default::default();
        for e in trace.surviving() {
            if e.addr as usize % 2 == 0 {
                continue; // value word
            }
            if let Some(&(prev, prev_ts)) = last.get(&e.addr) {
                if e.val < prev {
                    bail!(
                        "zipfkv: version of word {} went backwards: {} (ts {}) \
                         after {} (ts {})",
                        e.addr,
                        e.val,
                        e.ts,
                        prev,
                        prev_ts
                    );
                }
            }
            last.insert(e.addr, (e.val, e.ts));
        }
        // Committed state must be at least as fresh as the last surviving
        // record for every CPU-side key (no other writer touches them).
        for (addr, (ver, _)) in &last {
            let a = *addr as usize;
            if a < self.cfg.n_keys {
                // CPU half: version words below n_keys (= 2 * (n_keys/2)).
                let cur = stmr.load(a);
                if cur < *ver {
                    bail!(
                        "zipfkv: committed version {cur} at word {a} older than \
                         surviving log record {ver}"
                    );
                }
            }
        }
        // Versions never go negative (they start at 0 and only increment).
        for k in 0..self.cfg.n_keys {
            let v = stmr.load(self.cfg.ver_w(k));
            if v < 0 {
                bail!("zipfkv: key {k} version is negative ({v})");
            }
        }
        Ok(())
    }

    fn stats_summary(&self) -> String {
        let t = crate::util::sync::lock(&self.trace);
        format!(
            "zipfkv trace: {} surviving entries, {} rounds promoted, {} discarded",
            t.surviving().len(),
            t.rounds_promoted,
            t.rounds_discarded
        )
    }

    fn on_recovered(&self, carried: &[crate::stm::WriteEntry]) {
        crate::util::sync::lock(&self.trace).on_recovered(carried);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Raw;
    use crate::gpu::Backend;
    use crate::stm::tinystm::TinyStm;
    use crate::stm::GlobalClock;

    fn sys() -> SystemConfig {
        SystemConfig::from_raw(&Raw::new()).unwrap()
    }

    fn wl(n_keys: usize) -> ZipfKvWorkload {
        ZipfKvWorkload::new(ZipfKvConfig::new(n_keys), &sys())
    }

    #[test]
    fn cpu_updates_bump_versions_and_record_trace() {
        let w = wl(1 << 10);
        let stmr = Arc::new(SharedStmr::new(w.n_words()));
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let mut cfg = w.cfg.clone();
        cfg.update_frac = 1.0;
        let mut cpu = ZipfKvCpu::new(
            stmr.clone(),
            tm,
            cfg,
            w.trace(),
            0..512,
            8,
            2e-6,
            1,
        );
        let mut log = Vec::new();
        let s = cpu.run(0.002, &mut log);
        assert!(s.commits > 1_000);
        {
            let mut t = w.trace.lock().unwrap();
            assert_eq!(t.pending.len(), log.len(), "every entry recorded");
            t.round_end(true);
        }
        w.check_invariants(&stmr).unwrap();
        // The hottest key saw many updates.
        assert!(stmr.load(w.cfg.ver_w(0)) > 10, "zipf head gets traffic");
    }

    #[test]
    fn discarded_rounds_drop_pending_entries() {
        let mut s = sys();
        s.policy = PolicyKind::FavorGpu;
        let w = ZipfKvWorkload::new(ZipfKvConfig::new(64), &s);
        {
            let mut t = w.trace.lock().unwrap();
            t.record(&[WriteEntry {
                addr: 1,
                val: 5,
                ts: 1,
            }]);
            t.round_end(false);
            assert_eq!(t.surviving().len(), 0, "favor-GPU abort discards");
            t.record(&[WriteEntry {
                addr: 1,
                val: 1,
                ts: 2,
            }]);
            t.round_end(true);
            assert_eq!(t.surviving().len(), 1);
        }
        // The v=5 entry is gone, so v=1 after it is NOT a violation.
        let stmr = SharedStmr::new(w.n_words());
        stmr.store(1, 1);
        w.check_invariants(&stmr).unwrap();
    }

    #[test]
    fn recovery_drops_pending_and_seeds_empty_trace() {
        let w = wl(64);
        let carried = [WriteEntry {
            addr: 2,
            val: 3,
            ts: 1,
        }];
        {
            // The round in flight at the crash never survives it.
            let mut t = w.trace.lock().unwrap();
            t.record(&[WriteEntry {
                addr: 1,
                val: 9,
                ts: 1,
            }]);
        }
        w.on_recovered(&carried);
        {
            let t = w.trace.lock().unwrap();
            assert_eq!(t.pending.len(), 0, "crash gap discards pending");
            assert_eq!(t.surviving(), &carried[..], "carried log seeds the oracle");
        }
        // Seeding is idempotent and never clobbers replayed history.
        w.on_recovered(&[]);
        assert_eq!(w.trace.lock().unwrap().surviving(), &carried[..]);
        // The seeded oracle accepts a state at least as fresh as carried.
        let stmr = SharedStmr::new(w.n_words());
        stmr.store(2, 3);
        w.check_invariants(&stmr).unwrap();
    }

    #[test]
    fn oracle_catches_version_regression() {
        let w = wl(64);
        {
            let mut t = w.trace.lock().unwrap();
            t.record(&[
                WriteEntry {
                    addr: 3,
                    val: 7,
                    ts: 1,
                },
                WriteEntry {
                    addr: 3,
                    val: 6,
                    ts: 2,
                },
            ]);
            t.round_end(true);
        }
        let stmr = SharedStmr::new(w.n_words());
        assert!(w.check_invariants(&stmr).is_err());
    }

    #[test]
    fn gpu_updates_bump_device_versions() {
        let w = wl(1 << 10);
        let nk = w.cfg.n_keys;
        let mut cfg = w.cfg.clone();
        cfg.update_frac = 1.0;
        let map = ShardMap::solo(w.n_words());
        let mut gpu = ZipfKvGpu::new(
            cfg,
            w.trace(),
            map,
            0,
            nk / 2..nk,
            128,
            20e-6,
            230e-9,
            3,
        );
        let mut d = GpuDevice::new(w.n_words(), 0, Backend::Native);
        d.begin_round();
        let s = gpu.run(&mut d, 0.01).unwrap();
        assert!(s.commits > 0);
        // Versions on the device replica are consistent: ver word for the
        // GPU half only, each >= 0, and the hot head was touched.
        let mut bumped = 0;
        for k in nk / 2..nk {
            let v = d.stmr()[w.cfg.ver_w(k)];
            assert!(v >= 0);
            if v > 0 {
                bumped += 1;
            }
        }
        assert!(bumped > 0, "some versions bumped");
        // No writes below the partition.
        for (st, e) in d.ws_bmp().dirty_word_ranges() {
            for word in st..e {
                assert!(word >= nk, "wrote CPU-half word {word}");
            }
        }
    }

    #[test]
    fn sharded_gpu_homes_normal_keys_but_hot_keys_cross() {
        let n_keys = 1 << 12;
        let mut cfg = ZipfKvConfig::new(n_keys);
        cfg.update_frac = 1.0;
        cfg.hot_prob = 0.5;
        let map = ShardMap::new(2 * n_keys, 2, 4); // 16-word blocks
        let s = sys();
        let w = ZipfKvWorkload::new(cfg.clone(), &s);
        let mut gpu = ZipfKvGpu::new(
            cfg,
            w.trace(),
            map.clone(),
            1,
            n_keys / 2..n_keys,
            128,
            20e-6,
            230e-9,
            5,
        );
        let mut d = GpuDevice::new(2 * n_keys, 0, Backend::Native);
        d.begin_round();
        gpu.run(&mut d, 0.01).unwrap();
        let (mut own, mut foreign) = (0u32, 0u32);
        for (st, e) in d.ws_bmp().dirty_word_ranges() {
            for word in st..e {
                if map.owner(word) == 1 {
                    own += 1;
                } else {
                    foreign += 1;
                }
            }
        }
        assert!(own > 0, "homed traffic stays owned");
        assert!(foreign > 0, "hot keys generate cross-shard writes");
    }
}
