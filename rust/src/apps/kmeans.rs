//! K-means-lite: read-dominated centroid reassignment with conservation
//! oracles.
//!
//! A fixed set of points (coordinates derived deterministically from the
//! seed, held outside the STMR like STAMP's read-only input arrays) is
//! partitioned over the devices; the STMR holds the clustering state:
//!
//! ```text
//! word c                      count[c]   — points assigned to centroid c
//! word k + c*dim + j          acc[c][j]  — per-dimension coordinate sums
//! word k*(1+dim) + p          assign[p]  — point p's current centroid
//! ```
//!
//! Every move transaction probes a handful of candidate centroids (the
//! read-dominated part), picks the least-loaded one, and atomically moves
//! its point: rewrite `assign[p]`, shift one unit of count and the point's
//! coordinates between the two centroids.  Because each move is a
//! transfer, two quantities are **invariant**: `Σ count[c] = n_points`
//! and, per dimension, `Σ acc[c][j] = Σ coord(p, j)` — the oracle.
//!
//! Partitioning: CPU points move among centroids `[0, k/2)`; GPU points
//! among `[k/2, k)`, statically striped so that at `n_gpus = N` device `d`
//! moves its points only among its own centroid sub-range — single-writer
//! per count word, like the other homed workloads.  `hot_prob` makes a
//! GPU transaction additionally *read* a CPU-side count word, which turns
//! CPU count updates into inter-device conflicts (abort-path stressor that
//! cannot unbalance anything).
//!
//! The GPU driver builds its batches by reading the device replica
//! host-side and emitting store-mode writes with precomputed absolute
//! values.  That is sound because every read-modify-write source word is
//! in the transaction's read set: PR-STM's priority rule aborts any
//! transaction whose read overlaps an earlier committer's write, so every
//! committed transaction's inputs equal the pre-batch state its values
//! were computed from (asserted by `prop_prstm_committers_serialize_by_
//! priority`).  Aborted losers are simply regenerated from fresh replica
//! state instead of being retried verbatim — their precomputed values
//! would be stale.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::workload::{gpu_seed, Workload};
use crate::cluster::shard::ShardMap;
use crate::config::{Raw, SystemConfig};
use crate::coordinator::round::{CpuDriver, CpuSlice, GpuDriver, GpuSlice};
use crate::gpu::{GpuDevice, TxnBatch};
use crate::stm::{GuestTm, SharedStmr, WriteEntry};
use crate::util::Rng;

/// K-means workload configuration (`[kmeans]` config section).
#[derive(Debug, Clone)]
pub struct KmeansConfig {
    /// Centroids (even; CPU gets the lower half, GPUs the upper).
    pub k: usize,
    /// Coordinate dimensions.
    pub dim: usize,
    /// Points (multiple of `k`; half per side).
    pub n_points: usize,
    /// Candidate centroids probed per transaction.
    pub probe: usize,
    /// Fraction of transactions allowed to move their point (the rest are
    /// pure probes).
    pub move_frac: f64,
    /// Probability a GPU transaction reads a CPU-side count word
    /// (inter-device conflict stressor).
    pub hot_prob: f64,
}

impl KmeansConfig {
    /// Defaults over `n_points`.
    pub fn new(n_points: usize) -> Self {
        KmeansConfig {
            k: 64,
            dim: 2,
            n_points,
            probe: 4,
            move_frac: 1.0,
            hot_prob: 0.0,
        }
    }

    /// Parse the `[kmeans]` section.
    pub fn from_raw(raw: &Raw) -> Result<Self> {
        let d = KmeansConfig::new(raw.get_or("kmeans.points", 1usize << 13)?);
        let cfg = KmeansConfig {
            k: raw.get_or("kmeans.k", d.k)?,
            dim: raw.get_or("kmeans.dim", d.dim)?,
            n_points: d.n_points,
            probe: raw.get_or("kmeans.probe", d.probe)?,
            move_frac: raw.get_or("kmeans.move_frac", d.move_frac)?,
            hot_prob: raw.get_or("kmeans.hot_prob", d.hot_prob)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject shapes the striping cannot partition cleanly.
    pub fn validate(&self) -> Result<()> {
        if self.k < 4 || self.k % 2 != 0 {
            bail!("kmeans.k must be even and >= 4 (got {})", self.k);
        }
        if self.n_points % self.k != 0 {
            bail!(
                "kmeans.points ({}) must be a multiple of kmeans.k ({})",
                self.n_points,
                self.k
            );
        }
        if self.dim == 0 || self.probe == 0 {
            bail!("kmeans.dim and kmeans.probe must be positive");
        }
        Ok(())
    }

    /// STMR words: counts, accumulators, assignments.
    pub fn n_words(&self) -> usize {
        self.k * (1 + self.dim) + self.n_points
    }

    /// Word holding `count[c]`.
    pub fn count_w(&self, c: usize) -> usize {
        c
    }

    /// Word holding `acc[c][j]`.
    pub fn acc_w(&self, c: usize, j: usize) -> usize {
        self.k + c * self.dim + j
    }

    /// Word holding `assign[p]`.
    pub fn assign_w(&self, p: usize) -> usize {
        self.k * (1 + self.dim) + p
    }

    /// Initial centroid of point `p` (group striping within each side).
    pub fn initial_centroid(&self, p: usize) -> usize {
        let half_p = self.n_points / 2;
        let half_c = self.k / 2;
        if p < half_p {
            p % half_c
        } else {
            half_c + (p - half_p) % half_c
        }
    }
}

fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Point `p`'s coordinate in dimension `j` (deterministic in the seed;
/// small values keep the accumulators far from overflow).
pub fn point_coord(seed: u64, p: usize, j: usize) -> i32 {
    (mix(seed ^ (((p as u64) << 8) | j as u64)) & 63) as i32
}

/// CPU-side k-means driver: probe-and-move through the guest TM.
pub struct KmeansCpu {
    stmr: Arc<SharedStmr>,
    tm: Arc<dyn GuestTm>,
    cfg: KmeansConfig,
    seed: u64,
    /// Modeled worker threads.
    pub threads: usize,
    /// Per-transaction execution time per worker (virtual seconds).
    pub txn_s: f64,
    rng: Rng,
    read_only: bool,
    debt: f64,
    widx: Vec<u32>,
}

impl KmeansCpu {
    /// Build a CPU driver over an initialized k-means STMR.
    pub fn new(
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        cfg: KmeansConfig,
        coord_seed: u64,
        threads: usize,
        txn_s: f64,
        seed: u64,
    ) -> Self {
        assert_eq!(stmr.len(), cfg.n_words());
        KmeansCpu {
            stmr,
            tm,
            cfg,
            seed: coord_seed,
            threads,
            txn_s,
            rng: Rng::new(seed),
            read_only: false,
            debt: 0.0,
            widx: Vec::new(),
        }
    }

    /// Transactions per virtual second at full tilt.
    pub fn rate(&self) -> f64 {
        self.threads as f64 / self.txn_s
    }

    fn run_one(&mut self, log: &mut Vec<WriteEntry>) -> u32 {
        let cfg = self.cfg.clone();
        let half_c = cfg.k / 2;
        // Pre-draw point and probe set (retries must replay them).
        let p = self.rng.below_usize(cfg.n_points / 2);
        self.rng
            .distinct(half_c, cfg.probe.min(half_c), &mut self.widx);
        let candidates: Vec<usize> = self.widx.iter().map(|&c| c as usize).collect();
        let may_move = !self.read_only && self.rng.chance(cfg.move_frac);
        let seed = self.seed;

        let r = self.tm.execute_into(
            &self.stmr,
            &mut |tx| {
                let old = tx.read(cfg.assign_w(p))? as usize;
                assert!(old < half_c, "CPU point {p} assigned to foreign centroid {old}");
                // Probe candidates (the read-dominated part); the move
                // target is the least-loaded candidate other than `old`.
                let mut new = None;
                let mut new_cnt = i32::MAX;
                for &c in &candidates {
                    let cnt = tx.read(cfg.count_w(c))?;
                    if c != old && cnt < new_cnt {
                        new_cnt = cnt;
                        new = Some(c);
                    }
                }
                let (new, new_cnt) = match (may_move, new) {
                    (true, Some(n)) => (n, new_cnt),
                    _ => return Ok(()), // pure probe
                };
                let old_cnt = tx.read(cfg.count_w(old))?;
                tx.write(cfg.assign_w(p), new as i32)?;
                tx.write(cfg.count_w(old), old_cnt - 1)?;
                tx.write(cfg.count_w(new), new_cnt + 1)?;
                for j in 0..cfg.dim {
                    let x = point_coord(seed, p, j);
                    let co = tx.read(cfg.acc_w(old, j))?;
                    tx.write(cfg.acc_w(old, j), co - x)?;
                    let cn = tx.read(cfg.acc_w(new, j))?;
                    tx.write(cfg.acc_w(new, j), cn + x)?;
                }
                Ok(())
            },
            log,
        );
        r.retries + 1
    }
}

impl CpuDriver for KmeansCpu {
    fn epoch_reset(&mut self, base: i64) {
        self.tm.epoch_reset(base);
    }

    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        let want = dur_s * self.rate() + self.debt;
        let n = want.floor() as u64;
        self.debt = want - n as f64;
        let mut attempts = 0u64;
        for _ in 0..n {
            attempts += self.run_one(log) as u64;
        }
        CpuSlice {
            commits: n,
            attempts,
        }
    }

    fn stmr(&self) -> &SharedStmr {
        &self.stmr
    }

    fn set_read_only(&mut self, ro: bool) {
        self.read_only = ro;
    }
    // snapshot/rollback: the trait's default SharedStmr path.
}

/// GPU-side k-means driver: batched assignment phases with host-side
/// read-modify-write (see the module docs for the soundness argument).
pub struct KmeansGpu {
    cfg: KmeansConfig,
    seed: u64,
    /// This device's index and the cluster size (centroid striping).
    dev: usize,
    n_dev: usize,
    /// Batch size.
    pub batch: usize,
    /// Kernel-activation latency (virtual seconds).
    pub kernel_latency_s: f64,
    /// Per-transaction device time (virtual seconds).
    pub txn_s: f64,
    rng: Rng,
    widx: Vec<u32>,
    budget_carry: f64,
}

impl KmeansGpu {
    /// Build the driver for device `dev` of `n_dev`.
    pub fn new(
        cfg: KmeansConfig,
        coord_seed: u64,
        dev: usize,
        n_dev: usize,
        batch: usize,
        kernel_latency_s: f64,
        txn_s: f64,
        seed: u64,
    ) -> Self {
        assert!(dev < n_dev);
        assert!(
            cfg.k / 2 >= n_dev,
            "kmeans needs at least one GPU centroid per device"
        );
        // A non-divisible split would silently freeze the tail centroids
        // (and their points) out of the workload; reject it instead.
        assert!(
            (cfg.k / 2) % n_dev == 0,
            "kmeans.k/2 ({}) must be divisible by the GPU count ({n_dev}) so \
             every centroid is covered",
            cfg.k / 2
        );
        KmeansGpu {
            cfg,
            seed: coord_seed,
            dev,
            n_dev,
            batch,
            kernel_latency_s,
            txn_s,
            rng: Rng::new(seed),
            widx: Vec::new(),
            budget_carry: 0.0,
        }
    }

    /// Device seconds one kernel activation costs.
    pub fn batch_cost(&self) -> f64 {
        self.kernel_latency_s + self.batch as f64 * self.txn_s
    }

    /// This device's centroid sub-range within the GPU half.
    fn my_centroids(&self) -> (usize, usize) {
        let half_c = self.cfg.k / 2;
        let sub = half_c / self.n_dev;
        (half_c + self.dev * sub, sub)
    }

    /// Batch shape: reads = assign + probes + old count + accs + hot word.
    fn widths(&self) -> (usize, usize) {
        let r = 2 + self.cfg.probe + 2 * self.cfg.dim + 1;
        let w = 3 + 2 * self.cfg.dim;
        (r, w)
    }

    fn fill_batch(&mut self, stmr: &[i32]) -> TxnBatch {
        let cfg = self.cfg.clone();
        let (r, w) = self.widths();
        let (base_c, sub) = self.my_centroids();
        let half_c = cfg.k / 2;
        let half_p = cfg.n_points / 2;
        let inst = half_p / half_c; // points per centroid group
        let mut batch = TxnBatch::empty(self.batch, r, w);
        for i in 0..self.batch {
            // A point whose centroid group belongs to this device.
            let g = base_c - half_c + self.rng.below_usize(sub);
            let q = g + half_c * self.rng.below_usize(inst);
            let p = half_p + q;
            let assign_w = cfg.assign_w(p);
            let old = stmr[assign_w] as usize;
            assert!(
                old >= base_c && old < base_c + sub,
                "GPU point {p} assigned to foreign centroid {old}"
            );
            self.rng.distinct(sub, cfg.probe.min(sub), &mut self.widx);
            let candidates: Vec<usize> =
                self.widx.iter().map(|&c| base_c + c as usize).collect();
            let may_move = self.rng.chance(cfg.move_frac);
            let hot = cfg.hot_prob > 0.0 && self.rng.chance(cfg.hot_prob);

            // Reads: every word feeding the host-side computation.
            let mut reads = vec![assign_w as i32];
            for &c in &candidates {
                reads.push(cfg.count_w(c) as i32);
            }
            let mut new = None;
            let mut new_cnt = i32::MAX;
            for &c in &candidates {
                let cnt = stmr[cfg.count_w(c)];
                if c != old && cnt < new_cnt {
                    new_cnt = cnt;
                    new = Some(c);
                }
            }
            if hot {
                // Probe a CPU-side count word (conflict stressor).
                reads.push(cfg.count_w(self.rng.below_usize(half_c)) as i32);
            }
            if let (true, Some(new)) = (may_move, new) {
                let old_cnt = stmr[cfg.count_w(old)];
                reads.push(cfg.count_w(old) as i32);
                let mut writes = vec![
                    (cfg.assign_w(p), new as i32),
                    (cfg.count_w(old), old_cnt - 1),
                    (cfg.count_w(new), new_cnt + 1),
                ];
                for j in 0..cfg.dim {
                    let x = point_coord(self.seed, p, j);
                    reads.push(cfg.acc_w(old, j) as i32);
                    reads.push(cfg.acc_w(new, j) as i32);
                    writes.push((cfg.acc_w(old, j), stmr[cfg.acc_w(old, j)] - x));
                    writes.push((cfg.acc_w(new, j), stmr[cfg.acc_w(new, j)] + x));
                }
                for (j, (a, v)) in writes.into_iter().enumerate() {
                    batch.write_idx[i * w + j] = a as i32;
                    batch.write_val[i * w + j] = v;
                }
            }
            for (j, &a) in reads.iter().take(r).enumerate() {
                batch.read_idx[i * r + j] = a;
            }
            batch.op[i] = 1; // store: absolute precomputed values
        }
        batch
    }
}

impl GpuDriver for KmeansGpu {
    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
        let mut out = GpuSlice::default();
        let cost = self.batch_cost();
        let mut left = budget_s + self.budget_carry;
        while left >= cost {
            let batch = self.fill_batch(device.stmr());
            let r = device.run_txn_batch(&batch)?;
            // Losers are NOT retried verbatim: their precomputed absolute
            // values are stale; fresh batches regenerate from the replica.
            out.commits += r.n_commits as u64;
            out.attempts += self.batch as u64;
            out.batches += 1;
            out.busy_s += cost;
            left -= cost;
        }
        self.budget_carry = left;
        Ok(out)
    }

    fn on_round_end(&mut self, _committed: bool) {
        self.budget_carry = 0.0;
        // No host-side clustering state: the replica is the only truth the
        // generator reads, so rollbacks need no driver bookkeeping.
    }
}

/// K-means as a [`Workload`]: count and coordinate-sum conservation.
pub struct KmeansWorkload {
    /// Workload configuration.
    pub cfg: KmeansConfig,
    seed: u64,
    /// Per-dimension coordinate totals (the conserved quantities).
    acc_totals: Vec<i64>,
}

impl KmeansWorkload {
    /// Wrap a config; `seed` fixes the point coordinates.
    pub fn new(cfg: KmeansConfig, seed: u64) -> Self {
        // audit:allow(D6, reason = "documented constructor contract: an invalid config is a caller bug, and validate()'s message names the bad knob")
        cfg.validate().expect("invalid kmeans config");
        let mut acc_totals = vec![0i64; cfg.dim];
        for p in 0..cfg.n_points {
            for (j, t) in acc_totals.iter_mut().enumerate() {
                *t += point_coord(seed, p, j) as i64;
            }
        }
        KmeansWorkload {
            cfg,
            seed,
            acc_totals,
        }
    }
}

impl Workload for KmeansWorkload {
    fn name(&self) -> &str {
        "kmeans"
    }

    fn n_words(&self) -> usize {
        self.cfg.n_words()
    }

    fn init_words(&self, words: &mut [i32]) {
        assert_eq!(words.len(), self.cfg.n_words());
        words.fill(0);
        for p in 0..self.cfg.n_points {
            let c = self.cfg.initial_centroid(p);
            words[self.cfg.count_w(c)] += 1;
            for j in 0..self.cfg.dim {
                words[self.cfg.acc_w(c, j)] += point_coord(self.seed, p, j);
            }
            words[self.cfg.assign_w(p)] = c as i32;
        }
    }

    fn build(
        &self,
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        map: &ShardMap,
        gpu_batch: usize,
        cfg: &SystemConfig,
    ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>) {
        let n_dev = map.n_shards();
        let cpu = KmeansCpu::new(
            stmr,
            tm,
            self.cfg.clone(),
            self.seed,
            cfg.cpu_threads,
            cfg.cpu_txn_s,
            cfg.seed,
        );
        let mut gpus: Vec<Box<dyn GpuDriver + Send>> = Vec::with_capacity(n_dev);
        for d in 0..n_dev {
            gpus.push(Box::new(KmeansGpu::new(
                self.cfg.clone(),
                self.seed,
                d,
                n_dev,
                gpu_batch,
                cfg.gpu_kernel_latency_s,
                cfg.gpu_txn_s,
                gpu_seed(cfg.seed, d),
            )));
        }
        (Box::new(cpu), gpus)
    }

    fn check_invariants(&self, stmr: &SharedStmr) -> Result<()> {
        let cfg = &self.cfg;
        if stmr.len() != cfg.n_words() {
            bail!("kmeans: STMR size mismatch");
        }
        let mut count_sum = 0i64;
        for c in 0..cfg.k {
            let cnt = stmr.load(cfg.count_w(c));
            if cnt < 0 {
                bail!("kmeans: centroid {c} count went negative ({cnt})");
            }
            count_sum += cnt as i64;
        }
        if count_sum != cfg.n_points as i64 {
            bail!(
                "kmeans: count conservation violated — {count_sum} assigned, \
                 {} points exist",
                cfg.n_points
            );
        }
        for j in 0..cfg.dim {
            let sum: i64 = (0..cfg.k).map(|c| stmr.load(cfg.acc_w(c, j)) as i64).sum();
            if sum != self.acc_totals[j] {
                bail!(
                    "kmeans: accumulator conservation violated in dim {j}: \
                     {sum} vs {}",
                    self.acc_totals[j]
                );
            }
        }
        let half_c = cfg.k / 2;
        for p in 0..cfg.n_points {
            let a = stmr.load(cfg.assign_w(p));
            let ok = if p < cfg.n_points / 2 {
                (0..half_c as i32).contains(&a)
            } else {
                (half_c as i32..cfg.k as i32).contains(&a)
            };
            if !ok {
                bail!("kmeans: point {p} assigned outside its side ({a})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Backend;
    use crate::stm::tinystm::TinyStm;
    use crate::stm::GlobalClock;

    fn small() -> KmeansConfig {
        let mut c = KmeansConfig::new(1 << 10);
        c.k = 16;
        c
    }

    fn init(wl: &KmeansWorkload) -> Arc<SharedStmr> {
        let stmr = Arc::new(SharedStmr::new(wl.n_words()));
        let mut words = vec![0; wl.n_words()];
        wl.init_words(&mut words);
        stmr.install_range(0, &words);
        stmr
    }

    #[test]
    fn initial_image_satisfies_oracle() {
        let wl = KmeansWorkload::new(small(), 7);
        let stmr = init(&wl);
        wl.check_invariants(&stmr).unwrap();
    }

    #[test]
    fn cpu_moves_conserve_counts_and_accs() {
        let wl = KmeansWorkload::new(small(), 7);
        let stmr = init(&wl);
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let mut cpu = KmeansCpu::new(stmr.clone(), tm, wl.cfg.clone(), 7, 8, 2e-6, 1);
        let mut log = Vec::new();
        let s = cpu.run(0.005, &mut log);
        assert!(s.commits > 1_000);
        assert!(!log.is_empty(), "moves must log write-sets");
        wl.check_invariants(&stmr).unwrap();
    }

    #[test]
    fn cpu_read_only_mode_probes_without_logging() {
        let wl = KmeansWorkload::new(small(), 7);
        let stmr = init(&wl);
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let mut cpu = KmeansCpu::new(stmr, tm, wl.cfg.clone(), 7, 8, 2e-6, 1);
        cpu.set_read_only(true);
        let mut log = Vec::new();
        let s = cpu.run(0.002, &mut log);
        assert!(s.commits > 0);
        assert!(log.is_empty());
    }

    #[test]
    fn gpu_moves_conserve_on_device() {
        let wl = KmeansWorkload::new(small(), 7);
        let mut d = GpuDevice::new(wl.n_words(), 0, Backend::Native);
        let mut words = vec![0; wl.n_words()];
        wl.init_words(&mut words);
        d.stmr_mut().copy_from_slice(&words);
        d.begin_round();
        let mut gpu = KmeansGpu::new(wl.cfg.clone(), 7, 0, 1, 128, 20e-6, 230e-9, 3);
        let s = gpu.run(&mut d, 0.01).unwrap();
        assert!(s.batches > 0 && s.commits > 0);
        let stmr = SharedStmr::new(wl.n_words());
        stmr.install_range(0, d.stmr());
        wl.check_invariants(&stmr).unwrap();
    }

    #[test]
    fn sharded_gpu_stays_in_its_centroid_slice() {
        let mut cfg = small();
        cfg.k = 16; // GPU half = centroids 8..16; 2 devices => 4 each
        let wl = KmeansWorkload::new(cfg.clone(), 9);
        for dev in 0..2 {
            let mut d = GpuDevice::new(wl.n_words(), 0, Backend::Native);
            let mut words = vec![0; wl.n_words()];
            wl.init_words(&mut words);
            d.stmr_mut().copy_from_slice(&words);
            d.begin_round();
            let mut gpu =
                KmeansGpu::new(cfg.clone(), 9, dev, 2, 128, 20e-6, 230e-9, 11 + dev as u64);
            gpu.run(&mut d, 0.005).unwrap();
            let (base_c, sub) = (8 + dev * 4, 4);
            for (s, e) in d.ws_bmp().dirty_word_ranges() {
                for w in s..e {
                    let owned_count = w >= base_c && w < base_c + sub;
                    let owned_acc = (cfg.k..cfg.k * (1 + cfg.dim)).contains(&w) && {
                        let c = (w - cfg.k) / cfg.dim;
                        c >= base_c && c < base_c + sub
                    };
                    let owned_assign = w >= cfg.k * (1 + cfg.dim);
                    assert!(
                        owned_count || owned_acc || owned_assign,
                        "device {dev} wrote foreign word {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn oracle_catches_count_drift() {
        let wl = KmeansWorkload::new(small(), 7);
        let stmr = init(&wl);
        stmr.store(0, stmr.load(0) + 1);
        assert!(wl.check_invariants(&stmr).is_err());
    }
}
