//! Applications on top of the HeTM abstraction.
//!
//! * [`synth`] — the paper's synthetic workloads W1/W2 (§V-A..§V-C):
//!   uniform random reads/updates with tunable update ratio, STMR
//!   partitioning (no-contention studies) and inter-device conflict
//!   injection (sensitivity studies);
//! * [`memcached`] — the MemcachedGPU reproduction (§V-D): an 8-way
//!   set-associative object cache with per-device LRU clocks, key-parity
//!   load balancing and steal-based rebalancing.

pub mod memcached;
pub mod synth;
