//! Applications on top of the HeTM abstraction.
//!
//! Every application implements [`workload::Workload`] — generation for
//! both device sides, shard-aware homing, and a built-in correctness
//! oracle checked after every run (see `workload.rs`):
//!
//! * [`synth`] — the paper's synthetic workloads W1/W2 (§V-A..§V-C):
//!   uniform random reads/updates with tunable update ratio, STMR
//!   partitioning (no-contention studies) and inter-device conflict
//!   injection (sensitivity studies);
//! * [`memcached`] — the MemcachedGPU reproduction (§V-D): an 8-way
//!   set-associative object cache with per-device LRU clocks, key-parity
//!   load balancing and steal-based rebalancing;
//! * [`bank`] — STAMP-style transfers; oracle: balance conservation;
//! * [`kmeans`] — read-dominated centroid reassignment; oracle: count and
//!   coordinate-sum conservation;
//! * [`zipfkv`] — Zipf-skewed KV updates with cross-shard hot keys;
//!   oracle: per-key version monotonicity over the CPU write log.

pub mod bank;
pub mod kmeans;
pub mod memcached;
pub mod synth;
pub mod workload;
pub mod zipfkv;

pub use workload::Workload;
