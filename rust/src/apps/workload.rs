//! The [`Workload`] trait: one plug for every application.
//!
//! A workload owns everything the engines do not: the STMR layout and its
//! initial image, the CPU- and GPU-side transaction generators (shard-aware
//! on clusters), and — crucially — a **correctness oracle**: a semantic
//! invariant of the application (bank-balance conservation, k-means count
//! conservation, per-key version monotonicity) that must hold on the
//! committed state after any run, under every policy, variant and cluster
//! size.  Benches and the `shetm run` command call the oracle after every
//! run, so every performance experiment doubles as a correctness check.
//!
//! Implementations:
//!
//! * [`SynthWorkload`] / [`MemcachedWorkload`] — the paper's original
//!   applications ([`super::synth`], [`super::memcached`]) refitted onto
//!   the trait;
//! * [`super::bank`] — STAMP-style transfers; oracle: total balance is
//!   conserved;
//! * [`super::kmeans`] — read-dominated centroid reassignment; oracle:
//!   counts and coordinate accumulators are conserved;
//! * [`super::zipfkv`] — skewed KV store; oracle: per-key version
//!   monotonicity over the surviving CPU write log.
//!
//! A `Workload` instance drives **one** engine run: oracles may accumulate
//! run-local evidence (e.g. the zipf-kv write-log trace), so build a fresh
//! instance per engine.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::bank::{BankConfig, BankWorkload};
use super::kmeans::{KmeansConfig, KmeansWorkload};
use super::memcached::{init_cache_words, McConfig, McCpu, McGpu, McWorld};
use super::synth::{SynthCpu, SynthGpu, SynthSpec};
use super::zipfkv::{ZipfKvConfig, ZipfKvWorkload};
use crate::cluster::shard::ShardMap;
use crate::config::{Raw, SystemConfig};
use crate::coordinator::round::{CpuDriver, GpuDriver};
use crate::gpu::native::mc;
use crate::stm::{GuestTm, SharedStmr};

/// An application pluggable into both `RoundEngine` and `ClusterEngine`.
///
/// # Example: a minimal end-to-end workload
///
/// Layout (one counter word) → drivers (a CPU incrementer through the
/// provided guest TM; an idle GPU per shard) → oracle (the counter never
/// goes negative and nothing else is written):
///
/// ```
/// use std::sync::Arc;
/// use anyhow::{bail, Result};
/// use shetm::apps::workload::Workload;
/// use shetm::cluster::ShardMap;
/// use shetm::config::{Raw, SystemConfig};
/// use shetm::coordinator::round::{CpuDriver, CpuSlice, GpuDriver, GpuSlice};
/// use shetm::gpu::GpuDevice;
/// use shetm::stm::{GuestTm, SharedStmr, WriteEntry};
///
/// struct CountCpu {
///     stmr: Arc<SharedStmr>,
///     tm: Arc<dyn GuestTm>,
///     debt: f64,
/// }
///
/// impl CpuDriver for CountCpu {
///     fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
///         let want = dur_s * 100_000.0 + self.debt; // 100k tx/s modeled
///         let n = want.floor() as u64;
///         self.debt = want - n as f64;
///         for _ in 0..n {
///             self.tm.execute_into(
///                 &self.stmr,
///                 &mut |tx| {
///                     let v = tx.read(0)?;
///                     tx.write(0, v + 1)
///                 },
///                 log,
///             );
///         }
///         CpuSlice { commits: n, attempts: n }
///     }
///
///     fn stmr(&self) -> &SharedStmr {
///         &self.stmr
///     }
/// }
///
/// struct IdleGpu;
///
/// impl GpuDriver for IdleGpu {
///     fn run(&mut self, _dev: &mut GpuDevice, _budget_s: f64) -> Result<GpuSlice> {
///         Ok(GpuSlice::default())
///     }
/// }
///
/// struct CounterWorkload;
///
/// impl Workload for CounterWorkload {
///     fn name(&self) -> &str {
///         "counter"
///     }
///
///     fn n_words(&self) -> usize {
///         64
///     }
///
///     fn build(
///         &self,
///         stmr: Arc<SharedStmr>,
///         tm: Arc<dyn GuestTm>,
///         map: &ShardMap,
///         _gpu_batch: usize,
///         _cfg: &SystemConfig,
///     ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>) {
///         let cpu = CountCpu { stmr, tm, debt: 0.0 };
///         let gpus = (0..map.n_shards())
///             .map(|_| Box::new(IdleGpu) as Box<dyn GpuDriver + Send>)
///             .collect();
///         (Box::new(cpu), gpus)
///     }
///
///     fn check_invariants(&self, stmr: &SharedStmr) -> Result<()> {
///         if stmr.load(0) < 0 {
///             bail!("counter went negative");
///         }
///         for w in 1..stmr.len() {
///             if stmr.load(w) != 0 {
///                 bail!("stray write at word {w}");
///             }
///         }
///         Ok(())
///     }
/// }
///
/// let mut cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
/// cfg.period_s = 0.001;
/// let mut session = shetm::session::Hetm::from_config(&cfg)
///     .workload(Box::new(CounterWorkload))
///     .gpu_batch(32)
///     .build()
///     .unwrap();
/// session.run_rounds(2).unwrap();
/// session.drain().unwrap();
/// session.check_invariants().unwrap();
/// assert!(session.stmr().load(0) > 0, "the counter advanced");
/// ```
pub trait Workload {
    /// Workload name (labels, diagnostics).
    fn name(&self) -> &str;

    /// STMR words this workload needs.
    fn n_words(&self) -> usize;

    /// Initial STMR image (defaults to all-zero).
    fn init_words(&self, _words: &mut [i32]) {}

    /// Build the CPU driver and one GPU driver per shard of `map`.
    ///
    /// All drivers of one call share generator state where the app needs
    /// it (queues, logs); `map` carries the cluster's shard homing — with
    /// a one-shard map generation must match the single-device stream.
    fn build(
        &self,
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        map: &ShardMap,
        gpu_batch: usize,
        cfg: &SystemConfig,
    ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>);

    /// The correctness oracle, checked against the post-run CPU truth
    /// (quiesce with `drain()` first so carried commits have landed).
    fn check_invariants(&self, stmr: &SharedStmr) -> Result<()>;

    /// Optional run-level summary line (hit rates, recorded updates, ...).
    fn stats_summary(&self) -> String {
        String::new()
    }

    /// Called once after `Session::recover` has rebuilt and verified the
    /// session, with the recovered carried log (all shards concatenated).
    /// Workloads that buffer oracle state outside the STMR (e.g. the
    /// zipf-kv round-buffered version oracle) rebuild it here instead of
    /// tripping over the crash gap.  Default: nothing to rebuild.
    fn on_recovered(&self, _carried: &[crate::stm::WriteEntry]) {}
}

/// Per-device GPU seed derivation: device 0 keeps the single-engine seed
/// (`seed ^ 0x9E37_79B9`), later devices derive — the same scheme as the
/// synth cluster builder, so n_gpus = 1 stays bit-identical.
pub fn gpu_seed(seed: u64, dev: usize) -> u64 {
    seed ^ 0x9E37_79B9 ^ (dev as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Build a workload from its config name plus the raw per-app sections.
///
/// Accepted names: `synth`, `memcached`, `bank`, `kmeans`, `zipfkv`
/// (alias `zipf-kv`).
pub fn from_raw(name: &str, raw: &Raw, cfg: &SystemConfig) -> Result<Box<dyn Workload>> {
    Ok(match name {
        "synth" => Box::new(SynthWorkload::from_raw(raw, cfg)?),
        "memcached" => Box::new(MemcachedWorkload::from_raw(raw, cfg)?),
        "bank" => Box::new(BankWorkload::new(BankConfig::from_raw(raw)?, cfg.seed)),
        "kmeans" => Box::new(KmeansWorkload::new(KmeansConfig::from_raw(raw)?, cfg.seed)),
        "zipfkv" | "zipf-kv" => {
            Box::new(ZipfKvWorkload::new(ZipfKvConfig::from_raw(raw)?, cfg))
        }
        other => bail!("unknown workload {other:?} (synth|memcached|bank|kmeans|zipfkv)"),
    })
}

// ---------------------------------------------------------------------------
// The paper's applications, refitted onto the trait.
// ---------------------------------------------------------------------------

/// The synthetic W1/W2 workload as a [`Workload`]: CPU on the lower half,
/// GPU on the upper half (the paper's partitioned configuration), with the
/// usual conflict-injection and cluster cross-shard knobs.
pub struct SynthWorkload {
    /// CPU-side spec.
    pub cpu_spec: SynthSpec,
    /// GPU-side template spec (homed per device at build time).
    pub gpu_spec: SynthSpec,
    n_words: usize,
}

impl SynthWorkload {
    /// Explicit CPU/GPU specs over an `n_words` region (the
    /// [`crate::session::Hetm::synth`] path).
    pub fn new(cpu_spec: SynthSpec, gpu_spec: SynthSpec, n_words: usize) -> Self {
        SynthWorkload {
            cpu_spec,
            gpu_spec,
            n_words,
        }
    }

    /// Partitioned W1/W2 over `cfg.n_words` from the `[synth]` section:
    /// `reads` (4 = W1, 40 = W2), `update_frac`, `conflict_prob`.
    pub fn from_raw(raw: &Raw, cfg: &SystemConfig) -> Result<Self> {
        let n = cfg.n_words;
        let reads: usize = raw.get_or("synth.reads", 4)?;
        let update_frac: f64 = raw.get_or("synth.update_frac", 1.0)?;
        let conflict: f64 = raw.get_or("synth.conflict_prob", 0.0)?;
        let mut cpu_spec = SynthSpec::w1(n, update_frac)
            .partitioned(0..n / 2)
            .with_conflicts(conflict, n / 2..n);
        cpu_spec.reads = reads;
        let mut gpu_spec = SynthSpec::w1(n, update_frac).partitioned(n / 2..n);
        gpu_spec.reads = reads;
        Ok(SynthWorkload {
            cpu_spec,
            gpu_spec,
            n_words: n,
        })
    }
}

impl Workload for SynthWorkload {
    fn name(&self) -> &str {
        "synth"
    }

    fn n_words(&self) -> usize {
        self.n_words
    }

    fn build(
        &self,
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        map: &ShardMap,
        gpu_batch: usize,
        cfg: &SystemConfig,
    ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>) {
        let cpu = SynthCpu::new(
            stmr,
            tm,
            self.cpu_spec.clone(),
            cfg.cpu_threads,
            cfg.cpu_txn_s,
            cfg.seed,
        );
        let mut gpus: Vec<Box<dyn GpuDriver + Send>> = Vec::with_capacity(map.n_shards());
        for d in 0..map.n_shards() {
            let mut spec = self.gpu_spec.clone().homed(map.clone(), d);
            if map.n_shards() > 1 {
                spec = spec.with_cross_shard(cfg.cross_shard_prob);
            }
            gpus.push(Box::new(SynthGpu::new(
                spec,
                gpu_batch,
                cfg.gpu_kernel_latency_s,
                cfg.gpu_txn_s,
                gpu_seed(cfg.seed, d),
            )));
        }
        (Box::new(cpu), gpus)
    }

    fn check_invariants(&self, stmr: &SharedStmr) -> Result<()> {
        // The generators only ever write values in [0, 1 << 20] (a uniform
        // draw below 2^20 plus a 1-bit read dependency), so any word
        // outside that range means a corrupted merge/rollback.
        for w in 0..stmr.len() {
            let v = stmr.load(w);
            if !(0..=1 << 20).contains(&v) {
                bail!("synth: word {w} = {v} outside the generated value domain");
            }
        }
        Ok(())
    }
}

/// MemcachedGPU as a [`Workload`]; the oracle checks the structural cache
/// invariants of the set-associative table.
pub struct MemcachedWorkload {
    /// Cache configuration.
    pub mc: McConfig,
    seed: u64,
}

impl MemcachedWorkload {
    /// Explicit cache configuration (the
    /// [`crate::session::Hetm::memcached`] path).
    pub fn new(mc: McConfig, seed: u64) -> Self {
        MemcachedWorkload { mc, seed }
    }

    /// From the `[memcached]` section: `n_sets`, `steal`.
    pub fn from_raw(raw: &Raw, cfg: &SystemConfig) -> Result<Self> {
        let n_sets: usize = raw.get_or("memcached.n_sets", 1usize << 12)?;
        let mut mc = McConfig::new(n_sets);
        mc.steal_shift = raw.get_or("memcached.steal", 0.0)?;
        Ok(MemcachedWorkload { mc, seed: cfg.seed })
    }
}

impl Workload for MemcachedWorkload {
    fn name(&self) -> &str {
        "memcached"
    }

    fn n_words(&self) -> usize {
        self.mc.n_words()
    }

    fn init_words(&self, words: &mut [i32]) {
        init_cache_words(words, self.mc.n_sets);
    }

    fn build(
        &self,
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        map: &ShardMap,
        gpu_batch: usize,
        cfg: &SystemConfig,
    ) -> (Box<dyn CpuDriver + Send>, Vec<Box<dyn GpuDriver + Send>>) {
        let world = McWorld::new_sharded(
            self.mc.clone(),
            self.seed,
            self.mc.steal_shift > 0.0,
            map.clone(),
        );
        let cpu = McCpu::new(
            stmr,
            tm,
            world.clone(),
            self.mc.clone(),
            cfg.cpu_threads,
            cfg.cpu_txn_s,
        );
        let mut gpus: Vec<Box<dyn GpuDriver + Send>> = Vec::with_capacity(map.n_shards());
        for d in 0..map.n_shards() {
            gpus.push(Box::new(
                McGpu::new(
                    world.clone(),
                    self.mc.clone(),
                    gpu_batch,
                    cfg.gpu_kernel_latency_s,
                    cfg.gpu_txn_s,
                )
                .on_device(d),
            ));
        }
        (Box::new(cpu), gpus)
    }

    fn check_invariants(&self, stmr: &SharedStmr) -> Result<()> {
        // Structural cache invariants: within every set, live keys are
        // distinct and hash to that set. Any violation means a merge mixed
        // two devices' inserts without the set-timestamp conflict firing.
        let n_sets = self.mc.n_sets;
        for s in 0..n_sets {
            let base = s * mc::WORDS_PER_SET;
            let mut keys = Vec::with_capacity(mc::WAYS);
            for w in 0..mc::WAYS {
                let k = stmr.load(base + mc::OFF_KEYS + w);
                if k == -1 {
                    continue;
                }
                if k < 0 {
                    bail!("memcached: set {s} way {w} holds invalid key {k}");
                }
                if mc::hash(k, n_sets) != s {
                    bail!("memcached: key {k} stored in set {s}, hashes elsewhere");
                }
                if keys.contains(&k) {
                    bail!("memcached: key {k} duplicated within set {s}");
                }
                keys.push(k);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Raw;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::from_raw(&Raw::new()).unwrap();
        c.n_words = 1 << 12;
        c
    }

    #[test]
    fn factory_builds_every_workload() {
        let c = cfg();
        let raw = Raw::new();
        for name in ["synth", "memcached", "bank", "kmeans", "zipfkv", "zipf-kv"] {
            let w = from_raw(name, &raw, &c).unwrap();
            assert!(w.n_words() > 0, "{name}");
            let mut words = vec![0; w.n_words()];
            w.init_words(&mut words);
            // A fresh image must satisfy the oracle.
            let stmr = SharedStmr::new(w.n_words());
            stmr.install_range(0, &words);
            w.check_invariants(&stmr).unwrap();
        }
        assert!(from_raw("nope", &raw, &c).is_err());
    }

    #[test]
    fn per_app_sections_parse() {
        let c = cfg();
        let raw = Raw::parse(
            "[bank]\naccounts = 512\n[kmeans]\npoints = 256\n[zipfkv]\nkeys = 128\n",
        )
        .unwrap();
        assert_eq!(from_raw("bank", &raw, &c).unwrap().n_words(), 512);
        assert_eq!(from_raw("zipfkv", &raw, &c).unwrap().n_words(), 256);
        assert!(from_raw("kmeans", &raw, &c).unwrap().n_words() >= 256);
    }

    #[test]
    fn synth_oracle_flags_out_of_domain_words() {
        let c = cfg();
        let w = from_raw("synth", &Raw::new(), &c).unwrap();
        let stmr = SharedStmr::new(w.n_words());
        stmr.store(7, -3);
        assert!(w.check_invariants(&stmr).is_err());
    }

    #[test]
    fn memcached_oracle_flags_misplaced_key() {
        let c = cfg();
        let raw = Raw::parse("[memcached]\nn_sets = 64\n").unwrap();
        let w = from_raw("memcached", &raw, &c).unwrap();
        let mut words = vec![0; w.n_words()];
        w.init_words(&mut words);
        let stmr = SharedStmr::new(w.n_words());
        stmr.install_range(0, &words);
        // Plant a key in a set it does not hash to.
        let k = 10i32;
        let wrong_set = (mc::hash(k, 64) + 1) % 64;
        stmr.store(wrong_set * mc::WORDS_PER_SET + mc::OFF_KEYS, k);
        assert!(w.check_invariants(&stmr).is_err());
    }
}
