//! MemcachedGPU on HeTM (paper §V-D).
//!
//! An in-memory object cache whose state — an 8-way set-associative table
//! with per-slot LRU timestamps — lives inside the STMR, concurrently
//! served by CPU worker threads (transactional GET/PUT through the guest
//! TM) and by the GPU (batched GET/PUT kernel).  Key design points
//! reproduced from the paper:
//!
//! * **device-local LRU clocks**: the pair freshness is only affected by
//!   device-local transactions, so CPU GETs never conflict with GPU GETs;
//! * **per-set timestamp**: every PUT updates a set-shared word, so
//!   inter-device PUT/PUT on one set always conflicts;
//! * **key-parity load balancing**: requests route to CPU_Q/GPU_Q by the
//!   last key bit (the `no-conflicts` workload), and the *steal-X%*
//!   workloads shift arrivals toward the CPU and let the GPU steal.
//!
//! STMR layout: 33 words/set, shared with the GPU kernel — see
//! `rust/src/gpu/native.rs::mc` and `python/compile/kernels/memcached.py`.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cluster::shard::ShardMap;
use crate::coordinator::dispatch::{Affinity, Dispatcher};
use crate::coordinator::round::{CpuDriver, CpuSlice, GpuDriver, GpuSlice};
use crate::gpu::native::mc;
use crate::gpu::{GpuDevice, McBatch};
use crate::stm::{GuestTm, SharedStmr, TxOps, WriteEntry};
use crate::util::{Rng, Zipf};

/// One cache request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct McRequest {
    /// 0 = GET, 1 = PUT.
    pub op: u8,
    /// Key (non-negative; -1 is the empty-slot sentinel).
    pub key: i32,
    /// Value for PUTs.
    pub val: i32,
}

/// Workload configuration (paper §V-D defaults).
#[derive(Debug, Clone)]
pub struct McConfig {
    /// Number of cache sets (paper: 1 M; scaled by default).
    pub n_sets: usize,
    /// Fraction of GETs (paper: 0.999).
    pub get_frac: f64,
    /// Zipf exponent over keys (paper: 0.5).
    pub zipf_alpha: f64,
    /// Distinct keys.
    pub key_space: u64,
    /// Probability that a GPU-bound arrival is redirected to the CPU queue
    /// (the steal-X% workloads; 0 = balanced `no-conflicts`).
    pub steal_shift: f64,
}

impl McConfig {
    /// Paper-shaped defaults over `n_sets`.
    pub fn new(n_sets: usize) -> Self {
        McConfig {
            n_sets,
            get_frac: 0.999,
            zipf_alpha: 0.5,
            key_space: (n_sets as u64) * 4,
            steal_shift: 0.0,
        }
    }

    /// STMR words required.
    pub fn n_words(&self) -> usize {
        self.n_sets * mc::WORDS_PER_SET
    }
}

/// Initialize an STMR buffer to an empty cache (keys = -1).
pub fn init_cache_words(words: &mut [i32], n_sets: usize) {
    assert_eq!(words.len(), n_sets * mc::WORDS_PER_SET);
    words.fill(0);
    for s in 0..n_sets {
        let base = s * mc::WORDS_PER_SET;
        words[base..base + mc::WAYS].fill(-1);
    }
}

/// Shared request world: generator + the three dispatch queues.
pub struct McWorld {
    /// The CPU_Q / per-device GPU_Q / SHARED_Q dispatcher.
    pub dispatcher: Dispatcher<McRequest>,
    cfg: McConfig,
    rng: Rng,
    zipf: Zipf,
    /// Cluster sharding: GPU-bound arrivals route to the device owning
    /// the request's cache set (shard-aware batch generation). `None` (or
    /// a one-shard map) is the single-device behavior, unchanged.
    shard: Option<ShardMap>,
    /// GETs answered with a value (hit) — liveness diagnostics.
    pub get_hits: u64,
    /// Requests generated so far.
    pub generated: u64,
}

impl McWorld {
    /// New world; `gpu_steal` enables GPU work stealing from CPU_Q.
    pub fn new(cfg: McConfig, seed: u64, gpu_steal: bool) -> Arc<Mutex<Self>> {
        Self::build(cfg, seed, gpu_steal, None)
    }

    /// New world over a sharded cluster: one GPU queue per device, and
    /// GPU-bound arrivals route by set ownership.
    pub fn new_sharded(
        cfg: McConfig,
        seed: u64,
        gpu_steal: bool,
        map: ShardMap,
    ) -> Arc<Mutex<Self>> {
        Self::build(cfg, seed, gpu_steal, Some(map))
    }

    fn build(
        cfg: McConfig,
        seed: u64,
        gpu_steal: bool,
        shard: Option<ShardMap>,
    ) -> Arc<Mutex<Self>> {
        let zipf = Zipf::new(cfg.key_space, cfg.zipf_alpha);
        let n_queues = shard.as_ref().map(|m| m.n_shards()).unwrap_or(1);
        let mut dispatcher = Dispatcher::with_gpu_queues(n_queues);
        dispatcher.gpu_steal_prob = if gpu_steal { 1.0 } else { 0.0 };
        Arc::new(Mutex::new(McWorld {
            dispatcher,
            cfg,
            rng: Rng::new(seed),
            zipf,
            shard,
            get_hits: 0,
            generated: 0,
        }))
    }

    /// Generate `n` arrivals into the queues with the configured mix.
    pub fn generate(&mut self, n: usize) {
        for _ in 0..n {
            let key = self.zipf.sample(&mut self.rng) as i32;
            let op = if self.rng.chance(self.cfg.get_frac) { 0 } else { 1 };
            let val = self.rng.below(1 << 20) as i32;
            // Key-parity affinity balances load and guarantees disjoint
            // set access (§V-D `no-conflicts`)...
            let mut aff = if key & 1 == 1 {
                Affinity::Cpu
            } else {
                Affinity::Gpu
            };
            // ...while the steal workloads shift GPU-bound arrivals onto
            // the CPU queue (popularity shift), forcing the GPU to steal.
            if aff == Affinity::Gpu && self.rng.chance(self.cfg.steal_shift) {
                aff = Affinity::Cpu;
            }
            let req = McRequest { op, key, val };
            match (&self.shard, aff) {
                (Some(map), Affinity::Gpu) => {
                    // Shard-aware routing: the device owning the request's
                    // set serves it (its replica is authoritative there).
                    let set = mc::hash(key, self.cfg.n_sets);
                    let dev = map.owner(set * mc::WORDS_PER_SET);
                    self.dispatcher.submit_gpu(req, dev);
                }
                _ => self.dispatcher.submit(req, aff),
            }
            self.generated += 1;
        }
    }

    fn pop_cpu(&mut self) -> McRequest {
        loop {
            if let Some(r) = self.dispatcher.pop_cpu() {
                return r;
            }
            self.generate(1024);
        }
    }

    fn pop_gpu(&mut self, dev: usize, n: usize, out: &mut Vec<McRequest>) {
        let mut rng = self.rng.fork();
        loop {
            // `pop_gpu_batch_on` fills `out` up to a TOTAL of `n` entries.
            self.dispatcher.pop_gpu_batch_on(dev, n, &mut rng, out);
            if out.len() >= n {
                return;
            }
            self.generate(1024);
        }
    }
}

/// CPU-side memcached driver.
pub struct McCpu {
    stmr: Arc<SharedStmr>,
    tm: Arc<dyn GuestTm>,
    world: Arc<Mutex<McWorld>>,
    cfg: McConfig,
    /// Modeled worker threads.
    pub threads: usize,
    /// Per-request execution time per worker (virtual seconds).
    pub txn_s: f64,
    lru_clk: i32,
    read_only: bool,
    deferred: Vec<McRequest>,
    debt: f64,
}

impl McCpu {
    /// Build a CPU driver over an initialized cache STMR.
    pub fn new(
        stmr: Arc<SharedStmr>,
        tm: Arc<dyn GuestTm>,
        world: Arc<Mutex<McWorld>>,
        cfg: McConfig,
        threads: usize,
        txn_s: f64,
    ) -> Self {
        assert_eq!(stmr.len(), cfg.n_words());
        McCpu {
            stmr,
            tm,
            world,
            cfg,
            threads,
            txn_s,
            lru_clk: 1,
            read_only: false,
            deferred: Vec::new(),
            debt: 0.0,
        }
    }

    /// Requests per virtual second.
    pub fn rate(&self) -> f64 {
        self.threads as f64 / self.txn_s
    }

    /// Execute one request transactionally. Returns (attempts, hit).
    fn run_one(&mut self, req: McRequest, log: &mut Vec<WriteEntry>) -> (u32, bool) {
        let n_sets = self.cfg.n_sets;
        let set = mc::hash(req.key, n_sets);
        let base = set * mc::WORDS_PER_SET;
        self.lru_clk = self.lru_clk.wrapping_add(1);
        let clk = self.lru_clk;
        let mut hit_out = false;

        let r = self.tm.execute_into(
            &self.stmr,
            &mut |tx: &mut dyn TxOps| {
                // Probe the 8 ways.
                let mut slot = None;
                for s in 0..mc::WAYS {
                    if tx.read(base + mc::OFF_KEYS + s)? == req.key {
                        slot = Some(s);
                        break;
                    }
                }
                if req.op == 0 {
                    // GET: read value, touch the CPU-local LRU timestamp.
                    if let Some(s) = slot {
                        let _v = tx.read(base + mc::OFF_VALS + s)?;
                        tx.write(base + mc::OFF_TS_CPU + s, clk)?;
                        hit_out = true;
                    }
                } else {
                    // PUT: overwrite the hit slot or evict the CPU-LRU one.
                    let s = match slot {
                        Some(s) => s,
                        None => {
                            let mut best = 0;
                            let mut best_ts = i32::MAX;
                            for s in 0..mc::WAYS {
                                let t = tx.read(base + mc::OFF_TS_CPU + s)?;
                                if t < best_ts {
                                    best_ts = t;
                                    best = s;
                                }
                            }
                            best
                        }
                    };
                    tx.write(base + mc::OFF_KEYS + s, req.key)?;
                    tx.write(base + mc::OFF_VALS + s, req.val)?;
                    tx.write(base + mc::OFF_TS_CPU + s, clk)?;
                    // The set-shared timestamp word: inter-device PUT/PUT
                    // conflicts are guaranteed through it (§V-D).
                    tx.write(base + mc::OFF_SET_TS, clk)?;
                }
                Ok(())
            },
            log,
        );
        (r.retries + 1, hit_out)
    }
}

impl CpuDriver for McCpu {
    fn epoch_reset(&mut self, base: i64) {
        self.tm.epoch_reset(base);
    }

    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        let want = dur_s * self.rate() + self.debt;
        let n = want.floor() as u64;
        self.debt = want - n as f64;
        let mut commits = 0u64;
        let mut attempts = 0u64;
        let mut hits = 0u64;
        for _ in 0..n {
            let req = crate::util::sync::lock(&self.world).pop_cpu();
            if self.read_only && req.op == 1 {
                // Starvation guard: defer update transactions (§IV-E).
                self.deferred.push(req);
                continue;
            }
            let (a, hit) = self.run_one(req, log);
            commits += 1;
            attempts += a as u64;
            hits += hit as u64;
        }
        if !self.read_only && !self.deferred.is_empty() {
            let mut w = crate::util::sync::lock(&self.world);
            for req in self.deferred.drain(..) {
                w.dispatcher.submit(req, Affinity::Cpu);
            }
        }
        crate::util::sync::lock(&self.world).get_hits += hits;
        CpuSlice { commits, attempts }
    }

    fn stmr(&self) -> &SharedStmr {
        &self.stmr
    }

    fn set_read_only(&mut self, ro: bool) {
        self.read_only = ro;
    }
    // snapshot/rollback: the trait's default SharedStmr path.
}

/// GPU-side memcached driver: fills kernel batches from GPU_Q (stealing
/// from CPU_Q per the workload), retries arbitration losers, and requeues
/// speculatively-committed requests when a round aborts.
///
/// The dispatcher draw is split across the [`GpuDriver`] hooks: `prepare`
/// (coordinator thread, device-index order) pulls enough requests for the
/// coming slice into a driver-local prefetch queue, and `run` consumes
/// only local state — which is what lets the threaded cluster engine run
/// this driver's slices concurrently and stay deterministic (the shared
/// RNG and steal decisions advance at one fixed point of the round).
/// Callers that never call `prepare` (direct driver tests) fall back to
/// pulling lazily inside `run`, exactly as before.
pub struct McGpu {
    world: Arc<Mutex<McWorld>>,
    cfg: McConfig,
    /// Requests per kernel activation (must match the artifact's `q`).
    pub batch: usize,
    /// Kernel-activation latency (virtual seconds).
    pub kernel_latency_s: f64,
    /// Per-request device time (virtual seconds).
    pub txn_s: f64,
    /// Which cluster device this driver feeds (0 in the single-device
    /// system; selects the dispatcher GPU queue to pull from).
    pub dev: usize,
    clk0: i32,
    retry: Vec<McRequest>,
    round_committed: Vec<McRequest>,
    /// Requests pulled ahead by `prepare`, consumed FIFO by `run`.
    prefetch: std::collections::VecDeque<McRequest>,
    /// Sub-batch budget carried across segments of one round.
    budget_carry: f64,
}

impl McGpu {
    /// Build a GPU driver.
    pub fn new(
        world: Arc<Mutex<McWorld>>,
        cfg: McConfig,
        batch: usize,
        kernel_latency_s: f64,
        txn_s: f64,
    ) -> Self {
        McGpu {
            world,
            cfg,
            batch,
            kernel_latency_s,
            txn_s,
            dev: 0,
            clk0: 1,
            retry: Vec::new(),
            round_committed: Vec::new(),
            prefetch: std::collections::VecDeque::new(),
            budget_carry: 0.0,
        }
    }

    /// Bind this driver to cluster device `dev` (queue selection).
    pub fn on_device(mut self, dev: usize) -> Self {
        self.dev = dev;
        self
    }

    /// Device seconds one kernel activation costs.
    pub fn batch_cost(&self) -> f64 {
        self.kernel_latency_s + self.batch as f64 * self.txn_s
    }

    /// Peak requests per device second.
    pub fn rate(&self) -> f64 {
        self.batch as f64 / self.batch_cost()
    }
}

impl GpuDriver for McGpu {
    fn prepare(&mut self, budget_s: f64) {
        let cost = self.batch_cost();
        if cost <= 0.0 {
            return;
        }
        // Upper bound on the batches `run` will execute from this budget
        // (+1 guards the floor-vs-iterated-subtraction edge), minus what
        // the retry and prefetch queues already cover.  Over-pulling is
        // harmless: prefetched requests persist and are consumed first.
        let n_batches = ((budget_s + self.budget_carry) / cost).floor() as usize + 1;
        let need = (n_batches * self.batch)
            .saturating_sub(self.retry.len() + self.prefetch.len());
        if need == 0 {
            return;
        }
        let mut pulled: Vec<McRequest> = Vec::with_capacity(need);
        crate::util::sync::lock(&self.world).pop_gpu(self.dev, need, &mut pulled);
        self.prefetch.extend(pulled);
    }

    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
        let mut out = GpuSlice::default();
        let cost = self.batch_cost();
        let mut left = budget_s + self.budget_carry;
        let mut reqs: Vec<McRequest> = Vec::with_capacity(self.batch);
        while left >= cost {
            reqs.clear();
            // Retry queue first (arbitration losers), then the prefetch
            // filled by `prepare`, then — only if `prepare` was never
            // called — the dispatcher itself.
            while reqs.len() < self.batch {
                match self.retry.pop() {
                    Some(r) => reqs.push(r),
                    None => break,
                }
            }
            while reqs.len() < self.batch {
                match self.prefetch.pop_front() {
                    Some(r) => reqs.push(r),
                    None => break,
                }
            }
            if reqs.len() < self.batch {
                crate::util::sync::lock(&self.world).pop_gpu(self.dev, self.batch, &mut reqs);
            }
            let mut b = McBatch::empty(self.batch);
            for (i, r) in reqs.iter().enumerate() {
                b.op[i] = r.op as i32;
                b.key[i] = r.key;
                b.val[i] = r.val;
            }
            b.clk0 = self.clk0;
            self.clk0 = self.clk0.wrapping_add(self.batch as i32);

            let r = device.run_mc_batch(&b, self.cfg.n_sets)?;
            let mut hits = 0u64;
            for (i, req) in reqs.iter().enumerate() {
                if r.commit[i] == 0 {
                    self.retry.push(*req); // intra-batch loser: host retry
                } else {
                    self.round_committed.push(*req);
                    if req.op == 0 && r.out_val[i] >= 0 {
                        hits += 1;
                    }
                }
            }
            crate::util::sync::lock(&self.world).get_hits += hits;
            out.commits += r.n_commits as u64;
            out.attempts += self.batch as u64;
            out.batches += 1;
            out.busy_s += cost;
            left -= cost;
        }
        self.budget_carry = left;
        Ok(out)
    }

    fn on_round_end(&mut self, committed: bool) {
        self.budget_carry = 0.0;
        if committed {
            self.round_committed.clear();
        } else {
            // Speculative commits were rolled back: re-execute them.
            self.retry.append(&mut self.round_committed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Backend;
    use crate::stm::tinystm::TinyStm;
    use crate::stm::GlobalClock;

    fn setup(n_sets: usize, steal_shift: f64) -> (McConfig, Arc<SharedStmr>, Arc<Mutex<McWorld>>) {
        let mut cfg = McConfig::new(n_sets);
        cfg.steal_shift = steal_shift;
        let stmr = Arc::new(SharedStmr::new(cfg.n_words()));
        let mut words = vec![0; cfg.n_words()];
        init_cache_words(&mut words, n_sets);
        stmr.install_range(0, &words);
        let world = McWorld::new(cfg.clone(), 7, steal_shift > 0.0);
        (cfg, stmr, world)
    }

    fn cpu_driver(
        cfg: &McConfig,
        stmr: Arc<SharedStmr>,
        world: Arc<Mutex<McWorld>>,
    ) -> McCpu {
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        McCpu::new(stmr, tm, world, cfg.clone(), 8, 2e-6)
    }

    #[test]
    fn cpu_serves_requests_and_logs_updates() {
        let (cfg, stmr, world) = setup(256, 0.0);
        let mut cpu = cpu_driver(&cfg, stmr, world.clone());
        let mut log = Vec::new();
        let s = cpu.run(0.01, &mut log);
        assert!(s.commits > 10_000);
        // GET touches write the CPU LRU word -> log entries exist.
        assert!(!log.is_empty());
        // CPU only received odd keys (parity affinity, no stealing).
        // (Checked via the world's queues: GPU_Q holds only even keys.)
        let w = world.lock().unwrap();
        assert!(w.generated > 0);
    }

    #[test]
    fn cpu_put_get_roundtrip() {
        let (cfg, stmr, world) = setup(64, 0.0);
        let mut cpu = cpu_driver(&cfg, stmr.clone(), world);
        let mut log = Vec::new();
        let (a, _) = cpu.run_one(
            McRequest {
                op: 1,
                key: 33,
                val: 3300,
            },
            &mut log,
        );
        assert!(a >= 1);
        let (_, hit) = cpu.run_one(
            McRequest {
                op: 0,
                key: 33,
                val: 0,
            },
            &mut log,
        );
        assert!(hit, "GET after PUT must hit");
        // The PUT logged the set-shared timestamp word.
        let set = mc::hash(33, 64);
        let set_ts_word = (set * mc::WORDS_PER_SET + mc::OFF_SET_TS) as u32;
        assert!(log.iter().any(|e| e.addr == set_ts_word));
    }

    #[test]
    fn gpu_driver_consumes_and_retries() {
        let (cfg, _stmr, world) = setup(256, 0.0);
        let mut gpu = McGpu::new(world, cfg.clone(), 256, 20e-6, 230e-9);
        let mut dev = GpuDevice::new(cfg.n_words(), 0, Backend::Native);
        let mut words = vec![0; cfg.n_words()];
        init_cache_words(&mut words, cfg.n_sets);
        dev.stmr_mut().copy_from_slice(&words);
        dev.begin_round();
        let s = gpu.run(&mut dev, 0.01).unwrap();
        assert!(s.batches > 0);
        assert!(s.commits > 0);
        // Round abort requeues speculative commits for re-execution.
        let committed_before = gpu.round_committed.len();
        assert!(committed_before > 0);
        gpu.on_round_end(false);
        assert_eq!(gpu.retry.len() >= committed_before, true);
    }

    #[test]
    fn steal_shift_moves_load_to_cpu_queue() {
        let (_cfg, _stmr, world) = setup(256, 1.0);
        world.lock().unwrap().generate(10_000);
        let (c, g, s) = world.lock().unwrap().dispatcher.depths();
        assert_eq!(g, 0, "all GPU-bound arrivals shifted to CPU_Q");
        assert!(c > 9_000);
        assert_eq!(s, 0);
    }

    #[test]
    fn sharded_world_routes_gpu_arrivals_to_owner_queues() {
        let cfg = McConfig::new(256);
        let map = ShardMap::new(cfg.n_words(), 2, 7); // 128-word blocks
        let world = McWorld::new_sharded(cfg, 7, false, map);
        world.lock().unwrap().generate(5_000);
        let w = world.lock().unwrap();
        assert_eq!(w.dispatcher.n_gpu_queues(), 2);
        assert!(
            w.dispatcher.depth_gpu(0) > 0 && w.dispatcher.depth_gpu(1) > 0,
            "both owner queues fed: {} / {}",
            w.dispatcher.depth_gpu(0),
            w.dispatcher.depth_gpu(1)
        );
        let (c, g, s) = w.dispatcher.depths();
        assert!(c > 0 && g > 0);
        assert_eq!(s, 0);
    }

    #[test]
    fn balanced_affinity_splits_by_parity() {
        let (_cfg, _stmr, world) = setup(256, 0.0);
        world.lock().unwrap().generate(10_000);
        let (c, g, _) = world.lock().unwrap().dispatcher.depths();
        assert!(c > 3_000 && g > 3_000, "both queues fed: c={c} g={g}");
    }
}
