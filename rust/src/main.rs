//! `shetm` — the SHeTM leader binary.
//!
//! Subcommands:
//!
//! ```text
//! shetm info      [--artifacts DIR]          list compiled PJRT artifacts
//! shetm synth     [OPTS]                     run the synthetic workload
//! shetm memcached [OPTS]                     run the memcached application
//! shetm baselines [OPTS]                     CPU-only / GPU-only reference
//! ```
//!
//! Common options:
//!   --config FILE        TOML-subset config file (see config/mod.rs)
//!   --set key=value      override any config key (repeatable)
//!   --rounds N           synchronization rounds to run (default 50)
//!   --threads N          OS threads for the per-device cluster pipelines
//!   --basic              use the basic (unoptimized) algorithm variant
//!   --pjrt               force the PJRT backend from ./artifacts
//!   --trace FILE         write a Perfetto-loadable virtual-time trace
//!   --checkpoint-dir DIR write round-boundary checkpoints under DIR
//!   --recover            resume `run` from the newest checkpoint in DIR
//!
//! Example:
//!   shetm synth --set hetm.period_ms=80 --set cpu.guest=norec --rounds 100
//!   shetm memcached --set hetm.period_ms=10 --set seed=7 --pjrt

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use shetm::apps::memcached::McConfig;
use shetm::apps::synth::{SynthCpu, SynthGpu, SynthSpec};
use shetm::config::{Raw, SystemConfig};
use shetm::coordinator::baseline;
use shetm::coordinator::round::Variant;
use shetm::gpu::{Backend, GpuDevice};
use shetm::launch;
use shetm::runtime::ArtifactStore;
use shetm::session::{Hetm, Session};
use shetm::stm::{GlobalClock, SharedStmr};
use shetm::telemetry::MetricsSnapshot;

struct Cli {
    cmd: String,
    raw: Raw,
    rounds: usize,
    basic: bool,
    pjrt: bool,
    gpus: Option<usize>,
    threads: Option<usize>,
    workload: Option<String>,
    trace: Option<String>,
    checkpoint_dir: Option<String>,
    recover: bool,
    rebalance: bool,
}

fn parse_cli() -> Result<Cli> {
    let mut all: Vec<String> = std::env::args().skip(1).collect();
    // `shetm --workload bank ...` is sugar for `shetm run --workload ...`
    // (but `--help`/`-h` keep printing help, as ever).
    let is_help = matches!(all.first().map(|a| a.as_str()), Some("--help") | Some("-h"));
    let is_flag = all.first().map(|a| a.starts_with('-')).unwrap_or(false);
    let cmd = if all.is_empty() {
        "help".to_string()
    } else if is_help {
        all.remove(0);
        "help".to_string()
    } else if is_flag {
        "run".to_string()
    } else {
        all.remove(0)
    };
    let mut args = all.into_iter();
    let mut raw = Raw::new();
    let mut rounds = 50;
    let mut basic = false;
    let mut pjrt = false;
    let mut gpus = None;
    let mut threads = None;
    let mut workload = None;
    let mut trace = None;
    let mut checkpoint_dir = None;
    let mut recover = false;
    let mut rebalance = false;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--config" => {
                let path = args.next().context("--config needs a file")?;
                raw = Raw::load(&path)?;
            }
            "--set" => {
                let kv = args.next().context("--set needs key=value")?;
                raw.set(&kv)?;
            }
            "--rounds" => {
                rounds = args
                    .next()
                    .context("--rounds needs a number")?
                    .parse()
                    .context("--rounds")?;
            }
            "--gpus" => {
                gpus = Some(
                    args.next()
                        .context("--gpus needs a number")?
                        .parse()
                        .context("--gpus")?,
                );
            }
            "--threads" => {
                threads = Some(
                    args.next()
                        .context("--threads needs a number")?
                        .parse()
                        .context("--threads")?,
                );
            }
            "--workload" => {
                workload = Some(args.next().context("--workload needs a name")?);
            }
            "--trace" => {
                trace = Some(args.next().context("--trace needs an output file")?);
            }
            "--checkpoint-dir" => {
                checkpoint_dir = Some(args.next().context("--checkpoint-dir needs a path")?);
            }
            "--recover" => recover = true,
            "--rebalance" => rebalance = true,
            "--basic" => basic = true,
            "--pjrt" => pjrt = true,
            other => bail!("unknown argument {other:?} (try `shetm help`)"),
        }
    }
    Ok(Cli {
        cmd,
        raw,
        rounds,
        basic,
        pjrt,
        gpus,
        threads,
        workload,
        trace,
        checkpoint_dir,
        recover,
        rebalance,
    })
}

/// Render the session's results (stats block, cluster block, histogram
/// lines, workload summary) from one [`MetricsSnapshot`] — the single
/// serializer shared with the session API and the benches — and write
/// the trace file when `--trace` was given.
fn report(cli: &Cli, label: &str, session: &Session) -> Result<()> {
    println!("{}", session.metrics_snapshot(label).render_text());
    if let Some(path) = &cli.trace {
        session
            .write_trace(path)
            .with_context(|| format!("writing trace to {path}"))?;
        println!("  trace             : {path}");
    }
    Ok(())
}

fn variant(cli: &Cli) -> Variant {
    if cli.basic {
        Variant::Basic
    } else {
        Variant::Optimized
    }
}

fn system_config(cli: &Cli) -> Result<SystemConfig> {
    let mut cfg = SystemConfig::from_raw(&cli.raw)?;
    if cli.pjrt && cfg.artifacts_dir.is_empty() {
        cfg.artifacts_dir = "artifacts".to_string();
    }
    if let Some(g) = cli.gpus {
        if g == 0 {
            bail!("--gpus must be at least 1");
        }
        cfg.n_gpus = g;
    }
    if let Some(t) = cli.threads {
        if t == 0 {
            bail!("--threads must be at least 1");
        }
        cfg.cluster_threads = t;
    }
    if let Some(d) = &cli.checkpoint_dir {
        cfg.checkpoint_dir = d.clone();
    }
    if cli.rebalance {
        cfg.rebalance = true;
    }
    // CI-friendly fault injection: the crash plan can ride in on the
    // environment so a sweep script does not have to rewrite configs.
    if let Ok(p) = std::env::var("SHETM_CRASH_POINT") {
        if !p.is_empty() {
            cfg.crash_point = p;
        }
    }
    if let Ok(r) = std::env::var("SHETM_CRASH_ROUND") {
        if !r.is_empty() {
            cfg.crash_round = r.parse().context("SHETM_CRASH_ROUND")?;
        }
    }
    Ok(cfg)
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let cfg = system_config(cli)?;
    let dir = if cfg.artifacts_dir.is_empty() {
        "artifacts".to_string()
    } else {
        cfg.artifacts_dir.clone()
    };
    println!("config: {cfg:#?}");
    if ArtifactStore::available(&dir) {
        let store = ArtifactStore::load(&dir)?;
        println!("artifacts in {dir}:");
        for name in store.names() {
            let meta = store.get(name)?.meta();
            println!("  {name:<22} kind={:?} params={:?}", meta.kind, meta.params);
        }
    } else if cfg!(feature = "pjrt") {
        println!("no artifacts in {dir} (run `make artifacts`)");
    } else {
        println!(
            "artifacts unavailable: this build has no `pjrt` feature \
             (native backend only; see DESIGN.md §4)"
        );
    }
    Ok(())
}

fn cmd_synth(cli: &Cli) -> Result<()> {
    let cfg = system_config(cli)?;
    let n = cfg.n_words;
    // Partitioned halves (the paper's no-contention configuration); use
    // --set to explore other shapes.
    let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
    let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
    if !cfg.artifacts_dir.is_empty() && (n != 1 << 18 || cfg.bmp_shift != 0) {
        bail!("PJRT artifacts are compiled for stmr.n_words=262144, bmp_shift=0");
    }
    let mut session = Hetm::from_config(&cfg)
        .variant(variant(cli))
        .synth(cpu_spec, gpu_spec)
        .trace(cli.trace.is_some())
        .build()?;
    session.run_rounds(cli.rounds)?;
    let label = if session.is_cluster() {
        format!(
            "synthetic W1-100% on {} sharded GPUs{}",
            session.n_gpus(),
            if cfg.cpu_parallel { ", parallel CPU" } else { "" }
        )
    } else if cfg.cpu_parallel {
        "synthetic W1-100%, partitioned, parallel CPU".to_string()
    } else {
        "synthetic W1-100%, partitioned".to_string()
    };
    report(cli, &label, &session)
}

fn cmd_memcached(cli: &Cli) -> Result<()> {
    let cfg = system_config(cli)?;
    let n_sets = cli
        .raw
        .get_or("memcached.n_sets", 1usize << 15)
        .context("memcached.n_sets")?;
    let mut mc = McConfig::new(n_sets);
    mc.steal_shift = cli.raw.get_or("memcached.steal", 0.0)?;
    if !cfg.artifacts_dir.is_empty() && (n_sets != 1 << 15 || cfg.bmp_shift != 0) {
        bail!("PJRT memcached artifact is compiled for memcached.n_sets=32768, bmp_shift=0");
    }
    let mut session = Hetm::from_config(&cfg)
        .variant(variant(cli))
        .memcached(mc)
        .trace(cli.trace.is_some())
        .build()?;
    session.run_rounds(cli.rounds)?;
    let label = if session.is_cluster() {
        format!("memcachedGPU on {} sharded GPUs", session.n_gpus())
    } else {
        "memcachedGPU on SHeTM".to_string()
    };
    report(cli, &label, &session)
}

/// `shetm run [--workload NAME] [--gpus N]`: drive any [`shetm::apps`]
/// workload through its `Workload` implementation and verify its
/// correctness oracle afterwards — the run FAILS if the invariant breaks.
fn cmd_run(cli: &Cli) -> Result<()> {
    let mut cfg = system_config(cli)?;
    if cli.pjrt || !cfg.artifacts_dir.is_empty() {
        bail!("`shetm run` drives the native backend only (drop --pjrt)");
    }
    if cli.recover && cfg.checkpoint_dir.is_empty() {
        bail!("--recover needs --checkpoint-dir (or durability.checkpoint_dir)");
    }
    if cli.recover {
        // The recovery run finishes the job; re-arming the same crash
        // plan would just kill it again at the next due checkpoint.
        cfg.crash_point = String::new();
    }
    let name = cli
        .workload
        .clone()
        .unwrap_or_else(|| cfg.workload.clone());
    let label = format!("workload {name} on {} device(s)", cfg.n_gpus.max(1));
    let builder = Hetm::from_config(&cfg)
        .variant(variant(cli))
        .workload_named(&name)
        .app_config(cli.raw.clone())
        .trace(cli.trace.is_some());
    let mut session = if cli.recover {
        let dir = cfg.checkpoint_dir.clone();
        let session = builder.recover(&dir)?;
        println!(
            "recovered from {dir} at round {} (virtual t = {:.6}s)",
            session.stats().rounds,
            session.now()
        );
        session
    } else {
        builder.build()?
    };
    let done = session.stats().rounds as usize;
    if done < cli.rounds {
        session.run_rounds(cli.rounds - done)?;
    }
    session.drain()?;
    report(cli, &label, &session)?;
    session
        .check_invariants()
        .context("correctness oracle FAILED")?;
    println!("  invariants        : OK ({name} oracle passed)");
    Ok(())
}

fn cmd_baselines(cli: &Cli) -> Result<()> {
    let cfg = system_config(cli)?;
    let n = cfg.n_words;
    let dur = cfg.period_s * cli.rounds as f64;

    let clock = Arc::new(GlobalClock::new());
    let stmr = Arc::new(SharedStmr::new(n));
    let tm = launch::build_guest(cfg.guest, clock);
    let mut cpu = SynthCpu::new(
        stmr,
        tm,
        SynthSpec::w1(n, 1.0),
        cfg.cpu_threads,
        cfg.cpu_txn_s,
        cfg.seed,
    );
    let cpu_stats = baseline::run_cpu_only(&mut cpu, dur, cfg.period_s);
    println!(
        "{}",
        MetricsSnapshot::from_run_stats("CPU-only (uninstrumented guest)", &cpu_stats)
            .render_text()
    );

    let mut gpu = SynthGpu::new(
        SynthSpec::w1(n, 1.0),
        1024,
        cfg.gpu_kernel_latency_s,
        cfg.gpu_txn_s,
        cfg.seed,
    );
    let mut device = GpuDevice::new(n, cfg.bmp_shift, Backend::Native);
    let cost = launch::cost_model(&cfg);
    let gpu_stats = baseline::run_gpu_only(&mut gpu, &mut device, &cost, dur, cfg.period_s)?;
    println!(
        "{}",
        MetricsSnapshot::from_run_stats("GPU-only (double buffering)", &gpu_stats).render_text()
    );
    Ok(())
}

fn main() -> Result<()> {
    let cli = parse_cli()?;
    match cli.cmd.as_str() {
        "info" => cmd_info(&cli),
        "run" | "workload" => cmd_run(&cli),
        "synth" => cmd_synth(&cli),
        "memcached" => cmd_memcached(&cli),
        "baselines" => cmd_baselines(&cli),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
shetm — Speculative Heterogeneous Transactional Memory (PACT'19 reproduction)

USAGE: shetm <info|run|synth|memcached|baselines> [OPTIONS]

  run runs any application through the Workload trait and verifies its
  built-in correctness oracle afterwards; `shetm --workload bank --gpus 2`
  is shorthand for `shetm run --workload bank --gpus 2`.

OPTIONS:
  --config FILE     load a TOML-subset config file
  --set key=value   override a config key (repeatable)
  --workload NAME   synth|memcached|bank|kmeans|zipfkv (run command)
  --rounds N        synchronization rounds (default 50)
  --gpus N          shard the STMR across N simulated devices (cluster)
  --threads N       drive the N per-device pipelines on N OS threads
                    (wall-clock only: results are bit-identical; N > 1
                    selects the cluster engine even at --gpus 1)
  --basic           basic algorithm variant (Fig. 1a)
  --pjrt            use PJRT artifacts from ./artifacts
  --trace FILE      write a Perfetto-loadable virtual-time trace (JSON;
                    implies telemetry; deterministic — bit-identical
                    across --threads N; see docs/OBSERVABILITY.md)
  --checkpoint-dir DIR
                    write incremental round-boundary checkpoints + the
                    external-txn journal under DIR (DESIGN.md §13);
                    checkpoint I/O costs zero virtual time, so results
                    stay bit-identical to a run without it
  --recover         (run command) resume from the newest complete
                    checkpoint in the checkpoint dir, replay the journal
                    prefix, verify bit-exactly, then run the remaining
                    rounds; crash injection is disabled on this run;
                    --gpus / cluster.shard_bits must match the
                    checkpoint's recorded shard layout
  --rebalance       enable the online round-barrier shard rebalancer
                    (cluster only; DESIGN.md §14): migrate hot ownership
                    blocks from the most to the least loaded device

ENVIRONMENT:
  SHETM_CRASH_POINT   arm deterministic fault injection at a checkpoint:
                      mid-page-write|after-pages|mid-wal-append|after-wal|
                      mid-manifest|corrupt-page-byte|corrupt-manifest-byte|
                      after-checkpoint|mid-migration (overrides
                      durability.crash_point)
  SHETM_CRASH_ROUND   first round the armed crash may fire at (default 0)
  SHETM_CRASH_KILL=1  crash via process exit(3) instead of an error

KEYS (defaults): stmr.n_words=262144 stmr.bmp_shift=0 cpu.threads=8
  cpu.parallel=false (synth: run the cpu.threads workers on real OS
  threads via ParallelCpuDriver — deterministic, different trace)
  cpu.guest=tinystm|norec|htm cpu.txn_ns hetm.period_ms=80
  hetm.policy=favor-cpu|favor-gpu|starvation-guard hetm.early_validation
  hetm.early_interval_frac=0.25 (in (0,1])
  hetm.log_compaction=false (dedup the write log last-write-wins before
  chunking) hetm.chunk_filter=false (skip per-entry chunk validation on
  provable non-intersection via chunk signatures)
  bus.latency_us bus.gbps gpu.kernel_latency_us gpu.txn_ns
  gpu.validate_entry_ns gpu.sig_check_ns=250
  cluster.n_gpus=1 cluster.shard_bits=12 cluster.cross_shard_prob=0
  cluster.threads=1 cluster.rebalance=false cluster.rebalance_interval=4
  cluster.rebalance_threshold=1.25 cluster.rebalance_granules=8
  cluster.dev_speed= (comma list, e.g. \"1,2,1,1\": per-device speed
  factors — scaled cost models + load-proportional initial layout)
  telemetry.enabled=false (labeled metrics + latency histograms at every
  round barrier; zero-overhead when off)
  durability.checkpoint_dir= (empty = off) durability.interval_rounds=1
  durability.crash_point= durability.crash_round=0
  memcached.n_sets memcached.steal runtime.artifacts seed
  workload=synth|memcached|bank|kmeans|zipfkv plus per-app sections:
  bank.accounts bank.balance bank.max_transfer bank.update_frac
  bank.cross_prob kmeans.k kmeans.dim kmeans.points kmeans.probe
  kmeans.hot_prob zipfkv.keys zipfkv.theta zipfkv.update_frac
  zipfkv.hot_keys zipfkv.hot_prob zipfkv.cpu_hot_prob zipfkv.hot_stride
  zipfkv.drift";
