//! The programmer-facing front door: [`Hetm`] (a fluent builder) and
//! [`Session`] (one facade over both engines).
//!
//! The paper's headline contribution is an *abstraction*: "the illusion of
//! a single memory region, shared among the CPUs and the GPU(s), with
//! support for atomic transactions."  This module is that abstraction's
//! API surface.  Instead of picking one of the `launch::build_*`
//! constructors and programming against two engine types, embedders write:
//!
//! ```text
//! let mut session = Hetm::builder()
//!     .words(1 << 20)
//!     .gpus(4)
//!     .threads(4)
//!     .guest(GuestKind::Tiny)
//!     .policy(PolicyKind::FavorCpu)
//!     .workload(Box::new(my_workload))
//!     .build()?;
//! session.run_rounds(50)?;
//! session.check_invariants()?;
//! ```
//!
//! The builder validates the full knob cross-product up front with typed
//! [`BuildError`]s (zero threads, zero devices, shard-layout mismatches,
//! `cpu.parallel` on a non-synthetic workload, PJRT in cluster mode, the
//! `early_interval_frac` domain — every check that used to live scattered
//! across `main.rs` and the config parser, in one place) and decides the
//! engine shape itself: one device → [`RoundEngine`]; several devices, or
//! `threads > 1`, or an explicit [`Hetm::force_cluster`] →
//! [`ClusterEngine`].  Construction is **bit-identical** to the legacy
//! `launch::build_*` paths on the same configuration — enforced by the
//! golden equivalence suite in `rust/tests/session_api.rs` — so the
//! `n_gpus = 1` ≡ `RoundEngine` and threaded ≡ sequential guarantees
//! carry over unchanged.
//!
//! [`Session::txn`] is the paper-faithful transaction entry point: a
//! CPU-side atomic block executed through the session's guest TM against
//! the shared region, whose write-set ships to the device replicas with
//! the next synchronization round — the single-shared-memory illusion
//! without constructing drivers by hand.
//!
//! # Example
//!
//! ```
//! use shetm::config::{Raw, SystemConfig};
//! use shetm::session::Hetm;
//!
//! let mut cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
//! cfg.n_words = 1 << 14; // small region so the doctest runs fast
//! cfg.cpu_txn_s = 2e-6;
//! cfg.period_s = 0.004;
//!
//! let mut session = Hetm::from_config(&cfg).build().unwrap();
//! session.run_rounds(2).unwrap();
//! assert!(session.stats().cpu_commits > 0);
//!
//! // The single-shared-memory illusion: an atomic CPU-side transaction
//! // through the session itself...
//! let r = session
//!     .txn(|tx| {
//!         let v = tx.read(0)?;
//!         tx.write(0, v + 1)
//!     })
//!     .unwrap();
//! assert!(r.ts > 0);
//!
//! // ...whose write lands on the device replica with the next round.
//! session.run_round().unwrap();
//! session.drain().unwrap();
//! assert_eq!(session.stmr().load(0), session.device_stmr(0)[0]);
//! session.check_invariants().unwrap();
//! ```

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::apps::memcached::McConfig;
use crate::apps::synth::{SynthGpu, SynthSpec};
use crate::apps::workload::{from_raw, gpu_seed, MemcachedWorkload, SynthWorkload, Workload};
use crate::cluster::{ClusterEngine, ClusterStats, ShardMap};
use crate::config::{PolicyKind, Raw, SystemConfig};
use crate::coordinator::round::{CpuDriver, GpuDriver, RoundEngine, Variant};
use crate::coordinator::stats::{RoundStats, RunStats};
use crate::durability::{
    self, CrashPoint, DurabilityHook, ExternalJournal, FaultPlan, JournalRecord, RecordKind,
};
use crate::gpu::{Backend, GpuDevice};
use crate::launch::{self, WorkloadClusterEngine, WorkloadEngine};
use crate::stm::{Abort, GuestTm, SharedStmr, TxOps, TxnResult, WriteEntry};
use crate::telemetry::{Collector, MetricsSnapshot, Telemetry};

/// A misconfiguration caught by [`Hetm::build`].  Every knob-cross-product
/// rule lives here, as a typed error instead of a scattered panic or an
/// ad-hoc `bail!` at some call-site.
#[derive(Debug, Clone, PartialEq)]
pub enum BuildError {
    /// `words` was 0: the STMR must hold at least one word.
    ZeroWords,
    /// `cpu_threads` was 0: the CPU side models at least one worker.
    ZeroCpuThreads,
    /// `gpus` was 0: the platform needs at least one device.
    ZeroGpus,
    /// `threads` was 0 (the `--threads 0` class of error): the cluster
    /// pipelines need at least one OS thread (1 = sequential).
    ZeroThreads,
    /// `gpu_batch` was 0: device kernels execute whole batches.
    ZeroGpuBatch,
    /// The execution period must be finite and positive (seconds).
    InvalidPeriod(f64),
    /// `early_interval_frac` outside `(0, 1]` (the `hetm.early_interval_frac`
    /// class of error): `1 / frac` must be a sane early-validation count.
    InvalidEarlyInterval(f64),
    /// The starvation-guard policy with a zero abort limit would never
    /// disengage its read-only mode meaningfully.
    ZeroStarvationLimit,
    /// The STMR is too large for the engine's wire formats: chunk and
    /// batch address channels (`LogChunk::addrs`, `TxnBatch::read_idx`)
    /// are `i32`, so every word index must fit in an `i32`.
    StmrTooLarge {
        /// STMR words requested.
        words: usize,
    },
    /// More devices requested than STMR words: at least one word per
    /// device is the hard floor.
    GpusExceedWords {
        /// Devices requested.
        gpus: usize,
        /// STMR words available.
        words: usize,
    },
    /// An explicitly-set `shard_bits` does not fit: `gpus << shard_bits`
    /// exceeds the region, so some device would own no block.  (When
    /// `shard_bits` is left at its default the builder clamps instead,
    /// matching the legacy CLI behavior.)
    ShardLayout {
        /// Devices requested.
        gpus: usize,
        /// Explicit ownership-block shift.
        shard_bits: u32,
        /// STMR words available.
        words: usize,
    },
    /// `dev_speed` does not describe the cluster: one finite positive
    /// factor per device is required.
    DevSpeed {
        /// Factors supplied.
        factors: usize,
        /// Devices configured.
        gpus: usize,
    },
    /// [`Session::recover`] was invoked with a configuration whose shard
    /// layout shape contradicts the checkpoint's recorded one (device
    /// count or ownership-block shift).  Replaying under a different
    /// layout would route every log chunk differently and is guaranteed
    /// to diverge, so it is rejected before any replay work.
    LayoutMismatch {
        /// Devices this session is configured for.
        gpus: usize,
        /// Ownership-block shift this session would build.
        shard_bits: u32,
        /// Devices the checkpoint was written by.
        ck_gpus: usize,
        /// Ownership-block shift the checkpoint recorded.
        ck_shard_bits: u32,
    },
    /// `parallel_cpu` is only implemented for the synthetic workload
    /// (its disjoint-partition workers satisfy the determinism contract
    /// of [`crate::coordinator::ParallelCpuDriver`]).
    ParallelCpuUnsupported {
        /// The offending workload's name.
        workload: String,
    },
    /// The PJRT backend drives a single device only (cluster mode is
    /// native-backend).
    PjrtCluster,
    /// No PJRT artifacts exist for this workload (only the paper's synth
    /// and memcached kernels were compiled).
    PjrtWorkload {
        /// The offending workload's name.
        workload: String,
    },
    /// The artifact directory was configured but could not be loaded.
    Artifacts(String),
    /// Workload resolution failed (unknown name or bad app section).
    Workload(String),
    /// `clock_epoch_limit` applies to the shared commit clock; the
    /// parallel CPU driver owns per-worker clocks instead.
    EpochLimitUnsupported,
    /// The durability layer could not be armed (unparsable
    /// `durability.crash_point`, or the checkpoint directory/journal
    /// could not be created).
    Durability(String),
}

impl std::fmt::Display for BuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuildError::ZeroWords => write!(f, "stmr.n_words must be at least 1"),
            BuildError::ZeroCpuThreads => write!(f, "cpu.threads must be at least 1"),
            BuildError::ZeroGpus => write!(f, "cluster.n_gpus must be at least 1"),
            BuildError::ZeroThreads => {
                write!(f, "cluster.threads must be at least 1 (1 = sequential)")
            }
            BuildError::ZeroGpuBatch => write!(f, "gpu_batch must be at least 1"),
            BuildError::InvalidPeriod(p) => {
                write!(f, "hetm.period must be a finite positive duration, got {p}")
            }
            BuildError::InvalidEarlyInterval(x) => write!(
                f,
                "hetm.early_interval_frac must be a finite fraction in (0, 1], got {x}"
            ),
            BuildError::ZeroStarvationLimit => write!(
                f,
                "hetm.gpu_starvation_limit must be at least 1 under the \
                 starvation-guard policy"
            ),
            BuildError::StmrTooLarge { words } => write!(
                f,
                "stmr.n_words = {words} exceeds the i32 address channels \
                 (log chunks and device batches index words as i32; the \
                 maximum supported STMR is {} words)",
                i32::MAX
            ),
            BuildError::GpusExceedWords { gpus, words } => write!(
                f,
                "{gpus} devices cannot shard a {words}-word STMR (one word \
                 per device is the hard floor)"
            ),
            BuildError::ShardLayout {
                gpus,
                shard_bits,
                words,
            } => write!(
                f,
                "shard layout does not fit: {gpus} devices x 2^{shard_bits}-word \
                 ownership blocks exceed the {words}-word STMR; lower \
                 shard_bits or leave it default to auto-clamp"
            ),
            BuildError::DevSpeed { factors, gpus } => write!(
                f,
                "cluster.dev_speed lists {factors} factors for {gpus} devices \
                 (one finite positive factor per device is required)"
            ),
            BuildError::LayoutMismatch {
                gpus,
                shard_bits,
                ck_gpus,
                ck_shard_bits,
            } => write!(
                f,
                "recovery layout mismatch: the checkpoint was written by \
                 {ck_gpus} devices with 2^{ck_shard_bits}-word ownership \
                 blocks, but this session is configured for {gpus} devices \
                 with 2^{shard_bits}-word blocks; recover with the original \
                 --gpus / cluster.shard_bits"
            ),
            BuildError::ParallelCpuUnsupported { workload } => write!(
                f,
                "cpu.parallel is only supported for the synthetic workload \
                 (got {workload:?}): other drivers do not partition into \
                 deterministic per-thread workers"
            ),
            BuildError::PjrtCluster => {
                write!(f, "cluster mode (gpus > 1) supports the native backend only")
            }
            BuildError::PjrtWorkload { workload } => write!(
                f,
                "no PJRT artifacts exist for workload {workload:?} (synth and \
                 memcached only); unset runtime.artifacts or pick Backend::Native"
            ),
            BuildError::Artifacts(msg) => write!(f, "artifact backend unavailable: {msg}"),
            BuildError::Workload(msg) => write!(f, "workload resolution failed: {msg}"),
            BuildError::EpochLimitUnsupported => write!(
                f,
                "clock_epoch_limit applies to the shared commit clock; \
                 cpu.parallel workers own per-worker clocks"
            ),
            BuildError::Durability(msg) => write!(f, "durability setup failed: {msg}"),
        }
    }
}

impl std::error::Error for BuildError {}

/// What the session will run: either a named/boxed [`Workload`] or one of
/// the paper applications with caller-supplied parameters.
enum AppChoice {
    /// Resolve by name through [`from_raw`] (uses the per-app config
    /// sections of [`Hetm::app_config`]).
    Named(String),
    /// A caller-built workload.
    Boxed(Box<dyn Workload>),
    /// The synthetic workload with explicit CPU/GPU specs.
    Synth {
        cpu: Box<SynthSpec>,
        gpu: Box<SynthSpec>,
    },
    /// MemcachedGPU with an explicit cache configuration.
    Memcached(McConfig),
}

/// Fluent builder for a [`Session`] — the one front door to the platform.
///
/// Start from [`Hetm::builder`] (defaults) or [`Hetm::from_config`] (seed
/// every knob from a parsed [`SystemConfig`]), chain setters, finish with
/// [`Hetm::build`].  See the [module docs](self) for the full story and a
/// runnable example.
pub struct Hetm {
    cfg: SystemConfig,
    raw: Raw,
    app: AppChoice,
    variant: Variant,
    gpu_batch: usize,
    backend: Option<Backend>,
    clock_epoch_limit: Option<i32>,
    shard_bits_explicit: bool,
    force_cluster: bool,
    trace: bool,
}

impl Default for Hetm {
    fn default() -> Self {
        Self::builder()
    }
}

impl Hetm {
    /// A builder with the default [`SystemConfig`] and the synthetic
    /// workload (the paper's partitioned W1-100% configuration).
    pub fn builder() -> Self {
        Self::from_config(&SystemConfig::default())
    }

    /// A builder seeded from a parsed configuration; individual setters
    /// override afterwards.
    pub fn from_config(cfg: &SystemConfig) -> Self {
        Hetm {
            cfg: cfg.clone(),
            raw: Raw::new(),
            app: AppChoice::Named(cfg.workload.clone()),
            variant: Variant::Optimized,
            gpu_batch: 1024,
            backend: None,
            clock_epoch_limit: None,
            shard_bits_explicit: false,
            force_cluster: false,
            trace: false,
        }
    }

    /// STMR size in words (named workloads may override with their own
    /// layout, e.g. `bank.accounts`).
    pub fn words(mut self, n: usize) -> Self {
        self.cfg.n_words = n;
        self
    }

    /// Bitmap granularity shift (granule = `1 << shift` words).
    pub fn bmp_shift(mut self, shift: u32) -> Self {
        self.cfg.bmp_shift = shift;
        self
    }

    /// Simulated devices the STMR is sharded across (1 = the paper's
    /// single-device SHeTM).
    pub fn gpus(mut self, n: usize) -> Self {
        self.cfg.n_gpus = n;
        self
    }

    /// OS worker threads driving the per-device cluster pipelines
    /// (`cluster.threads`; purely a wall-clock lever — results are
    /// bit-identical at any setting).  Values above 1 select the cluster
    /// engine even at one device, so the run crosses a real thread
    /// boundary.
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.cluster_threads = n;
        self
    }

    /// Modeled CPU worker threads (`cpu.threads`).
    pub fn cpu_threads(mut self, n: usize) -> Self {
        self.cfg.cpu_threads = n;
        self
    }

    /// Run the CPU side's workers on real OS threads via
    /// [`crate::coordinator::ParallelCpuDriver`] (`cpu.parallel`;
    /// synthetic workload only).
    pub fn parallel_cpu(mut self, on: bool) -> Self {
        self.cfg.cpu_parallel = on;
        self
    }

    /// CPU guest TM (§IV-B modularity).
    pub fn guest(mut self, guest: crate::config::GuestKind) -> Self {
        self.cfg.guest = guest;
        self
    }

    /// Inter-device conflict-resolution policy (§IV-E).
    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.cfg.policy = policy;
        self
    }

    /// Algorithm variant: basic (Fig. 1a) or optimized SHeTM (Fig. 1b).
    pub fn variant(mut self, variant: Variant) -> Self {
        self.variant = variant;
        self
    }

    /// Execution-phase duration in seconds.
    pub fn period_s(mut self, s: f64) -> Self {
        self.cfg.period_s = s;
        self
    }

    /// Execution-phase duration in milliseconds.
    pub fn period_ms(mut self, ms: f64) -> Self {
        self.cfg.period_s = ms / 1e3;
        self
    }

    /// Workload-generation RNG seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Enable early validation (§IV-D).
    pub fn early_validation(mut self, on: bool) -> Self {
        self.cfg.early_validation = on;
        self
    }

    /// Early-validation trigger interval as a fraction of the period;
    /// must be finite and in `(0, 1]` (validated at [`Hetm::build`]).
    pub fn early_interval_frac(mut self, frac: f64) -> Self {
        self.cfg.early_interval_frac = frac;
        self
    }

    /// Deduplicate the write log last-write-wins before chunking
    /// (`hetm.log_compaction`).
    pub fn log_compaction(mut self, on: bool) -> Self {
        self.cfg.log_compaction = on;
        self
    }

    /// Attach conflict-prefilter signatures to log chunks
    /// (`hetm.chunk_filter`).
    pub fn chunk_filter(mut self, on: bool) -> Self {
        self.cfg.chunk_filter = on;
        self
    }

    /// Consecutive GPU aborts before the starvation guard engages.
    pub fn starvation_limit(mut self, n: u32) -> Self {
        self.cfg.gpu_starvation_limit = n;
        self
    }

    /// Shard-ownership block shift (`cluster.shard_bits`): blocks of
    /// `1 << bits` words.  Setting this explicitly makes a layout that
    /// does not fit a [`BuildError::ShardLayout`] instead of the default
    /// auto-clamp.
    pub fn shard_bits(mut self, bits: u32) -> Self {
        self.cfg.shard_bits = bits;
        self.shard_bits_explicit = true;
        self
    }

    /// Cross-shard write-injection probability (cluster synth only).
    pub fn cross_shard_prob(mut self, p: f64) -> Self {
        self.cfg.cross_shard_prob = p;
        self
    }

    /// Enable the online round-barrier rebalancer (`cluster.rebalance`):
    /// migrate hot ownership blocks from the most loaded device to the
    /// least loaded one at the synchronization barrier (DESIGN.md §14).
    /// Off by default — the layout then stays bit-identical to the
    /// static one.
    pub fn rebalance(mut self, on: bool) -> Self {
        self.cfg.rebalance = on;
        self
    }

    /// Rebalancer tuning: observation window in rounds, trigger
    /// threshold (migrate when the hottest device's windowed load
    /// exceeds `threshold` × the mean), and the per-migration cap on
    /// moved ownership blocks.
    pub fn rebalance_tuning(mut self, interval: usize, threshold: f64, granules: usize) -> Self {
        self.cfg.rebalance_interval = interval;
        self.cfg.rebalance_threshold = threshold;
        self.cfg.rebalance_granules = granules;
        self
    }

    /// Per-device relative speed factors (`cluster.dev_speed`): each
    /// factor scales that device's cost model, and the initial shard
    /// layout becomes load-proportional ([`ShardMap::proportional`]).
    /// One finite positive factor per device (validated at
    /// [`Hetm::build`]); empty = uniform cluster.
    pub fn dev_speeds(mut self, speeds: &[f64]) -> Self {
        self.cfg.dev_speed = speeds.to_vec();
        self
    }

    /// Device batch size (transactions per kernel activation; must match
    /// the compiled artifact's `b` under the PJRT backend).
    pub fn gpu_batch(mut self, n: usize) -> Self {
        self.gpu_batch = n;
        self
    }

    /// Run a caller-built [`Workload`] (the trait is the plug for every
    /// application; see `rust/src/apps/workload.rs`).
    pub fn workload(mut self, w: Box<dyn Workload>) -> Self {
        self.app = AppChoice::Boxed(w);
        self
    }

    /// Run a workload by name (`synth | memcached | bank | kmeans |
    /// zipfkv`), resolved against the per-app sections of
    /// [`Hetm::app_config`].
    pub fn workload_named(mut self, name: &str) -> Self {
        self.app = AppChoice::Named(name.to_string());
        self
    }

    /// Per-app config sections (`[bank]`, `[zipfkv]`, ...) for
    /// [`Hetm::workload_named`].
    pub fn app_config(mut self, raw: Raw) -> Self {
        self.raw = raw;
        self
    }

    /// Run the synthetic workload with explicit CPU/GPU specs (the
    /// paper's §V-A..§V-C shapes; conflict injection, partitions).
    pub fn synth(mut self, cpu_spec: SynthSpec, gpu_spec: SynthSpec) -> Self {
        self.app = AppChoice::Synth {
            cpu: Box::new(cpu_spec),
            gpu: Box::new(gpu_spec),
        };
        self
    }

    /// Run MemcachedGPU with an explicit cache configuration (§V-D).
    pub fn memcached(mut self, mc: McConfig) -> Self {
        self.app = AppChoice::Memcached(mc);
        self
    }

    /// Force a device backend, skipping the artifact-directory resolution
    /// (e.g. a preloaded [`Backend::Pjrt`] store).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Override the commit clock's per-round tick budget (tests force a
    /// small epoch to exercise the round-boundary epoch reset cheaply).
    pub fn clock_epoch_limit(mut self, limit: i32) -> Self {
        self.clock_epoch_limit = Some(limit);
        self
    }

    /// Always use the cluster engine, even at one device (exposes
    /// [`ClusterStats`] and the per-device pipeline; bit-identical to the
    /// single-device engine at `gpus = 1`).
    pub fn force_cluster(mut self, on: bool) -> Self {
        self.force_cluster = on;
        self
    }

    /// Enable the telemetry collector (`telemetry.enabled`): labeled
    /// counters, gauges, and latency histograms gathered at every round
    /// barrier.  Off by default — the engines then skip all observation
    /// work (one branch per round; DESIGN.md §11).
    pub fn telemetry(mut self, on: bool) -> Self {
        self.cfg.telemetry_enabled = on;
        self
    }

    /// Additionally buffer the virtual-time trace stream (implies
    /// telemetry; export with [`Session::trace_json`] /
    /// [`Session::write_trace`], or `shetm run --trace FILE`).  The
    /// stream is deterministic: bit-identical across `--threads N` and
    /// across engines at one device.
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// Enable durability (`durability.checkpoint_dir`, CLI
    /// `--checkpoint-dir`): incremental checkpoints at the round barrier
    /// plus a write-ahead journal of [`Session::txn`] injections, all
    /// under `dir`.  Recover with [`Hetm::recover`].  Checkpoints cost
    /// zero virtual time, so results stay bit-identical to a
    /// durability-off run (DESIGN.md §13).
    pub fn checkpoint_dir(mut self, dir: &str) -> Self {
        self.cfg.checkpoint_dir = dir.to_string();
        self
    }

    /// Checkpoint every `rounds` rounds (`durability.interval_rounds`;
    /// default 1, 0 = journal-only).
    pub fn checkpoint_interval(mut self, rounds: u64) -> Self {
        self.cfg.checkpoint_interval_rounds = rounds;
        self
    }

    /// Arm a deterministic fault: crash at `point` at the first
    /// checkpoint whose round is `>= at_round` (the crash-injection test
    /// harness; see [`CrashPoint`]).
    pub fn crash_plan(mut self, point: CrashPoint, at_round: u64) -> Self {
        self.cfg.crash_point = point.as_str().to_string();
        self.cfg.crash_round = at_round;
        self
    }

    /// Recover from the newest complete checkpoint under `dir` and return
    /// a session resumed at that round — bit-identical to a run that
    /// never crashed — with durability re-armed on the same directory.
    /// With no usable checkpoint the session starts fresh at round 0.
    /// Shorthand for [`Session::recover`].
    pub fn recover(self, dir: &str) -> Result<Session> {
        Session::recover(self, dir)
    }

    /// Validate the whole knob cross-product and assemble the [`Session`].
    pub fn build(self) -> Result<Session, BuildError> {
        let Hetm {
            cfg,
            raw,
            app,
            variant,
            gpu_batch,
            backend,
            clock_epoch_limit,
            shard_bits_explicit,
            force_cluster,
            trace,
        } = self;

        // --- Scalar knob validation (one place, typed) -------------------
        if cfg.n_words == 0 {
            return Err(BuildError::ZeroWords);
        }
        if cfg.cpu_threads == 0 {
            return Err(BuildError::ZeroCpuThreads);
        }
        if cfg.n_gpus == 0 {
            return Err(BuildError::ZeroGpus);
        }
        if cfg.cluster_threads == 0 {
            return Err(BuildError::ZeroThreads);
        }
        if gpu_batch == 0 {
            return Err(BuildError::ZeroGpuBatch);
        }
        if !cfg.period_s.is_finite() || cfg.period_s <= 0.0 {
            return Err(BuildError::InvalidPeriod(cfg.period_s));
        }
        if !cfg.early_interval_frac.is_finite()
            || cfg.early_interval_frac <= 0.0
            || cfg.early_interval_frac > 1.0
        {
            return Err(BuildError::InvalidEarlyInterval(cfg.early_interval_frac));
        }
        if cfg.policy == PolicyKind::CpuWithStarvationGuard && cfg.gpu_starvation_limit == 0 {
            return Err(BuildError::ZeroStarvationLimit);
        }
        if !cfg.dev_speed.is_empty()
            && (cfg.dev_speed.len() != cfg.n_gpus
                || cfg.dev_speed.iter().any(|s| !s.is_finite() || *s <= 0.0))
        {
            return Err(BuildError::DevSpeed {
                factors: cfg.dev_speed.len(),
                gpus: cfg.n_gpus,
            });
        }

        // --- Workload resolution -----------------------------------------
        // Synth specs are kept alongside when `cpu.parallel` needs them.
        let (workload, synth_specs): (Box<dyn Workload>, Option<(SynthSpec, SynthSpec)>) =
            match app {
                AppChoice::Named(name) => {
                    let w =
                        from_raw(&name, &raw, &cfg).map_err(|e| BuildError::Workload(e.to_string()))?;
                    let specs = if name == "synth" {
                        // Re-derive the specs for the parallel-CPU path.
                        let sw = SynthWorkload::from_raw(&raw, &cfg)
                            .map_err(|e| BuildError::Workload(e.to_string()))?;
                        Some((sw.cpu_spec.clone(), sw.gpu_spec.clone()))
                    } else {
                        None
                    };
                    (w, specs)
                }
                AppChoice::Boxed(w) => (w, None),
                AppChoice::Synth { cpu, gpu } => {
                    let cpu = *cpu;
                    let gpu = *gpu;
                    let w = SynthWorkload::new(cpu.clone(), gpu.clone(), cfg.n_words);
                    (Box::new(w), Some((cpu, gpu)))
                }
                AppChoice::Memcached(mc) => {
                    (Box::new(MemcachedWorkload::new(mc, cfg.seed)), None)
                }
            };
        let n_words = workload.n_words();
        // Word addresses travel through i32 channels (`LogChunk::addrs`,
        // `TxnBatch::read_idx`, ...): an STMR whose indices overflow them
        // would alias or go negative silently — reject it up front.
        if n_words > i32::MAX as usize {
            return Err(BuildError::StmrTooLarge { words: n_words });
        }
        let is_synth = synth_specs.is_some();

        if cfg.cpu_parallel && !is_synth {
            return Err(BuildError::ParallelCpuUnsupported {
                workload: workload.name().to_string(),
            });
        }
        if cfg.cpu_parallel && clock_epoch_limit.is_some() {
            return Err(BuildError::EpochLimitUnsupported);
        }

        // --- Cluster layout ----------------------------------------------
        if cfg.n_gpus > n_words {
            return Err(BuildError::GpusExceedWords {
                gpus: cfg.n_gpus,
                words: n_words,
            });
        }
        if shard_bits_explicit && cfg.n_gpus > 1 {
            // Checked: absurd shifts (e.g. shard_bits = 63) must surface
            // as the typed error, not an arithmetic-overflow panic.
            let fits = 1usize
                .checked_shl(cfg.shard_bits)
                .and_then(|block| cfg.n_gpus.checked_mul(block))
                .is_some_and(|span| span <= n_words);
            if !fits {
                return Err(BuildError::ShardLayout {
                    gpus: cfg.n_gpus,
                    shard_bits: cfg.shard_bits,
                    words: n_words,
                });
            }
        }
        let cluster = cfg.n_gpus > 1 || cfg.cluster_threads > 1 || force_cluster;

        // --- Backend resolution ------------------------------------------
        let backend = match backend {
            Some(b) => b,
            None => {
                if cfg.artifacts_dir.is_empty() {
                    Backend::Native
                } else {
                    let name = workload.name().to_string();
                    let (prstm, validate, mc_art) = match name.as_str() {
                        "synth" => ("prstm_r4_g0", "validate_synth_g0", ""),
                        "memcached" => ("prstm_r4_g0", "validate_mc_g0", "memcached"),
                        _ => return Err(BuildError::PjrtWorkload { workload: name }),
                    };
                    launch::build_backend(&cfg, prstm, validate, mc_art)
                        .map_err(|e| BuildError::Artifacts(e.to_string()))?
                }
            }
        };
        if matches!(backend, Backend::Pjrt { .. }) && cluster {
            return Err(BuildError::PjrtCluster);
        }

        // --- Assembly (bit-identical to the legacy launch paths) ---------
        let mut tm_handle: Option<Arc<dyn GuestTm>> = None;
        let mut stmr_handle: Option<Arc<SharedStmr>> = None;
        let mut inner = if cfg.cpu_parallel {
            // Synthetic workload on real CPU worker threads: mirrors the
            // former `build_parallel_synth_{,cluster_}engine` construction
            // exactly (same seeds, same specs), with the drivers boxed.
            let (cpu_spec, gpu_spec) = match synth_specs {
                Some(specs) => specs,
                // Unreachable: `cpu_parallel && !is_synth` was rejected
                // during validation; keep the typed error anyway so the
                // builder can never panic on a refactor of that check.
                None => {
                    return Err(BuildError::ParallelCpuUnsupported {
                        workload: workload.name().to_string(),
                    })
                }
            };
            if cluster {
                let map = launch::shard_map(&cfg, n_words);
                let cpu: Box<dyn CpuDriver + Send> =
                    Box::new(launch::build_parallel_synth_cpu(&cfg, &cpu_spec));
                let mut devices = Vec::with_capacity(map.n_shards());
                let mut gpus: Vec<Box<dyn GpuDriver + Send>> =
                    Vec::with_capacity(map.n_shards());
                for d in 0..map.n_shards() {
                    let mut spec = gpu_spec.clone().homed(map.clone(), d);
                    if map.n_shards() > 1 {
                        spec = spec.with_cross_shard(cfg.cross_shard_prob);
                    }
                    gpus.push(Box::new(SynthGpu::new(
                        spec,
                        gpu_batch,
                        cfg.gpu_kernel_latency_s,
                        cfg.gpu_txn_s,
                        gpu_seed(cfg.seed, d),
                    )));
                    devices.push(GpuDevice::new(n_words, cfg.bmp_shift, backend.clone()));
                }
                let mut engine = ClusterEngine::new(
                    launch::engine_config(&cfg, variant),
                    launch::cost_model(&cfg),
                    map,
                    devices,
                    cpu,
                    gpus,
                );
                launch::apply_cluster_knobs(&cfg, &mut engine);
                engine.align_replicas();
                Inner::Cluster(Box::new(engine))
            } else {
                let cpu: Box<dyn CpuDriver + Send> =
                    Box::new(launch::build_parallel_synth_cpu(&cfg, &cpu_spec));
                let gpu: Box<dyn GpuDriver + Send> = Box::new(SynthGpu::new(
                    gpu_spec.clone(),
                    gpu_batch,
                    cfg.gpu_kernel_latency_s,
                    cfg.gpu_txn_s,
                    gpu_seed(cfg.seed, 0),
                ));
                let device = GpuDevice::new(n_words, cfg.bmp_shift, backend);
                let mut engine = RoundEngine::new(
                    launch::engine_config(&cfg, variant),
                    launch::cost_model(&cfg),
                    device,
                    cpu,
                    gpu,
                );
                engine.align_replicas();
                Inner::Single(Box::new(engine))
            }
        } else if cluster {
            let map = launch::shard_map(&cfg, n_words);
            let (stmr, tm, cpu, gpus) = launch::workload_parts_full(
                &cfg,
                workload.as_ref(),
                &map,
                gpu_batch,
                clock_epoch_limit,
            );
            tm_handle = Some(tm);
            stmr_handle = Some(stmr);
            let devices = (0..map.n_shards())
                .map(|_| GpuDevice::new(n_words, cfg.bmp_shift, backend.clone()))
                .collect();
            let mut engine = ClusterEngine::new(
                launch::engine_config(&cfg, variant),
                launch::cost_model(&cfg),
                map,
                devices,
                cpu,
                gpus,
            );
            launch::apply_cluster_knobs(&cfg, &mut engine);
            engine.align_replicas();
            Inner::Cluster(Box::new(engine))
        } else {
            let map = ShardMap::solo(n_words);
            let (stmr, tm, cpu, mut gpus) = launch::workload_parts_full(
                &cfg,
                workload.as_ref(),
                &map,
                gpu_batch,
                clock_epoch_limit,
            );
            tm_handle = Some(tm);
            stmr_handle = Some(stmr);
            let gpu = gpus.remove(0);
            let device = GpuDevice::new(n_words, cfg.bmp_shift, backend);
            let mut engine = RoundEngine::new(
                launch::engine_config(&cfg, variant),
                launch::cost_model(&cfg),
                device,
                cpu,
                gpu,
            );
            engine.align_replicas();
            Inner::Single(Box::new(engine))
        };

        // Telemetry is installed after assembly so the constructors stay
        // bit-identical to the legacy launch paths; observation never
        // participates in the deterministic schedule.
        if cfg.telemetry_enabled || trace {
            match &mut inner {
                Inner::Single(e) => e.tel = Telemetry::collecting(trace),
                Inner::Cluster(e) => e.tel = Telemetry::collecting(trace),
            }
        }

        let mut session = Session {
            inner,
            workload,
            tm: tm_handle,
            txn_stmr: stmr_handle,
            txn_buf: Vec::new(),
            journal: None,
        };
        if !cfg.checkpoint_dir.is_empty() {
            let plan =
                crash_plan_from(&cfg).map_err(|e| BuildError::Durability(e.to_string()))?;
            session
                .arm_durability(
                    &cfg.checkpoint_dir,
                    cfg.checkpoint_interval_rounds,
                    plan,
                    None,
                )
                .map_err(|e| BuildError::Durability(e.to_string()))?;
        }
        Ok(session)
    }
}

/// Resolve the configured fault plan (`durability.crash_point` /
/// `crash_round`); empty = none.
fn crash_plan_from(cfg: &SystemConfig) -> Result<Option<FaultPlan>> {
    if cfg.crash_point.is_empty() {
        return Ok(None);
    }
    Ok(Some(FaultPlan {
        point: CrashPoint::parse(&cfg.crash_point)?,
        at_round: cfg.crash_round,
    }))
}

/// The engine behind the facade (boxed: the engines are large).
enum Inner {
    /// Single-device round engine (the paper's SHeTM).
    Single(Box<WorkloadEngine>),
    /// Sharded multi-device cluster engine.
    Cluster(Box<WorkloadClusterEngine>),
}

/// A running SHeTM platform: one facade over both engines, built by
/// [`Hetm`].  See the [module docs](self) for the API story.
pub struct Session {
    inner: Inner,
    workload: Box<dyn Workload>,
    /// Guest TM handle for [`Session::txn`] (absent under `cpu.parallel`,
    /// whose workers own per-worker TMs).
    tm: Option<Arc<dyn GuestTm>>,
    /// Shared-region handle for [`Session::txn`].
    txn_stmr: Option<Arc<SharedStmr>>,
    /// Reused write-entry buffer for [`Session::txn`].
    txn_buf: Vec<crate::stm::WriteEntry>,
    /// Write-ahead journal of external events, armed with durability
    /// (`None` = durability off).
    journal: Option<ExternalJournal>,
}

impl Session {
    /// Execute one synchronization round.
    pub fn run_round(&mut self) -> Result<()> {
        match &mut self.inner {
            Inner::Single(e) => e.run_round(),
            Inner::Cluster(e) => e.run_round(),
        }
    }

    /// Run `n` synchronization rounds.
    pub fn run_rounds(&mut self, n: usize) -> Result<()> {
        match &mut self.inner {
            Inner::Single(e) => e.run_rounds(n),
            Inner::Cluster(e) => e.run_rounds(n),
        }
    }

    /// Run rounds until at least `dur_s` of virtual time has elapsed.
    pub fn run_for(&mut self, dur_s: f64) -> Result<()> {
        match &mut self.inner {
            Inner::Single(e) => e.run_for(dur_s),
            Inner::Cluster(e) => e.run_for(dur_s),
        }
    }

    /// Quiesce: one zero-length round so commits carried from the last
    /// validation window ship and apply; afterwards the CPU and device
    /// replicas agree everywhere.
    pub fn drain(&mut self) -> Result<()> {
        // Write-ahead: the drain round may itself write a checkpoint, and
        // a crash inside it must recover to a journal that still replays
        // this drain at the right boundary.
        let rounds = self.stats().rounds;
        if let Some(j) = &mut self.journal {
            j.append(&JournalRecord {
                kind: RecordKind::Drain,
                after_round: rounds,
                commits: 0,
                attempts: 0,
                entries: Vec::new(),
            })?;
        }
        match &mut self.inner {
            Inner::Single(e) => e.drain(),
            Inner::Cluster(e) => e.drain(),
        }
    }

    /// Aggregate run statistics (single-device-compatible totals).
    pub fn stats(&self) -> &RunStats {
        match &self.inner {
            Inner::Single(e) => &e.stats,
            Inner::Cluster(e) => &e.stats,
        }
    }

    /// Cluster-only statistics (`None` on the single-device engine).
    pub fn cluster(&self) -> Option<&ClusterStats> {
        match &self.inner {
            Inner::Single(_) => None,
            Inner::Cluster(e) => Some(&e.cluster),
        }
    }

    /// Per-round statistics (most recent rounds, ring-limited).
    pub fn round_log(&self) -> &[RoundStats] {
        match &self.inner {
            Inner::Single(e) => &e.round_log,
            Inner::Cluster(e) => &e.round_log,
        }
    }

    /// The CPU-side STMR replica — the committed truth of the platform.
    pub fn stmr(&self) -> &SharedStmr {
        match &self.inner {
            Inner::Single(e) => e.cpu.stmr(),
            Inner::Cluster(e) => e.cpu.stmr(),
        }
    }

    /// Device `d`'s STMR replica (between a committed `drain` and the
    /// next round it equals the CPU truth).
    pub fn device_stmr(&self, d: usize) -> &[i32] {
        match &self.inner {
            Inner::Single(e) => {
                assert_eq!(d, 0, "single-device session");
                e.device.stmr()
            }
            Inner::Cluster(e) => e.devices[d].stmr(),
        }
    }

    /// Number of simulated devices.
    pub fn n_gpus(&self) -> usize {
        match &self.inner {
            Inner::Single(_) => 1,
            Inner::Cluster(e) => e.n_gpus(),
        }
    }

    /// OS worker threads driving the per-device pipelines (1 on the
    /// single-device engine).
    pub fn threads(&self) -> usize {
        match &self.inner {
            Inner::Single(_) => 1,
            Inner::Cluster(e) => e.threads(),
        }
    }

    /// Whether the cluster engine is running underneath.
    pub fn is_cluster(&self) -> bool {
        matches!(self.inner, Inner::Cluster(_))
    }

    /// Descriptor of the versioned shard layout — epoch, block shift,
    /// and the block → device owner table (`None` on the single-device
    /// engine, which has no layout to version).  The epoch starts at 0
    /// and bumps once per installed migration.
    pub fn layout_desc(&self) -> Option<crate::cluster::LayoutDesc> {
        match &self.inner {
            Inner::Single(_) => None,
            Inner::Cluster(e) => Some(e.map.desc()),
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        match &self.inner {
            Inner::Single(e) => e.now(),
            Inner::Cluster(e) => e.now(),
        }
    }

    /// Change the log-chunk size (ablation benches); call between rounds.
    pub fn set_chunk_entries(&mut self, n: usize) {
        match &mut self.inner {
            Inner::Single(e) => e.set_chunk_entries(n),
            Inner::Cluster(e) => e.set_chunk_entries(n),
        }
    }

    /// The workload driving this session.
    pub fn workload(&self) -> &dyn Workload {
        self.workload.as_ref()
    }

    /// The workload's name (labels, diagnostics).
    pub fn workload_name(&self) -> &str {
        self.workload.name()
    }

    /// The workload's optional run-summary line.
    pub fn stats_summary(&self) -> String {
        self.workload.stats_summary()
    }

    /// The active telemetry collector (`None` when telemetry is off).
    pub fn collector(&self) -> Option<&Collector> {
        match &self.inner {
            Inner::Single(e) => e.tel.collector(),
            Inner::Cluster(e) => e.tel.collector(),
        }
    }

    /// Export everything this run produced as one [`MetricsSnapshot`] —
    /// the single serializer behind `shetm`'s stats block, the JSON and
    /// Prometheus exports, and the bench files.
    pub fn metrics_snapshot(&self, label: &str) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::from_run_stats(label, self.stats());
        snap.meta = vec![
            ("workload".to_string(), self.workload_name().to_string()),
            ("n_gpus".to_string(), self.n_gpus().to_string()),
            ("threads".to_string(), self.threads().to_string()),
        ];
        snap.cluster = self.cluster().cloned();
        snap.registry = self.collector().map(|c| c.registry().clone());
        snap.workload_summary = self.stats_summary();
        snap
    }

    /// The buffered virtual-time trace as a Perfetto-loadable JSON
    /// document (`None` unless the session was built with
    /// [`Hetm::trace`]).
    pub fn trace_json(&self) -> Option<String> {
        self.collector().and_then(|c| c.trace_json())
    }

    /// Write the trace document to `path` (errors when tracing was not
    /// enabled on this session).
    pub fn write_trace(&self, path: &str) -> Result<()> {
        let mut doc = self.trace_json().ok_or_else(|| {
            anyhow!("tracing was not enabled on this session (Hetm::trace)")
        })?;
        doc.push('\n');
        std::fs::write(path, doc)?;
        Ok(())
    }

    /// Run the workload's correctness oracle against the committed CPU
    /// truth.  Call [`Session::drain`] first so carried commits have
    /// landed.
    pub fn check_invariants(&self) -> Result<()> {
        self.workload.check_invariants(self.stmr())
    }

    /// Execute a CPU-side atomic transaction against the shared region —
    /// the paper's single-shared-memory illusion as an API.
    ///
    /// The body runs through the session's guest TM (same commit clock as
    /// the workload's driver, so timestamps stay totally ordered),
    /// retrying on intra-CPU conflicts until commit; its write-set ships
    /// to the device replicas with the next round as a *carried* commit,
    /// which also makes it survive a favor-GPU round abort (it committed
    /// before that round began).  Instantaneous in virtual time.
    ///
    /// Errors under `cpu.parallel` (the workers own per-worker TMs, so
    /// there is no single clock an external transaction could join).
    pub fn txn<F>(&mut self, mut body: F) -> Result<TxnResult>
    where
        F: FnMut(&mut dyn TxOps) -> Result<(), Abort>,
    {
        let tm = self.tm.as_ref().ok_or_else(|| {
            anyhow!("session.txn() is unavailable under cpu.parallel (per-worker clocks)")
        })?;
        let stmr = self
            .txn_stmr
            .as_ref()
            .ok_or_else(|| anyhow!("txn_stmr missing while tm is present (builder invariant)"))?;
        self.txn_buf.clear();
        let rounds = match &self.inner {
            Inner::Single(e) => e.stats.rounds,
            Inner::Cluster(e) => e.stats.rounds,
        };
        let r = tm.execute_into(stmr, &mut body, &mut self.txn_buf);
        let attempts = 1 + u64::from(r.retries);
        match &mut self.inner {
            Inner::Single(e) => e.inject_external(&self.txn_buf, 1, attempts),
            Inner::Cluster(e) => e.inject_external(&self.txn_buf, 1, attempts),
        }
        if let Some(j) = &mut self.journal {
            j.append(&JournalRecord {
                kind: RecordKind::Txn,
                after_round: rounds,
                commits: 1,
                attempts,
                entries: self.txn_buf.clone(),
            })?;
        }
        Ok(r)
    }

    /// Per-shard carried write-log prefix, as it will seed the next round
    /// (one shard on the single-device engine).  Recovery compares this
    /// against the checkpoint's WAL copy; tests use it to pin
    /// bit-identity after a recover.
    pub fn carried_entries(&self) -> Vec<Vec<WriteEntry>> {
        match &self.inner {
            Inner::Single(e) => vec![e.carried_entries().to_vec()],
            Inner::Cluster(e) => (0..e.n_gpus())
                .map(|s| e.carried_entries(s).to_vec())
                .collect(),
        }
    }

    /// Replay one journaled external transaction: re-execute its recorded
    /// write-set through the guest TM (ticking the clock exactly as the
    /// original did) and re-inject the recorded statistics.  Read-only
    /// transactions left no entries and never ticked the clock, so for
    /// them the stats injection alone is exact.
    fn replay_external(&mut self, rec: &JournalRecord) -> Result<()> {
        if rec.entries.is_empty() {
            match &mut self.inner {
                Inner::Single(e) => e.inject_external(&[], rec.commits, rec.attempts),
                Inner::Cluster(e) => e.inject_external(&[], rec.commits, rec.attempts),
            }
            return Ok(());
        }
        let tm = self.tm.as_ref().ok_or_else(|| {
            anyhow!("cannot replay an external transaction under cpu.parallel")
        })?;
        let stmr = self
            .txn_stmr
            .as_ref()
            .ok_or_else(|| anyhow!("txn_stmr missing while tm is present (builder invariant)"))?;
        self.txn_buf.clear();
        let entries = &rec.entries;
        let _ = tm.execute_into(
            stmr,
            &mut |tx: &mut dyn TxOps| {
                for e in entries {
                    tx.write(e.addr as usize, e.val)?;
                }
                Ok(())
            },
            &mut self.txn_buf,
        );
        // The replayed commit must regenerate the journaled write-set bit
        // for bit — same addresses, values, AND timestamps (the clock
        // history up to here is identical by induction).
        if self.txn_buf != rec.entries {
            bail!(
                "recovery divergence: replayed external txn write-set \
                 differs from the journal (after round {})",
                rec.after_round
            );
        }
        match &mut self.inner {
            Inner::Single(e) => e.inject_external(&self.txn_buf, rec.commits, rec.attempts),
            Inner::Cluster(e) => e.inject_external(&self.txn_buf, rec.commits, rec.attempts),
        }
        Ok(())
    }

    /// Install the durability hook + journal on this session's engine.
    /// Shared by [`Hetm::build`] (fresh chain) and [`Session::recover`]
    /// (resume an existing chain at `resume_from`).
    fn arm_durability(
        &mut self,
        dir: &str,
        interval_rounds: u64,
        plan: Option<FaultPlan>,
        resume_from: Option<u64>,
    ) -> Result<()> {
        let path = std::path::Path::new(dir);
        let n_words = self.stmr().len();
        let shift = match &self.inner {
            Inner::Single(e) => e.device.rs_bmp().shift(),
            Inner::Cluster(e) => e.devices[0].rs_bmp().shift(),
        };
        let mut hook = DurabilityHook::new(path, interval_rounds, n_words, shift, plan)?;
        if let Some(r) = resume_from {
            hook.resume_from(r);
        }
        match &mut self.inner {
            Inner::Single(e) => e.dur = Some(Box::new(hook)),
            Inner::Cluster(e) => e.dur = Some(Box::new(hook)),
        }
        self.journal = Some(ExternalJournal::open(path)?);
        Ok(())
    }

    /// Recover a session from the newest complete checkpoint under `dir`.
    ///
    /// Engine drivers hold unserializable host state (RNG streams, rate
    /// debt, oracle traces), but every run is deterministic in virtual
    /// time — so recovery **replays**: it builds a fresh session from
    /// `builder` (durability suppressed), re-runs rounds to the
    /// checkpointed round with the journaled external transactions and
    /// drains re-applied at their recorded boundaries, then verifies the
    /// result bit-exactly against the checkpoint (STMR words, `RunStats`
    /// digest, virtual clock, per-shard carried log) — any divergence is
    /// an error, never a silent approximation.  The journal's lost tail
    /// (events after the checkpoint) is truncated, the workload's
    /// [`Workload::on_recovered`] hook runs, and durability is re-armed
    /// to continue the same checkpoint chain.  With no usable checkpoint
    /// the session starts fresh at round 0.
    ///
    /// `builder` must carry the same configuration as the crashed run —
    /// a different config diverges and errors.  An armed crash plan is
    /// preserved, but only fires at checkpoints *after* the recovered
    /// round (earlier ones already happened).
    pub fn recover(builder: Hetm, dir: &str) -> Result<Session> {
        let path = std::path::Path::new(dir);
        let mut b = builder;
        let interval = b.cfg.checkpoint_interval_rounds;
        let plan = crash_plan_from(&b.cfg)?;
        // The replayed prefix must not re-checkpoint or re-journal: run
        // it bare, arm durability after verification.
        b.cfg.checkpoint_dir = String::new();
        let mut s = b.build()?;
        let Some(ck) = durability::load_latest(path)? else {
            // Nothing durable survived the crash: restart from the
            // initial state and drop the stale journal.
            ExternalJournal::truncate_from(path, 0)?;
            s.arm_durability(dir, interval, plan, None)?;
            return Ok(s);
        };
        // --- Typed layout-shape gate (before any replay work) ------------
        // A different device count or block shift cannot replay the
        // checkpointed run: every log chunk would route differently.
        // Shape mismatches are caller configuration errors, so they get
        // the typed [`BuildError::LayoutMismatch`]; epoch/owner-table
        // divergence after a shape-correct replay is an internal error
        // and stays a divergence bail below.
        let built = s.layout_desc();
        let built_gpus = s.n_gpus();
        let ck_gpus = ck
            .layout
            .as_ref()
            .map_or(ck.carried.len(), |l| l.n_shards());
        let built_bits = built.as_ref().map_or(0, |l| l.shard_bits);
        let ck_bits = ck.layout.as_ref().map_or(0, |l| l.shard_bits);
        if built_gpus != ck_gpus
            || (ck_gpus > 1 && ck.layout.is_some() && built_bits != ck_bits)
        {
            return Err(BuildError::LayoutMismatch {
                gpus: built_gpus,
                shard_bits: built_bits,
                ck_gpus,
                ck_shard_bits: ck_bits,
            }
            .into());
        }

        let records = ExternalJournal::load(path)?;
        for rec in &records {
            if rec.after_round >= ck.round {
                // Lost tail: the event postdates the checkpoint.
                break;
            }
            while s.stats().rounds < rec.after_round {
                s.run_round()?;
            }
            match rec.kind {
                RecordKind::Txn => s.replay_external(rec)?,
                RecordKind::Drain => s.drain()?,
            }
        }
        while s.stats().rounds < ck.round {
            s.run_round()?;
        }

        // --- Bit-exact verification against the checkpoint ---------------
        if s.stmr().len() != ck.n_words {
            bail!(
                "recovery divergence: STMR is {} words, checkpoint {} has {}",
                s.stmr().len(),
                ck.round,
                ck.n_words
            );
        }
        if s.stmr().snapshot() != ck.image {
            bail!(
                "recovery divergence: replayed STMR differs from checkpoint {}",
                ck.round
            );
        }
        let digest = durability::stats_digest(s.stats());
        if digest != ck.stats_fnv {
            bail!(
                "recovery divergence: replayed stats digest {digest:016x} != \
                 checkpoint {:016x}",
                ck.stats_fnv
            );
        }
        if s.now().to_bits() != ck.t.to_bits() {
            bail!(
                "recovery divergence: replayed clock {} != checkpoint {}",
                s.now(),
                ck.t
            );
        }
        let carried = s.carried_entries();
        if carried.len() != ck.carried.len() {
            bail!(
                "recovery divergence: {} shards replayed, checkpoint has {}",
                carried.len(),
                ck.carried.len()
            );
        }
        for (i, (got, want)) in carried.iter().zip(&ck.carried).enumerate() {
            if got != want {
                bail!("recovery divergence: shard {i} carried log differs");
            }
        }
        // The shard layout must have replayed bit-exactly too: the
        // deterministic rebalancer re-makes every migration, so epoch
        // and owner table land exactly where the checkpoint recorded
        // them (DESIGN.md §14).
        if let Some(want) = &ck.layout {
            match s.layout_desc() {
                Some(got) if got == *want => {}
                Some(got) => bail!(
                    "recovery divergence: replayed shard layout (epoch {}) \
                     differs from checkpoint layout (epoch {}) — was the \
                     rebalancer configured differently?",
                    got.epoch,
                    want.epoch
                ),
                None => bail!(
                    "recovery divergence: checkpoint {} records a shard \
                     layout but the session is single-device",
                    ck.round
                ),
            }
        }

        let all: Vec<WriteEntry> = ck.carried.iter().flatten().copied().collect();
        s.workload.on_recovered(&all);
        ExternalJournal::truncate_from(path, ck.round)?;
        s.arm_durability(dir, interval, plan, Some(ck.round))?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GuestKind;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::from_raw(&Raw::new()).unwrap();
        c.n_words = 1 << 14;
        c.cpu_txn_s = 2e-6;
        c.period_s = 0.004;
        c
    }

    #[test]
    fn builder_defaults_run_a_synth_session() {
        let mut s = Hetm::from_config(&cfg()).build().unwrap();
        assert!(!s.is_cluster());
        assert_eq!(s.n_gpus(), 1);
        s.run_rounds(2).unwrap();
        s.drain().unwrap();
        assert!(s.stats().cpu_commits > 0);
        assert!(s.stats().gpu_commits > 0);
        s.check_invariants().unwrap();
        assert_eq!(s.workload_name(), "synth");
    }

    #[test]
    fn builder_selects_the_cluster_engine_for_multi_gpu() {
        let mut s = Hetm::from_config(&cfg()).gpus(2).build().unwrap();
        assert!(s.is_cluster());
        assert_eq!(s.n_gpus(), 2);
        s.run_rounds(2).unwrap();
        assert!(s.cluster().unwrap().per_device.iter().all(|d| d.attempts > 0));
    }

    #[test]
    fn threads_knob_upgrades_to_the_cluster_engine() {
        let s = Hetm::from_config(&cfg()).threads(2).build().unwrap();
        assert!(s.is_cluster(), "threads > 1 needs the lane machinery");
        assert_eq!(s.n_gpus(), 1);
        assert_eq!(s.threads(), 2);
    }

    #[test]
    fn force_cluster_exposes_cluster_stats_at_one_device() {
        let mut s = Hetm::from_config(&cfg()).force_cluster(true).build().unwrap();
        assert!(s.is_cluster());
        s.run_rounds(1).unwrap();
        assert!(s.cluster().is_some());
    }

    #[test]
    fn every_guest_and_policy_builds() {
        for guest in [GuestKind::Tiny, GuestKind::Norec, GuestKind::Htm] {
            for policy in [
                PolicyKind::FavorCpu,
                PolicyKind::FavorGpu,
                PolicyKind::CpuWithStarvationGuard,
            ] {
                let mut s = Hetm::from_config(&cfg())
                    .guest(guest)
                    .policy(policy)
                    .build()
                    .unwrap();
                s.run_rounds(1).unwrap();
            }
        }
    }

    #[test]
    fn txn_reaches_the_device_replica() {
        // Confine the drivers to the upper region so word 3 is touched by
        // the external transaction only.
        let c = cfg();
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 4..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut s = Hetm::from_config(&c).synth(cpu_spec, gpu_spec).build().unwrap();
        s.run_round().unwrap();
        let r = s
            .txn(|tx| {
                let v = tx.read(3)?;
                tx.write(3, v + 41)
            })
            .unwrap();
        assert!(r.ts > 0);
        // Visible on the CPU truth immediately...
        assert_eq!(s.stmr().load(3), 41);
        // ...and on the device replica after the next round + drain.
        s.run_round().unwrap();
        s.drain().unwrap();
        assert_eq!(s.device_stmr(0)[3], 41);
        assert_eq!(s.stmr().load(3), 41);
    }

    #[test]
    fn txn_survives_a_favor_gpu_abort() {
        // Conflict-injected CPU spec under favor-GPU: rounds abort the
        // CPU, but an external txn committed BEFORE a round is carried
        // and must survive its rollback.
        let c = cfg();
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0)
            .partitioned(n / 4..n / 2)
            .with_conflicts(1.0, n / 2..n);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut s = Hetm::from_config(&c)
            .policy(PolicyKind::FavorGpu)
            .synth(cpu_spec, gpu_spec)
            .build()
            .unwrap();
        s.txn(|tx| tx.write(7, 1234)).unwrap();
        s.run_rounds(2).unwrap();
        s.drain().unwrap();
        assert_eq!(
            s.stmr().load(7),
            1234,
            "externally committed write must survive favor-GPU rollbacks"
        );
    }

    #[test]
    fn set_chunk_entries_preserves_carried_commits() {
        // Re-chunking between rounds must not drop the carried prefix:
        // an external commit made before the call still reaches the
        // device (regression for the silent-discard bug).
        let c = cfg();
        let n = c.n_words;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 4..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        for cluster in [false, true] {
            let mut s = Hetm::from_config(&c)
                .synth(cpu_spec.clone(), gpu_spec.clone())
                .force_cluster(cluster)
                .build()
                .unwrap();
            s.txn(|tx| tx.write(5, 777)).unwrap();
            s.set_chunk_entries(512);
            s.run_round().unwrap();
            s.drain().unwrap();
            assert_eq!(s.stmr().load(5), 777, "cluster={cluster}: CPU value");
            assert_eq!(s.device_stmr(0)[5], 777, "cluster={cluster}: device value");
        }
    }

    #[test]
    fn shard_layout_overflow_is_a_typed_error() {
        let c = cfg();
        assert!(matches!(
            Hetm::from_config(&c).gpus(2).shard_bits(63).build().err(),
            Some(BuildError::ShardLayout { .. })
        ));
    }

    #[test]
    fn txn_is_rejected_under_parallel_cpu() {
        let mut c = cfg();
        c.cpu_parallel = true;
        let mut s = Hetm::from_config(&c).build().unwrap();
        assert!(s.txn(|tx| tx.write(0, 1)).is_err());
    }

    #[test]
    fn parallel_cpu_cluster_is_thread_count_invariant() {
        // cpu.parallel composes with cluster.threads: the fully threaded
        // platform (CPU workers + device lanes) must be bit-identical to
        // the sequential schedule of the same configuration.
        let run = |cluster_threads: usize| {
            let mut c = cfg();
            c.cpu_threads = 4;
            c.n_gpus = 2;
            c.cluster_threads = cluster_threads;
            c.cpu_parallel = true;
            let n = c.n_words;
            let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
            let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
            let mut s = Hetm::from_config(&c)
                .synth(cpu_spec, gpu_spec)
                .gpu_batch(256)
                .build()
                .unwrap();
            s.run_rounds(2).unwrap();
            s.drain().unwrap();
            (format!("{:?}", s.stats()), s.stmr().snapshot())
        };
        let seq = run(1);
        let thr = run(2);
        assert_eq!(seq.0, thr.0, "stats diverged");
        assert_eq!(seq.1, thr.1, "state diverged");
    }

    #[test]
    fn epoch_reset_sustains_tiny_clock_epochs() {
        // ~16k commits per round; a 20k-tick epoch survives only because
        // the engines epoch-reset at every round boundary.  Ten rounds
        // drive ~160k cumulative ticks through the 20k epoch — the
        // scaled-down equivalent of pushing the legacy clock past
        // i32::MAX.
        let mut s = Hetm::from_config(&cfg())
            .clock_epoch_limit(20_000)
            .build()
            .unwrap();
        s.run_rounds(10).unwrap();
        s.drain().unwrap();
        assert!(
            s.stats().cpu_commits > 20_000,
            "the run must outlive a single epoch to prove the reset works \
             (got {} commits)",
            s.stats().cpu_commits
        );
        s.check_invariants().unwrap();
    }

    #[test]
    fn telemetry_collects_and_snapshots() {
        let mut s = Hetm::from_config(&cfg())
            .telemetry(true)
            .trace(true)
            .build()
            .unwrap();
        s.run_rounds(2).unwrap();
        s.drain().unwrap(); // the drain is a round too
        let c = s.collector().expect("collector must be active");
        assert_eq!(c.registry().counter("hetm_rounds_total"), 3);
        let snap = s.metrics_snapshot("t");
        assert!(snap.render_text().contains("hist hetm_round_latency_seconds"));
        assert!(snap.to_json().contains("\"hetm_rounds_total\":3"));
        assert!(snap.to_prometheus().contains("# TYPE hetm_rounds_total counter"));
        let doc = s.trace_json().expect("trace requested");
        assert!(crate::telemetry::validate_trace(&doc).unwrap() > 0);
    }

    #[test]
    fn telemetry_off_has_no_collector() {
        let mut s = Hetm::from_config(&cfg()).build().unwrap();
        s.run_rounds(1).unwrap();
        assert!(s.collector().is_none());
        assert!(s.trace_json().is_none());
        assert!(s.write_trace("/nonexistent/never-written.json").is_err());
        let snap = s.metrics_snapshot("off");
        assert!(snap.registry.is_none());
    }

    #[test]
    fn bad_crash_point_is_a_typed_error() {
        let mut c = cfg();
        c.checkpoint_dir = std::env::temp_dir()
            .join(format!("shetm-session-dur-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        c.crash_point = "explode".to_string();
        assert!(matches!(
            Hetm::from_config(&c).build().err(),
            Some(BuildError::Durability(_))
        ));
    }

    #[test]
    fn misconfigurations_return_typed_errors() {
        let c = cfg();
        assert_eq!(
            Hetm::from_config(&c).words(0).build().err(),
            Some(BuildError::ZeroWords)
        );
        assert_eq!(
            Hetm::from_config(&c).gpus(0).build().err(),
            Some(BuildError::ZeroGpus)
        );
        // An STMR whose word indices overflow the i32 chunk/batch address
        // channels must be rejected before anything is allocated.
        assert_eq!(
            Hetm::from_config(&c)
                .words(i32::MAX as usize + 1)
                .build()
                .err(),
            Some(BuildError::StmrTooLarge {
                words: i32::MAX as usize + 1
            })
        );
        assert_eq!(
            Hetm::from_config(&c).threads(0).build().err(),
            Some(BuildError::ZeroThreads)
        );
        assert_eq!(
            Hetm::from_config(&c).cpu_threads(0).build().err(),
            Some(BuildError::ZeroCpuThreads)
        );
        assert_eq!(
            Hetm::from_config(&c).gpu_batch(0).build().err(),
            Some(BuildError::ZeroGpuBatch)
        );
        assert!(matches!(
            Hetm::from_config(&c).period_ms(0.0).build().err(),
            Some(BuildError::InvalidPeriod(_))
        ));
        assert!(matches!(
            Hetm::from_config(&c).early_interval_frac(1.5).build().err(),
            Some(BuildError::InvalidEarlyInterval(_))
        ));
        assert!(matches!(
            Hetm::from_config(&c)
                .parallel_cpu(true)
                .workload_named("bank")
                .build()
                .err(),
            Some(BuildError::ParallelCpuUnsupported { .. })
        ));
        assert!(matches!(
            Hetm::from_config(&c).workload_named("nope").build().err(),
            Some(BuildError::Workload(_))
        ));
        // Explicit shard_bits that cannot fit is an error; the default is
        // clamped instead (legacy CLI behavior).
        assert!(matches!(
            Hetm::from_config(&c)
                .words(1 << 10)
                .gpus(8)
                .shard_bits(12)
                .build()
                .err(),
            Some(BuildError::ShardLayout { .. })
        ));
        assert!(Hetm::from_config(&c).words(1 << 10).gpus(8).build().is_ok());
    }
}
