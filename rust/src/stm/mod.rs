//! CPU guest transactional memories (paper §IV-B).
//!
//! SHeTM is modular over the per-device TM: any implementation that (a)
//! ensures opacity for intra-device concurrency and (b) reports, at commit
//! time, its write-set as `(addr, value, timestamp)` with totally-ordered
//! timestamps can be plugged in.  This module provides the integration
//! contract ([`GuestTm`]) and three guests:
//!
//! * [`tinystm::TinyStm`] — word-based, lazy-versioning, time-based STM with
//!   timestamp extension (the TinySTM/TL2 family the paper uses);
//! * [`norec::NorecStm`] — single-sequence-lock, value-validation STM
//!   (NOrec), demonstrating guest modularity;
//! * [`htm::HtmEmu`] — a bounded-speculation emulation of Intel TSX:
//!   capacity and interference aborts with a serial fallback, RDTSCP-style
//!   commit timestamps (DESIGN.md §2 substitution table).
//!
//! The write-set callback is exactly the paper's: timestamps come from a
//! [`GlobalClock`] shared by every CPU guest so that the GPU's validation
//! freshness check (§IV-C.2) sees one total order of CPU commits.

pub mod htm;
pub mod norec;
pub mod tinystm;

use std::sync::atomic::{AtomicI32, AtomicI64, Ordering};
use std::sync::Mutex;

/// One committed write, as handed to SHeTM's commit callback (§IV-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteEntry {
    /// STMR word index.
    pub addr: u32,
    /// Value written.
    pub val: i32,
    /// Commit timestamp (global CPU clock; totally ordered).
    pub ts: i32,
}

/// Marker for a doomed transaction; bodies propagate it with `?`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Abort;

/// Outcome of [`GuestTm::execute_into`].
#[derive(Debug, Clone, Copy)]
pub struct TxnResult {
    /// Commit timestamp (0 for read-only transactions, which do not
    /// advance the clock and leave no log entries).
    pub ts: i32,
    /// Times the body was re-run due to intra-device conflicts.
    pub retries: u32,
}

/// Transactional operations exposed to a transaction body.
pub trait TxOps {
    /// Transactional read of one STMR word.
    fn read(&mut self, addr: usize) -> Result<i32, Abort>;
    /// Transactional write of one STMR word.
    fn write(&mut self, addr: usize, val: i32) -> Result<(), Abort>;
}

/// A CPU guest TM: runs transaction bodies to commit over a [`SharedStmr`].
pub trait GuestTm: Send + Sync {
    /// Human-readable guest name (diagnostics, bench labels).
    fn name(&self) -> &'static str;

    /// Round-boundary epoch reset (the engines call this after every
    /// merge): restart the commit clock at `base` — every write-log entry
    /// still outstanding has been renumbered into `1..=base` by the
    /// coordinator — and drop any clock-derived metadata (e.g. orec
    /// versions) so the next round's timestamps start fresh.  Timestamps
    /// are only ever compared *within* one round (the device freshness
    /// array resets with the clock), so the reset preserves every
    /// validate/apply outcome while keeping the clock inside the i32
    /// range the device kernels use, forever.
    ///
    /// The default is a no-op: a guest that ignores the reset keeps the
    /// legacy grow-forever clock and inherits its epoch-exhaustion limit.
    fn epoch_reset(&self, _base: i64) {}

    /// Execute `body` as a transaction, retrying on conflict until commit.
    ///
    /// On commit, the transaction's write-set — `(addr, value, ts)` exactly
    /// as the paper's callback specifies — is appended to `writes` (which
    /// is NOT cleared: the caller owns batching, so commit log appends are
    /// allocation-free once warm).
    fn execute_into(
        &self,
        stmr: &SharedStmr,
        body: &mut dyn FnMut(&mut dyn TxOps) -> Result<(), Abort>,
        writes: &mut Vec<WriteEntry>,
    ) -> TxnResult;
}

/// The CPU-side STMR replica: word-addressed shared memory.
///
/// Guests access it through atomics; SHeTM itself performs the
/// merge-phase bulk updates non-transactionally (§IV-B "additional
/// assumptions": all TM metadata lives outside the STMR, and merge runs
/// while no transaction executes).
pub struct SharedStmr {
    words: Box<[AtomicI32]>,
    /// Round-start snapshot slot for the favor-GPU policy (the paper uses
    /// fork/COW); filled by [`Self::save_snapshot`], consumed by
    /// [`Self::restore_snapshot`].  The buffer is retained across rounds
    /// so repeated favor-GPU snapshots are allocation-free once warm.
    snap: Mutex<SnapSlot>,
}

/// Reusable snapshot buffer: `valid` flags whether `buf` currently holds
/// a pending snapshot; the allocation survives a restore.
#[derive(Default)]
struct SnapSlot {
    buf: Vec<i32>,
    valid: bool,
}

impl SharedStmr {
    /// Zero-initialized STMR of `n` words.
    pub fn new(n: usize) -> Self {
        let mut v = Vec::with_capacity(n);
        v.resize_with(n, || AtomicI32::new(0));
        SharedStmr {
            words: v.into_boxed_slice(),
            snap: Mutex::new(SnapSlot::default()),
        }
    }

    /// Length in words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if the region is empty.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Raw atomic load.
    #[inline]
    pub fn load(&self, addr: usize) -> i32 {
        self.words[addr].load(Ordering::Acquire)
    }

    /// Raw atomic store (non-transactional; merge/init paths only).
    #[inline]
    pub fn store(&self, addr: usize, val: i32) {
        self.words[addr].store(val, Ordering::Release);
    }

    /// Copy the whole region out (round-start snapshot for the GPU).
    pub fn snapshot(&self) -> Vec<i32> {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Acquire))
            .collect()
    }

    /// Install a word range non-transactionally (merge phase).
    pub fn install_range(&self, start: usize, data: &[i32]) {
        for (i, &v) in data.iter().enumerate() {
            self.words[start + i].store(v, Ordering::Release);
        }
    }

    /// Save an internal full-region snapshot (favor-GPU round start; the
    /// engine charges the fork/COW cost separately via its cost model).
    ///
    /// The snapshot buffer is reused across rounds: after the first
    /// favor-GPU round this is a copy into an existing allocation, not a
    /// fresh `Vec` per round.
    pub fn save_snapshot(&self) {
        let mut slot = crate::util::sync::lock(&self.snap);
        slot.buf.clear();
        slot.buf
            .extend(self.words.iter().map(|w| w.load(Ordering::Acquire)));
        slot.valid = true;
    }

    /// Restore and consume the snapshot saved by [`Self::save_snapshot`]
    /// (favor-GPU round abort). Panics if no snapshot is pending.  The
    /// buffer's allocation is kept for the next round's snapshot.
    pub fn restore_snapshot(&self) {
        let mut slot = crate::util::sync::lock(&self.snap);
        assert!(
            slot.valid,
            "save_snapshot must precede restore_snapshot"
        );
        slot.valid = false;
        for (i, v) in slot.buf.iter().enumerate() {
            self.words[i].store(*v, Ordering::Release);
        }
    }
}

impl std::fmt::Debug for SharedStmr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedStmr({} words)", self.words.len())
    }
}

/// Global logical commit clock shared by every CPU guest (§IV-B: "a logical
/// timestamp to totally order the commits of all transactions").
///
/// Timestamps live in the i32 range the device kernels use, but the clock
/// never exhausts it in engine runs: the coordinators perform a
/// round-boundary **epoch reset** ([`Self::epoch_reset`], reached through
/// [`GuestTm::epoch_reset`]) after every merge, renumbering the handful of
/// carried log entries and restarting the count.  Timestamps therefore
/// stay totally ordered *within* a round — the only scope any freshness
/// comparison spans — while the clock value stays bounded by one round's
/// commit volume.  [`Self::tick`] still panics if a single epoch
/// (i.e. one round) overflows its limit, which is `i32::MAX` by default;
/// tests force a small limit via [`Self::with_epoch_limit`] to exercise
/// the reset cheaply.
#[derive(Debug)]
pub struct GlobalClock {
    t: AtomicI64,
    /// Highest timestamp one epoch may reach before [`Self::tick`] panics.
    limit: i64,
}

impl Default for GlobalClock {
    fn default() -> Self {
        GlobalClock {
            t: AtomicI64::new(0),
            limit: i64::from(i32::MAX),
        }
    }
}

impl GlobalClock {
    /// Clock starting at 0 (first commit gets ts 1).
    pub fn new() -> Self {
        Self::default()
    }

    /// Clock with a custom epoch limit: [`Self::tick`] panics when a
    /// single epoch exceeds `limit` ticks without an intervening
    /// [`Self::epoch_reset`].  Lets tests drive the clock past
    /// `i32::MAX`-equivalent tick volumes in milliseconds.
    pub fn with_epoch_limit(limit: i32) -> Self {
        assert!(limit > 0, "epoch limit must be positive");
        GlobalClock {
            t: AtomicI64::new(0),
            limit: i64::from(limit),
        }
    }

    /// The configured epoch limit.
    pub fn epoch_limit(&self) -> i64 {
        self.limit
    }

    /// Current value without advancing.
    #[inline]
    pub fn now(&self) -> i64 {
        self.t.load(Ordering::Acquire)
    }

    /// Advance and return the new timestamp.
    ///
    /// Panics if one epoch exhausts the configured limit (`i32::MAX` by
    /// default — the range the device kernels use).  The engines prevent
    /// this by epoch-resetting at every round boundary; only a driver
    /// that commits more than `limit` transactions in a *single round*
    /// can trip it.
    #[inline]
    pub fn tick(&self) -> i32 {
        let v = self.t.fetch_add(1, Ordering::AcqRel) + 1;
        assert!(
            v <= self.limit,
            "global clock exceeded its epoch limit ({}) within one round — \
             the engine must call epoch_reset() at round boundaries",
            self.limit
        );
        v as i32
    }

    /// Round-boundary epoch reset: restart the clock at `base`.
    ///
    /// The caller (the coordinator, after merge) guarantees that every
    /// write-log entry still outstanding has been renumbered into
    /// `1..=base`, and that all clock-derived metadata (guest version
    /// tables, the device freshness array) is reset alongside — see
    /// [`GuestTm::epoch_reset`].  Must not be called while transactions
    /// are in flight.
    pub fn epoch_reset(&self, base: i64) {
        debug_assert!((0..=self.limit).contains(&base));
        self.t.store(base, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stmr_load_store_roundtrip() {
        let m = SharedStmr::new(8);
        assert_eq!(m.load(3), 0);
        m.store(3, 42);
        assert_eq!(m.load(3), 42);
        assert_eq!(m.len(), 8);
    }

    #[test]
    fn stmr_snapshot_and_install() {
        let m = SharedStmr::new(4);
        m.store(1, 5);
        let snap = m.snapshot();
        assert_eq!(snap, vec![0, 5, 0, 0]);
        m.install_range(2, &[7, 8]);
        assert_eq!(m.snapshot(), vec![0, 5, 7, 8]);
    }

    #[test]
    fn snapshot_slot_roundtrips_and_consumes() {
        let m = SharedStmr::new(4);
        m.store(2, 9);
        m.save_snapshot();
        m.store(2, 11);
        m.store(0, 1);
        m.restore_snapshot();
        assert_eq!(m.snapshot(), vec![0, 0, 9, 0]);
    }

    #[test]
    #[should_panic(expected = "save_snapshot must precede")]
    fn restore_without_save_panics() {
        SharedStmr::new(2).restore_snapshot();
    }

    #[test]
    fn snapshot_buffer_is_reused_across_rounds() {
        let m = SharedStmr::new(4);
        m.store(0, 1);
        m.save_snapshot();
        m.store(0, 2);
        m.restore_snapshot();
        assert_eq!(m.load(0), 1);
        // Second favor-GPU round: the slot must accept a fresh snapshot
        // (same buffer, new contents) and restore the LATEST image.
        m.store(0, 7);
        m.save_snapshot();
        m.store(0, 9);
        m.restore_snapshot();
        assert_eq!(m.load(0), 7);
    }

    #[test]
    fn clock_epoch_reset_restarts_the_count() {
        let c = GlobalClock::with_epoch_limit(8);
        for _ in 0..8 {
            c.tick();
        }
        assert_eq!(c.now(), 8);
        // Round boundary: 3 carried entries renumbered 1..=3.
        c.epoch_reset(3);
        assert_eq!(c.now(), 3);
        assert_eq!(c.tick(), 4);
        // With per-round resets the clock sustains unbounded cumulative
        // tick volume under a tiny epoch limit.
        for _ in 0..100 {
            c.epoch_reset(0);
            for _ in 0..8 {
                c.tick();
            }
        }
        assert_eq!(c.now(), 8);
    }

    #[test]
    #[should_panic(expected = "epoch limit")]
    fn clock_without_reset_exhausts_its_epoch() {
        let c = GlobalClock::with_epoch_limit(8);
        for _ in 0..9 {
            c.tick();
        }
    }

    #[test]
    fn clock_monotonic_across_threads() {
        use std::sync::Arc;
        let clock = Arc::new(GlobalClock::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = clock.clone();
            handles.push(std::thread::spawn(move || {
                (0..1000).map(|_| c.tick()).collect::<Vec<i32>>()
            }));
        }
        let mut all: Vec<i32> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4000, "timestamps must be unique");
        assert_eq!(clock.now(), 4000);
    }
}
