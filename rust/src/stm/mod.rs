//! CPU guest transactional memories (paper §IV-B).
//!
//! SHeTM is modular over the per-device TM: any implementation that (a)
//! ensures opacity for intra-device concurrency and (b) reports, at commit
//! time, its write-set as `(addr, value, timestamp)` with totally-ordered
//! timestamps can be plugged in.  This module provides the integration
//! contract ([`GuestTm`]) and three guests:
//!
//! * [`tinystm::TinyStm`] — word-based, lazy-versioning, time-based STM with
//!   timestamp extension (the TinySTM/TL2 family the paper uses);
//! * [`norec::NorecStm`] — single-sequence-lock, value-validation STM
//!   (NOrec), demonstrating guest modularity;
//! * [`htm::HtmEmu`] — a bounded-speculation emulation of Intel TSX:
//!   capacity and interference aborts with a serial fallback, RDTSCP-style
//!   commit timestamps (DESIGN.md §2 substitution table).
//!
//! The write-set callback is exactly the paper's: timestamps come from a
//! [`GlobalClock`] shared by every CPU guest so that the GPU's validation
//! freshness check (§IV-C.2) sees one total order of CPU commits.

pub mod htm;
pub mod norec;
pub mod tinystm;

use std::sync::atomic::{AtomicI32, AtomicI64, Ordering};
use std::sync::Mutex;

/// One committed write, as handed to SHeTM's commit callback (§IV-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteEntry {
    /// STMR word index.
    pub addr: u32,
    /// Value written.
    pub val: i32,
    /// Commit timestamp (global CPU clock; totally ordered).
    pub ts: i32,
}

/// Marker for a doomed transaction; bodies propagate it with `?`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Abort;

/// Outcome of [`GuestTm::execute_into`].
#[derive(Debug, Clone, Copy)]
pub struct TxnResult {
    /// Commit timestamp (0 for read-only transactions, which do not
    /// advance the clock and leave no log entries).
    pub ts: i32,
    /// Times the body was re-run due to intra-device conflicts.
    pub retries: u32,
}

/// Transactional operations exposed to a transaction body.
pub trait TxOps {
    /// Transactional read of one STMR word.
    fn read(&mut self, addr: usize) -> Result<i32, Abort>;
    /// Transactional write of one STMR word.
    fn write(&mut self, addr: usize, val: i32) -> Result<(), Abort>;
}

/// A CPU guest TM: runs transaction bodies to commit over a [`SharedStmr`].
pub trait GuestTm: Send + Sync {
    /// Human-readable guest name (diagnostics, bench labels).
    fn name(&self) -> &'static str;

    /// Execute `body` as a transaction, retrying on conflict until commit.
    ///
    /// On commit, the transaction's write-set — `(addr, value, ts)` exactly
    /// as the paper's callback specifies — is appended to `writes` (which
    /// is NOT cleared: the caller owns batching, so commit log appends are
    /// allocation-free once warm).
    fn execute_into(
        &self,
        stmr: &SharedStmr,
        body: &mut dyn FnMut(&mut dyn TxOps) -> Result<(), Abort>,
        writes: &mut Vec<WriteEntry>,
    ) -> TxnResult;
}

/// The CPU-side STMR replica: word-addressed shared memory.
///
/// Guests access it through atomics; SHeTM itself performs the
/// merge-phase bulk updates non-transactionally (§IV-B "additional
/// assumptions": all TM metadata lives outside the STMR, and merge runs
/// while no transaction executes).
pub struct SharedStmr {
    words: Box<[AtomicI32]>,
    /// Round-start snapshot slot for the favor-GPU policy (the paper uses
    /// fork/COW); filled by [`Self::save_snapshot`], consumed by
    /// [`Self::restore_snapshot`].
    snap: Mutex<Option<Vec<i32>>>,
}

impl SharedStmr {
    /// Zero-initialized STMR of `n` words.
    pub fn new(n: usize) -> Self {
        let mut v = Vec::with_capacity(n);
        v.resize_with(n, || AtomicI32::new(0));
        SharedStmr {
            words: v.into_boxed_slice(),
            snap: Mutex::new(None),
        }
    }

    /// Length in words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if the region is empty.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Raw atomic load.
    #[inline]
    pub fn load(&self, addr: usize) -> i32 {
        self.words[addr].load(Ordering::Acquire)
    }

    /// Raw atomic store (non-transactional; merge/init paths only).
    #[inline]
    pub fn store(&self, addr: usize, val: i32) {
        self.words[addr].store(val, Ordering::Release);
    }

    /// Copy the whole region out (round-start snapshot for the GPU).
    pub fn snapshot(&self) -> Vec<i32> {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Acquire))
            .collect()
    }

    /// Install a word range non-transactionally (merge phase).
    pub fn install_range(&self, start: usize, data: &[i32]) {
        for (i, &v) in data.iter().enumerate() {
            self.words[start + i].store(v, Ordering::Release);
        }
    }

    /// Save an internal full-region snapshot (favor-GPU round start; the
    /// engine charges the fork/COW cost separately via its cost model).
    pub fn save_snapshot(&self) {
        *self.snap.lock().unwrap() = Some(self.snapshot());
    }

    /// Restore and consume the snapshot saved by [`Self::save_snapshot`]
    /// (favor-GPU round abort). Panics if no snapshot is pending.
    pub fn restore_snapshot(&self) {
        let snap = self
            .snap
            .lock()
            .unwrap()
            .take()
            .expect("save_snapshot must precede restore_snapshot");
        self.install_range(0, &snap);
    }
}

impl std::fmt::Debug for SharedStmr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedStmr({} words)", self.words.len())
    }
}

/// Global logical commit clock shared by every CPU guest (§IV-B: "a logical
/// timestamp to totally order the commits of all transactions").
#[derive(Debug, Default)]
pub struct GlobalClock {
    t: AtomicI64,
}

impl GlobalClock {
    /// Clock starting at 0 (first commit gets ts 1).
    pub fn new() -> Self {
        Self::default()
    }

    /// Current value without advancing.
    #[inline]
    pub fn now(&self) -> i64 {
        self.t.load(Ordering::Acquire)
    }

    /// Advance and return the new timestamp.
    ///
    /// Panics if the i32 range the device kernels use is exhausted — at
    /// one commit per 100 ns that is ~3.5 minutes of saturated commits,
    /// far beyond any bench round; a production build would epoch-reset
    /// between rounds.
    #[inline]
    pub fn tick(&self) -> i32 {
        let v = self.t.fetch_add(1, Ordering::AcqRel) + 1;
        i32::try_from(v).expect("global clock exceeded i32 (epoch reset needed)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stmr_load_store_roundtrip() {
        let m = SharedStmr::new(8);
        assert_eq!(m.load(3), 0);
        m.store(3, 42);
        assert_eq!(m.load(3), 42);
        assert_eq!(m.len(), 8);
    }

    #[test]
    fn stmr_snapshot_and_install() {
        let m = SharedStmr::new(4);
        m.store(1, 5);
        let snap = m.snapshot();
        assert_eq!(snap, vec![0, 5, 0, 0]);
        m.install_range(2, &[7, 8]);
        assert_eq!(m.snapshot(), vec![0, 5, 7, 8]);
    }

    #[test]
    fn snapshot_slot_roundtrips_and_consumes() {
        let m = SharedStmr::new(4);
        m.store(2, 9);
        m.save_snapshot();
        m.store(2, 11);
        m.store(0, 1);
        m.restore_snapshot();
        assert_eq!(m.snapshot(), vec![0, 0, 9, 0]);
    }

    #[test]
    #[should_panic(expected = "save_snapshot must precede")]
    fn restore_without_save_panics() {
        SharedStmr::new(2).restore_snapshot();
    }

    #[test]
    fn clock_monotonic_across_threads() {
        use std::sync::Arc;
        let clock = Arc::new(GlobalClock::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = clock.clone();
            handles.push(std::thread::spawn(move || {
                (0..1000).map(|_| c.tick()).collect::<Vec<i32>>()
            }));
        }
        let mut all: Vec<i32> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4000, "timestamps must be unique");
        assert_eq!(clock.now(), 4000);
    }
}
