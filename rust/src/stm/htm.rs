//! Emulated hardware TM ("TSX"): bounded speculation with a serial
//! fallback, standing in for the Intel TSX guest the paper runs on its
//! Xeon (DESIGN.md §2 substitution table).
//!
//! The emulation reproduces the *behavioural envelope* SHeTM cares about:
//!
//! * **capacity aborts** — a transaction whose footprint exceeds
//!   [`HtmEmu::capacity`] tracked locations aborts unconditionally, like a
//!   TSX transaction overflowing L1 (the paper's W2 workload, 40 reads,
//!   stays well inside; pathological transactions fall back);
//! * **interference aborts** — any concurrent committing writer aborts
//!   running speculative transactions (eager conflict detection, no
//!   value-based tolerance), which emulates cache-line invalidation
//!   killing a TSX transaction — strictly more abort-prone than NOrec;
//! * **serial fallback** — after [`HtmEmu::max_htm_retries`] aborts the
//!   transaction takes a global fallback lock and runs non-speculatively
//!   (the standard TSX lock-elision pattern);
//! * **RDTSCP-style timestamps** — commit timestamps come from the global
//!   clock, mirroring the paper's use of RDTSCP to order HTM commits, and
//!   the write-set is gathered by software instrumentation of writes
//!   (§IV-B: "for HTM, SHeTM requires the software instrumentation of
//!   write operations").

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{Abort, GlobalClock, GuestTm, SharedStmr, TxOps, TxnResult, WriteEntry};

/// Emulated HTM guest.
pub struct HtmEmu {
    /// Global sequence lock: even = free; odd = committer or fallback holder.
    seq: AtomicU64,
    clock: Arc<GlobalClock>,
    /// Max tracked locations (reads + writes) before a capacity abort.
    pub capacity: usize,
    /// Speculative attempts before taking the serial fallback.
    pub max_htm_retries: u32,
}

impl HtmEmu {
    /// Defaults: 448-location capacity (≈ L1 associativity budget),
    /// 8 speculative attempts.
    pub fn with_clock(clock: Arc<GlobalClock>) -> Self {
        HtmEmu {
            seq: AtomicU64::new(0),
            clock,
            capacity: 448,
            max_htm_retries: 8,
        }
    }

    #[inline]
    fn wait_even(&self) -> u64 {
        loop {
            let s = self.seq.load(Ordering::Acquire);
            if s & 1 == 0 {
                return s;
            }
            std::hint::spin_loop();
        }
    }
}

struct Tx<'a> {
    stm: &'a HtmEmu,
    stmr: &'a SharedStmr,
    rv: u64,
    footprint: usize,
    reads: Vec<(usize, i32)>,
    writes: Vec<(usize, i32)>,
    /// Fallback mode: holds the lock, executes directly.
    serial: bool,
}

impl<'a> Tx<'a> {
    fn check_capacity(&mut self) -> Result<(), Abort> {
        self.footprint += 1;
        if !self.serial && self.footprint > self.stm.capacity {
            Err(Abort) // capacity abort
        } else {
            Ok(())
        }
    }

    fn commit(&mut self, out: &mut Vec<WriteEntry>) -> Result<i32, Abort> {
        if self.serial {
            // Fallback: we already hold the lock; write back and release.
            let wv = if self.writes.is_empty() {
                0
            } else {
                let wv = self.stm.clock.tick();
                for &(addr, val) in &self.writes {
                    self.stmr.store(addr, val);
                    out.push(WriteEntry {
                        addr: addr as u32,
                        val,
                        ts: wv,
                    });
                }
                wv
            };
            self.stm.seq.store(self.rv + 2, Ordering::Release);
            return Ok(wv);
        }
        if self.writes.is_empty() {
            // Eager detection: any interference already aborted us.
            if self.stm.seq.load(Ordering::Acquire) != self.rv {
                return Err(Abort);
            }
            return Ok(0);
        }
        // HTM-style commit: succeed only if NOTHING committed since we
        // started (eager interference emulation — no value validation).
        if self
            .stm
            .seq
            .compare_exchange(self.rv, self.rv + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return Err(Abort);
        }
        let wv = self.stm.clock.tick();
        for &(addr, val) in &self.writes {
            self.stmr.store(addr, val);
            out.push(WriteEntry {
                addr: addr as u32,
                val,
                ts: wv,
            });
        }
        self.stm.seq.store(self.rv + 2, Ordering::Release);
        Ok(wv)
    }
}

impl TxOps for Tx<'_> {
    fn read(&mut self, addr: usize) -> Result<i32, Abort> {
        if let Some(&(_, v)) = self.writes.iter().rev().find(|&&(a, _)| a == addr) {
            return Ok(v);
        }
        if !self.serial && self.stm.seq.load(Ordering::Acquire) != self.rv {
            return Err(Abort); // interference: someone committed
        }
        self.check_capacity()?;
        let val = self.stmr.load(addr);
        self.reads.push((addr, val));
        Ok(val)
    }

    fn write(&mut self, addr: usize, val: i32) -> Result<(), Abort> {
        if !self.serial && self.stm.seq.load(Ordering::Acquire) != self.rv {
            return Err(Abort);
        }
        if let Some(e) = self.writes.iter_mut().find(|e| e.0 == addr) {
            e.1 = val;
            return Ok(());
        }
        self.check_capacity()?;
        self.writes.push((addr, val));
        Ok(())
    }
}

impl GuestTm for HtmEmu {
    fn epoch_reset(&self, base: i64) {
        // The sequence lock is an independent interference counter;
        // only the RDTSCP-style commit clock restarts.
        self.clock.epoch_reset(base);
    }

    fn name(&self) -> &'static str {
        "htm-emu"
    }

    fn execute_into(
        &self,
        stmr: &SharedStmr,
        body: &mut dyn FnMut(&mut dyn TxOps) -> Result<(), Abort>,
        writes: &mut Vec<WriteEntry>,
    ) -> TxnResult {
        let mut retries = 0u32;
        loop {
            let serial = retries >= self.max_htm_retries;
            let rv = if serial {
                // Acquire the fallback lock (spin on CAS even -> odd).
                loop {
                    let s = self.wait_even();
                    if self
                        .seq
                        .compare_exchange(s, s + 1, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        break s;
                    }
                }
            } else {
                self.wait_even()
            };
            let mut tx = Tx {
                stm: self,
                stmr,
                rv,
                footprint: 0,
                reads: Vec::new(),
                writes: Vec::new(),
                serial,
            };
            let ran = body(&mut tx);
            let committed = match ran {
                Ok(()) => tx.commit(writes),
                Err(Abort) => {
                    if serial {
                        // A body-level abort inside the fallback must
                        // release the lock before retrying.
                        self.seq.store(rv + 2, Ordering::Release);
                    }
                    Err(Abort)
                }
            };
            match committed {
                Ok(ts) => return TxnResult { ts, retries },
                Err(Abort) => {
                    retries += 1;
                    for _ in 0..retries.min(8) {
                        std::hint::spin_loop();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn setup(n: usize) -> (Arc<HtmEmu>, Arc<SharedStmr>) {
        let clock = Arc::new(GlobalClock::new());
        (
            Arc::new(HtmEmu::with_clock(clock)),
            Arc::new(SharedStmr::new(n)),
        )
    }

    #[test]
    fn basic_commit() {
        let (stm, stmr) = setup(8);
        let mut log = Vec::new();
        let r = stm.execute_into(
            &stmr,
            &mut |tx| {
                let v = tx.read(0)?;
                tx.write(0, v + 1)?;
                Ok(())
            },
            &mut log,
        );
        assert!(r.ts > 0);
        assert_eq!(stmr.load(0), 1);
    }

    #[test]
    fn capacity_abort_falls_back_to_serial_and_commits() {
        let clock = Arc::new(GlobalClock::new());
        let mut stm = HtmEmu::with_clock(clock);
        stm.capacity = 8;
        stm.max_htm_retries = 2;
        let stm = Arc::new(stm);
        let stmr = Arc::new(SharedStmr::new(64));
        let mut log = Vec::new();
        // Footprint of 32 > capacity 8: must succeed via fallback.
        let r = stm.execute_into(
            &stmr,
            &mut |tx| {
                for a in 0..32 {
                    let v = tx.read(a)?;
                    tx.write(a, v + 1)?;
                }
                Ok(())
            },
            &mut log,
        );
        assert!(r.retries >= 2, "needed the fallback");
        assert!((0..32).all(|a| stmr.load(a) == 1));
        assert_eq!(log.len(), 32);
    }

    #[test]
    fn concurrent_increments_lose_no_updates() {
        let (stm, stmr) = setup(4);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let stm = stm.clone();
                let stmr = stmr.clone();
                s.spawn(move || {
                    let mut log = Vec::new();
                    for _ in 0..250 {
                        stm.execute_into(
                            &stmr,
                            &mut |tx| {
                                let v = tx.read(0)?;
                                tx.write(0, v + 1)?;
                                Ok(())
                            },
                            &mut log,
                        );
                    }
                });
            }
        });
        assert_eq!(stmr.load(0), 1000);
    }
}
