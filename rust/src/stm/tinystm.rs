//! TinySTM-like guest: word-based, lazy-versioning, time-based STM with
//! timestamp extension (the LSA/TL2 algorithm family of Felber et al.,
//! which the paper uses as its software CPU guest).
//!
//! * Ownership records (orecs): a striped table of versioned locks; word
//!   `a` maps to orec `a & (table_len - 1)`.
//! * Reads are invisible and validated against a read version `rv`; when a
//!   too-new orec version is observed the read version is *extended* by
//!   revalidating the read-set against the current clock (TinySTM's
//!   incremental extension).
//! * Writes are buffered (lazy versioning) and written back at commit
//!   under 2-phase orec locking, then stamped with a fresh global-clock
//!   timestamp — which doubles as the SHeTM callback timestamp (§IV-B).
//!
//! Opacity: standard time-based argument — every read observes a snapshot
//! consistent at `rv`, and commit revalidates before write-back.

use std::cell::RefCell;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use super::{Abort, GlobalClock, GuestTm, SharedStmr, TxOps, TxnResult, WriteEntry};

const LOCKED: u64 = 1;

#[inline]
fn version_of(orec: u64) -> u64 {
    orec >> 1
}

#[inline]
fn is_locked(orec: u64) -> bool {
    orec & LOCKED != 0
}

/// TinySTM-like guest TM. Cheap to share via `Arc`.
pub struct TinyStm {
    orecs: Box<[AtomicU64]>,
    mask: usize,
    clock: Arc<GlobalClock>,
    /// Clock value at the last epoch reset: if the clock has not ticked
    /// since, no commit wrote an orec version, so the reset's table sweep
    /// can be skipped (keeps empty rounds free of the 2^16-store sweep).
    epoch_mark: AtomicI64,
    /// Max body re-runs before panicking (livelock guard in tests).
    max_retries: u32,
}

impl TinyStm {
    /// Build with a `2^log2_orecs`-entry orec table over `clock`.
    pub fn new(log2_orecs: u32, clock: Arc<GlobalClock>) -> Self {
        let n = 1usize << log2_orecs;
        let mut v = Vec::with_capacity(n);
        v.resize_with(n, || AtomicU64::new(0));
        let epoch_mark = AtomicI64::new(clock.now());
        TinyStm {
            orecs: v.into_boxed_slice(),
            mask: n - 1,
            clock,
            epoch_mark,
            max_retries: 1_000_000,
        }
    }

    /// Default sizing: 2^16 orecs.
    pub fn with_clock(clock: Arc<GlobalClock>) -> Self {
        Self::new(16, clock)
    }

    #[inline]
    fn orec_index(&self, addr: usize) -> usize {
        addr & self.mask
    }

    #[inline]
    fn orec(&self, idx: usize) -> &AtomicU64 {
        &self.orecs[idx]
    }
}

// Per-thread transaction scratch: read/write sets are reused across every
// transaction on the thread, keeping the commit path allocation-free once
// warm (§Perf L3a optimization, EXPERIMENTS.md).
thread_local! {
    static TX_SCRATCH: RefCell<(Vec<(usize, u64)>, Vec<(usize, i32)>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

struct Tx<'a> {
    stm: &'a TinyStm,
    stmr: &'a SharedStmr,
    rv: u64,
    /// (orec index, observed orec value) per first read of a stripe.
    reads: Vec<(usize, u64)>,
    /// (addr, value) write buffer, latest-wins on rewrite.
    writes: Vec<(usize, i32)>,
}

impl<'a> Tx<'a> {
    fn new(stm: &'a TinyStm, stmr: &'a SharedStmr) -> Self {
        let (mut reads, mut writes) = TX_SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
        reads.clear();
        writes.clear();
        Tx {
            stm,
            stmr,
            rv: stm.clock.now() as u64,
            reads,
            writes,
        }
    }

    /// Return the scratch buffers to the thread-local pool.
    fn recycle(self) {
        TX_SCRATCH.with(|s| {
            *s.borrow_mut() = (self.reads, self.writes);
        });
    }

    fn reset(&mut self) {
        self.rv = self.stm.clock.now() as u64;
        self.reads.clear();
        self.writes.clear();
    }

    /// Revalidate the read-set against the current clock (extension).
    fn extend(&mut self) -> Result<(), Abort> {
        let new_rv = self.stm.clock.now() as u64;
        for &(oi, seen) in &self.reads {
            let cur = self.stm.orec(oi).load(Ordering::Acquire);
            if cur != seen {
                return Err(Abort);
            }
        }
        self.rv = new_rv;
        Ok(())
    }

    fn commit(&mut self, out: &mut Vec<WriteEntry>) -> Result<i32, Abort> {
        if self.writes.is_empty() {
            return Ok(0); // read-only: snapshot already consistent at rv
        }

        // Phase 1: lock written orecs (sorted to avoid deadlock; deduped).
        let mut lock_idx: Vec<usize> = self
            .writes
            .iter()
            .map(|&(a, _)| self.stm.orec_index(a))
            .collect();
        lock_idx.sort_unstable();
        lock_idx.dedup();

        let mut locked: Vec<(usize, u64)> = Vec::with_capacity(lock_idx.len());
        for &oi in &lock_idx {
            let o = self.stm.orec(oi);
            let cur = o.load(Ordering::Acquire);
            if is_locked(cur)
                || o.compare_exchange(cur, cur | LOCKED, Ordering::AcqRel, Ordering::Acquire)
                    .is_err()
            {
                for &(li, lv) in &locked {
                    self.stm.orec(li).store(lv, Ordering::Release);
                }
                return Err(Abort);
            }
            locked.push((oi, cur));
        }

        // Phase 2: validate the read-set (our own locks are fine).
        for &(oi, seen) in &self.reads {
            let cur = self.stm.orec(oi).load(Ordering::Acquire);
            let mine = lock_idx.binary_search(&oi).is_ok();
            let ok = if mine { cur == seen | LOCKED } else { cur == seen };
            if !ok {
                for &(li, lv) in &locked {
                    self.stm.orec(li).store(lv, Ordering::Release);
                }
                return Err(Abort);
            }
        }

        // Phase 3: write back, stamp, release.
        let wv = self.stm.clock.tick();
        for &(addr, val) in &self.writes {
            self.stmr.store(addr, val);
            out.push(WriteEntry {
                addr: addr as u32,
                val,
                ts: wv,
            });
        }
        for &(oi, _) in &locked {
            self.stm.orec(oi).store((wv as u64) << 1, Ordering::Release);
        }
        Ok(wv)
    }
}

impl TxOps for Tx<'_> {
    fn read(&mut self, addr: usize) -> Result<i32, Abort> {
        // Read-after-write serves from the buffer (latest entry wins).
        if let Some(&(_, v)) = self.writes.iter().rev().find(|&&(a, _)| a == addr) {
            return Ok(v);
        }
        let oi = self.stm.orec_index(addr);
        let o = self.stm.orec(oi);
        loop {
            let v1 = o.load(Ordering::Acquire);
            if is_locked(v1) {
                // Writer in progress on this stripe: abort (simple policy).
                return Err(Abort);
            }
            let val = self.stmr.load(addr);
            let v2 = o.load(Ordering::Acquire);
            if v1 != v2 {
                continue; // raced a writer; retry the read
            }
            if version_of(v1) > self.rv {
                self.extend()?; // TinySTM timestamp extension
                continue;
            }
            if !self.reads.iter().any(|&(i, _)| i == oi) {
                self.reads.push((oi, v1));
            }
            return Ok(val);
        }
    }

    fn write(&mut self, addr: usize, val: i32) -> Result<(), Abort> {
        if let Some(e) = self.writes.iter_mut().find(|e| e.0 == addr) {
            e.1 = val;
        } else {
            self.writes.push((addr, val));
        }
        Ok(())
    }
}

impl GuestTm for TinyStm {
    fn name(&self) -> &'static str {
        "tinystm"
    }

    fn epoch_reset(&self, base: i64) {
        // Orec versions are clock values; a clock restart must clear them
        // or next-epoch reads (rv >= base) would mistake stale versions
        // for concurrent writers.  No transaction is in flight (the
        // engines reset at round boundaries), so plain stores suffice.
        // Commits are the only orec writers and every commit ticks the
        // clock, so an un-ticked epoch left the table untouched and the
        // sweep can be skipped — empty rounds stay sweep-free.
        if self.clock.now() != self.epoch_mark.load(Ordering::Acquire) {
            for o in self.orecs.iter() {
                o.store(0, Ordering::Release);
            }
        }
        self.clock.epoch_reset(base);
        self.epoch_mark.store(base, Ordering::Release);
    }

    fn execute_into(
        &self,
        stmr: &SharedStmr,
        body: &mut dyn FnMut(&mut dyn TxOps) -> Result<(), Abort>,
        writes: &mut Vec<WriteEntry>,
    ) -> TxnResult {
        let mut tx = Tx::new(self, stmr);
        let mut retries = 0u32;
        loop {
            let ran = body(&mut tx);
            let committed = match ran {
                Ok(()) => tx.commit(writes),
                Err(Abort) => Err(Abort),
            };
            match committed {
                Ok(ts) => {
                    tx.recycle();
                    return TxnResult { ts, retries };
                }
                Err(Abort) => {
                    retries += 1;
                    assert!(
                        retries < self.max_retries,
                        "tinystm: txn livelocked after {retries} retries"
                    );
                    // Bounded exponential backoff keeps writers from
                    // colliding repeatedly under contention.
                    for _ in 0..(retries.min(6)) {
                        std::hint::spin_loop();
                    }
                    tx.reset();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn setup(n: usize) -> (Arc<TinyStm>, Arc<SharedStmr>) {
        let clock = Arc::new(GlobalClock::new());
        (
            Arc::new(TinyStm::with_clock(clock)),
            Arc::new(SharedStmr::new(n)),
        )
    }

    #[test]
    fn read_write_commit_and_callback() {
        let (stm, stmr) = setup(16);
        let mut log = Vec::new();
        let r = stm.execute_into(
            &stmr,
            &mut |tx| {
                let v = tx.read(3)?;
                tx.write(3, v + 5)?;
                tx.write(7, 9)?;
                Ok(())
            },
            &mut log,
        );
        assert!(r.ts > 0);
        assert_eq!(stmr.load(3), 5);
        assert_eq!(stmr.load(7), 9);
        assert_eq!(log.len(), 2);
        assert!(log.iter().all(|e| e.ts == r.ts));
    }

    #[test]
    fn read_only_txn_has_no_log_and_ts_zero() {
        let (stm, stmr) = setup(8);
        stmr.store(2, 11);
        let mut log = Vec::new();
        let mut seen = 0;
        let r = stm.execute_into(
            &stmr,
            &mut |tx| {
                seen = tx.read(2)?;
                Ok(())
            },
            &mut log,
        );
        assert_eq!(seen, 11);
        assert_eq!(r.ts, 0);
        assert!(log.is_empty());
    }

    #[test]
    fn read_after_write_sees_own_write() {
        let (stm, stmr) = setup(8);
        let mut log = Vec::new();
        stm.execute_into(
            &stmr,
            &mut |tx| {
                tx.write(1, 42)?;
                assert_eq!(tx.read(1)?, 42);
                tx.write(1, 43)?;
                assert_eq!(tx.read(1)?, 43);
                Ok(())
            },
            &mut log,
        );
        assert_eq!(stmr.load(1), 43);
        // Latest-wins buffering: a single log entry for addr 1.
        assert_eq!(log.len(), 1);
        assert_eq!(log[0].val, 43);
    }

    #[test]
    fn concurrent_increments_lose_no_updates() {
        let (stm, stmr) = setup(4);
        let threads = 4;
        let per = 500;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let stm = stm.clone();
                let stmr = stmr.clone();
                s.spawn(move || {
                    let mut log = Vec::new();
                    for _ in 0..per {
                        stm.execute_into(
                            &stmr,
                            &mut |tx| {
                                let v = tx.read(0)?;
                                tx.write(0, v + 1)?;
                                Ok(())
                            },
                            &mut log,
                        );
                    }
                });
            }
        });
        assert_eq!(stmr.load(0), (threads * per) as i32);
    }

    #[test]
    fn timestamps_order_writes_to_same_word() {
        let (stm, stmr) = setup(4);
        let mut log = Vec::new();
        for i in 0..10 {
            stm.execute_into(
                &stmr,
                &mut |tx| {
                    tx.write(2, i)?;
                    Ok(())
                },
                &mut log,
            );
        }
        let ts: Vec<i32> = log.iter().map(|e| e.ts).collect();
        let mut sorted = ts.clone();
        sorted.sort_unstable();
        assert_eq!(ts, sorted, "commit order == timestamp order");
        assert_eq!(stmr.load(2), 9);
    }

    #[test]
    fn bank_transfer_invariant_under_concurrency() {
        // Classic serializability smoke: total balance is conserved.
        let (stm, stmr) = setup(8);
        for a in 0..8 {
            stmr.store(a, 100);
        }
        std::thread::scope(|s| {
            for t in 0..4 {
                let stm = stm.clone();
                let stmr = stmr.clone();
                s.spawn(move || {
                    let mut log = Vec::new();
                    let mut rng = crate::util::Rng::new(t as u64);
                    for _ in 0..400 {
                        let from = rng.below_usize(8);
                        let to = rng.below_usize(8);
                        if from == to {
                            continue;
                        }
                        stm.execute_into(
                            &stmr,
                            &mut |tx| {
                                let f = tx.read(from)?;
                                let g = tx.read(to)?;
                                tx.write(from, f - 1)?;
                                tx.write(to, g + 1)?;
                                Ok(())
                            },
                            &mut log,
                        );
                    }
                });
            }
        });
        let total: i32 = (0..8).map(|a| stmr.load(a)).sum();
        assert_eq!(total, 800);
    }
}
