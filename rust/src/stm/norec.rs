//! NOrec-like guest: a single global sequence lock plus value-based
//! validation (Dalessandro, Spear & Scott, PPoPP'10 — cited by the paper
//! as a representative software guest).
//!
//! NOrec keeps no per-location metadata: reads log `(addr, value)` pairs
//! and are revalidated by value whenever the global sequence number moves;
//! commits serialize on the sequence lock.  Low single-thread overhead and
//! graceful behaviour at modest thread counts — a good contrast to the
//! orec-based [`super::tinystm::TinyStm`] for SHeTM's modularity story.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{Abort, GlobalClock, GuestTm, SharedStmr, TxOps, TxnResult, WriteEntry};

/// NOrec guest TM.
pub struct NorecStm {
    /// Global sequence lock: even = free, odd = a writer is committing.
    seq: AtomicU64,
    clock: Arc<GlobalClock>,
    max_retries: u32,
}

impl NorecStm {
    /// Build over the shared CPU commit clock.
    pub fn with_clock(clock: Arc<GlobalClock>) -> Self {
        NorecStm {
            seq: AtomicU64::new(0),
            clock,
            max_retries: 1_000_000,
        }
    }

    /// Spin until the sequence number is even, returning it.
    #[inline]
    fn wait_even(&self) -> u64 {
        loop {
            let s = self.seq.load(Ordering::Acquire);
            if s & 1 == 0 {
                return s;
            }
            std::hint::spin_loop();
        }
    }
}

struct Tx<'a> {
    stm: &'a NorecStm,
    stmr: &'a SharedStmr,
    rv: u64,
    /// Value-validation read log.
    reads: Vec<(usize, i32)>,
    writes: Vec<(usize, i32)>,
}

impl<'a> Tx<'a> {
    fn new(stm: &'a NorecStm, stmr: &'a SharedStmr) -> Self {
        let rv = stm.wait_even();
        Tx {
            stm,
            stmr,
            rv,
            reads: Vec::new(),
            writes: Vec::new(),
        }
    }

    fn reset(&mut self) {
        self.rv = self.stm.wait_even();
        self.reads.clear();
        self.writes.clear();
    }

    /// Value-based revalidation; returns the new consistent snapshot seq.
    fn revalidate(&mut self) -> Result<u64, Abort> {
        loop {
            let s = self.stm.wait_even();
            for &(a, v) in &self.reads {
                if self.stmr.load(a) != v {
                    return Err(Abort);
                }
            }
            if self.stm.seq.load(Ordering::Acquire) == s {
                return Ok(s);
            }
        }
    }

    fn commit(&mut self, out: &mut Vec<WriteEntry>) -> Result<i32, Abort> {
        if self.writes.is_empty() {
            return Ok(0);
        }
        // Acquire the sequence lock, revalidating whenever we lose a race.
        loop {
            match self.stm.seq.compare_exchange(
                self.rv,
                self.rv + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(_) => self.rv = self.revalidate()?,
            }
        }
        let wv = self.stm.clock.tick();
        for &(addr, val) in &self.writes {
            self.stmr.store(addr, val);
            out.push(WriteEntry {
                addr: addr as u32,
                val,
                ts: wv,
            });
        }
        self.stm.seq.store(self.rv + 2, Ordering::Release);
        Ok(wv)
    }
}

impl TxOps for Tx<'_> {
    fn read(&mut self, addr: usize) -> Result<i32, Abort> {
        if let Some(&(_, v)) = self.writes.iter().rev().find(|&&(a, _)| a == addr) {
            return Ok(v);
        }
        let mut val = self.stmr.load(addr);
        while self.stm.seq.load(Ordering::Acquire) != self.rv {
            self.rv = self.revalidate()?;
            val = self.stmr.load(addr);
        }
        self.reads.push((addr, val));
        Ok(val)
    }

    fn write(&mut self, addr: usize, val: i32) -> Result<(), Abort> {
        if let Some(e) = self.writes.iter_mut().find(|e| e.0 == addr) {
            e.1 = val;
        } else {
            self.writes.push((addr, val));
        }
        Ok(())
    }
}

impl GuestTm for NorecStm {
    fn epoch_reset(&self, base: i64) {
        // NOrec keeps no clock-derived metadata (the sequence lock is an
        // independent counter; validation is by value), so only the
        // commit clock itself restarts.
        self.clock.epoch_reset(base);
    }

    fn name(&self) -> &'static str {
        "norec"
    }

    fn execute_into(
        &self,
        stmr: &SharedStmr,
        body: &mut dyn FnMut(&mut dyn TxOps) -> Result<(), Abort>,
        writes: &mut Vec<WriteEntry>,
    ) -> TxnResult {
        let mut tx = Tx::new(self, stmr);
        let mut retries = 0u32;
        loop {
            let ran = body(&mut tx);
            let committed = match ran {
                Ok(()) => tx.commit(writes),
                Err(Abort) => Err(Abort),
            };
            match committed {
                Ok(ts) => return TxnResult { ts, retries },
                Err(Abort) => {
                    retries += 1;
                    assert!(
                        retries < self.max_retries,
                        "norec: txn livelocked after {retries} retries"
                    );
                    tx.reset();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn setup(n: usize) -> (Arc<NorecStm>, Arc<SharedStmr>) {
        let clock = Arc::new(GlobalClock::new());
        (
            Arc::new(NorecStm::with_clock(clock)),
            Arc::new(SharedStmr::new(n)),
        )
    }

    #[test]
    fn commit_applies_and_logs() {
        let (stm, stmr) = setup(8);
        let mut log = Vec::new();
        let r = stm.execute_into(
            &stmr,
            &mut |tx| {
                let v = tx.read(0)?;
                tx.write(0, v + 7)?;
                Ok(())
            },
            &mut log,
        );
        assert_eq!(stmr.load(0), 7);
        assert_eq!(log.len(), 1);
        assert_eq!(log[0], WriteEntry { addr: 0, val: 7, ts: r.ts });
    }

    #[test]
    fn concurrent_increments_lose_no_updates() {
        let (stm, stmr) = setup(4);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let stm = stm.clone();
                let stmr = stmr.clone();
                s.spawn(move || {
                    let mut log = Vec::new();
                    for _ in 0..300 {
                        stm.execute_into(
                            &stmr,
                            &mut |tx| {
                                let v = tx.read(1)?;
                                tx.write(1, v + 1)?;
                                Ok(())
                            },
                            &mut log,
                        );
                    }
                });
            }
        });
        assert_eq!(stmr.load(1), 1200);
    }

    #[test]
    fn value_validation_tolerates_silent_rewrites() {
        // NOrec validates by value: a concurrent writer writing the SAME
        // value does not abort the reader.
        let (stm, stmr) = setup(2);
        stmr.store(0, 5);
        let mut log = Vec::new();
        let r = stm.execute_into(
            &stmr,
            &mut |tx| {
                let a = tx.read(0)?;
                let b = tx.read(1)?;
                tx.write(1, a + b)?;
                Ok(())
            },
            &mut log,
        );
        assert!(r.ts > 0);
        assert_eq!(stmr.load(1), 5);
    }
}
