//! Deterministic pseudo-random number generation (xoshiro256** seeded via
//! SplitMix64), replacing the `rand` crate in this offline build.
//!
//! Statistical quality is ample for workload generation; determinism across
//! runs and platforms is the property the benches and property tests rely
//! on.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that any `u64` (including 0) is a good seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    ///
    /// Lemire's multiply-shift with rejection: unbiased.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let l = m as u64;
            if l >= bound || l >= l.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// `k` distinct values from `[0, n)` (partial Fisher-Yates on a sparse
    /// map would be overkill; n is large and k tiny, so rejection is fine).
    pub fn distinct(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        debug_assert!(k <= n);
        out.clear();
        while out.len() < k {
            let v = self.below_usize(n) as u32;
            if !out.contains(&v) {
                out.push(v);
            }
        }
    }

    /// Split off an independently-seeded child generator.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_uniformish() {
        let mut r = Rng::new(9);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn distinct_yields_unique() {
        let mut r = Rng::new(11);
        let mut v = Vec::new();
        r.distinct(100, 40, &mut v);
        assert_eq!(v.len(), 40);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
