//! Small statistics helpers: online mean/min/max, percentile summaries and
//! fixed-width histograms, used by the coordinator metrics and the bench
//! harness.

/// Online summary of a stream of f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary {
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Population standard deviation (0 if < 2 samples).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64) - m * m).max(0.0).sqrt()
    }

    /// Smallest sample (0 if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample (0 if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Percentile over a mutable sample buffer (nearest-rank).
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize;
    samples[rank.clamp(1, samples.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - (1.25f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut xs, 50.0), 50.0);
        assert_eq!(percentile(&mut xs, 99.0), 99.0);
        assert_eq!(percentile(&mut xs, 100.0), 100.0);
        let mut one = vec![7.0];
        assert_eq!(percentile(&mut one, 50.0), 7.0);
    }
}
