//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many deterministically-seeded random cases and, on
//! failure, retries the failing case with "smaller" size parameters to aid
//! debugging (linear shrinking of the case's size knob).
//!
//! ```rust,no_run
//! use shetm::util::prop::{forall, Cases};
//! forall(Cases::new("sum_commutes", 200), |rng, size| {
//!     let a = rng.below(size.max(1) as u64);
//!     let b = rng.below(size.max(1) as u64);
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Configuration for one property run.
#[derive(Debug, Clone)]
pub struct Cases {
    /// Property name (printed on failure).
    pub name: &'static str,
    /// Number of random cases.
    pub count: u32,
    /// Base RNG seed; each case derives `seed + case_index`.
    pub seed: u64,
    /// Maximum "size" hint handed to the property (cases ramp up to it).
    pub max_size: usize,
}

impl Cases {
    /// Standard configuration: `count` cases, sizes ramping to 256.
    pub fn new(name: &'static str, count: u32) -> Self {
        Cases {
            name,
            count,
            seed: 0x5EED_0BAD_F00D,
            max_size: 256,
        }
    }

    /// Override the size ramp's maximum.
    pub fn max_size(mut self, s: usize) -> Self {
        self.max_size = s;
        self
    }

    /// Override the seed (for reproducing failures).
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `property` over random cases; panics with diagnostics on failure.
///
/// The property receives a seeded RNG and a size hint that grows from 1 to
/// `max_size` across cases, and returns `Err(description)` to signal a
/// counterexample.
pub fn forall<F>(cases: Cases, mut property: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for i in 0..cases.count {
        let size = 1 + (i as usize * cases.max_size) / cases.count.max(1) as usize;
        let case_seed = cases.seed.wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = property(&mut rng, size) {
            // Shrink: retry the same seed at smaller sizes, reporting the
            // smallest size that still fails.
            let mut min_fail = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                match property(&mut rng, s) {
                    Err(m) => {
                        min_fail = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property {:?} failed (case {}, seed {:#x}):\n  at size {}: {}\n  \
                 minimal failing size {}: {}\n  reproduce with Cases::new(..).seed({:#x})",
                cases.name, i, case_seed, size, msg, min_fail.0, min_fail.1, case_seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(Cases::new("add_comm", 100), |rng, size| {
            let a = rng.below(size.max(1) as u64);
            let b = rng.below(size.max(1) as u64);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a}+{b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always_fails\" failed")]
    fn failing_property_panics_with_shrink_info() {
        forall(Cases::new("always_fails", 10), |_rng, size| {
            if size >= 1 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_seen = 0usize;
        forall(Cases::new("ramp", 50).max_size(128), |_rng, size| {
            max_seen = max_seen.max(size);
            Ok(())
        });
        assert!(max_seen > 64, "sizes should approach max: {max_seen}");
    }
}
