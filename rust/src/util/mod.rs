//! Dependency-free utilities: deterministic RNG, Zipf sampling, statistics,
//! a property-test harness and a micro-bench timer.
//!
//! The offline build vendors only the `xla` crate closure, so the usual
//! ecosystem crates (rand / proptest / criterion) are replaced by the small,
//! well-tested implementations in this module (DESIGN.md §4).

pub mod bench;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod zipf;

pub use rng::Rng;
pub use zipf::Zipf;
