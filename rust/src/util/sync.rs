//! Poison-explicit lock acquisition.
//!
//! The panic-policy audit rule (D6, DESIGN.md §15) bans bare
//! `.unwrap()`/`.expect()` in library code.  Lock poisoning is the one
//! case where crashing *is* the policy — a worker panicked while
//! holding shared engine state, so no consistent continuation exists —
//! but that decision should live in one audited place with a uniform
//! diagnostic, not in dozens of ad-hoc `lock().unwrap()` calls.  These
//! helpers make the poison check explicit and keep call sites clean.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Acquire `m`, panicking with a uniform diagnostic if a previous
/// holder panicked (deliberate crash-on-poison policy; see module docs).
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(e) => panic!("mutex poisoned by a panicking holder: {e}"),
    }
}

/// Read-acquire `l`, panicking with a uniform diagnostic on poison.
pub fn read_lock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(e) => panic!("rwlock poisoned by a panicking holder: {e}"),
    }
}

/// Write-acquire `l`, panicking with a uniform diagnostic on poison.
pub fn write_lock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(e) => panic!("rwlock poisoned by a panicking holder: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locks_pass_through() {
        let m = Mutex::new(3);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 4);
        let l = RwLock::new(7);
        assert_eq!(*read_lock(&l), 7);
        *write_lock(&l) = 8;
        assert_eq!(*read_lock(&l), 8);
    }

    #[test]
    fn poisoned_mutex_panics_with_policy_message() {
        let m = std::sync::Arc::new(Mutex::new(0));
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        let got = std::panic::catch_unwind(|| {
            let _ = lock(&m);
        });
        assert!(got.is_err(), "lock() must crash on poison");
    }
}
