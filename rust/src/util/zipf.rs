//! Zipfian sampling over `[0, n)` with parameter alpha, used by the
//! memcached workload (paper §V-D: object popularity Zipf with alpha = 0.5).
//!
//! Uses the rejection-inversion method of Hörmann & Derflinger, which needs
//! no O(n) table and is exact for any alpha >= 0 (alpha = 0 degenerates to
//! uniform).

use super::rng::Rng;

/// Zipf(n, alpha) sampler: `P(k) ∝ (k+1)^-alpha` for `k in [0, n)`.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    // Precomputed constants of the rejection-inversion scheme.
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    /// Construct a sampler; `n > 0`, `alpha >= 0`.
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n > 0, "Zipf needs n > 0");
        assert!(alpha >= 0.0, "Zipf needs alpha >= 0");
        let h = |x: f64| -> f64 {
            if (alpha - 1.0).abs() < 1e-12 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - alpha) - 1.0) / (1.0 - alpha)
            }
        };
        let h_x1 = h(1.5) - 1.0;
        let h_n = h(n as f64 + 0.5);
        let s = 2.0 - Self::h_inv_static(alpha, h(2.5) - (2.0f64).powf(-alpha));
        Zipf {
            n,
            alpha,
            h_x1,
            h_n,
            s,
        }
    }

    fn h_inv_static(alpha: f64, x: f64) -> f64 {
        if (alpha - 1.0).abs() < 1e-12 {
            x.exp() - 1.0
        } else {
            ((1.0 - alpha) * x + 1.0).powf(1.0 / (1.0 - alpha)) - 1.0
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        Self::h_inv_static(self.alpha, x)
    }

    /// Draw one sample in `[0, n)` (0 is the most popular rank).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.alpha == 0.0 {
            return rng.below(self.n);
        }
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            let h_k = {
                let a = self.alpha;
                if (a - 1.0).abs() < 1e-12 {
                    (k + 0.5).ln()
                } else {
                    ((k + 0.5).powf(1.0 - a) - 1.0) / (1.0 - a)
                }
            };
            if k - x <= self.s || u >= h_k - k.powf(-self.alpha) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_zero_is_uniformish() {
        let z = Zipf::new(100, 0.0);
        let mut r = Rng::new(3);
        let mut counts = [0u32; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min < 2.0, "uniform spread (max {max}, min {min})");
    }

    #[test]
    fn skew_orders_ranks() {
        let z = Zipf::new(1000, 0.99);
        let mut r = Rng::new(4);
        let mut head = 0u32;
        let mut tail = 0u32;
        for _ in 0..20_000 {
            let k = z.sample(&mut r);
            if k < 10 {
                head += 1;
            } else if k >= 500 {
                tail += 1;
            }
        }
        assert!(
            head > tail,
            "popular head should dominate: head={head} tail={tail}"
        );
    }

    #[test]
    fn samples_in_range() {
        for &alpha in &[0.0, 0.5, 1.0, 1.5] {
            let z = Zipf::new(37, alpha);
            let mut r = Rng::new(5);
            for _ in 0..2000 {
                assert!(z.sample(&mut r) < 37);
            }
        }
    }

    #[test]
    fn alpha_half_matches_paper_workload_shape() {
        // alpha = 0.5 (the paper's memcached workload): mild skew — the top
        // 1% of ranks should get noticeably more than 1% of the mass, but
        // far from a heavy-tail majority.
        let z = Zipf::new(10_000, 0.5);
        let mut r = Rng::new(6);
        let mut top = 0u32;
        let n = 50_000;
        for _ in 0..n {
            if z.sample(&mut r) < 100 {
                top += 1;
            }
        }
        let frac = top as f64 / n as f64;
        assert!(frac > 0.02 && frac < 0.25, "top-1% mass {frac}");
    }
}
