//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Every `benches/*.rs` target uses this: warm-up, timed iterations,
//! mean/stddev reporting and a tabular printer whose rows mirror the
//! corresponding paper table/figure series (EXPERIMENTS.md records them).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Label, e.g. `shetm/period=80ms`.
    pub name: String,
    /// Per-iteration wall time.
    pub mean: Duration,
    /// Standard deviation across iterations.
    pub stddev: Duration,
    /// Iterations measured.
    pub iters: u32,
}

impl BenchResult {
    /// Iterations per second implied by the mean.
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            0.0
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

/// Time `f` with `iters` measured iterations after `warmup` unmeasured ones.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        mean: Duration::from_secs_f64(s.mean()),
        stddev: Duration::from_secs_f64(s.stddev()),
        iters,
    }
}

/// Print one benchmark line in a stable, grep-friendly format.
pub fn report(r: &BenchResult) {
    println!(
        "bench {:<44} {:>12.3?} ±{:>10.3?}  ({} iters)",
        r.name, r.mean, r.stddev, r.iters
    );
}

/// A table printer for figure-series output: fixed column widths, one
/// header, rows of f64 cells. The benches print paper-figure series with it.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
}

impl Table {
    /// Build a table with the given column headers and print the header row.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        println!("\n== {title} ==");
        let widths: Vec<usize> = headers.iter().map(|h| h.len().max(12)).collect();
        let mut line = String::new();
        for (h, w) in headers.iter().zip(&widths) {
            line.push_str(&format!("{h:>w$}  "));
        }
        println!("{line}");
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths,
        }
    }

    /// Print one row; cells are formatted with 4 significant decimals.
    pub fn row(&self, cells: &[f64]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{c:>w$.4}  "));
        }
        println!("{line}");
    }

    /// Print a row whose first cell is a string label.
    pub fn row_labeled(&self, label: &str, cells: &[f64]) {
        assert_eq!(cells.len() + 1, self.headers.len(), "table row arity");
        let mut line = format!("{label:>w$}  ", w = self.widths[0]);
        for (c, w) in cells.iter().zip(&self.widths[1..]) {
            line.push_str(&format!("{c:>w$.4}  "));
        }
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.per_sec() > 0.0);
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn table_checks_arity() {
        let t = Table::new("t", &["a", "b"]);
        t.row(&[1.0]);
    }
}
