//! Artifact manifest parsing and the compiled-executable store.
//!
//! `artifacts/manifest.txt` is one line per kernel of whitespace-separated
//! `key=value` fields (a deliberately dependency-free format: this build is
//! fully offline and carries no serde).  Required keys: `name`, `kind`,
//! `file`; every other key is an integer parameter recorded in
//! [`ArtifactMeta::params`] (shapes, bitmap shift, ...).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::exec::KernelExec;

/// Which Layer-2 step function an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// PR-STM batch transaction step (`model.prstm_step`).
    Prstm,
    /// CPU-log validation + freshness-guarded apply (`model.validate_step`).
    Validate,
    /// Memcached GET/PUT batch step (`model.memcached_step`).
    Memcached,
}

impl KernelKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "prstm" => KernelKind::Prstm,
            "validate" => KernelKind::Validate,
            "memcached" => KernelKind::Memcached,
            other => bail!("unknown kernel kind {other:?} in manifest"),
        })
    }
}

/// One manifest entry: a named, shape-monomorphic compiled kernel.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name, e.g. `prstm_r4_g0`.
    pub name: String,
    /// Step-function family.
    pub kind: KernelKind,
    /// HLO text file, relative to the artifact directory.
    pub file: PathBuf,
    /// Integer shape/config parameters (`n`, `b`, `r`, `w`, `bmp_shift`, ...).
    pub params: HashMap<String, i64>,
}

impl ArtifactMeta {
    /// Parse one manifest line. Returns `None` for blank/comment lines.
    fn parse_line(line: &str) -> Result<Option<Self>> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut name = None;
        let mut kind = None;
        let mut file = None;
        let mut params = HashMap::new();
        for field in line.split_whitespace() {
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| anyhow!("malformed manifest field {field:?}"))?;
            match k {
                "name" => name = Some(v.to_string()),
                "kind" => kind = Some(KernelKind::parse(v)?),
                "file" => file = Some(PathBuf::from(v)),
                _ => {
                    let n: i64 = v
                        .parse()
                        .with_context(|| format!("non-integer manifest value {field:?}"))?;
                    params.insert(k.to_string(), n);
                }
            }
        }
        Ok(Some(ArtifactMeta {
            name: name.ok_or_else(|| anyhow!("manifest line missing name: {line:?}"))?,
            kind: kind.ok_or_else(|| anyhow!("manifest line missing kind: {line:?}"))?,
            file: file.ok_or_else(|| anyhow!("manifest line missing file: {line:?}"))?,
            params,
        }))
    }

    /// Fetch a required integer parameter.
    pub fn param(&self, key: &str) -> Result<i64> {
        self.params
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("artifact {} missing param {key:?}", self.name))
    }

    /// Fetch a required parameter as `usize`.
    pub fn param_usize(&self, key: &str) -> Result<usize> {
        Ok(usize::try_from(self.param(key)?)?)
    }
}

/// Parse a whole manifest file body.
pub fn parse_manifest(body: &str) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    for (i, line) in body.lines().enumerate() {
        if let Some(meta) =
            ArtifactMeta::parse_line(line).with_context(|| format!("manifest line {}", i + 1))?
        {
            out.push(meta);
        }
    }
    Ok(out)
}

/// Store of compiled PJRT executables, keyed by artifact name.
///
/// Compilation happens eagerly at construction (one-time cost, so the hot
/// path never compiles); the store is cheap to clone across threads.
#[derive(Clone)]
pub struct ArtifactStore {
    inner: Arc<StoreInner>,
}

struct StoreInner {
    dir: PathBuf,
    kernels: HashMap<String, KernelExec>,
}

impl ArtifactStore {
    /// Load `manifest.txt` from `dir`, compile every artifact on a fresh
    /// PJRT CPU client.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let metas = parse_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;

        let mut kernels = HashMap::new();
        for meta in metas {
            let path = dir.join(&meta.file);
            let exec = KernelExec::compile(&client, &path, meta.clone())
                .with_context(|| format!("compiling artifact {}", meta.name))?;
            kernels.insert(meta.name.clone(), exec);
        }
        Ok(ArtifactStore {
            inner: Arc::new(StoreInner { dir, kernels }),
        })
    }

    /// Stub loader: the build carries no PJRT runtime, so artifact
    /// directories can never be loaded (and [`Self::available`] reports
    /// them unavailable, letting every caller self-skip first).
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "cannot load artifacts from {}: SHeTM was built without the \
             `pjrt` cargo feature (see DESIGN.md §4)",
            dir.as_ref().display()
        )
    }

    /// Whether an artifact directory looks loadable: a manifest exists AND
    /// this build can actually execute artifacts (the `pjrt` feature).
    /// Every PJRT-dependent test and launcher path checks this first, so
    /// `cargo test -q` passes without `make artifacts`.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        cfg!(feature = "pjrt") && dir.as_ref().join("manifest.txt").is_file()
    }

    /// Look up a compiled kernel by artifact name.
    pub fn get(&self, name: &str) -> Result<&KernelExec> {
        self.inner
            .kernels
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name:?} in {}", self.inner.dir.display()))
    }

    /// All loaded kernel names (sorted, for diagnostics).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.inner.kernels.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Artifact directory this store was loaded from.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_well_formed_manifest() {
        let body = "\
# comment
name=prstm_r4_g0 kind=prstm file=p.hlo.txt b=1024 n=262144

name=validate_synth_g0 kind=validate file=v.hlo.txt c=4096 n=262144
";
        let metas = parse_manifest(body).unwrap();
        assert_eq!(metas.len(), 2);
        assert_eq!(metas[0].name, "prstm_r4_g0");
        assert_eq!(metas[0].kind, KernelKind::Prstm);
        assert_eq!(metas[0].param("b").unwrap(), 1024);
        assert_eq!(metas[1].kind, KernelKind::Validate);
        assert_eq!(metas[1].param_usize("c").unwrap(), 4096);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(parse_manifest("kind=prstm file=x.hlo.txt").is_err());
        assert!(parse_manifest("name=a file=x.hlo.txt").is_err());
        assert!(parse_manifest("name=a kind=prstm").is_err());
        assert!(parse_manifest("name=a kind=bogus file=x").is_err());
        assert!(parse_manifest("name=a kind=prstm file=x n=abc").is_err());
    }

    #[test]
    fn missing_param_is_error() {
        let metas = parse_manifest("name=a kind=prstm file=x.hlo.txt n=4").unwrap();
        assert!(metas[0].param("b").is_err());
        assert_eq!(metas[0].param("n").unwrap(), 4);
    }
}
