//! Thin, typed wrapper around one compiled PJRT executable.
//!
//! All SHeTM kernels exchange only `i32` tensors (the STMR is word-indexed),
//! so the interface is deliberately narrow: callers hand in `&[i32]` slices
//! plus shapes, get back `Vec<Vec<i32>>` (the lowered jax functions return
//! tuples — `aot.py` lowers with `return_tuple=True`).
//!
//! The `xla` crate (and with it the whole PJRT closure) is only linked when
//! the `pjrt` cargo feature is enabled; the default offline build compiles
//! a stub whose entry points report the missing feature, and
//! [`super::ArtifactStore::available`] returns `false` so every
//! artifact-dependent test and launcher path self-skips (DESIGN.md §4).

#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
#[cfg(not(feature = "pjrt"))]
use anyhow::bail;
use anyhow::Result;

use super::artifacts::ArtifactMeta;

/// An input tensor: flat `i32` data plus its dimensions.
#[derive(Debug, Clone)]
pub struct TensorI32<'a> {
    /// Row-major flat data.
    pub data: &'a [i32],
    /// Dimensions; empty means scalar.
    pub dims: Vec<i64>,
}

impl<'a> TensorI32<'a> {
    /// 1-D tensor covering the whole slice.
    pub fn vec(data: &'a [i32]) -> Self {
        TensorI32 {
            data,
            dims: vec![data.len() as i64],
        }
    }

    /// 2-D tensor; `data.len()` must equal `rows * cols`.
    pub fn mat(data: &'a [i32], rows: usize, cols: usize) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        TensorI32 {
            data,
            dims: vec![rows as i64, cols as i64],
        }
    }

    /// Scalar tensor (slice of length 1).
    pub fn scalar(data: &'a [i32]) -> Self {
        debug_assert_eq!(data.len(), 1);
        TensorI32 { data, dims: vec![] }
    }
}

/// One compiled PJRT executable plus its manifest metadata.
///
/// `xla::PjRtLoadedExecutable` is not `Sync`, and the simulated GPU device
/// serializes kernel activations anyway (a real GPU stream would too), so
/// executions are guarded by a mutex.
pub struct KernelExec {
    meta: ArtifactMeta,
    #[cfg(feature = "pjrt")]
    exe: Mutex<xla::PjRtLoadedExecutable>,
}

impl KernelExec {
    /// Manifest metadata for this kernel.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }
}

#[cfg(feature = "pjrt")]
impl KernelExec {
    /// Compile HLO text at `path` on `client`.
    pub fn compile(client: &xla::PjRtClient, path: &Path, meta: ArtifactMeta) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {}: {e:?}", path.display()))?;
        Ok(KernelExec {
            meta,
            exe: Mutex::new(exe),
        })
    }

    /// Execute with `i32` tensors; returns every tuple element as a flat vec.
    pub fn run(&self, inputs: &[TensorI32<'_>]) -> Result<Vec<Vec<i32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            // Build each literal directly with its final shape: going
            // through `vec1(..).reshape(..)` copies the buffer twice
            // (§Perf L1 optimization, EXPERIMENTS.md).
            let dims: Vec<usize> = t.dims.iter().map(|&d| d as usize).collect();
            // The crate denies `unsafe_code`; this is the one justified
            // exception: xla-rs takes untyped bytes, so the i32 slice is
            // reinterpreted in place (same allocation, same length in
            // bytes, i32 has no padding or invalid bit patterns) to avoid
            // copying every tensor an extra time on the hot path.
            #[allow(unsafe_code)]
            let bytes = unsafe {
                std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &dims,
                bytes,
            )
            .map_err(|e| anyhow!("literal create {:?}: {e:?}", t.dims))?;
            literals.push(lit);
        }

        let exe = crate::util::sync::lock(&self.exe);
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.meta.name))?;
        drop(exe);

        let out = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.meta.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: device->host: {e:?}", self.meta.name))?;

        let elems = out
            .to_tuple()
            .map_err(|e| anyhow!("{}: untuple: {e:?}", self.meta.name))?;
        let mut vecs = Vec::with_capacity(elems.len());
        for (i, el) in elems.into_iter().enumerate() {
            let v = el
                .to_vec::<i32>()
                .with_context(|| format!("{}: output {i} as i32", self.meta.name))?;
            vecs.push(v);
        }
        Ok(vecs)
    }
}

#[cfg(not(feature = "pjrt"))]
impl KernelExec {
    /// Stub executor: the build carries no PJRT runtime.
    ///
    /// Unreachable in practice — without the feature no [`KernelExec`] can
    /// be constructed (`ArtifactStore::load` refuses) — but keeping the
    /// method compiled preserves one call surface for `gpu::device`.
    pub fn run(&self, _inputs: &[TensorI32<'_>]) -> Result<Vec<Vec<i32>>> {
        bail!(
            "artifact {}: SHeTM was built without the `pjrt` cargo feature",
            self.meta.name
        )
    }
}
