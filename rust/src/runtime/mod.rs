//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from Rust.
//!
//! This is the only boundary between the Rust coordinator and the XLA world.
//! `python/compile/aot.py` lowers the Layer-2 jax step functions to HLO
//! *text* under `artifacts/` together with a `manifest.txt`; at startup the
//! coordinator builds an [`ArtifactStore`] which compiles each module once
//! on a shared `xla::PjRtClient` (only linked under the `pjrt` cargo
//! feature) and hands out [`KernelExec`] handles that the hot path calls
//! with plain `&[i32]` slices.
//!
//! Python never runs at request time: after `make artifacts` the Rust binary
//! is self-contained.

mod artifacts;
mod exec;

pub use artifacts::{ArtifactMeta, ArtifactStore, KernelKind};
pub use exec::{KernelExec, TensorI32};
