//! Round-boundary incremental checkpoints + deterministic crash recovery.
//!
//! SHeTM's synchronization barrier already maintains exactly the state a
//! durability layer needs: packed dirty-granule bitmaps (which STMR pages
//! changed) and the carried write-log prefix (validation-window commits
//! that survive even a favor-GPU round abort).  This module piggybacks on
//! that barrier — it never adds a synchronization point of its own:
//!
//! * [`DurabilityHook`] — owned by both engines; accumulates the round's
//!   write footprint into a cross-round dirty [`Bitmap`] and, every
//!   `durability.interval_rounds` rounds, writes one checkpoint:
//!   - `ckpt-NNNNNNNN.pages` — dirty STMR extents (full image for the
//!     first checkpoint of a chain), FNV-1a-checksummed;
//!   - `ckpt-NNNNNNNN.wal`   — a write-ahead copy of the per-shard
//!     carried log (the §IV-D validation-window prefix), checksummed;
//!   - `ckpt-NNNNNNNN.manifest` — round, epoch base, virtual clock,
//!     per-file sizes/checksums, `prev` chain link, and a trailing
//!     whole-manifest checksum.  The manifest is written **last**: its
//!     valid checksum line is the checkpoint's commit point.
//! * [`ExternalJournal`] — a write-ahead journal of
//!   [`crate::session::Session::txn`] injections (and `drain` barriers),
//!   so recovery can replay them at the recorded round boundaries.
//! * [`load_latest`] — scans a checkpoint directory for the newest
//!   checkpoint whose *entire* chain (manifest, pages, WAL, every
//!   ancestor) validates, reconstructs the STMR image base→…→newest, and
//!   cross-checks it against the manifest's whole-image checksum; torn or
//!   corrupted checkpoints fall back to the previous complete one.
//! * [`CrashPoint`] / [`FaultPlan`] — a deterministic fault-injection
//!   layer that tears or corrupts checkpoint files at every interesting
//!   point and then simulates process death (an error that unwinds out of
//!   `run_round`; `SHETM_CRASH_KILL=1` upgrades it to a real `exit(3)`
//!   for CLI sweeps).  Zero-cost when no plan is armed: the engines test
//!   one `Option` per round.
//!
//! Recovery (`Session::recover`) is **replay-based**: engine drivers hold
//! unserializable host state (RNGs, rate debt, oracle traces), but every
//! run is deterministic in virtual time, so the recovered session is
//! rebuilt from the same configuration, re-run to the checkpointed round
//! with journaled external transactions re-injected at their recorded
//! boundaries, and then *verified bit-exactly* against the checkpoint
//! (STMR words, `RunStats` digest, per-shard carried log, virtual clock).
//! Checkpoint I/O costs zero virtual time and touches no statistics, so a
//! durability-enabled run is bit-identical to a durability-off run.
//! See DESIGN.md §13 for the full catalog of formats and invariants.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::stats::RunStats;
use crate::gpu::bitmap::Bitmap;
use crate::stm::{SharedStmr, WriteEntry};

/// Marker every simulated-crash error message starts with; tests and the
/// CLI detect an injected crash (vs a real failure) with
/// [`is_simulated_crash`].
pub const CRASH_MARKER: &str = "simulated crash";

/// Magic first line of a checkpoint manifest.
const MANIFEST_MAGIC: &str = "shetm-checkpoint v1";

/// FNV-1a 64-bit over a byte slice (dependency-free checksum; the same
/// polynomial everywhere a checkpoint file carries a trailer).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Digest of a full `RunStats` through its `Debug` form (which prints
/// every f64 at full precision), used to pin recovered statistics
/// bit-exactly to the checkpointed ones.
pub fn stats_digest(stats: &RunStats) -> u64 {
    fnv1a(format!("{stats:?}").as_bytes())
}

/// True if `err` is an injected [`FaultPlan`] crash rather than a real
/// engine failure.
pub fn is_simulated_crash(err: &anyhow::Error) -> bool {
    err.to_string().contains(CRASH_MARKER)
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// Where, within one checkpoint write, the injected crash fires.
///
/// The write order is pages → WAL → manifest (manifest last = commit
/// point), so each variant leaves a distinct torn state behind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Die after writing only the first half of the pages file.
    MidPageWrite,
    /// Die after the pages file, before the WAL.
    AfterPages,
    /// Die after writing only the first half of the WAL file.
    MidWalAppend,
    /// Die after the WAL, before the manifest.
    AfterWal,
    /// Die after writing only half of the manifest (no checksum line).
    MidManifest,
    /// Complete the checkpoint, then flip one byte of the pages file.
    CorruptPageByte,
    /// Complete the checkpoint, then flip one byte of the manifest.
    CorruptManifestByte,
    /// Complete the checkpoint intact, then die between rounds.
    AfterCheckpoint,
    /// Die at the cluster round barrier in the middle of a shard
    /// migration: the rebalancer has picked a move and scheduled the page
    /// DMA, but the next layout epoch is not yet installed.  Fired by the
    /// cluster engine (not the checkpoint writer), so it leaves no torn
    /// checkpoint files — recovery replays from the last complete
    /// checkpoint and the deterministic rebalancer re-makes the same
    /// decision (DESIGN.md §14).
    MidMigration,
}

impl CrashPoint {
    /// Every crash point, in write order (test matrices sweep this).
    pub const ALL: [CrashPoint; 9] = [
        CrashPoint::MidPageWrite,
        CrashPoint::AfterPages,
        CrashPoint::MidWalAppend,
        CrashPoint::AfterWal,
        CrashPoint::MidManifest,
        CrashPoint::CorruptPageByte,
        CrashPoint::CorruptManifestByte,
        CrashPoint::AfterCheckpoint,
        CrashPoint::MidMigration,
    ];

    /// Parse the config/CLI spelling (`durability.crash_point`).
    pub fn parse(s: &str) -> Result<CrashPoint> {
        Ok(match s {
            "mid-page-write" => CrashPoint::MidPageWrite,
            "after-pages" => CrashPoint::AfterPages,
            "mid-wal-append" => CrashPoint::MidWalAppend,
            "after-wal" => CrashPoint::AfterWal,
            "mid-manifest" => CrashPoint::MidManifest,
            "corrupt-page-byte" => CrashPoint::CorruptPageByte,
            "corrupt-manifest-byte" => CrashPoint::CorruptManifestByte,
            "after-checkpoint" => CrashPoint::AfterCheckpoint,
            "mid-migration" => CrashPoint::MidMigration,
            other => bail!(
                "unknown crash point {other:?} (mid-page-write|after-pages|\
                 mid-wal-append|after-wal|mid-manifest|corrupt-page-byte|\
                 corrupt-manifest-byte|after-checkpoint|mid-migration)"
            ),
        })
    }

    /// The config/CLI spelling accepted by [`CrashPoint::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            CrashPoint::MidPageWrite => "mid-page-write",
            CrashPoint::AfterPages => "after-pages",
            CrashPoint::MidWalAppend => "mid-wal-append",
            CrashPoint::AfterWal => "after-wal",
            CrashPoint::MidManifest => "mid-manifest",
            CrashPoint::CorruptPageByte => "corrupt-page-byte",
            CrashPoint::CorruptManifestByte => "corrupt-manifest-byte",
            CrashPoint::AfterCheckpoint => "after-checkpoint",
            CrashPoint::MidMigration => "mid-migration",
        }
    }

    /// Whether crashing here leaves the in-flight checkpoint unusable,
    /// forcing recovery to fall back to the previous complete one.
    /// Every point does except [`CrashPoint::AfterCheckpoint`] and
    /// [`CrashPoint::MidMigration`], which fire outside the checkpoint
    /// write (after the manifest commit point / at the migration barrier).
    pub fn tears_checkpoint(&self) -> bool {
        !matches!(self, CrashPoint::AfterCheckpoint | CrashPoint::MidMigration)
    }
}

/// An armed fault: fire `point` at the first checkpoint whose round is
/// `>= at_round`.  One-shot by construction — firing kills the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Where within the checkpoint write to crash.
    pub point: CrashPoint,
    /// First checkpoint round at which the fault is eligible.
    pub at_round: u64,
}

/// Simulate process death at `point`: leave whatever torn files exist on
/// disk and unwind.  `SHETM_CRASH_KILL=1` turns the unwind into a real
/// `exit(3)` so CLI sweeps can exercise hard kills.
fn crash(point: CrashPoint, round: u64) -> anyhow::Error {
    if std::env::var("SHETM_CRASH_KILL").as_deref() == Ok("1") {
        eprintln!("{CRASH_MARKER}: {} at checkpoint round {round}", point.as_str());
        std::process::exit(3);
    }
    anyhow!(
        "{CRASH_MARKER}: {} at checkpoint round {round}",
        point.as_str()
    )
}

// ---------------------------------------------------------------------------
// Checkpoint writer (the engine-side hook)
// ---------------------------------------------------------------------------

/// Summary of one written checkpoint, fed to telemetry
/// (`hetm_checkpoint_*` counters).
#[derive(Debug, Clone, Copy)]
pub struct CheckpointSummary {
    /// Round the checkpoint captured.
    pub round: u64,
    /// Whether this was a full-image checkpoint (chain base).
    pub full: bool,
    /// Total bytes written (pages + WAL + manifest).
    pub bytes: u64,
    /// Dirty extents snapshot into the pages file.
    pub extents: u64,
    /// STMR words those extents cover.
    pub dirty_words: u64,
    /// Carried-log entries copied into the WAL.
    pub wal_entries: u64,
    /// Wall-clock write duration in microseconds.  Real time, not virtual
    /// — excluded from every determinism comparison; checkpoints cost
    /// zero *virtual* time by design.
    pub write_micros: u64,
}

/// Engine-side checkpoint pipeline: dirty accumulation across rounds plus
/// the barrier-time writer.  Installed into an engine's `dur` slot by the
/// session builder when a checkpoint directory is configured; engines
/// without one pay a single `Option` test per round.
pub struct DurabilityHook {
    dir: PathBuf,
    /// Checkpoint every this many rounds (0 = journal-only, never
    /// checkpoint).
    interval_rounds: u64,
    plan: Option<FaultPlan>,
    /// Granules written since the last complete checkpoint (union of CPU
    /// log footprints and device write-set bitmaps; over-approximation is
    /// safe, under-approximation is caught by the manifest's whole-image
    /// checksum at load time).
    dirty: Bitmap,
    /// Round of the previous complete checkpoint (chain link); `None`
    /// until the first one, which therefore snapshots the full image.
    prev: Option<u64>,
    /// Reused extent scratch.
    ranges: Vec<(usize, usize)>,
}

impl DurabilityHook {
    /// Create the hook, ensuring `dir` exists.  `n_words`/`shift` must
    /// match the engine devices' bitmaps so write-set unions line up.
    pub fn new(
        dir: &Path,
        interval_rounds: u64,
        n_words: usize,
        shift: u32,
        plan: Option<FaultPlan>,
    ) -> Result<Self> {
        fs::create_dir_all(dir)
            .map_err(|e| anyhow!("checkpoint dir {}: {e}", dir.display()))?;
        Ok(DurabilityHook {
            dir: dir.to_path_buf(),
            interval_rounds,
            plan,
            dirty: Bitmap::new(n_words, shift),
            prev: None,
            ranges: Vec::new(),
        })
    }

    /// Continue an existing chain: the next checkpoint links `prev = round`
    /// and snapshots only pages dirtied after it (recovery re-arm).
    pub fn resume_from(&mut self, round: u64) {
        self.prev = Some(round);
        self.dirty.clear();
    }

    /// The checkpoint directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Fold a write-log slice into the dirty accumulator.
    pub fn mark_entries(&mut self, entries: &[WriteEntry]) {
        for e in entries {
            self.dirty.mark_word(e.addr as usize);
        }
    }

    /// Fold a device write-set bitmap into the dirty accumulator.
    pub fn mark_device(&mut self, ws: &Bitmap) {
        self.dirty.union_with(ws);
    }

    /// Whether a checkpoint is due at `round` (engines pre-check this to
    /// keep the barrier hot path free of slice assembly).
    pub fn due(&self, round: u64) -> bool {
        self.interval_rounds > 0 && round > 0 && round % self.interval_rounds == 0
    }

    /// Cluster-engine entry point for the migration fault: simulate
    /// process death if a [`CrashPoint::MidMigration`] plan is armed and
    /// `round` (same numbering as [`DurabilityHook::maybe_checkpoint`])
    /// has reached its eligibility.  Called by the rebalancer after the
    /// move is chosen and the page DMA scheduled, before the new layout
    /// epoch installs — so nothing durable records the aborted migration
    /// and deterministic replay re-makes the identical decision.
    pub fn crash_mid_migration(&self, round: u64) -> Result<()> {
        if let Some(p) = self.plan {
            if p.point == CrashPoint::MidMigration && round >= p.at_round {
                return Err(crash(CrashPoint::MidMigration, round));
            }
        }
        Ok(())
    }

    /// Barrier-time entry point: write a checkpoint if one is due.
    ///
    /// Must be called after the round's epoch rebase, so each shard of
    /// `carried` holds exactly the entries (renumbered `ts = 1..=k`) that
    /// will seed the next round — the prefix recovery replays through
    /// `inject_external`.  `layout` is the cluster engine's versioned
    /// shard-ownership table at the barrier (`None` on the single-device
    /// engine, and accepted as absent by the lenient manifest parser for
    /// pre-versioned checkpoints); recovery restores and verifies it
    /// bit-exactly.  Returns the summary for telemetry, or `None` when no
    /// checkpoint was due.
    pub fn maybe_checkpoint(
        &mut self,
        round: u64,
        t: f64,
        epoch_base: i64,
        carried: &[&[WriteEntry]],
        stmr: &SharedStmr,
        stats_fnv: u64,
        layout: Option<&crate::cluster::shard::LayoutDesc>,
    ) -> Result<Option<CheckpointSummary>> {
        if !self.due(round) {
            return Ok(None);
        }
        // Wall clock is deliberate here: `write_micros` feeds only the
        // `hetm_checkpoint_write_wall_seconds` histogram, which the
        // deterministic snapshot view and perf gates exclude by the
        // "wall" naming convention (DESIGN.md §15).
        // audit:allow(D2, reason = "wall-clock-only checkpoint-write cost; excluded from deterministic snapshots and perf gates")
        let started = std::time::Instant::now();
        let full = self.prev.is_none();
        let mut ranges = std::mem::take(&mut self.ranges);
        ranges.clear();
        if full {
            ranges.push((0, stmr.len()));
        } else {
            self.dirty.dirty_word_ranges_into(&mut ranges);
        }
        let point = self
            .plan
            .filter(|p| round >= p.at_round)
            .map(|p| p.point);

        // --- pages ---------------------------------------------------------
        let mut pages: Vec<u8> = Vec::new();
        let mut dirty_words = 0u64;
        for &(s, e) in &ranges {
            pages.extend_from_slice(&(s as u32).to_le_bytes());
            pages.extend_from_slice(&((e - s) as u32).to_le_bytes());
            for w in s..e {
                pages.extend_from_slice(&stmr.load(w).to_le_bytes());
            }
            dirty_words += (e - s) as u64;
        }
        let pages_sum = fnv1a(&pages);
        pages.extend_from_slice(&pages_sum.to_le_bytes());

        // --- WAL -----------------------------------------------------------
        let mut wal: Vec<u8> = Vec::new();
        wal.extend_from_slice(&(carried.len() as u32).to_le_bytes());
        let mut wal_entries = 0u64;
        for shard in carried {
            wal.extend_from_slice(&(shard.len() as u32).to_le_bytes());
            for e in *shard {
                wal.extend_from_slice(&e.addr.to_le_bytes());
                wal.extend_from_slice(&e.val.to_le_bytes());
                wal.extend_from_slice(&e.ts.to_le_bytes());
            }
            wal_entries += shard.len() as u64;
        }
        let wal_sum = fnv1a(&wal);
        wal.extend_from_slice(&wal_sum.to_le_bytes());

        // --- manifest (built fully before any file is written) -------------
        let pages_name = format!("ckpt-{round:08}.pages");
        let wal_name = format!("ckpt-{round:08}.wal");
        let mut image_sum = 0xcbf2_9ce4_8422_2325u64;
        for w in 0..stmr.len() {
            for b in stmr.load(w).to_le_bytes() {
                image_sum ^= u64::from(b);
                image_sum = image_sum.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        let mut man = String::new();
        man.push_str(MANIFEST_MAGIC);
        man.push('\n');
        man.push_str(&format!("round = {round}\n"));
        match self.prev {
            Some(p) => man.push_str(&format!("prev = {p}\n")),
            None => man.push_str("prev = none\n"),
        }
        man.push_str(&format!("epoch_base = {epoch_base}\n"));
        man.push_str(&format!("t_bits = {:016x}\n", t.to_bits()));
        man.push_str(&format!("n_words = {}\n", stmr.len()));
        man.push_str(&format!("n_shards = {}\n", carried.len()));
        man.push_str(&format!("stats_fnv = {stats_fnv:016x}\n"));
        man.push_str(&format!("stmr_fnv = {image_sum:016x}\n"));
        if let Some(l) = layout {
            // Versioned shard layout (DESIGN.md §14): covered by the
            // trailing whole-manifest checksum like every other line.
            man.push_str(&format!("layout_epoch = {}\n", l.epoch));
            man.push_str(&format!("layout_bits = {}\n", l.shard_bits));
            man.push_str(&format!("layout = {}\n", l.to_rle()));
        }
        man.push_str(&format!(
            "pages = {pages_name} {} {pages_sum:016x}\n",
            pages.len()
        ));
        man.push_str(&format!("wal = {wal_name} {} {wal_sum:016x}\n", wal.len()));
        let man_sum = fnv1a(man.as_bytes());
        let man_full = format!("{man}checksum = {man_sum:016x}\n");
        let man_name = format!("ckpt-{round:08}.manifest");

        // --- write in commit order, tearing at the armed point -------------
        let pages_path = self.dir.join(&pages_name);
        if point == Some(CrashPoint::MidPageWrite) {
            fs::write(&pages_path, &pages[..pages.len() / 2])?;
            return Err(crash(CrashPoint::MidPageWrite, round));
        }
        fs::write(&pages_path, &pages)?;
        if point == Some(CrashPoint::AfterPages) {
            return Err(crash(CrashPoint::AfterPages, round));
        }
        let wal_path = self.dir.join(&wal_name);
        if point == Some(CrashPoint::MidWalAppend) {
            fs::write(&wal_path, &wal[..wal.len() / 2])?;
            return Err(crash(CrashPoint::MidWalAppend, round));
        }
        fs::write(&wal_path, &wal)?;
        if point == Some(CrashPoint::AfterWal) {
            return Err(crash(CrashPoint::AfterWal, round));
        }
        let man_path = self.dir.join(&man_name);
        if point == Some(CrashPoint::MidManifest) {
            fs::write(&man_path, &man.as_bytes()[..man.len() / 2])?;
            return Err(crash(CrashPoint::MidManifest, round));
        }
        fs::write(&man_path, man_full.as_bytes())?;
        match point {
            Some(CrashPoint::CorruptPageByte) => {
                flip_byte(&pages_path)?;
                return Err(crash(CrashPoint::CorruptPageByte, round));
            }
            Some(CrashPoint::CorruptManifestByte) => {
                flip_byte(&man_path)?;
                return Err(crash(CrashPoint::CorruptManifestByte, round));
            }
            Some(CrashPoint::AfterCheckpoint) => {
                return Err(crash(CrashPoint::AfterCheckpoint, round));
            }
            _ => {}
        }

        // --- commit: advance the chain, reset accumulation ------------------
        self.prev = Some(round);
        self.dirty.clear();
        let summary = CheckpointSummary {
            round,
            full,
            bytes: (pages.len() + wal.len() + man_full.len()) as u64,
            extents: ranges.len() as u64,
            dirty_words,
            wal_entries,
            write_micros: started.elapsed().as_micros() as u64,
        };
        self.ranges = ranges;
        Ok(Some(summary))
    }
}

/// XOR the middle byte of `path` with 0xFF (deterministic corruption).
fn flip_byte(path: &Path) -> Result<()> {
    let mut bytes = fs::read(path)?;
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    fs::write(path, &bytes)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Checkpoint loader
// ---------------------------------------------------------------------------

/// A fully-validated checkpoint, reconstructed from its chain.
#[derive(Debug, Clone)]
pub struct LoadedCheckpoint {
    /// Round the checkpoint captured.
    pub round: u64,
    /// Chain link to the previous checkpoint (`None` for a chain base).
    pub prev: Option<u64>,
    /// Epoch base at the barrier (carried-prefix length after rebase).
    pub epoch_base: i64,
    /// Virtual time at the barrier (bit-exact f64).
    pub t: f64,
    /// STMR size in words.
    pub n_words: usize,
    /// `RunStats` digest at the barrier ([`stats_digest`]).
    pub stats_fnv: u64,
    /// Full STMR image, reconstructed base→…→newest.
    pub image: Vec<i32>,
    /// Per-shard carried log at the barrier (the WAL copy).
    pub carried: Vec<Vec<WriteEntry>>,
    /// Versioned shard layout at the barrier (`None` for single-device
    /// checkpoints and pre-versioned manifests).
    pub layout: Option<crate::cluster::shard::LayoutDesc>,
}

struct Manifest {
    round: u64,
    prev: Option<u64>,
    epoch_base: i64,
    t: f64,
    n_words: usize,
    n_shards: usize,
    stats_fnv: u64,
    stmr_fnv: u64,
    pages_name: String,
    pages_len: usize,
    pages_sum: u64,
    wal_name: String,
    wal_len: usize,
    wal_sum: u64,
    layout_epoch: Option<u64>,
    layout_bits: u32,
    layout_rle: Option<String>,
}

fn parse_hex(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad hex {s:?}: {e}"))
}

fn read_manifest(dir: &Path, round: u64) -> Result<Manifest> {
    let path = dir.join(format!("ckpt-{round:08}.manifest"));
    let text = fs::read_to_string(&path)
        .map_err(|e| anyhow!("manifest {}: {e}", path.display()))?;
    // The trailing checksum line is the commit point: recompute FNV over
    // everything before it.
    let idx = text
        .rfind("checksum = ")
        .ok_or_else(|| anyhow!("manifest {round}: no checksum line"))?;
    let declared = parse_hex(
        text[idx + "checksum = ".len()..]
            .trim_end_matches('\n')
            .trim(),
    )?;
    let actual = fnv1a(text[..idx].as_bytes());
    if declared != actual {
        bail!("manifest {round}: checksum mismatch ({declared:016x} != {actual:016x})");
    }
    let mut lines = text.lines();
    if lines.next() != Some(MANIFEST_MAGIC) {
        bail!("manifest {round}: bad magic");
    }
    let mut m = Manifest {
        round: u64::MAX,
        prev: None,
        epoch_base: 0,
        t: 0.0,
        n_words: 0,
        n_shards: 0,
        stats_fnv: 0,
        stmr_fnv: 0,
        pages_name: String::new(),
        pages_len: 0,
        pages_sum: 0,
        wal_name: String::new(),
        wal_len: 0,
        wal_sum: 0,
        layout_epoch: None,
        layout_bits: 0,
        layout_rle: None,
    };
    for line in lines {
        let Some((k, v)) = line.split_once(" = ") else {
            continue;
        };
        match k {
            "round" => m.round = v.parse()?,
            "prev" => m.prev = if v == "none" { None } else { Some(v.parse()?) },
            "epoch_base" => m.epoch_base = v.parse()?,
            "t_bits" => m.t = f64::from_bits(parse_hex(v)?),
            "n_words" => m.n_words = v.parse()?,
            "n_shards" => m.n_shards = v.parse()?,
            "stats_fnv" => m.stats_fnv = parse_hex(v)?,
            "stmr_fnv" => m.stmr_fnv = parse_hex(v)?,
            "layout_epoch" => m.layout_epoch = Some(v.parse()?),
            "layout_bits" => m.layout_bits = v.parse()?,
            "layout" => m.layout_rle = Some(v.to_string()),
            "pages" | "wal" => {
                let mut it = v.split_whitespace();
                let (name, len, sum) = (
                    it.next().ok_or_else(|| anyhow!("manifest {round}: bad {k}"))?,
                    it.next().ok_or_else(|| anyhow!("manifest {round}: bad {k}"))?,
                    it.next().ok_or_else(|| anyhow!("manifest {round}: bad {k}"))?,
                );
                if k == "pages" {
                    m.pages_name = name.to_string();
                    m.pages_len = len.parse()?;
                    m.pages_sum = parse_hex(sum)?;
                } else {
                    m.wal_name = name.to_string();
                    m.wal_len = len.parse()?;
                    m.wal_sum = parse_hex(sum)?;
                }
            }
            _ => {}
        }
    }
    if m.round != round {
        bail!("manifest {round}: names round {}", m.round);
    }
    if m.n_words == 0 || m.pages_name.is_empty() || m.wal_name.is_empty() {
        bail!("manifest {round}: incomplete");
    }
    Ok(m)
}

/// Little-endian field readers for the checkpoint/WAL wire format.
/// Every call site length-checks its record first, so a short slice is
/// file corruption the caller reports as a typed error, never a panic.
fn le_u32(b: &[u8], off: usize) -> Result<u32> {
    match b.get(off..off + 4) {
        Some(s) => Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]])),
        None => bail!("truncated u32 field at byte {off}"),
    }
}

fn le_i32(b: &[u8], off: usize) -> Result<i32> {
    match b.get(off..off + 4) {
        Some(s) => Ok(i32::from_le_bytes([s[0], s[1], s[2], s[3]])),
        None => bail!("truncated i32 field at byte {off}"),
    }
}

fn le_u64(b: &[u8], off: usize) -> Result<u64> {
    match b.get(off..off + 8) {
        Some(s) => Ok(u64::from_le_bytes([
            s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
        ])),
        None => bail!("truncated u64 field at byte {off}"),
    }
}

/// Decode one 12-byte wire entry (`addr: u32, val: i32, ts: i32`, LE).
fn le_entry(b: &[u8], off: usize) -> Result<WriteEntry> {
    Ok(WriteEntry {
        addr: le_u32(b, off)?,
        val: le_i32(b, off + 4)?,
        ts: le_i32(b, off + 8)?,
    })
}

/// Read + checksum-verify a payload file declared by a manifest, returning
/// the bytes *without* the 8-byte FNV trailer.
fn read_payload(dir: &Path, name: &str, declared_len: usize, declared_sum: u64) -> Result<Vec<u8>> {
    let path = dir.join(name);
    let bytes = fs::read(&path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    if bytes.len() != declared_len || bytes.len() < 8 {
        bail!("{name}: size {} != declared {declared_len}", bytes.len());
    }
    let body = &bytes[..bytes.len() - 8];
    let trailer = le_u64(&bytes, bytes.len() - 8)?;
    let sum = fnv1a(body);
    if sum != trailer || sum != declared_sum {
        bail!("{name}: checksum mismatch");
    }
    Ok(body.to_vec())
}

/// Apply a pages payload's extents onto `image`.
fn overlay_pages(image: &mut [i32], body: &[u8]) -> Result<usize> {
    let mut i = 0usize;
    let mut extents = 0usize;
    while i < body.len() {
        if body.len() - i < 8 {
            bail!("pages: truncated extent header");
        }
        let start = le_u32(body, i)? as usize;
        let len = le_u32(body, i + 4)? as usize;
        i += 8;
        if body.len() - i < len * 4 || start + len > image.len() {
            bail!("pages: extent [{start}, +{len}) out of bounds");
        }
        for w in 0..len {
            image[start + w] = le_i32(body, i + 4 * w)?;
        }
        i += len * 4;
        extents += 1;
    }
    Ok(extents)
}

fn parse_wal(body: &[u8], n_shards: usize) -> Result<Vec<Vec<WriteEntry>>> {
    if body.len() < 4 {
        bail!("wal: truncated");
    }
    let declared = le_u32(body, 0)? as usize;
    if declared != n_shards {
        bail!("wal: shard count {declared} != manifest {n_shards}");
    }
    let mut i = 4usize;
    let mut out = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        if body.len() - i < 4 {
            bail!("wal: truncated shard header");
        }
        let n = le_u32(body, i)? as usize;
        i += 4;
        if body.len() - i < n * 12 {
            bail!("wal: truncated entries");
        }
        let mut shard = Vec::with_capacity(n);
        for e in 0..n {
            shard.push(le_entry(body, i + 12 * e)?);
        }
        i += n * 12;
        out.push(shard);
    }
    if i != body.len() {
        bail!("wal: trailing garbage");
    }
    Ok(out)
}

/// Load checkpoint `round` by walking its `prev` chain to the base and
/// overlaying extents oldest-first; every file of every link must
/// validate, and the reconstructed image must match the newest manifest's
/// whole-image checksum.
fn load_chain(dir: &Path, round: u64) -> Result<LoadedCheckpoint> {
    let newest = read_manifest(dir, round)?;
    let mut cur = newest.round;
    let mut prev = newest.prev;
    let mut chain = vec![newest];
    while let Some(p) = prev {
        if p >= cur {
            bail!("checkpoint {cur}: non-decreasing prev link {p}");
        }
        let m = read_manifest(dir, p)?;
        if m.n_words != chain[0].n_words || m.n_shards != chain[0].n_shards {
            bail!("checkpoint {p}: shape differs from {round}");
        }
        cur = m.round;
        prev = m.prev;
        chain.push(m);
    }
    let mut image = vec![0i32; chain[0].n_words];
    for m in chain.iter().rev() {
        let body = read_payload(dir, &m.pages_name, m.pages_len, m.pages_sum)?;
        overlay_pages(&mut image, &body)?;
    }
    let newest = &chain[0];
    let mut sum = 0xcbf2_9ce4_8422_2325u64;
    for w in &image {
        for b in w.to_le_bytes() {
            sum ^= u64::from(b);
            sum = sum.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    if sum != newest.stmr_fnv {
        bail!(
            "checkpoint {round}: reconstructed image checksum {sum:016x} != \
             manifest {:016x} (dirty-page under-approximation?)",
            newest.stmr_fnv
        );
    }
    let wal_body = read_payload(dir, &newest.wal_name, newest.wal_len, newest.wal_sum)?;
    let carried = parse_wal(&wal_body, newest.n_shards)?;
    let layout = match (&newest.layout_rle, newest.layout_epoch) {
        (Some(rle), Some(epoch)) => {
            let owners = crate::cluster::shard::LayoutDesc::parse_rle(rle)
                .ok_or_else(|| anyhow!("checkpoint {round}: malformed layout table"))?;
            let expect = newest
                .n_words
                .div_ceil(1usize << newest.layout_bits.min(usize::BITS - 1));
            if owners.len() != expect {
                bail!(
                    "checkpoint {round}: layout covers {} blocks, expected {expect}",
                    owners.len()
                );
            }
            Some(crate::cluster::shard::LayoutDesc {
                epoch,
                shard_bits: newest.layout_bits,
                owners,
            })
        }
        _ => None,
    };
    Ok(LoadedCheckpoint {
        round: newest.round,
        prev: newest.prev,
        epoch_base: newest.epoch_base,
        t: newest.t,
        n_words: newest.n_words,
        stats_fnv: newest.stats_fnv,
        image,
        carried,
        layout,
    })
}

/// Newest checkpoint in `dir` whose entire chain validates, or `None` if
/// the directory holds no usable checkpoint (missing dir included).
/// Torn/corrupted newer checkpoints are skipped — the fall-back the
/// crash-injection suite exercises at every [`CrashPoint`].
pub fn load_latest(dir: &Path) -> Result<Option<LoadedCheckpoint>> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(None),
    };
    let mut rounds: Vec<u64> = Vec::new();
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(num) = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".manifest"))
        {
            if let Ok(r) = num.parse::<u64>() {
                rounds.push(r);
            }
        }
    }
    rounds.sort_unstable();
    for &r in rounds.iter().rev() {
        if let Ok(ck) = load_chain(dir, r) {
            return Ok(Some(ck));
        }
    }
    Ok(None)
}

// ---------------------------------------------------------------------------
// External-transaction journal
// ---------------------------------------------------------------------------

/// What a journal record replays as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    /// A `Session::txn` injection (entries re-executed through the guest
    /// TM, stats re-injected through `inject_external`).
    Txn,
    /// A `Session::drain` barrier (replayed as a drain).
    Drain,
}

/// One journaled event: `kind` happened after `after_round` rounds had
/// completed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalRecord {
    /// Event kind.
    pub kind: RecordKind,
    /// Rounds completed when the event happened (its replay position).
    pub after_round: u64,
    /// Commits `inject_external` was credited with (txn records).
    pub commits: u64,
    /// Attempts `inject_external` was credited with (txn records).
    pub attempts: u64,
    /// The transaction's committed write-set (empty for read-only txns
    /// and drain records).
    pub entries: Vec<WriteEntry>,
}

/// Append-only write-ahead journal of external events (`external.log` in
/// the checkpoint directory).  Each record carries its own FNV trailer;
/// a torn tail (crash mid-append) is detected and dropped at load.
pub struct ExternalJournal {
    file: fs::File,
}

/// Path of the journal inside a checkpoint directory.
pub fn journal_path(dir: &Path) -> PathBuf {
    dir.join("external.log")
}

fn encode_record(rec: &JournalRecord) -> Vec<u8> {
    let mut b = Vec::with_capacity(37 + rec.entries.len() * 12);
    b.push(match rec.kind {
        RecordKind::Txn => 0u8,
        RecordKind::Drain => 1u8,
    });
    b.extend_from_slice(&rec.after_round.to_le_bytes());
    b.extend_from_slice(&rec.commits.to_le_bytes());
    b.extend_from_slice(&rec.attempts.to_le_bytes());
    b.extend_from_slice(&(rec.entries.len() as u32).to_le_bytes());
    for e in &rec.entries {
        b.extend_from_slice(&e.addr.to_le_bytes());
        b.extend_from_slice(&e.val.to_le_bytes());
        b.extend_from_slice(&e.ts.to_le_bytes());
    }
    let sum = fnv1a(&b);
    b.extend_from_slice(&sum.to_le_bytes());
    b
}

impl ExternalJournal {
    /// Open (append/create) the journal of `dir`, creating `dir` if
    /// needed.
    pub fn open(dir: &Path) -> Result<Self> {
        fs::create_dir_all(dir)
            .map_err(|e| anyhow!("checkpoint dir {}: {e}", dir.display()))?;
        let file = fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(journal_path(dir))
            .map_err(|e| anyhow!("journal {}: {e}", journal_path(dir).display()))?;
        Ok(ExternalJournal { file })
    }

    /// Durably append one record (write + fsync: the journal is the
    /// write-ahead half of the recovery contract).
    pub fn append(&mut self, rec: &JournalRecord) -> Result<()> {
        self.file.write_all(&encode_record(rec))?;
        self.file.sync_data()?;
        Ok(())
    }

    /// Read every intact record of `dir`'s journal, in append order.
    /// Stops at the first torn or checksum-failing record (a crash
    /// mid-append); a missing journal reads as empty.
    pub fn load(dir: &Path) -> Result<Vec<JournalRecord>> {
        let bytes = match fs::read(journal_path(dir)) {
            Ok(b) => b,
            Err(_) => return Ok(Vec::new()),
        };
        let mut out = Vec::new();
        let mut i = 0usize;
        while bytes.len() - i >= 37 {
            let kind = match bytes[i] {
                0 => RecordKind::Txn,
                1 => RecordKind::Drain,
                _ => break,
            };
            let after_round = le_u64(&bytes, i + 1)?;
            let commits = le_u64(&bytes, i + 9)?;
            let attempts = le_u64(&bytes, i + 17)?;
            let n = le_u32(&bytes, i + 25)? as usize;
            let body_len = 29 + n * 12;
            if bytes.len() - i < body_len + 8 {
                break;
            }
            let declared = le_u64(&bytes, i + body_len)?;
            if fnv1a(&bytes[i..i + body_len]) != declared {
                break;
            }
            let mut entries = Vec::with_capacity(n);
            for e in 0..n {
                entries.push(le_entry(&bytes, i + 29 + 12 * e)?);
            }
            out.push(JournalRecord {
                kind,
                after_round,
                commits,
                attempts,
                entries,
            });
            i += body_len + 8;
        }
        Ok(out)
    }

    /// Drop every record at or beyond the recovery horizon (they postdate
    /// the checkpoint being recovered to — the lost tail), rewriting the
    /// journal in place.  Returns the surviving records.
    pub fn truncate_from(dir: &Path, horizon: u64) -> Result<Vec<JournalRecord>> {
        let records = Self::load(dir)?;
        let kept: Vec<JournalRecord> = records
            .into_iter()
            .filter(|r| r.after_round < horizon)
            .collect();
        let mut bytes = Vec::new();
        for r in &kept {
            bytes.extend_from_slice(&encode_record(r));
        }
        fs::write(journal_path(dir), &bytes)?;
        Ok(kept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "shetm-durability-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn entry(addr: u32, val: i32, ts: i32) -> WriteEntry {
        WriteEntry { addr, val, ts }
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Standard FNV-1a 64 vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn crash_point_spellings_round_trip() {
        for p in CrashPoint::ALL {
            assert_eq!(CrashPoint::parse(p.as_str()).unwrap(), p);
        }
        assert!(CrashPoint::parse("nope").is_err());
    }

    #[test]
    fn full_then_incremental_checkpoint_round_trips() {
        let dir = tmpdir("roundtrip");
        let stmr = SharedStmr::new(256);
        for w in 0..256 {
            stmr.store(w, w as i32);
        }
        let mut hook = DurabilityHook::new(&dir, 1, 256, 0, None).unwrap();
        let carried = [entry(3, 30, 1), entry(9, 90, 2)];
        let s1 = hook
            .maybe_checkpoint(1, 0.5, 2, &[&carried], &stmr, 77, None)
            .unwrap()
            .unwrap();
        assert!(s1.full);
        assert_eq!(s1.extents, 1);
        assert_eq!(s1.wal_entries, 2);
        // Round 2 dirties two words.
        stmr.store(5, -5);
        stmr.store(200, -200);
        hook.mark_entries(&[entry(5, -5, 1), entry(200, -200, 2)]);
        let s2 = hook
            .maybe_checkpoint(2, 0.75, 0, &[&[]], &stmr, 78, None)
            .unwrap()
            .unwrap();
        assert!(!s2.full);
        assert_eq!(s2.dirty_words, 2);
        let ck = load_latest(&dir).unwrap().unwrap();
        assert_eq!(ck.round, 2);
        assert_eq!(ck.prev, Some(1));
        assert_eq!(ck.stats_fnv, 78);
        assert_eq!(ck.t.to_bits(), 0.75f64.to_bits());
        assert_eq!(ck.image, stmr.snapshot());
        assert_eq!(ck.carried, vec![Vec::<WriteEntry>::new()]);
    }

    #[test]
    fn torn_newest_falls_back_to_previous_complete() {
        let dir = tmpdir("fallback");
        let stmr = SharedStmr::new(64);
        let mut hook = DurabilityHook::new(&dir, 1, 64, 0, None).unwrap();
        hook.maybe_checkpoint(1, 0.1, 0, &[&[]], &stmr, 1, None)
            .unwrap()
            .unwrap();
        stmr.store(0, 42);
        hook.mark_entries(&[entry(0, 42, 1)]);
        // Round 2's checkpoint tears mid-page-write.
        hook.plan = Some(FaultPlan {
            point: CrashPoint::MidPageWrite,
            at_round: 2,
        });
        let err = hook
            .maybe_checkpoint(2, 0.2, 0, &[&[]], &stmr, 2, None)
            .unwrap_err();
        assert!(is_simulated_crash(&err), "{err}");
        let ck = load_latest(&dir).unwrap().unwrap();
        assert_eq!(ck.round, 1, "torn round 2 must be skipped");
        assert_eq!(ck.image[0], 0, "round 1 predates the store");
    }

    #[test]
    fn every_corruption_is_detected() {
        let dir = tmpdir("corrupt");
        let stmr = SharedStmr::new(64);
        stmr.store(7, 7);
        let mut hook = DurabilityHook::new(&dir, 1, 64, 0, None).unwrap();
        hook.maybe_checkpoint(1, 0.1, 0, &[&[entry(7, 7, 1)]], &stmr, 1, None)
            .unwrap()
            .unwrap();
        for name in ["ckpt-00000001.pages", "ckpt-00000001.wal", "ckpt-00000001.manifest"] {
            let path = dir.join(name);
            let orig = fs::read(&path).unwrap();
            flip_byte(&path).unwrap();
            assert!(
                load_latest(&dir).unwrap().is_none(),
                "corrupted {name} must invalidate the only checkpoint"
            );
            fs::write(&path, &orig).unwrap();
            assert!(load_latest(&dir).unwrap().is_some(), "restored {name}");
        }
    }

    #[test]
    fn journal_round_trips_and_drops_torn_tail() {
        let dir = tmpdir("journal");
        let recs = vec![
            JournalRecord {
                kind: RecordKind::Txn,
                after_round: 1,
                commits: 1,
                attempts: 2,
                entries: vec![entry(4, 44, 3)],
            },
            JournalRecord {
                kind: RecordKind::Drain,
                after_round: 2,
                commits: 0,
                attempts: 0,
                entries: vec![],
            },
            JournalRecord {
                kind: RecordKind::Txn,
                after_round: 3,
                commits: 1,
                attempts: 1,
                entries: vec![],
            },
        ];
        {
            let mut j = ExternalJournal::open(&dir).unwrap();
            for r in &recs {
                j.append(r).unwrap();
            }
        }
        assert_eq!(ExternalJournal::load(&dir).unwrap(), recs);
        // Tear the tail mid-record: only intact prefixes survive.
        let path = journal_path(&dir);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert_eq!(ExternalJournal::load(&dir).unwrap(), recs[..2]);
        // Horizon truncation drops the post-checkpoint tail on disk.
        let kept = ExternalJournal::truncate_from(&dir, 2).unwrap();
        assert_eq!(kept, recs[..1]);
        assert_eq!(ExternalJournal::load(&dir).unwrap(), recs[..1]);
    }

    #[test]
    fn empty_or_missing_dir_loads_none() {
        let dir = tmpdir("empty");
        assert!(load_latest(&dir).unwrap().is_none());
        assert!(load_latest(&dir.join("missing")).unwrap().is_none());
        assert!(ExternalJournal::load(&dir).unwrap().is_empty());
    }
}
