//! The device object: replica state + kernel execution backends.
//!
//! A [`GpuDevice`] owns the GPU-side replica of the STMR, the access
//! bitmaps, the validation timestamp array and the shadow copy used for
//! double buffering and rollback (paper §IV-D).  Batch compute runs either
//! through the PJRT artifacts ([`Backend::Pjrt`]) or the native mirrors
//! ([`Backend::Native`]); both produce identical results (asserted by
//! integration tests), so callers never care which backend is active.

use anyhow::{bail, Context, Result};

use super::bitmap::Bitmap;
use super::native;
use super::{LogChunk, McBatch, TxnBatch};
use crate::runtime::{ArtifactStore, KernelExec, TensorI32};

/// Compute backend selection for a device.
#[derive(Clone)]
pub enum Backend {
    /// Native Rust mirrors (oracle + fast simulation backend).
    Native,
    /// AOT-compiled jax/Pallas kernels through PJRT.
    Pjrt {
        /// Compiled-artifact store.
        store: ArtifactStore,
        /// Artifact name for the transaction-batch kernel (synthetic
        /// workloads), e.g. `prstm_r4_g0`. Empty if unused.
        prstm: String,
        /// Artifact name for the validation kernel, e.g. `validate_synth_g0`.
        validate: String,
        /// Artifact name for the memcached kernel. Empty if unused.
        memcached: String,
    },
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Pjrt {
                prstm,
                validate,
                memcached,
                ..
            } => write!(f, "Pjrt(prstm={prstm}, validate={validate}, mc={memcached})"),
        }
    }
}

/// Outcome of one transaction-batch kernel activation.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Per-transaction commit flags (1 = speculatively committed).
    pub commit: Vec<i32>,
    /// Commits in this activation.
    pub n_commits: u32,
}

/// Outcome of one memcached kernel activation.
#[derive(Debug, Clone)]
pub struct McOutcome {
    /// GET results (-1 for misses/aborts/PUTs).
    pub out_val: Vec<i32>,
    /// Per-request commit flags.
    pub commit: Vec<i32>,
    /// Commits in this activation.
    pub n_commits: u32,
}

/// The simulated accelerator: STMR replica, bitmaps, TS array, shadow copy.
pub struct GpuDevice {
    backend: Backend,
    stmr: Vec<i32>,
    shadow: Vec<i32>,
    ts_arr: Vec<i32>,
    /// Whether `ts_arr` holds any non-zero freshness stamps (skips the
    /// epoch-reset memset on rounds that validated nothing).
    ts_dirty: bool,
    rs_bmp: Bitmap,
    ws_bmp: Bitmap,
    lock_shift: u32,
    /// Thread budget for intra-device parallel chunk validation (set by
    /// the cluster from its `threads` knob; 1 = sequential).
    validate_threads: usize,
    /// Reused scratch for the packed-bitmap → i32-tensor expansion at the
    /// PJRT boundary (steady-state rounds allocate nothing).
    rs_tensor: Vec<i32>,
    ws_tensor: Vec<i32>,
    /// Count of kernel activations (diagnostics / cost accounting).
    pub activations: u64,
}

impl GpuDevice {
    /// Create a device over an `n_words` STMR with the given bitmap
    /// granularity shift and backend.
    pub fn new(n_words: usize, bmp_shift: u32, backend: Backend) -> Self {
        GpuDevice {
            backend,
            stmr: vec![0; n_words],
            shadow: vec![0; n_words],
            ts_arr: vec![0; n_words],
            ts_dirty: false,
            rs_bmp: Bitmap::new(n_words, bmp_shift),
            ws_bmp: Bitmap::new(n_words, bmp_shift),
            lock_shift: 0,
            validate_threads: 1,
            rs_tensor: Vec::new(),
            ws_tensor: Vec::new(),
            activations: 0,
        }
    }

    /// Set the thread budget for intra-device parallel chunk validation.
    /// Only engages on scans large enough to amortize the spawns
    /// ([`native::PAR_VALIDATE_MIN_ENTRIES`]); results are bit-identical
    /// at any budget.
    pub fn set_validate_threads(&mut self, threads: usize) {
        self.validate_threads = threads.max(1);
    }

    /// STMR length in words.
    pub fn n_words(&self) -> usize {
        self.stmr.len()
    }

    /// Read access to the device STMR replica.
    pub fn stmr(&self) -> &[i32] {
        &self.stmr
    }

    /// Mutable access to the device STMR replica (host-initiated state
    /// install, e.g. initial snapshot or merge-phase overwrite).
    pub fn stmr_mut(&mut self) -> &mut Vec<i32> {
        &mut self.stmr
    }

    /// The GPU read-set bitmap of the current round.
    pub fn rs_bmp(&self) -> &Bitmap {
        &self.rs_bmp
    }

    /// The GPU write-set bitmap of the current round.
    pub fn ws_bmp(&self) -> &Bitmap {
        &self.ws_bmp
    }

    /// Begin a synchronization round: snapshot the shadow copy (the
    /// device-to-device copy of §IV-D) and clear the access bitmaps.
    pub fn begin_round(&mut self) {
        self.shadow.copy_from_slice(&self.stmr);
        self.rs_bmp.clear();
        self.ws_bmp.clear();
    }

    /// Execute one speculative transaction batch.
    pub fn run_txn_batch(&mut self, batch: &TxnBatch) -> Result<BatchOutcome> {
        self.activations += 1;
        match &self.backend {
            Backend::Native => {
                let out = native::prstm_step(
                    &mut self.stmr,
                    &mut self.rs_bmp,
                    &mut self.ws_bmp,
                    batch,
                    self.lock_shift,
                );
                Ok(BatchOutcome {
                    commit: out.commit,
                    n_commits: out.n_commits,
                })
            }
            Backend::Pjrt { store, prstm, .. } => {
                let exec = store.get(prstm)?;
                self.check_prstm_shape(exec, batch)?;
                self.rs_bmp.to_tensor_into(&mut self.rs_tensor);
                self.ws_bmp.to_tensor_into(&mut self.ws_tensor);
                let outs = exec.run(&[
                    TensorI32::vec(&self.stmr),
                    TensorI32::vec(&self.rs_tensor),
                    TensorI32::vec(&self.ws_tensor),
                    TensorI32::mat(&batch.read_idx, batch.b, batch.r),
                    TensorI32::mat(&batch.write_idx, batch.b, batch.w),
                    TensorI32::mat(&batch.write_val, batch.b, batch.w),
                    TensorI32::vec(&batch.op),
                    TensorI32::vec(&batch.prio),
                ])?;
                // Outputs: stmr', rs_bmp', ws_bmp', commit, n_commits.
                let [stmr, rs, ws, commit, n]: [Vec<i32>; 5] = outs
                    .try_into()
                    .map_err(|v: Vec<_>| anyhow::anyhow!("prstm arity {}", v.len()))?;
                self.stmr = stmr;
                self.rs_bmp.from_tensor(&rs);
                self.ws_bmp.from_tensor(&ws);
                Ok(BatchOutcome {
                    commit,
                    n_commits: u32::try_from(n[0]).context("negative commit count")?,
                })
            }
        }
    }

    /// Round-boundary epoch reset: clear the freshness timestamp array so
    /// next round's renumbered CPU timestamps (restarting near 1) still
    /// compare fresh.  Pairs with [`crate::stm::GlobalClock::epoch_reset`];
    /// the engines call both after every merge.
    pub fn epoch_reset(&mut self) {
        if self.ts_dirty {
            self.ts_arr.fill(0);
            self.ts_dirty = false;
        }
    }

    /// Validate-and-apply one CPU write-log chunk; returns conflict count.
    pub fn validate_chunk(&mut self, chunk: &LogChunk) -> Result<u32> {
        self.activations += 1;
        self.ts_dirty = true;
        match &self.backend {
            Backend::Native => {
                // SoA split (DESIGN.md §12): read-only conflict scan —
                // fanned over `validate_threads` for oversized chunks —
                // then the in-order freshness-apply pass.
                let n_conf = if self.validate_threads > 1
                    && chunk.addrs.len() >= native::PAR_VALIDATE_MIN_ENTRIES
                {
                    native::conflict_count_par(&self.rs_bmp, &chunk.addrs, self.validate_threads)
                } else {
                    native::conflict_count(&self.rs_bmp, &chunk.addrs)
                };
                native::apply_chunk(&mut self.stmr, &mut self.ts_arr, chunk);
                Ok(n_conf)
            }
            Backend::Pjrt {
                store, validate, ..
            } => {
                let exec = store.get(validate)?;
                let c = exec.meta().param_usize("c")?;
                if chunk.addrs.len() != c {
                    bail!(
                        "validate chunk len {} != artifact c {}",
                        chunk.addrs.len(),
                        c
                    );
                }
                self.rs_bmp.to_tensor_into(&mut self.rs_tensor);
                let outs = exec.run(&[
                    TensorI32::vec(&self.stmr),
                    TensorI32::vec(&self.ts_arr),
                    TensorI32::vec(&self.rs_tensor),
                    TensorI32::vec(&chunk.addrs),
                    TensorI32::vec(&chunk.vals),
                    TensorI32::vec(&chunk.ts),
                ])?;
                let [stmr, ts_arr, n]: [Vec<i32>; 3] = outs
                    .try_into()
                    .map_err(|v: Vec<_>| anyhow::anyhow!("validate arity {}", v.len()))?;
                self.stmr = stmr;
                self.ts_arr = ts_arr;
                Ok(u32::try_from(n[0]).context("negative conflict count")?)
            }
        }
    }

    /// Conflict prefilter (`hetm.chunk_filter`): `true` when the chunk's
    /// signature PROVES it cannot intersect the current read-set bitmap,
    /// so the per-entry validation pass can be skipped and the chunk
    /// applied as a plain scatter.  Conservative: a chunk without a
    /// signature, or whose signature intersects, is never filtered.
    pub fn chunk_provably_clean(&self, chunk: &LogChunk) -> bool {
        match &chunk.sig {
            Some(sig) => !sig.may_intersect(&self.rs_bmp),
            None => false,
        }
    }

    /// Validate a chunk WITHOUT applying it (early validation, §IV-D):
    /// pure bitmap intersection against the current read-set bitmap.
    pub fn early_validate_chunk(&self, chunk: &LogChunk) -> u32 {
        native::conflict_count(&self.rs_bmp, &chunk.addrs)
    }

    /// [`GpuDevice::early_validate_chunk`] for a batch of chunks at once,
    /// fanned across the device's `validate_threads` budget; `out[i]`
    /// receives chunk `i`'s conflict count.  Bit-identical to calling the
    /// scalar form in order (the scan is read-only).
    pub fn early_validate_chunks_into(&self, chunks: &[LogChunk], out: &mut Vec<u32>) {
        native::conflict_counts_into(&self.rs_bmp, chunks, self.validate_threads, out);
    }

    /// Execute one memcached request batch.
    pub fn run_mc_batch(&mut self, batch: &McBatch, n_sets: usize) -> Result<McOutcome> {
        self.activations += 1;
        match &self.backend {
            Backend::Native => {
                let out = native::memcached_step(
                    &mut self.stmr,
                    &mut self.rs_bmp,
                    &mut self.ws_bmp,
                    batch,
                    n_sets,
                );
                Ok(McOutcome {
                    out_val: out.out_val,
                    commit: out.commit,
                    n_commits: out.n_commits,
                })
            }
            Backend::Pjrt {
                store, memcached, ..
            } => {
                let exec = store.get(memcached)?;
                if exec.meta().param_usize("n_sets")? != n_sets {
                    bail!("memcached artifact n_sets mismatch");
                }
                let clk0 = [batch.clk0];
                self.rs_bmp.to_tensor_into(&mut self.rs_tensor);
                self.ws_bmp.to_tensor_into(&mut self.ws_tensor);
                let outs = exec.run(&[
                    TensorI32::vec(&self.stmr),
                    TensorI32::vec(&self.rs_tensor),
                    TensorI32::vec(&self.ws_tensor),
                    TensorI32::vec(&batch.op),
                    TensorI32::vec(&batch.key),
                    TensorI32::vec(&batch.val),
                    TensorI32::scalar(&clk0),
                ])?;
                let [stmr, rs, ws, out_val, commit, n]: [Vec<i32>; 6] = outs
                    .try_into()
                    .map_err(|v: Vec<_>| anyhow::anyhow!("memcached arity {}", v.len()))?;
                self.stmr = stmr;
                self.rs_bmp.from_tensor(&rs);
                self.ws_bmp.from_tensor(&ws);
                Ok(McOutcome {
                    out_val,
                    commit,
                    n_commits: u32::try_from(n[0]).context("negative commit count")?,
                })
            }
        }
    }

    /// Roll back a failed round (favor-CPU policy, §IV-C.3 optimized with
    /// the §IV-D shadow copy): re-align the shadow to the CPU by replaying
    /// the round's CPU logs onto it, then promote it to the working copy.
    ///
    /// `cpu_logs` must be the full set of chunks the CPU shipped this round.
    pub fn rollback_with_logs(&mut self, cpu_logs: &[LogChunk]) {
        self.ts_dirty = true;
        std::mem::swap(&mut self.stmr, &mut self.shadow);
        // Freshness array: the swap discarded validation-phase applies on
        // the working copy; replay brings both the values and the ts_arr
        // to the CPU-aligned state (ts entries are monotonic, so replay
        // with >= reproduces them).
        for chunk in cpu_logs {
            native::apply_chunk(&mut self.stmr, &mut self.ts_arr, chunk);
        }
    }

    /// Sanity-check the batch shape against the PJRT artifact metadata.
    fn check_prstm_shape(&self, exec: &KernelExec, batch: &TxnBatch) -> Result<()> {
        let m = exec.meta();
        if m.param_usize("b")? != batch.b
            || m.param_usize("r")? != batch.r
            || m.param_usize("w")? != batch.w
            || m.param_usize("n")? != self.stmr.len()
        {
            bail!(
                "batch shape (b={}, r={}, w={}, n={}) does not match artifact {}",
                batch.b,
                batch.r,
                batch.w,
                self.stmr.len(),
                m.name
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn device(n: usize) -> GpuDevice {
        GpuDevice::new(n, 0, Backend::Native)
    }

    fn batch_writing(addr: i32, val: i32) -> TxnBatch {
        let mut b = TxnBatch::empty(1, 1, 1);
        b.read_idx = vec![-1];
        b.write_idx = vec![addr];
        b.write_val = vec![val];
        b.op = vec![1];
        b
    }

    #[test]
    fn begin_round_snapshots_shadow_and_clears_bitmaps() {
        let mut d = device(32);
        d.run_txn_batch(&batch_writing(3, 7)).unwrap();
        assert!(d.ws_bmp().test_word(3));
        d.begin_round();
        assert!(d.ws_bmp().is_empty());
        assert_eq!(d.shadow[3], 7);
    }

    #[test]
    fn rollback_discards_gpu_writes_keeps_cpu_logs() {
        let mut d = device(32);
        d.begin_round();
        d.run_txn_batch(&batch_writing(3, 99)).unwrap();
        // CPU log says word 10 = 55 at ts 4.
        let mut chunk = LogChunk::empty(4);
        chunk.addrs[0] = 10;
        chunk.vals[0] = 55;
        chunk.ts[0] = 4;
        d.validate_chunk(&chunk).unwrap();
        d.rollback_with_logs(&[chunk]);
        assert_eq!(d.stmr()[3], 0, "GPU speculative write undone");
        assert_eq!(d.stmr()[10], 55, "CPU write preserved");
        assert_eq!(d.ts_arr[10], 4);
    }

    #[test]
    fn early_validate_counts_without_applying() {
        let mut d = device(32);
        d.begin_round();
        let mut rb = TxnBatch::empty(1, 1, 1);
        rb.read_idx = vec![5];
        rb.write_idx = vec![-1];
        d.run_txn_batch(&rb).unwrap();
        let mut chunk = LogChunk::empty(2);
        chunk.addrs = vec![5, 9];
        chunk.vals = vec![1, 2];
        chunk.ts = vec![1, 1];
        assert_eq!(d.early_validate_chunk(&chunk), 1);
        assert_eq!(d.stmr()[5], 0, "early validation must not apply");
    }

    #[test]
    fn chunk_filter_is_conservative_and_exact_at_matching_shift() {
        let mut d = device(64);
        d.begin_round();
        let mut rb = TxnBatch::empty(1, 1, 1);
        rb.read_idx = vec![40];
        rb.write_idx = vec![-1];
        d.run_txn_batch(&rb).unwrap();
        // Chunk touching only the low half: provably clean.
        let mut low = LogChunk::empty(4);
        low.addrs = vec![3, 7, 3, -1];
        low.build_sig(0);
        assert!(d.chunk_provably_clean(&low));
        assert_eq!(d.early_validate_chunk(&low), 0, "filter agrees with scan");
        // Chunk touching the read word: must not be filtered.
        let mut hot = LogChunk::empty(4);
        hot.addrs = vec![3, 40, -1, -1];
        hot.build_sig(0);
        assert!(!d.chunk_provably_clean(&hot));
        // No signature -> never filtered, however clean.
        let mut bare = LogChunk::empty(2);
        bare.addrs = vec![3, -1];
        assert!(!d.chunk_provably_clean(&bare));
    }

    #[test]
    fn chunk_filter_coarse_sig_stays_conservative() {
        // Device bitmap at word granularity, signature sampled coarser:
        // a near-miss inside the same signature granule must NOT filter.
        let mut d = device(64);
        d.begin_round();
        let mut rb = TxnBatch::empty(1, 1, 1);
        rb.read_idx = vec![9];
        rb.write_idx = vec![-1];
        d.run_txn_batch(&rb).unwrap();
        let mut c = LogChunk::empty(2);
        c.addrs = vec![8, -1]; // same 4-word granule as the read of 9
        c.build_sig(2);
        assert!(!d.chunk_provably_clean(&c), "coarse sig must stay conservative");
        let mut far = LogChunk::empty(2);
        far.addrs = vec![32, -1];
        far.build_sig(2);
        assert!(d.chunk_provably_clean(&far));
    }

    #[test]
    fn validate_after_read_conflict_still_applies() {
        let mut d = device(16);
        d.begin_round();
        let mut rb = TxnBatch::empty(1, 1, 1);
        rb.read_idx = vec![2];
        rb.write_idx = vec![-1];
        d.run_txn_batch(&rb).unwrap();
        let mut chunk = LogChunk::empty(1);
        chunk.addrs = vec![2];
        chunk.vals = vec![77];
        chunk.ts = vec![3];
        let conf = d.validate_chunk(&chunk).unwrap();
        assert_eq!(conf, 1);
        assert_eq!(d.stmr()[2], 77, "paper §IV-C.2: apply despite conflict");
    }
}
