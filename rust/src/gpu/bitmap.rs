//! Access-tracking bitmaps with configurable granularity (paper §IV-B/V-A).
//!
//! The GPU guest TM records, per committed transaction, which *granules*
//! (`1 << shift` STMR words) were read (`RS_bmp`) and written (`WS_bmp`).
//! Coarser granules shrink the bitmap (better locality, ~5% overhead in the
//! paper) at the price of false-positive conflicts — the trade-off Figure 2
//! and our `ablate_granularity` bench quantify.
//!
//! Granules are stored **packed**, 64 per `u64` word, so the whole-bitmap
//! operations the engines lean on (`intersects`, `intersect_count`,
//! `count`, `is_empty`, the dirty-range scans) run word-parallel with
//! `count_ones`/`trailing_zeros` over 1/32nd of the memory the previous
//! one-`i32`-per-granule layout touched (DESIGN.md §12).  The PJRT
//! kernels still consume the flat i32 tensor layout; that interchange is
//! now an explicit boundary — [`Bitmap::to_tensor`] /
//! [`Bitmap::from_tensor`] — instead of a borrowed slice of the native
//! representation.
//!
//! Representation invariant: bits at granule indices `>= len()` in the
//! final storage word are always zero, so the word-parallel scans never
//! need a tail mask.

/// A granule-tracking bitmap over an STMR of `n_words` words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitmap {
    shift: u32,
    n_words: usize,
    /// Number of granule entries (`n_words.div_ceil(1 << shift)`).
    n_granules: usize,
    /// Packed storage: granule `g` lives at bit `g & 63` of `bits[g >> 6]`.
    bits: Vec<u64>,
}

impl Bitmap {
    /// Create an empty bitmap; granularity is `1 << shift` words.
    pub fn new(n_words: usize, shift: u32) -> Self {
        let n_granules = n_words.div_ceil(1 << shift);
        Bitmap {
            shift,
            n_words,
            n_granules,
            bits: vec![0; n_granules.div_ceil(64)],
        }
    }

    /// Granularity shift (granule = `1 << shift` words).
    pub fn shift(&self) -> u32 {
        self.shift
    }

    /// Number of granule entries.
    pub fn len(&self) -> usize {
        self.n_granules
    }

    /// True if no granule is marked.
    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&w| w == 0)
    }

    /// The packed storage words (64 granules per entry; tail bits zero).
    /// Hot loops (`native::validate_step`) hoist this and the shift once
    /// instead of paying the accessor per entry.
    #[inline]
    pub(crate) fn words(&self) -> &[u64] {
        &self.bits
    }

    /// Mark the granule containing `word`.
    #[inline]
    pub fn mark_word(&mut self, word: usize) {
        debug_assert!(word < self.n_words);
        let g = word >> self.shift;
        self.bits[g >> 6] |= 1u64 << (g & 63);
    }

    /// Test the granule containing `word`.
    #[inline]
    pub fn test_word(&self, word: usize) -> bool {
        let g = word >> self.shift;
        self.bits[g >> 6] >> (g & 63) & 1 != 0
    }

    /// Test a granule by index; indices past the end (possible when a
    /// coarser summary rounds a range out) read as unmarked.
    #[inline]
    pub fn test_granule(&self, g: usize) -> bool {
        g < self.n_granules && self.bits[g >> 6] >> (g & 63) & 1 != 0
    }

    /// Whether any granule overlapping the word range `[start, end)` is
    /// marked; the range is clamped to the STMR (chunk-signature probes
    /// may round past the end).
    pub fn any_in_word_range(&self, start: usize, end: usize) -> bool {
        let end = end.min(self.n_words);
        if start >= end {
            return false;
        }
        let g0 = start >> self.shift;
        let g1 = (end - 1) >> self.shift;
        let (w0, w1) = (g0 >> 6, g1 >> 6);
        let head = !0u64 << (g0 & 63);
        let tail = !0u64 >> (63 - (g1 & 63));
        if w0 == w1 {
            return self.bits[w0] & head & tail != 0;
        }
        self.bits[w0] & head != 0
            || self.bits[w1] & tail != 0
            || self.bits[w0 + 1..w1].iter().any(|&w| w != 0)
    }

    /// Mark a granule directly.
    #[inline]
    pub fn mark_granule(&mut self, g: usize) {
        debug_assert!(g < self.n_granules);
        self.bits[g >> 6] |= 1u64 << (g & 63);
    }

    /// Clear all marks (start of a new synchronization round).
    pub fn clear(&mut self) {
        self.bits.fill(0);
    }

    /// Count of marked granules (word-parallel popcount).
    pub fn count(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Expand to the flat i32 tensor layout (one 0/1 entry per granule)
    /// the PJRT kernels consume.  The packed representation never crosses
    /// the artifact boundary; this is the explicit conversion.
    pub fn to_tensor(&self) -> Vec<i32> {
        let mut out = Vec::new();
        self.to_tensor_into(&mut out);
        out
    }

    /// [`Bitmap::to_tensor`] into a caller-reused buffer.
    pub fn to_tensor_into(&self, out: &mut Vec<i32>) {
        out.clear();
        out.resize(self.n_granules, 0);
        for g in self.iter_marked() {
            out[g] = 1;
        }
    }

    /// Replace contents from a kernel output tensor (one entry per
    /// granule; any non-zero value reads as marked).
    pub fn from_tensor(&mut self, data: &[i32]) {
        assert_eq!(data.len(), self.n_granules, "bitmap tensor shape");
        self.bits.fill(0);
        for (g, &v) in data.iter().enumerate() {
            if v != 0 {
                self.bits[g >> 6] |= 1u64 << (g & 63);
            }
        }
    }

    /// Iterate the indices of marked granules in ascending order.
    pub fn iter_marked(&self) -> impl Iterator<Item = usize> + '_ {
        self.bits.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let bit = w.trailing_zeros() as usize;
                w &= w - 1;
                Some((wi << 6) | bit)
            })
        })
    }

    /// Word range `[start, end)` covered by granule `g`, clamped to the STMR.
    pub fn granule_words(&self, g: usize) -> (usize, usize) {
        let start = g << self.shift;
        let end = ((g + 1) << self.shift).min(self.n_words);
        (start, end)
    }

    /// First marked granule at index `>= from`, if any.
    fn next_set(&self, from: usize) -> Option<usize> {
        if from >= self.n_granules {
            return None;
        }
        let mut wi = from >> 6;
        let mut w = self.bits[wi] & (!0u64 << (from & 63));
        loop {
            if w != 0 {
                // Tail bits are always zero, so this is < n_granules.
                return Some((wi << 6) | w.trailing_zeros() as usize);
            }
            wi += 1;
            if wi >= self.bits.len() {
                return None;
            }
            w = self.bits[wi];
        }
    }

    /// First unmarked granule at index `>= from` (clamped to `len()`).
    fn next_clear(&self, from: usize) -> usize {
        let mut wi = from >> 6;
        let mut w = !self.bits[wi] & (!0u64 << (from & 63));
        loop {
            if w != 0 {
                return ((wi << 6) | w.trailing_zeros() as usize).min(self.n_granules);
            }
            wi += 1;
            if wi >= self.bits.len() {
                return self.n_granules;
            }
            w = !self.bits[wi];
        }
    }

    /// Iterate maximal runs of consecutive marked granules as word ranges
    /// `[start, end)` — the transfer-coalescing the paper's GPU-controller
    /// performs in the merge phase (§IV-D).
    pub fn dirty_word_ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.dirty_word_ranges_into(&mut out);
        out
    }

    /// [`Bitmap::dirty_word_ranges`] into a caller-reused buffer (cleared
    /// first), so steady-state merge phases allocate nothing.
    pub fn dirty_word_ranges_into(&self, out: &mut Vec<(usize, usize)>) {
        out.clear();
        let mut g = 0usize;
        while let Some(run_start) = self.next_set(g) {
            let run_end = self.next_clear(run_start);
            out.push((run_start << self.shift, (run_end << self.shift).min(self.n_words)));
            g = run_end;
        }
    }

    /// Total words covered by marked granules.
    pub fn dirty_words(&self) -> usize {
        let mut total = 0usize;
        let mut g = 0usize;
        while let Some(run_start) = self.next_set(g) {
            let run_end = self.next_clear(run_start);
            total += (run_end << self.shift).min(self.n_words) - (run_start << self.shift);
            g = run_end;
        }
        total
    }

    /// Dirty word ranges rounded out to `granule_words` boundaries and
    /// re-coalesced — the paper's merge-phase transfer granularity
    /// (16 KB, §IV-D): fine-grained conflict tracking would otherwise
    /// shatter the DtH copy into thousands of latency-dominated DMAs.
    pub fn dirty_word_ranges_coarse(&self, granule_words: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.dirty_word_ranges_coarse_into(granule_words, &mut out);
        out
    }

    /// [`Bitmap::dirty_word_ranges_coarse`] into a caller-reused buffer
    /// (cleared first).
    pub fn dirty_word_ranges_coarse_into(
        &self,
        granule_words: usize,
        out: &mut Vec<(usize, usize)>,
    ) {
        assert!(granule_words > 0);
        out.clear();
        let mut g = 0usize;
        while let Some(run_start) = self.next_set(g) {
            let run_end = self.next_clear(run_start);
            let s = run_start << self.shift;
            let e = (run_end << self.shift).min(self.n_words);
            let s = (s / granule_words) * granule_words;
            let e = e.div_ceil(granule_words) * granule_words;
            let e = e.min(self.n_words);
            match out.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => out.push((s, e)),
            }
            g = run_end;
        }
    }

    /// Number of granules marked in BOTH bitmaps — the word-level
    /// escalation of the cluster's pairwise cross-shard check (exact at
    /// `shift = 0`, where one granule is one word).  Word-parallel: 64
    /// granules per AND + popcount.
    pub fn intersect_count(&self, other: &Bitmap) -> usize {
        assert_eq!(self.n_granules, other.n_granules, "bitmap shapes differ");
        self.bits
            .iter()
            .zip(&other.bits)
            .map(|(&a, &b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// Whether any marked granule of `self` is also marked in `other`
    /// (bitmap-level intersection; used by early-validation fast paths).
    pub fn intersects(&self, other: &Bitmap) -> bool {
        assert_eq!(self.n_granules, other.n_granules, "bitmap shapes differ");
        self.bits.iter().zip(&other.bits).any(|(&a, &b)| a & b != 0)
    }

    /// OR every granule of `other` into `self` (word-parallel).  Both
    /// bitmaps must share shape AND granularity: with differing shifts
    /// equal granule indices would alias different word ranges, so this
    /// is asserted rather than converted.  Used by the durability layer
    /// to fold per-round device write-sets into the cross-round dirty
    /// accumulator that selects checkpoint pages.
    pub fn union_with(&mut self, other: &Bitmap) {
        assert_eq!(self.n_granules, other.n_granules, "bitmap shapes differ");
        assert_eq!(self.shift, other.shift, "bitmap granularities differ");
        for (a, &b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_and_test_word_granularity() {
        let mut b = Bitmap::new(1024, 0);
        assert!(!b.test_word(5));
        b.mark_word(5);
        assert!(b.test_word(5));
        assert!(!b.test_word(6));
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn coarse_granule_aliases_words() {
        let mut b = Bitmap::new(1024, 4); // 16-word granules
        b.mark_word(17);
        assert!(b.test_word(16));
        assert!(b.test_word(31));
        assert!(!b.test_word(32));
        assert_eq!(b.len(), 64);
    }

    #[test]
    fn non_power_of_two_tail() {
        let b = Bitmap::new(100, 5); // 32-word granules -> 4 entries
        assert_eq!(b.len(), 4);
        assert_eq!(b.granule_words(3), (96, 100));
    }

    #[test]
    fn coarse_ranges_round_out_and_merge() {
        let mut b = Bitmap::new(1 << 14, 0);
        b.mark_word(10);
        b.mark_word(4100); // next 4096-granule
        b.mark_word(9000);
        // 10 -> [0,4096), 4100 -> [4096,8192), 9000 -> [8192,12288):
        // adjacent granule ranges coalesce into one DMA.
        assert_eq!(b.dirty_word_ranges_coarse(4096), vec![(0, 12288)]);
        // Tail clamps to n_words.
        let mut c = Bitmap::new(5000, 0);
        c.mark_word(4999);
        assert_eq!(c.dirty_word_ranges_coarse(4096), vec![(4096, 5000)]);
    }

    #[test]
    fn dirty_ranges_coalesce() {
        let mut b = Bitmap::new(320, 5); // granules of 32 words, 10 entries
        b.mark_granule(1);
        b.mark_granule(2);
        b.mark_granule(5);
        assert_eq!(b.dirty_word_ranges(), vec![(32, 96), (160, 192)]);
        assert_eq!(b.dirty_words(), 96);
    }

    #[test]
    fn dirty_ranges_cross_storage_word_boundaries() {
        // A run spanning the 64-granule packing boundary must stay one
        // range, and adjacent-but-separate runs must stay two.
        let mut b = Bitmap::new(256, 0);
        for g in 60..70 {
            b.mark_granule(g);
        }
        b.mark_granule(128); // exactly on a storage-word boundary
        assert_eq!(b.dirty_word_ranges(), vec![(60, 70), (128, 129)]);
        assert_eq!(b.dirty_words(), 11);
    }

    #[test]
    fn clear_resets() {
        let mut b = Bitmap::new(64, 0);
        b.mark_word(3);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.dirty_word_ranges(), vec![]);
    }

    #[test]
    fn intersect_count_counts_shared_granules() {
        let mut a = Bitmap::new(64, 0);
        let mut b = Bitmap::new(64, 0);
        for w in [1, 5, 9] {
            a.mark_word(w);
        }
        for w in [5, 9, 30] {
            b.mark_word(w);
        }
        assert_eq!(a.intersect_count(&b), 2);
        assert_eq!(b.intersect_count(&a), 2);
        assert_eq!(Bitmap::new(64, 0).intersect_count(&a), 0);
    }

    #[test]
    fn any_in_word_range_clamps_and_tests() {
        let mut b = Bitmap::new(100, 2); // 4-word granules, 25 entries
        b.mark_word(17); // granule 4 -> words [16, 20)
        assert!(b.any_in_word_range(16, 20));
        assert!(b.any_in_word_range(19, 24), "touches granule 4");
        assert!(!b.any_in_word_range(20, 100));
        assert!(b.any_in_word_range(0, 1_000), "end clamps to n_words");
        assert!(!b.any_in_word_range(50, 50), "empty range");
        assert!(b.test_granule(4));
        assert!(!b.test_granule(5));
        assert!(!b.test_granule(10_000), "past-the-end reads unmarked");
    }

    #[test]
    fn any_in_word_range_spans_storage_words() {
        let mut b = Bitmap::new(1 << 10, 0); // 1024 granules, 16 storage words
        b.mark_word(200);
        assert!(b.any_in_word_range(0, 1 << 10));
        assert!(b.any_in_word_range(190, 210));
        assert!(b.any_in_word_range(200, 201));
        assert!(!b.any_in_word_range(0, 200));
        assert!(!b.any_in_word_range(201, 1 << 10));
    }

    #[test]
    fn intersects_detects_overlap() {
        let mut a = Bitmap::new(64, 1);
        let mut b = Bitmap::new(64, 1);
        a.mark_word(10);
        b.mark_word(40);
        assert!(!a.intersects(&b));
        b.mark_word(11); // same granule as 10 (shift 1)
        assert!(a.intersects(&b));
    }

    #[test]
    fn tensor_boundary_round_trips() {
        let mut b = Bitmap::new(200, 1); // 100 granules
        b.mark_granule(0);
        b.mark_granule(63);
        b.mark_granule(64);
        b.mark_granule(99);
        let t = b.to_tensor();
        assert_eq!(t.len(), 100);
        assert_eq!(t.iter().filter(|&&v| v != 0).count(), 4);
        let mut c = Bitmap::new(200, 1);
        c.from_tensor(&t);
        assert_eq!(b, c);
        // Non-zero tensor entries read as marked (kernel outputs may use
        // any non-zero sentinel).
        let mut t2 = vec![0i32; 100];
        t2[7] = 3;
        c.from_tensor(&t2);
        assert!(c.test_granule(7));
        assert_eq!(c.count(), 1);
    }

    #[test]
    fn union_with_ors_and_checks_shape() {
        let mut a = Bitmap::new(300, 1);
        let mut b = Bitmap::new(300, 1);
        a.mark_word(10);
        b.mark_word(10); // shared granule stays a single mark
        b.mark_word(64);
        b.mark_word(299);
        a.union_with(&b);
        let got: Vec<usize> = a.iter_marked().collect();
        assert_eq!(got, vec![5, 32, 149]);
        // The union accumulates across rounds: clearing the source must
        // not clear the accumulator.
        b.clear();
        assert_eq!(a.count(), 3);
    }

    #[test]
    #[should_panic(expected = "granularities differ")]
    fn union_with_rejects_mismatched_shift() {
        let mut a = Bitmap::new(256, 0);
        let b = Bitmap::new(512, 1); // same granule count, different shift
        a.union_with(&b);
    }

    #[test]
    fn iter_marked_is_ascending_and_complete() {
        let mut b = Bitmap::new(300, 0);
        for g in [0usize, 1, 63, 64, 65, 127, 128, 299] {
            b.mark_granule(g);
        }
        let got: Vec<usize> = b.iter_marked().collect();
        assert_eq!(got, vec![0, 1, 63, 64, 65, 127, 128, 299]);
    }
}
