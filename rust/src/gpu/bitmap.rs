//! Access-tracking bitmaps with configurable granularity (paper §IV-B/V-A).
//!
//! The GPU guest TM records, per committed transaction, which *granules*
//! (`1 << shift` STMR words) were read (`RS_bmp`) and written (`WS_bmp`).
//! Coarser granules shrink the bitmap (better locality, ~5% overhead in the
//! paper) at the price of false-positive conflicts — the trade-off Figure 2
//! and our `ablate_granularity` bench quantify.
//!
//! Entries are `i32` 0/1 (not packed bits) to stay layout-identical with
//! the PJRT kernel tensors, letting the device hand its bitmap to the
//! artifact without conversion.

/// A granule-tracking bitmap over an STMR of `n_words` words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitmap {
    shift: u32,
    n_words: usize,
    bits: Vec<i32>,
}

impl Bitmap {
    /// Create an empty bitmap; granularity is `1 << shift` words.
    pub fn new(n_words: usize, shift: u32) -> Self {
        let len = n_words.div_ceil(1 << shift);
        Bitmap {
            shift,
            n_words,
            bits: vec![0; len],
        }
    }

    /// Granularity shift (granule = `1 << shift` words).
    pub fn shift(&self) -> u32 {
        self.shift
    }

    /// Number of granule entries.
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    /// True if no granule is marked.
    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&b| b == 0)
    }

    /// Mark the granule containing `word`.
    #[inline]
    pub fn mark_word(&mut self, word: usize) {
        debug_assert!(word < self.n_words);
        self.bits[word >> self.shift] = 1;
    }

    /// Test the granule containing `word`.
    #[inline]
    pub fn test_word(&self, word: usize) -> bool {
        self.bits[word >> self.shift] != 0
    }

    /// Test a granule by index; indices past the end (possible when a
    /// coarser summary rounds a range out) read as unmarked.
    #[inline]
    pub fn test_granule(&self, g: usize) -> bool {
        g < self.bits.len() && self.bits[g] != 0
    }

    /// Whether any granule overlapping the word range `[start, end)` is
    /// marked; the range is clamped to the STMR (chunk-signature probes
    /// may round past the end).
    pub fn any_in_word_range(&self, start: usize, end: usize) -> bool {
        let end = end.min(self.n_words);
        if start >= end {
            return false;
        }
        let g0 = start >> self.shift;
        let g1 = (end - 1) >> self.shift;
        self.bits[g0..=g1].iter().any(|&b| b != 0)
    }

    /// Mark a granule directly.
    #[inline]
    pub fn mark_granule(&mut self, g: usize) {
        self.bits[g] = 1;
    }

    /// Clear all marks (start of a new synchronization round).
    pub fn clear(&mut self) {
        self.bits.fill(0);
    }

    /// Count of marked granules.
    pub fn count(&self) -> usize {
        self.bits.iter().filter(|&&b| b != 0).count()
    }

    /// Raw tensor view (for the PJRT kernels).
    pub fn as_slice(&self) -> &[i32] {
        &self.bits
    }

    /// Replace contents from a kernel output tensor.
    pub fn set_from_slice(&mut self, data: &[i32]) {
        assert_eq!(data.len(), self.bits.len(), "bitmap tensor shape");
        self.bits.copy_from_slice(data);
    }

    /// Word range `[start, end)` covered by granule `g`, clamped to the STMR.
    pub fn granule_words(&self, g: usize) -> (usize, usize) {
        let start = g << self.shift;
        let end = ((g + 1) << self.shift).min(self.n_words);
        (start, end)
    }

    /// Iterate maximal runs of consecutive marked granules as word ranges
    /// `[start, end)` — the transfer-coalescing the paper's GPU-controller
    /// performs in the merge phase (§IV-D).
    pub fn dirty_word_ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut i = 0usize;
        while i < self.bits.len() {
            if self.bits[i] != 0 {
                let run_start = i;
                while i < self.bits.len() && self.bits[i] != 0 {
                    i += 1;
                }
                let (s, _) = self.granule_words(run_start);
                let (_, e) = self.granule_words(i - 1);
                out.push((s, e));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Total words covered by marked granules.
    pub fn dirty_words(&self) -> usize {
        self.dirty_word_ranges().iter().map(|(s, e)| e - s).sum()
    }

    /// Dirty word ranges rounded out to `granule_words` boundaries and
    /// re-coalesced — the paper's merge-phase transfer granularity
    /// (16 KB, §IV-D): fine-grained conflict tracking would otherwise
    /// shatter the DtH copy into thousands of latency-dominated DMAs.
    pub fn dirty_word_ranges_coarse(&self, granule_words: usize) -> Vec<(usize, usize)> {
        assert!(granule_words > 0);
        let mut out: Vec<(usize, usize)> = Vec::new();
        for (s, e) in self.dirty_word_ranges() {
            let s = (s / granule_words) * granule_words;
            let e = e.div_ceil(granule_words) * granule_words;
            let e = e.min(self.n_words);
            match out.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => out.push((s, e)),
            }
        }
        out
    }

    /// Number of granules marked in BOTH bitmaps — the word-level
    /// escalation of the cluster's pairwise cross-shard check (exact at
    /// `shift = 0`, where one granule is one word).
    pub fn intersect_count(&self, other: &Bitmap) -> usize {
        assert_eq!(self.bits.len(), other.bits.len(), "bitmap shapes differ");
        self.bits
            .iter()
            .zip(&other.bits)
            .filter(|&(&a, &b)| a != 0 && b != 0)
            .count()
    }

    /// Whether any marked granule of `self` is also marked in `other`
    /// (bitmap-level intersection; used by early-validation fast paths).
    pub fn intersects(&self, other: &Bitmap) -> bool {
        assert_eq!(self.bits.len(), other.bits.len(), "bitmap shapes differ");
        self.bits
            .iter()
            .zip(&other.bits)
            .any(|(&a, &b)| a != 0 && b != 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_and_test_word_granularity() {
        let mut b = Bitmap::new(1024, 0);
        assert!(!b.test_word(5));
        b.mark_word(5);
        assert!(b.test_word(5));
        assert!(!b.test_word(6));
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn coarse_granule_aliases_words() {
        let mut b = Bitmap::new(1024, 4); // 16-word granules
        b.mark_word(17);
        assert!(b.test_word(16));
        assert!(b.test_word(31));
        assert!(!b.test_word(32));
        assert_eq!(b.len(), 64);
    }

    #[test]
    fn non_power_of_two_tail() {
        let b = Bitmap::new(100, 5); // 32-word granules -> 4 entries
        assert_eq!(b.len(), 4);
        assert_eq!(b.granule_words(3), (96, 100));
    }

    #[test]
    fn coarse_ranges_round_out_and_merge() {
        let mut b = Bitmap::new(1 << 14, 0);
        b.mark_word(10);
        b.mark_word(4100); // next 4096-granule
        b.mark_word(9000);
        // 10 -> [0,4096), 4100 -> [4096,8192), 9000 -> [8192,12288):
        // adjacent granule ranges coalesce into one DMA.
        assert_eq!(b.dirty_word_ranges_coarse(4096), vec![(0, 12288)]);
        // Tail clamps to n_words.
        let mut c = Bitmap::new(5000, 0);
        c.mark_word(4999);
        assert_eq!(c.dirty_word_ranges_coarse(4096), vec![(4096, 5000)]);
    }

    #[test]
    fn dirty_ranges_coalesce() {
        let mut b = Bitmap::new(320, 5); // granules of 32 words, 10 entries
        b.mark_granule(1);
        b.mark_granule(2);
        b.mark_granule(5);
        assert_eq!(b.dirty_word_ranges(), vec![(32, 96), (160, 192)]);
        assert_eq!(b.dirty_words(), 96);
    }

    #[test]
    fn clear_resets() {
        let mut b = Bitmap::new(64, 0);
        b.mark_word(3);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.dirty_word_ranges(), vec![]);
    }

    #[test]
    fn intersect_count_counts_shared_granules() {
        let mut a = Bitmap::new(64, 0);
        let mut b = Bitmap::new(64, 0);
        for w in [1, 5, 9] {
            a.mark_word(w);
        }
        for w in [5, 9, 30] {
            b.mark_word(w);
        }
        assert_eq!(a.intersect_count(&b), 2);
        assert_eq!(b.intersect_count(&a), 2);
        assert_eq!(Bitmap::new(64, 0).intersect_count(&a), 0);
    }

    #[test]
    fn any_in_word_range_clamps_and_tests() {
        let mut b = Bitmap::new(100, 2); // 4-word granules, 25 entries
        b.mark_word(17); // granule 4 -> words [16, 20)
        assert!(b.any_in_word_range(16, 20));
        assert!(b.any_in_word_range(19, 24), "touches granule 4");
        assert!(!b.any_in_word_range(20, 100));
        assert!(b.any_in_word_range(0, 1_000), "end clamps to n_words");
        assert!(!b.any_in_word_range(50, 50), "empty range");
        assert!(b.test_granule(4));
        assert!(!b.test_granule(5));
        assert!(!b.test_granule(10_000), "past-the-end reads unmarked");
    }

    #[test]
    fn intersects_detects_overlap() {
        let mut a = Bitmap::new(64, 1);
        let mut b = Bitmap::new(64, 1);
        a.mark_word(10);
        b.mark_word(40);
        assert!(!a.intersects(&b));
        b.mark_word(11); // same granule as 10 (shift 1)
        assert!(a.intersects(&b));
    }
}
