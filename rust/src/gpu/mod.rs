//! The simulated discrete accelerator ("GPU") device.
//!
//! The paper runs its GPU side on an NVIDIA GTX 1080 with PR-STM as the
//! guest TM.  Here the device is a software construct that preserves the
//! architectural role the SHeTM design depends on (DESIGN.md §2):
//!
//! * it executes transactions **in large batches**, data-parallel, with
//!   PR-STM-style priority-rule conflict resolution;
//! * it owns a **full local replica** of the STMR plus the read/write-set
//!   bitmaps and the validation timestamp array;
//! * it is reachable only through the [`crate::bus`] model, never by direct
//!   memory access.
//!
//! Batch compute has two interchangeable backends:
//! [`Backend::Pjrt`] executes the AOT-compiled jax/Pallas artifacts through
//! the PJRT runtime (the production path), and [`Backend::Native`] is a
//! bit-exact Rust mirror used as a correctness oracle and as the fast path
//! for large simulation sweeps.  Integration tests assert the two agree.

pub mod bitmap;
pub mod device;
pub mod native;

pub use bitmap::Bitmap;
pub use device::{Backend, BatchOutcome, GpuDevice, McOutcome};

/// One batch of synthetic transactions, laid out exactly like the PJRT
/// kernel inputs: row-major `[b, r]` / `[b, w]` index matrices with `-1`
/// padding.
#[derive(Debug, Clone)]
pub struct TxnBatch {
    /// Transactions in the batch.
    pub b: usize,
    /// Reads per transaction (matrix width; pad unused slots with -1).
    pub r: usize,
    /// Writes per transaction (matrix width; pad unused slots with -1).
    pub w: usize,
    /// Read word-indices, `b * r` row-major.
    pub read_idx: Vec<i32>,
    /// Write word-indices, `b * w` row-major; within one transaction the
    /// non-padding entries must be distinct (scatter determinism).
    pub write_idx: Vec<i32>,
    /// Values for each write slot, `b * w` row-major.
    pub write_val: Vec<i32>,
    /// Per-transaction write mode: 0 = add, 1 = store.
    pub op: Vec<i32>,
    /// Per-transaction priority; must be unique and non-negative.
    pub prio: Vec<i32>,
}

impl TxnBatch {
    /// An empty (all-padding) batch of the given shape.
    pub fn empty(b: usize, r: usize, w: usize) -> Self {
        TxnBatch {
            b,
            r,
            w,
            read_idx: vec![-1; b * r],
            write_idx: vec![-1; b * w],
            write_val: vec![0; b * w],
            op: vec![0; b],
            prio: (0..b as i32).collect(),
        }
    }

    /// Number of non-padding transactions (those with at least one access).
    pub fn live_txns(&self) -> usize {
        (0..self.b)
            .filter(|&i| {
                self.read_idx[i * self.r..(i + 1) * self.r]
                    .iter()
                    .chain(&self.write_idx[i * self.w..(i + 1) * self.w])
                    .any(|&a| a >= 0)
            })
            .count()
    }
}

/// Cheap conservative summary of a [`LogChunk`]'s address footprint:
/// the address min/max plus a packed granule bitmap sampled at the
/// device bitmap's granularity shift.  The validation phase tests it
/// against the GPU read-set bitmap and skips the per-entry pass when the
/// signature PROVES the chunk cannot intersect — the signature-based
/// conflict prefiltering of limited-read/write-set HTMs, applied to
/// SHeTM's log shipping.  False positives (signature intersects, entries
/// do not) only cost the ordinary per-entry pass; false negatives are
/// impossible because every live address is represented at a granularity
/// at least as coarse as the read-set bitmap tests at.
#[derive(Debug, Clone)]
pub struct ChunkSig {
    /// Granule shift the signature was sampled at: the requested shift,
    /// coarsened as needed so the packed bitmap stays within
    /// [`ChunkSig::MAX_GRANULES`] (wide-range chunks — e.g. a shard's
    /// block-cyclic stripe — would otherwise blow the summary up to the
    /// size of the full bitmap).
    shift: u32,
    /// First granule index the packed bitmap covers.
    g0: usize,
    /// Packed bits over granules `[g0, g0 + 64 * bits.len())`.
    bits: Vec<u64>,
    /// Smallest live address in the chunk.
    min_addr: u32,
    /// Largest live address in the chunk.
    max_addr: u32,
}

impl ChunkSig {
    /// Upper bound on signature granules (4096 bits = 512 B packed): the
    /// summary stays ~1% of a 48 KB chunk, so its wire footprint is
    /// legitimately ignored by the cost model, like the chunk header.
    pub const MAX_GRANULES: usize = 4096;

    /// Summarize a set of live addresses at granule shift `shift` (the
    /// shift is coarsened until the spanned range fits
    /// [`Self::MAX_GRANULES`], which stays conservative); `None` for an
    /// empty chunk (nothing to validate, nothing to prove).
    pub fn from_addrs(addrs: impl Iterator<Item = u32> + Clone, shift: u32) -> Option<Self> {
        let mut min_addr = u32::MAX;
        let mut max_addr = 0u32;
        let mut any = false;
        for a in addrs.clone() {
            any = true;
            min_addr = min_addr.min(a);
            max_addr = max_addr.max(a);
        }
        if !any {
            return None;
        }
        let mut shift = shift;
        while ((max_addr >> shift) - (min_addr >> shift)) as usize >= Self::MAX_GRANULES {
            shift += 1;
        }
        let g0 = (min_addr >> shift) as usize;
        let g1 = (max_addr >> shift) as usize;
        let mut bits = vec![0u64; (g1 - g0) / 64 + 1];
        for a in addrs {
            let g = (a >> shift) as usize - g0;
            bits[g / 64] |= 1u64 << (g % 64);
        }
        Some(ChunkSig {
            shift,
            g0,
            bits,
            min_addr,
            max_addr,
        })
    }

    /// Address range `[min, max]` covered by the signature.
    pub fn addr_range(&self) -> (u32, u32) {
        (self.min_addr, self.max_addr)
    }

    /// Granule shift the signature was sampled at.
    pub fn shift(&self) -> u32 {
        self.shift
    }

    /// Conservative intersection test against an access bitmap: `false`
    /// PROVES that no live address of the summarized chunk falls in a
    /// marked granule of `bmp`.  Exact (and O(set bits)) when
    /// `self.shift == bmp.shift()` — the way the engines build it;
    /// otherwise each signature granule probes its whole word range,
    /// which stays conservative.
    pub fn may_intersect(&self, bmp: &Bitmap) -> bool {
        for (wi, &w) in self.bits.iter().enumerate() {
            let mut w = w;
            while w != 0 {
                let bit = w.trailing_zeros() as usize;
                w &= w - 1;
                let g = self.g0 + wi * 64 + bit;
                let hit = if self.shift == bmp.shift() {
                    bmp.test_granule(g)
                } else {
                    bmp.any_in_word_range(g << self.shift, (g + 1) << self.shift)
                };
                if hit {
                    return true;
                }
            }
        }
        false
    }
}

/// One chunk of the CPU write-set log, as shipped to the device for
/// validation (paper §IV-C.2). Fixed length; pad with `addr = -1`.
#[derive(Debug, Clone)]
pub struct LogChunk {
    /// Logged word addresses (-1 = padding).
    pub addrs: Vec<i32>,
    /// Values written.
    pub vals: Vec<i32>,
    /// Commit timestamps (global CPU clock).
    pub ts: Vec<i32>,
    /// Optional conflict-prefilter signature (`hetm.chunk_filter`); rides
    /// along on the wire.  Its packed size is bounded at
    /// [`ChunkSig::MAX_GRANULES`] bits (512 B, ~1% of the 48 KB chunk),
    /// so the cost model ignores it, like the chunk header.
    pub sig: Option<ChunkSig>,
}

impl LogChunk {
    /// An all-padding chunk of length `c`.
    pub fn empty(c: usize) -> Self {
        LogChunk {
            addrs: vec![-1; c],
            vals: vec![0; c],
            ts: vec![0; c],
            sig: None,
        }
    }

    /// (Re)build the conflict-prefilter signature from the live entries
    /// at granule shift `shift`.
    pub fn build_sig(&mut self, shift: u32) {
        self.sig = ChunkSig::from_addrs(
            self.addrs.iter().filter(|&&a| a >= 0).map(|&a| a as u32),
            shift,
        );
    }

    /// Number of live (non-padding) entries.
    pub fn live(&self) -> usize {
        self.addrs.iter().filter(|&&a| a >= 0).count()
    }

    /// Bytes this chunk occupies on the bus (addr + val + ts per entry —
    /// the paper's 12-byte log record).
    pub fn wire_bytes(&self) -> u64 {
        (self.addrs.len() * 12) as u64
    }
}

/// One batch of memcached GET/PUT requests (paper §V-D).
#[derive(Debug, Clone)]
pub struct McBatch {
    /// 0 = GET, 1 = PUT, per request.
    pub op: Vec<i32>,
    /// Request keys.
    pub key: Vec<i32>,
    /// PUT values (ignored for GETs).
    pub val: Vec<i32>,
    /// Device-local LRU clock base for this activation.
    pub clk0: i32,
}

impl McBatch {
    /// An all-GET batch with sentinel keys (used for padding).
    pub fn empty(q: usize) -> Self {
        McBatch {
            op: vec![0; q],
            key: vec![0; q],
            val: vec![0; q],
            clk0: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_sig_coarsens_wide_ranges_within_bound() {
        // A chunk spanning the whole region (block-cyclic shard stripes
        // produce these) must coarsen instead of allocating a packed
        // bitmap the size of the device bitmap.
        let n = 1usize << 18;
        let sig = ChunkSig::from_addrs([0u32, (n - 1) as u32].into_iter(), 0).unwrap();
        assert!(sig.shift() > 0, "wide range must coarsen");
        assert!(
            sig.bits.len() * 64 <= ChunkSig::MAX_GRANULES,
            "packed size bounded: {} granules",
            sig.bits.len() * 64
        );
        assert_eq!(sig.addr_range(), (0, (n - 1) as u32));
        // Coarse signatures stay conservative: a read in the same coarse
        // granule as a live address must block filtering...
        let mut near = Bitmap::new(n, 0);
        near.mark_word(13); // same coarse granule as address 0
        assert!(sig.may_intersect(&near));
        // ...while granules the chunk provably never touches test clean.
        let mut far = Bitmap::new(n, 0);
        far.mark_word(n / 2);
        assert!(!sig.may_intersect(&far));
    }

    #[test]
    fn chunk_sig_empty_and_exact_shift() {
        assert!(ChunkSig::from_addrs(std::iter::empty(), 0).is_none());
        let sig = ChunkSig::from_addrs([4u32, 5, 4].into_iter(), 1).unwrap();
        assert_eq!(sig.shift(), 1, "narrow ranges keep the requested shift");
        let mut bmp = Bitmap::new(64, 1);
        bmp.mark_word(5);
        assert!(sig.may_intersect(&bmp));
        bmp.clear();
        bmp.mark_word(40);
        assert!(!sig.may_intersect(&bmp));
    }
}
