//! The simulated discrete accelerator ("GPU") device.
//!
//! The paper runs its GPU side on an NVIDIA GTX 1080 with PR-STM as the
//! guest TM.  Here the device is a software construct that preserves the
//! architectural role the SHeTM design depends on (DESIGN.md §2):
//!
//! * it executes transactions **in large batches**, data-parallel, with
//!   PR-STM-style priority-rule conflict resolution;
//! * it owns a **full local replica** of the STMR plus the read/write-set
//!   bitmaps and the validation timestamp array;
//! * it is reachable only through the [`crate::bus`] model, never by direct
//!   memory access.
//!
//! Batch compute has two interchangeable backends:
//! [`Backend::Pjrt`] executes the AOT-compiled jax/Pallas artifacts through
//! the PJRT runtime (the production path), and [`Backend::Native`] is a
//! bit-exact Rust mirror used as a correctness oracle and as the fast path
//! for large simulation sweeps.  Integration tests assert the two agree.

pub mod bitmap;
pub mod device;
pub mod native;

pub use bitmap::Bitmap;
pub use device::{Backend, BatchOutcome, GpuDevice, McOutcome};

/// One batch of synthetic transactions, laid out exactly like the PJRT
/// kernel inputs: row-major `[b, r]` / `[b, w]` index matrices with `-1`
/// padding.
#[derive(Debug, Clone)]
pub struct TxnBatch {
    /// Transactions in the batch.
    pub b: usize,
    /// Reads per transaction (matrix width; pad unused slots with -1).
    pub r: usize,
    /// Writes per transaction (matrix width; pad unused slots with -1).
    pub w: usize,
    /// Read word-indices, `b * r` row-major.
    pub read_idx: Vec<i32>,
    /// Write word-indices, `b * w` row-major; within one transaction the
    /// non-padding entries must be distinct (scatter determinism).
    pub write_idx: Vec<i32>,
    /// Values for each write slot, `b * w` row-major.
    pub write_val: Vec<i32>,
    /// Per-transaction write mode: 0 = add, 1 = store.
    pub op: Vec<i32>,
    /// Per-transaction priority; must be unique and non-negative.
    pub prio: Vec<i32>,
}

impl TxnBatch {
    /// An empty (all-padding) batch of the given shape.
    pub fn empty(b: usize, r: usize, w: usize) -> Self {
        TxnBatch {
            b,
            r,
            w,
            read_idx: vec![-1; b * r],
            write_idx: vec![-1; b * w],
            write_val: vec![0; b * w],
            op: vec![0; b],
            prio: (0..b as i32).collect(),
        }
    }

    /// Number of non-padding transactions (those with at least one access).
    pub fn live_txns(&self) -> usize {
        (0..self.b)
            .filter(|&i| {
                self.read_idx[i * self.r..(i + 1) * self.r]
                    .iter()
                    .chain(&self.write_idx[i * self.w..(i + 1) * self.w])
                    .any(|&a| a >= 0)
            })
            .count()
    }
}

/// One chunk of the CPU write-set log, as shipped to the device for
/// validation (paper §IV-C.2). Fixed length; pad with `addr = -1`.
#[derive(Debug, Clone)]
pub struct LogChunk {
    /// Logged word addresses (-1 = padding).
    pub addrs: Vec<i32>,
    /// Values written.
    pub vals: Vec<i32>,
    /// Commit timestamps (global CPU clock).
    pub ts: Vec<i32>,
}

impl LogChunk {
    /// An all-padding chunk of length `c`.
    pub fn empty(c: usize) -> Self {
        LogChunk {
            addrs: vec![-1; c],
            vals: vec![0; c],
            ts: vec![0; c],
        }
    }

    /// Number of live (non-padding) entries.
    pub fn live(&self) -> usize {
        self.addrs.iter().filter(|&&a| a >= 0).count()
    }

    /// Bytes this chunk occupies on the bus (addr + val + ts per entry —
    /// the paper's 12-byte log record).
    pub fn wire_bytes(&self) -> u64 {
        (self.addrs.len() * 12) as u64
    }
}

/// One batch of memcached GET/PUT requests (paper §V-D).
#[derive(Debug, Clone)]
pub struct McBatch {
    /// 0 = GET, 1 = PUT, per request.
    pub op: Vec<i32>,
    /// Request keys.
    pub key: Vec<i32>,
    /// PUT values (ignored for GETs).
    pub val: Vec<i32>,
    /// Device-local LRU clock base for this activation.
    pub clk0: i32,
}

impl McBatch {
    /// An all-GET batch with sentinel keys (used for padding).
    pub fn empty(q: usize) -> Self {
        McBatch {
            op: vec![0; q],
            key: vec![0; q],
            val: vec![0; q],
            clk0: 0,
        }
    }
}
