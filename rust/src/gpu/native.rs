//! Native Rust mirrors of the three device kernels.
//!
//! These implement EXACTLY the semantics of the jax/Pallas kernels in
//! `python/compile/` (and of `kernels/ref.py`): integration tests execute
//! both backends on identical inputs and assert bit-equality.  They also
//! serve as the fast backend for large simulation sweeps.
//!
//! Keep every semantic detail in sync with `python/compile/model.py`:
//! wrap-around i32 adds, `WS ⊆ RS` bitmap marking, freshness `>=` with
//! later-position tie-break, and the memcached arbitration rules.

use super::bitmap::Bitmap;
use super::{LogChunk, McBatch, TxnBatch};

/// Unclaimed-lock sentinel (i32::MAX), matching the kernels' `INF`.
pub const INF: i32 = i32::MAX;

/// Memcached layout constants (keep in sync with `kernels/common.py`).
pub mod mc {
    /// Slots per set (8-way associative, as in the paper).
    pub const WAYS: usize = 8;
    /// Word offset of the key row inside a set.
    pub const OFF_KEYS: usize = 0;
    /// Word offset of the value row.
    pub const OFF_VALS: usize = 8;
    /// Word offset of the CPU-device LRU timestamp row.
    pub const OFF_TS_CPU: usize = 16;
    /// Word offset of the GPU-device LRU timestamp row.
    pub const OFF_TS_GPU: usize = 24;
    /// Word offset of the per-set timestamp (the shared conflict word).
    pub const OFF_SET_TS: usize = 32;
    /// Words per set.
    pub const WORDS_PER_SET: usize = 33;
    /// Knuth multiplicative hash constant.
    pub const HASH_MULT: u32 = 2654435761;

    /// Hash a key to its set index (`n_sets` must be a power of two).
    ///
    /// Parity-preserving: the set's last bit equals the key's last bit,
    /// so key-parity load balancing yields device-disjoint sets (§V-D).
    #[inline]
    pub fn hash(key: i32, n_sets: usize) -> usize {
        debug_assert!(n_sets.is_power_of_two());
        let h = (key as u32).wrapping_mul(HASH_MULT) >> 7;
        let s = (h << 1) | (key as u32 & 1);
        (s as usize) & (n_sets - 1)
    }
}

/// Outcome of a native PR-STM batch step.
#[derive(Debug, Clone)]
pub struct PrstmOutput {
    /// 1 = transaction committed, 0 = priority-rule abort.
    pub commit: Vec<i32>,
    /// Number of commits.
    pub n_commits: u32,
}

/// PR-STM batch step: priority-rule arbitration, apply, bitmap updates.
/// Mirrors `model.prstm_step`.
pub fn prstm_step(
    stmr: &mut [i32],
    rs_bmp: &mut Bitmap,
    ws_bmp: &mut Bitmap,
    batch: &TxnBatch,
    lock_shift: u32,
) -> PrstmOutput {
    prstm_step_inner(stmr, Some((rs_bmp, ws_bmp)), batch, lock_shift)
}

/// PR-STM batch step WITHOUT SHeTM's bitmap instrumentation — the
/// "un-instrumented PR-STM" baseline of Figure 2 (left): the guest GPU TM
/// running solo, with no access tracking for inter-device validation.
pub fn prstm_step_uninstrumented(
    stmr: &mut [i32],
    batch: &TxnBatch,
    lock_shift: u32,
) -> PrstmOutput {
    prstm_step_inner(stmr, None, batch, lock_shift)
}

// Per-thread epoch-stamped lock table: a dense array reused across every
// batch on the thread.  Entries are `(epoch << 32) | prio`; a stale epoch
// means "unclaimed", so the table never needs clearing — replacing the old
// per-batch HashMap cut the native kernel cost ~2x (§Perf L3b,
// EXPERIMENTS.md).
thread_local! {
    static LOCK_TBL: std::cell::RefCell<(Vec<u64>, u32)> =
        const { std::cell::RefCell::new((Vec::new(), 0)) };
}

fn prstm_step_inner(
    stmr: &mut [i32],
    bitmaps: Option<(&mut Bitmap, &mut Bitmap)>,
    batch: &TxnBatch,
    lock_shift: u32,
) -> PrstmOutput {
    let (b, r, w) = (batch.b, batch.r, batch.w);
    debug_assert_eq!(batch.read_idx.len(), b * r);
    debug_assert_eq!(batch.write_idx.len(), b * w);

    let n_lock = stmr.len() >> lock_shift;
    let (mut tbl, epoch) = LOCK_TBL.with(|t| {
        let mut t = t.borrow_mut();
        if t.0.len() < n_lock + 1 {
            t.0 = vec![0u64; n_lock + 1];
            t.1 = 0;
        }
        t.1 = t.1.wrapping_add(1);
        if t.1 == 0 {
            t.0.fill(0);
            t.1 = 1;
        }
        (std::mem::take(&mut t.0), t.1)
    });

    // Lock acquisition: min priority per written granule.
    let stamp = (epoch as u64) << 32;
    for i in 0..b {
        let p = batch.prio[i] as u32 as u64;
        for &a in &batch.write_idx[i * w..(i + 1) * w] {
            if a >= 0 {
                let g = (a as usize) >> lock_shift;
                let cur = tbl[g];
                if cur >> 32 != epoch as u64 || (cur & 0xFFFF_FFFF) > p {
                    tbl[g] = stamp | p;
                }
            }
        }
    }

    let tbl_ref = &tbl;
    let holder = move |a: i32| -> i32 {
        let cur = tbl_ref[(a as usize) >> lock_shift];
        if cur >> 32 == epoch as u64 {
            (cur & 0xFFFF_FFFF) as i32
        } else {
            INF
        }
    };

    let mut commit = vec![0i32; b];
    let mut n_commits = 0u32;
    for i in 0..b {
        let p = batch.prio[i];
        let owns = batch.write_idx[i * w..(i + 1) * w]
            .iter()
            .all(|&a| a < 0 || holder(a) == p);
        // PR-STM priority rule: a read is valid unless an EARLIER
        // (lower-priority) transaction writes it; INF covers "unclaimed".
        let reads_ok = batch.read_idx[i * r..(i + 1) * r]
            .iter()
            .all(|&a| a < 0 || holder(a) >= p);
        if owns && reads_ok {
            commit[i] = 1;
            n_commits += 1;
        }
    }

    let mut bitmaps = bitmaps;
    for i in 0..b {
        if commit[i] == 0 {
            continue;
        }
        for j in 0..w {
            let a = batch.write_idx[i * w + j];
            if a < 0 {
                continue;
            }
            let v = batch.write_val[i * w + j];
            let cell = &mut stmr[a as usize];
            *cell = if batch.op[i] == 0 { cell.wrapping_add(v) } else { v };
        }
        if let Some((rs_bmp, ws_bmp)) = bitmaps.as_mut() {
            for &a in &batch.read_idx[i * r..(i + 1) * r] {
                if a >= 0 {
                    rs_bmp.mark_word(a as usize);
                }
            }
            for &a in &batch.write_idx[i * w..(i + 1) * w] {
                if a >= 0 {
                    // WS ⊆ RS: one test covers WW and RW conflicts.
                    rs_bmp.mark_word(a as usize);
                    ws_bmp.mark_word(a as usize);
                }
            }
        }
    }

    LOCK_TBL.with(|t| t.borrow_mut().0 = tbl);
    PrstmOutput { commit, n_commits }
}

/// Validate-and-apply one CPU log chunk against the device state.
/// Mirrors `model.validate_step`; returns the number of conflicting entries.
///
/// Split into two flat-slice passes (DESIGN.md §12): the read-only
/// conflict scan touches only the packed read-set bitmap (32 KB for a
/// 2^18-word STMR, L1-resident) while the freshness-apply pass touches
/// only `ts_arr`/`stmr` — the interleaved loop used to drag all three
/// arrays through the cache per entry.  Bit-identical to the interleaved
/// form: the conflict test never reads `ts_arr`/`stmr` and the apply
/// never reads the bitmap.
pub fn validate_step(
    stmr: &mut [i32],
    ts_arr: &mut [i32],
    rs_bmp: &Bitmap,
    chunk: &LogChunk,
) -> u32 {
    let n_conf = conflict_count(rs_bmp, &chunk.addrs);
    apply_chunk(stmr, ts_arr, chunk);
    n_conf
}

/// The conflict-detection pass of [`validate_step`]: how many live
/// entries of `addrs` land on a granule marked in `rs_bmp`.  Read-only;
/// the packed bitmap words and granularity shift are hoisted out of the
/// loop so each probe is one load + shift + mask.
pub fn conflict_count(rs_bmp: &Bitmap, addrs: &[i32]) -> u32 {
    let bits = rs_bmp.words();
    let shift = rs_bmp.shift();
    let mut n = 0u32;
    for &a in addrs {
        if a >= 0 {
            let g = (a as usize) >> shift;
            n += (bits[g >> 6] >> (g & 63) & 1) as u32;
        }
    }
    n
}

/// Minimum number of chunk entries before the conflict scan fans out
/// over OS threads: below this, thread spawn/join costs more than the
/// scan itself (a 4096-entry default chunk scans in a few microseconds).
pub const PAR_VALIDATE_MIN_ENTRIES: usize = 1 << 15;

/// [`conflict_count`] with the entry range split over up to `threads`
/// scoped OS threads (intra-device parallel chunk validation).  Partial
/// sums fold in slice order; `u32` addition is associative, so the
/// result is bit-identical to the sequential scan at any thread count.
pub fn conflict_count_par(rs_bmp: &Bitmap, addrs: &[i32], threads: usize) -> u32 {
    let threads = threads.min(addrs.len().div_ceil(PAR_VALIDATE_MIN_ENTRIES).max(1));
    if threads <= 1 {
        return conflict_count(rs_bmp, addrs);
    }
    let per = addrs.len().div_ceil(threads);
    let mut partials = vec![0u32; addrs.len().div_ceil(per)];
    std::thread::scope(|s| {
        for (part, block) in partials.iter_mut().zip(addrs.chunks(per)) {
            s.spawn(move || *part = conflict_count(rs_bmp, block));
        }
    });
    partials.into_iter().sum()
}

/// Conflict counts for a batch of chunks, fanned chunk-wise across up to
/// `threads` scoped OS threads; `out[i]` receives chunk `i`'s count.
/// The pass is read-only, so the fan-out is bit-identical to scanning
/// the chunks in order.  Falls back to the sequential scan when the
/// total work is too small to amortize the spawns.
pub fn conflict_counts_into(
    rs_bmp: &Bitmap,
    chunks: &[LogChunk],
    threads: usize,
    out: &mut Vec<u32>,
) {
    out.clear();
    out.resize(chunks.len(), 0);
    let work: usize = chunks.iter().map(|c| c.addrs.len()).sum();
    let threads = threads.min(chunks.len());
    if threads <= 1 || work < PAR_VALIDATE_MIN_ENTRIES {
        for (o, c) in out.iter_mut().zip(chunks) {
            *o = conflict_count(rs_bmp, &c.addrs);
        }
        return;
    }
    let per = chunks.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (ob, cb) in out.chunks_mut(per).zip(chunks.chunks(per)) {
            s.spawn(move || {
                for (o, c) in ob.iter_mut().zip(cb) {
                    *o = conflict_count(rs_bmp, &c.addrs);
                }
            });
        }
    });
}

/// The freshness-apply pass of [`validate_step`] (also the rollback
/// replay loop): apply each live entry iff at least as fresh as what
/// previous chunks applied.  In-order `>=` reproduces max-(ts, position)
/// — chunks MUST be applied in shipping order.  Flat zipped walk over
/// the chunk's parallel arrays (no per-entry indexing/bounds checks).
pub fn apply_chunk(stmr: &mut [i32], ts_arr: &mut [i32], chunk: &LogChunk) {
    for ((&a, &v), &t) in chunk.addrs.iter().zip(&chunk.vals).zip(&chunk.ts) {
        if a < 0 {
            continue;
        }
        let a = a as usize;
        if t >= ts_arr[a] {
            ts_arr[a] = t;
            stmr[a] = v;
        }
    }
}

/// Outcome of a native memcached batch step.
#[derive(Debug, Clone)]
pub struct McOutput {
    /// GET results (-1 for misses, aborts and PUTs).
    pub out_val: Vec<i32>,
    /// 1 = request committed, 0 = arbitration abort (host retries).
    pub commit: Vec<i32>,
    /// Number of commits.
    pub n_commits: u32,
}

/// Memcached batch step. Mirrors `model.memcached_step`.
pub fn memcached_step(
    stmr: &mut [i32],
    rs_bmp: &mut Bitmap,
    ws_bmp: &mut Bitmap,
    batch: &McBatch,
    n_sets: usize,
) -> McOutput {
    use mc::*;
    let q = batch.key.len();
    let mut out_val = vec![-1i32; q];
    let mut commit = vec![0i32; q];

    // Probe against the pre-batch state.
    let set_idx: Vec<usize> = batch.key.iter().map(|&k| hash(k, n_sets)).collect();
    let mut probe_hit = vec![false; q];
    let mut probe_slot = vec![-1i32; q];
    let mut probe_val = vec![-1i32; q];
    for i in 0..q {
        let base = set_idx[i] * WORDS_PER_SET;
        let keys = &stmr[base + OFF_KEYS..base + OFF_KEYS + WAYS];
        if let Some(s) = keys.iter().position(|&k| k == batch.key[i]) {
            probe_hit[i] = true;
            probe_slot[i] = s as i32;
            probe_val[i] = stmr[base + OFF_VALS + s];
        } else if batch.op[i] == 1 {
            // LRU victim under the GPU-local clock; empties (ts 0) first.
            let ts = &stmr[base + OFF_TS_GPU..base + OFF_TS_GPU + WAYS];
            // First-minimum scan (strict `<` keeps min_by_key's
            // lowest-index tie-break) over the WAYS-long window; a
            // manual loop because the slice is never empty, so there is
            // no None case to unwrap.
            let mut lru = 0usize;
            for (s, &t) in ts.iter().enumerate().skip(1) {
                if t < ts[lru] {
                    lru = s;
                }
            }
            probe_slot[i] = lru as i32;
        }
    }

    // Arbitration: PUT claims its set, GET hit claims its slot.
    // audit:allow(D1, reason = "entry/get arbitration index, never iterated; winners are decided by request order, not map order")
    let mut set_lock: std::collections::HashMap<usize, i32> = std::collections::HashMap::new();
    // audit:allow(D1, reason = "entry/get arbitration index, never iterated; winners are decided by request order, not map order")
    let mut slot_lock: std::collections::HashMap<usize, i32> = std::collections::HashMap::new();
    for i in 0..q {
        if batch.op[i] == 1 {
            let e = set_lock.entry(set_idx[i]).or_insert(INF);
            if (i as i32) < *e {
                *e = i as i32;
            }
        } else if probe_hit[i] {
            let sk = set_idx[i] * WAYS + probe_slot[i] as usize;
            let e = slot_lock.entry(sk).or_insert(INF);
            if (i as i32) < *e {
                *e = i as i32;
            }
        }
    }

    let mut n_commits = 0u32;
    for i in 0..q {
        let s = set_idx[i];
        let set_free = !set_lock.contains_key(&s);
        let c = if batch.op[i] == 1 {
            set_lock.get(&s) == Some(&(i as i32))
        } else if probe_hit[i] {
            set_free && slot_lock.get(&(s * WAYS + probe_slot[i] as usize)) == Some(&(i as i32))
        } else {
            set_free
        };
        if c {
            commit[i] = 1;
            n_commits += 1;
        }
    }

    // Apply committed requests; their footprints are disjoint by
    // construction of the locks, so order does not matter.
    for i in 0..q {
        if commit[i] == 0 {
            continue;
        }
        let base = set_idx[i] * WORDS_PER_SET;
        let clk = batch.clk0.wrapping_add(i as i32);
        for wd in 0..WAYS {
            rs_bmp.mark_word(base + OFF_KEYS + wd);
        }
        let mark_w = |bmp_r: &mut Bitmap, bmp_w: &mut Bitmap, word: usize| {
            bmp_r.mark_word(word);
            bmp_w.mark_word(word);
        };
        if batch.op[i] == 1 {
            let slot = probe_slot[i] as usize;
            for wd in 0..WAYS {
                rs_bmp.mark_word(base + OFF_TS_GPU + wd);
            }
            stmr[base + OFF_KEYS + slot] = batch.key[i];
            stmr[base + OFF_VALS + slot] = batch.val[i];
            stmr[base + OFF_TS_GPU + slot] = clk;
            stmr[base + OFF_SET_TS] = clk;
            mark_w(rs_bmp, ws_bmp, base + OFF_KEYS + slot);
            mark_w(rs_bmp, ws_bmp, base + OFF_VALS + slot);
            mark_w(rs_bmp, ws_bmp, base + OFF_TS_GPU + slot);
            mark_w(rs_bmp, ws_bmp, base + OFF_SET_TS);
        } else if probe_hit[i] {
            let slot = probe_slot[i] as usize;
            out_val[i] = probe_val[i];
            stmr[base + OFF_TS_GPU + slot] = clk;
            rs_bmp.mark_word(base + OFF_VALS + slot);
            mark_w(rs_bmp, ws_bmp, base + OFF_TS_GPU + slot);
        }
        // GET miss: read-only (key row already marked).
    }

    McOutput {
        out_val,
        commit,
        n_commits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bmp(n: usize) -> Bitmap {
        Bitmap::new(n, 0)
    }

    #[test]
    fn prstm_disjoint_txns_all_commit() {
        let n = 64;
        let mut stmr = vec![0i32; n];
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        let mut b = TxnBatch::empty(2, 2, 2);
        // txn 0 reads {0,1} writes {2,3}; txn 1 reads {10,11} writes {12,13}
        b.read_idx = vec![0, 1, 10, 11];
        b.write_idx = vec![2, 3, 12, 13];
        b.write_val = vec![5, 6, 7, 8];
        b.op = vec![1, 1];
        let out = prstm_step(&mut stmr, &mut rs, &mut ws, &b, 0);
        assert_eq!(out.commit, vec![1, 1]);
        assert_eq!(stmr[2], 5);
        assert_eq!(stmr[13], 8);
        assert!(rs.test_word(0) && rs.test_word(2) && ws.test_word(12));
        assert!(!ws.test_word(0), "reads are not in WS");
    }

    #[test]
    fn prstm_write_write_conflict_low_prio_wins() {
        let n = 16;
        let mut stmr = vec![0i32; n];
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        let mut b = TxnBatch::empty(2, 1, 1);
        b.read_idx = vec![-1, -1];
        b.write_idx = vec![4, 4];
        b.write_val = vec![100, 200];
        b.op = vec![1, 1];
        let out = prstm_step(&mut stmr, &mut rs, &mut ws, &b, 0);
        assert_eq!(out.commit, vec![1, 0], "priority 0 beats priority 1");
        assert_eq!(stmr[4], 100);
    }

    #[test]
    fn prstm_read_write_conflict_aborts_reader() {
        let n = 16;
        let mut stmr = vec![0i32; n];
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        let mut b = TxnBatch::empty(2, 1, 1);
        // txn 0 (high prio) writes 4; txn 1 reads 4 and writes elsewhere.
        b.read_idx = vec![-1, 4];
        b.write_idx = vec![4, 8];
        b.write_val = vec![1, 1];
        b.op = vec![0, 0];
        let out = prstm_step(&mut stmr, &mut rs, &mut ws, &b, 0);
        assert_eq!(out.commit, vec![1, 0]);
        assert_eq!(stmr[8], 0, "aborted txn leaves no trace");
        assert!(!rs.test_word(8));
    }

    #[test]
    fn prstm_add_wraps_like_jnp() {
        let n = 4;
        let mut stmr = vec![i32::MAX; n];
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        let mut b = TxnBatch::empty(1, 1, 1);
        b.read_idx = vec![-1];
        b.write_idx = vec![0];
        b.write_val = vec![1];
        b.op = vec![0];
        prstm_step(&mut stmr, &mut rs, &mut ws, &b, 0);
        assert_eq!(stmr[0], i32::MIN);
    }

    #[test]
    fn validate_counts_conflicts_and_applies_freshest() {
        let n = 16;
        let mut stmr = vec![0i32; n];
        let mut ts_arr = vec![0i32; n];
        let mut rs = bmp(n);
        rs.mark_word(3);
        let chunk = LogChunk {
            addrs: vec![3, 5, 5, -1],
            vals: vec![30, 50, 51, 0],
            ts: vec![10, 7, 5, 0],
            sig: None,
        };
        let conf = validate_step(&mut stmr, &mut ts_arr, &rs, &chunk);
        assert_eq!(conf, 1, "only addr 3 hits RS");
        assert_eq!(stmr[3], 30, "applied even though conflicting");
        assert_eq!(stmr[5], 50, "ts 7 beats ts 5 regardless of order");
        assert_eq!(ts_arr[5], 7);
    }

    #[test]
    fn validate_respects_prior_chunk_freshness() {
        let n = 8;
        let mut stmr = vec![0i32; n];
        let mut ts_arr = vec![0i32; n];
        let rs = bmp(n);
        let c1 = LogChunk {
            addrs: vec![2],
            vals: vec![20],
            ts: vec![9],
            sig: None,
        };
        let c2 = LogChunk {
            addrs: vec![2],
            vals: vec![21],
            ts: vec![4],
            sig: None,
        };
        validate_step(&mut stmr, &mut ts_arr, &rs, &c1);
        validate_step(&mut stmr, &mut ts_arr, &rs, &c2);
        assert_eq!(stmr[2], 20, "stale value from later chunk must not win");
    }

    #[test]
    fn memcached_put_then_get_roundtrip() {
        let n_sets = 16;
        let n = n_sets * mc::WORDS_PER_SET;
        let mut stmr = vec![0i32; n];
        for s in 0..n_sets {
            for wd in 0..mc::WAYS {
                stmr[s * mc::WORDS_PER_SET + wd] = -1; // empty keys
            }
        }
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        let put = McBatch {
            op: vec![1],
            key: vec![42],
            val: vec![4242],
            clk0: 100,
        };
        let o1 = memcached_step(&mut stmr, &mut rs, &mut ws, &put, n_sets);
        assert_eq!(o1.commit, vec![1]);
        let get = McBatch {
            op: vec![0],
            key: vec![42],
            val: vec![0],
            clk0: 200,
        };
        let o2 = memcached_step(&mut stmr, &mut rs, &mut ws, &get, n_sets);
        assert_eq!(o2.commit, vec![1]);
        assert_eq!(o2.out_val, vec![4242]);
    }

    #[test]
    fn memcached_put_put_same_set_arbitrates() {
        let n_sets = 4;
        let n = n_sets * mc::WORDS_PER_SET;
        let mut stmr = vec![0i32; n];
        for s in 0..n_sets {
            for wd in 0..mc::WAYS {
                stmr[s * mc::WORDS_PER_SET + wd] = -1;
            }
        }
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        // Two PUTs with keys hashing to the same set (same key => same set).
        let b = McBatch {
            op: vec![1, 1],
            key: vec![7, 7],
            val: vec![1, 2],
            clk0: 0,
        };
        let o = memcached_step(&mut stmr, &mut rs, &mut ws, &b, n_sets);
        assert_eq!(o.commit, vec![1, 0], "first PUT wins the set");
    }

    #[test]
    fn memcached_get_miss_is_read_only() {
        let n_sets = 4;
        let n = n_sets * mc::WORDS_PER_SET;
        let mut stmr = vec![0i32; n];
        for s in 0..n_sets {
            for wd in 0..mc::WAYS {
                stmr[s * mc::WORDS_PER_SET + wd] = -1;
            }
        }
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        let b = McBatch {
            op: vec![0],
            key: vec![9],
            val: vec![0],
            clk0: 0,
        };
        let o = memcached_step(&mut stmr, &mut rs, &mut ws, &b, n_sets);
        assert_eq!(o.commit, vec![1]);
        assert_eq!(o.out_val, vec![-1]);
        assert!(ws.is_empty(), "miss writes nothing");
        assert!(!rs.is_empty(), "but reads the key row");
    }

    #[test]
    fn memcached_lru_evicts_oldest() {
        let n_sets = 1;
        let n = mc::WORDS_PER_SET;
        let mut stmr = vec![0i32; n];
        for wd in 0..mc::WAYS {
            stmr[wd] = -1;
        }
        let (mut rs, mut ws) = (bmp(n), bmp(n));
        // Fill all 8 slots with distinct keys (one batch each to avoid
        // set-level arbitration aborts).
        for k in 0..8 {
            let b = McBatch {
                op: vec![1],
                key: vec![k],
                val: vec![k * 10],
                clk0: 10 + k,
            };
            let o = memcached_step(&mut stmr, &mut rs, &mut ws, &b, n_sets);
            assert_eq!(o.commit, vec![1]);
        }
        // Touch key 0 so key 1 becomes LRU, then insert a 9th key.
        let g = McBatch {
            op: vec![0],
            key: vec![0],
            val: vec![0],
            clk0: 100,
        };
        memcached_step(&mut stmr, &mut rs, &mut ws, &g, n_sets);
        let p = McBatch {
            op: vec![1],
            key: vec![99],
            val: vec![990],
            clk0: 200,
        };
        memcached_step(&mut stmr, &mut rs, &mut ws, &p, n_sets);
        let keys: Vec<i32> = stmr[0..8].to_vec();
        assert!(keys.contains(&99));
        assert!(keys.contains(&0), "recently-touched key survives");
        assert!(!keys.contains(&1), "LRU key evicted");
    }
}
