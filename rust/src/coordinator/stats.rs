//! Metrics: per-round and aggregate statistics, including the phase-time
//! breakdown the paper reports in Figure 4 (processing / validation /
//! merge / blocked, per device).

/// Where a device spent its time during rounds (virtual seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseBreakdown {
    /// Executing transactions.
    pub processing_s: f64,
    /// GPU: validating CPU log chunks. CPU: shipping logs while blocked
    /// (basic variant only).
    pub validation_s: f64,
    /// Merge-phase transfers / state installs.
    pub merge_s: f64,
    /// Blocked waiting on the other device or the bus.
    pub blocked_s: f64,
}

impl PhaseBreakdown {
    /// Sum of all accounted time.
    pub fn total(&self) -> f64 {
        self.processing_s + self.validation_s + self.merge_s + self.blocked_s
    }

    /// Accumulate another breakdown (used by [`RunStats::absorb`] and the
    /// cluster engine's per-device accounting).
    pub fn add(&mut self, o: &PhaseBreakdown) {
        self.processing_s += o.processing_s;
        self.validation_s += o.validation_s;
        self.merge_s += o.merge_s;
        self.blocked_s += o.blocked_s;
    }
}

/// Statistics of one synchronization round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// Virtual time at round start.
    pub t_start: f64,
    /// Virtual end of the round (next round's start).
    pub t_end: f64,
    /// CPU transactions committed (these are final under favor-CPU).
    pub cpu_commits: u64,
    /// CPU execution attempts (commits + intra-device retries).
    pub cpu_attempts: u64,
    /// GPU transactions speculatively committed this round.
    pub gpu_commits: u64,
    /// GPU execution attempts.
    pub gpu_attempts: u64,
    /// GPU kernel activations.
    pub gpu_batches: u64,
    /// Log chunks shipped and validated.
    pub chunks: u64,
    /// CPU write-log entries committed into the round log (raw, before
    /// compaction; carried re-ships count).
    pub log_entries_raw: u64,
    /// Log entries actually shipped in chunks (equals `log_entries_raw`
    /// with `hetm.log_compaction` off).
    pub log_entries_shipped: u64,
    /// Chunks whose per-entry validation pass was skipped because their
    /// signature proved non-intersection (`hetm.chunk_filter`).
    pub chunks_filtered: u64,
    /// Chunks whose per-entry validation pass was skipped because an
    /// early validation had already decided the round's fate (the chunks
    /// still ship — apply/rollback needs them).
    pub chunks_skipped_post_abort: u64,
    /// Conflicting log entries found by validation.  On early-aborted
    /// rounds this is the early-validation count (the full recount is
    /// skipped, see `chunks_skipped_post_abort`).
    pub conflict_entries: u64,
    /// Whether inter-device validation succeeded.
    pub committed: bool,
    /// Whether early validation aborted the round before the period ended.
    pub early_aborted: bool,
    /// Speculative commits discarded by the losing device.
    pub discarded_commits: u64,
    /// Per-device phase breakdown.
    pub cpu_phases: PhaseBreakdown,
    /// GPU phase breakdown.
    pub gpu_phases: PhaseBreakdown,
}

/// Aggregate over a run of many rounds.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Rounds executed.
    pub rounds: u64,
    /// Rounds whose validation succeeded.
    pub rounds_committed: u64,
    /// Rounds aborted by early validation.
    pub rounds_early_aborted: u64,
    /// Total virtual duration.
    pub duration_s: f64,
    /// Committed CPU transactions.
    pub cpu_commits: u64,
    /// CPU attempts.
    pub cpu_attempts: u64,
    /// GPU transactions whose speculative commit survived the round.
    pub gpu_commits: u64,
    /// GPU attempts (includes intra-batch retries).
    pub gpu_attempts: u64,
    /// Speculative commits discarded on round aborts (wasted work).
    pub discarded_commits: u64,
    /// Total log chunks validated.
    pub chunks: u64,
    /// Total raw (pre-compaction) CPU log entries.
    pub log_entries_raw: u64,
    /// Total log entries shipped in chunks (post-compaction).
    pub log_entries_shipped: u64,
    /// Total chunks skipped by the signature prefilter.
    pub chunks_filtered: u64,
    /// Total chunks whose validation was skipped after an early abort.
    pub chunks_skipped_post_abort: u64,
    /// Aggregate CPU phase breakdown.
    pub cpu_phases: PhaseBreakdown,
    /// Aggregate GPU phase breakdown.
    pub gpu_phases: PhaseBreakdown,
}

impl RunStats {
    /// Fold one round into the aggregate.
    ///
    /// `RoundStats::{cpu,gpu}_commits` are SURVIVING commits — the engine
    /// zeroes the losing device's count and moves it to
    /// `discarded_commits` before absorbing.
    pub fn absorb(&mut self, r: &RoundStats) {
        self.rounds += 1;
        if r.committed {
            self.rounds_committed += 1;
        }
        self.gpu_commits += r.gpu_commits;
        if r.early_aborted {
            self.rounds_early_aborted += 1;
        }
        self.duration_s += r.t_end - r.t_start;
        self.cpu_commits += r.cpu_commits;
        self.cpu_attempts += r.cpu_attempts;
        self.gpu_attempts += r.gpu_attempts;
        self.discarded_commits += r.discarded_commits;
        self.chunks += r.chunks;
        self.log_entries_raw += r.log_entries_raw;
        self.log_entries_shipped += r.log_entries_shipped;
        self.chunks_filtered += r.chunks_filtered;
        self.chunks_skipped_post_abort += r.chunks_skipped_post_abort;
        self.cpu_phases.add(&r.cpu_phases);
        self.gpu_phases.add(&r.gpu_phases);
    }

    /// Committed transactions (both devices) per virtual second.
    pub fn throughput(&self) -> f64 {
        if self.duration_s == 0.0 {
            0.0
        } else {
            (self.cpu_commits + self.gpu_commits) as f64 / self.duration_s
        }
    }

    /// Fraction of rounds that failed inter-device validation.
    pub fn round_abort_rate(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            1.0 - self.rounds_committed as f64 / self.rounds as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_accumulates_and_rates() {
        let mut run = RunStats::default();
        let mut r = RoundStats {
            t_start: 0.0,
            t_end: 0.5,
            cpu_commits: 100,
            gpu_commits: 200,
            committed: true,
            ..Default::default()
        };
        run.absorb(&r);
        r.t_start = 0.5;
        r.t_end = 1.0;
        r.committed = false;
        r.gpu_commits = 0; // engine moves the losing side's commits...
        r.discarded_commits = 200; // ...into discarded before absorbing
        run.absorb(&r);
        assert_eq!(run.rounds, 2);
        assert_eq!(run.rounds_committed, 1);
        assert_eq!(run.cpu_commits, 200);
        assert_eq!(run.gpu_commits, 200, "failed round's GPU commits dropped");
        assert_eq!(run.discarded_commits, 200);
        assert!((run.round_abort_rate() - 0.5).abs() < 1e-12);
        assert!((run.throughput() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run_has_zero_rates() {
        let run = RunStats::default();
        assert_eq!(run.throughput(), 0.0);
        assert_eq!(run.round_abort_rate(), 0.0);
    }

    #[test]
    fn phase_breakdown_totals() {
        let p = PhaseBreakdown {
            processing_s: 1.0,
            validation_s: 2.0,
            merge_s: 3.0,
            blocked_s: 4.0,
        };
        assert!((p.total() - 10.0).abs() < 1e-12);
    }
}
