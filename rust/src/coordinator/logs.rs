//! CPU write-set log management (paper §IV-B/§IV-C.2).
//!
//! Guest TMs append `(addr, value, ts)` entries at commit; the coordinator
//! periodically drains them into fixed-size [`LogChunk`]s — the 48 KB
//! transfer units the validation phase streams to the GPU.  The last chunk
//! of a round is padded with `addr = -1` sentinels.

use crate::bus::chunking::LOG_CHUNK_ENTRIES;
use crate::gpu::LogChunk;
use crate::stm::WriteEntry;

/// Accumulates one round's CPU write-set log and chunks it for shipping.
#[derive(Debug, Default)]
pub struct RoundLog {
    entries: Vec<WriteEntry>,
    /// Entries already drained into chunks.
    drained: usize,
    /// Leading entries carried over from the previous round's validation
    /// window; they survive a favor-GPU rollback (their transactions
    /// committed BEFORE the rolled-back round started).
    carried: usize,
    chunk_entries: usize,
}

impl RoundLog {
    /// New log with the paper's 4096-entry (48 KB) chunking.
    pub fn new() -> Self {
        Self::with_chunk_entries(LOG_CHUNK_ENTRIES)
    }

    /// New log with custom chunk size (ablation benches).
    pub fn with_chunk_entries(chunk_entries: usize) -> Self {
        assert!(chunk_entries > 0);
        RoundLog {
            entries: Vec::new(),
            drained: 0,
            carried: 0,
            chunk_entries,
        }
    }

    /// Entries per chunk.
    pub fn chunk_entries(&self) -> usize {
        self.chunk_entries
    }

    /// Append a batch of committed write entries.
    pub fn append(&mut self, entries: &[WriteEntry]) {
        self.entries.extend_from_slice(entries);
    }

    /// Append a single committed write entry (the cluster log router
    /// scatters entry-by-entry).
    pub fn push(&mut self, entry: WriteEntry) {
        self.entries.push(entry);
    }

    /// Total entries logged this round.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if nothing was logged.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries not yet drained into chunks.
    pub fn pending(&self) -> usize {
        self.entries.len() - self.drained
    }

    /// Drain as many FULL chunks as available (streaming during the
    /// execution phase ships only complete 48 KB units).
    pub fn drain_full_chunks(&mut self, out: &mut Vec<LogChunk>) {
        while self.entries.len() - self.drained >= self.chunk_entries {
            out.push(self.make_chunk(self.chunk_entries));
        }
    }

    /// Drain everything, padding the final partial chunk (end of round).
    pub fn drain_all(&mut self, out: &mut Vec<LogChunk>) {
        self.drain_full_chunks(out);
        let rest = self.entries.len() - self.drained;
        if rest > 0 {
            out.push(self.make_chunk(rest));
        }
    }

    /// Reset for the next round, seeding with `carry` (commits that
    /// happened while the previous round was validating — §IV-D
    /// non-blocking CPU).
    pub fn reset_with_carry(&mut self, carry: &[WriteEntry]) {
        self.entries.clear();
        self.drained = 0;
        self.entries.extend_from_slice(carry);
        self.carried = carry.len();
    }

    /// Favor-GPU round abort (§IV-E): this round's CPU commits are rolled
    /// back and their log entries discarded — but the carried prefix
    /// (commits from BEFORE the round started, still unshipped to the
    /// winning device) survives and re-ships next round.
    pub fn truncate_to_carried(&mut self) {
        self.entries.truncate(self.carried);
        self.drained = 0;
    }

    /// View of all entries logged this round (rollback replay needs them).
    pub fn entries(&self) -> &[WriteEntry] {
        &self.entries
    }

    fn make_chunk(&mut self, n: usize) -> LogChunk {
        debug_assert!(n <= self.chunk_entries);
        let mut chunk = LogChunk::empty(self.chunk_entries);
        for (i, e) in self.entries[self.drained..self.drained + n].iter().enumerate() {
            chunk.addrs[i] = e.addr as i32;
            chunk.vals[i] = e.val;
            chunk.ts[i] = e.ts;
        }
        self.drained += n;
        chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(addr: u32, val: i32, ts: i32) -> WriteEntry {
        WriteEntry { addr, val, ts }
    }

    #[test]
    fn full_chunks_then_padded_tail() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&(0..10).map(|i| entry(i, i as i32, 1)).collect::<Vec<_>>());
        let mut chunks = Vec::new();
        log.drain_full_chunks(&mut chunks);
        assert_eq!(chunks.len(), 2);
        assert_eq!(log.pending(), 2);
        log.drain_all(&mut chunks);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[2].live(), 2);
        assert_eq!(chunks[2].addrs, vec![8, 9, -1, -1]);
        assert_eq!(log.pending(), 0);
    }

    #[test]
    fn entries_preserve_order_and_fields() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&[entry(7, 70, 3), entry(9, 90, 4)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        assert_eq!(chunks[0].addrs[..2], [7, 9]);
        assert_eq!(chunks[0].vals[..2], [70, 90]);
        assert_eq!(chunks[0].ts[..2], [3, 4]);
    }

    #[test]
    fn carry_seeds_next_round() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&[entry(1, 1, 1)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        log.reset_with_carry(&[entry(2, 2, 2)]);
        assert_eq!(log.len(), 1);
        assert_eq!(log.pending(), 1);
        let mut chunks2 = Vec::new();
        log.drain_all(&mut chunks2);
        assert_eq!(chunks2[0].addrs[0], 2);
    }

    #[test]
    fn default_chunking_is_paper_sized() {
        let log = RoundLog::new();
        assert_eq!(log.chunk_entries(), 4096);
        // 4096 entries * 12 B = 48 KB.
        assert_eq!(LogChunk::empty(log.chunk_entries()).wire_bytes(), 48 * 1024);
    }
}
