//! CPU write-set log management (paper §IV-B/§IV-C.2).
//!
//! Guest TMs append `(addr, value, ts)` entries at commit; the coordinator
//! periodically drains them into fixed-size [`LogChunk`]s — the 48 KB
//! transfer units the validation phase streams to the GPU.  The last chunk
//! of a round is padded with `addr = -1` sentinels.
//!
//! # Compaction (`hetm.log_compaction`)
//!
//! With compaction enabled, every drain first deduplicates the
//! not-yet-shipped window last-write-wins per address, so wire bytes and
//! chunk count scale with the round's *write-set footprint* instead of its
//! commit count — the decisive lever on hot-key workloads like `zipfkv`,
//! where thousands of commits rewrite a handful of addresses.  What makes
//! this sound (DESIGN.md §9):
//!
//! * **Apply-order winner.** The survivor for an address is the entry the
//!   GPU's freshness-guarded replay (`ts >= ts_arr`, later position wins
//!   ties) would leave: the LAST entry among those carrying the maximal
//!   `ts`.  Applying the compacted window therefore produces the exact
//!   final `(stmr, ts_arr)` the raw window produces, and the same holds
//!   for the favor-CPU `rollback_with_logs` replay, which uses the same
//!   `>=` rule.
//! * **Conflict decisions survive.** Validation flags an entry iff its
//!   address granule is in the GPU read-set bitmap; deduplication keeps
//!   the address SET of the window intact, so "any conflict" is invariant
//!   (only the conflicting-entry *count* can shrink).
//! * **Never across the carried boundary.** Entries carried from the
//!   previous round's validation window survive a favor-GPU rollback
//!   (their transactions committed before the aborted round began) while
//!   this round's entries are truncated; merging across that boundary
//!   would either resurrect rolled-back values or lose carried ones, so
//!   compaction only touches `entries[max(drained, carried)..]`.
//! * **Never across a shipped boundary.** Already-drained entries are on
//!   the wire; an address they carried that is rewritten later simply
//!   ships again, exactly as in the raw log.

use std::collections::HashMap;

use crate::bus::chunking::LOG_CHUNK_ENTRIES;
use crate::gpu::LogChunk;
use crate::stm::WriteEntry;

/// Accumulates one round's CPU write-set log and chunks it for shipping.
#[derive(Debug, Default)]
pub struct RoundLog {
    entries: Vec<WriteEntry>,
    /// Entries already drained into chunks.
    drained: usize,
    /// Leading entries carried over from the previous round's validation
    /// window; they survive a favor-GPU rollback (their transactions
    /// committed BEFORE the rolled-back round started).
    carried: usize,
    chunk_entries: usize,
    /// Deduplicate the pending window last-write-wins before draining.
    compact: bool,
    /// Granule shift for chunk conflict-prefilter signatures (`None` =
    /// no signatures).
    sig_shift: Option<u32>,
    /// Entries appended since the last reset (the raw, pre-compaction
    /// shipping load; carry seeds count — they re-ship).
    raw_appended: u64,
    /// Live entries actually drained into chunks since the last reset.
    shipped: u64,
    /// Dedup scratch: address -> kept index (reused across drains).
    // audit:allow(D1, reason = "lookup-only index (get/insert, never iterated); output order is driven by the entries vec")
    dedup: HashMap<u32, usize>,
    /// Retired chunk buffers awaiting reuse (DESIGN.md §12 arena): the
    /// engines hand back each round's chunks via [`Self::recycle`], so
    /// steady-state drains allocate nothing.
    pool: Vec<LogChunk>,
}

impl RoundLog {
    /// New log with the paper's 4096-entry (48 KB) chunking.
    pub fn new() -> Self {
        Self::with_chunk_entries(LOG_CHUNK_ENTRIES)
    }

    /// New log with custom chunk size (ablation benches).
    pub fn with_chunk_entries(chunk_entries: usize) -> Self {
        assert!(chunk_entries > 0);
        RoundLog {
            entries: Vec::new(),
            drained: 0,
            carried: 0,
            chunk_entries,
            compact: false,
            sig_shift: None,
            raw_appended: 0,
            shipped: 0,
            // audit:allow(D1, reason = "lookup-only index (get/insert, never iterated); output order is driven by the entries vec")
            dedup: HashMap::new(),
            pool: Vec::new(),
        }
    }

    /// Entries per chunk.
    pub fn chunk_entries(&self) -> usize {
        self.chunk_entries
    }

    /// Enable/disable last-write-wins compaction of the pending window
    /// (`hetm.log_compaction`).
    pub fn set_compaction(&mut self, on: bool) {
        self.compact = on;
    }

    /// Whether compaction is enabled.
    pub fn compaction(&self) -> bool {
        self.compact
    }

    /// Enable chunk signatures at granule shift `shift` (`None` disables;
    /// the engines pass the device bitmap's shift so the signature test
    /// is exact at the granularity validation checks at).
    pub fn set_sig_shift(&mut self, shift: Option<u32>) {
        self.sig_shift = shift;
    }

    /// Configured signature shift.
    pub fn sig_shift(&self) -> Option<u32> {
        self.sig_shift
    }

    /// Append a batch of committed write entries.
    pub fn append(&mut self, entries: &[WriteEntry]) {
        self.entries.extend_from_slice(entries);
        self.raw_appended += entries.len() as u64;
    }

    /// Append a single committed write entry (the cluster log router
    /// scatters entry-by-entry).
    pub fn push(&mut self, entry: WriteEntry) {
        self.entries.push(entry);
        self.raw_appended += 1;
    }

    /// Total entries logged this round.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if nothing was logged.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries not yet drained into chunks.
    pub fn pending(&self) -> usize {
        self.entries.len() - self.drained
    }

    /// Entries appended since the last reset — the raw (pre-compaction)
    /// shipping load, carry seeds included.
    pub fn raw_appended(&self) -> u64 {
        self.raw_appended
    }

    /// Live entries drained into chunks since the last reset (equals
    /// [`Self::raw_appended`] once fully drained with compaction off).
    pub fn shipped(&self) -> u64 {
        self.shipped
    }

    /// Drain as many FULL chunks as available (streaming during the
    /// execution phase ships only complete 48 KB units).
    pub fn drain_full_chunks(&mut self, out: &mut Vec<LogChunk>) {
        if self.compact {
            self.compact_pending();
        }
        while self.entries.len() - self.drained >= self.chunk_entries {
            out.push(self.make_chunk(self.chunk_entries));
        }
    }

    /// Drain everything, padding the final partial chunk (end of round).
    pub fn drain_all(&mut self, out: &mut Vec<LogChunk>) {
        self.drain_full_chunks(out);
        let rest = self.entries.len() - self.drained;
        if rest > 0 {
            out.push(self.make_chunk(rest));
        }
    }

    /// Reset for the next round, seeding with `carry` (commits that
    /// happened while the previous round was validating — §IV-D
    /// non-blocking CPU).
    pub fn reset_with_carry(&mut self, carry: &[WriteEntry]) {
        self.entries.clear();
        self.drained = 0;
        self.entries.extend_from_slice(carry);
        self.carried = carry.len();
        self.raw_appended = carry.len() as u64;
        self.shipped = 0;
    }

    /// Favor-GPU round abort (§IV-E): this round's CPU commits are rolled
    /// back and their log entries discarded — but the carried prefix
    /// (commits from BEFORE the round started, still unshipped to the
    /// winning device) survives and re-ships next round.
    pub fn truncate_to_carried(&mut self) {
        self.entries.truncate(self.carried);
        self.drained = 0;
        self.raw_appended = self.carried as u64;
        self.shipped = 0;
    }

    /// View of all entries logged this round (rollback replay needs them).
    pub fn entries(&self) -> &[WriteEntry] {
        &self.entries
    }

    /// Round-boundary epoch rebase: renumber the log's entries into
    /// timestamps `1..=len` (preserving entry order) and return the new
    /// clock base.  Called while the log holds only the next round's
    /// carried prefix, as part of the engines' epoch reset
    /// ([`crate::stm::GlobalClock::epoch_reset`]).
    ///
    /// Per address, entry order equals commit order (one worker owns each
    /// address), so position-order renumbering preserves every
    /// `>=`-freshness apply winner and every compaction survivor.
    pub fn rebase_epoch(&mut self) -> i64 {
        debug_assert_eq!(self.drained, 0, "rebase only between rounds");
        for (i, e) in self.entries.iter_mut().enumerate() {
            e.ts = (i + 1) as i32;
        }
        self.entries.len() as i64
    }

    /// Append externally-committed entries to the carried prefix (the
    /// [`crate::session::Session::txn`] path).  Between rounds the log
    /// holds only carried entries, so the append extends that prefix:
    /// the entries ship with the next round and — like the §IV-D
    /// validation-window carry — survive a favor-GPU truncation (their
    /// transactions committed before that round began).
    pub fn extend_carried(&mut self, entries: &[WriteEntry]) {
        debug_assert_eq!(self.drained, 0, "external commits land between rounds");
        debug_assert_eq!(
            self.entries.len(),
            self.carried,
            "between rounds the log is exactly its carried prefix"
        );
        self.entries.extend_from_slice(entries);
        self.carried = self.entries.len();
        self.raw_appended += entries.len() as u64;
    }

    /// Deduplicate the pending, non-carried window in place, keeping per
    /// address the entry the freshness-guarded apply would leave (the
    /// last one whose `ts` ties the maximum) at its first-occurrence
    /// position.  Distinct addresses commute under apply, so position
    /// within the window is free.
    fn compact_pending(&mut self) {
        let start = self.drained.max(self.carried);
        if self.entries.len().saturating_sub(start) < 2 {
            return;
        }
        self.dedup.clear();
        let mut w = start;
        for r in start..self.entries.len() {
            let e = self.entries[r];
            match self.dedup.get(&e.addr) {
                Some(&i) => {
                    // Same `>=` rule as the GPU apply: a later entry with
                    // an equal-or-fresher ts replaces the kept one.
                    if e.ts >= self.entries[i].ts {
                        self.entries[i] = e;
                    }
                }
                None => {
                    self.dedup.insert(e.addr, w);
                    self.entries[w] = e;
                    w += 1;
                }
            }
        }
        self.entries.truncate(w);
    }

    /// Return a round's retired chunks to the arena so later drains reuse
    /// their buffers (chunks of a stale size are dropped at reuse time).
    pub fn recycle(&mut self, chunks: &mut Vec<LogChunk>) {
        self.pool.append(chunks);
    }

    fn make_chunk(&mut self, n: usize) -> LogChunk {
        debug_assert!(n <= self.chunk_entries);
        let mut chunk = match self.pool.pop() {
            Some(mut c) if c.addrs.len() == self.chunk_entries => {
                c.addrs.fill(-1);
                c.vals.fill(0);
                c.ts.fill(0);
                c.sig = None;
                c
            }
            _ => LogChunk::empty(self.chunk_entries),
        };
        for (i, e) in self.entries[self.drained..self.drained + n].iter().enumerate() {
            chunk.addrs[i] = e.addr as i32;
            chunk.vals[i] = e.val;
            chunk.ts[i] = e.ts;
        }
        if let Some(shift) = self.sig_shift {
            chunk.build_sig(shift);
        }
        self.drained += n;
        self.shipped += n as u64;
        chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(addr: u32, val: i32, ts: i32) -> WriteEntry {
        WriteEntry { addr, val, ts }
    }

    #[test]
    fn full_chunks_then_padded_tail() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&(0..10).map(|i| entry(i, i as i32, 1)).collect::<Vec<_>>());
        let mut chunks = Vec::new();
        log.drain_full_chunks(&mut chunks);
        assert_eq!(chunks.len(), 2);
        assert_eq!(log.pending(), 2);
        log.drain_all(&mut chunks);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[2].live(), 2);
        assert_eq!(chunks[2].addrs, vec![8, 9, -1, -1]);
        assert_eq!(log.pending(), 0);
        assert_eq!(log.raw_appended(), 10);
        assert_eq!(log.shipped(), 10, "raw mode ships everything");
    }

    #[test]
    fn entries_preserve_order_and_fields() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&[entry(7, 70, 3), entry(9, 90, 4)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        assert_eq!(chunks[0].addrs[..2], [7, 9]);
        assert_eq!(chunks[0].vals[..2], [70, 90]);
        assert_eq!(chunks[0].ts[..2], [3, 4]);
    }

    #[test]
    fn carry_seeds_next_round() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&[entry(1, 1, 1)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        log.reset_with_carry(&[entry(2, 2, 2)]);
        assert_eq!(log.len(), 1);
        assert_eq!(log.pending(), 1);
        let mut chunks2 = Vec::new();
        log.drain_all(&mut chunks2);
        assert_eq!(chunks2[0].addrs[0], 2);
    }

    #[test]
    fn default_chunking_is_paper_sized() {
        let log = RoundLog::new();
        assert_eq!(log.chunk_entries(), 4096);
        // 4096 entries * 12 B = 48 KB.
        assert_eq!(LogChunk::empty(log.chunk_entries()).wire_bytes(), 48 * 1024);
    }

    #[test]
    fn compaction_keeps_apply_order_winner() {
        let mut log = RoundLog::with_chunk_entries(8);
        log.set_compaction(true);
        // ts sequence 5, 9, 7, 9 on addr 3: the raw `>=` replay would end
        // on the SECOND ts-9 entry (val 40).
        log.append(&[
            entry(3, 10, 5),
            entry(3, 20, 9),
            entry(1, 11, 6),
            entry(3, 30, 7),
            entry(3, 40, 9),
        ]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].live(), 2);
        assert_eq!(chunks[0].addrs[..2], [3, 1], "first-occurrence order");
        assert_eq!(chunks[0].vals[..2], [40, 11]);
        assert_eq!(chunks[0].ts[..2], [9, 6]);
        assert_eq!(log.raw_appended(), 5);
        assert_eq!(log.shipped(), 2);
    }

    #[test]
    fn compaction_never_merges_across_drained_boundary() {
        let mut log = RoundLog::with_chunk_entries(2);
        log.set_compaction(true);
        log.append(&[entry(1, 10, 1), entry(2, 20, 2)]);
        let mut chunks = Vec::new();
        log.drain_full_chunks(&mut chunks);
        assert_eq!(chunks.len(), 1);
        // Rewrite addr 1 after it shipped: it must ship AGAIN (the wire
        // copy cannot be recalled), not merge backwards.
        log.append(&[entry(1, 11, 3), entry(1, 12, 4)]);
        log.drain_all(&mut chunks);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[1].live(), 1, "post-ship rewrites still compact");
        assert_eq!(chunks[1].vals[0], 12);
    }

    #[test]
    fn compaction_never_merges_into_carried_prefix() {
        let mut log = RoundLog::with_chunk_entries(8);
        log.set_compaction(true);
        // Carried entry on addr 5, then this-round rewrites of addr 5.
        log.reset_with_carry(&[entry(5, 50, 3)]);
        log.append(&[entry(5, 51, 7), entry(5, 52, 8), entry(6, 60, 9)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        // Carried entry ships verbatim; the round's rewrites compact.
        assert_eq!(chunks[0].live(), 3);
        assert_eq!(chunks[0].addrs[..3], [5, 5, 6]);
        assert_eq!(chunks[0].vals[..3], [50, 52, 60]);
        // A favor-GPU abort must recover exactly the carried prefix.
        log.truncate_to_carried();
        assert_eq!(log.entries(), &[entry(5, 50, 3)]);
        assert_eq!(log.raw_appended(), 1);
        assert_eq!(log.shipped(), 0);
    }

    #[test]
    fn signatures_attach_when_enabled() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.set_sig_shift(Some(1));
        log.append(&[entry(8, 1, 1), entry(9, 2, 2), entry(3, 3, 3)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        let sig = chunks[0].sig.as_ref().expect("signature built");
        assert_eq!(sig.shift(), 1);
        assert_eq!(sig.addr_range(), (3, 9));
        // Disabled by default.
        let mut plain = RoundLog::with_chunk_entries(4);
        plain.append(&[entry(1, 1, 1)]);
        let mut chunks = Vec::new();
        plain.drain_all(&mut chunks);
        assert!(chunks[0].sig.is_none());
    }

    #[test]
    fn rebase_renumbers_carried_entries_in_order() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.reset_with_carry(&[entry(5, 50, 900), entry(7, 70, 901), entry(5, 51, 905)]);
        let base = log.rebase_epoch();
        assert_eq!(base, 3);
        let ts: Vec<i32> = log.entries().iter().map(|e| e.ts).collect();
        assert_eq!(ts, vec![1, 2, 3], "position-order renumbering");
        let vals: Vec<i32> = log.entries().iter().map(|e| e.val).collect();
        assert_eq!(vals, vec![50, 70, 51], "order and values untouched");
        // Empty log rebases to base 0.
        log.reset_with_carry(&[]);
        assert_eq!(log.rebase_epoch(), 0);
    }

    #[test]
    fn extend_carried_joins_the_carried_prefix() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.reset_with_carry(&[entry(1, 10, 1)]);
        log.extend_carried(&[entry(2, 20, 2), entry(3, 30, 3)]);
        assert_eq!(log.len(), 3);
        assert_eq!(log.raw_appended(), 3);
        // The whole prefix survives a favor-GPU truncation.
        log.append(&[entry(9, 90, 9)]);
        log.truncate_to_carried();
        assert_eq!(log.entries().len(), 3);
        assert_eq!(log.entries()[2], entry(3, 30, 3));
    }

    #[test]
    fn counters_reset_with_carry() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.append(&[entry(1, 1, 1), entry(2, 2, 2)]);
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        log.reset_with_carry(&[entry(9, 9, 9)]);
        assert_eq!(log.raw_appended(), 1, "carry re-ships, so it counts");
        assert_eq!(log.shipped(), 0);
    }

    /// Recycled chunk buffers come back fully reset (stale entries, pad
    /// values, signatures all cleared) and stale-size buffers retired by
    /// `set_chunk_entries` are never reused.
    #[test]
    fn recycled_chunks_reset_and_respect_chunk_size() {
        let mut log = RoundLog::with_chunk_entries(4);
        log.set_sig_shift(Some(0));
        log.append(&(0..6).map(|i| entry(i, i as i32 + 10, 1)).collect::<Vec<_>>());
        let mut chunks = Vec::new();
        log.drain_all(&mut chunks);
        assert_eq!(chunks.len(), 2);
        assert!(chunks.iter().all(|c| c.sig.is_some()));
        log.recycle(&mut chunks);
        assert!(chunks.is_empty(), "recycle drains the retired buffers");

        // Next round's drains must produce chunks indistinguishable from
        // fresh allocations.
        log.reset_with_carry(&[]);
        log.append(&[entry(2, 99, 1)]);
        log.drain_all(&mut chunks);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].addrs, vec![2, -1, -1, -1]);
        assert_eq!(chunks[0].vals, vec![99, 0, 0, 0]);
        assert_eq!(chunks[0].ts, vec![1, 0, 0, 0]);
        assert_eq!(chunks[0].live(), 1);

        // Defensive: a pooled buffer of the wrong shape (possible only
        // across engine reconfiguration) is dropped, never reused.
        log.recycle(&mut chunks);
        let mut stale_size = vec![LogChunk::empty(8)];
        log.recycle(&mut stale_size);
        log.append(&[entry(3, 33, 1)]);
        log.drain_all(&mut chunks);
        assert_eq!(chunks[0].addrs.len(), 4, "stale-size pool entry not reused");
        assert_eq!(chunks[0].addrs[0], 3);
    }
}
