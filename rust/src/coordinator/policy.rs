//! Conflict-resolution policies (paper §IV-E).
//!
//! The default policy deterministically discards the GPU's speculative
//! commits on inter-device conflict — CPU results can then be externalized
//! without waiting for inter-device synchronization.  Alternatives favor
//! the GPU, or add the anti-starvation contention manager: after a number
//! of consecutive GPU aborts, the next round restricts the CPU to
//! read-only transactions, which guarantees the GPU validates successfully
//! (an empty CPU write-set cannot conflict).

use crate::config::PolicyKind;

/// Which device loses the current round on conflict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loser {
    /// Discard GPU speculative commits (default).
    Gpu,
    /// Discard CPU speculative commits.
    Cpu,
}

/// Runtime policy state machine.
#[derive(Debug, Clone)]
pub struct Policy {
    kind: PolicyKind,
    starvation_limit: u32,
    consecutive_gpu_aborts: u32,
    /// When set, the CPU must run only read-only transactions this round.
    cpu_read_only_round: bool,
}

impl Policy {
    /// Build from config.
    pub fn new(kind: PolicyKind, starvation_limit: u32) -> Self {
        Policy {
            kind,
            starvation_limit,
            consecutive_gpu_aborts: 0,
            cpu_read_only_round: false,
        }
    }

    /// Who loses if validation fails this round.
    pub fn loser(&self) -> Loser {
        match self.kind {
            PolicyKind::FavorCpu | PolicyKind::CpuWithStarvationGuard => Loser::Gpu,
            PolicyKind::FavorGpu => Loser::Cpu,
        }
    }

    /// Under favor-GPU, validation must NOT apply CPU values during
    /// the checking pass (apply is conditional on success, §IV-E).
    pub fn conditional_apply(&self) -> bool {
        self.kind == PolicyKind::FavorGpu
    }

    /// Whether the CPU is restricted to read-only transactions this round.
    pub fn cpu_read_only(&self) -> bool {
        self.cpu_read_only_round
    }

    /// Record a round outcome; updates the starvation guard.
    pub fn on_round(&mut self, committed: bool) {
        if committed {
            self.consecutive_gpu_aborts = 0;
            self.cpu_read_only_round = false;
            return;
        }
        if self.loser() == Loser::Gpu {
            self.consecutive_gpu_aborts += 1;
            if self.kind == PolicyKind::CpuWithStarvationGuard
                && self.consecutive_gpu_aborts >= self.starvation_limit
            {
                // §IV-E: only read-only CPU txns next round => the GPU is
                // guaranteed to validate successfully.
                self.cpu_read_only_round = true;
            }
        }
    }

    /// Consecutive GPU-losing rounds so far.
    pub fn gpu_abort_streak(&self) -> u32 {
        self.consecutive_gpu_aborts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn favor_cpu_discards_gpu() {
        let p = Policy::new(PolicyKind::FavorCpu, 3);
        assert_eq!(p.loser(), Loser::Gpu);
        assert!(!p.conditional_apply());
    }

    #[test]
    fn favor_gpu_discards_cpu_and_defers_apply() {
        let p = Policy::new(PolicyKind::FavorGpu, 3);
        assert_eq!(p.loser(), Loser::Cpu);
        assert!(p.conditional_apply());
    }

    #[test]
    fn starvation_guard_engages_and_releases() {
        let mut p = Policy::new(PolicyKind::CpuWithStarvationGuard, 2);
        p.on_round(false);
        assert!(!p.cpu_read_only(), "below limit");
        p.on_round(false);
        assert!(p.cpu_read_only(), "limit hit: next round is read-only");
        // A read-only CPU round always validates; the streak resets.
        p.on_round(true);
        assert!(!p.cpu_read_only());
        assert_eq!(p.gpu_abort_streak(), 0);
    }

    #[test]
    fn plain_favor_cpu_never_restricts() {
        let mut p = Policy::new(PolicyKind::FavorCpu, 1);
        for _ in 0..5 {
            p.on_round(false);
        }
        assert!(!p.cpu_read_only());
        assert_eq!(p.gpu_abort_streak(), 5);
    }
}
