//! The synchronization-round state machine (paper §IV-C/§IV-D).
//!
//! Each round has three phases — execution, validation, merge — driven by a
//! hybrid engine: all *data* operations are real (CPU transactions execute
//! through a guest TM against the CPU STMR replica; GPU batches and chunk
//! validation execute through the device backends, native or PJRT), while
//! *time* is virtual, advanced by the cost models of DESIGN.md §2 (bus
//! latency/bandwidth, kernel activation latency, per-transaction and
//! per-log-entry costs).  This is what lets a machine without a discrete
//! GPU reproduce the paper's timing phenomenology with real state.
//!
//! The engine implements both the basic algorithm (Fig. 1a: blocking
//! validation and merge) and the optimized SHeTM (Fig. 1b: log streaming
//! overlapped with CPU processing, GPU double buffering via the shadow
//! copy, early validation, coalesced merge transfers), plus the §IV-E
//! conflict-resolution policies.

use anyhow::Result;

use super::logs::RoundLog;
use super::policy::{Loser, Policy};
use super::stats::{RoundStats, RunStats};
use crate::bus::{BusModel, BusTimeline};
use crate::config::PolicyKind;
use crate::gpu::{GpuDevice, LogChunk};
use crate::stm::{SharedStmr, WriteEntry};
use crate::telemetry::{RoundObs, Telemetry};

/// Algorithm variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// §IV-C basic algorithm: blocking validation + merge, no shadow copy,
    /// logs shipped only after the execution phase ends.
    Basic,
    /// §IV-D optimized SHeTM (the default).
    Optimized,
}

/// Result of one CPU execution slice.
#[derive(Debug, Clone, Copy, Default)]
pub struct CpuSlice {
    /// Transactions committed in the slice.
    pub commits: u64,
    /// Execution attempts (commits + guest-TM retries).
    pub attempts: u64,
}

/// The CPU side of the platform, as the engine sees it: a driver that runs
/// `dur_s` virtual seconds of transaction processing and appends committed
/// write-sets to a log.
pub trait CpuDriver {
    /// Run transactions for exactly `dur_s` virtual seconds, appending
    /// committed `(addr, val, ts)` entries to `log`.
    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice;

    /// The CPU STMR replica (merge installs into it).
    fn stmr(&self) -> &SharedStmr;

    /// Restrict the next slices to read-only transactions (starvation
    /// guard, §IV-E).
    fn set_read_only(&mut self, _ro: bool) {}

    /// Snapshot the CPU state (favor-GPU policy; the paper uses fork/COW).
    ///
    /// The default stores a full-region copy inside the driver's
    /// [`SharedStmr`], so `PolicyKind::FavorGpu` works with every driver
    /// out of the box; drivers with extra host-side state must override
    /// this (and [`Self::rollback`]) to save it alongside.
    fn snapshot(&mut self) {
        self.stmr().save_snapshot();
    }

    /// Restore the snapshot (favor-GPU round abort).
    fn rollback(&mut self) {
        self.stmr().restore_snapshot();
    }

    /// Round-boundary epoch reset: the engine calls this after every
    /// merge, once all outstanding log entries have been renumbered into
    /// `1..=base`.  Drivers owning a guest TM forward to
    /// [`crate::stm::GuestTm::epoch_reset`] so the shared commit clock
    /// restarts and never exhausts the i32 timestamp range the device
    /// kernels use.  The default is a no-op (legacy grow-forever clock).
    fn epoch_reset(&mut self, _base: i64) {}
}

impl CpuDriver for Box<dyn CpuDriver> {
    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        (**self).run(dur_s, log)
    }

    fn stmr(&self) -> &SharedStmr {
        (**self).stmr()
    }

    fn set_read_only(&mut self, ro: bool) {
        (**self).set_read_only(ro)
    }

    fn snapshot(&mut self) {
        (**self).snapshot()
    }

    fn rollback(&mut self) {
        (**self).rollback()
    }

    fn epoch_reset(&mut self, base: i64) {
        (**self).epoch_reset(base)
    }
}

impl CpuDriver for Box<dyn CpuDriver + Send> {
    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        (**self).run(dur_s, log)
    }

    fn stmr(&self) -> &SharedStmr {
        (**self).stmr()
    }

    fn set_read_only(&mut self, ro: bool) {
        (**self).set_read_only(ro)
    }

    fn snapshot(&mut self) {
        (**self).snapshot()
    }

    fn rollback(&mut self) {
        (**self).rollback()
    }

    fn epoch_reset(&mut self, base: i64) {
        (**self).epoch_reset(base)
    }
}

/// Result of one GPU execution slice.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuSlice {
    /// Transactions speculatively committed.
    pub commits: u64,
    /// Transactions attempted (includes intra-batch priority aborts).
    pub attempts: u64,
    /// Kernel activations.
    pub batches: u64,
    /// Device compute seconds actually used (<= budget; the remainder is
    /// idle because another whole batch does not fit).
    pub busy_s: f64,
}

/// The GPU side: a driver that feeds batches to the device under a compute
/// budget.
pub trait GpuDriver {
    /// Pre-slice hook: the engine calls this on the coordinator thread, in
    /// device-index order, immediately before every [`Self::run`] slice
    /// with the same `budget_s` the slice will receive.
    ///
    /// Drivers whose batch generation draws from *shared* state (a request
    /// dispatcher, a shared RNG) must do all of that shared access here and
    /// stash the drawn work locally, so that [`Self::run`] touches only
    /// driver-local state.  That is what lets the threaded
    /// [`ClusterEngine`] run per-device slices concurrently and still be
    /// bit-identical to the sequential schedule (DESIGN.md §8): shared
    /// draws happen at a deterministic point in a deterministic order, and
    /// the parallel phase is data-disjoint.  Drivers with purely local
    /// generators (the common case) keep the default no-op.
    ///
    /// [`ClusterEngine`]: crate::cluster::ClusterEngine
    fn prepare(&mut self, _budget_s: f64) {}

    /// Execute whole batches while they fit in `budget_s` device-seconds.
    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice>;

    /// Round ended: `committed` tells the driver whether its speculative
    /// work survived (on `false` it must restore/requeue consumed input).
    fn on_round_end(&mut self, _committed: bool) {}
}

impl GpuDriver for Box<dyn GpuDriver> {
    fn prepare(&mut self, budget_s: f64) {
        (**self).prepare(budget_s)
    }

    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
        (**self).run(device, budget_s)
    }

    fn on_round_end(&mut self, committed: bool) {
        (**self).on_round_end(committed)
    }
}

impl GpuDriver for Box<dyn GpuDriver + Send> {
    fn prepare(&mut self, budget_s: f64) {
        (**self).prepare(budget_s)
    }

    fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
        (**self).run(device, budget_s)
    }

    fn on_round_end(&mut self, committed: bool) {
        (**self).on_round_end(committed)
    }
}

/// Cost model for device compute and local copies (bus costs live in
/// [`BusModel`]).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Host->device bus.
    pub bus_h2d: BusModel,
    /// Device->host bus.
    pub bus_d2h: BusModel,
    /// Fixed kernel-activation latency.
    pub gpu_kernel_latency_s: f64,
    /// Per-transaction GPU execution time.
    pub gpu_txn_s: f64,
    /// Per-log-entry validation/apply time on the GPU.
    pub gpu_validate_entry_s: f64,
    /// Per-chunk signature-check time (`hetm.chunk_filter`): the cost of
    /// testing a chunk's conflict-prefilter signature against the
    /// read-set bitmap.  Charged for every chunk while filtering is on;
    /// a filtered chunk pays ONLY this (its conflict-free scatter apply
    /// overlaps the next chunk's bus-in), an unfiltered chunk pays it on
    /// top of the ordinary per-entry pass.
    pub gpu_sig_check_s: f64,
    /// Device-to-device copy bandwidth (shadow snapshot).
    pub gpu_dtd_bytes_per_s: f64,
    /// CPU-side snapshot cost (favor-GPU fork/COW) per byte.
    pub cpu_snapshot_bytes_per_s: f64,
}

impl CostModel {
    /// Derive a per-device model from this baseline and a relative
    /// compute-speed factor (`1.0` = the baseline device).  Compute
    /// terms (kernel latency, per-transaction time, per-entry
    /// validation, signature checks) divide by `speed` and the
    /// device-local copy bandwidth multiplies by it; bus bandwidths and
    /// the CPU-side snapshot rate describe the host interconnect and
    /// are left untouched.  `scaled(1.0)` is a bitwise identity (IEEE
    /// `x / 1.0 == x`), which keeps uniform clusters bit-identical to
    /// the pre-heterogeneous code path.
    pub fn scaled(&self, speed: f64) -> CostModel {
        CostModel {
            bus_h2d: self.bus_h2d,
            bus_d2h: self.bus_d2h,
            gpu_kernel_latency_s: self.gpu_kernel_latency_s / speed,
            gpu_txn_s: self.gpu_txn_s / speed,
            gpu_validate_entry_s: self.gpu_validate_entry_s / speed,
            gpu_sig_check_s: self.gpu_sig_check_s / speed,
            gpu_dtd_bytes_per_s: self.gpu_dtd_bytes_per_s * speed,
            cpu_snapshot_bytes_per_s: self.cpu_snapshot_bytes_per_s,
        }
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            bus_h2d: BusModel::default(),
            bus_d2h: BusModel::default(),
            gpu_kernel_latency_s: 20e-6,
            gpu_txn_s: 90e-9,
            gpu_validate_entry_s: 1.2e-9,
            // A few hundred ns: a bitmap-range test in the validation
            // kernel's prologue, far below one chunk's per-entry pass.
            gpu_sig_check_s: 250e-9,
            // GTX-1080-class device-to-device copy.
            gpu_dtd_bytes_per_s: 200e9,
            // COW fork: page-table work only, very high effective rate.
            cpu_snapshot_bytes_per_s: 2e12,
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Execution-phase duration (s).
    pub period_s: f64,
    /// Algorithm variant.
    pub variant: Variant,
    /// Early validation enabled (§IV-D; Optimized only).
    pub early_validation: bool,
    /// Early validations per round (the round is split into this+1
    /// segments).
    pub early_points: usize,
    /// Log entries per chunk (paper: 4096 = 48 KB).
    pub chunk_entries: usize,
    /// Deduplicate each drain window last-write-wins before chunking
    /// (`hetm.log_compaction`): wire bytes and validation work scale with
    /// the write-set footprint instead of the commit count.
    pub log_compaction: bool,
    /// Attach a conflict-prefilter signature to every chunk and skip the
    /// per-entry validation pass on provable non-intersection
    /// (`hetm.chunk_filter`).
    pub chunk_filter: bool,
    /// Conflict-resolution policy.
    pub policy: PolicyKind,
    /// Consecutive GPU aborts before the starvation guard engages.
    pub starvation_limit: u32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            period_s: 0.080,
            variant: Variant::Optimized,
            early_validation: true,
            early_points: 3,
            chunk_entries: crate::bus::chunking::LOG_CHUNK_ENTRIES,
            log_compaction: false,
            chunk_filter: false,
            policy: PolicyKind::FavorCpu,
            starvation_limit: 3,
        }
    }
}

/// The SHeTM round engine.
pub struct RoundEngine<C: CpuDriver, G: GpuDriver> {
    /// Engine configuration (variant, period, policy, ...).
    pub cfg: EngineConfig,
    /// Cost model used to advance virtual time.
    pub cost: CostModel,
    /// The simulated accelerator.
    pub device: GpuDevice,
    /// CPU-side driver.
    pub cpu: C,
    /// GPU-side driver.
    pub gpu: G,
    /// Aggregate statistics.
    pub stats: RunStats,
    /// Per-round statistics (most recent rounds, ring-limited).
    pub round_log: Vec<RoundStats>,
    /// Telemetry recorder (no-op unless installed by the session
    /// builder).  Observations are gathered only when
    /// `tel.enabled()`; a disabled recorder costs one branch per round.
    pub tel: Telemetry,
    /// Durability hook (checkpoints at the round barrier).  `None` unless
    /// the session builder configured a checkpoint directory; the off
    /// path costs one `Option` test per round.
    pub dur: Option<Box<crate::durability::DurabilityHook>>,

    policy: Policy,
    h2d: BusTimeline,
    d2h: BusTimeline,
    /// Virtual time of the current round's start.
    t: f64,
    /// When the CPU may resume processing (merge install blocks it).
    cpu_avail: f64,
    log: RoundLog,
    carry: Vec<WriteEntry>,
    scratch: Vec<WriteEntry>,
    /// Round-lifetime buffers, reused across rounds (DESIGN.md §12
    /// arena): shipped chunks + their bus-arrival times, merge transfer
    /// ranges, and per-chunk early-validation conflict counts.  Steady
    /// state rounds allocate nothing.
    chunks: Vec<LogChunk>,
    arrivals: Vec<f64>,
    ranges: Vec<(usize, usize)>,
    early_conf: Vec<u32>,
}

impl<C: CpuDriver, G: GpuDriver> RoundEngine<C, G> {
    /// Assemble an engine; the device's STMR must equal the CPU driver's.
    pub fn new(cfg: EngineConfig, cost: CostModel, device: GpuDevice, cpu: C, gpu: G) -> Self {
        assert_eq!(
            device.n_words(),
            cpu.stmr().len(),
            "CPU and GPU replicas must cover the same STMR"
        );
        let policy = Policy::new(cfg.policy, cfg.starvation_limit);
        let log = Self::make_log(&cfg, &device);
        RoundEngine {
            cfg,
            cost,
            device,
            cpu,
            gpu,
            stats: RunStats::default(),
            round_log: Vec::new(),
            tel: Telemetry::off(),
            dur: None,
            policy,
            h2d: BusTimeline::new(),
            d2h: BusTimeline::new(),
            t: 0.0,
            cpu_avail: 0.0,
            log,
            carry: Vec::new(),
            scratch: Vec::new(),
            chunks: Vec::new(),
            arrivals: Vec::new(),
            ranges: Vec::new(),
            early_conf: Vec::new(),
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Build a round log configured per the engine config (chunk size,
    /// compaction, signature shift from the device's bitmap).
    fn make_log(cfg: &EngineConfig, device: &GpuDevice) -> RoundLog {
        let mut log = RoundLog::with_chunk_entries(cfg.chunk_entries);
        log.set_compaction(cfg.log_compaction);
        if cfg.chunk_filter {
            log.set_sig_shift(Some(device.rs_bmp().shift()));
        }
        log
    }

    /// Change the log-chunk size (ablation benches). Must be called
    /// between rounds; the log is rebuilt at the new chunking (compaction
    /// and signature settings are preserved) and re-seeded with its
    /// carried prefix — commits already counted on the CPU (the §IV-D
    /// validation-window carry, [`Self::inject_external`] entries) still
    /// ship next round instead of being silently dropped.
    pub fn set_chunk_entries(&mut self, n: usize) {
        self.cfg.chunk_entries = n;
        let carried: Vec<WriteEntry> = self.log.entries().to_vec();
        self.log = Self::make_log(&self.cfg, &self.device);
        self.log.reset_with_carry(&carried);
        self.carry.clear();
    }

    /// Copy the CPU STMR into the device replica (initial alignment; both
    /// replicas must start identical — a consistent snapshot, §IV-C.1).
    pub fn align_replicas(&mut self) {
        let snap = self.cpu.stmr().snapshot();
        self.device.stmr_mut().copy_from_slice(&snap);
    }

    /// Run `n` synchronization rounds.
    pub fn run_rounds(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.run_round()?;
        }
        Ok(())
    }

    /// Run rounds until at least `dur_s` of virtual time has elapsed.
    pub fn run_for(&mut self, dur_s: f64) -> Result<()> {
        let end = self.t + dur_s;
        while self.t < end {
            self.run_round()?;
        }
        Ok(())
    }

    /// Quiesce: run one zero-length round so that commits carried over
    /// from the last validation window (the §IV-D non-blocking CPU) are
    /// shipped and applied.  After a committed drain the two replicas are
    /// guaranteed identical; between ordinary rounds the GPU legitimately
    /// lags by the carry.
    pub fn drain(&mut self) -> Result<()> {
        let saved = self.cfg.clone();
        self.cfg.period_s = 0.0;
        self.cfg.early_validation = false;
        let r = self.run_round();
        self.cfg = saved;
        r
    }

    /// Enqueue externally-committed CPU write entries (the
    /// [`crate::session::Session::txn`] entry point).  The guest TM has
    /// already applied them to the CPU STMR; they ship to the device at
    /// the start of the next round as *carried* commits — they happened
    /// before that round began, so, exactly like the §IV-D
    /// validation-window carry, they survive a favor-GPU round abort.
    /// Instantaneous in virtual time.
    pub fn inject_external(&mut self, entries: &[WriteEntry], commits: u64, attempts: u64) {
        self.log.extend_carried(entries);
        self.stats.cpu_commits += commits;
        self.stats.cpu_attempts += attempts;
        if self.tel.enabled() {
            self.tel.record_txn(entries.len() as u64, attempts, self.t);
        }
    }

    /// Merge-phase transfer ranges: the GPU write-set rounded out to the
    /// paper's 16 KB transfer granularity and coalesced (§IV-D), scanned
    /// into the reused `self.ranges` buffer.
    fn merge_ranges_into(&mut self) {
        let granule_words = (crate::bus::chunking::MERGE_GRANULE_BYTES / 4) as usize;
        self.device
            .ws_bmp()
            .dirty_word_ranges_coarse_into(granule_words, &mut self.ranges);
    }

    /// Execute one synchronization round.
    pub fn run_round(&mut self) -> Result<()> {
        let optimized = self.cfg.variant == Variant::Optimized;
        let t0 = self.t;
        let mut rs = RoundStats {
            t_start: t0,
            ..Default::default()
        };
        let n_bytes = (self.device.n_words() * 4) as u64;

        // Telemetry scratch: per-chunk cost samples gathered only when a
        // recorder is installed, folded into one `record_round` at the
        // round barrier (same shape as the cluster engine's lane fold).
        let tel_on = self.tel.enabled();
        let mut obs_vcost: Vec<f64> = Vec::new();
        let mut obs_ship: Vec<f64> = Vec::new();
        let mut obs_merge: Vec<f64> = Vec::new();

        let read_only = self.policy.cpu_read_only();
        self.cpu.set_read_only(read_only);
        if self.policy.conditional_apply() {
            // favor-GPU needs a CPU snapshot to roll back to (fork/COW).
            self.cpu.snapshot();
        }

        // --- Execution phase --------------------------------------------
        self.device.begin_round();
        let mut gpu_cursor = t0;
        if optimized {
            // Shadow copy (DtD) before the GPU may process (§IV-D).
            let dtd = n_bytes as f64 / self.cost.gpu_dtd_bytes_per_s;
            gpu_cursor += dtd;
            rs.gpu_phases.merge_s += dtd;
        }
        let exec_end_target = t0 + self.cfg.period_s;

        // Arena buffers: recycled at the previous round's wrap-up, so
        // these clears are no-ops in steady state.
        self.chunks.clear();
        self.arrivals.clear();
        let mut early_abort = false;
        let mut early_conf = 0u64;

        let mut cpu_cursor = self.cpu_avail.max(t0);
        rs.cpu_phases.blocked_s += cpu_cursor - t0;
        let segments = if optimized && self.cfg.early_validation {
            self.cfg.early_points + 1
        } else {
            1
        };
        let seg_dur = (exec_end_target - cpu_cursor).max(0.0) / segments as f64;

        for s in 0..segments {
            // CPU slice (real transactions through the guest TM).
            self.scratch.clear();
            let cs = self.cpu.run(seg_dur, &mut self.scratch);
            self.log.append(&self.scratch);
            rs.cpu_commits += cs.commits;
            rs.cpu_attempts += cs.attempts;
            rs.cpu_phases.processing_s += seg_dur;
            cpu_cursor += seg_dur;

            // GPU slice covering the same virtual span.  `prepare` runs
            // first so shared-state draws happen at the same deterministic
            // point as in the (possibly threaded) cluster engine.
            let budget = (cpu_cursor - gpu_cursor).max(0.0);
            self.gpu.prepare(budget);
            let gs = self.gpu.run(&mut self.device, budget)?;
            rs.gpu_commits += gs.commits;
            rs.gpu_attempts += gs.attempts;
            rs.gpu_batches += gs.batches;
            rs.gpu_phases.processing_s += gs.busy_s;
            // Drivers may carry unusable sub-batch budget across segments
            // (a real GPU's kernel stream is not segment-quantized), so
            // `busy_s` can slightly exceed one segment's budget.
            rs.gpu_phases.blocked_s += (budget - gs.busy_s).max(0.0);
            gpu_cursor = cpu_cursor;

            // Non-blocking log streaming (§IV-D): ship full chunks now.
            if optimized {
                let n0 = self.chunks.len();
                self.log.drain_full_chunks(&mut self.chunks);
                for c in &self.chunks[n0..] {
                    let dur = self.cost.bus_h2d.transfer_secs(c.wire_bytes());
                    let (_, end) = self.h2d.schedule(cpu_cursor, dur);
                    self.arrivals.push(end);
                    if tel_on {
                        obs_ship.push(dur);
                    }
                }
            }

            // Early validation between segments (§IV-D): check arrived
            // chunks against the current read-set bitmap without applying.
            if optimized && self.cfg.early_validation && s + 1 < segments {
                let arrived = self.arrivals.iter().filter(|&&a| a <= cpu_cursor).count();
                let mut conf = 0u32;
                let cost = if self.cfg.chunk_filter {
                    // Signature-prefiltered scan: a provably-clean chunk
                    // pays only the per-chunk signature test.
                    let mut cost = 0.0;
                    for c in self.chunks.iter().take(arrived) {
                        cost += self.cost.gpu_sig_check_s;
                        if self.device.chunk_provably_clean(c) {
                            continue;
                        }
                        conf += self.device.early_validate_chunk(c);
                        cost +=
                            self.cfg.chunk_entries as f64 * self.cost.gpu_validate_entry_s;
                    }
                    cost
                } else {
                    // Unfiltered: one batched, read-only scan — fanned
                    // over the device's validate-thread budget, summed in
                    // chunk order (bit-identical to the scalar loop).
                    self.device
                        .early_validate_chunks_into(&self.chunks[..arrived], &mut self.early_conf);
                    conf += self.early_conf.iter().sum::<u32>();
                    arrived as f64
                        * self.cfg.chunk_entries as f64
                        * self.cost.gpu_validate_entry_s
                };
                gpu_cursor += cost;
                rs.gpu_phases.validation_s += cost;
                if conf > 0 {
                    // Conflict already certain: finish the round now
                    // instead of wasting the rest of the period.  The
                    // main validation pass below is skipped too — the
                    // round's fate is decided.
                    early_abort = true;
                    early_conf = u64::from(conf);
                    rs.early_aborted = true;
                    break;
                }
            }
        }

        // Drain the remaining (tail) chunks.
        {
            let n0 = self.chunks.len();
            self.log.drain_all(&mut self.chunks);
            let mut ship_end = cpu_cursor;
            for c in &self.chunks[n0..] {
                let dur = self.cost.bus_h2d.transfer_secs(c.wire_bytes());
                let (_, end) = self.h2d.schedule(cpu_cursor, dur);
                self.arrivals.push(end);
                if tel_on {
                    obs_ship.push(dur);
                }
                if !optimized {
                    // Basic: the CPU is blocked while shipping its logs.
                    rs.cpu_phases.validation_s += dur;
                    ship_end = end;
                }
            }
            // Basic: the CPU cursor follows the shipping it was blocked
            // on (charging the time without advancing the cursor would
            // recount the same span as blocked during validation).
            cpu_cursor = cpu_cursor.max(ship_end);
        }

        // --- Validation phase --------------------------------------------
        let conditional = self.policy.conditional_apply();
        let mut conflicts = 0u64;
        let chunk_cost = self.cfg.chunk_entries as f64 * self.cost.gpu_validate_entry_s;
        let filter = self.cfg.chunk_filter;
        for (c, &arr) in self.chunks.iter().zip(&self.arrivals) {
            let start = arr.max(gpu_cursor);
            rs.gpu_phases.blocked_s += start - gpu_cursor;
            if early_abort {
                // Fate decided by early validation: the chunk still lands
                // on the device (apply/rollback needs it) but the
                // per-entry pass is pure waste — skip it.
                rs.chunks_skipped_post_abort += 1;
                gpu_cursor = start;
                continue;
            }
            let mut vcost = 0.0;
            let clean = filter && self.device.chunk_provably_clean(c);
            if filter {
                vcost += self.cost.gpu_sig_check_s;
            }
            if clean {
                rs.chunks_filtered += 1;
                if !conditional {
                    // Provably conflict-free: apply as a plain scatter,
                    // skipping the per-entry conflict pass.
                    let n = self.device.validate_chunk(c)?;
                    debug_assert_eq!(n, 0, "signature filter must be conservative");
                }
            } else {
                conflicts += if conditional {
                    // favor-GPU: check without applying (§IV-E).
                    u64::from(self.device.early_validate_chunk(c))
                } else {
                    u64::from(self.device.validate_chunk(c)?)
                };
                vcost += chunk_cost;
            }
            if tel_on {
                obs_vcost.push(vcost);
            }
            gpu_cursor = start + vcost;
            rs.gpu_phases.validation_s += vcost;
        }
        if early_abort {
            conflicts += early_conf;
        }
        rs.chunks = self.chunks.len() as u64;
        rs.log_entries_raw = self.log.raw_appended();
        rs.log_entries_shipped = self.log.shipped();
        rs.conflict_entries = conflicts;
        let tv = gpu_cursor;

        // Non-blocking CPU (§IV-D): keep processing during validation;
        // commits logged for the NEXT round.  Suppressed in zero-period
        // drain rounds (which flush the carry, not grow it) and under the
        // favor-GPU policy: commits made during validation postdate the
        // round's rollback snapshot, so they could not be undone if the
        // NEXT round aborts the CPU — the paper's fork-at-phase-start
        // sketch (§IV-E) implies the CPU blocks there too.
        if optimized && tv > cpu_cursor && self.cfg.period_s > 0.0 && !conditional {
            let bonus = tv - cpu_cursor;
            self.scratch.clear();
            let cs = self.cpu.run(bonus, &mut self.scratch);
            self.carry.extend_from_slice(&self.scratch);
            rs.cpu_commits += cs.commits;
            rs.cpu_attempts += cs.attempts;
            rs.cpu_phases.processing_s += bonus;
            cpu_cursor = tv;
        } else if tv > cpu_cursor {
            rs.cpu_phases.blocked_s += tv - cpu_cursor;
            cpu_cursor = tv;
        }

        // --- Merge phase ---------------------------------------------------
        // Speculative commits as of the verdict, before loser-discard
        // zeroing (the per-device series the trace reports).
        let dev_commits_pre = rs.gpu_commits;
        let ok = conflicts == 0;
        rs.committed = ok;
        let round_end;
        if ok {
            if conditional {
                // favor-GPU deferred apply: now that validation succeeded,
                // apply the CPU log chunks to the device replica.  The
                // applies stay sequential in shipping order — the `>=`
                // freshness rule is order-dependent.
                for c in &self.chunks {
                    self.device.validate_chunk(c)?;
                }
                let cost = self.chunks.len() as f64 * chunk_cost;
                gpu_cursor += cost;
                rs.gpu_phases.merge_s += cost;
            }
            // DtH transfer of the GPU's dirty regions at the paper's 16 KB
            // merge granularity, coalesced (§IV-D); install into the CPU
            // replica.  (Post-validation, the GPU's words equal the CPU's
            // everywhere the GPU did not write, so rounding ranges out to
            // coarse granules copies only agreeing bytes.)
            self.merge_ranges_into();
            let mut dth_end = gpu_cursor;
            for &(s, e) in &self.ranges {
                let bytes = ((e - s) * 4) as u64;
                let dur = self.cost.bus_d2h.transfer_secs(bytes);
                let (_, end) = self.d2h.schedule(gpu_cursor, dur);
                dth_end = end;
                if tel_on {
                    obs_merge.push(dur);
                }
                let data = &self.device.stmr()[s..e];
                self.cpu.stmr().install_range(s, data);
            }
            // Carry-window CPU commits re-win their words locally: they
            // serialize AFTER this round's GPU transactions (see DESIGN.md).
            for e in &self.carry {
                self.cpu.stmr().store(e.addr as usize, e.val);
            }
            if optimized {
                // GPU resumes immediately (the next round's shadow feeds
                // nothing — the DtH reads finished state; device free at tv).
                rs.cpu_phases.merge_s += dth_end - cpu_cursor;
                self.cpu_avail = dth_end;
                round_end = gpu_cursor;
            } else {
                // Basic: both devices blocked until the transfer completes.
                rs.cpu_phases.merge_s += dth_end - cpu_cursor;
                rs.gpu_phases.merge_s += dth_end - gpu_cursor;
                self.cpu_avail = dth_end;
                round_end = dth_end;
            }
        } else {
            rs.discarded_commits = match self.policy.loser() {
                Loser::Gpu => {
                    let discarded = rs.gpu_commits;
                    rs.gpu_commits = 0;
                    if optimized {
                        // Shadow + CPU-log replay (§IV-D rollback latency).
                        self.device.rollback_with_logs(&self.chunks);
                        let cost = self.chunks.len() as f64 * chunk_cost;
                        gpu_cursor += cost;
                        rs.gpu_phases.merge_s += cost;
                        round_end = gpu_cursor;
                        self.cpu_avail = cpu_cursor;
                    } else {
                        // Basic: re-copy every GPU-dirty region from the CPU
                        // (16 KB merge granularity, as in the merge phase).
                        self.merge_ranges_into();
                        let mut h2d_end = gpu_cursor;
                        for &(s, e) in &self.ranges {
                            let bytes = ((e - s) * 4) as u64;
                            let dur = self.cost.bus_h2d.transfer_secs(bytes);
                            let (_, end) = self.h2d.schedule(gpu_cursor, dur);
                            h2d_end = end;
                            for w in s..e {
                                let v = self.cpu.stmr().load(w);
                                self.device.stmr_mut()[w] = v;
                            }
                        }
                        rs.gpu_phases.merge_s += h2d_end - gpu_cursor;
                        rs.cpu_phases.blocked_s += h2d_end - cpu_cursor;
                        self.cpu_avail = h2d_end;
                        round_end = h2d_end;
                    }
                    discarded
                }
                Loser::Cpu => {
                    // favor-GPU: roll the CPU back to its round-start
                    // snapshot, then install the GPU's dirty regions.
                    // Commits carried from before this round survive the
                    // rollback (the snapshot postdates them), so their
                    // still-unshipped log prefix is preserved; only this
                    // round's entries (including its bonus window, held in
                    // `carry`) are discarded.
                    let discarded = rs.cpu_commits;
                    self.cpu.rollback();
                    self.carry.clear();
                    self.log.truncate_to_carried();
                    let snap_cost = n_bytes as f64 / self.cost.cpu_snapshot_bytes_per_s;
                    self.merge_ranges_into();
                    let mut dth_end = gpu_cursor + snap_cost;
                    for &(s, e) in &self.ranges {
                        let bytes = ((e - s) * 4) as u64;
                        let dur = self.cost.bus_d2h.transfer_secs(bytes);
                        let (_, end) = self.d2h.schedule(dth_end, dur);
                        dth_end = end;
                        let data = &self.device.stmr()[s..e];
                        self.cpu.stmr().install_range(s, data);
                    }
                    rs.cpu_commits = 0;
                    rs.cpu_phases.merge_s += dth_end - cpu_cursor;
                    self.cpu_avail = dth_end;
                    round_end = gpu_cursor;
                    discarded
                }
            };
        }

        // --- Round wrap-up -------------------------------------------------
        let cpu_lost = !ok && self.policy.loser() == Loser::Cpu;
        // Fold this round's write footprint into the durability dirty
        // accumulator while it is still intact: the CPU log (carried
        // prefix included), the next round's carry, and the device
        // write-set bitmap.  Over-approximation is safe (extra clean
        // pages in a checkpoint), so rolled-back writes need no special
        // casing.
        if let Some(dur) = &mut self.dur {
            dur.mark_entries(self.log.entries());
            dur.mark_entries(&self.carry);
            dur.mark_device(self.device.ws_bmp());
        }
        self.policy.on_round(ok);
        self.gpu.on_round_end(ok);
        // Retire this round's chunk buffers into the log's arena so next
        // round's drains reuse them instead of allocating.
        self.log.recycle(&mut self.chunks);
        self.arrivals.clear();
        // Entries carried into the next round (zero when the CPU lost:
        // its branch already cleared the carry).
        let carried = self.carry.len() as u64;
        if !cpu_lost {
            self.log.reset_with_carry(&self.carry);
        }
        self.carry.clear();
        // Epoch reset (§IV-B clock): the log now holds exactly the next
        // round's carried prefix.  Renumber it into 1..=k, restart the
        // shared commit clock at k, and clear the device freshness array
        // — timestamps are only ever compared within one round, so this
        // preserves every validate/apply outcome bit for bit while
        // keeping the clock inside the i32 range forever.
        let base = self.log.rebase_epoch();
        self.cpu.epoch_reset(base);
        self.device.epoch_reset();
        rs.t_end = round_end;
        self.t = round_end;
        self.stats.absorb(&rs);
        if tel_on {
            // Derive the round's telemetry at the barrier, purely from
            // per-round data — the cluster engine emits bit-identical
            // observations at n_gpus = 1 (see DESIGN.md §11).
            let dev_phases = [rs.gpu_phases];
            let dev_commits = [dev_commits_pre];
            let chunk_validate = [std::mem::take(&mut obs_vcost)];
            let bus_ship = [std::mem::take(&mut obs_ship)];
            let bus_merge = [std::mem::take(&mut obs_merge)];
            let h2d_busy = [self.h2d.busy_total()];
            let d2h_busy = [self.d2h.busy_total()];
            self.tel.record_round(&RoundObs {
                round: self.stats.rounds - 1,
                rs: &rs,
                read_only,
                abort_streak: self.policy.gpu_abort_streak(),
                epoch_base: base,
                carried,
                dev_phases: &dev_phases,
                dev_commits: &dev_commits,
                chunk_validate_s: &chunk_validate,
                bus_ship_s: &bus_ship,
                bus_merge_s: &bus_merge,
                h2d_busy_s: &h2d_busy,
                d2h_busy_s: &d2h_busy,
            });
        }
        // Round-barrier checkpoint (DESIGN.md §13).  Runs after the epoch
        // rebase so the log holds exactly the renumbered carried prefix
        // the WAL must copy; costs zero virtual time and touches no
        // statistics, so durability-on runs stay bit-identical to
        // durability-off runs.
        if let Some(dur) = self.dur.as_mut().filter(|d| d.due(self.stats.rounds)) {
            let stats_fnv = crate::durability::stats_digest(&self.stats);
            let carried_shards = [self.log.entries()];
            if let Some(sum) = dur.maybe_checkpoint(
                self.stats.rounds,
                self.t,
                base,
                &carried_shards,
                self.cpu.stmr(),
                stats_fnv,
                None,
            )? {
                self.tel.record_checkpoint(&sum);
            }
        }
        if self.round_log.len() < 10_000 {
            self.round_log.push(rs);
        }
        Ok(())
    }

    /// The carried write-log prefix that will seed the next round
    /// (renumbered `ts = 1..=k` by the epoch rebase).  Recovery compares
    /// this against the checkpoint's WAL copy to prove bit-identity.
    pub fn carried_entries(&self) -> &[WriteEntry] {
        self.log.entries()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{Backend, TxnBatch};
    use crate::stm::{GlobalClock, GuestTm, SharedStmr, WriteEntry};
    use crate::stm::tinystm::TinyStm;
    use std::sync::Arc;

    /// Deterministic scripted CPU driver: writes `addr = round_counter`
    /// style entries through a real TinySTM.
    struct ScriptCpu {
        stmr: Arc<SharedStmr>,
        tm: Arc<TinyStm>,
        txns_per_sec: f64,
        addr_base: usize,
        counter: i32,
        ro: bool,
        debt: f64,
    }

    impl ScriptCpu {
        fn new(n: usize, txns_per_sec: f64, addr_base: usize) -> Self {
            let clock = Arc::new(GlobalClock::new());
            ScriptCpu {
                stmr: Arc::new(SharedStmr::new(n)),
                tm: Arc::new(TinyStm::with_clock(clock)),
                txns_per_sec,
                addr_base,
                counter: 0,
                ro: false,
                debt: 0.0,
            }
        }
    }

    impl CpuDriver for ScriptCpu {
        fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
            let want = dur_s * self.txns_per_sec + self.debt;
            let n = want.floor() as u64;
            self.debt = want - n as f64;
            let mut commits = 0;
            for _ in 0..n {
                if self.ro {
                    continue;
                }
                let addr = self.addr_base + (self.counter as usize % 16);
                let val = self.counter;
                self.counter += 1;
                self.tm.execute_into(
                    &self.stmr,
                    &mut |tx| {
                        let _ = tx.read(addr)?;
                        tx.write(addr, val)?;
                        Ok(())
                    },
                    log,
                );
                commits += 1;
            }
            CpuSlice {
                commits,
                attempts: commits,
            }
        }

        fn stmr(&self) -> &SharedStmr {
            &self.stmr
        }

        fn set_read_only(&mut self, ro: bool) {
            self.ro = ro;
        }
        // snapshot/rollback: the trait's default SharedStmr path — the
        // favor-GPU tests below are its regression coverage.
    }

    /// Scripted GPU driver: each batch writes a fixed disjoint region, and
    /// optionally reads an address the CPU writes (to force conflicts).
    struct ScriptGpu {
        batch_cost_s: f64,
        write_base: usize,
        read_conflict_addr: Option<usize>,
        counter: i32,
        carry: f64,
    }

    impl GpuDriver for ScriptGpu {
        fn run(&mut self, device: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
            let mut out = GpuSlice::default();
            let mut left = budget_s + self.carry;
            while left >= self.batch_cost_s {
                let mut b = TxnBatch::empty(4, 1, 1);
                for i in 0..4 {
                    b.read_idx[i] = match self.read_conflict_addr {
                        Some(a) if i == 0 => a as i32,
                        _ => -1,
                    };
                    b.write_idx[i] = (self.write_base + i) as i32;
                    b.write_val[i] = self.counter;
                    b.op[i] = 1;
                }
                self.counter += 1;
                let r = device.run_txn_batch(&b)?;
                out.commits += r.n_commits as u64;
                out.attempts += 4;
                out.batches += 1;
                out.busy_s += self.batch_cost_s;
                left -= self.batch_cost_s;
            }
            self.carry = left;
            Ok(out)
        }

        fn on_round_end(&mut self, _committed: bool) {
            self.carry = 0.0;
        }
    }

    fn engine(
        conflict: bool,
        variant: Variant,
        policy: PolicyKind,
    ) -> RoundEngine<ScriptCpu, ScriptGpu> {
        let n = 1024;
        let cpu = ScriptCpu::new(n, 10_000.0, 0); // writes words 0..16
        let gpu = ScriptGpu {
            batch_cost_s: 0.3e-3,
            write_base: 512,
            read_conflict_addr: conflict.then_some(3),
            counter: 0,
            carry: 0.0,
        };
        let device = GpuDevice::new(n, 0, Backend::Native);
        let cfg = EngineConfig {
            period_s: 0.010,
            variant,
            early_validation: false,
            policy,
            ..Default::default()
        };
        let mut e = RoundEngine::new(cfg, CostModel::default(), device, cpu, gpu);
        e.align_replicas();
        e
    }

    #[test]
    fn clean_round_merges_replicas() {
        for variant in [Variant::Optimized, Variant::Basic] {
            let mut e = engine(false, variant, PolicyKind::FavorCpu);
            e.run_rounds(3).unwrap();
            assert_eq!(e.stats.rounds_committed, 3, "{variant:?}");
            assert!(e.stats.cpu_commits > 0);
            assert!(e.stats.gpu_commits > 0);
            // Replica agreement: CPU and GPU STMRs identical after merge.
            let cpu_snap = e.cpu.stmr().snapshot();
            assert_eq!(&cpu_snap[..], e.device.stmr(), "{variant:?}");
        }
    }

    #[test]
    fn conflicting_round_favor_cpu_discards_gpu() {
        for variant in [Variant::Optimized, Variant::Basic] {
            let mut e = engine(true, variant, PolicyKind::FavorCpu);
            e.run_rounds(2).unwrap();
            assert_eq!(e.stats.rounds_committed, 0, "{variant:?}");
            assert_eq!(e.stats.gpu_commits, 0, "GPU work discarded");
            assert!(e.stats.discarded_commits > 0);
            assert!(e.stats.cpu_commits > 0, "CPU commits survive");
            // GPU writes must not be visible anywhere.
            assert_eq!(e.cpu.stmr().load(512), 0);
            assert_eq!(e.device.stmr()[512], 0);
            // CPU values must have reached the GPU replica regardless.
            let cpu_snap = e.cpu.stmr().snapshot();
            assert_eq!(&cpu_snap[..], e.device.stmr(), "{variant:?}");
        }
    }

    #[test]
    fn conflicting_round_favor_gpu_discards_cpu() {
        let mut e = engine(true, Variant::Optimized, PolicyKind::FavorGpu);
        e.run_rounds(1).unwrap();
        assert_eq!(e.stats.rounds_committed, 0);
        assert_eq!(e.stats.cpu_commits, 0, "CPU commits discarded");
        assert!(e.stats.gpu_commits > 0, "GPU commits survive");
        // GPU writes visible on both replicas; CPU writes rolled back.
        assert!(e.cpu.stmr().load(512) >= 0);
        assert_eq!(e.cpu.stmr().load(3), 0, "CPU write rolled back");
        assert_eq!(e.device.stmr()[3], 0);
    }

    #[test]
    fn starvation_guard_forces_read_only_round() {
        let mut e = engine(true, Variant::Optimized, PolicyKind::CpuWithStarvationGuard);
        e.cfg.starvation_limit = 2;
        e.policy = Policy::new(PolicyKind::CpuWithStarvationGuard, 2);
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 0);
        // Third round: CPU runs read-only => validation must succeed.
        e.run_rounds(1).unwrap();
        assert_eq!(e.stats.rounds_committed, 1, "read-only round validates");
    }

    #[test]
    fn longer_periods_amortize_sync_overhead() {
        let mut short = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        short.cfg.period_s = 0.002;
        short.run_for(0.4).unwrap();
        let mut long = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        long.cfg.period_s = 0.050;
        long.run_for(0.4).unwrap();
        assert!(
            long.stats.throughput() > short.stats.throughput(),
            "long {} <= short {}",
            long.stats.throughput(),
            short.stats.throughput()
        );
    }

    #[test]
    fn optimized_beats_basic_on_short_rounds() {
        let mut basic = engine(false, Variant::Basic, PolicyKind::FavorCpu);
        basic.cfg.period_s = 0.002;
        basic.run_for(0.4).unwrap();
        let mut opt = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        opt.cfg.period_s = 0.002;
        opt.run_for(0.4).unwrap();
        assert!(
            opt.stats.throughput() >= basic.stats.throughput(),
            "optimized {} < basic {}",
            opt.stats.throughput(),
            basic.stats.throughput()
        );
    }

    #[test]
    fn time_and_phases_are_accounted() {
        let mut e = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        e.run_rounds(5).unwrap();
        assert!(e.now() > 0.0);
        assert!(e.stats.duration_s > 0.0);
        assert!(e.stats.gpu_phases.processing_s > 0.0);
        assert!(e.stats.cpu_phases.processing_s > 0.0);
        assert!(e.stats.chunks > 0);
        assert_eq!(
            e.stats.log_entries_raw, e.stats.log_entries_shipped,
            "compaction off: every raw entry ships"
        );
        assert!(e.stats.log_entries_shipped > 0);
    }

    /// Satellite fix regression (fig-3-style basic-vs-optimized timing):
    /// the basic variant blocks the CPU while it ships its tail logs, so
    /// that time must advance the CPU cursor — charging it to
    /// `validation_s` while leaving the cursor behind double-counted the
    /// same span as `blocked_s` and understated round wall-clock.
    #[test]
    fn basic_tail_shipping_blocks_cpu_and_accounts_once() {
        let mut e = engine(false, Variant::Basic, PolicyKind::FavorCpu);
        // Small chunks so the tail shipping is many DMAs of real length.
        e.set_chunk_entries(16);
        e.run_rounds(4).unwrap();
        assert!(
            e.stats.cpu_phases.validation_s > 0.0,
            "basic CPU ships logs while blocked"
        );
        // Every CPU second is accounted exactly once: the per-phase sum
        // equals the round wall-clock (pre-fix it exceeded it by the
        // shipping time, which was charged AND re-counted as blocked).
        let total = e.stats.cpu_phases.total();
        let dur = e.stats.duration_s;
        assert!(
            (total - dur).abs() < 1e-9 * dur.max(1.0),
            "cpu phase sum {total} != duration {dur}"
        );
        // And the optimized variant still beats or matches basic.
        let mut opt = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        opt.set_chunk_entries(16);
        opt.run_rounds(4).unwrap();
        assert!(
            opt.stats.duration_s <= e.stats.duration_s,
            "optimized {} slower than basic {}",
            opt.stats.duration_s,
            e.stats.duration_s
        );
    }

    /// Satellite fix regression: once early validation has decided the
    /// round's fate, the full per-chunk validation pass is skipped (the
    /// chunks still ship — rollback needs them) and RoundStats says so.
    #[test]
    fn early_abort_skips_redundant_validation() {
        let mut e = engine(true, Variant::Optimized, PolicyKind::FavorCpu);
        e.cfg.early_validation = true;
        e.cfg.early_points = 3;
        // Small chunks so full chunks stream (and early-validate) mid-round.
        e.set_chunk_entries(8);
        e.run_rounds(2).unwrap();
        assert!(e.stats.rounds_early_aborted > 0, "conflict must early-abort");
        assert!(
            e.stats.chunks_skipped_post_abort > 0,
            "post-abort chunks must skip the per-entry pass"
        );
        assert!(e.stats.conflict_entries > 0, "early conflicts recorded");
        assert_eq!(e.stats.rounds_committed, 0);
        // State equivalence with the non-skipping path: the rollback
        // replay must still land every shipped CPU value on the device,
        // and after a committed drain (which flushes the bonus-window
        // carry) the replicas are identical.
        e.drain().unwrap();
        let cpu_snap = e.cpu.stmr().snapshot();
        assert_eq!(&cpu_snap[..], e.device.stmr(), "replicas agree after drain");
    }

    /// Compaction ships the write-set footprint, not the commit count,
    /// and a clean round still merges to identical replicas.
    #[test]
    fn compaction_ships_footprint_not_commits() {
        let mut raw = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        raw.run_rounds(3).unwrap();
        let mut comp = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
        comp.cfg.log_compaction = true;
        // Rebuild the round log from the updated config.
        comp.set_chunk_entries(comp.cfg.chunk_entries);
        comp.run_rounds(3).unwrap();
        // ScriptCpu cycles over 16 addresses, so dedup is massive.
        assert_eq!(comp.stats.log_entries_raw, raw.stats.log_entries_raw);
        assert!(
            comp.stats.log_entries_shipped * 2 <= comp.stats.log_entries_raw,
            "hot-key log must compact >= 2x: {} of {}",
            comp.stats.log_entries_shipped,
            comp.stats.log_entries_raw
        );
        assert_eq!(comp.stats.rounds_committed, 3);
        let cpu_snap = comp.cpu.stmr().snapshot();
        assert_eq!(&cpu_snap[..], comp.device.stmr(), "replicas agree");
        assert_eq!(
            comp.cpu.stmr().snapshot(),
            raw.cpu.stmr().snapshot(),
            "compacted final state == raw final state"
        );
    }

    /// The chunk filter skips per-entry validation on provably-clean
    /// chunks (partitioned workload: all of them) and charges only the
    /// signature cost, without changing outcomes.
    #[test]
    fn chunk_filter_skips_clean_chunks_and_preserves_state() {
        let build = |filter: bool| {
            let mut e = engine(false, Variant::Optimized, PolicyKind::FavorCpu);
            e.cfg.chunk_filter = filter;
            // Rebuild the round log from the updated config.
            e.set_chunk_entries(e.cfg.chunk_entries);
            e.run_rounds(3).unwrap();
            e
        };
        let plain = build(false);
        let filt = build(true);
        assert_eq!(filt.stats.chunks, plain.stats.chunks);
        assert_eq!(
            filt.stats.chunks_filtered, filt.stats.chunks,
            "disjoint partitions: every chunk provably clean"
        );
        assert_eq!(plain.stats.chunks_filtered, 0);
        assert!(
            filt.stats.gpu_phases.validation_s < plain.stats.gpu_phases.validation_s,
            "filtered validation must be cheaper: {} vs {}",
            filt.stats.gpu_phases.validation_s,
            plain.stats.gpu_phases.validation_s
        );
        assert_eq!(filt.stats.rounds_committed, plain.stats.rounds_committed);
        // Filtered chunks are still applied: the replicas agree after the
        // merge exactly as in the unfiltered engine.  (Bit-identity of
        // the full data path is pinned by tests/log_equivalence.rs under
        // neutralized costs; here timing legitimately differs.)
        let cpu_snap = filt.cpu.stmr().snapshot();
        assert_eq!(&cpu_snap[..], filt.device.stmr(), "replicas agree");
    }

    /// A conflicting chunk must never be filtered: the signature
    /// intersects the read-set and the per-entry pass still runs.
    #[test]
    fn chunk_filter_never_hides_conflicts() {
        let mut e = engine(true, Variant::Optimized, PolicyKind::FavorCpu);
        e.cfg.chunk_filter = true;
        e.set_chunk_entries(e.cfg.chunk_entries);
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 0, "conflicts still abort");
        assert!(e.stats.conflict_entries > 0);
    }
}
