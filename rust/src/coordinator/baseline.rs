//! Solo-device baselines (the paper's CPU-only and GPU-only comparators).
//!
//! * CPU-only: the guest TM runs alone, uninstrumented (its write-sets are
//!   not logged for SHeTM) — the right-hand normalization of Figs. 3/5/6.
//! * GPU-only: PR-STM runs alone, copying its STMR to the host after each
//!   round using double buffering, i.e. compute overlaps the DtH transfer
//!   (the paper's "GPU-only with double buffer" baseline).

use anyhow::Result;

use super::round::{CostModel, CpuDriver, GpuDriver};
use super::stats::RunStats;
use crate::bus::BusTimeline;
use crate::gpu::GpuDevice;
use crate::stm::WriteEntry;

/// Run a CPU driver solo for `dur_s`; returns aggregate stats.
///
/// The log sink is drained and discarded between slices — the driver runs
/// *uninstrumented* in the sense that nothing downstream consumes its
/// write-sets (matching the paper's un-instrumented normalization).
pub fn run_cpu_only<C: CpuDriver>(cpu: &mut C, dur_s: f64, slice_s: f64) -> RunStats {
    let mut stats = RunStats::default();
    let mut t = 0.0;
    let mut sink: Vec<WriteEntry> = Vec::new();
    while t < dur_s {
        let d = slice_s.min(dur_s - t);
        let cs = cpu.run(d, &mut sink);
        sink.clear();
        stats.cpu_commits += cs.commits;
        stats.cpu_attempts += cs.attempts;
        stats.cpu_phases.processing_s += d;
        t += d;
    }
    stats.rounds = 1;
    stats.rounds_committed = 1;
    stats.duration_s = dur_s;
    stats
}

/// Run a GPU driver solo for `dur_s` of device time, shipping the dirty
/// regions to the host once per `period_s` with double buffering.
pub fn run_gpu_only<G: GpuDriver>(
    gpu: &mut G,
    device: &mut GpuDevice,
    cost: &CostModel,
    dur_s: f64,
    period_s: f64,
) -> Result<RunStats> {
    let mut stats = RunStats::default();
    let mut d2h = BusTimeline::new();
    let mut t = 0.0;
    let n_bytes = (device.n_words() * 4) as u64;
    while t < dur_s {
        device.begin_round();
        // Shadow copy so compute can resume while DtH streams (§IV-D).
        let dtd = n_bytes as f64 / cost.gpu_dtd_bytes_per_s;
        t += dtd;
        stats.gpu_phases.merge_s += dtd;
        let budget = period_s.min(dur_s - t).max(0.0);
        let gs = gpu.run(device, budget)?;
        stats.gpu_commits += gs.commits;
        stats.gpu_attempts += gs.attempts;
        stats.gpu_phases.processing_s += gs.busy_s;
        stats.gpu_phases.blocked_s += budget - gs.busy_s;
        t += budget;
        // DtH of dirty regions overlaps the next round (double buffer):
        // only schedule it; compute never waits on d2h.
        let dirty_bytes = (device.ws_bmp().dirty_words() * 4) as u64;
        if dirty_bytes > 0 {
            let dur = cost.bus_d2h.transfer_secs(dirty_bytes);
            d2h.schedule(t, dur);
        }
        gpu.on_round_end(true);
        stats.rounds += 1;
        stats.rounds_committed += 1;
    }
    // If the bus is still draining at the end, the tail is exposed.
    stats.duration_s = t.max(d2h.free_at());
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::round::{CpuSlice, GpuSlice};
    use crate::gpu::Backend;
    use crate::stm::SharedStmr;

    struct FixedCpu {
        stmr: SharedStmr,
        rate: f64,
    }
    impl CpuDriver for FixedCpu {
        fn run(&mut self, dur_s: f64, _log: &mut Vec<WriteEntry>) -> CpuSlice {
            let n = (dur_s * self.rate) as u64;
            CpuSlice {
                commits: n,
                attempts: n,
            }
        }
        fn stmr(&self) -> &SharedStmr {
            &self.stmr
        }
    }

    struct FixedGpu {
        rate: f64,
    }
    impl GpuDriver for FixedGpu {
        fn run(&mut self, _d: &mut GpuDevice, budget_s: f64) -> Result<GpuSlice> {
            Ok(GpuSlice {
                commits: (budget_s * self.rate) as u64,
                attempts: (budget_s * self.rate) as u64,
                batches: 1,
                busy_s: budget_s,
            })
        }
    }

    #[test]
    fn cpu_only_throughput_matches_rate() {
        let mut cpu = FixedCpu {
            stmr: SharedStmr::new(16),
            rate: 1000.0,
        };
        let stats = run_cpu_only(&mut cpu, 2.0, 0.1);
        assert!((stats.throughput() - 1000.0).abs() < 20.0);
    }

    #[test]
    fn gpu_only_overlaps_transfers() {
        let mut gpu = FixedGpu { rate: 1000.0 };
        let mut device = GpuDevice::new(1 << 12, 0, Backend::Native);
        let cost = CostModel::default();
        let stats = run_gpu_only(&mut gpu, &mut device, &cost, 1.0, 0.05).unwrap();
        // Shadow copies cost a little, transfers are overlapped: the
        // throughput should stay within a few percent of the raw rate.
        assert!(stats.throughput() > 900.0, "{}", stats.throughput());
    }
}
