//! Transaction scheduling & dispatching (paper §IV-A).
//!
//! For every registered transaction type with both CPU and GPU
//! implementations, SHeTM keeps three request queues — `CPU_Q`, `GPU_Q` and
//! `SHARED_Q`.  Submitters may pass a *device affinity*; requests without
//! affinity land in the shared queue and are consumed by either device
//! under work stealing.  Conflict-aware dispatching is exactly this
//! mechanism: route transactions likely to conflict to the same device so
//! the (cheap) local TM resolves them.
//!
//! The queues are used by the memcached application (§V-D), including its
//! *steal* experiments where the GPU deliberately steals requests bound
//! for the CPU with a configurable probability.

use std::collections::VecDeque;

use crate::util::Rng;

/// Where a submitted request should run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Affinity {
    /// Must/should run on the CPU.
    Cpu,
    /// Must/should run on the GPU.
    Gpu,
    /// Either device (work stealing).
    Shared,
}

/// Three-queue dispatcher for one transaction type.
///
/// The GPU side holds one queue **per device** (a cluster shards `GPU_Q`
/// by owner device); the single-device system is simply the one-queue
/// special case, and the historical single-queue API delegates to device 0.
#[derive(Debug)]
pub struct Dispatcher<T> {
    cpu_q: VecDeque<T>,
    gpu_qs: Vec<VecDeque<T>>,
    shared_q: VecDeque<T>,
    /// Probability that the GPU steals from `CPU_Q` when its own queues
    /// run dry (the §V-D steal-X% workloads).
    pub gpu_steal_prob: f64,
    stolen: u64,
}

impl<T> Default for Dispatcher<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Dispatcher<T> {
    /// Empty dispatcher, one GPU queue, no stealing.
    pub fn new() -> Self {
        Self::with_gpu_queues(1)
    }

    /// Empty dispatcher with one GPU queue per device.
    pub fn with_gpu_queues(n_gpus: usize) -> Self {
        assert!(n_gpus >= 1);
        Dispatcher {
            cpu_q: VecDeque::new(),
            gpu_qs: (0..n_gpus).map(|_| VecDeque::new()).collect(),
            shared_q: VecDeque::new(),
            gpu_steal_prob: 0.0,
            stolen: 0,
        }
    }

    /// Number of per-device GPU queues.
    pub fn n_gpu_queues(&self) -> usize {
        self.gpu_qs.len()
    }

    /// Submit one request (GPU affinity lands on device 0's queue; use
    /// [`Self::submit_gpu`] to target a specific device).
    pub fn submit(&mut self, req: T, affinity: Affinity) {
        match affinity {
            Affinity::Cpu => self.cpu_q.push_back(req),
            Affinity::Gpu => self.gpu_qs[0].push_back(req),
            Affinity::Shared => self.shared_q.push_back(req),
        }
    }

    /// Submit one GPU-bound request to a specific device's queue.
    pub fn submit_gpu(&mut self, req: T, dev: usize) {
        self.gpu_qs[dev].push_back(req);
    }

    /// Queued requests per (cpu, gpu-total, shared).
    pub fn depths(&self) -> (usize, usize, usize) {
        (
            self.cpu_q.len(),
            self.gpu_qs.iter().map(|q| q.len()).sum(),
            self.shared_q.len(),
        )
    }

    /// Queue depth of one device's GPU queue.
    pub fn depth_gpu(&self, dev: usize) -> usize {
        self.gpu_qs[dev].len()
    }

    /// Total requests the GPU stole from `CPU_Q`.
    pub fn stolen(&self) -> u64 {
        self.stolen
    }

    /// CPU worker pull: own queue first, then the shared queue.
    pub fn pop_cpu(&mut self) -> Option<T> {
        self.cpu_q
            .pop_front()
            .or_else(|| self.shared_q.pop_front())
    }

    /// Device-0 GPU pull (single-device API; see
    /// [`Self::pop_gpu_batch_on`]).
    pub fn pop_gpu_batch(&mut self, n: usize, rng: &mut Rng, out: &mut Vec<T>) {
        self.pop_gpu_batch_on(0, n, rng, out);
    }

    /// GPU-controller pull of up to `n` requests to feed device `dev`'s
    /// kernel batch: the device's own queue first, then `SHARED_Q`, then
    /// (with `gpu_steal_prob`) `CPU_Q`.
    pub fn pop_gpu_batch_on(&mut self, dev: usize, n: usize, rng: &mut Rng, out: &mut Vec<T>) {
        while out.len() < n {
            if let Some(r) = self.gpu_qs[dev].pop_front() {
                out.push(r);
            } else if let Some(r) = self.shared_q.pop_front() {
                out.push(r);
            } else if self.gpu_steal_prob > 0.0
                && !self.cpu_q.is_empty()
                && rng.chance(self.gpu_steal_prob)
            {
                // The emptiness check above also gates the RNG draw, so
                // it must stay in the condition; this match only replaces
                // the unwrap it used to justify.
                match self.cpu_q.pop_front() {
                    Some(r) => {
                        out.push(r);
                        self.stolen += 1;
                    }
                    None => break,
                }
            } else {
                break;
            }
        }
    }

    /// Return unconsumed requests to the FRONT of device 0's GPU queue
    /// (round abort: the batch must be re-executed).
    pub fn unpop_gpu(&mut self, reqs: impl DoubleEndedIterator<Item = T>) {
        self.unpop_gpu_on(0, reqs);
    }

    /// Return unconsumed requests to the FRONT of one device's GPU queue.
    pub fn unpop_gpu_on(&mut self, dev: usize, reqs: impl DoubleEndedIterator<Item = T>) {
        for r in reqs.rev() {
            self.gpu_qs[dev].push_front(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_routing() {
        let mut d = Dispatcher::new();
        d.submit(1, Affinity::Cpu);
        d.submit(2, Affinity::Gpu);
        d.submit(3, Affinity::Shared);
        assert_eq!(d.depths(), (1, 1, 1));
        assert_eq!(d.pop_cpu(), Some(1));
        // CPU falls back to shared once its queue is dry.
        assert_eq!(d.pop_cpu(), Some(3));
        assert_eq!(d.pop_cpu(), None);
    }

    #[test]
    fn gpu_batch_fills_from_gpu_then_shared() {
        let mut d = Dispatcher::new();
        for i in 0..3 {
            d.submit(i, Affinity::Gpu);
        }
        for i in 10..12 {
            d.submit(i, Affinity::Shared);
        }
        let mut rng = Rng::new(1);
        let mut batch = Vec::new();
        d.pop_gpu_batch(10, &mut rng, &mut batch);
        assert_eq!(batch, vec![0, 1, 2, 10, 11]);
    }

    #[test]
    fn gpu_never_steals_without_probability() {
        let mut d = Dispatcher::new();
        d.submit(7, Affinity::Cpu);
        let mut rng = Rng::new(1);
        let mut batch = Vec::new();
        d.pop_gpu_batch(4, &mut rng, &mut batch);
        assert!(batch.is_empty());
        assert_eq!(d.stolen(), 0);
    }

    #[test]
    fn gpu_steals_with_probability_one() {
        let mut d = Dispatcher::new();
        for i in 0..5 {
            d.submit(i, Affinity::Cpu);
        }
        d.gpu_steal_prob = 1.0;
        let mut rng = Rng::new(1);
        let mut batch = Vec::new();
        d.pop_gpu_batch(3, &mut rng, &mut batch);
        assert_eq!(batch, vec![0, 1, 2]);
        assert_eq!(d.stolen(), 3);
        assert_eq!(d.depths().0, 2);
    }

    #[test]
    fn per_device_queues_route_and_pop_independently() {
        let mut d = Dispatcher::with_gpu_queues(3);
        d.submit_gpu(10, 0);
        d.submit_gpu(21, 1);
        d.submit_gpu(22, 1);
        d.submit_gpu(30, 2);
        assert_eq!(d.depths().1, 4, "gpu total sums devices");
        assert_eq!(d.depth_gpu(1), 2);
        let mut rng = Rng::new(1);
        let mut batch = Vec::new();
        d.pop_gpu_batch_on(1, 8, &mut rng, &mut batch);
        assert_eq!(batch, vec![21, 22], "device 1 sees only its queue");
        assert_eq!(d.depth_gpu(0), 1);
        assert_eq!(d.depth_gpu(2), 1);
    }

    #[test]
    fn unpop_restores_order() {
        let mut d = Dispatcher::new();
        for i in 0..4 {
            d.submit(i, Affinity::Gpu);
        }
        let mut rng = Rng::new(1);
        let mut batch = Vec::new();
        d.pop_gpu_batch(4, &mut rng, &mut batch);
        d.unpop_gpu(batch.drain(..));
        let mut batch2 = Vec::new();
        d.pop_gpu_batch(4, &mut rng, &mut batch2);
        assert_eq!(batch2, vec![0, 1, 2, 3]);
    }
}
