//! Real-thread CPU-side execution: [`ParallelCpuDriver`].
//!
//! The paper's CPU side is an 8-thread guest TM; the single-device
//! engines model that with a *rate* multiplier inside one driver
//! (`cpu.threads` × `1/cpu.txn_ns`).  This wrapper makes the workers
//! real: it owns one inner [`CpuDriver`] per worker thread and fans every
//! execution slice out on scoped OS threads, so the CPU slice's real
//! wall-clock work scales down with core count alongside the threaded
//! [`ClusterEngine`] lanes (DESIGN.md §8).
//!
//! # Determinism contract
//!
//! The merged result is deterministic — same seed ⇒ same log, same
//! stats, same STMR — provided the workers are **data-disjoint**:
//!
//! * each worker is built over its own partition of the STMR (so worker
//!   transactions never conflict with each other and each word is only
//!   ever written by one worker), and
//! * each worker has its **own guest-TM instance and commit clock**
//!   ("per-thread guest-TM instances"): a clock shared across workers
//!   would hand out timestamps in scheduling order, making the logged
//!   `ts` values racy.
//!
//! Under that contract each worker's slice is a deterministic function of
//! its own seed, and the merge is deterministic by construction: worker
//! logs are concatenated **stably by worker index, then commit
//! timestamp** (each worker's log is already in its commit order, so
//! concatenation in index order realizes the `(worker, ts)` sort key).
//! Relaxation vs. the single-clock system: timestamps are totally ordered
//! *per worker* (hence per address, by disjointness) instead of globally —
//! exactly what the GPU-side freshness check (§IV-C.2) needs, since it
//! compares timestamps per word.  [`crate::launch::build_parallel_synth_cpu`]
//! builds a compliant worker set from a [`SystemConfig`].
//!
//! [`ClusterEngine`]: crate::cluster::ClusterEngine
//! [`SystemConfig`]: crate::config::SystemConfig

use super::round::{CpuDriver, CpuSlice};
use crate::stm::{SharedStmr, WriteEntry};

/// Fans one CPU execution slice out across per-thread inner drivers.
///
/// See the module docs for the determinism contract.  With
/// `parallel(false)` (or a single worker) the workers run sequentially on
/// the caller's thread — bit-identical to the threaded run, which is what
/// `rust/src/coordinator/parallel.rs`'s tests assert.
pub struct ParallelCpuDriver<C: CpuDriver + Send> {
    workers: Vec<C>,
    /// Per-worker log scratch, reused across slices.
    logs: Vec<Vec<WriteEntry>>,
    parallel: bool,
}

impl<C: CpuDriver + Send> ParallelCpuDriver<C> {
    /// Wrap a non-empty worker set.  All workers must drive the same
    /// [`SharedStmr`] instance (asserted); keeping their access patterns
    /// disjoint is the builder's responsibility (see the module docs).
    pub fn new(workers: Vec<C>) -> Self {
        assert!(!workers.is_empty(), "need at least one CPU worker");
        let stmr0 = workers[0].stmr() as *const SharedStmr;
        for w in &workers {
            assert!(
                std::ptr::eq(w.stmr(), stmr0),
                "all workers must share one SharedStmr"
            );
        }
        let n = workers.len();
        ParallelCpuDriver {
            workers,
            logs: (0..n).map(|_| Vec::new()).collect(),
            parallel: true,
        }
    }

    /// Number of worker threads.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Toggle real threading (`true` by default).  `false` runs the
    /// workers sequentially on the caller's thread — same results, no
    /// spawns; the equivalence tests use it as the oracle.
    pub fn parallel(mut self, on: bool) -> Self {
        self.parallel = on;
        self
    }

    /// Immutable view of the workers (diagnostics, tests).
    pub fn workers(&self) -> &[C] {
        &self.workers
    }
}

impl<C: CpuDriver + Send> CpuDriver for ParallelCpuDriver<C> {
    fn run(&mut self, dur_s: f64, log: &mut Vec<WriteEntry>) -> CpuSlice {
        for l in &mut self.logs {
            l.clear();
        }
        let mut total = CpuSlice::default();
        if self.parallel && self.workers.len() > 1 {
            let slices: Vec<CpuSlice> = std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .workers
                    .iter_mut()
                    .zip(self.logs.iter_mut())
                    .map(|(w, l)| s.spawn(move || w.run(dur_s, l)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(slice) => slice,
                        // Re-raise the worker's own panic payload on the
                        // coordinator thread instead of a generic expect.
                        Err(payload) => std::panic::resume_unwind(payload),
                    })
                    .collect()
            });
            for sl in &slices {
                total.commits += sl.commits;
                total.attempts += sl.attempts;
            }
        } else {
            for (w, l) in self.workers.iter_mut().zip(self.logs.iter_mut()) {
                let sl = w.run(dur_s, l);
                total.commits += sl.commits;
                total.attempts += sl.attempts;
            }
        }
        // Deterministic log merge: stable by worker index, then commit
        // timestamp (each worker's log is already in its commit order).
        for l in &self.logs {
            log.extend_from_slice(l);
        }
        total
    }

    fn stmr(&self) -> &SharedStmr {
        self.workers[0].stmr()
    }

    fn set_read_only(&mut self, ro: bool) {
        for w in &mut self.workers {
            w.set_read_only(ro);
        }
    }

    fn snapshot(&mut self) {
        // One region-level snapshot: the workers share the SharedStmr and
        // its internal snapshot slot.  Workers carrying host-side rollback
        // state beyond the STMR are outside this wrapper's contract.
        self.workers[0].snapshot();
    }

    fn rollback(&mut self) {
        self.workers[0].rollback();
    }

    fn epoch_reset(&mut self, base: i64) {
        // Every worker owns its own guest TM and commit clock; each
        // restarts at the same base, so all next-epoch timestamps exceed
        // every renumbered carried entry.  Per-address ordering is per
        // worker (disjoint partitions), so the shared rebase is sound.
        for w in &mut self.workers {
            w.epoch_reset(base);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::synth::{SynthCpu, SynthSpec};
    use crate::stm::tinystm::TinyStm;
    use crate::stm::GlobalClock;
    use std::sync::Arc;

    /// Disjoint-partition worker set: `n_workers` SynthCpus over one
    /// SharedStmr, each with its own TinySTM + clock and its own seed.
    fn workers(n_words: usize, n_workers: usize) -> ParallelCpuDriver<SynthCpu> {
        let stmr = Arc::new(SharedStmr::new(n_words));
        let span = (n_words / 2) / n_workers;
        let ws = (0..n_workers)
            .map(|i| {
                let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
                let spec =
                    SynthSpec::w1(n_words, 1.0).partitioned(i * span..(i + 1) * span);
                SynthCpu::new(stmr.clone(), tm, spec, 1, 2e-6, 100 + i as u64)
            })
            .collect();
        ParallelCpuDriver::new(ws)
    }

    #[test]
    fn threaded_run_matches_sequential_run() {
        let mut par = workers(1 << 12, 4);
        let mut seq = workers(1 << 12, 4).parallel(false);
        let (mut log_p, mut log_s) = (Vec::new(), Vec::new());
        for _ in 0..3 {
            let sp = par.run(0.002, &mut log_p);
            let ss = seq.run(0.002, &mut log_s);
            assert_eq!(sp.commits, ss.commits);
            assert_eq!(sp.attempts, ss.attempts);
        }
        assert_eq!(log_p, log_s, "merged logs must be bit-identical");
        assert_eq!(
            par.stmr().snapshot(),
            seq.stmr().snapshot(),
            "final STMR state must be bit-identical"
        );
    }

    #[test]
    fn merge_is_stable_by_worker_index_then_ts() {
        let mut d = workers(1 << 12, 4);
        let mut log = Vec::new();
        d.run(0.002, &mut log);
        assert!(!log.is_empty());
        // Worker partitions are the disjoint quarters of the lower half:
        // recover each entry's worker from its address, and check that the
        // merged order is non-decreasing in (worker, ts).
        let span = (1usize << 11) / 4;
        let mut last = (0usize, 0i32);
        for e in &log {
            let w = (e.addr as usize) / span;
            assert!(
                (w, e.ts) >= last,
                "entry {e:?} out of (worker, ts) order after {last:?}"
            );
            last = (w, e.ts);
        }
    }

    #[test]
    fn read_only_mode_reaches_every_worker() {
        let mut d = workers(1 << 12, 3);
        d.set_read_only(true);
        let mut log = Vec::new();
        let s = d.run(0.002, &mut log);
        assert!(s.commits > 0);
        assert!(log.is_empty(), "read-only slices log nothing");
    }

    #[test]
    fn snapshot_rollback_round_trips_through_worker_zero() {
        let mut d = workers(1 << 12, 2);
        let mut log = Vec::new();
        d.run(0.001, &mut log);
        let before = d.stmr().snapshot();
        d.snapshot();
        d.run(0.001, &mut log);
        d.rollback();
        assert_eq!(d.stmr().snapshot(), before, "rollback restores the region");
    }

    #[test]
    #[should_panic(expected = "share one SharedStmr")]
    fn distinct_stmrs_are_rejected() {
        let a = workers(1 << 12, 1);
        let b = workers(1 << 12, 1);
        let mut ws = Vec::new();
        ws.extend(a.workers.into_iter());
        ws.extend(b.workers.into_iter());
        ParallelCpuDriver::new(ws);
    }
}
