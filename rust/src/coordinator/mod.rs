//! The SHeTM coordinator: the paper's system contribution.
//!
//! * [`round`] — the synchronization-round state machine (execution /
//!   validation / merge), both the basic and the optimized variants;
//! * [`logs`] — CPU write-set log collection and 48 KB chunking;
//! * [`dispatch`] — CPU_Q / GPU_Q / SHARED_Q queues with device affinity
//!   and work stealing;
//! * [`policy`] — conflict-resolution policies (favor-CPU / favor-GPU /
//!   anti-starvation);
//! * [`parallel`] — [`parallel::ParallelCpuDriver`]: real worker threads
//!   for the CPU side, with a deterministic log-merge order;
//! * [`stats`] — round and run metrics, incl. the Fig. 4 phase breakdown;
//! * [`baseline`] — CPU-only / GPU-only solo engines (the paper's
//!   comparison baselines).
//!
//! Most users assemble a [`round::RoundEngine`] through the workload
//! drivers in [`crate::apps`]; see `examples/quickstart.rs`.

pub mod baseline;
pub mod dispatch;
pub mod logs;
pub mod parallel;
pub mod policy;
pub mod round;
pub mod stats;

pub use dispatch::{Affinity, Dispatcher};
pub use logs::RoundLog;
pub use parallel::ParallelCpuDriver;
pub use policy::{Loser, Policy};
pub use round::{CostModel, CpuDriver, CpuSlice, EngineConfig, GpuDriver, GpuSlice, RoundEngine, Variant};
pub use stats::{PhaseBreakdown, RoundStats, RunStats};
