//! Dependency-free configuration system.
//!
//! Offline builds carry no serde/toml, so this module implements a small
//! TOML-subset parser ([`Raw`]) plus the typed [`SystemConfig`] the
//! launcher and benches consume.  Supported syntax:
//!
//! ```toml
//! # comment
//! [section]
//! int_key = 42
//! float_key = 3.5
//! bool_key = true
//! string_key = "quoted"
//! ```
//!
//! Keys flatten to `section.key`; CLI `--set section.key=value` overrides
//! win over file values (see `rust/src/main.rs`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::bus::BusModel;

/// Flat key-value view of a parsed config file plus overrides.
#[derive(Debug, Clone, Default)]
pub struct Raw {
    values: HashMap<String, String>,
}

impl Raw {
    /// Empty config.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, unquote(v.trim()).to_string());
        }
        Ok(Raw { values })
    }

    /// Load and parse a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn set(&mut self, assignment: &str) -> Result<()> {
        let (k, v) = assignment
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value: {assignment:?}"))?;
        self.values
            .insert(k.trim().to_string(), unquote(v.trim()).to_string());
        Ok(())
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("config {key} = {s:?}: {e}")),
        }
    }

    /// Boolean lookup with default (`true`/`false`/`1`/`0`).
    pub fn get_bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(other) => bail!("config {key} = {other:?}: expected bool"),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside quotes is content, not a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(s: &str) -> &str {
    s.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(s)
}

/// Which conflict-resolution policy a round uses (paper §IV-E).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Default: on inter-device conflict, the GPU's speculative commits are
    /// discarded (CPU results can be externalized immediately).
    FavorCpu,
    /// Discard the CPU's speculative commits instead.
    FavorGpu,
    /// Favor-CPU plus the anti-starvation contention manager: after
    /// `gpu_starvation_limit` consecutive GPU aborts, the next round
    /// admits only read-only CPU transactions.
    CpuWithStarvationGuard,
}

impl PolicyKind {
    /// Parse a policy name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "favor-cpu" => PolicyKind::FavorCpu,
            "favor-gpu" => PolicyKind::FavorGpu,
            "starvation-guard" => PolicyKind::CpuWithStarvationGuard,
            other => bail!("unknown policy {other:?} (favor-cpu|favor-gpu|starvation-guard)"),
        })
    }
}

/// Which CPU guest TM to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuestKind {
    /// TinySTM-like (word-based, time-based).
    Tiny,
    /// NOrec-like (value validation).
    Norec,
    /// Emulated HTM (TSX envelope).
    Htm,
}

impl GuestKind {
    /// Parse a guest name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "tinystm" => GuestKind::Tiny,
            "norec" => GuestKind::Norec,
            "htm" => GuestKind::Htm,
            other => bail!("unknown guest TM {other:?} (tinystm|norec|htm)"),
        })
    }
}

/// Fully-typed system configuration consumed by the coordinator.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// STMR size in words.
    pub n_words: usize,
    /// Bitmap granularity shift (granule = `1 << shift` words).
    pub bmp_shift: u32,
    /// CPU worker threads (paper: 8).
    pub cpu_threads: usize,
    /// Run the CPU side's `cpu.threads` workers on real OS threads via
    /// [`crate::coordinator::ParallelCpuDriver`] (`cpu.parallel`; synth
    /// paths only).  Off by default: the single-driver rate model is the
    /// paper-reproduction reference, and the parallel driver's per-worker
    /// clocks/seeds produce a different (still deterministic) trace.
    pub cpu_parallel: bool,
    /// CPU guest TM.
    pub guest: GuestKind,
    /// Conflict-resolution policy.
    pub policy: PolicyKind,
    /// Execution-phase duration in seconds (paper: 1 ms – 600 ms).
    pub period_s: f64,
    /// Enable early validation (§IV-D).
    pub early_validation: bool,
    /// Early-validation trigger interval, as a fraction of the period.
    /// Must be finite and in `(0, 1]` (rejected at parse time — `0`,
    /// negatives or NaN would silently misbehave in `1.0 / frac`).
    pub early_interval_frac: f64,
    /// Deduplicate the write log last-write-wins before chunking
    /// (`hetm.log_compaction`): shipped bytes and validation work scale
    /// with the write-set footprint instead of the commit count.
    pub log_compaction: bool,
    /// Attach conflict-prefilter signatures to log chunks and skip the
    /// per-entry validation pass on provable non-intersection
    /// (`hetm.chunk_filter`).
    pub chunk_filter: bool,
    /// Consecutive GPU aborts before the starvation guard engages.
    pub gpu_starvation_limit: u32,
    /// Host->device bus model.
    pub bus_h2d: BusModel,
    /// Device->host bus model.
    pub bus_d2h: BusModel,
    /// GPU cost model: fixed kernel-activation latency (s).
    pub gpu_kernel_latency_s: f64,
    /// GPU cost model: per-transaction execution time (s).
    pub gpu_txn_s: f64,
    /// GPU cost model: per-log-entry validation time (s).
    pub gpu_validate_entry_s: f64,
    /// GPU cost model: per-chunk signature-check time (s), charged while
    /// `hetm.chunk_filter` is on (`gpu.sig_check_ns`).
    pub gpu_sig_check_s: f64,
    /// CPU cost model: per-transaction execution time (s) per worker.
    /// When `calibrate_cpu` is set the launcher measures this instead.
    pub cpu_txn_s: f64,
    /// Artifact directory for the PJRT backend (empty = native backend).
    pub artifacts_dir: String,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Simulated GPU devices the STMR is sharded across (1 = the
    /// single-device SHeTM of the paper; >1 enables the cluster engine).
    pub n_gpus: usize,
    /// Shard-block size shift: ownership blocks are `1 << shard_bits`
    /// words (default 12 → 4096 words = 16 KB, the merge granule).
    pub shard_bits: u32,
    /// Probability that a GPU update transaction redirects one write into
    /// another shard (cross-shard traffic injection; cluster only).
    pub cross_shard_prob: f64,
    /// OS worker threads driving the cluster engine's per-device round
    /// pipelines (`cluster.threads`, CLI `--threads`).  1 = fully
    /// sequential; results are bit-identical at any setting (DESIGN.md
    /// §8) — this is purely a wall-clock lever.
    pub cluster_threads: usize,
    /// Enable the online round-barrier rebalancer (`cluster.rebalance`,
    /// CLI `--rebalance`): migrate hot ownership blocks from the most
    /// loaded device to the least loaded one at the synchronization
    /// barrier (DESIGN.md §14).  Off by default — the layout then stays
    /// bit-identical to the static striped one.
    pub rebalance: bool,
    /// Rounds per rebalancer observation window
    /// (`cluster.rebalance_interval`; must be ≥ 1).
    pub rebalance_interval: usize,
    /// Migrate only when the hottest device's windowed load exceeds this
    /// multiple of the mean (`cluster.rebalance_threshold`; finite,
    /// > 1.0 — at 1.0 the trigger would fire on any nonzero traffic).
    pub rebalance_threshold: f64,
    /// Ownership blocks moved per migration at most
    /// (`cluster.rebalance_granules`; must be ≥ 1).
    pub rebalance_granules: usize,
    /// Per-device relative speed factors (`cluster.dev_speed`, a
    /// comma-separated list like `"1.0,2.0,1.0,1.0"`).  Empty = uniform
    /// cluster (the default, bit-identical to pre-heterogeneity builds).
    /// When set, its length must equal `cluster.n_gpus`; each factor
    /// scales that device's cost model and weighs the initial
    /// load-proportional shard layout.
    pub dev_speed: Vec<f64>,
    /// Application driven by `shetm run` / the workload builders:
    /// `synth | memcached | bank | kmeans | zipfkv`.  Per-app knobs live in
    /// their own config sections (`[bank]`, `[kmeans]`, `[zipfkv]`,
    /// `[synth]`, `[memcached]`) and are parsed by
    /// [`crate::apps::workload::from_raw`].
    pub workload: String,
    /// Enable the telemetry layer (`telemetry.enabled`): metrics registry
    /// plus — with `shetm run --trace` — the virtual-time trace stream.
    /// Off by default; off means a no-op recorder and zero overhead
    /// (DESIGN.md §11).
    pub telemetry_enabled: bool,
    /// Checkpoint directory (`durability.checkpoint_dir`, CLI
    /// `--checkpoint-dir`).  Empty = durability off (the default): no
    /// journal, no checkpoints, zero overhead.
    pub checkpoint_dir: String,
    /// Checkpoint every N rounds (`durability.interval_rounds`; 0 =
    /// journal-only, never checkpoint).  Only meaningful with a
    /// checkpoint directory.
    pub checkpoint_interval_rounds: u64,
    /// Fault-injection point (`durability.crash_point`, or the
    /// `SHETM_CRASH_POINT` env var via the CLI); empty = no fault.  See
    /// [`crate::durability::CrashPoint::parse`] for the spellings.
    pub crash_point: String,
    /// First checkpoint round at which `crash_point` fires
    /// (`durability.crash_round` / `SHETM_CRASH_ROUND`).
    pub crash_round: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            n_words: 1 << 18,
            bmp_shift: 0,
            cpu_threads: 8,
            cpu_parallel: false,
            guest: GuestKind::Tiny,
            policy: PolicyKind::FavorCpu,
            period_s: 0.080,
            early_validation: true,
            early_interval_frac: 0.25,
            log_compaction: false,
            chunk_filter: false,
            gpu_starvation_limit: 3,
            bus_h2d: BusModel::default(),
            bus_d2h: BusModel::default(),
            gpu_kernel_latency_s: 20e-6,
            gpu_txn_s: 90e-9,
            cpu_txn_s: 90e-9,
            gpu_validate_entry_s: 1e-9,
            gpu_sig_check_s: 250e-9,
            artifacts_dir: String::new(),
            seed: 42,
            n_gpus: 1,
            shard_bits: 12,
            cross_shard_prob: 0.0,
            cluster_threads: 1,
            rebalance: false,
            rebalance_interval: 4,
            rebalance_threshold: 1.25,
            rebalance_granules: 8,
            dev_speed: Vec::new(),
            workload: "synth".to_string(),
            telemetry_enabled: false,
            checkpoint_dir: String::new(),
            checkpoint_interval_rounds: 1,
            crash_point: String::new(),
            crash_round: 0,
        }
    }
}

impl SystemConfig {
    /// Build from a raw config (file + overrides), falling back to the
    /// defaults above for missing keys.
    pub fn from_raw(raw: &Raw) -> Result<Self> {
        let d = SystemConfig::default();
        let cluster_threads: usize = raw.get_or("cluster.threads", d.cluster_threads)?;
        if cluster_threads == 0 {
            bail!("cluster.threads must be at least 1 (1 = sequential)");
        }
        let n_gpus: usize = raw.get_or("cluster.n_gpus", d.n_gpus)?;
        let rebalance_interval: usize =
            raw.get_or("cluster.rebalance_interval", d.rebalance_interval)?;
        if rebalance_interval == 0 {
            bail!("cluster.rebalance_interval must be at least 1 round");
        }
        let rebalance_threshold: f64 =
            raw.get_or("cluster.rebalance_threshold", d.rebalance_threshold)?;
        if !rebalance_threshold.is_finite() || rebalance_threshold <= 1.0 {
            bail!(
                "cluster.rebalance_threshold must be a finite multiple > 1.0, \
                 got {rebalance_threshold}"
            );
        }
        let rebalance_granules: usize =
            raw.get_or("cluster.rebalance_granules", d.rebalance_granules)?;
        if rebalance_granules == 0 {
            bail!("cluster.rebalance_granules must be at least 1 block");
        }
        let dev_speed: Vec<f64> = match raw.get("cluster.dev_speed") {
            None => Vec::new(),
            Some(s) if s.trim().is_empty() => Vec::new(),
            Some(s) => {
                let mut v = Vec::with_capacity(n_gpus);
                for part in s.split(',') {
                    let f: f64 = part
                        .trim()
                        .parse()
                        .map_err(|e| anyhow!("cluster.dev_speed entry {part:?}: {e}"))?;
                    if !f.is_finite() || f <= 0.0 {
                        bail!("cluster.dev_speed factors must be finite and positive, got {f}");
                    }
                    v.push(f);
                }
                if v.len() != n_gpus {
                    bail!(
                        "cluster.dev_speed lists {} factors but cluster.n_gpus = {n_gpus} \
                         (one factor per device)",
                        v.len()
                    );
                }
                v
            }
        };
        let early_interval_frac: f64 =
            raw.get_or("hetm.early_interval_frac", d.early_interval_frac)?;
        if !early_interval_frac.is_finite()
            || early_interval_frac <= 0.0
            || early_interval_frac > 1.0
        {
            bail!(
                "hetm.early_interval_frac must be a finite fraction in (0, 1], \
                 got {early_interval_frac}"
            );
        }
        Ok(SystemConfig {
            n_words: raw.get_or("stmr.n_words", d.n_words)?,
            bmp_shift: raw.get_or("stmr.bmp_shift", d.bmp_shift)?,
            cpu_threads: raw.get_or("cpu.threads", d.cpu_threads)?,
            cpu_parallel: raw.get_bool_or("cpu.parallel", d.cpu_parallel)?,
            guest: match raw.get("cpu.guest") {
                Some(s) => GuestKind::parse(s)?,
                None => d.guest,
            },
            policy: match raw.get("hetm.policy") {
                Some(s) => PolicyKind::parse(s)?,
                None => d.policy,
            },
            period_s: raw.get_or("hetm.period_ms", d.period_s * 1e3)? / 1e3,
            early_validation: raw.get_bool_or("hetm.early_validation", d.early_validation)?,
            early_interval_frac,
            log_compaction: raw.get_bool_or("hetm.log_compaction", d.log_compaction)?,
            chunk_filter: raw.get_bool_or("hetm.chunk_filter", d.chunk_filter)?,
            gpu_starvation_limit: raw.get_or("hetm.gpu_starvation_limit", d.gpu_starvation_limit)?,
            bus_h2d: BusModel {
                latency_s: raw.get_or("bus.latency_us", d.bus_h2d.latency_s * 1e6)? / 1e6,
                bytes_per_s: raw.get_or("bus.gbps", d.bus_h2d.bytes_per_s / 1e9)? * 1e9,
            },
            bus_d2h: BusModel {
                latency_s: raw.get_or("bus.latency_us", d.bus_d2h.latency_s * 1e6)? / 1e6,
                bytes_per_s: raw.get_or("bus.gbps", d.bus_d2h.bytes_per_s / 1e9)? * 1e9,
            },
            gpu_kernel_latency_s: raw.get_or("gpu.kernel_latency_us", d.gpu_kernel_latency_s * 1e6)?
                / 1e6,
            gpu_txn_s: raw.get_or("gpu.txn_ns", d.gpu_txn_s * 1e9)? / 1e9,
            gpu_validate_entry_s: raw.get_or("gpu.validate_entry_ns", d.gpu_validate_entry_s * 1e9)?
                / 1e9,
            gpu_sig_check_s: raw.get_or("gpu.sig_check_ns", d.gpu_sig_check_s * 1e9)? / 1e9,
            cpu_txn_s: raw.get_or("cpu.txn_ns", d.cpu_txn_s * 1e9)? / 1e9,
            artifacts_dir: raw.get("runtime.artifacts").unwrap_or("").to_string(),
            seed: raw.get_or("seed", d.seed)?,
            n_gpus,
            shard_bits: raw.get_or("cluster.shard_bits", d.shard_bits)?,
            cross_shard_prob: raw.get_or("cluster.cross_shard_prob", d.cross_shard_prob)?,
            cluster_threads,
            rebalance: raw.get_bool_or("cluster.rebalance", d.rebalance)?,
            rebalance_interval,
            rebalance_threshold,
            rebalance_granules,
            dev_speed,
            workload: raw.get("workload").unwrap_or(&d.workload).to_string(),
            telemetry_enabled: raw.get_bool_or("telemetry.enabled", d.telemetry_enabled)?,
            checkpoint_dir: raw
                .get("durability.checkpoint_dir")
                .unwrap_or(&d.checkpoint_dir)
                .to_string(),
            checkpoint_interval_rounds: raw
                .get_or("durability.interval_rounds", d.checkpoint_interval_rounds)?,
            crash_point: raw
                .get("durability.crash_point")
                .unwrap_or(&d.crash_point)
                .to_string(),
            crash_round: raw.get_or("durability.crash_round", d.crash_round)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_types_and_comments() {
        let raw = Raw::parse(
            r#"
# top comment
seed = 7
[stmr]
n_words = 1024   # inline comment
[cpu]
guest = "norec"
threads = 4
[hetm]
early_validation = false
period_ms = 2.5
"#,
        )
        .unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.n_words, 1024);
        assert_eq!(cfg.guest, GuestKind::Norec);
        assert_eq!(cfg.cpu_threads, 4);
        assert!(!cfg.early_validation);
        assert!((cfg.period_s - 0.0025).abs() < 1e-12);
    }

    #[test]
    fn overrides_win() {
        let mut raw = Raw::parse("[stmr]\nn_words = 10\n").unwrap();
        raw.set("stmr.n_words=99").unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.n_words, 99);
    }

    #[test]
    fn defaults_fill_missing() {
        let cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
        assert_eq!(cfg.cpu_threads, 8);
        assert_eq!(cfg.policy, PolicyKind::FavorCpu);
        assert_eq!(cfg.n_gpus, 1, "single device by default");
        assert_eq!(cfg.shard_bits, 12, "16 KB ownership blocks");
        assert_eq!(cfg.cross_shard_prob, 0.0);
        assert_eq!(cfg.workload, "synth");
    }

    #[test]
    fn workload_key_parses() {
        let raw = Raw::parse("workload = \"bank\"\n").unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.workload, "bank");
    }

    #[test]
    fn cluster_keys_parse() {
        let raw = Raw::parse(
            "[cluster]\nn_gpus = 4\nshard_bits = 8\ncross_shard_prob = 0.05\nthreads = 4\n",
        )
        .unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.n_gpus, 4);
        assert_eq!(cfg.shard_bits, 8);
        assert!((cfg.cross_shard_prob - 0.05).abs() < 1e-12);
        assert_eq!(cfg.cluster_threads, 4);
    }

    #[test]
    fn rebalance_keys_parse_and_default_off() {
        let cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
        assert!(!cfg.rebalance, "rebalancer is opt-in");
        assert_eq!(cfg.rebalance_interval, 4);
        assert!((cfg.rebalance_threshold - 1.25).abs() < 1e-12);
        assert_eq!(cfg.rebalance_granules, 8);
        assert!(cfg.dev_speed.is_empty(), "uniform cluster by default");

        let raw = Raw::parse(
            "[cluster]\nn_gpus = 2\nrebalance = true\nrebalance_interval = 2\n\
             rebalance_threshold = 1.5\nrebalance_granules = 3\ndev_speed = \"1.0, 2.0\"\n",
        )
        .unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert!(cfg.rebalance);
        assert_eq!(cfg.rebalance_interval, 2);
        assert!((cfg.rebalance_threshold - 1.5).abs() < 1e-12);
        assert_eq!(cfg.rebalance_granules, 3);
        assert_eq!(cfg.dev_speed, vec![1.0, 2.0]);
    }

    #[test]
    fn rebalance_knobs_are_validated() {
        for bad in [
            "cluster.rebalance_interval=0",
            "cluster.rebalance_threshold=1.0",
            "cluster.rebalance_threshold=NaN",
            "cluster.rebalance_granules=0",
        ] {
            let mut raw = Raw::new();
            raw.set(bad).unwrap();
            assert!(SystemConfig::from_raw(&raw).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn dev_speed_must_match_n_gpus_and_be_positive() {
        let mut raw = Raw::new();
        raw.set("cluster.n_gpus=4").unwrap();
        raw.set("cluster.dev_speed=1.0,2.0").unwrap();
        assert!(
            SystemConfig::from_raw(&raw).is_err(),
            "2 factors for 4 devices must be rejected"
        );
        for bad in ["0.0,1.0,1.0,1.0", "-1.0,1.0,1.0,1.0", "inf,1.0,1.0,1.0"] {
            let mut raw = Raw::new();
            raw.set("cluster.n_gpus=4").unwrap();
            raw.set(&format!("cluster.dev_speed={bad}")).unwrap();
            assert!(SystemConfig::from_raw(&raw).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn cluster_threads_defaults_to_sequential() {
        let cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
        assert_eq!(cfg.cluster_threads, 1);
    }

    #[test]
    fn cluster_threads_zero_is_rejected() {
        let mut raw = Raw::new();
        raw.set("cluster.threads=0").unwrap();
        assert!(SystemConfig::from_raw(&raw).is_err(), "0 threads is invalid");
    }

    #[test]
    fn early_interval_frac_is_validated_at_parse() {
        for bad in ["0", "-0.25", "NaN", "inf", "1.5"] {
            let mut raw = Raw::new();
            raw.set(&format!("hetm.early_interval_frac={bad}")).unwrap();
            assert!(
                SystemConfig::from_raw(&raw).is_err(),
                "early_interval_frac={bad} must be rejected at parse time"
            );
        }
        for good in ["0.25", "1.0", "0.01"] {
            let mut raw = Raw::new();
            raw.set(&format!("hetm.early_interval_frac={good}")).unwrap();
            assert!(SystemConfig::from_raw(&raw).is_ok(), "{good} is valid");
        }
    }

    #[test]
    fn log_compaction_and_chunk_filter_keys_parse() {
        let cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
        assert!(!cfg.log_compaction, "compaction off by default");
        assert!(!cfg.chunk_filter, "filter off by default");
        let mut raw = Raw::new();
        raw.set("hetm.log_compaction=true").unwrap();
        raw.set("hetm.chunk_filter=true").unwrap();
        raw.set("gpu.sig_check_ns=500").unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert!(cfg.log_compaction);
        assert!(cfg.chunk_filter);
        assert!((cfg.gpu_sig_check_s - 500e-9).abs() < 1e-18);
        let mut raw = Raw::new();
        raw.set("hetm.chunk_filter=maybe").unwrap();
        assert!(SystemConfig::from_raw(&raw).is_err(), "bools are validated");
    }

    #[test]
    fn durability_keys_parse() {
        let cfg = SystemConfig::from_raw(&Raw::new()).unwrap();
        assert!(cfg.checkpoint_dir.is_empty(), "durability off by default");
        assert_eq!(cfg.checkpoint_interval_rounds, 1);
        assert!(cfg.crash_point.is_empty());
        let raw = Raw::parse(
            "[durability]\ncheckpoint_dir = \"/tmp/ck\"\ninterval_rounds = 3\n\
             crash_point = \"mid-wal-append\"\ncrash_round = 2\n",
        )
        .unwrap();
        let cfg = SystemConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.checkpoint_dir, "/tmp/ck");
        assert_eq!(cfg.checkpoint_interval_rounds, 3);
        assert_eq!(cfg.crash_point, "mid-wal-append");
        assert_eq!(cfg.crash_round, 2);
    }

    #[test]
    fn bad_values_are_errors() {
        assert!(Raw::parse("[x\nk=v").is_err());
        assert!(Raw::parse("novalue\n").is_err());
        let mut raw = Raw::new();
        raw.set("cpu.guest=weird").unwrap();
        assert!(SystemConfig::from_raw(&raw).is_err());
        let mut raw = Raw::new();
        raw.set("hetm.early_validation=maybe").unwrap();
        assert!(SystemConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let raw = Raw::parse("name = \"a#b\"\n").unwrap();
        assert_eq!(raw.get("name"), Some("a#b"));
    }
}
