//! Tiny deterministic JSON builders (serde is unavailable offline).
//!
//! Every machine-readable artifact in the repo — `MetricsSnapshot`
//! exports, trace events, and the `BENCH_*.json` files written by the
//! benches — is rendered through these builders so that the byte layout
//! is identical across runs and platforms.  Floats are always formatted
//! with an explicit, fixed number of decimals; map keys appear in the
//! order fields were added (callers add them in a deterministic order).

/// Escape a string for embedding in a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Builder for one JSON object; fields render in insertion order.
#[derive(Debug, Clone)]
pub struct Obj {
    buf: String,
}

impl Default for Obj {
    fn default() -> Self {
        Obj::new()
    }
}

impl Obj {
    /// Start an empty object.
    pub fn new() -> Self {
        Obj { buf: String::from("{") }
    }

    fn key(&mut self, k: &str) {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        self.buf.push('"');
        self.buf.push_str(&escape(k));
        self.buf.push_str("\":");
    }

    /// Add a string field.
    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    /// Add an unsigned integer field.
    pub fn u64(mut self, k: &str, v: u64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Add a signed integer field.
    pub fn i64(mut self, k: &str, v: i64) -> Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Add a float field with a fixed number of decimals (deterministic).
    pub fn f64(mut self, k: &str, v: f64, decimals: usize) -> Self {
        self.key(k);
        self.buf.push_str(&format!("{v:.decimals$}"));
        self
    }

    /// Add a boolean field.
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Add a field whose value is pre-rendered JSON (object, array, number).
    pub fn raw(mut self, k: &str, json: &str) -> Self {
        self.key(k);
        self.buf.push_str(json);
        self
    }

    /// Finish the object and return its JSON text.
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// Builder for one JSON array of pre-rendered elements.
#[derive(Debug, Clone, Default)]
pub struct Arr {
    items: Vec<String>,
}

impl Arr {
    /// Start an empty array.
    pub fn new() -> Self {
        Arr::default()
    }

    /// Append a pre-rendered JSON value.
    pub fn push(&mut self, json: String) {
        self.items.push(json);
    }

    /// Render on one line: `[a,b,c]`.
    pub fn finish(self) -> String {
        format!("[{}]", self.items.join(","))
    }

    /// Render with one element per line (used for `points` in bench files).
    pub fn finish_lines(self) -> String {
        if self.items.is_empty() {
            return "[]".to_string();
        }
        format!("[\n{}\n]", self.items.join(",\n"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_renders_in_order() {
        let o = Obj::new()
            .str("name", "a\"b")
            .u64("n", 3)
            .f64("x", 1.5, 3)
            .bool("ok", true)
            .raw("inner", "[1,2]")
            .finish();
        assert_eq!(o, r#"{"name":"a\"b","n":3,"x":1.500,"ok":true,"inner":[1,2]}"#);
    }

    #[test]
    fn array_renders_lines() {
        let mut a = Arr::new();
        a.push("{\"i\":0}".into());
        a.push("{\"i\":1}".into());
        assert_eq!(a.finish_lines(), "[\n{\"i\":0},\n{\"i\":1}\n]");
        assert_eq!(Arr::new().finish(), "[]");
    }

    #[test]
    fn escape_handles_controls() {
        assert_eq!(escape("a\nb\t\u{1}"), "a\\nb\\t\\u0001");
    }
}
