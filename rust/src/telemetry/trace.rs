//! Chrome/Perfetto-compatible trace events stamped in **virtual time**.
//!
//! The collector derives every event at the round barrier, on the
//! coordinator thread, purely from per-round deterministic data (the
//! `RoundStats` plus per-device partials folded in device-index order) —
//! never inline from interleaved execution.  That is what makes the
//! emitted stream bit-identical across `--threads N` and across the
//! single-device vs. cluster engines at `n_gpus = 1`.
//!
//! ## File format
//!
//! The writer emits a *valid JSON array with exactly one event object
//! per line* (the "JSON Array Format" of the Chrome trace spec, laid out
//! line-wise).  `chrome://tracing` and [ui.perfetto.dev] load it
//! directly, while line-oriented tools (`jq`, grep, the schema
//! validator below) can still process it one event per line.
//!
//! ## Timestamps
//!
//! Virtual-time seconds are converted once, deterministically:
//! `ns = round(t * 1e9)`, rendered as microseconds with exactly three
//! decimals (`ns / 1000 . ns % 1000`).  Two runs that agree on the f64
//! virtual times agree on every emitted byte.
//!
//! [ui.perfetto.dev]: https://ui.perfetto.dev

use super::json::Obj;

/// Trace process id (single simulated process).
pub const PID: u32 = 1;
/// Thread id for the coordinator timeline.
pub const TID_COORD: u32 = 0;
/// Thread id for the CPU timeline.
pub const TID_CPU: u32 = 1;
/// Thread id for device `d` is `TID_GPU_BASE + d`.
pub const TID_GPU_BASE: u32 = 100;

/// Convert virtual-time seconds to integer nanoseconds (deterministic).
pub fn virt_ns(t: f64) -> u64 {
    (t * 1e9).round().max(0.0) as u64
}

/// Render nanoseconds as the Chrome `ts`/`dur` microsecond field with
/// exactly three decimals.
pub fn micros(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// One trace event.  `ph` is `'X'` (complete span) or `'i'` (instant);
/// metadata events are synthesized by the renderer.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event name (static: the schema enumerates them).
    pub name: &'static str,
    /// Phase: `'X'` span or `'i'` instant.
    pub ph: char,
    /// Thread id ([`TID_COORD`], [`TID_CPU`], or `TID_GPU_BASE + d`).
    pub tid: u32,
    /// Start timestamp in virtual nanoseconds.
    pub ts_ns: u64,
    /// Duration in virtual nanoseconds (spans only; 0 for instants).
    pub dur_ns: u64,
    /// Pre-rendered JSON object for `args` (empty string = omitted).
    pub args: String,
}

impl TraceEvent {
    /// A complete span.
    pub fn span(name: &'static str, tid: u32, ts_ns: u64, dur_ns: u64, args: String) -> Self {
        TraceEvent { name, ph: 'X', tid, ts_ns, dur_ns, args }
    }

    /// A thread-scoped instant event.
    pub fn instant(name: &'static str, tid: u32, ts_ns: u64, args: String) -> Self {
        TraceEvent { name, ph: 'i', tid, ts_ns, dur_ns: 0, args }
    }

    fn render(&self) -> String {
        let mut o = Obj::new()
            .str("name", self.name)
            .str("cat", "hetm")
            .str("ph", &self.ph.to_string())
            .u64("pid", PID as u64)
            .u64("tid", self.tid as u64)
            .raw("ts", &micros(self.ts_ns));
        if self.ph == 'X' {
            o = o.raw("dur", &micros(self.dur_ns));
        }
        if self.ph == 'i' {
            o = o.str("s", "t");
        }
        if !self.args.is_empty() {
            o = o.raw("args", &self.args);
        }
        o.finish()
    }
}

fn metadata(name: &'static str, tid: u32, value: &str) -> String {
    Obj::new()
        .str("name", name)
        .str("ph", "M")
        .u64("pid", PID as u64)
        .u64("tid", tid as u64)
        .raw("args", &Obj::new().str("name", value).finish())
        .finish()
}

/// Render a full trace document: metadata naming the process and the
/// coordinator/cpu/gpu timelines for `n_devices` devices, followed by
/// `events`, one JSON object per line inside a valid JSON array.
pub fn render_trace(events: &[TraceEvent], n_devices: usize) -> String {
    let mut lines: Vec<String> = Vec::with_capacity(events.len() + n_devices + 3);
    lines.push(metadata("process_name", TID_COORD, "shetm"));
    lines.push(metadata("thread_name", TID_COORD, "coordinator"));
    lines.push(metadata("thread_name", TID_CPU, "cpu"));
    for d in 0..n_devices {
        let name = format!("gpu{d}");
        lines.push(metadata("thread_name", TID_GPU_BASE + d as u32, &name));
    }
    for e in events {
        lines.push(e.render());
    }
    let mut out = String::from("[\n");
    let last = lines.len().saturating_sub(1);
    for (i, l) in lines.into_iter().enumerate() {
        out.push_str(&l);
        out.push_str(if i == last { "\n" } else { ",\n" });
    }
    out.push(']');
    out
}

/// Check that a JSON value on one line is structurally sound: balanced
/// braces/brackets outside string literals, no stray quotes.
fn balanced(line: &str) -> bool {
    let (mut depth, mut in_str, mut esc) = (0i32, false, false);
    for c in line.chars() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => depth += 1,
            '}' | ']' => depth -= 1,
            _ => {}
        }
        if depth < 0 {
            return false;
        }
    }
    depth == 0 && !in_str
}

/// Validate a trace document against the schema in
/// `docs/OBSERVABILITY.md`; returns the number of non-metadata events.
///
/// Checked per line: the array framing, JSON balance, required fields
/// (`name`, `ph`, `pid`, `tid`), a known phase (`M`/`X`/`i`), `ts` + `dur`
/// on spans, and `ts` + thread scope on instants.
pub fn validate_trace(text: &str) -> Result<usize, String> {
    let mut lines = text.lines();
    if lines.next().map(str::trim) != Some("[") {
        return Err("trace must start with a '[' line".into());
    }
    let mut events = 0usize;
    let mut closed = false;
    for (i, raw) in lines.enumerate() {
        let line = raw.trim();
        if line == "]" {
            closed = true;
            continue;
        }
        if closed {
            return Err(format!("line {}: content after closing ']'", i + 2));
        }
        let obj = line.strip_suffix(',').unwrap_or(line);
        let err = |msg: &str| Err(format!("line {}: {msg}: {obj}", i + 2));
        if !obj.starts_with('{') || !obj.ends_with('}') {
            return err("event is not a JSON object");
        }
        if !balanced(obj) {
            return err("unbalanced JSON");
        }
        for field in ["\"name\":\"", "\"ph\":\"", "\"pid\":", "\"tid\":"] {
            if !obj.contains(field) {
                return err(&format!("missing required field {field}"));
            }
        }
        let ph = obj
            .split("\"ph\":\"")
            .nth(1)
            .and_then(|s| s.chars().next())
            .ok_or_else(|| format!("line {}: bad ph", i + 2))?;
        match ph {
            'M' => {}
            'X' => {
                if !obj.contains("\"ts\":") || !obj.contains("\"dur\":") {
                    return err("span missing ts/dur");
                }
                events += 1;
            }
            'i' => {
                if !obj.contains("\"ts\":") || !obj.contains("\"s\":\"t\"") {
                    return err("instant missing ts or thread scope");
                }
                events += 1;
            }
            other => return err(&format!("unknown phase {other:?}")),
        }
    }
    if !closed {
        return Err("trace must end with a ']' line".into());
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micros_formatting_is_exact() {
        assert_eq!(micros(0), "0.000");
        assert_eq!(micros(1_234_567), "1234.567");
        assert_eq!(micros(999), "0.999");
        assert_eq!(virt_ns(0.002), 2_000_000);
    }

    #[test]
    fn render_and_validate_round_trip() {
        let events = vec![
            TraceEvent::span("round", TID_COORD, 0, 2_000_000, Obj::new().u64("round", 0).finish()),
            TraceEvent::span("processing", TID_CPU, 0, 1_500_000, String::new()),
            TraceEvent::instant("epoch_reset", TID_COORD, 2_000_000, Obj::new().i64("base", 7).finish()),
        ];
        let doc = render_trace(&events, 2);
        assert_eq!(validate_trace(&doc).unwrap(), 3);
        assert!(doc.contains("\"name\":\"gpu1\""));
        // Perfetto-loadable: the whole document is one valid JSON array.
        assert!(doc.starts_with("[\n") && doc.ends_with(']'));
    }

    #[test]
    fn validator_rejects_garbage() {
        assert!(validate_trace("not a trace").is_err());
        assert!(validate_trace("[\n{\"name\":\"x\"}\n]").is_err());
        let bad_ph = "[\n{\"name\":\"x\",\"ph\":\"Q\",\"pid\":1,\"tid\":0}\n]";
        assert!(validate_trace(bad_ph).unwrap_err().contains("unknown phase"));
        let unbalanced = "[\n{\"name\":\"x\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\"args\":{\n]";
        assert!(validate_trace(unbalanced).is_err());
    }

    #[test]
    fn empty_trace_validates() {
        let doc = render_trace(&[], 0);
        assert_eq!(validate_trace(&doc).unwrap(), 0);
    }
}
