//! Labeled metrics registry: counters, gauges, and deterministic
//! log-linear histograms.
//!
//! The histogram is the part that has to be engineered carefully: the
//! cluster engine observes per-chunk costs on per-lane scratch buffers
//! and folds them into the registry in device-index order at the round
//! barrier, so **merge must be exactly order-insensitive** or threaded
//! runs would diverge from sequential ones.  We get that by construction:
//!
//! * bucketing is pure bit manipulation on the `f64` (biased exponent +
//!   top two mantissa bits → 4 linear sub-buckets per octave), so every
//!   value maps to one bucket with no platform-dependent rounding;
//! * bucket counts are `u64` and the running sum is fixed-point `i128`
//!   picoseconds, so merge is integer addition — commutative and
//!   associative down to the last bit;
//! * min/max use `f64::min`/`max`, which are commutative for the
//!   non-NaN values we record.
//!
//! Quantiles (p50/p99/p999) report the lower edge of the bucket holding
//! the target rank — a deterministic value, accurate to the ~6% bucket
//! width, which is plenty for round-latency and bus-cost distributions.

use std::collections::BTreeMap;

use super::json::Obj;

/// First biased exponent tracked (2^-40 ≈ 0.9 ps when values are seconds).
const E0: i64 = 983;
/// Octaves covered: exponents 2^-40 .. 2^10 (≈ 17 minutes of virtual time).
const OCTAVES: usize = 51;
/// Linear sub-buckets per octave (top two mantissa bits).
const SUBS: usize = 4;
/// Total bucket count; out-of-range values clamp to the edge buckets.
pub const HIST_BUCKETS: usize = OCTAVES * SUBS;

/// Deterministic log-linear histogram over non-negative `f64` samples
/// (by convention: seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    /// Exact running sum in fixed-point picoseconds (1e-12).
    sum_ps: i128,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum_ps: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Map a value to its bucket index (pure bit manipulation; total).
    pub fn bucket_index(v: f64) -> usize {
        if !v.is_finite() || v <= 0.0 {
            return 0;
        }
        let bits = v.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i64; // biased exponent
        let sub = ((bits >> 50) & 0x3) as i64; // top 2 mantissa bits
        ((e - E0) * SUBS as i64 + sub).clamp(0, HIST_BUCKETS as i64 - 1) as usize
    }

    /// Lower edge of bucket `idx`, reconstructed exactly from the index.
    pub fn bucket_lower(idx: usize) -> f64 {
        let idx = idx.min(HIST_BUCKETS - 1);
        let e = (E0 + (idx / SUBS) as i64) as u64;
        let sub = (idx % SUBS) as u64;
        f64::from_bits((e << 52) | (sub << 50))
    }

    /// Record one sample.
    pub fn observe(&mut self, v: f64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum_ps += (v * 1e12).round() as i128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram in.  Exactly commutative and associative.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ps += other.sum_ps;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of bucket counts (equals `count()` when conservation holds).
    pub fn bucket_total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Raw bucket counts (for the property tests).
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Mean sample value in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            (self.sum_ps as f64 / 1e12) / self.count as f64
        }
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate: lower edge of the bucket holding rank `⌈q·n⌉`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_lower(i);
            }
        }
        Self::bucket_lower(HIST_BUCKETS - 1)
    }

    /// Render as a JSON object (count, sum, min/max, key quantiles).
    pub fn to_json(&self) -> String {
        Obj::new()
            .u64("count", self.count)
            .f64("sum_s", self.sum_ps as f64 / 1e12, 9)
            .f64("min_s", self.min(), 9)
            .f64("max_s", self.max(), 9)
            .f64("p50_s", self.quantile(0.50), 9)
            .f64("p99_s", self.quantile(0.99), 9)
            .f64("p999_s", self.quantile(0.999), 9)
            .finish()
    }
}

/// Labeled metrics registry.  Names follow Prometheus conventions with
/// inline labels, e.g. `hetm_bus_h2d_seconds{device="0"}`; `BTreeMap`
/// keys give every renderer a deterministic iteration order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    /// Increment a counter by `by` (creating it at zero first).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge to `v`.
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record one histogram sample.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(v);
    }

    /// Fold a pre-built histogram into `name` (used for per-lane scratch).
    pub fn merge_histogram(&mut self, name: &str, h: &Histogram) {
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    /// Is `name` a wall-clock metric?  By convention (DESIGN.md §15)
    /// any metric whose name contains `_wall` measures host real time:
    /// it may vary between bit-identical runs and is excluded from
    /// deterministic comparison ([`Self::deterministic`]) and from perf
    /// gating (`scripts/check_perf.py`).
    pub fn is_wall_clock(name: &str) -> bool {
        name.contains("_wall")
    }

    /// The deterministic view of this registry: every metric except the
    /// wall-clock family ([`Self::is_wall_clock`]).  Two runs of the
    /// same configuration and seed must produce *equal* deterministic
    /// views — `rust/tests/telemetry.rs` pins this with checkpoints
    /// enabled (whose write histogram is wall-clock).
    pub fn deterministic(&self) -> MetricsRegistry {
        MetricsRegistry {
            counters: self
                .counters
                .iter()
                .filter(|(k, _)| !Self::is_wall_clock(k))
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            gauges: self
                .gauges
                .iter()
                .filter(|(k, _)| !Self::is_wall_clock(k))
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            histograms: self
                .histograms
                .iter()
                .filter(|(k, _)| !Self::is_wall_clock(k))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        }
    }

    /// Current counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current gauge value (None when absent).
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Histogram by name.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Iterate counters in name order.
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate gauges in name order.
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate histograms in name order.
    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_total() {
        let vals = [
            0.0,
            -1.0,
            f64::NAN,
            1e-15,
            2.3e-9,
            1e-6,
            0.5e-3,
            1.0,
            999.0,
            1e9,
        ];
        for v in vals {
            let i = Histogram::bucket_index(v);
            assert!(i < HIST_BUCKETS);
        }
        // Monotone over positives.
        let mut last = 0;
        for k in 0..200 {
            let v = 1e-12 * 1.5f64.powi(k);
            let i = Histogram::bucket_index(v);
            assert!(i >= last, "bucket index must be monotone");
            last = i;
        }
    }

    #[test]
    fn bucket_lower_bounds_its_members() {
        for v in [3.7e-9, 1.2e-4, 0.25, 7.5] {
            let i = Histogram::bucket_index(v);
            assert!(Histogram::bucket_lower(i) <= v);
            if i + 1 < HIST_BUCKETS {
                assert!(Histogram::bucket_lower(i + 1) > v);
            }
        }
    }

    #[test]
    fn quantiles_and_mean() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-6); // 1µs..1ms
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.bucket_total(), 1000);
        let p50 = h.quantile(0.5);
        assert!(p50 > 3e-4 && p50 <= 5.2e-4, "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 > 8e-4 && p99 <= 1.1e-3, "p99 {p99}");
        assert!((h.mean() - 5.005e-4).abs() < 1e-6);
        assert!((h.min() - 1e-6).abs() < 1e-12);
        assert!((h.max() - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn merge_matches_single_stream() {
        let vals: Vec<f64> = (0..500).map(|i| 1e-7 * (i as f64 + 0.5)).collect();
        let mut whole = Histogram::new();
        for &v in &vals {
            whole.observe(v);
        }
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &v) in vals.iter().enumerate() {
            if i % 3 == 0 {
                a.observe(v);
            } else {
                b.observe(v);
            }
        }
        let mut merged = Histogram::new();
        merged.merge(&b);
        merged.merge(&a);
        assert_eq!(merged, whole);
    }

    #[test]
    fn registry_basics() {
        let mut r = MetricsRegistry::new();
        r.inc("hetm_rounds_total", 2);
        r.inc("hetm_rounds_total", 1);
        r.set_gauge("hetm_virtual_time_seconds", 1.25);
        r.observe("hetm_round_latency_seconds", 0.002);
        assert_eq!(r.counter("hetm_rounds_total"), 3);
        assert_eq!(r.gauge("hetm_virtual_time_seconds"), Some(1.25));
        assert_eq!(r.histogram("hetm_round_latency_seconds").unwrap().count(), 1);
        assert!(!r.is_empty());
    }
}
