//! `MetricsSnapshot` — the single exporter for run results.
//!
//! One snapshot captures everything a run produced: the aggregate
//! [`RunStats`], the per-device [`ClusterStats`] (cluster engine only),
//! the live [`MetricsRegistry`] (when telemetry was enabled), and the
//! workload's own oracle summary line.  Every consumer renders from it:
//!
//! * `shetm` (main.rs) prints [`MetricsSnapshot::render_text`] — the
//!   human-readable block previously hand-rolled in two places;
//! * `--trace`/tooling exports [`MetricsSnapshot::to_json`] and
//!   [`MetricsSnapshot::to_prometheus`];
//! * the benches write `BENCH_*.json` through [`write_bench_json`].

use std::fmt::Write as _;

use crate::cluster::ClusterStats;
use crate::coordinator::RunStats;

use super::json::{Arr, Obj};
use super::metrics::MetricsRegistry;

/// A point-in-time export of one run's statistics and metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Human-readable run label (printed as the `== label ==` header).
    pub label: String,
    /// Deterministic key/value metadata (workload, n_gpus, threads, ...).
    pub meta: Vec<(String, String)>,
    /// Aggregate engine statistics.
    pub run: RunStats,
    /// Per-device statistics (cluster engine only).
    pub cluster: Option<ClusterStats>,
    /// Telemetry registry contents (None when telemetry was off).
    pub registry: Option<MetricsRegistry>,
    /// The workload's `stats_summary()` line ("" when it has none).
    pub workload_summary: String,
}

impl MetricsSnapshot {
    /// Snapshot of bare [`RunStats`] (baselines and tools that have no
    /// session — no cluster stats, no registry, no workload summary).
    pub fn from_run_stats(label: &str, run: &RunStats) -> Self {
        MetricsSnapshot {
            label: label.to_string(),
            meta: Vec::new(),
            run: run.clone(),
            cluster: None,
            registry: None,
            workload_summary: String::new(),
        }
    }

    /// This snapshot with wall-clock metrics removed from the registry
    /// ([`MetricsRegistry::deterministic`]): the view to diff when
    /// comparing two runs for bit-identical behavior — wall-clock
    /// families (e.g. `hetm_checkpoint_write_wall_seconds`) measure the
    /// host, not the engine, and legitimately differ between otherwise
    /// identical runs (DESIGN.md §15).
    pub fn deterministic(&self) -> MetricsSnapshot {
        let mut s = self.clone();
        s.registry = s.registry.map(|r| r.deterministic());
        s
    }

    /// Render the human-readable stats block (the format `shetm`
    /// subcommands print after a run).
    pub fn render_text(&self) -> String {
        let s = &self.run;
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.label);
        let _ = writeln!(
            out,
            "  rounds            : {} ({} committed, {} early-aborted)",
            s.rounds, s.rounds_committed, s.rounds_early_aborted
        );
        let _ = writeln!(out, "  virtual duration  : {:.4} s", s.duration_s);
        let _ = writeln!(
            out,
            "  cpu commits       : {} ({} attempts)",
            s.cpu_commits, s.cpu_attempts
        );
        let _ = writeln!(
            out,
            "  gpu commits       : {} ({} attempts)",
            s.gpu_commits, s.gpu_attempts
        );
        let _ = writeln!(out, "  discarded commits : {}", s.discarded_commits);
        let _ = writeln!(out, "  log chunks        : {}", s.chunks);
        let _ = writeln!(
            out,
            "  log entries       : {} raw -> {} shipped ({} chunks filtered, {} skipped post-abort)",
            s.log_entries_raw, s.log_entries_shipped, s.chunks_filtered, s.chunks_skipped_post_abort
        );
        let _ = writeln!(out, "  throughput        : {:.0} tx/s", s.throughput());
        let _ = writeln!(out, "  round abort rate  : {:.3}", s.round_abort_rate());
        let c = &s.cpu_phases;
        let g = &s.gpu_phases;
        let _ = writeln!(
            out,
            "  cpu phases (s)    : proc {:.4} validate {:.4} merge {:.4} blocked {:.4}",
            c.processing_s, c.validation_s, c.merge_s, c.blocked_s
        );
        let _ = writeln!(
            out,
            "  gpu phases (s)    : proc {:.4} validate {:.4} merge {:.4} blocked {:.4}",
            g.processing_s, g.validation_s, g.merge_s, g.blocked_s
        );
        if let Some(cl) = &self.cluster {
            let _ = writeln!(
                out,
                "  cross-shard       : {} checks, {} escalations, {} conflict entries",
                cl.cross_checks, cl.cross_escalations, cl.cross_conflict_entries
            );
            let _ = writeln!(
                out,
                "  cross-shard aborts: {} rounds ({:.3} of all rounds)",
                cl.rounds_aborted_cross_shard,
                cl.cross_shard_abort_rate(s.rounds)
            );
            let _ = writeln!(
                out,
                "  refresh traffic   : {} KiB in {} DMAs",
                cl.refresh_bytes / 1024,
                cl.refresh_transfers
            );
            let _ = writeln!(
                out,
                "  shard imbalance   : {:.3} max/mean shipped | {} migrations, \
                 {} blocks, {} KiB",
                cl.shipped_imbalance(),
                cl.migrations,
                cl.granules_moved,
                cl.migrated_bytes / 1024
            );
            for (d, dev) in cl.per_device.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "  gpu[{d}]            : {} commits {} batches {} chunks ({} filtered) | \
                     proc {:.4} validate {:.4} merge {:.4} blocked {:.4}",
                    dev.commits,
                    dev.batches,
                    dev.chunks,
                    dev.chunks_filtered,
                    dev.phases.processing_s,
                    dev.phases.validation_s,
                    dev.phases.merge_s,
                    dev.phases.blocked_s
                );
            }
        }
        if let Some(reg) = &self.registry {
            for (name, h) in reg.histograms() {
                let _ = writeln!(
                    out,
                    "  hist {name}: n={} p50={:.6} p99={:.6} p999={:.6} max={:.6}",
                    h.count(),
                    h.quantile(0.50),
                    h.quantile(0.99),
                    h.quantile(0.999),
                    h.max()
                );
            }
        }
        if !self.workload_summary.is_empty() {
            let _ = writeln!(out, "  {}", self.workload_summary);
        }
        out.pop(); // drop the trailing newline for println! callers
        out
    }

    fn phases_json(p: &crate::coordinator::PhaseBreakdown) -> String {
        Obj::new()
            .f64("processing_s", p.processing_s, 9)
            .f64("validation_s", p.validation_s, 9)
            .f64("merge_s", p.merge_s, 9)
            .f64("blocked_s", p.blocked_s, 9)
            .finish()
    }

    fn run_json(s: &RunStats) -> String {
        Obj::new()
            .u64("rounds", s.rounds)
            .u64("rounds_committed", s.rounds_committed)
            .u64("rounds_early_aborted", s.rounds_early_aborted)
            .f64("duration_s", s.duration_s, 9)
            .u64("cpu_commits", s.cpu_commits)
            .u64("cpu_attempts", s.cpu_attempts)
            .u64("gpu_commits", s.gpu_commits)
            .u64("gpu_attempts", s.gpu_attempts)
            .u64("discarded_commits", s.discarded_commits)
            .u64("chunks", s.chunks)
            .u64("log_entries_raw", s.log_entries_raw)
            .u64("log_entries_shipped", s.log_entries_shipped)
            .u64("chunks_filtered", s.chunks_filtered)
            .u64("chunks_skipped_post_abort", s.chunks_skipped_post_abort)
            .f64("throughput_tx_per_s", s.throughput(), 3)
            .f64("round_abort_rate", s.round_abort_rate(), 6)
            .raw("cpu_phases", &Self::phases_json(&s.cpu_phases))
            .raw("gpu_phases", &Self::phases_json(&s.gpu_phases))
            .finish()
    }

    fn cluster_json(s: &RunStats, c: &ClusterStats) -> String {
        let mut devs = Arr::new();
        for dev in &c.per_device {
            devs.push(
                Obj::new()
                    .u64("commits", dev.commits)
                    .u64("attempts", dev.attempts)
                    .u64("batches", dev.batches)
                    .u64("chunks", dev.chunks)
                    .u64("chunks_filtered", dev.chunks_filtered)
                    .u64("conflict_entries", dev.conflict_entries)
                    .u64("refresh_bytes", dev.refresh_bytes)
                    .u64("refresh_transfers", dev.refresh_transfers)
                    .u64("shipped_entries", dev.shipped_entries)
                    .raw("phases", &Self::phases_json(&dev.phases))
                    .finish(),
            );
        }
        Obj::new()
            .u64("cross_checks", c.cross_checks)
            .u64("cross_escalations", c.cross_escalations)
            .u64("cross_conflict_entries", c.cross_conflict_entries)
            .u64("rounds_aborted_cross_shard", c.rounds_aborted_cross_shard)
            .f64("cross_shard_abort_rate", c.cross_shard_abort_rate(s.rounds), 6)
            .u64("refresh_bytes", c.refresh_bytes)
            .u64("refresh_transfers", c.refresh_transfers)
            .f64("shard_imbalance", c.shipped_imbalance(), 6)
            .u64("migrations", c.migrations)
            .u64("granules_moved", c.granules_moved)
            .u64("migrated_bytes", c.migrated_bytes)
            .raw("per_device", &devs.finish())
            .finish()
    }

    /// Export everything as one JSON document.
    pub fn to_json(&self) -> String {
        let mut meta = Obj::new();
        for (k, v) in &self.meta {
            meta = meta.str(k, v);
        }
        let mut o = Obj::new()
            .str("label", &self.label)
            .raw("meta", &meta.finish())
            .raw("run", &Self::run_json(&self.run));
        if let Some(c) = &self.cluster {
            o = o.raw("cluster", &Self::cluster_json(&self.run, c));
        }
        if !self.workload_summary.is_empty() {
            o = o.str("workload_summary", &self.workload_summary);
        }
        if let Some(reg) = &self.registry {
            let mut counters = Obj::new();
            for (k, v) in reg.counters() {
                counters = counters.u64(k, v);
            }
            let mut gauges = Obj::new();
            for (k, v) in reg.gauges() {
                gauges = gauges.f64(k, v, 9);
            }
            let mut hists = Obj::new();
            for (k, h) in reg.histograms() {
                hists = hists.raw(k, &h.to_json());
            }
            o = o.raw(
                "metrics",
                &Obj::new()
                    .raw("counters", &counters.finish())
                    .raw("gauges", &gauges.finish())
                    .raw("histograms", &hists.finish())
                    .finish(),
            );
        }
        o.finish()
    }

    /// Export the registry in the Prometheus text exposition format.
    /// Histograms are rendered as summaries (`quantile` labels).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        // The shard-balance family is derived from `ClusterStats` rather
        // than the live registry, so it exports even with telemetry off.
        if let Some(cl) = &self.cluster {
            let _ = writeln!(out, "# TYPE cluster_shard_imbalance gauge");
            let _ = writeln!(
                out,
                "cluster_shard_imbalance {:.9}",
                cl.shipped_imbalance()
            );
            let _ = writeln!(out, "# TYPE cluster_migrations_total counter");
            let _ = writeln!(out, "cluster_migrations_total {}", cl.migrations);
            let _ = writeln!(out, "# TYPE cluster_granules_moved_total counter");
            let _ = writeln!(out, "cluster_granules_moved_total {}", cl.granules_moved);
        }
        let Some(reg) = &self.registry else {
            return out;
        };
        let mut last_type: Option<(String, &str)> = None;
        let mut type_line = |out: &mut String, name: &str, kind: &'static str| {
            let base = name.split('{').next().unwrap_or(name).to_string();
            if last_type.as_ref().map(|(b, k)| (b.as_str(), *k)) != Some((base.as_str(), kind)) {
                let _ = writeln!(out, "# TYPE {base} {kind}");
                last_type = Some((base, kind));
            }
        };
        for (name, v) in reg.counters() {
            type_line(&mut out, name, "counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in reg.gauges() {
            type_line(&mut out, name, "gauge");
            let _ = writeln!(out, "{name} {v:.9}");
        }
        for (name, h) in reg.histograms() {
            type_line(&mut out, name, "summary");
            let (base, labels) = match name.split_once('{') {
                Some((b, rest)) => (b, rest.trim_end_matches('}')),
                None => (name, ""),
            };
            let with = |extra: &str| {
                if labels.is_empty() {
                    format!("{base}{{{extra}}}")
                } else {
                    format!("{base}{{{labels},{extra}}}")
                }
            };
            for (q, label) in [(0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")] {
                let _ = writeln!(
                    out,
                    "{} {:.9}",
                    with(&format!("quantile=\"{label}\"")),
                    h.quantile(q)
                );
            }
            let _ = writeln!(out, "{base}_sum{{{labels}}} {:.9}", h.mean() * h.count() as f64);
            let _ = writeln!(out, "{base}_count{{{labels}}} {}", h.count());
        }
        out
    }
}

/// Assemble one `BENCH_*.json` document: a `bench` name, the `fast`
/// flag, extra top-level fields (pre-rendered JSON values), and the
/// measurement points, one object per line.
pub fn bench_doc(bench: &str, fast: bool, extras: &[(&str, String)], points: Vec<String>) -> String {
    let mut o = Obj::new().str("bench", bench).bool("fast", fast);
    for (k, v) in extras {
        o = o.raw(k, v);
    }
    let mut arr = Arr::new();
    for p in points {
        arr.push(p);
    }
    o.raw("points", &arr.finish_lines()).finish()
}

/// Write a bench document to `path` (with a trailing newline).
pub fn write_bench_json(
    path: &str,
    bench: &str,
    fast: bool,
    extras: &[(&str, String)],
    points: Vec<String>,
) -> std::io::Result<()> {
    let mut doc = bench_doc(bench, fast, extras, points);
    doc.push('\n');
    std::fs::write(path, doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> RunStats {
        let mut s = RunStats::default();
        s.rounds = 4;
        s.rounds_committed = 3;
        s.duration_s = 0.008;
        s.cpu_commits = 120;
        s.cpu_attempts = 125;
        s.gpu_commits = 300;
        s.gpu_attempts = 310;
        s.chunks = 6;
        s
    }

    #[test]
    fn text_render_has_expected_lines() {
        let snap = MetricsSnapshot::from_run_stats("demo", &stats());
        let text = snap.render_text();
        assert!(text.starts_with("== demo =="));
        assert!(text.contains("  rounds            : 4 (3 committed, 0 early-aborted)"));
        assert!(text.contains("  throughput        : 52500 tx/s"));
        assert!(!text.ends_with('\n'));
    }

    #[test]
    fn json_is_well_formed_and_complete() {
        let mut snap = MetricsSnapshot::from_run_stats("demo", &stats());
        let mut reg = MetricsRegistry::new();
        reg.inc("hetm_rounds_total", 4);
        reg.observe("hetm_round_latency_seconds", 0.002);
        snap.registry = Some(reg);
        snap.meta.push(("workload".into(), "bank".into()));
        let j = snap.to_json();
        assert!(j.contains("\"label\":\"demo\""));
        assert!(j.contains("\"workload\":\"bank\""));
        assert!(j.contains("\"hetm_rounds_total\":4"));
        assert!(j.contains("\"p50_s\":"));
    }

    #[test]
    fn prometheus_renders_types_and_quantiles() {
        let mut snap = MetricsSnapshot::from_run_stats("demo", &stats());
        let mut reg = MetricsRegistry::new();
        reg.inc("hetm_rounds_total", 4);
        reg.set_gauge("hetm_virtual_time_seconds", 0.008);
        reg.observe("hetm_bus_h2d_seconds{device=\"0\"}", 1.5e-4);
        snap.registry = Some(reg);
        let p = snap.to_prometheus();
        assert!(p.contains("# TYPE hetm_rounds_total counter"));
        assert!(p.contains("hetm_rounds_total 4"));
        assert!(p.contains("# TYPE hetm_bus_h2d_seconds summary"));
        assert!(p.contains("hetm_bus_h2d_seconds{device=\"0\",quantile=\"0.5\"}"));
        assert!(p.contains("hetm_bus_h2d_seconds_count{device=\"0\"} 1"));
    }

    #[test]
    fn shard_balance_family_exports_without_a_registry() {
        let mut snap = MetricsSnapshot::from_run_stats("demo", &stats());
        let mut cl = crate::cluster::ClusterStats::new(2);
        cl.per_device[0].shipped_entries = 30;
        cl.per_device[1].shipped_entries = 10;
        cl.migrations = 2;
        cl.granules_moved = 5;
        cl.migrated_bytes = 4096;
        snap.cluster = Some(cl);
        let text = snap.render_text();
        assert!(text.contains("shard imbalance   : 1.500 max/mean shipped"));
        assert!(text.contains("2 migrations, 5 blocks, 4 KiB"));
        let j = snap.to_json();
        assert!(j.contains("\"shard_imbalance\":1.500000"));
        assert!(j.contains("\"migrations\":2"));
        assert!(j.contains("\"shipped_entries\":30"));
        let p = snap.to_prometheus();
        assert!(p.contains("# TYPE cluster_shard_imbalance gauge"));
        assert!(p.contains("cluster_shard_imbalance 1.5"));
        assert!(p.contains("cluster_migrations_total 2"));
    }

    #[test]
    fn bench_doc_layout() {
        let doc = bench_doc(
            "scale_gpus",
            true,
            &[("sim_s", "0.0625".to_string())],
            vec!["{\"n\":1}".to_string(), "{\"n\":2}".to_string()],
        );
        assert!(doc.starts_with("{\"bench\":\"scale_gpus\",\"fast\":true,\"sim_s\":0.0625,"));
        assert!(doc.contains("\"points\":[\n{\"n\":1},\n{\"n\":2}\n]"));
    }
}
