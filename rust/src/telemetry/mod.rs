//! Observability layer: metrics registry, virtual-time trace stream,
//! and the `MetricsSnapshot` exporter (DESIGN.md §11).
//!
//! ## Shape
//!
//! Both engines own a [`Telemetry`] handle (a boxed [`Recorder`]).  The
//! default is [`NullRecorder`] — one `enabled()` branch per round and
//! zero allocation, so telemetry-off runs are unobservably close to the
//! pre-telemetry engine.  When the session builder enables telemetry the
//! handle holds a [`Collector`], which maintains a [`MetricsRegistry`]
//! and (optionally) a [`trace::TraceEvent`] buffer.
//!
//! ## Determinism contract
//!
//! Everything the collector records is derived **at the round barrier on
//! the coordinator thread** from per-round deterministic data:
//!
//! * the finished [`RoundStats`],
//! * per-device partials (phase breakdowns, pre-discard commit counts,
//!   per-chunk cost samples) gathered from the cluster lanes and folded
//!   **in device-index order**, mirroring how the engines already fold
//!   `gpu_phases`,
//! * the epoch base / carry length captured at the existing reset points.
//!
//! No event is emitted inline from interleaved lane execution, so the
//! trace and registry are bit-identical across `--threads N` and across
//! `RoundEngine` vs. `ClusterEngine` at `n_gpus = 1` — the property the
//! `telemetry.rs` golden suite pins.

pub mod json;
pub mod metrics;
pub mod snapshot;
pub mod trace;

pub use metrics::{Histogram, MetricsRegistry};
pub use snapshot::{bench_doc, write_bench_json, MetricsSnapshot};
pub use trace::{validate_trace, TraceEvent};

use crate::coordinator::{PhaseBreakdown, RoundStats};

use json::Obj;
use trace::{virt_ns, TID_COORD, TID_CPU, TID_GPU_BASE};

/// Everything one finished round exposes to the recorder.  Slices are
/// per-device, in device index order (`len() == 1` on the single-device
/// engine).
#[derive(Debug)]
pub struct RoundObs<'a> {
    /// Zero-based round index.
    pub round: u64,
    /// The round's finished statistics (surviving commits only).
    pub rs: &'a RoundStats,
    /// Whether the policy held the CPU read-only this round.
    pub read_only: bool,
    /// Policy's consecutive-GPU-abort streak after this round.
    pub abort_streak: u32,
    /// Epoch base returned by the round-boundary log rebase.
    pub epoch_base: i64,
    /// Write-log entries carried into the next round (bonus window).
    pub carried: u64,
    /// Per-device phase breakdowns for this round.
    pub dev_phases: &'a [PhaseBreakdown],
    /// Per-device speculative commits BEFORE loser-discard zeroing.
    pub dev_commits: &'a [u64],
    /// Per-device per-chunk validation costs (seconds).
    pub chunk_validate_s: &'a [Vec<f64>],
    /// Per-device per-chunk H2D log-ship durations (seconds).
    pub bus_ship_s: &'a [Vec<f64>],
    /// Per-device D2H merge transfer durations (seconds).
    pub bus_merge_s: &'a [Vec<f64>],
    /// Per-device cumulative H2D bus busy time (seconds).
    pub h2d_busy_s: &'a [f64],
    /// Per-device cumulative D2H bus busy time (seconds).
    pub d2h_busy_s: &'a [f64],
}

/// Sink for engine observations.  The engines call it unconditionally;
/// implementations decide whether anything is kept.
pub trait Recorder: Send {
    /// True when the engine should spend effort gathering observations
    /// (per-chunk sample buffers, per-device partials).
    fn enabled(&self) -> bool;

    /// Record one finished round (called at the round barrier).
    fn record_round(&mut self, obs: &RoundObs<'_>);

    /// Record one externally injected transaction (`session.txn()`).
    fn record_txn(&mut self, entries: u64, attempts: u64, now: f64);

    /// Record one written checkpoint (called at the round barrier when
    /// durability is on).  Default: ignore — existing recorders keep
    /// working unchanged.
    fn record_checkpoint(&mut self, _sum: &crate::durability::CheckpointSummary) {}

    /// Downcast to the standard collector, if this recorder is one.
    fn as_collector(&self) -> Option<&Collector> {
        None
    }
}

/// The no-op recorder: telemetry off.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    fn enabled(&self) -> bool {
        false
    }
    fn record_round(&mut self, _obs: &RoundObs<'_>) {}
    fn record_txn(&mut self, _entries: u64, _attempts: u64, _now: f64) {}
}

/// The standard recorder: labeled metrics plus (optionally) the
/// virtual-time trace stream.
#[derive(Debug, Clone, Default)]
pub struct Collector {
    registry: MetricsRegistry,
    trace_on: bool,
    events: Vec<TraceEvent>,
    n_devices: usize,
}

impl Collector {
    /// A collector; `trace` additionally buffers trace events.
    pub fn new(trace: bool) -> Self {
        Collector {
            trace_on: trace,
            ..Collector::default()
        }
    }

    /// The metrics recorded so far.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Buffered trace events (empty unless tracing was requested).
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Render the buffered events as a Perfetto-loadable JSON document
    /// (None when tracing was not requested).
    pub fn trace_json(&self) -> Option<String> {
        self.trace_on
            .then(|| trace::render_trace(&self.events, self.n_devices.max(1)))
    }

    fn phase_spans(&mut self, tid: u32, t_start: f64, p: &PhaseBreakdown) {
        let mut cursor = t_start;
        for (name, dur) in [
            ("processing", p.processing_s),
            ("validation", p.validation_s),
            ("merge", p.merge_s),
            ("blocked", p.blocked_s),
        ] {
            if dur > 0.0 {
                self.events.push(TraceEvent::span(
                    name,
                    tid,
                    virt_ns(cursor),
                    virt_ns(cursor + dur) - virt_ns(cursor),
                    String::new(),
                ));
            }
            cursor += dur;
        }
    }

    fn trace_round(&mut self, obs: &RoundObs<'_>) {
        let rs = obs.rs;
        let (start, end) = (virt_ns(rs.t_start), virt_ns(rs.t_end));
        self.events.push(TraceEvent::span(
            "round",
            TID_COORD,
            start,
            end - start,
            Obj::new()
                .u64("round", obs.round)
                .bool("committed", rs.committed)
                .bool("early_aborted", rs.early_aborted)
                .u64("conflict_entries", rs.conflict_entries)
                .u64("cpu_commits", rs.cpu_commits)
                .u64("gpu_commits", rs.gpu_commits)
                .u64("discarded_commits", rs.discarded_commits)
                .finish(),
        ));
        if obs.read_only {
            self.events.push(TraceEvent::instant(
                "cpu_read_only",
                TID_CPU,
                start,
                Obj::new().u64("round", obs.round).finish(),
            ));
        }
        self.phase_spans(TID_CPU, rs.t_start, &rs.cpu_phases);
        for (d, p) in obs.dev_phases.iter().enumerate() {
            self.phase_spans(TID_GPU_BASE + d as u32, rs.t_start, p);
        }
        self.events.push(TraceEvent::instant(
            "validate",
            TID_COORD,
            end,
            Obj::new()
                .str("verdict", if rs.committed { "commit" } else { "abort" })
                .u64("conflict_entries", rs.conflict_entries)
                .finish(),
        ));
        if rs.early_aborted {
            self.events.push(TraceEvent::instant(
                "early_abort",
                TID_COORD,
                end,
                Obj::new().u64("round", obs.round).finish(),
            ));
        }
        if obs.carried > 0 {
            self.events.push(TraceEvent::instant(
                "carry_rebase",
                TID_COORD,
                end,
                Obj::new().u64("entries", obs.carried).finish(),
            ));
        }
        self.events.push(TraceEvent::instant(
            "epoch_reset",
            TID_COORD,
            end,
            Obj::new().i64("base", obs.epoch_base).finish(),
        ));
    }
}

impl Recorder for Collector {
    fn enabled(&self) -> bool {
        true
    }

    fn record_round(&mut self, obs: &RoundObs<'_>) {
        let rs = obs.rs;
        self.n_devices = self.n_devices.max(obs.dev_phases.len());
        let r = &mut self.registry;
        r.inc("hetm_rounds_total", 1);
        r.inc("hetm_rounds_committed_total", rs.committed as u64);
        r.inc("hetm_rounds_early_aborted_total", rs.early_aborted as u64);
        r.inc("hetm_rounds_cpu_read_only_total", obs.read_only as u64);
        r.inc("hetm_cpu_commits_total", rs.cpu_commits);
        r.inc("hetm_cpu_attempts_total", rs.cpu_attempts);
        r.inc("hetm_gpu_commits_total", rs.gpu_commits);
        r.inc("hetm_gpu_attempts_total", rs.gpu_attempts);
        r.inc("hetm_discarded_commits_total", rs.discarded_commits);
        r.inc("hetm_log_chunks_total", rs.chunks);
        r.inc("hetm_log_entries_raw_total", rs.log_entries_raw);
        r.inc("hetm_log_entries_shipped_total", rs.log_entries_shipped);
        r.inc("hetm_chunks_filtered_total", rs.chunks_filtered);
        r.inc("hetm_chunks_skipped_total", rs.chunks_skipped_post_abort);
        r.inc("hetm_conflict_entries_total", rs.conflict_entries);
        r.inc("hetm_carried_entries_total", obs.carried);
        r.set_gauge("hetm_virtual_time_seconds", rs.t_end);
        r.set_gauge("hetm_policy_abort_streak", obs.abort_streak as f64);
        r.observe("hetm_round_latency_seconds", rs.t_end - rs.t_start);
        for (phase, dur) in [
            ("processing", rs.cpu_phases.processing_s),
            ("validation", rs.cpu_phases.validation_s),
            ("merge", rs.cpu_phases.merge_s),
            ("blocked", rs.cpu_phases.blocked_s),
        ] {
            r.observe(&format!("hetm_cpu_phase_seconds{{phase=\"{phase}\"}}"), dur);
        }
        for (d, commits) in obs.dev_commits.iter().enumerate() {
            r.inc(&format!("hetm_device_commits_total{{device=\"{d}\"}}"), *commits);
        }
        for (d, samples) in obs.chunk_validate_s.iter().enumerate() {
            let name = format!("hetm_chunk_validation_seconds{{device=\"{d}\"}}");
            for &v in samples {
                r.observe(&name, v);
            }
        }
        for (d, samples) in obs.bus_ship_s.iter().enumerate() {
            let name = format!("hetm_bus_h2d_seconds{{device=\"{d}\"}}");
            for &v in samples {
                r.observe(&name, v);
            }
        }
        for (d, samples) in obs.bus_merge_s.iter().enumerate() {
            let name = format!("hetm_bus_d2h_seconds{{device=\"{d}\"}}");
            for &v in samples {
                r.observe(&name, v);
            }
        }
        for (d, &busy) in obs.h2d_busy_s.iter().enumerate() {
            r.set_gauge(&format!("hetm_bus_h2d_busy_seconds{{device=\"{d}\"}}"), busy);
        }
        for (d, &busy) in obs.d2h_busy_s.iter().enumerate() {
            r.set_gauge(&format!("hetm_bus_d2h_busy_seconds{{device=\"{d}\"}}"), busy);
        }
        if self.trace_on {
            self.trace_round(obs);
        }
    }

    fn record_txn(&mut self, entries: u64, attempts: u64, now: f64) {
        self.registry.inc("hetm_txn_external_total", 1);
        self.registry.inc("hetm_txn_external_attempts_total", attempts);
        self.registry.inc("hetm_txn_external_entries_total", entries);
        if self.trace_on {
            self.events.push(TraceEvent::instant(
                "txn",
                TID_CPU,
                virt_ns(now),
                Obj::new().u64("entries", entries).u64("attempts", attempts).finish(),
            ));
        }
    }

    fn record_checkpoint(&mut self, sum: &crate::durability::CheckpointSummary) {
        let r = &mut self.registry;
        r.inc("hetm_checkpoints_total", 1);
        r.inc("hetm_checkpoint_bytes_total", sum.bytes);
        r.inc("hetm_checkpoint_extents_total", sum.extents);
        r.inc("hetm_checkpoint_wal_entries_total", sum.wal_entries);
        // Wall-clock write cost, for operators sizing
        // `durability.interval_rounds`.  Real time, not virtual — it
        // never enters trace events, and the `_wall_` name marks it for
        // exclusion from deterministic snapshot comparison and perf
        // gating (MetricsRegistry::deterministic, DESIGN.md §15).
        r.observe(
            "hetm_checkpoint_write_wall_seconds",
            sum.write_micros as f64 * 1e-6,
        );
    }

    fn as_collector(&self) -> Option<&Collector> {
        Some(self)
    }
}

/// The engine-side telemetry handle: a boxed [`Recorder`], no-op by
/// default.
pub struct Telemetry {
    rec: Box<dyn Recorder>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry")
            .field("enabled", &self.enabled())
            .finish()
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::off()
    }
}

impl Telemetry {
    /// Disabled telemetry (the engines' default).
    pub fn off() -> Self {
        Telemetry {
            rec: Box::new(NullRecorder),
        }
    }

    /// Telemetry backed by the standard [`Collector`]; `trace` also
    /// buffers the virtual-time trace stream.
    pub fn collecting(trace: bool) -> Self {
        Telemetry {
            rec: Box::new(Collector::new(trace)),
        }
    }

    /// Telemetry backed by a custom recorder.
    pub fn with_recorder(rec: Box<dyn Recorder>) -> Self {
        Telemetry { rec }
    }

    /// True when the engine should gather observations this round.
    pub fn enabled(&self) -> bool {
        self.rec.enabled()
    }

    /// Forward one finished round.
    pub fn record_round(&mut self, obs: &RoundObs<'_>) {
        self.rec.record_round(obs);
    }

    /// Forward one injected transaction.
    pub fn record_txn(&mut self, entries: u64, attempts: u64, now: f64) {
        self.rec.record_txn(entries, attempts, now);
    }

    /// Forward one written checkpoint.
    pub fn record_checkpoint(&mut self, sum: &crate::durability::CheckpointSummary) {
        self.rec.record_checkpoint(sum);
    }

    /// Access the standard collector, when active.
    pub fn collector(&self) -> Option<&Collector> {
        self.rec.as_collector()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs_fixture(rs: &RoundStats) -> (Vec<PhaseBreakdown>, Vec<u64>) {
        (vec![rs.gpu_phases], vec![rs.gpu_commits])
    }

    fn round_stats() -> RoundStats {
        let mut rs = RoundStats::default();
        rs.t_start = 0.0;
        rs.t_end = 0.002;
        rs.cpu_commits = 10;
        rs.cpu_attempts = 11;
        rs.gpu_commits = 40;
        rs.gpu_attempts = 41;
        rs.chunks = 2;
        rs.log_entries_raw = 20;
        rs.log_entries_shipped = 20;
        rs.committed = true;
        rs.cpu_phases.processing_s = 0.0015;
        rs.cpu_phases.blocked_s = 0.0005;
        rs.gpu_phases.processing_s = 0.002;
        rs
    }

    #[test]
    fn null_recorder_is_off() {
        let mut t = Telemetry::off();
        assert!(!t.enabled());
        let rs = round_stats();
        let (phases, commits) = obs_fixture(&rs);
        t.record_round(&RoundObs {
            round: 0,
            rs: &rs,
            read_only: false,
            abort_streak: 0,
            epoch_base: 0,
            carried: 0,
            dev_phases: &phases,
            dev_commits: &commits,
            chunk_validate_s: &[],
            bus_ship_s: &[],
            bus_merge_s: &[],
            h2d_busy_s: &[],
            d2h_busy_s: &[],
        });
        assert!(t.collector().is_none());
    }

    #[test]
    fn collector_records_counters_and_trace() {
        let mut t = Telemetry::collecting(true);
        assert!(t.enabled());
        let rs = round_stats();
        let (phases, commits) = obs_fixture(&rs);
        let vcost = vec![vec![1e-5, 2e-5]];
        let ship = vec![vec![3e-5]];
        t.record_round(&RoundObs {
            round: 0,
            rs: &rs,
            read_only: true,
            abort_streak: 0,
            epoch_base: 7,
            carried: 3,
            dev_phases: &phases,
            dev_commits: &commits,
            chunk_validate_s: &vcost,
            bus_ship_s: &ship,
            bus_merge_s: &[],
            h2d_busy_s: &[3e-5],
            d2h_busy_s: &[0.0],
        });
        t.record_txn(2, 1, 0.002);
        let c = t.collector().unwrap();
        let r = c.registry();
        assert_eq!(r.counter("hetm_rounds_total"), 1);
        assert_eq!(r.counter("hetm_cpu_commits_total"), 10);
        assert_eq!(r.counter("hetm_rounds_cpu_read_only_total"), 1);
        assert_eq!(r.counter("hetm_txn_external_entries_total"), 2);
        assert_eq!(
            r.histogram("hetm_chunk_validation_seconds{device=\"0\"}").unwrap().count(),
            2
        );
        let doc = c.trace_json().unwrap();
        assert!(validate_trace(&doc).unwrap() >= 6);
        assert!(doc.contains("\"name\":\"carry_rebase\""));
        assert!(doc.contains("\"name\":\"epoch_reset\""));
        assert!(doc.contains("\"name\":\"cpu_read_only\""));
        assert!(doc.contains("\"name\":\"txn\""));
    }
}
