//! Scatter the CPU write-set log across shard owners.
//!
//! The CPU side of the cluster is unchanged from the single-device system:
//! one guest TM, one global commit clock, one stream of `(addr, val, ts)`
//! write entries.  The router splits that stream by
//! [`ShardLayout::owner`](super::shard::ShardLayout::owner) into
//! per-device [`RoundLog`]s, each of which chunks independently into
//! the paper's 48 KB transfer units and ships over that device's own
//! host-to-device bus channel.  Order is preserved within each device's
//! log, so the per-shard validation sees CPU commits in timestamp order
//! exactly as the single-device validation does.
//!
//! The router holds a shared handle to the cluster's versioned
//! [`ShardLayout`](super::shard::ShardLayout): when the round-barrier
//! rebalancer installs a new layout epoch, the next batch scatters by the
//! new table with no router surgery.  Each scatter loop takes one layout
//! view per batch, so a batch is routed under exactly one epoch.  When
//! the rebalancer is enabled the router also keeps a per-ownership-block
//! **heat** counter (entries routed per block since the last decision
//! window) — the signal the coordinator uses to pick which blocks to
//! migrate.
//!
//! With one shard the router is a plain [`RoundLog`] wrapper: every entry
//! routes to device 0 in arrival order, producing bit-identical chunks.

use super::shard::ShardMap;
use crate::coordinator::logs::RoundLog;
use crate::gpu::LogChunk;
use crate::stm::WriteEntry;

/// Routes committed CPU write entries to their owner shard's round log.
#[derive(Debug)]
pub struct LogRouter {
    map: ShardMap,
    logs: Vec<RoundLog>,
    /// Entries routed since construction (diagnostics).
    routed: u64,
    /// Scratch: per-shard slices of a carry batch (avoids reallocating).
    carry_buf: Vec<Vec<WriteEntry>>,
    /// Per-ownership-block routed-entry counters for the rebalancer
    /// (`None` keeps the default path allocation-free and branch-cheap).
    heat: Option<Vec<u64>>,
}

impl LogRouter {
    /// Build a router with one `chunk_entries`-sized log per shard.
    pub fn new(map: ShardMap, chunk_entries: usize) -> Self {
        let n = map.n_shards();
        LogRouter {
            map,
            logs: (0..n)
                .map(|_| RoundLog::with_chunk_entries(chunk_entries))
                .collect(),
            routed: 0,
            carry_buf: (0..n).map(|_| Vec::new()).collect(),
            heat: None,
        }
    }

    /// Enable per-block heat tracking (the rebalancer's migration-target
    /// signal).  Counters start at zero; [`LogRouter::take_heat`] reads
    /// and resets them per decision window.
    pub fn enable_heat(&mut self) {
        if self.heat.is_none() {
            self.heat = Some(vec![0; self.map.n_blocks()]);
        }
    }

    /// Per-block routed-entry counts since the last call, resetting the
    /// window (empty slice when heat tracking is off).
    pub fn take_heat(&mut self) -> Vec<u64> {
        match &mut self.heat {
            Some(h) => {
                let out = h.clone();
                h.iter_mut().for_each(|c| *c = 0);
                out
            }
            None => Vec::new(),
        }
    }

    /// The ownership map.
    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// Enable/disable per-shard last-write-wins compaction
    /// (`hetm.log_compaction`) on every shard log.  Per-shard compaction
    /// composes with the scatter: each device's window dedups over
    /// exactly the entries routed to it, so the shipped address SET per
    /// shard — and with it every conflict decision — is unchanged.
    pub fn set_compaction(&mut self, on: bool) {
        for log in &mut self.logs {
            log.set_compaction(on);
        }
    }

    /// Enable chunk conflict-prefilter signatures at granule shift
    /// `shift` on every shard log (`None` disables).
    pub fn set_sig_shift(&mut self, shift: Option<u32>) {
        for log in &mut self.logs {
            log.set_sig_shift(shift);
        }
    }

    /// Raw (pre-compaction) entries appended since the last reset, across
    /// all shards.
    pub fn raw_appended_total(&self) -> u64 {
        self.logs.iter().map(|l| l.raw_appended()).sum()
    }

    /// Live entries drained into chunks since the last reset, across all
    /// shards.
    pub fn shipped_total(&self) -> u64 {
        self.logs.iter().map(|l| l.shipped()).sum()
    }

    /// Number of shards routed to.
    pub fn n_shards(&self) -> usize {
        self.logs.len()
    }

    /// Total entries routed since construction.
    pub fn routed(&self) -> u64 {
        self.routed
    }

    /// One shard's round log (tests / diagnostics).
    pub fn log(&self, shard: usize) -> &RoundLog {
        &self.logs[shard]
    }

    /// Route a batch of committed entries to their owners, in order.  The
    /// batch scatters under one layout view (the epoch current when the
    /// call starts), and feeds the per-block heat window when tracking is
    /// enabled.
    pub fn append(&mut self, entries: &[WriteEntry]) {
        let view = self.map.view();
        let shift = self.map.shard_bits();
        for e in entries {
            let w = e.addr as usize;
            if let Some(h) = &mut self.heat {
                h[w >> shift] += 1;
            }
            self.logs[view.owner(w)].push(*e);
        }
        self.routed += entries.len() as u64;
    }

    /// Drain complete chunks from one shard's log (streaming, §IV-D).
    pub fn drain_full_chunks(&mut self, shard: usize, out: &mut Vec<LogChunk>) {
        self.logs[shard].drain_full_chunks(out);
    }

    /// Drain everything from one shard's log, padding the tail chunk.
    pub fn drain_all(&mut self, shard: usize, out: &mut Vec<LogChunk>) {
        self.logs[shard].drain_all(out);
    }

    /// Return retired chunk buffers to one shard log's arena pool
    /// ([`RoundLog::recycle`]): next round's drains on that shard reuse
    /// the allocations instead of growing fresh ones.
    pub fn recycle(&mut self, shard: usize, chunks: &mut Vec<LogChunk>) {
        self.logs[shard].recycle(chunks);
    }

    /// Entries logged this round across all shards.
    pub fn len_total(&self) -> usize {
        self.logs.iter().map(|l| l.len()).sum()
    }

    /// Entries not yet drained into chunks, across all shards.
    pub fn pending_total(&self) -> usize {
        self.logs.iter().map(|l| l.pending()).sum()
    }

    /// Reset every shard log for the next round, seeding each with its
    /// share of the carry (commits made during the previous round's
    /// validation window).
    pub fn reset_with_carry(&mut self, carry: &[WriteEntry]) {
        for buf in &mut self.carry_buf {
            buf.clear();
        }
        let view = self.map.view();
        for e in carry {
            self.carry_buf[view.owner(e.addr as usize)].push(*e);
        }
        for (log, buf) in self.logs.iter_mut().zip(&self.carry_buf) {
            log.reset_with_carry(buf);
        }
    }

    /// Favor-GPU round abort: drop this round's entries everywhere, keep
    /// each shard's carried prefix for re-shipping.
    pub fn truncate_to_carried(&mut self) {
        for log in &mut self.logs {
            log.truncate_to_carried();
        }
    }

    /// Round-boundary epoch rebase: renumber every shard's carried prefix
    /// into `1..=k_shard` ([`RoundLog::rebase_epoch`]) and return the
    /// maximum base — the value the shared commit clock restarts at, so
    /// every next-epoch timestamp exceeds every renumbered carried entry.
    /// Shards are address-disjoint, so per-shard renumbering preserves
    /// every per-address freshness outcome.
    pub fn rebase_epoch(&mut self) -> i64 {
        let mut base = 0i64;
        for log in &mut self.logs {
            base = base.max(log.rebase_epoch());
        }
        base
    }

    /// Scatter externally-committed entries into each owner shard's
    /// carried prefix (the `Session::txn` path; see
    /// [`RoundLog::extend_carried`]).
    pub fn extend_carried(&mut self, entries: &[WriteEntry]) {
        let view = self.map.view();
        for e in entries {
            self.logs[view.owner(e.addr as usize)].extend_carried(std::slice::from_ref(e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(addr: u32, val: i32, ts: i32) -> WriteEntry {
        WriteEntry { addr, val, ts }
    }

    #[test]
    fn routes_every_entry_to_its_owner_in_order() {
        let map = ShardMap::new(64, 2, 2); // 4-word blocks
        let mut r = LogRouter::new(map.clone(), 4);
        let entries: Vec<WriteEntry> =
            (0..32).map(|i| entry((i * 2) % 64, i as i32, i as i32 + 1)).collect();
        r.append(&entries);
        assert_eq!(r.routed(), 32);
        assert_eq!(r.len_total(), 32);
        for shard in 0..2 {
            let mut chunks = Vec::new();
            r.drain_all(shard, &mut chunks);
            let mut last_ts = 0;
            for c in &chunks {
                for (i, &a) in c.addrs.iter().enumerate() {
                    if a < 0 {
                        continue;
                    }
                    assert_eq!(map.owner(a as usize), shard, "entry on wrong shard");
                    assert!(c.ts[i] > last_ts, "order preserved per shard");
                    last_ts = c.ts[i];
                }
            }
        }
    }

    #[test]
    fn solo_router_matches_single_round_log() {
        let entries: Vec<WriteEntry> = (0..10).map(|i| entry(i, i as i32, 1)).collect();
        let mut solo = RoundLog::with_chunk_entries(4);
        solo.append(&entries);
        let mut want = Vec::new();
        solo.drain_all(&mut want);

        let mut r = LogRouter::new(ShardMap::solo(64), 4);
        r.append(&entries);
        let mut got = Vec::new();
        r.drain_all(0, &mut got);

        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.addrs, g.addrs);
            assert_eq!(w.vals, g.vals);
            assert_eq!(w.ts, g.ts);
        }
    }

    #[test]
    fn per_shard_compaction_dedups_within_each_shard_only() {
        let map = ShardMap::new(64, 2, 2); // 4-word blocks
        let mut r = LogRouter::new(map.clone(), 8);
        r.set_compaction(true);
        r.set_sig_shift(Some(0));
        // Addr 1 (shard 0) written three times, addr 4 (shard 1) twice.
        r.append(&[
            entry(1, 10, 1),
            entry(4, 40, 2),
            entry(1, 11, 3),
            entry(4, 41, 4),
            entry(1, 12, 5),
        ]);
        assert_eq!(r.raw_appended_total(), 5);
        let mut c0 = Vec::new();
        let mut c1 = Vec::new();
        r.drain_all(0, &mut c0);
        r.drain_all(1, &mut c1);
        assert_eq!(c0[0].live(), 1, "shard 0 compacts to one entry");
        assert_eq!(c0[0].vals[0], 12);
        assert_eq!(c1[0].live(), 1, "shard 1 compacts to one entry");
        assert_eq!(c1[0].vals[0], 41);
        assert!(c0[0].sig.is_some(), "signatures attach per shard");
        assert_eq!(r.shipped_total(), 2);
    }

    #[test]
    fn carry_routes_and_survives_truncate() {
        let map = ShardMap::new(64, 2, 2);
        let mut r = LogRouter::new(map.clone(), 4);
        // Carry one entry per shard.
        let carry = vec![entry(0, 10, 5), entry(4, 11, 6)];
        r.reset_with_carry(&carry);
        assert_eq!(r.len_total(), 2);
        // New-round entries then a favor-GPU abort:
        r.append(&[entry(1, 99, 7), entry(5, 98, 8)]);
        assert_eq!(r.len_total(), 4);
        r.truncate_to_carried();
        assert_eq!(r.len_total(), 2, "carried prefix survives");
        let mut c0 = Vec::new();
        r.drain_all(0, &mut c0);
        assert_eq!(c0[0].addrs[0], 0);
        assert_eq!(c0[0].vals[0], 10);
    }

    #[test]
    fn heat_window_counts_per_block_and_resets() {
        let map = ShardMap::new(64, 2, 2); // 16 blocks of 4 words
        let mut r = LogRouter::new(map, 4);
        assert!(r.take_heat().is_empty(), "off by default");
        r.enable_heat();
        r.append(&[entry(0, 1, 1), entry(1, 2, 2), entry(4, 3, 3)]);
        let h = r.take_heat();
        assert_eq!(h.len(), 16);
        assert_eq!(h[0], 2, "two entries in block 0");
        assert_eq!(h[1], 1, "one entry in block 1");
        assert_eq!(r.take_heat(), vec![0u64; 16], "window resets");
    }

    #[test]
    fn scatter_follows_a_migrated_layout() {
        let map = ShardMap::new(64, 2, 2);
        let mut r = LogRouter::new(map.clone(), 8);
        assert_eq!(map.owner(0), 0);
        map.migrate(&[0], 1); // block 0 (words 0..4) now on device 1
        r.append(&[entry(0, 7, 1), entry(4, 8, 2)]);
        let mut c1 = Vec::new();
        r.drain_all(1, &mut c1);
        let on_dev1: Vec<i32> = c1
            .iter()
            .flat_map(|c| c.addrs.iter().copied().filter(|&a| a >= 0))
            .collect();
        assert_eq!(on_dev1, vec![0, 4], "both blocks route to device 1 now");
        assert_eq!(r.log(0).len(), 0);
    }
}
