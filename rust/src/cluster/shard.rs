//! Versioned word-range → device ownership layout for the sharded STMR.
//!
//! The region is cut into fixed blocks of `1 << shard_bits` words.  Where
//! the old `ShardMap` *computed* ownership (`owner(word) = (word >>
//! shard_bits) % n_shards`), [`ShardLayout`] *stores* it: an explicit
//! block → device table plus a monotonically increasing **layout epoch**.
//! The default constructors fill the table with exactly the old stripe —
//! bit-identical behavior for every consumer — but the table can also be
//! built load-proportionally from per-device speed weights
//! ([`ShardLayout::proportional`]) and rewritten online by the cluster
//! engine's round-barrier rebalancer ([`ShardLayout::migrate`]).
//!
//! Handles are cheap to clone and **share** the table: the log router,
//! the engine and the shard-homed workload generators all observe a
//! migration the moment the coordinator installs the next epoch.  Installs
//! happen only at quiesced round barriers (never while lanes run), so
//! every reader of one round sees one consistent epoch and results stay
//! bit-identical at any `cluster.threads` setting.
//!
//! The block size aligns with the paper's 16 KB transfer granule when
//! `shard_bits = 12` (4096 words = 16 KB), so ownership boundaries and
//! merge-DMA boundaries coincide.  With `n_shards = 1` every helper
//! degenerates to the identity — the single-device configuration is
//! bit-for-bit the existing coordinator.

use crate::util::sync::{read_lock, write_lock};
use std::sync::{Arc, RwLock};

/// The historical name of the ownership map; today an alias for the
/// versioned [`ShardLayout`] (same constructors, same striped defaults).
pub type ShardMap = ShardLayout;

/// One immutable version of the ownership table.  Readers hold an `Arc`
/// snapshot; [`ShardLayout::migrate`] installs a successor instead of
/// mutating in place.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Table {
    /// Layout version: 0 for the initial layout, +1 per migration.
    epoch: u64,
    /// Owner device of each ownership block, indexed by block id.
    owners: Vec<u32>,
    /// Blocks owned by each device, ascending (rehome / range index).
    by_shard: Vec<Vec<u32>>,
}

impl Table {
    fn from_owners(epoch: u64, owners: Vec<u32>, n_shards: usize) -> Self {
        let mut by_shard = vec![Vec::new(); n_shards];
        for (b, &d) in owners.iter().enumerate() {
            // audit:allow(D5, reason = "block index < n_blocks <= n_words <= i32::MAX (builder-enforced), so it fits u32")
            by_shard[d as usize].push(b as u32);
        }
        Table {
            epoch,
            owners,
            by_shard,
        }
    }
}

/// Versioned ownership layout: word index → shard (device) id, consulted
/// through a shared, atomically replaceable table.
pub struct ShardLayout {
    n_words: usize,
    n_shards: usize,
    shard_bits: u32,
    table: Arc<RwLock<Arc<Table>>>,
}

/// Handles share the table: a clone observes every later migration.
impl Clone for ShardLayout {
    fn clone(&self) -> Self {
        ShardLayout {
            n_words: self.n_words,
            n_shards: self.n_shards,
            shard_bits: self.shard_bits,
            table: Arc::clone(&self.table),
        }
    }
}

impl std::fmt::Debug for ShardLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t = self.snapshot();
        f.debug_struct("ShardLayout")
            .field("n_words", &self.n_words)
            .field("n_shards", &self.n_shards)
            .field("shard_bits", &self.shard_bits)
            .field("epoch", &t.epoch)
            .finish()
    }
}

impl PartialEq for ShardLayout {
    fn eq(&self, other: &Self) -> bool {
        self.n_words == other.n_words
            && self.n_shards == other.n_shards
            && self.shard_bits == other.shard_bits
            && *self.snapshot() == *other.snapshot()
    }
}
impl Eq for ShardLayout {}

impl ShardLayout {
    /// Build the classic striped layout over `n_words` with `n_shards`
    /// devices and `1 << shard_bits`-word blocks: block `b` is owned by
    /// `b % n_shards`, exactly the arithmetic the pre-versioned map used.
    ///
    /// Panics unless every shard owns at least one full block
    /// (`n_words >= n_shards << shard_bits`) — a thinner region cannot be
    /// meaningfully sharded at this granularity.
    pub fn new(n_words: usize, n_shards: usize, shard_bits: u32) -> Self {
        Self::check_dims(n_words, n_shards, shard_bits);
        // audit:allow(D5, reason = "shift guarded: check_dims asserts shard_bits < usize::BITS")
        let n_blocks = n_words.div_ceil(1usize << shard_bits);
        // audit:allow(D5, reason = "stripe id = b % n_shards < n_shards <= n_words <= i32::MAX, so it fits u32")
        let owners = (0..n_blocks).map(|b| (b % n_shards) as u32).collect();
        Self::from_table(n_words, n_shards, shard_bits, 0, owners)
    }

    /// The single-device identity layout.
    pub fn solo(n_words: usize) -> Self {
        Self::new(n_words, 1, 0)
    }

    /// Build a load-proportional layout: blocks are dealt by weighted
    /// round robin over `weights` (one positive relative speed per
    /// device), so a device rated `2.0` receives twice the blocks of a
    /// device rated `1.0`.  **Equal weights reproduce the stripe of
    /// [`ShardLayout::new`] exactly** (weighted round robin with uniform
    /// weights degenerates to round robin), so the cost-model layout is a
    /// strict generalization of the default.  Every shard is guaranteed
    /// at least one block (deterministically taken from the largest
    /// holding when extreme weights would starve one).
    pub fn proportional(n_words: usize, n_shards: usize, shard_bits: u32, weights: &[f64]) -> Self {
        Self::check_dims(n_words, n_shards, shard_bits);
        assert_eq!(weights.len(), n_shards, "one weight per shard");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "device speed weights must be finite and positive"
        );
        // audit:allow(D5, reason = "shift guarded: check_dims asserts shard_bits < usize::BITS")
        let n_blocks = n_words.div_ceil(1usize << shard_bits);
        let total: f64 = weights.iter().sum();
        let mut credit = vec![0.0f64; n_shards];
        let mut owners = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            for (c, w) in credit.iter_mut().zip(weights) {
                *c += w;
            }
            // Argmax with ties to the lowest index: deterministic.
            let mut win = 0usize;
            for d in 1..n_shards {
                if credit[d] > credit[win] {
                    win = d;
                }
            }
            credit[win] -= total;
            // audit:allow(D5, reason = "winner index < n_shards <= n_words <= i32::MAX, so it fits u32")
            owners.push(win as u32);
        }
        // Extreme weights can starve a shard of blocks entirely; give
        // every starved shard (ascending) the last block of whichever
        // shard holds the most (ties to the lowest index).
        let mut held = vec![0usize; n_shards];
        for &d in &owners {
            held[d as usize] += 1;
        }
        for d in 0..n_shards {
            if held[d] > 0 {
                continue;
            }
            let mut donor = 0usize;
            for s in 1..n_shards {
                if held[s] > held[donor] {
                    donor = s;
                }
            }
            let b = owners
                .iter()
                .rposition(|&o| o as usize == donor)
                // audit:allow(D6, reason = "donor is the argmax of held[], so it owns at least one block by construction")
                .expect("donor holds a block");
            // audit:allow(D5, reason = "starved-shard id < n_shards <= n_words <= i32::MAX, so it fits u32")
            owners[b] = d as u32;
            held[donor] -= 1;
            held[d] += 1;
        }
        Self::from_table(n_words, n_shards, shard_bits, 0, owners)
    }

    fn check_dims(n_words: usize, n_shards: usize, shard_bits: u32) {
        assert!(n_shards >= 1, "need at least one shard");
        assert!(shard_bits < usize::BITS, "shard_bits out of range");
        // audit:allow(D5, reason = "shift guarded by the shard_bits < usize::BITS assert directly above")
        let block = 1usize << shard_bits;
        // `n_shards << shard_bits` here used to wrap silently in release
        // builds for pathological (n_shards, shard_bits) pairs, letting
        // an undersized STMR slip past this check; route the product
        // through checked_mul so overflow reads as "too many words
        // required" and the assert fires.
        let need = n_shards.checked_mul(block).unwrap_or(usize::MAX);
        assert!(
            n_words >= need,
            "STMR of {n_words} words cannot give {n_shards} shards a \
             {block}-word block each (lower cluster.shard_bits)"
        );
    }

    fn from_table(
        n_words: usize,
        n_shards: usize,
        shard_bits: u32,
        epoch: u64,
        owners: Vec<u32>,
    ) -> Self {
        let table = Table::from_owners(epoch, owners, n_shards);
        ShardLayout {
            n_words,
            n_shards,
            shard_bits,
            table: Arc::new(RwLock::new(Arc::new(table))),
        }
    }

    fn snapshot(&self) -> Arc<Table> {
        Arc::clone(&read_lock(&self.table))
    }

    /// STMR size in words.
    pub fn n_words(&self) -> usize {
        self.n_words
    }

    /// Number of shards (devices).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Block-size shift (block = `1 << shard_bits` words).
    pub fn shard_bits(&self) -> u32 {
        self.shard_bits
    }

    /// Words per ownership block.
    pub fn block_words(&self) -> usize {
        // audit:allow(D5, reason = "shift guarded: check_dims asserted shard_bits < usize::BITS at construction")
        1usize << self.shard_bits
    }

    /// Number of ownership blocks (last one may be partial).
    pub fn n_blocks(&self) -> usize {
        self.n_words.div_ceil(self.block_words())
    }

    /// Current layout epoch (0 = initial; bumped by every migration).
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// The device owning `word`.
    #[inline]
    pub fn owner(&self, word: usize) -> usize {
        debug_assert!(word < self.n_words);
        if self.n_shards == 1 {
            return 0;
        }
        read_lock(&self.table).owners[word >> self.shard_bits] as usize
    }

    /// A borrowed snapshot of the current table for batch lookups: one
    /// lock acquisition amortized over a whole scatter loop, reading the
    /// epoch that was current when the view was taken.
    pub fn view(&self) -> LayoutView {
        LayoutView {
            table: self.snapshot(),
            n_words: self.n_words,
            shard_bits: self.shard_bits,
        }
    }

    /// Remap `word` to a word (same in-block offset) owned by `shard` —
    /// the shard-aware workload generators draw uniformly over the whole
    /// region and rehome each access, which keeps their RNG streams
    /// identical across cluster sizes.  On a striped table this selects
    /// the same block as the old stripe arithmetic did (the `word`'s own
    /// stripe cycle, stepped back at the tail), so homed generators are
    /// bit-identical; on a migrated table it deterministically indexes
    /// the target shard's block list.  Identity when the layout is
    /// [`ShardLayout::solo`]-shaped.
    pub fn rehome(&self, word: usize, shard: usize) -> usize {
        debug_assert!(word < self.n_words);
        debug_assert!(shard < self.n_shards);
        if self.n_shards == 1 {
            return word;
        }
        let t = read_lock(&self.table);
        let blocks = &t.by_shard[shard];
        debug_assert!(!blocks.is_empty(), "every shard owns at least one block");
        // On a striped table `blocks == [shard, shard + n, shard + 2n, …]`
        // and this index reproduces the old `block - block % n + shard`
        // (clamping covers the tail step-back, which the old loop took at
        // most once).
        let idx = ((word >> self.shard_bits) / self.n_shards).min(blocks.len() - 1);
        // audit:allow(D5, reason = "shift guarded: block id < n_blocks and shard_bits < usize::BITS (check_dims), so start < n_words")
        let start = (blocks[idx] as usize) << self.shard_bits;
        let len = (self.n_words - start).min(self.block_words());
        start + (word & (self.block_words() - 1)) % len
    }

    /// Words owned by `shard`.
    pub fn owned_words(&self, shard: usize) -> usize {
        self.owned_ranges(shard).iter().map(|(s, e)| e - s).sum()
    }

    /// Maximal word ranges `[start, end)` owned by `shard`, ascending.
    pub fn owned_ranges(&self, shard: usize) -> Vec<(usize, usize)> {
        assert!(shard < self.n_shards);
        let t = self.snapshot();
        let mut out: Vec<(usize, usize)> = Vec::new();
        for &b in &t.by_shard[shard] {
            // audit:allow(D5, reason = "shift guarded: block id < n_blocks and shard_bits < usize::BITS (check_dims), so s < n_words")
            let s = (b as usize) << self.shard_bits;
            // audit:allow(D5, reason = "shift guarded: (b + 1) <= n_blocks, shard_bits < usize::BITS (check_dims); min clamps the tail")
            let e = ((b as usize + 1) << self.shard_bits).min(self.n_words);
            match out.last_mut() {
                Some(last) if last.1 == s => last.1 = e,
                _ => out.push((s, e)),
            }
        }
        out
    }

    /// Install the next layout epoch with `blocks` reassigned to device
    /// `to`, and return the epoch now current.  Moves that would leave a
    /// shard with no blocks are skipped (every shard must keep at least
    /// one block for [`ShardLayout::rehome`]); if nothing changes the
    /// epoch is not bumped.  Every clone of this handle observes the new
    /// table immediately — callers (the engine's round-barrier
    /// rebalancer) must only invoke this while the lanes are quiesced.
    pub fn migrate(&self, blocks: &[usize], to: usize) -> u64 {
        assert!(to < self.n_shards, "target shard out of range");
        let mut guard = write_lock(&self.table);
        let cur = &**guard;
        let mut owners = cur.owners.clone();
        let mut held = vec![0usize; self.n_shards];
        for &d in &owners {
            held[d as usize] += 1;
        }
        let mut changed = false;
        for &b in blocks {
            assert!(b < owners.len(), "block {b} out of range");
            let from = owners[b] as usize;
            if from == to || held[from] <= 1 {
                continue;
            }
            // audit:allow(D5, reason = "target shard id < n_shards <= n_words <= i32::MAX, so it fits u32")
            owners[b] = to as u32;
            held[from] -= 1;
            held[to] += 1;
            changed = true;
        }
        if !changed {
            return cur.epoch;
        }
        let next = Table::from_owners(cur.epoch + 1, owners, self.n_shards);
        *guard = Arc::new(next);
        guard.epoch
    }

    /// Serializable description of the current table (checkpoint
    /// manifests record this; recovery verifies the replayed layout
    /// against it bit-exactly).
    pub fn desc(&self) -> LayoutDesc {
        let t = self.snapshot();
        LayoutDesc {
            epoch: t.epoch,
            shard_bits: self.shard_bits,
            owners: t.owners.clone(),
        }
    }
}

/// An immutable point-in-time view of a [`ShardLayout`] table, for batch
/// scatter loops (one lock acquisition per batch instead of per word).
pub struct LayoutView {
    table: Arc<Table>,
    n_words: usize,
    shard_bits: u32,
}

impl LayoutView {
    /// The device owning `word` in this view.
    #[inline]
    pub fn owner(&self, word: usize) -> usize {
        debug_assert!(word < self.n_words);
        self.table.owners[word >> self.shard_bits] as usize
    }

    /// The layout epoch this view captured.
    pub fn epoch(&self) -> u64 {
        self.table.epoch
    }
}

/// A layout snapshot in serializable form: the epoch, the block shift and
/// the per-block owner table.  [`LayoutDesc::to_rle`]/[`LayoutDesc::parse_rle`]
/// round-trip the owner table through the compact `owner*count,...`
/// run-length text the checkpoint manifest stores.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayoutDesc {
    /// Layout epoch at capture time.
    pub epoch: u64,
    /// Block-size shift (block = `1 << shard_bits` words).
    pub shard_bits: u32,
    /// Owner device of each ownership block.
    pub owners: Vec<u32>,
}

impl LayoutDesc {
    /// The single-device description (`RoundEngine` has no shard map; its
    /// layout is one epoch-0 block table owned entirely by device 0).
    pub fn solo(n_words: usize) -> Self {
        LayoutDesc {
            epoch: 0,
            shard_bits: 0,
            owners: vec![0; n_words],
        }
    }

    /// Number of shards this description spans (max owner + 1).
    pub fn n_shards(&self) -> usize {
        self.owners.iter().map(|&d| d as usize + 1).max().unwrap_or(1)
    }

    /// Run-length encode the owner table as `owner*count` runs joined by
    /// commas (e.g. a 4-device stripe of 8 blocks is
    /// `0*1,1*1,2*1,3*1,0*1,1*1,2*1,3*1`).
    pub fn to_rle(&self) -> String {
        let mut out = String::new();
        let mut i = 0usize;
        while i < self.owners.len() {
            let d = self.owners[i];
            let mut j = i + 1;
            while j < self.owners.len() && self.owners[j] == d {
                j += 1;
            }
            if !out.is_empty() {
                out.push(',');
            }
            out.push_str(&format!("{d}*{}", j - i));
            i = j;
        }
        out
    }

    /// Decode a [`LayoutDesc::to_rle`] string back into an owner table
    /// (`None` on malformed text — recovery treats that as no layout
    /// record, like a pre-versioned checkpoint).
    pub fn parse_rle(s: &str) -> Option<Vec<u32>> {
        let mut owners = Vec::new();
        if s.is_empty() {
            return Some(owners);
        }
        for run in s.split(',') {
            let (d, n) = run.split_once('*')?;
            let d: u32 = d.parse().ok()?;
            let n: usize = n.parse().ok()?;
            if n == 0 {
                return None;
            }
            owners.extend(std::iter::repeat_n(d, n));
        }
        Some(owners)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_owns_everything_and_rehome_is_identity() {
        let m = ShardMap::solo(1000);
        for w in [0usize, 1, 500, 999] {
            assert_eq!(m.owner(w), 0);
            assert_eq!(m.rehome(w, 0), w);
        }
        assert_eq!(m.owned_words(0), 1000);
        assert_eq!(m.owned_ranges(0), vec![(0, 1000)]);
        assert_eq!(m.epoch(), 0);
    }

    #[test]
    fn striping_is_round_robin() {
        let m = ShardMap::new(64, 4, 2); // 4-word blocks, 16 blocks
        assert_eq!(m.owner(0), 0);
        assert_eq!(m.owner(3), 0);
        assert_eq!(m.owner(4), 1);
        assert_eq!(m.owner(8), 2);
        assert_eq!(m.owner(12), 3);
        assert_eq!(m.owner(16), 0);
        for d in 0..4 {
            assert_eq!(m.owned_words(d), 16, "balanced stripes");
        }
    }

    #[test]
    fn rehome_lands_on_target_shard_preserving_offset() {
        let m = ShardMap::new(64, 4, 2);
        for w in 0..64 {
            for d in 0..4 {
                let r = m.rehome(w, d);
                assert!(r < 64);
                assert_eq!(m.owner(r), d, "word {w} -> shard {d} gave {r}");
                assert_eq!(r & 3, w & 3, "in-block offset preserved");
            }
        }
    }

    #[test]
    fn rehome_matches_legacy_stripe_arithmetic() {
        // The exact formula ShardMap used before the table: chosen block
        // is the word's own stripe cycle, stepped back at the tail.
        for (n_words, n_shards, bits) in [(64usize, 4usize, 2u32), (70, 2, 4), (100, 3, 3)] {
            let m = ShardLayout::new(n_words, n_shards, bits);
            for w in 0..n_words {
                for d in 0..n_shards {
                    let block = w >> bits;
                    let mut b = block - block % n_shards + d;
                    while (b << bits) >= n_words {
                        b -= n_shards;
                    }
                    let start = b << bits;
                    let len = (n_words - start).min(1 << bits);
                    let legacy = start + (w & ((1 << bits) - 1)) % len;
                    assert_eq!(m.rehome(w, d), legacy, "word {w} shard {d}");
                }
            }
        }
    }

    #[test]
    fn rehome_handles_partial_tail_block() {
        // 70 words, 2 shards, 16-word blocks: blocks 0..4, block 4 has
        // 6 words (64..70) and is owned by shard 0.
        let m = ShardMap::new(70, 2, 4);
        for w in 0..70 {
            for d in 0..2 {
                let r = m.rehome(w, d);
                assert!(r < 70, "word {w} shard {d} gave {r}");
                assert_eq!(m.owner(r), d);
            }
        }
    }

    #[test]
    fn owned_ranges_cover_exactly_once() {
        let m = ShardMap::new(100, 3, 3); // 8-word blocks
        let mut seen = vec![0u32; 100];
        for d in 0..3 {
            for (s, e) in m.owned_ranges(d) {
                for w in s..e {
                    seen[w] += 1;
                    assert_eq!(m.owner(w), d);
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "partition of the region");
    }

    #[test]
    #[should_panic(expected = "cannot give")]
    fn too_small_region_is_rejected() {
        ShardMap::new(16, 4, 4);
    }

    #[test]
    fn proportional_with_equal_weights_is_the_stripe() {
        for (n_words, n_shards, bits) in [(64usize, 4usize, 2u32), (70, 2, 4), (100, 3, 3)] {
            let striped = ShardLayout::new(n_words, n_shards, bits);
            let prop =
                ShardLayout::proportional(n_words, n_shards, bits, &vec![1.0; n_shards]);
            assert_eq!(striped, prop, "uniform WRR must reproduce the stripe");
        }
    }

    #[test]
    fn proportional_follows_weights() {
        // 16 blocks, speeds 3:1 -> the fast device gets ~12 of them.
        let m = ShardLayout::proportional(64, 2, 2, &[3.0, 1.0]);
        let fast = m.owned_ranges(0).iter().map(|(s, e)| e - s).sum::<usize>();
        let slow = m.owned_ranges(1).iter().map(|(s, e)| e - s).sum::<usize>();
        assert_eq!(fast + slow, 64);
        assert!(fast >= 44, "3:1 weights must skew the deal, got {fast}/{slow}");
        assert!(slow >= 4, "the slow device still owns blocks");
    }

    #[test]
    fn proportional_never_starves_a_shard() {
        let m = ShardLayout::proportional(64, 4, 2, &[1000.0, 1.0, 1.0, 1.0]);
        for d in 0..4 {
            assert!(m.owned_words(d) > 0, "shard {d} must own at least a block");
        }
    }

    #[test]
    fn migrate_moves_ownership_and_bumps_epoch() {
        let m = ShardLayout::new(64, 4, 2);
        let clone = m.clone(); // shares the table
        assert_eq!(m.owner(0), 0);
        let e1 = m.migrate(&[0], 3);
        assert_eq!(e1, 1);
        assert_eq!(m.owner(0), 3, "block 0 now owned by device 3");
        assert_eq!(clone.owner(0), 3, "clones observe the migration");
        assert_eq!(clone.epoch(), 1);
        // Rehome still lands on the owner under the migrated table.
        for w in 0..64 {
            for d in 0..4 {
                assert_eq!(m.owner(m.rehome(w, d)), d);
            }
        }
        // No-op move: epoch stays.
        assert_eq!(m.migrate(&[0], 3), 1);
    }

    #[test]
    fn migrate_never_empties_a_shard() {
        let m = ShardLayout::new(16, 4, 2); // exactly one block per shard
        let e = m.migrate(&[1], 0); // would empty shard 1: skipped
        assert_eq!(e, 0, "emptying move must be a no-op");
        assert_eq!(m.owner(4), 1);
    }

    #[test]
    fn owned_ranges_coalesce_adjacent_blocks_after_migration() {
        let m = ShardLayout::new(64, 2, 2);
        m.migrate(&[1], 0); // device 0 now owns blocks 0,1,2 contiguously? 0,1 and 2 (even)
        let r = m.owned_ranges(0);
        assert_eq!(r[0], (0, 12), "blocks 0..3 coalesce into one range");
        let mut seen = vec![0u32; 64];
        for d in 0..2 {
            for (s, e) in m.owned_ranges(d) {
                for w in s..e {
                    seen[w] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "still a partition");
    }

    #[test]
    fn layout_desc_rle_round_trips() {
        let m = ShardLayout::new(100, 3, 3);
        m.migrate(&[4, 7], 0);
        let d = m.desc();
        assert_eq!(d.epoch, 1);
        let rle = d.to_rle();
        assert_eq!(LayoutDesc::parse_rle(&rle).unwrap(), d.owners);
        assert_eq!(LayoutDesc::parse_rle(""), Some(vec![]));
        assert_eq!(LayoutDesc::parse_rle("junk"), None);
        assert_eq!(LayoutDesc::parse_rle("0*0"), None);
        let solo = LayoutDesc::solo(5);
        assert_eq!(solo.to_rle(), "0*5");
        assert_eq!(solo.n_shards(), 1);
    }

    #[test]
    fn view_matches_owner_and_pins_epoch() {
        let m = ShardLayout::new(64, 4, 2);
        let v = m.view();
        for w in 0..64 {
            assert_eq!(v.owner(w), m.owner(w));
        }
        assert_eq!(v.epoch(), 0);
        m.migrate(&[0], 2);
        assert_eq!(v.owner(0), 0, "a view is a point-in-time snapshot");
        assert_eq!(m.owner(0), 2);
        assert_eq!(m.view().epoch(), 1);
    }
}
