//! Word-range → device ownership map for the sharded STMR.
//!
//! The region is cut into fixed blocks of `1 << shard_bits` words and the
//! blocks are striped round-robin across the `n_shards` devices —
//! `owner(word) = (word >> shard_bits) % n_shards`.  Striping (rather than
//! one contiguous slab per device) keeps every device's share of a
//! partitioned workload balanced no matter how the apps partition the
//! region, and the block size aligns with the paper's 16 KB transfer
//! granule when `shard_bits = 12` (4096 words = 16 KB), so ownership
//! boundaries and merge-DMA boundaries coincide.
//!
//! With `n_shards = 1` every helper degenerates to the identity — the
//! single-device configuration is bit-for-bit the existing coordinator.

/// Ownership map: word index → shard (device) id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMap {
    n_words: usize,
    n_shards: usize,
    shard_bits: u32,
}

impl ShardMap {
    /// Build a map over `n_words` with `n_shards` devices and
    /// `1 << shard_bits`-word blocks.
    ///
    /// Panics unless every shard owns at least one full block
    /// (`n_words >= n_shards << shard_bits`) — a thinner region cannot be
    /// meaningfully sharded at this granularity.
    pub fn new(n_words: usize, n_shards: usize, shard_bits: u32) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        assert!(shard_bits < usize::BITS, "shard_bits out of range");
        assert!(
            n_words >= n_shards << shard_bits,
            "STMR of {n_words} words cannot give {n_shards} shards a \
             {}-word block each (lower cluster.shard_bits)",
            1usize << shard_bits
        );
        ShardMap {
            n_words,
            n_shards,
            shard_bits,
        }
    }

    /// The single-device identity map.
    pub fn solo(n_words: usize) -> Self {
        Self::new(n_words, 1, 0)
    }

    /// STMR size in words.
    pub fn n_words(&self) -> usize {
        self.n_words
    }

    /// Number of shards (devices).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Block-size shift (block = `1 << shard_bits` words).
    pub fn shard_bits(&self) -> u32 {
        self.shard_bits
    }

    /// Words per ownership block.
    pub fn block_words(&self) -> usize {
        1usize << self.shard_bits
    }

    /// Number of ownership blocks (last one may be partial).
    pub fn n_blocks(&self) -> usize {
        self.n_words.div_ceil(self.block_words())
    }

    /// The device owning `word`.
    #[inline]
    pub fn owner(&self, word: usize) -> usize {
        debug_assert!(word < self.n_words);
        (word >> self.shard_bits) % self.n_shards
    }

    /// Remap `word` to the nearest word (same in-block offset) owned by
    /// `shard` — the shard-aware workload generators draw uniformly over
    /// the whole region and rehome each access, which keeps their RNG
    /// streams identical across cluster sizes.  Identity when the map is
    /// [`ShardMap::solo`]-shaped.
    pub fn rehome(&self, word: usize, shard: usize) -> usize {
        debug_assert!(word < self.n_words);
        debug_assert!(shard < self.n_shards);
        let block = word >> self.shard_bits;
        let mut b = block - block % self.n_shards + shard;
        // The rounded block may start past the region's end (tail stripe):
        // step back one whole stripe. At most one step is ever needed —
        // the aligned base block starts in-range by construction.
        while (b << self.shard_bits) >= self.n_words {
            b -= self.n_shards;
        }
        let start = b << self.shard_bits;
        let len = (self.n_words - start).min(self.block_words());
        start + (word & (self.block_words() - 1)) % len
    }

    /// Words owned by `shard`.
    pub fn owned_words(&self, shard: usize) -> usize {
        self.owned_ranges(shard).iter().map(|(s, e)| e - s).sum()
    }

    /// Maximal word ranges `[start, end)` owned by `shard`, ascending.
    pub fn owned_ranges(&self, shard: usize) -> Vec<(usize, usize)> {
        assert!(shard < self.n_shards);
        let mut out = Vec::new();
        let mut b = shard;
        while b < self.n_blocks() {
            let s = b << self.shard_bits;
            let e = ((b + 1) << self.shard_bits).min(self.n_words);
            // Consecutive blocks coalesce only when n_shards == 1.
            match out.last_mut() {
                Some(last) if last.1 == s => last.1 = e,
                _ => out.push((s, e)),
            }
            b += self.n_shards;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_owns_everything_and_rehome_is_identity() {
        let m = ShardMap::solo(1000);
        for w in [0usize, 1, 500, 999] {
            assert_eq!(m.owner(w), 0);
            assert_eq!(m.rehome(w, 0), w);
        }
        assert_eq!(m.owned_words(0), 1000);
        assert_eq!(m.owned_ranges(0), vec![(0, 1000)]);
    }

    #[test]
    fn striping_is_round_robin() {
        let m = ShardMap::new(64, 4, 2); // 4-word blocks, 16 blocks
        assert_eq!(m.owner(0), 0);
        assert_eq!(m.owner(3), 0);
        assert_eq!(m.owner(4), 1);
        assert_eq!(m.owner(8), 2);
        assert_eq!(m.owner(12), 3);
        assert_eq!(m.owner(16), 0);
        for d in 0..4 {
            assert_eq!(m.owned_words(d), 16, "balanced stripes");
        }
    }

    #[test]
    fn rehome_lands_on_target_shard_preserving_offset() {
        let m = ShardMap::new(64, 4, 2);
        for w in 0..64 {
            for d in 0..4 {
                let r = m.rehome(w, d);
                assert!(r < 64);
                assert_eq!(m.owner(r), d, "word {w} -> shard {d} gave {r}");
                assert_eq!(r & 3, w & 3, "in-block offset preserved");
            }
        }
    }

    #[test]
    fn rehome_handles_partial_tail_block() {
        // 70 words, 2 shards, 16-word blocks: blocks 0..4, block 4 has
        // 6 words (64..70) and is owned by shard 0.
        let m = ShardMap::new(70, 2, 4);
        for w in 0..70 {
            for d in 0..2 {
                let r = m.rehome(w, d);
                assert!(r < 70, "word {w} shard {d} gave {r}");
                assert_eq!(m.owner(r), d);
            }
        }
    }

    #[test]
    fn owned_ranges_cover_exactly_once() {
        let m = ShardMap::new(100, 3, 3); // 8-word blocks
        let mut seen = vec![0u32; 100];
        for d in 0..3 {
            for (s, e) in m.owned_ranges(d) {
                for w in s..e {
                    seen[w] += 1;
                    assert_eq!(m.owner(w), d);
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "partition of the region");
    }

    #[test]
    #[should_panic(expected = "cannot give")]
    fn too_small_region_is_rejected() {
        ShardMap::new(16, 4, 4);
    }
}
