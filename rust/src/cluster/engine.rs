//! The multi-device synchronization-round engine.
//!
//! [`ClusterEngine`] generalizes [`RoundEngine`] from one simulated
//! accelerator to `N` devices over a sharded STMR:
//!
//! * one CPU side, unchanged: a single guest TM, one commit clock, one
//!   write-entry stream — scattered per-shard by the [`LogRouter`];
//! * per-device round pipelines: each device has its own H2D/D2H
//!   [`BusTimeline`] pair, its own virtual-time cursor, and validates only
//!   the CPU chunks routed to the words it owns, reusing the exact
//!   validation/merge machinery of the single-device engine
//!   ([`GpuDevice::validate_chunk`], shadow rollback, coarse-granule DtH);
//! * cross-shard conflict detection, hierarchical and batched (the
//!   Hechtman & Sorin cost lesson: never per-access): per-pair granule
//!   bitmap intersections first, escalating to a word-level scan only on a
//!   hit — CPU-written granules vs every non-owner device's read-set, and
//!   device write-sets vs every other device's read/write-sets;
//! * delta-coherence refresh: each device tracks which granules OTHER
//!   actors dirtied since it last saw them and pulls just those (coalesced
//!   at the 16 KB merge granule) from the post-merge CPU truth at round
//!   start — batched traffic instead of per-access coherence.
//!
//! # Threaded execution
//!
//! Each per-device pipeline is grouped into a `Lane`: the device, its
//! GPU driver, its bus timelines, its coherence bitmaps, its virtual-time
//! cursor and its *private partial* of the round statistics.  Lanes are
//! data-disjoint, so the engine can run the per-lane phases of a round
//! (refresh, execution slices, log shipping, own-shard validation, merge
//! transfers, rollback) either sequentially or on a pool of scoped OS
//! threads ([`ClusterEngine::set_threads`], config `cluster.threads`).
//! Everything that touches shared round state — the CPU slice, the log
//! router, cross-shard detection, merge installs into the CPU STMR, the
//! stale-map bookkeeping — runs on the coordinator thread at the barriers
//! between lane phases, in device-index order.  Shared-state *driver*
//! draws (e.g. the memcached dispatcher) happen in the
//! [`GpuDriver::prepare`] hook, also on the coordinator thread in index
//! order.  Because lane arithmetic is identical in both modes and every
//! reduction folds in device-index order, the threaded engine is
//! **bit-identical** to the sequential engine on the same seed — asserted
//! for every workload × policy by `rust/tests/cluster_equivalence.rs`, and
//! argued in DESIGN.md §8.
//!
//! **`n_gpus = 1` invariant**: with a [`ShardMap::solo`] map every
//! cluster-only mechanism is provably a no-op (no pairs, empty stale maps,
//! identity routing) and the remaining arithmetic is the same sequence of
//! operations as `RoundEngine::run_round` — the single lane accumulates
//! each statistic through exactly the chain of additions the single-device
//! engine performs, and the end-of-round fold adds that chain to a zeroed
//! field (`0.0 + x == x` bitwise for the non-negative phase times) — so
//! final state and [`RunStats`] are bit-identical on the same seed,
//! asserted by `rust/tests/cluster_equivalence.rs`.
//!
//! MAINTENANCE: `run_round` deliberately *mirrors* (rather than replaces)
//! `RoundEngine::run_round` — the untouched single-device engine is the
//! independent oracle that gives the equivalence test its teeth.  A change
//! to either round state machine must be mirrored in the other; the
//! equivalence suite fails loudly when the mirror drifts.  Within a lane,
//! keep the order of floating-point accumulations exactly as the
//! single-device engine performs them.
//!
//! [`RoundEngine`]: crate::coordinator::round::RoundEngine
//! [`GpuDriver::prepare`]: crate::coordinator::round::GpuDriver::prepare

use anyhow::{anyhow, Result};

use super::router::LogRouter;
use super::shard::ShardMap;
use super::stats::{ClusterStats, DeviceStats};
use crate::bus::BusTimeline;
use crate::coordinator::policy::{Loser, Policy};
use crate::coordinator::round::{CostModel, CpuDriver, EngineConfig, GpuDriver, Variant};
use crate::coordinator::stats::{PhaseBreakdown, RoundStats, RunStats};
use crate::gpu::{Bitmap, GpuDevice, LogChunk};
use crate::stm::WriteEntry;
use crate::telemetry::{RoundObs, Telemetry};

/// Lane-private telemetry samples, gathered only when a recorder is
/// installed and folded in device-index order at the round barrier —
/// observation never perturbs the deterministic schedule (DESIGN.md §11).
#[derive(Default)]
struct LaneObs {
    /// Per-chunk own-shard validation costs, in chunk order.
    vcost: Vec<f64>,
    /// Per-chunk H2D log-ship durations, in ship order.
    ship: Vec<f64>,
    /// Committed-merge D2H transfer durations, in range order.
    merge: Vec<f64>,
}

/// Per-lane round-lifetime buffers, owned by the engine and lent to the
/// [`Lane`] for the round in flight (DESIGN.md §12 arena): steady-state
/// rounds reuse the capacity grown in earlier rounds instead of
/// allocating.  The retired `LogChunk` buffers themselves go back to the
/// owning shard's [`RoundLog`] pool via [`LogRouter::recycle`].
#[derive(Default)]
struct LaneBufs {
    /// Backing store for [`Lane::chunks`].
    chunks: Vec<LogChunk>,
    /// Backing store for [`Lane::arrivals`].
    arrivals: Vec<f64>,
    /// Backing store for [`Lane::inbox`].
    inbox: Vec<LogChunk>,
    /// Backing store for [`Lane::coarse`].
    coarse: Vec<(usize, usize)>,
    /// Backing store for [`Lane::conf`].
    conf: Vec<u32>,
}

/// One device's pipeline state for the round in flight: disjoint mutable
/// borrows of the per-device engine state plus lane-private partials of
/// the shared [`RoundStats`].  Lanes never touch each other's fields, so a
/// phase over all lanes can run on worker threads (see the module docs).
struct Lane<'a, G> {
    /// The simulated accelerator (replica, bitmaps, shadow).
    dev: &'a mut GpuDevice,
    /// This device's GPU driver.
    gpu: &'a mut G,
    /// Host-to-device bus channel.
    h2d: &'a mut BusTimeline,
    /// Device-to-host bus channel.
    d2h: &'a mut BusTimeline,
    /// Granules dirtied elsewhere since this device last saw them.
    stale: &'a mut Bitmap,
    /// This round's routed CPU writes on this shard (cross-shard operand).
    cpu_ws: &'a mut Bitmap,
    /// Persistent per-device aggregate statistics.
    per_dev: &'a mut DeviceStats,
    /// This device's virtual-time cursor through the round.
    cursor: f64,
    /// Chunks routed and shipped to this shard this round.
    chunks: Vec<LogChunk>,
    /// Bus arrival time of each chunk in `chunks`.
    arrivals: Vec<f64>,
    /// Chunks drained from the router on the coordinator thread but not
    /// yet shipped (consumed inside the lane's next parallel phase).
    inbox: Vec<LogChunk>,
    /// Lane partial of `RoundStats::gpu_commits`.
    gpu_commits: u64,
    /// Lane partial of `RoundStats::gpu_attempts`.
    gpu_attempts: u64,
    /// Lane partial of `RoundStats::gpu_batches`.
    gpu_batches: u64,
    /// Lane partial of `RoundStats::gpu_phases` (folded at round end in
    /// device-index order).
    gpu_phases: PhaseBreakdown,
    /// Lane partial of `RoundStats::cpu_phases.validation_s` (basic
    /// variant: CPU blocked shipping this shard's logs).
    cpu_validation_s: f64,
    /// Own-shard conflicting entries this lane's validation found.
    own_conflicts: u64,
    /// Lane partial of `RoundStats::chunks_filtered`.
    chunks_filtered: u64,
    /// Lane partial of `RoundStats::chunks_skipped_post_abort`.
    chunks_skipped: u64,
    /// Basic variant: completion time of this lane's tail log shipping
    /// (the CPU is blocked until the last shard finishes shipping).
    ship_end: f64,
    /// Early-validation conflicts seen in the current segment.
    early_conf: u32,
    /// Coarse merge ranges computed while scheduling DtH transfers
    /// (reused by the coordinator-thread install).
    coarse: Vec<(usize, usize)>,
    /// Per-chunk conflict-count scratch for the batched validation fast
    /// paths ([`GpuDevice::early_validate_chunks_into`]).
    conf: Vec<u32>,
    /// Phase output: completion time of this lane's last bus transfer.
    dth_end: f64,
    /// First error raised inside a parallel phase (deferred to the next
    /// barrier; stored as a message so lanes stay `Send` regardless of
    /// the error type's auto traits).
    err: Option<String>,
    /// Refresh traffic of this round (folded into `ClusterStats`).
    refresh_bytes: u64,
    /// Refresh DMAs of this round (folded into `ClusterStats`).
    refresh_transfers: u64,
    /// Telemetry samples (`None` when the recorder is off — the common
    /// case pays one pointer of storage and no per-chunk work).
    obs: Option<LaneObs>,
}

/// Run `f` over every lane — sequentially when `threads <= 1`, otherwise
/// on `min(threads, n_lanes)` scoped OS threads, each owning a balanced
/// contiguous block of lanes (`n = q·t + r` ⇒ `r` blocks of `q + 1` and
/// `t − r` of `q`, so no requested thread idles while another holds two
/// lanes).  A single lane with `threads > 1` still runs on a spawned
/// worker, so threaded configurations cross a real thread boundary even
/// at `n_gpus = 1`.  Grouping does not affect results: lanes are
/// data-disjoint and `f` receives the same lane index either way, so
/// this is purely a wall-clock lever.
fn run_lanes<'a, G, F>(threads: usize, lanes: &mut [Lane<'a, G>], f: F)
where
    G: GpuDriver + Send,
    F: Fn(usize, &mut Lane<'a, G>) + Sync,
{
    let n = lanes.len();
    if threads <= 1 || n == 0 {
        for (d, lane) in lanes.iter_mut().enumerate() {
            f(d, lane);
        }
        return;
    }
    let t = threads.min(n);
    let (q, r) = (n / t, n % t);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest: &mut [Lane<'a, G>] = lanes;
        let mut base = 0usize;
        for g in 0..t {
            let take = q + usize::from(g < r);
            // Move the full-lifetime slice out before splitting, so the
            // halves live long enough for the scoped spawn.
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            s.spawn(move || {
                for (i, lane) in head.iter_mut().enumerate() {
                    f(base + i, lane);
                }
            });
            rest = tail;
            base += take;
        }
    });
}

/// First deferred lane error, by device index (mirrors the sequential
/// engine's propagation order).
fn first_lane_err<G>(lanes: &mut [Lane<'_, G>]) -> Option<String> {
    lanes.iter_mut().find_map(|l| l.err.take())
}

/// Online-rebalancer tuning (config keys `cluster.rebalance*`): how
/// often the coordinator inspects per-device load at the round barrier,
/// how much speed-normalized imbalance it tolerates, and how many
/// ownership blocks one migration may move (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RebalanceCfg {
    /// Rounds per observation window: a decision is made every
    /// `interval` committed-or-aborted rounds (favor-GPU abort rounds
    /// are skipped — see the barrier step in `run_round`).
    pub interval: usize,
    /// Trigger threshold: migrate only when the hottest device's
    /// speed-normalized shipped-entry load exceeds `threshold × mean`.
    pub threshold: f64,
    /// Maximum ownership blocks one migration ships.
    pub max_granules: usize,
}

impl Default for RebalanceCfg {
    fn default() -> Self {
        RebalanceCfg {
            interval: 4,
            threshold: 1.25,
            max_granules: 8,
        }
    }
}

/// The sharded SHeTM cluster engine.
pub struct ClusterEngine<C: CpuDriver, G: GpuDriver> {
    /// Engine configuration (variant, period, policy, ...), shared by all
    /// per-device pipelines.
    pub cfg: EngineConfig,
    /// Cost model used to advance virtual time (same for every device).
    pub cost: CostModel,
    /// Word-range → device ownership.
    pub map: ShardMap,
    /// The simulated accelerators, indexed by shard id.
    pub devices: Vec<GpuDevice>,
    /// The (single) CPU-side driver.
    pub cpu: C,
    /// Per-device GPU drivers, indexed by shard id.
    pub gpus: Vec<G>,
    /// Aggregate statistics, single-device-compatible (totals across
    /// devices; bit-identical to `RoundEngine` at `n_gpus = 1`).
    pub stats: RunStats,
    /// Cluster-only statistics (per-device + cross-shard accounting).
    pub cluster: ClusterStats,
    /// Per-round statistics (most recent rounds, ring-limited).
    pub round_log: Vec<RoundStats>,
    /// Observability hook (off by default; see [`crate::telemetry`]).
    /// At `n_gpus = 1` the recorded observations are bit-identical to
    /// [`RoundEngine`]'s (`rust/tests/telemetry.rs` pins this).
    pub tel: Telemetry,
    /// Durability hook (checkpoints at the round barrier, mirroring
    /// [`RoundEngine`]).  `None` unless the session builder configured a
    /// checkpoint directory; the off path costs one `Option` test per
    /// round.
    pub dur: Option<Box<crate::durability::DurabilityHook>>,

    policy: Policy,
    h2d: Vec<BusTimeline>,
    d2h: Vec<BusTimeline>,
    /// Virtual time of the current round's start.
    t: f64,
    /// When the CPU may resume processing (merge install blocks it).
    cpu_avail: f64,
    router: LogRouter,
    carry: Vec<WriteEntry>,
    scratch: Vec<WriteEntry>,
    /// Every entry routed this round (cross-shard merge reconciliation).
    round_entries: Vec<WriteEntry>,
    /// Per-device map of granules dirtied elsewhere since the device last
    /// saw them (drives the round-start delta refresh).
    stale: Vec<Bitmap>,
    /// Per-shard bitmaps of this round's routed CPU writes (cross-shard
    /// probe operands; rebuilt each round).
    cpu_ws: Vec<Bitmap>,
    /// OS worker threads driving the per-device lane phases (1 = fully
    /// sequential; results are identical at any setting).
    threads: usize,
    /// Per-lane round-lifetime buffers (DESIGN.md §12 arena), lent to the
    /// lanes each round and taken back at wrap-up.
    lane_bufs: Vec<LaneBufs>,
    /// Coordinator-thread scratch for exact dirty-range scans (merge
    /// installs, stale-map bookkeeping).
    exact: Vec<(usize, usize)>,
    /// Per-device cost models derived from the baseline `cost` and the
    /// relative speed factors ([`CostModel::scaled`]); at the default
    /// uniform speeds every element equals `cost` bitwise, so the
    /// heterogeneous plumbing preserves bit-identity with the
    /// pre-per-device engine.
    costs: Vec<CostModel>,
    /// Relative device speed factors (`1.0` = baseline); the rebalancer
    /// normalizes its shipped-entry load signal by these.
    speeds: Vec<f64>,
    /// Online round-barrier rebalancer tuning (`None` = off, the
    /// default — the off path costs one `Option` test per round).
    rebal: Option<RebalanceCfg>,
    /// Per-device shipped-entry accumulator over the current rebalance
    /// observation window.
    win_shipped: Vec<u64>,
    /// Rounds elapsed since the last rebalance decision.
    rounds_since_rebal: usize,
}

impl<C: CpuDriver, G: GpuDriver + Send> ClusterEngine<C, G> {
    /// Assemble a cluster engine; every device's replica must cover the
    /// same STMR as the CPU driver's, and `devices`/`gpus` are indexed by
    /// shard id of `map`.
    pub fn new(
        cfg: EngineConfig,
        cost: CostModel,
        map: ShardMap,
        devices: Vec<GpuDevice>,
        cpu: C,
        gpus: Vec<G>,
    ) -> Self {
        assert!(!devices.is_empty(), "need at least one device");
        assert_eq!(devices.len(), map.n_shards(), "one device per shard");
        assert_eq!(gpus.len(), map.n_shards(), "one GPU driver per shard");
        assert_eq!(
            map.n_words(),
            cpu.stmr().len(),
            "shard map must cover the CPU STMR"
        );
        for d in &devices {
            assert_eq!(
                d.n_words(),
                cpu.stmr().len(),
                "CPU and device replicas must cover the same STMR"
            );
        }
        let n = devices.len();
        let bmp_shift = devices[0].rs_bmp().shift();
        let policy = Policy::new(cfg.policy, cfg.starvation_limit);
        let mut router = LogRouter::new(map.clone(), cfg.chunk_entries);
        router.set_compaction(cfg.log_compaction);
        if cfg.chunk_filter {
            router.set_sig_shift(Some(bmp_shift));
        }
        ClusterEngine {
            cfg,
            cost,
            devices,
            cpu,
            gpus,
            stats: RunStats::default(),
            cluster: ClusterStats::new(n),
            round_log: Vec::new(),
            tel: Telemetry::off(),
            dur: None,
            policy,
            h2d: (0..n).map(|_| BusTimeline::new()).collect(),
            d2h: (0..n).map(|_| BusTimeline::new()).collect(),
            t: 0.0,
            cpu_avail: 0.0,
            router,
            carry: Vec::new(),
            scratch: Vec::new(),
            round_entries: Vec::new(),
            stale: (0..n).map(|_| Bitmap::new(map.n_words(), bmp_shift)).collect(),
            cpu_ws: (0..n).map(|_| Bitmap::new(map.n_words(), bmp_shift)).collect(),
            map,
            threads: 1,
            lane_bufs: (0..n).map(|_| LaneBufs::default()).collect(),
            exact: Vec::new(),
            costs: vec![cost; n],
            speeds: vec![1.0; n],
            rebal: None,
            win_shipped: vec![0; n],
            rounds_since_rebal: 0,
        }
    }

    /// Install per-device relative speed factors (config key
    /// `cluster.dev_speed`).  The per-device cost models derive from the
    /// baseline via [`CostModel::scaled`] — factor `1.0` keeps the
    /// baseline bit-exactly — and the rebalancer normalizes its load
    /// signal by these factors, so a fast device is expected to carry
    /// proportionally more shipped entries before it counts as hot.
    /// Panics unless exactly one finite positive factor per device is
    /// given.
    pub fn set_dev_speeds(&mut self, speeds: &[f64]) {
        assert_eq!(
            speeds.len(),
            self.devices.len(),
            "one speed factor per device"
        );
        assert!(
            speeds.iter().all(|s| s.is_finite() && *s > 0.0),
            "device speed factors must be finite and positive"
        );
        self.speeds = speeds.to_vec();
        self.costs = speeds.iter().map(|&s| self.cost.scaled(s)).collect();
    }

    /// Current per-device speed factors (see [`Self::set_dev_speeds`]).
    pub fn dev_speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// Enable (`Some`) or disable (`None`) the online round-barrier
    /// rebalancer (DESIGN.md §14).  Enabling turns on the router's
    /// per-block heat window, the signal used to pick migration targets.
    pub fn set_rebalance(&mut self, cfg: Option<RebalanceCfg>) {
        self.rebal = cfg;
        if cfg.is_some() {
            self.router.enable_heat();
        }
    }

    /// Current rebalancer setting (see [`Self::set_rebalance`]).
    pub fn rebalance(&self) -> Option<RebalanceCfg> {
        self.rebal
    }

    /// Number of devices in the cluster.
    pub fn n_gpus(&self) -> usize {
        self.devices.len()
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Set the number of OS worker threads driving the per-device lane
    /// phases (config key `cluster.threads`, CLI `--threads`).  Clamped to
    /// at least 1; values above `n_gpus` spawn one thread per device.
    /// Threads left over after one-per-lane also engage intra-device
    /// parallel conflict counting ([`GpuDevice::set_validate_threads`])
    /// when a device's chunk backlog is large enough to amortize the
    /// spawns.  Purely a wall-clock lever: results are bit-identical at
    /// any setting (DESIGN.md §8 and §12 — conflict counts are integer
    /// sums, associative in any fold order).
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
        let per_dev = (self.threads / self.devices.len()).max(1);
        for d in &mut self.devices {
            d.set_validate_threads(per_dev);
        }
    }

    /// Current worker-thread setting (see [`Self::set_threads`]).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Copy the CPU STMR into every device replica (initial alignment —
    /// all replicas must start from one consistent snapshot, §IV-C.1).
    pub fn align_replicas(&mut self) {
        let snap = self.cpu.stmr().snapshot();
        for d in &mut self.devices {
            d.stmr_mut().copy_from_slice(&snap);
        }
    }

    /// Run `n` synchronization rounds.
    pub fn run_rounds(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.run_round()?;
        }
        Ok(())
    }

    /// Run rounds until at least `dur_s` of virtual time has elapsed.
    pub fn run_for(&mut self, dur_s: f64) -> Result<()> {
        let end = self.t + dur_s;
        while self.t < end {
            self.run_round()?;
        }
        Ok(())
    }

    /// Quiesce: one zero-length round so carried commits ship and apply
    /// (see `RoundEngine::drain`).
    pub fn drain(&mut self) -> Result<()> {
        let saved = self.cfg.clone();
        self.cfg.period_s = 0.0;
        self.cfg.early_validation = false;
        let r = self.run_round();
        self.cfg = saved;
        r
    }

    /// Change the log-chunk size (ablation benches).  Must be called
    /// between rounds; the router is rebuilt at the new chunking
    /// (compaction and signature settings are preserved) and re-seeded
    /// with every shard's carried prefix — commits already counted on
    /// the CPU still ship next round instead of being silently dropped
    /// (mirrors `RoundEngine::set_chunk_entries`).
    pub fn set_chunk_entries(&mut self, n: usize) {
        self.cfg.chunk_entries = n;
        let mut carried: Vec<WriteEntry> = Vec::new();
        for s in 0..self.router.n_shards() {
            carried.extend_from_slice(self.router.log(s).entries());
        }
        let mut router = LogRouter::new(self.map.clone(), n);
        router.set_compaction(self.cfg.log_compaction);
        if self.cfg.chunk_filter {
            router.set_sig_shift(Some(self.devices[0].rs_bmp().shift()));
        }
        // Rescattering by owner reproduces each shard's prefix in order
        // (shards are address-disjoint, so concatenation order across
        // shards is immaterial).
        router.reset_with_carry(&carried);
        self.router = router;
        if self.rebal.is_some() {
            // The rebuilt router must keep feeding the rebalancer's heat
            // window (the old router's partial window is discarded).
            self.router.enable_heat();
        }
        self.carry.clear();
    }

    /// Enqueue externally-committed CPU write entries (the
    /// [`crate::session::Session::txn`] entry point), mirroring
    /// [`crate::coordinator::round::RoundEngine::inject_external`]: the
    /// entries scatter into their owner shards' carried prefixes and ship
    /// next round; every device (owner included, matching the round
    /// wrap-up's carry convention — the values live on the CPU only until
    /// the carry re-ships through validation) is marked stale so the
    /// delta-coherence refresh covers reads of those words too.
    pub fn inject_external(&mut self, entries: &[WriteEntry], commits: u64, attempts: u64) {
        self.router.extend_carried(entries);
        if self.devices.len() > 1 {
            // Like the validation-window carry: the values live on the
            // CPU only until the carry re-ships through validation, so
            // every device must refresh those words.
            for e in entries {
                for stale in &mut self.stale {
                    stale.mark_word(e.addr as usize);
                }
            }
        }
        self.stats.cpu_commits += commits;
        self.stats.cpu_attempts += attempts;
        if self.tel.enabled() {
            self.tel.record_txn(entries.len() as u64, attempts, self.t);
        }
    }

    /// Execute one synchronization round across all devices.
    ///
    /// Per-lane phases run sequentially or on worker threads (see
    /// [`Self::set_threads`]); all shared-state work happens at the
    /// barriers between them, on this thread, in device-index order.  On a
    /// device-backend error the round is abandoned mid-flight (state is
    /// poisoned exactly as in the sequential engine); the lowest-index
    /// lane's error is returned.
    pub fn run_round(&mut self) -> Result<()> {
        let ClusterEngine {
            cfg,
            cost,
            map,
            devices,
            cpu,
            gpus,
            stats,
            cluster,
            round_log,
            tel,
            dur,
            policy,
            h2d,
            d2h,
            t,
            cpu_avail,
            router,
            carry,
            scratch,
            round_entries,
            stale,
            cpu_ws,
            threads,
            lane_bufs,
            exact,
            costs,
            speeds,
            rebal,
            win_shipped,
            rounds_since_rebal,
        } = self;
        let threads = *threads;
        let cost = *cost;
        // Shared-slice reborrow: the lane closures capture the per-device
        // models read-only (at uniform speeds `costs[d] == cost` bitwise,
        // so every device-side charge below matches the pre-per-device
        // arithmetic exactly).
        let costs: &[CostModel] = costs;
        let optimized = cfg.variant == Variant::Optimized;
        let n_dev = devices.len();
        let t0 = *t;
        let mut rs = RoundStats {
            t_start: t0,
            ..Default::default()
        };
        let n_bytes = (map.n_words() * 4) as u64;
        let granule_words = (crate::bus::chunking::MERGE_GRANULE_BYTES / 4) as usize;
        let chunk_entries = cfg.chunk_entries;
        let filter = cfg.chunk_filter;

        // Telemetry samples live in the lanes and fold at the barrier in
        // device-index order (same shape as every other lane partial).
        let tel_on = tel.enabled();

        let read_only = policy.cpu_read_only();
        cpu.set_read_only(read_only);
        let conditional = policy.conditional_apply();
        if conditional {
            // favor-GPU needs a CPU snapshot to roll back to (fork/COW).
            cpu.snapshot();
        }

        // Round-lifetime buffers come from the engine-owned arena (taken
        // here, returned at wrap-up): steady-state rounds reuse last
        // round's capacity instead of allocating (DESIGN.md §12).
        let mut lanes: Vec<Lane<'_, G>> = devices
            .iter_mut()
            .zip(gpus.iter_mut())
            .zip(h2d.iter_mut())
            .zip(d2h.iter_mut())
            .zip(stale.iter_mut())
            .zip(cpu_ws.iter_mut())
            .zip(cluster.per_device.iter_mut())
            .zip(lane_bufs.iter_mut())
            .map(|(((((((dev, gpu), h2d), d2h), stale), cpu_ws), per_dev), bufs)| Lane {
                dev,
                gpu,
                h2d,
                d2h,
                stale,
                cpu_ws,
                per_dev,
                cursor: t0,
                chunks: std::mem::take(&mut bufs.chunks),
                arrivals: std::mem::take(&mut bufs.arrivals),
                inbox: std::mem::take(&mut bufs.inbox),
                gpu_commits: 0,
                gpu_attempts: 0,
                gpu_batches: 0,
                gpu_phases: PhaseBreakdown::default(),
                cpu_validation_s: 0.0,
                own_conflicts: 0,
                chunks_filtered: 0,
                chunks_skipped: 0,
                ship_end: 0.0,
                early_conf: 0,
                coarse: std::mem::take(&mut bufs.coarse),
                conf: std::mem::take(&mut bufs.conf),
                dth_end: 0.0,
                err: None,
                refresh_bytes: 0,
                refresh_transfers: 0,
                obs: tel_on.then(LaneObs::default),
            })
            .collect();

        // --- Execution phase --------------------------------------------
        // Delta-coherence refresh (empty at n_gpus = 1) + shadow snapshot,
        // per lane: pull granules other actors dirtied, coalesced at the
        // merge granule, from the post-merge CPU truth, over this device's
        // own H2D channel.  The CPU truth is read-only here.
        {
            let cpu_stmr = cpu.stmr();
            run_lanes(threads, &mut lanes, |d, lane| {
                let c = &costs[d];
                lane.stale
                    .dirty_word_ranges_coarse_into(granule_words, &mut lane.coarse);
                let mut refresh_end = t0;
                for &(s, e) in lane.coarse.iter() {
                    let bytes = ((e - s) * 4) as u64;
                    let dur = c.bus_h2d.transfer_secs(bytes);
                    let (_, end) = lane.h2d.schedule(t0, dur);
                    refresh_end = end;
                    let fresh: Vec<i32> = (s..e).map(|w| cpu_stmr.load(w)).collect();
                    lane.dev.stmr_mut()[s..e].copy_from_slice(&fresh);
                    lane.refresh_bytes += bytes;
                    lane.refresh_transfers += 1;
                    lane.per_dev.refresh_bytes += bytes;
                    lane.per_dev.refresh_transfers += 1;
                }
                lane.stale.clear();

                // Shadow snapshot AFTER the refresh so rollback keeps it.
                lane.dev.begin_round();
                lane.gpu_phases.merge_s += refresh_end - t0;
                lane.per_dev.phases.merge_s += refresh_end - t0;
                lane.cursor = refresh_end;
                if optimized {
                    // Shadow copy (DtD) before the device may process (§IV-D).
                    let dtd = n_bytes as f64 / c.gpu_dtd_bytes_per_s;
                    lane.cursor += dtd;
                    lane.gpu_phases.merge_s += dtd;
                    lane.per_dev.phases.merge_s += dtd;
                }
            });
        }
        for lane in &mut lanes {
            cluster.refresh_bytes += lane.refresh_bytes;
            cluster.refresh_transfers += lane.refresh_transfers;
        }
        let exec_end_target = t0 + cfg.period_s;
        let mut early_abort = false;
        let mut early_conf_total = 0u64;

        let mut cpu_cursor = cpu_avail.max(t0);
        rs.cpu_phases.blocked_s += cpu_cursor - t0;
        let segments = if optimized && cfg.early_validation {
            cfg.early_points + 1
        } else {
            1
        };
        let seg_dur = (exec_end_target - cpu_cursor).max(0.0) / segments as f64;

        for s in 0..segments {
            // CPU slice (real transactions through the guest TM), routed
            // to owner shards as it is logged.
            scratch.clear();
            let cs = cpu.run(seg_dur, scratch);
            router.append(scratch);
            if n_dev > 1 {
                // Kept for cross-shard merge reconciliation; never read
                // (so never copied) on the single-device path.
                round_entries.extend_from_slice(scratch);
            }
            rs.cpu_commits += cs.commits;
            rs.cpu_attempts += cs.attempts;
            rs.cpu_phases.processing_s += seg_dur;
            cpu_cursor += seg_dur;

            // Deterministic pre-slice, coordinator thread, index order:
            // shared-state driver draws (GpuDriver::prepare) and router
            // drains — so the parallel slice below is data-disjoint.
            for (d, lane) in lanes.iter_mut().enumerate() {
                let budget = (cpu_cursor - lane.cursor).max(0.0);
                lane.gpu.prepare(budget);
                if optimized {
                    router.drain_full_chunks(d, &mut lane.inbox);
                }
            }

            // Per-device GPU slices covering the same virtual span, plus
            // non-blocking log streaming (§IV-D) on each shard's own bus
            // channel, plus per-device early validation — one lane phase.
            let do_early = optimized && cfg.early_validation && s + 1 < segments;
            run_lanes(threads, &mut lanes, |d, lane| {
                let c = &costs[d];
                let budget = (cpu_cursor - lane.cursor).max(0.0);
                let gs = match lane.gpu.run(lane.dev, budget) {
                    Ok(gs) => gs,
                    Err(e) => {
                        lane.err = Some(format!("gpu slice: {e}"));
                        return;
                    }
                };
                lane.gpu_commits += gs.commits;
                lane.gpu_attempts += gs.attempts;
                lane.gpu_batches += gs.batches;
                lane.gpu_phases.processing_s += gs.busy_s;
                lane.gpu_phases.blocked_s += (budget - gs.busy_s).max(0.0);
                lane.per_dev.commits += gs.commits;
                lane.per_dev.attempts += gs.attempts;
                lane.per_dev.batches += gs.batches;
                lane.per_dev.phases.processing_s += gs.busy_s;
                lane.per_dev.phases.blocked_s += (budget - gs.busy_s).max(0.0);
                lane.cursor = cpu_cursor;

                // Ship this shard's full chunks now (§IV-D streaming).
                if optimized {
                    for chunk in lane.inbox.drain(..) {
                        let dur = c.bus_h2d.transfer_secs(chunk.wire_bytes());
                        let (_, end) = lane.h2d.schedule(cpu_cursor, dur);
                        lane.arrivals.push(end);
                        if let Some(o) = &mut lane.obs {
                            o.ship.push(dur);
                        }
                        lane.chunks.push(chunk);
                    }
                }

                // Early validation between segments (§IV-D), per device.
                if do_early {
                    let arrived =
                        lane.arrivals.iter().filter(|&&a| a <= cpu_cursor).count();
                    let mut conf = 0u32;
                    let vcost = if filter {
                        // Signature-prefiltered scan (mirrors RoundEngine).
                        let mut vcost = 0.0;
                        for chunk in lane.chunks.iter().take(arrived) {
                            vcost += c.gpu_sig_check_s;
                            if lane.dev.chunk_provably_clean(chunk) {
                                continue;
                            }
                            conf += lane.dev.early_validate_chunk(chunk);
                            vcost += chunk_entries as f64 * c.gpu_validate_entry_s;
                        }
                        vcost
                    } else {
                        // Batched fast path (DESIGN.md §12): one flat
                        // conflict-count pass per arrived chunk, fanned
                        // over the device's validate lanes when the
                        // backlog is large enough.  Integer partials sum
                        // in chunk order — bit-identical to the scalar
                        // loop.
                        lane.dev.early_validate_chunks_into(
                            &lane.chunks[..arrived],
                            &mut lane.conf,
                        );
                        conf += lane.conf.iter().sum::<u32>();
                        arrived as f64 * chunk_entries as f64 * c.gpu_validate_entry_s
                    };
                    lane.cursor += vcost;
                    lane.gpu_phases.validation_s += vcost;
                    lane.per_dev.phases.validation_s += vcost;
                    lane.early_conf = conf;
                }
            });
            if let Some(e) = first_lane_err(&mut lanes) {
                return Err(anyhow!("{e}"));
            }
            if do_early {
                let conf: u32 = lanes.iter().map(|l| l.early_conf).sum();
                for lane in &mut lanes {
                    lane.early_conf = 0;
                }
                if conf > 0 {
                    early_abort = true;
                    early_conf_total = u64::from(conf);
                    rs.early_aborted = true;
                    break;
                }
            }
        }

        // Drain the remaining (tail) chunks of every shard (coordinator
        // thread), then ship them and run own-shard validation per lane.
        for (d, lane) in lanes.iter_mut().enumerate() {
            router.drain_all(d, &mut lane.inbox);
        }

        // --- Validation phase: own shard -----------------------------------
        run_lanes(threads, &mut lanes, |d, lane| {
            let c = &costs[d];
            let chunk_cost = chunk_entries as f64 * c.gpu_validate_entry_s;
            lane.ship_end = cpu_cursor;
            for chunk in lane.inbox.drain(..) {
                let dur = c.bus_h2d.transfer_secs(chunk.wire_bytes());
                let (_, end) = lane.h2d.schedule(cpu_cursor, dur);
                lane.arrivals.push(end);
                if let Some(o) = &mut lane.obs {
                    o.ship.push(dur);
                }
                lane.chunks.push(chunk);
                if !optimized {
                    // Basic: the CPU is blocked while shipping its logs.
                    lane.cpu_validation_s += dur;
                    lane.ship_end = end;
                }
            }

            let mut dev_conf = 0u64;
            for i in 0..lane.chunks.len() {
                let arr = lane.arrivals[i];
                let start = arr.max(lane.cursor);
                lane.gpu_phases.blocked_s += start - lane.cursor;
                lane.per_dev.phases.blocked_s += start - lane.cursor;
                if early_abort {
                    // Fate decided by early validation: the chunk still
                    // lands (apply/rollback needs it) but the per-entry
                    // pass is skipped (mirrors RoundEngine).
                    lane.chunks_skipped += 1;
                    lane.cursor = start;
                    continue;
                }
                let mut vcost = 0.0;
                let clean = filter && lane.dev.chunk_provably_clean(&lane.chunks[i]);
                if filter {
                    vcost += c.gpu_sig_check_s;
                }
                if clean {
                    lane.chunks_filtered += 1;
                    lane.per_dev.chunks_filtered += 1;
                    if !conditional {
                        // Provably conflict-free: plain scatter apply.
                        match lane.dev.validate_chunk(&lane.chunks[i]) {
                            Ok(n) => debug_assert_eq!(
                                n, 0,
                                "signature filter must be conservative"
                            ),
                            Err(e) => {
                                lane.err = Some(format!("validate: {e}"));
                                return;
                            }
                        }
                    }
                } else {
                    dev_conf += if conditional {
                        // favor-GPU: check without applying (§IV-E).
                        u64::from(lane.dev.early_validate_chunk(&lane.chunks[i]))
                    } else {
                        match lane.dev.validate_chunk(&lane.chunks[i]) {
                            Ok(n) => u64::from(n),
                            Err(e) => {
                                lane.err = Some(format!("validate: {e}"));
                                return;
                            }
                        }
                    };
                    vcost += chunk_cost;
                }
                if let Some(o) = &mut lane.obs {
                    o.vcost.push(vcost);
                }
                lane.cursor = start + vcost;
                lane.gpu_phases.validation_s += vcost;
                lane.per_dev.phases.validation_s += vcost;
            }
            lane.per_dev.chunks += lane.chunks.len() as u64;
            lane.per_dev.conflict_entries += dev_conf;
            lane.own_conflicts = dev_conf;

            // Cross-shard probe operand: this shard's routed CPU writes.
            if n_dev > 1 {
                lane.cpu_ws.clear();
                for chunk in &lane.chunks {
                    for &a in &chunk.addrs {
                        if a >= 0 {
                            lane.cpu_ws.mark_word(a as usize);
                        }
                    }
                }
            }
        });
        if let Some(e) = first_lane_err(&mut lanes) {
            return Err(anyhow!("{e}"));
        }
        // Basic: the CPU cursor follows the tail shipping it was blocked
        // on — until the LAST shard's channel finishes (mirrors the
        // RoundEngine fix; with one lane the fold is the same max).  The
        // per-device channels ship in parallel, so the span the CPU is
        // actually blocked for is the max, not the per-channel sum —
        // recorded here for the multi-device validation_s charge below.
        let mut basic_ship_span = 0.0;
        if !optimized {
            let pre_ship = cpu_cursor;
            for lane in &lanes {
                cpu_cursor = cpu_cursor.max(lane.ship_end);
            }
            basic_ship_span = cpu_cursor - pre_ship;
        }
        rs.chunks = lanes.iter().map(|l| l.chunks.len() as u64).sum();
        rs.log_entries_raw = router.raw_appended_total();
        rs.log_entries_shipped = router.shipped_total();
        // Per-device shipped-entry accounting: the load signal behind the
        // `cluster_shard_imbalance` gauge and the rebalancer's window.
        for (d, lane) in lanes.iter_mut().enumerate() {
            let shipped = router.log(d).shipped();
            lane.per_dev.shipped_entries += shipped;
            win_shipped[d] += shipped;
        }
        for lane in &lanes {
            rs.chunks_filtered += lane.chunks_filtered;
            rs.chunks_skipped_post_abort += lane.chunks_skipped;
        }
        let own_conflicts: u64 = lanes.iter().map(|l| l.own_conflicts).sum();

        // --- Validation phase: cross-shard ---------------------------------
        // Hierarchical and batched (never per-access): granule bitmap
        // probes first, word-level scans only on a hit — exactly the
        // existing scheme's escalation, applied pairwise.  Runs on the
        // coordinator thread: it is O(pairs) and needs cross-lane reads.
        let mut cross_conflicts = 0u64;
        if n_dev > 1 && !early_abort {
            // CPU writes applied on shard `o` vs every other device's
            // read-set (a cross-shard GPU read of a CPU-written word).
            for o in 0..n_dev {
                if lanes[o].chunks.is_empty() {
                    continue;
                }
                for d in 0..n_dev {
                    if d == o {
                        continue;
                    }
                    cluster.cross_checks += 1;
                    let (lo, ld) = pair_mut(&mut lanes, o, d);
                    // Probe and escalation run on device `d`: charge them
                    // at that device's rates.
                    let probe = lo.cpu_ws.len() as f64 * costs[d].gpu_validate_entry_s;
                    ld.cursor += probe;
                    ld.gpu_phases.validation_s += probe;
                    ld.per_dev.phases.validation_s += probe;
                    if lo.cpu_ws.intersects(ld.dev.rs_bmp()) {
                        cluster.cross_escalations += 1;
                        // Escalated word-level scan, batched over the
                        // owner's chunks (DESIGN.md §12): per-chunk
                        // integer counts fold in chunk order, so the sum
                        // is bit-identical to the scalar loop.
                        ld.dev.early_validate_chunks_into(&lo.chunks, &mut ld.conf);
                        let n_conf: u64 = ld.conf.iter().map(|&c| u64::from(c)).sum();
                        let vcost = lo.chunks.len() as f64
                            * (chunk_entries as f64 * costs[d].gpu_validate_entry_s);
                        ld.cursor += vcost;
                        ld.gpu_phases.validation_s += vcost;
                        ld.per_dev.phases.validation_s += vcost;
                        cross_conflicts += n_conf;
                    }
                }
            }
            // Device write-sets vs every other device's read/write-sets
            // (cross-shard transactions touching another shard's words).
            for i in 0..n_dev {
                for j in (i + 1)..n_dev {
                    cluster.cross_checks += 1;
                    let (li, lj) = pair_mut(&mut lanes, i, j);
                    // Both devices scan the same operand, each at its own
                    // rate (identical charges on a uniform cluster).
                    let probe_i =
                        li.dev.ws_bmp().len() as f64 * costs[i].gpu_validate_entry_s;
                    let probe_j =
                        li.dev.ws_bmp().len() as f64 * costs[j].gpu_validate_entry_s;
                    li.cursor += probe_i;
                    lj.cursor += probe_j;
                    li.gpu_phases.validation_s += probe_i;
                    lj.gpu_phases.validation_s += probe_j;
                    li.per_dev.phases.validation_s += probe_i;
                    lj.per_dev.phases.validation_s += probe_j;
                    let wr = li.dev.ws_bmp().intersect_count(lj.dev.rs_bmp())
                        + lj.dev.ws_bmp().intersect_count(li.dev.rs_bmp());
                    let ww = li.dev.ws_bmp().intersect_count(lj.dev.ws_bmp());
                    if wr + ww > 0 {
                        cluster.cross_escalations += 1;
                        cross_conflicts += (wr + ww) as u64;
                        // Escalation tier: the word-level exchange rescans
                        // both devices' bitmaps — charge it, like the
                        // CPU-vs-device escalation above.
                        li.cursor += probe_i;
                        lj.cursor += probe_j;
                        li.gpu_phases.validation_s += probe_i;
                        lj.gpu_phases.validation_s += probe_j;
                        li.per_dev.phases.validation_s += probe_i;
                        lj.per_dev.phases.validation_s += probe_j;
                    }
                }
            }
            cluster.cross_conflict_entries += cross_conflicts;
        }

        let conflicts = own_conflicts
            + cross_conflicts
            + if early_abort { early_conf_total } else { 0 };
        rs.conflict_entries = conflicts;
        if own_conflicts == 0 && cross_conflicts > 0 {
            cluster.rounds_aborted_cross_shard += 1;
        }
        let tv = lanes.iter().fold(t0, |m, l| m.max(l.cursor));

        // GPU-side counters fold here (u64, order-free): the loser branch
        // below reads rs.gpu_commits, and no lane commits accrue later.
        for lane in &lanes {
            rs.gpu_commits += lane.gpu_commits;
            rs.gpu_attempts += lane.gpu_attempts;
            rs.gpu_batches += lane.gpu_batches;
        }

        // Non-blocking CPU (§IV-D): keep processing during validation;
        // commits logged for the NEXT round (same rules as RoundEngine).
        if optimized && tv > cpu_cursor && cfg.period_s > 0.0 && !conditional {
            let bonus = tv - cpu_cursor;
            scratch.clear();
            let cs = cpu.run(bonus, scratch);
            carry.extend_from_slice(scratch);
            rs.cpu_commits += cs.commits;
            rs.cpu_attempts += cs.attempts;
            rs.cpu_phases.processing_s += bonus;
            cpu_cursor = tv;
        } else if tv > cpu_cursor {
            rs.cpu_phases.blocked_s += tv - cpu_cursor;
            cpu_cursor = tv;
        }

        // --- Merge phase ---------------------------------------------------
        let ok = conflicts == 0;
        rs.committed = ok;
        let mut round_end;
        if ok {
            if conditional {
                // favor-GPU deferred apply, per owner shard.
                run_lanes(threads, &mut lanes, |d, lane| {
                    for i in 0..lane.chunks.len() {
                        if let Err(e) = lane.dev.validate_chunk(&lane.chunks[i]) {
                            lane.err = Some(format!("deferred apply: {e}"));
                            return;
                        }
                    }
                    let mcost = lane.chunks.len() as f64
                        * (chunk_entries as f64 * costs[d].gpu_validate_entry_s);
                    lane.cursor += mcost;
                    lane.gpu_phases.merge_s += mcost;
                    lane.per_dev.phases.merge_s += mcost;
                });
                if let Some(e) = first_lane_err(&mut lanes) {
                    return Err(anyhow!("{e}"));
                }
            }
            // Per-device DtH scheduling of the GPU write-sets (parallel;
            // the DMA cost keeps the paper's 16 KB coalesced granularity
            // on every device's own channel), then the install into the
            // CPU truth on the coordinator thread in device-index order —
            // the deterministic serialization point of the merge.
            run_lanes(threads, &mut lanes, |d, lane| {
                lane.dev
                    .ws_bmp()
                    .dirty_word_ranges_coarse_into(granule_words, &mut lane.coarse);
                let mut dth_end = lane.cursor;
                for &(s, e) in &lane.coarse {
                    let bytes = ((e - s) * 4) as u64;
                    let dur = costs[d].bus_d2h.transfer_secs(bytes);
                    let (_, end) = lane.d2h.schedule(lane.cursor, dur);
                    dth_end = end;
                    if let Some(o) = &mut lane.obs {
                        o.merge.push(dur);
                    }
                }
                lane.dth_end = dth_end;
            });
            // Data granularity differs by cluster size: a lone device's
            // replica agrees with the CPU everywhere it did not write (all
            // chunks applied locally), so coarse ranges copy only agreeing
            // bytes — the RoundEngine merge.  With n > 1 a replica is only
            // authoritative for what it wrote, so values install at exact
            // dirty granularity.
            let mut dth_end_max = cpu_cursor;
            for lane in &mut lanes {
                if n_dev == 1 {
                    for &(s, e) in &lane.coarse {
                        let data = &lane.dev.stmr()[s..e];
                        cpu.stmr().install_range(s, data);
                    }
                } else {
                    lane.dev.ws_bmp().dirty_word_ranges_into(exact);
                    for &(s, e) in exact.iter() {
                        let data = &lane.dev.stmr()[s..e];
                        cpu.stmr().install_range(s, data);
                    }
                }
                dth_end_max = dth_end_max.max(lane.dth_end);
            }
            if n_dev > 1 {
                // Cross-shard reconciliation: a device replica is stale for
                // CPU writes routed to OTHER owners, so after the installs
                // the CPU's committed values re-win their words (CPU
                // commits serialize after the GPUs', like the carry).
                for e in round_entries.iter() {
                    cpu.stmr().store(e.addr as usize, e.val);
                }
            }
            // Carry-window CPU commits re-win their words locally: they
            // serialize AFTER this round's GPU transactions.
            for e in carry.iter() {
                cpu.stmr().store(e.addr as usize, e.val);
            }
            if optimized {
                // Devices resume immediately; the CPU waits for the last
                // install to land.
                rs.cpu_phases.merge_s += dth_end_max - cpu_cursor;
                *cpu_avail = dth_end_max;
                round_end = lanes.iter().fold(t0, |m, l| m.max(l.cursor));
            } else {
                // Basic: everyone blocked until the transfers complete.
                rs.cpu_phases.merge_s += dth_end_max - cpu_cursor;
                for lane in &mut lanes {
                    lane.gpu_phases.merge_s += dth_end_max - lane.cursor;
                    lane.per_dev.phases.merge_s += dth_end_max - lane.cursor;
                }
                *cpu_avail = dth_end_max;
                round_end = dth_end_max;
            }
        } else {
            rs.discarded_commits = match policy.loser() {
                Loser::Gpu => {
                    let discarded = rs.gpu_commits;
                    rs.gpu_commits = 0;
                    if optimized {
                        // Shadow + per-shard CPU-log replay (§IV-D).
                        run_lanes(threads, &mut lanes, |d, lane| {
                            lane.dev.rollback_with_logs(&lane.chunks);
                            let mcost = lane.chunks.len() as f64
                                * (chunk_entries as f64 * costs[d].gpu_validate_entry_s);
                            lane.cursor += mcost;
                            lane.gpu_phases.merge_s += mcost;
                            lane.per_dev.phases.merge_s += mcost;
                        });
                        round_end = lanes.iter().fold(t0, |m, l| m.max(l.cursor));
                        *cpu_avail = cpu_cursor;
                    } else {
                        // Basic: re-copy every GPU-dirty region from the
                        // CPU truth, per device over its own channel (the
                        // CPU truth is read-only during this phase).
                        {
                            let cpu_stmr = cpu.stmr();
                            run_lanes(threads, &mut lanes, |d, lane| {
                                lane.dev
                                    .ws_bmp()
                                    .dirty_word_ranges_coarse_into(granule_words, &mut lane.coarse);
                                let mut h2d_end = lane.cursor;
                                for &(s, e) in lane.coarse.iter() {
                                    let bytes = ((e - s) * 4) as u64;
                                    let dur = costs[d].bus_h2d.transfer_secs(bytes);
                                    let (_, end) = lane.h2d.schedule(lane.cursor, dur);
                                    h2d_end = end;
                                    for w in s..e {
                                        let v = cpu_stmr.load(w);
                                        lane.dev.stmr_mut()[w] = v;
                                    }
                                }
                                lane.gpu_phases.merge_s += h2d_end - lane.cursor;
                                lane.per_dev.phases.merge_s += h2d_end - lane.cursor;
                                lane.dth_end = h2d_end;
                            });
                        }
                        let mut h2d_end_max = cpu_cursor;
                        for lane in &lanes {
                            h2d_end_max = h2d_end_max.max(lane.dth_end);
                        }
                        rs.cpu_phases.blocked_s += h2d_end_max - cpu_cursor;
                        *cpu_avail = h2d_end_max;
                        round_end = h2d_end_max;
                    }
                    discarded
                }
                Loser::Cpu => {
                    // favor-GPU: roll the CPU back to its round-start
                    // snapshot, then install every device's dirty regions.
                    // Inter-GPU write/write overlaps (possible only with
                    // cross-shard traffic) arbitrate deterministically by
                    // device order on install; every loser device is marked
                    // stale there and converges to the CPU truth at its
                    // next refresh.
                    let discarded = rs.cpu_commits;
                    cpu.rollback();
                    carry.clear();
                    router.truncate_to_carried();
                    let snap_cost = n_bytes as f64 / cost.cpu_snapshot_bytes_per_s;
                    run_lanes(threads, &mut lanes, |d, lane| {
                        lane.dev
                            .ws_bmp()
                            .dirty_word_ranges_coarse_into(granule_words, &mut lane.coarse);
                        let mut dth_end = lane.cursor + snap_cost;
                        for &(s, e) in &lane.coarse {
                            let bytes = ((e - s) * 4) as u64;
                            let dur = costs[d].bus_d2h.transfer_secs(bytes);
                            let (_, end) = lane.d2h.schedule(dth_end, dur);
                            dth_end = end;
                        }
                        lane.dth_end = dth_end;
                    });
                    let mut dth_end_max = cpu_cursor;
                    for lane in &mut lanes {
                        if n_dev == 1 {
                            for &(s, e) in &lane.coarse {
                                let data = &lane.dev.stmr()[s..e];
                                cpu.stmr().install_range(s, data);
                            }
                        } else {
                            lane.dev.ws_bmp().dirty_word_ranges_into(exact);
                            for &(s, e) in exact.iter() {
                                let data = &lane.dev.stmr()[s..e];
                                cpu.stmr().install_range(s, data);
                            }
                        }
                        dth_end_max = dth_end_max.max(lane.dth_end);
                    }
                    rs.cpu_commits = 0;
                    rs.cpu_phases.merge_s += dth_end_max - cpu_cursor;
                    *cpu_avail = dth_end_max;
                    round_end = lanes.iter().fold(t0, |m, l| m.max(l.cursor));
                    discarded
                }
            };
        }

        // --- Round wrap-up -------------------------------------------------
        let cpu_lost = !ok && policy.loser() == Loser::Cpu;
        // Fold this round's write footprint into the durability dirty
        // accumulator while the shard logs, carry, and device write-set
        // bitmaps are still intact (mirrors `RoundEngine::run_round`;
        // over-approximation is safe, so rolled-back writes need no
        // special casing).
        if let Some(hook) = dur.as_mut() {
            for s in 0..router.n_shards() {
                hook.mark_entries(router.log(s).entries());
            }
            hook.mark_entries(carry);
            hook.mark_entries(round_entries);
            for lane in &lanes {
                hook.mark_device(lane.dev.ws_bmp());
            }
        }
        policy.on_round(ok);
        for lane in &mut lanes {
            lane.gpu.on_round_end(ok);
        }

        // Delta-coherence bookkeeping: record what each device must pull
        // from the CPU truth before its next round. No-op at n_gpus = 1.
        if n_dev > 1 {
            if ok || cpu_lost {
                // Surviving device writes: every OTHER device is stale.
                // One reused range scan per device; stale marks are
                // idempotent set-bits, so the per-device interleaving is
                // immaterial to the resulting bitmaps.
                for d in 0..n_dev {
                    lanes[d].dev.ws_bmp().dirty_word_ranges_into(exact);
                    for &(s, e) in exact.iter() {
                        for (o, lane) in lanes.iter_mut().enumerate() {
                            if o == d {
                                continue;
                            }
                            let shift = lane.stale.shift();
                            for g in (s >> shift)..=((e - 1) >> shift) {
                                lane.stale.mark_granule(g);
                            }
                        }
                    }
                }
            }
            if !cpu_lost {
                // CPU writes applied on their owner: non-owners are stale.
                for e in round_entries.iter() {
                    let owner = map.owner(e.addr as usize);
                    for (d, lane) in lanes.iter_mut().enumerate() {
                        if d != owner {
                            lane.stale.mark_word(e.addr as usize);
                        }
                    }
                }
                // Carry values land on the CPU only; every device is stale
                // until the carry re-ships through next round's validation.
                for e in carry.iter() {
                    for lane in lanes.iter_mut() {
                        lane.stale.mark_word(e.addr as usize);
                    }
                }
            }
        }

        // --- Elastic rebalance step (DESIGN.md §14) ------------------------
        // Runs at the quiesced barrier, BEFORE the carry re-scatters, so
        // the freshly installed table governs next round's routing from
        // the first entry (the carried-log remap comes for free).
        // Favor-GPU abort rounds are skipped: `truncate_to_carried` left
        // per-shard carried prefixes scattered under the OLD table, and
        // migrating here would orphan them.  Correctness needs no page
        // copy — every device holds a full replica kept current by the
        // stale-mark protocol above — so the migration charges one
        // modeled bulk DMA on the recipient's H2D channel and installs
        // the next layout epoch.
        *rounds_since_rebal += 1;
        if let Some(rb) = *rebal {
            if !cpu_lost && *rounds_since_rebal >= rb.interval {
                *rounds_since_rebal = 0;
                let heat = router.take_heat();
                let loads: Vec<f64> = win_shipped
                    .iter()
                    .zip(speeds.iter())
                    .map(|(&s, &v)| s as f64 / v)
                    .collect();
                for w in win_shipped.iter_mut() {
                    *w = 0;
                }
                let total: f64 = loads.iter().sum();
                let mut donor = 0usize;
                let mut recipient = 0usize;
                for d in 1..n_dev {
                    if loads[d] > loads[donor] {
                        donor = d;
                    }
                    if loads[d] < loads[recipient] {
                        recipient = d;
                    }
                }
                let mean = total / n_dev as f64;
                if total > 0.0 && donor != recipient && loads[donor] > rb.threshold * mean {
                    // Hottest donor-owned blocks by observed heat (ties to
                    // the lowest block id), capped so the donor keeps at
                    // least one block.
                    let shift = map.shard_bits();
                    let view = map.view();
                    let mut held = 0usize;
                    let mut cand: Vec<(u64, usize)> = Vec::new();
                    for (b, &h) in heat.iter().enumerate() {
                        if view.owner(b << shift) != donor {
                            continue;
                        }
                        held += 1;
                        if h > 0 {
                            cand.push((h, b));
                        }
                    }
                    drop(view);
                    cand.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
                    let take = cand
                        .len()
                        .min(rb.max_granules)
                        .min(held.saturating_sub(1));
                    let blocks: Vec<usize> = cand[..take].iter().map(|&(_, b)| b).collect();
                    if !blocks.is_empty() {
                        // Crash injection BEFORE anything installs: the
                        // simulated death leaves no durable trace of the
                        // migration, and deterministic replay re-makes
                        // the identical decision (`stats.rounds` has not
                        // absorbed this round yet, hence the +1).
                        if let Some(hook) = dur.as_ref() {
                            hook.crash_mid_migration(stats.rounds + 1)?;
                        }
                        let block_words = map.block_words();
                        let mut words = 0usize;
                        for &b in &blocks {
                            let start = b << shift;
                            words += block_words.min(map.n_words() - start);
                        }
                        let bytes = (words * 4) as u64;
                        let dma = cost.bus_h2d.transfer_secs(bytes);
                        let (_, end) = lanes[recipient].h2d.schedule(round_end, dma);
                        round_end = round_end.max(end);
                        map.migrate(&blocks, recipient);
                        cluster.migrations += 1;
                        cluster.granules_moved += blocks.len() as u64;
                        cluster.migrated_bytes += bytes;
                    }
                }
            }
        }

        // Entries carried into the next round (zero when the CPU lost:
        // its branch already cleared the carry).
        let carried = carry.len() as u64;
        if !cpu_lost {
            router.reset_with_carry(carry);
        }
        carry.clear();
        round_entries.clear();

        // Epoch reset, mirroring `RoundEngine::run_round`: every shard
        // log now holds exactly its carried prefix.  Renumber each into
        // 1..=k, restart the shared commit clock at max(k), and clear the
        // per-device freshness arrays — timestamps are only compared
        // within one round and one shard, so results are bit-identical
        // (and identical to the single-device engine at n_gpus = 1, where
        // the solo router reproduces its renumbering exactly).
        let epoch_base = router.rebase_epoch();
        cpu.epoch_reset(epoch_base);
        for lane in &mut lanes {
            lane.dev.epoch_reset();
        }

        // Deterministic fold of the per-lane RoundStats partials, in
        // device-index order.  At n_dev = 1 each field receives exactly
        // one chain of additions (accumulated in the lane in the same
        // order RoundEngine performs them) on top of zero, so the fold
        // preserves bit-identity with the single-device engine.
        for lane in &lanes {
            rs.gpu_phases.add(&lane.gpu_phases);
        }
        // Basic-variant CPU shipping charge: at n_dev = 1 the single
        // lane's per-chunk chain reproduces RoundEngine bit for bit; with
        // more devices the channels overlap, so the CPU is blocked for
        // the overlapped span (summing per-channel durations would charge
        // more time than the round contains).
        if n_dev == 1 {
            rs.cpu_phases.validation_s += lanes[0].cpu_validation_s;
        } else if !optimized {
            rs.cpu_phases.validation_s += basic_ship_span;
        }

        // Telemetry fold: capture the per-lane series in device-index
        // order before the lane borrows are released.  At n_dev = 1 every
        // captured value is bitwise equal to what `RoundEngine` records
        // (single chain, same operation order), so traces and metrics are
        // bit-identical across the two engines.
        let tel_data = tel_on.then(|| {
            let mut dev_phases = Vec::with_capacity(n_dev);
            let mut dev_commits = Vec::with_capacity(n_dev);
            let mut chunk_validate = Vec::with_capacity(n_dev);
            let mut bus_ship = Vec::with_capacity(n_dev);
            let mut bus_merge = Vec::with_capacity(n_dev);
            let mut h2d_busy = Vec::with_capacity(n_dev);
            let mut d2h_busy = Vec::with_capacity(n_dev);
            for lane in &mut lanes {
                dev_phases.push(lane.gpu_phases);
                // Speculative commits as of the verdict: the lane partial
                // is never zeroed by loser discard.
                dev_commits.push(lane.gpu_commits);
                let o = lane.obs.take().unwrap_or_default();
                chunk_validate.push(o.vcost);
                bus_ship.push(o.ship);
                bus_merge.push(o.merge);
                h2d_busy.push(lane.h2d.busy_total());
                d2h_busy.push(lane.d2h.busy_total());
            }
            (
                dev_phases,
                dev_commits,
                chunk_validate,
                bus_ship,
                bus_merge,
                h2d_busy,
                d2h_busy,
            )
        });

        // Retire the round buffers into the engine arena: the routed
        // chunk buffers go back to their shard log's pool (reused by next
        // round's `make_chunk`), the vectors keep their capacity in
        // `lane_bufs` — steady-state rounds allocate nothing (§12).
        for (d, (lane, bufs)) in lanes.iter_mut().zip(lane_bufs.iter_mut()).enumerate() {
            router.recycle(d, &mut lane.chunks);
            lane.arrivals.clear();
            bufs.chunks = std::mem::take(&mut lane.chunks);
            bufs.arrivals = std::mem::take(&mut lane.arrivals);
            bufs.inbox = std::mem::take(&mut lane.inbox);
            bufs.coarse = std::mem::take(&mut lane.coarse);
            bufs.conf = std::mem::take(&mut lane.conf);
        }
        drop(lanes);

        rs.t_end = round_end;
        *t = round_end;
        stats.absorb(&rs);
        if let Some((
            dev_phases,
            dev_commits,
            chunk_validate,
            bus_ship,
            bus_merge,
            h2d_busy,
            d2h_busy,
        )) = &tel_data
        {
            tel.record_round(&RoundObs {
                round: stats.rounds - 1,
                rs: &rs,
                read_only,
                abort_streak: policy.gpu_abort_streak(),
                epoch_base,
                carried,
                dev_phases,
                dev_commits,
                chunk_validate_s: chunk_validate,
                bus_ship_s: bus_ship,
                bus_merge_s: bus_merge,
                h2d_busy_s: h2d_busy,
                d2h_busy_s: d2h_busy,
            });
        }
        // Round-barrier checkpoint (DESIGN.md §13), mirroring
        // `RoundEngine::run_round`: runs after the epoch rebase so each
        // shard log holds exactly the renumbered carried prefix the WAL
        // must copy; zero virtual-time cost, no statistics touched, so
        // durability-on runs stay bit-identical to durability-off runs.
        if let Some(hook) = dur.as_mut().filter(|d| d.due(stats.rounds)) {
            let stats_fnv = crate::durability::stats_digest(stats);
            let carried_shards: Vec<&[WriteEntry]> = (0..router.n_shards())
                .map(|s| router.log(s).entries())
                .collect();
            if let Some(sum) = hook.maybe_checkpoint(
                stats.rounds,
                *t,
                epoch_base,
                &carried_shards,
                cpu.stmr(),
                stats_fnv,
                Some(&map.desc()),
            )? {
                tel.record_checkpoint(&sum);
            }
        }
        if round_log.len() < 10_000 {
            round_log.push(rs);
        }
        Ok(())
    }

    /// Shard `s`'s carried write-log prefix that will seed the next round
    /// (renumbered `ts = 1..=k` by the epoch rebase).  Recovery compares
    /// these against the checkpoint's per-shard WAL copy.
    pub fn carried_entries(&self, s: usize) -> &[WriteEntry] {
        self.router.log(s).entries()
    }
}

/// Disjoint mutable borrows of two lanes (`i != j`), for the pairwise
/// cross-shard checks on the coordinator thread.
fn pair_mut<'l, 'a, G>(
    lanes: &'l mut [Lane<'a, G>],
    i: usize,
    j: usize,
) -> (&'l mut Lane<'a, G>, &'l mut Lane<'a, G>) {
    assert_ne!(i, j, "pair_mut needs distinct lanes");
    if i < j {
        let (a, b) = lanes.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = lanes.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::synth::{SynthCpu, SynthGpu, SynthSpec};
    use crate::config::PolicyKind;
    use crate::gpu::Backend;
    use crate::stm::tinystm::TinyStm;
    use crate::stm::{GlobalClock, SharedStmr};
    use std::sync::Arc;

    fn cluster(n_gpus: usize, cross_shard_prob: f64) -> ClusterEngine<SynthCpu, SynthGpu> {
        let n = 1 << 14;
        let map = ShardMap::new(n, n_gpus, 8); // 256-word blocks
        let stmr = Arc::new(SharedStmr::new(n));
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let cpu = SynthCpu::new(stmr, tm, cpu_spec, 8, 2e-6, 42);
        let mut devices = Vec::new();
        let mut gpus = Vec::new();
        for d in 0..n_gpus {
            let spec = SynthSpec::w1(n, 1.0)
                .partitioned(n / 2..n)
                .homed(map.clone(), d)
                .with_cross_shard(cross_shard_prob);
            devices.push(GpuDevice::new(n, 0, Backend::Native));
            gpus.push(SynthGpu::new(spec, 256, 20e-6, 230e-9, 7 + d as u64));
        }
        let cfg = EngineConfig {
            period_s: 0.004,
            early_validation: false,
            policy: PolicyKind::FavorCpu,
            ..Default::default()
        };
        let mut e = ClusterEngine::new(cfg, CostModel::default(), map, devices, cpu, gpus);
        e.align_replicas();
        e
    }

    #[test]
    fn partitioned_cluster_commits_cleanly() {
        for n_gpus in [1, 2, 4] {
            let mut e = cluster(n_gpus, 0.0);
            e.run_rounds(3).unwrap();
            assert_eq!(e.stats.rounds_committed, 3, "n_gpus={n_gpus}");
            assert!(e.stats.cpu_commits > 0);
            assert!(e.stats.gpu_commits > 0);
            assert_eq!(e.cluster.rounds_aborted_cross_shard, 0);
            // Every device produced work.
            for (d, dev) in e.cluster.per_device.iter().enumerate() {
                assert!(dev.commits > 0, "device {d} idle at n_gpus={n_gpus}");
            }
        }
    }

    #[test]
    fn shard_homing_keeps_writes_on_owned_granules() {
        let mut e = cluster(4, 0.0);
        e.run_rounds(1).unwrap();
        // Inspect each device's write bitmap: every dirty word must be
        // owned by that device (bmp_shift = 0 → word-exact).
        for (d, dev) in e.devices.iter().enumerate() {
            for (s, end) in dev.ws_bmp().dirty_word_ranges() {
                for w in s..end {
                    assert_eq!(e.map.owner(w), d, "device {d} wrote foreign word {w}");
                }
            }
        }
    }

    #[test]
    fn cross_shard_injection_aborts_rounds() {
        let mut e = cluster(2, 0.5);
        e.run_rounds(2).unwrap();
        assert!(e.stats.rounds_committed < 2, "cross-shard writes conflict");
        assert!(e.cluster.cross_checks > 0);
        assert!(e.cluster.cross_conflict_entries > 0);
        assert!(e.cluster.rounds_aborted_cross_shard > 0);
    }

    #[test]
    fn clean_cluster_replicas_converge_after_drain() {
        let mut e = cluster(2, 0.0);
        e.run_rounds(2).unwrap();
        e.drain().unwrap();
        // After a committed drain the CPU holds the global truth; each
        // device agrees on every granule it is NOT marked stale for.
        let truth = e.cpu.stmr().snapshot();
        for (d, dev) in e.devices.iter().enumerate() {
            for (w, &v) in truth.iter().enumerate() {
                if !e.stale[d].test_word(w) {
                    assert_eq!(dev.stmr()[w], v, "device {d} word {w} diverged");
                }
            }
        }
    }

    #[test]
    fn refresh_moves_bytes_only_in_real_clusters() {
        let mut solo = cluster(1, 0.0);
        solo.run_rounds(3).unwrap();
        assert_eq!(solo.cluster.refresh_bytes, 0, "no coherence traffic solo");
        let mut duo = cluster(2, 0.0);
        duo.run_rounds(3).unwrap();
        assert!(duo.cluster.refresh_bytes > 0, "cluster pulls deltas");
    }

    /// Threaded vs sequential bit-identity on a contended cluster (the
    /// cross-shard injection exercises aborts, rollback and the stale
    /// bookkeeping under threads).
    #[test]
    fn threaded_engine_is_bit_identical_to_sequential() {
        for (n_gpus, cross) in [(2usize, 0.0), (4, 0.0), (4, 0.3)] {
            let mut seq = cluster(n_gpus, cross);
            seq.run_rounds(3).unwrap();
            seq.drain().unwrap();

            let mut thr = cluster(n_gpus, cross);
            thr.set_threads(n_gpus);
            assert_eq!(thr.threads(), n_gpus);
            thr.run_rounds(3).unwrap();
            thr.drain().unwrap();

            let label = format!("n_gpus={n_gpus}/cross={cross}");
            assert_eq!(
                format!("{:?}", seq.stats),
                format!("{:?}", thr.stats),
                "{label}: RunStats diverged"
            );
            assert_eq!(
                seq.cpu.stmr().snapshot(),
                thr.cpu.stmr().snapshot(),
                "{label}: CPU state diverged"
            );
            for d in 0..n_gpus {
                assert_eq!(
                    seq.devices[d].stmr(),
                    thr.devices[d].stmr(),
                    "{label}: device {d} replica diverged"
                );
            }
            assert_eq!(
                seq.cluster.cross_checks, thr.cluster.cross_checks,
                "{label}"
            );
            assert_eq!(
                seq.cluster.refresh_bytes, thr.cluster.refresh_bytes,
                "{label}"
            );
        }
    }

    /// Basic-variant tail shipping blocks the CPU for the overlapped span
    /// of the per-device channels (not the per-channel sum): every CPU
    /// second is accounted exactly once at ANY cluster size.
    #[test]
    fn cluster_basic_tail_shipping_accounts_once() {
        for n_gpus in [1usize, 2, 4] {
            let mut e = cluster(n_gpus, 0.0);
            e.cfg.variant = Variant::Basic;
            e.run_rounds(3).unwrap();
            assert!(
                e.stats.cpu_phases.validation_s > 0.0,
                "n_gpus={n_gpus}: basic CPU ships logs while blocked"
            );
            let total = e.stats.cpu_phases.total();
            let dur = e.stats.duration_s;
            assert!(
                (total - dur).abs() < 1e-9 * dur.max(1.0),
                "n_gpus={n_gpus}: cpu phase sum {total} != duration {dur}"
            );
        }
    }

    /// Sharded compaction + filtering: per-shard dedup shrinks shipping,
    /// partitioned chunks filter, and the round outcomes are unchanged —
    /// threaded identically to sequential.
    #[test]
    fn cluster_compaction_and_filter_work_sharded() {
        let mut raw = cluster(2, 0.0);
        raw.run_rounds(3).unwrap();
        let build = |threads: usize| {
            let mut e = cluster(2, 0.0);
            e.cfg.log_compaction = true;
            e.cfg.chunk_filter = true;
            e.router.set_compaction(true);
            e.router.set_sig_shift(Some(0));
            e.set_threads(threads);
            e.run_rounds(3).unwrap();
            e
        };
        let e = build(1);
        assert_eq!(e.stats.rounds_committed, 3);
        assert_eq!(e.stats.log_entries_raw, raw.stats.log_entries_raw);
        assert!(
            e.stats.log_entries_shipped * 2 <= e.stats.log_entries_raw,
            "duplicate-heavy synth log must compact >= 2x: {} of {}",
            e.stats.log_entries_shipped,
            e.stats.log_entries_raw
        );
        assert_eq!(
            e.stats.chunks_filtered, e.stats.chunks,
            "partitioned shards: every chunk provably clean"
        );
        assert!(
            e.cluster.per_device.iter().all(|d| d.chunks_filtered == d.chunks),
            "per-device filter accounting"
        );
        assert!(
            e.stats.gpu_phases.validation_s < raw.stats.gpu_phases.validation_s,
            "filtered validation must be cheaper"
        );
        // Threaded execution stays bit-identical with the new data path.
        let thr = build(2);
        assert_eq!(format!("{:?}", e.stats), format!("{:?}", thr.stats));
        assert_eq!(e.cpu.stmr().snapshot(), thr.cpu.stmr().snapshot());
    }

    #[test]
    fn thread_setting_clamps_and_oversubscribes_safely() {
        let mut e = cluster(2, 0.0);
        e.set_threads(0);
        assert_eq!(e.threads(), 1, "zero clamps to sequential");
        e.set_threads(16); // more threads than devices: one per lane
        e.run_rounds(2).unwrap();
        assert_eq!(e.stats.rounds_committed, 2);
    }

    /// A CPU workload pinned to the first ownership block ships every
    /// entry to one device; the rebalancer must notice and move the hot
    /// block off it at the round barrier.
    #[test]
    fn rebalancer_migrates_hot_blocks_off_the_loaded_device() {
        let n = 1 << 14;
        let map = ShardMap::new(n, 4, 8); // 256-word blocks, 64 blocks
        let stmr = Arc::new(SharedStmr::new(n));
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        // All CPU writes land in block 0, owned (stripe) by device 0.
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..256);
        let cpu = SynthCpu::new(stmr, tm, cpu_spec, 8, 2e-6, 42);
        let mut devices = Vec::new();
        let mut gpus = Vec::new();
        for d in 0..4 {
            let spec = SynthSpec::w1(n, 1.0)
                .partitioned(n / 2..n)
                .homed(map.clone(), d);
            devices.push(GpuDevice::new(n, 0, Backend::Native));
            gpus.push(SynthGpu::new(spec, 256, 20e-6, 230e-9, 7 + d as u64));
        }
        let cfg = EngineConfig {
            period_s: 0.004,
            early_validation: false,
            policy: PolicyKind::FavorCpu,
            ..Default::default()
        };
        let mut e = ClusterEngine::new(cfg, CostModel::default(), map, devices, cpu, gpus);
        e.align_replicas();
        e.set_rebalance(Some(RebalanceCfg { interval: 1, threshold: 1.25, max_granules: 4 }));
        e.run_rounds(3).unwrap();
        assert_eq!(e.stats.rounds_committed, 3);
        // The hot block ping-pongs between donor and recipient under
        // interval = 1, so assert the mechanism fired rather than any
        // particular final owner.
        assert!(e.cluster.migrations >= 1, "hot block never migrated");
        assert!(e.map.epoch() >= 1, "migration must bump the layout epoch");
        assert!(e.cluster.granules_moved >= 1);
        assert!(e.cluster.migrated_bytes > 0, "page shipping must be modeled");
        assert_eq!(e.cluster.rounds_aborted_cross_shard, 0);
    }

    /// `set_dev_speeds(&[1.0, ..])` scales every per-device cost model by
    /// one, which is a bitwise no-op: the run must stay bit-identical to
    /// an engine that never heard of device speeds.
    #[test]
    fn uniform_dev_speeds_are_bit_identical_to_default() {
        let mut base = cluster(4, 0.3);
        base.run_rounds(3).unwrap();
        base.drain().unwrap();
        let mut tuned = cluster(4, 0.3);
        tuned.set_dev_speeds(&[1.0; 4]);
        tuned.run_rounds(3).unwrap();
        tuned.drain().unwrap();
        assert_eq!(format!("{:?}", base.stats), format!("{:?}", tuned.stats));
        assert_eq!(base.cpu.stmr().snapshot(), tuned.cpu.stmr().snapshot());
        for d in 0..4 {
            assert_eq!(
                base.devices[d].stmr(),
                tuned.devices[d].stmr(),
                "device {d} replica"
            );
        }
    }

    /// The per-device shipped-entry gauges partition the run total: their
    /// sum must equal `log_entries_shipped` exactly, and a CPU whose
    /// writes stripe uniformly keeps the imbalance gauge near 1.
    #[test]
    fn per_device_shipped_entries_sum_to_the_total() {
        let mut e = cluster(4, 0.0);
        e.run_rounds(3).unwrap();
        let per_dev: u64 = e.cluster.per_device.iter().map(|d| d.shipped_entries).sum();
        assert_eq!(per_dev, e.stats.log_entries_shipped, "gauges must partition the total");
        assert!(e.stats.log_entries_shipped > 0, "CPU writes must ship");
        assert!(e.cluster.shipped_imbalance() >= 1.0, "max/mean is at least 1");
    }
}
