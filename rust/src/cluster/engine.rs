//! The multi-device synchronization-round engine.
//!
//! [`ClusterEngine`] generalizes [`RoundEngine`] from one simulated
//! accelerator to `N` devices over a sharded STMR:
//!
//! * one CPU side, unchanged: a single guest TM, one commit clock, one
//!   write-entry stream — scattered per-shard by the [`LogRouter`];
//! * per-device round pipelines: each device has its own H2D/D2H
//!   [`BusTimeline`] pair, its own virtual-time cursor, and validates only
//!   the CPU chunks routed to the words it owns, reusing the exact
//!   validation/merge machinery of the single-device engine
//!   ([`GpuDevice::validate_chunk`], shadow rollback, coarse-granule DtH);
//! * cross-shard conflict detection, hierarchical and batched (the
//!   Hechtman & Sorin cost lesson: never per-access): per-pair granule
//!   bitmap intersections first, escalating to a word-level scan only on a
//!   hit — CPU-written granules vs every non-owner device's read-set, and
//!   device write-sets vs every other device's read/write-sets;
//! * delta-coherence refresh: each device tracks which granules OTHER
//!   actors dirtied since it last saw them and pulls just those (coalesced
//!   at the 16 KB merge granule) from the post-merge CPU truth at round
//!   start — batched traffic instead of per-access coherence.
//!
//! **`n_gpus = 1` invariant**: with a [`ShardMap::solo`] map every
//! cluster-only mechanism is provably a no-op (no pairs, empty stale maps,
//! identity routing) and the remaining arithmetic is the same sequence of
//! operations as `RoundEngine::run_round`, so final state and [`RunStats`]
//! are bit-identical on the same seed — asserted by
//! `rust/tests/cluster_equivalence.rs`.
//!
//! MAINTENANCE: `run_round` deliberately *mirrors* (rather than replaces)
//! `RoundEngine::run_round` — the untouched single-device engine is the
//! independent oracle that gives the equivalence test its teeth. A change
//! to either round state machine must be mirrored in the other; the
//! equivalence suite fails loudly when the mirror drifts.
//!
//! [`RoundEngine`]: crate::coordinator::round::RoundEngine

use anyhow::Result;

use super::router::LogRouter;
use super::shard::ShardMap;
use super::stats::ClusterStats;
use crate::bus::BusTimeline;
use crate::coordinator::policy::{Loser, Policy};
use crate::coordinator::round::{CostModel, CpuDriver, EngineConfig, GpuDriver, Variant};
use crate::coordinator::stats::{RoundStats, RunStats};
use crate::gpu::{Bitmap, GpuDevice, LogChunk};
use crate::stm::WriteEntry;

/// The sharded SHeTM cluster engine.
pub struct ClusterEngine<C: CpuDriver, G: GpuDriver> {
    /// Engine configuration (variant, period, policy, ...), shared by all
    /// per-device pipelines.
    pub cfg: EngineConfig,
    /// Cost model used to advance virtual time (same for every device).
    pub cost: CostModel,
    /// Word-range → device ownership.
    pub map: ShardMap,
    /// The simulated accelerators, indexed by shard id.
    pub devices: Vec<GpuDevice>,
    /// The (single) CPU-side driver.
    pub cpu: C,
    /// Per-device GPU drivers, indexed by shard id.
    pub gpus: Vec<G>,
    /// Aggregate statistics, single-device-compatible (totals across
    /// devices; bit-identical to `RoundEngine` at `n_gpus = 1`).
    pub stats: RunStats,
    /// Cluster-only statistics (per-device + cross-shard accounting).
    pub cluster: ClusterStats,
    /// Per-round statistics (most recent rounds, ring-limited).
    pub round_log: Vec<RoundStats>,

    policy: Policy,
    h2d: Vec<BusTimeline>,
    d2h: Vec<BusTimeline>,
    /// Virtual time of the current round's start.
    t: f64,
    /// When the CPU may resume processing (merge install blocks it).
    cpu_avail: f64,
    router: LogRouter,
    carry: Vec<WriteEntry>,
    scratch: Vec<WriteEntry>,
    /// Every entry routed this round (cross-shard merge reconciliation).
    round_entries: Vec<WriteEntry>,
    /// Per-device map of granules dirtied elsewhere since the device last
    /// saw them (drives the round-start delta refresh).
    stale: Vec<Bitmap>,
    /// Per-shard bitmaps of this round's routed CPU writes (cross-shard
    /// probe operands; rebuilt each round).
    cpu_ws: Vec<Bitmap>,
}

impl<C: CpuDriver, G: GpuDriver> ClusterEngine<C, G> {
    /// Assemble a cluster engine; every device's replica must cover the
    /// same STMR as the CPU driver's, and `devices`/`gpus` are indexed by
    /// shard id of `map`.
    pub fn new(
        cfg: EngineConfig,
        cost: CostModel,
        map: ShardMap,
        devices: Vec<GpuDevice>,
        cpu: C,
        gpus: Vec<G>,
    ) -> Self {
        assert!(!devices.is_empty(), "need at least one device");
        assert_eq!(devices.len(), map.n_shards(), "one device per shard");
        assert_eq!(gpus.len(), map.n_shards(), "one GPU driver per shard");
        assert_eq!(
            map.n_words(),
            cpu.stmr().len(),
            "shard map must cover the CPU STMR"
        );
        for d in &devices {
            assert_eq!(
                d.n_words(),
                cpu.stmr().len(),
                "CPU and device replicas must cover the same STMR"
            );
        }
        let n = devices.len();
        let bmp_shift = devices[0].rs_bmp().shift();
        let policy = Policy::new(cfg.policy, cfg.starvation_limit);
        let router = LogRouter::new(map.clone(), cfg.chunk_entries);
        ClusterEngine {
            cfg,
            cost,
            devices,
            cpu,
            gpus,
            stats: RunStats::default(),
            cluster: ClusterStats::new(n),
            round_log: Vec::new(),
            policy,
            h2d: (0..n).map(|_| BusTimeline::new()).collect(),
            d2h: (0..n).map(|_| BusTimeline::new()).collect(),
            t: 0.0,
            cpu_avail: 0.0,
            router,
            carry: Vec::new(),
            scratch: Vec::new(),
            round_entries: Vec::new(),
            stale: (0..n).map(|_| Bitmap::new(map.n_words(), bmp_shift)).collect(),
            cpu_ws: (0..n).map(|_| Bitmap::new(map.n_words(), bmp_shift)).collect(),
            map,
        }
    }

    /// Number of devices in the cluster.
    pub fn n_gpus(&self) -> usize {
        self.devices.len()
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.t
    }

    /// Copy the CPU STMR into every device replica (initial alignment —
    /// all replicas must start from one consistent snapshot, §IV-C.1).
    pub fn align_replicas(&mut self) {
        let snap = self.cpu.stmr().snapshot();
        for d in &mut self.devices {
            d.stmr_mut().copy_from_slice(&snap);
        }
    }

    /// Run `n` synchronization rounds.
    pub fn run_rounds(&mut self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.run_round()?;
        }
        Ok(())
    }

    /// Run rounds until at least `dur_s` of virtual time has elapsed.
    pub fn run_for(&mut self, dur_s: f64) -> Result<()> {
        let end = self.t + dur_s;
        while self.t < end {
            self.run_round()?;
        }
        Ok(())
    }

    /// Quiesce: one zero-length round so carried commits ship and apply
    /// (see `RoundEngine::drain`).
    pub fn drain(&mut self) -> Result<()> {
        let saved = self.cfg.clone();
        self.cfg.period_s = 0.0;
        self.cfg.early_validation = false;
        let r = self.run_round();
        self.cfg = saved;
        r
    }

    /// Execute one synchronization round across all devices.
    pub fn run_round(&mut self) -> Result<()> {
        let optimized = self.cfg.variant == Variant::Optimized;
        let n_dev = self.devices.len();
        let t0 = self.t;
        let mut rs = RoundStats {
            t_start: t0,
            ..Default::default()
        };
        let n_bytes = (self.map.n_words() * 4) as u64;
        let granule_words = (crate::bus::chunking::MERGE_GRANULE_BYTES / 4) as usize;

        self.cpu.set_read_only(self.policy.cpu_read_only());
        if self.policy.conditional_apply() {
            // favor-GPU needs a CPU snapshot to roll back to (fork/COW).
            self.cpu.snapshot();
        }

        // --- Execution phase --------------------------------------------
        let mut gpu_cursor = vec![t0; n_dev];
        for d in 0..n_dev {
            // Delta-coherence refresh (empty at n_gpus = 1): pull granules
            // other actors dirtied, coalesced at the merge granule, from
            // the post-merge CPU truth, over this device's own H2D channel.
            let ranges = self.stale[d].dirty_word_ranges_coarse(granule_words);
            let mut refresh_end = t0;
            for &(s, e) in &ranges {
                let bytes = ((e - s) * 4) as u64;
                let dur = self.cost.bus_h2d.transfer_secs(bytes);
                let (_, end) = self.h2d[d].schedule(t0, dur);
                refresh_end = end;
                let fresh: Vec<i32> = (s..e).map(|w| self.cpu.stmr().load(w)).collect();
                self.devices[d].stmr_mut()[s..e].copy_from_slice(&fresh);
                self.cluster.refresh_bytes += bytes;
                self.cluster.refresh_transfers += 1;
                self.cluster.per_device[d].refresh_bytes += bytes;
                self.cluster.per_device[d].refresh_transfers += 1;
            }
            self.stale[d].clear();

            // Shadow snapshot AFTER the refresh so rollback keeps it.
            self.devices[d].begin_round();
            rs.gpu_phases.merge_s += refresh_end - t0;
            self.cluster.per_device[d].phases.merge_s += refresh_end - t0;
            gpu_cursor[d] = refresh_end;
            if optimized {
                // Shadow copy (DtD) before the device may process (§IV-D).
                let dtd = n_bytes as f64 / self.cost.gpu_dtd_bytes_per_s;
                gpu_cursor[d] += dtd;
                rs.gpu_phases.merge_s += dtd;
                self.cluster.per_device[d].phases.merge_s += dtd;
            }
        }
        let exec_end_target = t0 + self.cfg.period_s;

        let mut chunks: Vec<Vec<LogChunk>> = vec![Vec::new(); n_dev];
        let mut arrivals: Vec<Vec<f64>> = vec![Vec::new(); n_dev];
        let mut early_abort = false;

        let mut cpu_cursor = self.cpu_avail.max(t0);
        rs.cpu_phases.blocked_s += cpu_cursor - t0;
        let segments = if optimized && self.cfg.early_validation {
            self.cfg.early_points + 1
        } else {
            1
        };
        let seg_dur = (exec_end_target - cpu_cursor).max(0.0) / segments as f64;

        for s in 0..segments {
            // CPU slice (real transactions through the guest TM), routed
            // to owner shards as it is logged.
            self.scratch.clear();
            let cs = self.cpu.run(seg_dur, &mut self.scratch);
            self.router.append(&self.scratch);
            if n_dev > 1 {
                // Kept for cross-shard merge reconciliation; never read
                // (so never copied) on the single-device path.
                self.round_entries.extend_from_slice(&self.scratch);
            }
            rs.cpu_commits += cs.commits;
            rs.cpu_attempts += cs.attempts;
            rs.cpu_phases.processing_s += seg_dur;
            cpu_cursor += seg_dur;

            // Per-device GPU slices covering the same virtual span.
            for d in 0..n_dev {
                let budget = (cpu_cursor - gpu_cursor[d]).max(0.0);
                let gs = self.gpus[d].run(&mut self.devices[d], budget)?;
                rs.gpu_commits += gs.commits;
                rs.gpu_attempts += gs.attempts;
                rs.gpu_batches += gs.batches;
                rs.gpu_phases.processing_s += gs.busy_s;
                rs.gpu_phases.blocked_s += (budget - gs.busy_s).max(0.0);
                gpu_cursor[d] = cpu_cursor;
                let dev = &mut self.cluster.per_device[d];
                dev.commits += gs.commits;
                dev.attempts += gs.attempts;
                dev.batches += gs.batches;
                dev.phases.processing_s += gs.busy_s;
                dev.phases.blocked_s += (budget - gs.busy_s).max(0.0);

                // Non-blocking log streaming (§IV-D): ship this shard's
                // full chunks now, on its own bus channel.
                if optimized {
                    let n0 = chunks[d].len();
                    self.router.drain_full_chunks(d, &mut chunks[d]);
                    for c in &chunks[d][n0..] {
                        let dur = self.cost.bus_h2d.transfer_secs(c.wire_bytes());
                        let (_, end) = self.h2d[d].schedule(cpu_cursor, dur);
                        arrivals[d].push(end);
                    }
                }
            }

            // Early validation between segments (§IV-D), per device.
            if optimized && self.cfg.early_validation && s + 1 < segments {
                let mut conf = 0u32;
                for d in 0..n_dev {
                    let arrived = arrivals[d].iter().filter(|&&a| a <= cpu_cursor).count();
                    for c in chunks[d].iter().take(arrived) {
                        conf += self.devices[d].early_validate_chunk(c);
                    }
                    let cost = arrived as f64
                        * self.cfg.chunk_entries as f64
                        * self.cost.gpu_validate_entry_s;
                    gpu_cursor[d] += cost;
                    rs.gpu_phases.validation_s += cost;
                    self.cluster.per_device[d].phases.validation_s += cost;
                }
                if conf > 0 {
                    early_abort = true;
                    rs.early_aborted = true;
                    break;
                }
            }
        }
        let _ = early_abort;

        // Drain the remaining (tail) chunks of every shard.
        for d in 0..n_dev {
            let n0 = chunks[d].len();
            self.router.drain_all(d, &mut chunks[d]);
            for c in &chunks[d][n0..] {
                let dur = self.cost.bus_h2d.transfer_secs(c.wire_bytes());
                let (_, end) = self.h2d[d].schedule(cpu_cursor, dur);
                arrivals[d].push(end);
                if !optimized {
                    // Basic: the CPU is blocked while shipping its logs.
                    rs.cpu_phases.validation_s += dur;
                }
            }
        }

        // --- Validation phase: own shard -----------------------------------
        let conditional = self.policy.conditional_apply();
        let mut own_conflicts = 0u64;
        let chunk_cost = self.cfg.chunk_entries as f64 * self.cost.gpu_validate_entry_s;
        for d in 0..n_dev {
            let mut dev_conf = 0u64;
            for (c, &arr) in chunks[d].iter().zip(&arrivals[d]) {
                let start = arr.max(gpu_cursor[d]);
                rs.gpu_phases.blocked_s += start - gpu_cursor[d];
                self.cluster.per_device[d].phases.blocked_s += start - gpu_cursor[d];
                dev_conf += if conditional {
                    // favor-GPU: check without applying (§IV-E).
                    u64::from(self.devices[d].early_validate_chunk(c))
                } else {
                    u64::from(self.devices[d].validate_chunk(c)?)
                };
                gpu_cursor[d] = start + chunk_cost;
                rs.gpu_phases.validation_s += chunk_cost;
                self.cluster.per_device[d].phases.validation_s += chunk_cost;
            }
            self.cluster.per_device[d].chunks += chunks[d].len() as u64;
            self.cluster.per_device[d].conflict_entries += dev_conf;
            own_conflicts += dev_conf;
        }
        rs.chunks = chunks.iter().map(|c| c.len() as u64).sum();

        // --- Validation phase: cross-shard ---------------------------------
        // Hierarchical and batched (never per-access): granule bitmap
        // probes first, word-level scans only on a hit — exactly the
        // existing scheme's escalation, applied pairwise.
        let mut cross_conflicts = 0u64;
        if n_dev > 1 {
            for b in &mut self.cpu_ws {
                b.clear();
            }
            for (o, shard_chunks) in chunks.iter().enumerate() {
                for c in shard_chunks {
                    for &a in &c.addrs {
                        if a >= 0 {
                            self.cpu_ws[o].mark_word(a as usize);
                        }
                    }
                }
            }
            // CPU writes applied on shard `o` vs every other device's
            // read-set (a cross-shard GPU read of a CPU-written word).
            for o in 0..n_dev {
                if chunks[o].is_empty() {
                    continue;
                }
                for d in 0..n_dev {
                    if d == o {
                        continue;
                    }
                    self.cluster.cross_checks += 1;
                    let probe =
                        self.cpu_ws[o].len() as f64 * self.cost.gpu_validate_entry_s;
                    gpu_cursor[d] += probe;
                    rs.gpu_phases.validation_s += probe;
                    self.cluster.per_device[d].phases.validation_s += probe;
                    if self.cpu_ws[o].intersects(self.devices[d].rs_bmp()) {
                        self.cluster.cross_escalations += 1;
                        let mut n_conf = 0u64;
                        for c in &chunks[o] {
                            n_conf += u64::from(self.devices[d].early_validate_chunk(c));
                        }
                        let cost = chunks[o].len() as f64 * chunk_cost;
                        gpu_cursor[d] += cost;
                        rs.gpu_phases.validation_s += cost;
                        self.cluster.per_device[d].phases.validation_s += cost;
                        cross_conflicts += n_conf;
                    }
                }
            }
            // Device write-sets vs every other device's read/write-sets
            // (cross-shard transactions touching another shard's words).
            for i in 0..n_dev {
                for j in (i + 1)..n_dev {
                    self.cluster.cross_checks += 1;
                    let probe =
                        self.devices[i].ws_bmp().len() as f64 * self.cost.gpu_validate_entry_s;
                    gpu_cursor[i] += probe;
                    gpu_cursor[j] += probe;
                    rs.gpu_phases.validation_s += 2.0 * probe;
                    self.cluster.per_device[i].phases.validation_s += probe;
                    self.cluster.per_device[j].phases.validation_s += probe;
                    let wr = self.devices[i].ws_bmp().intersect_count(self.devices[j].rs_bmp())
                        + self.devices[j].ws_bmp().intersect_count(self.devices[i].rs_bmp());
                    let ww =
                        self.devices[i].ws_bmp().intersect_count(self.devices[j].ws_bmp());
                    if wr + ww > 0 {
                        self.cluster.cross_escalations += 1;
                        cross_conflicts += (wr + ww) as u64;
                        // Escalation tier: the word-level exchange rescans
                        // both devices' bitmaps — charge it, like the
                        // CPU-vs-device escalation above.
                        gpu_cursor[i] += probe;
                        gpu_cursor[j] += probe;
                        rs.gpu_phases.validation_s += 2.0 * probe;
                        self.cluster.per_device[i].phases.validation_s += probe;
                        self.cluster.per_device[j].phases.validation_s += probe;
                    }
                }
            }
            self.cluster.cross_conflict_entries += cross_conflicts;
        }

        let conflicts = own_conflicts + cross_conflicts;
        rs.conflict_entries = conflicts;
        if own_conflicts == 0 && cross_conflicts > 0 {
            self.cluster.rounds_aborted_cross_shard += 1;
        }
        let tv = gpu_cursor.iter().copied().fold(t0, f64::max);

        // Non-blocking CPU (§IV-D): keep processing during validation;
        // commits logged for the NEXT round (same rules as RoundEngine).
        if optimized && tv > cpu_cursor && self.cfg.period_s > 0.0 && !conditional {
            let bonus = tv - cpu_cursor;
            self.scratch.clear();
            let cs = self.cpu.run(bonus, &mut self.scratch);
            self.carry.extend_from_slice(&self.scratch);
            rs.cpu_commits += cs.commits;
            rs.cpu_attempts += cs.attempts;
            rs.cpu_phases.processing_s += bonus;
            cpu_cursor = tv;
        } else if tv > cpu_cursor {
            rs.cpu_phases.blocked_s += tv - cpu_cursor;
            cpu_cursor = tv;
        }

        // --- Merge phase ---------------------------------------------------
        let ok = conflicts == 0;
        rs.committed = ok;
        let round_end;
        if ok {
            if conditional {
                // favor-GPU deferred apply, per owner shard.
                for d in 0..n_dev {
                    for c in &chunks[d] {
                        self.devices[d].validate_chunk(c)?;
                    }
                    let cost = chunks[d].len() as f64 * chunk_cost;
                    gpu_cursor[d] += cost;
                    rs.gpu_phases.merge_s += cost;
                    self.cluster.per_device[d].phases.merge_s += cost;
                }
            }
            // Per-device DtH install of the GPU write-sets. The DMA cost
            // keeps the paper's 16 KB coalesced granularity on every
            // device's own channel. Data granularity differs by cluster
            // size: a lone device's replica agrees with the CPU everywhere
            // it did not write (all chunks applied locally), so coarse
            // ranges copy only agreeing bytes — the RoundEngine merge.
            // With n > 1 a replica is only authoritative for what it
            // wrote, so values install at exact dirty granularity.
            let mut dth_end_max = cpu_cursor;
            for d in 0..n_dev {
                let coarse = self.devices[d].ws_bmp().dirty_word_ranges_coarse(granule_words);
                let mut dth_end = gpu_cursor[d];
                for &(s, e) in &coarse {
                    let bytes = ((e - s) * 4) as u64;
                    let dur = self.cost.bus_d2h.transfer_secs(bytes);
                    let (_, end) = self.d2h[d].schedule(gpu_cursor[d], dur);
                    dth_end = end;
                }
                if n_dev == 1 {
                    for &(s, e) in &coarse {
                        let data = &self.devices[d].stmr()[s..e];
                        self.cpu.stmr().install_range(s, data);
                    }
                } else {
                    let exact = self.devices[d].ws_bmp().dirty_word_ranges();
                    for &(s, e) in &exact {
                        let data = &self.devices[d].stmr()[s..e];
                        self.cpu.stmr().install_range(s, data);
                    }
                }
                dth_end_max = dth_end_max.max(dth_end);
            }
            if n_dev > 1 {
                // Cross-shard reconciliation: a device replica is stale for
                // CPU writes routed to OTHER owners, so after the installs
                // the CPU's committed values re-win their words (CPU
                // commits serialize after the GPUs', like the carry).
                for e in &self.round_entries {
                    self.cpu.stmr().store(e.addr as usize, e.val);
                }
            }
            // Carry-window CPU commits re-win their words locally: they
            // serialize AFTER this round's GPU transactions.
            for e in &self.carry {
                self.cpu.stmr().store(e.addr as usize, e.val);
            }
            if optimized {
                // Devices resume immediately; the CPU waits for the last
                // install to land.
                rs.cpu_phases.merge_s += dth_end_max - cpu_cursor;
                self.cpu_avail = dth_end_max;
                round_end = gpu_cursor.iter().copied().fold(t0, f64::max);
            } else {
                // Basic: everyone blocked until the transfers complete.
                rs.cpu_phases.merge_s += dth_end_max - cpu_cursor;
                for d in 0..n_dev {
                    rs.gpu_phases.merge_s += dth_end_max - gpu_cursor[d];
                    self.cluster.per_device[d].phases.merge_s += dth_end_max - gpu_cursor[d];
                }
                self.cpu_avail = dth_end_max;
                round_end = dth_end_max;
            }
        } else {
            rs.discarded_commits = match self.policy.loser() {
                Loser::Gpu => {
                    let discarded = rs.gpu_commits;
                    rs.gpu_commits = 0;
                    if optimized {
                        // Shadow + per-shard CPU-log replay (§IV-D).
                        for d in 0..n_dev {
                            self.devices[d].rollback_with_logs(&chunks[d]);
                            let cost = chunks[d].len() as f64 * chunk_cost;
                            gpu_cursor[d] += cost;
                            rs.gpu_phases.merge_s += cost;
                            self.cluster.per_device[d].phases.merge_s += cost;
                        }
                        round_end = gpu_cursor.iter().copied().fold(t0, f64::max);
                        self.cpu_avail = cpu_cursor;
                    } else {
                        // Basic: re-copy every GPU-dirty region from the
                        // CPU truth, per device over its own channel.
                        let mut h2d_end_max = cpu_cursor;
                        for d in 0..n_dev {
                            let ranges =
                                self.devices[d].ws_bmp().dirty_word_ranges_coarse(granule_words);
                            let mut h2d_end = gpu_cursor[d];
                            for &(s, e) in &ranges {
                                let bytes = ((e - s) * 4) as u64;
                                let dur = self.cost.bus_h2d.transfer_secs(bytes);
                                let (_, end) = self.h2d[d].schedule(gpu_cursor[d], dur);
                                h2d_end = end;
                                for w in s..e {
                                    let v = self.cpu.stmr().load(w);
                                    self.devices[d].stmr_mut()[w] = v;
                                }
                            }
                            rs.gpu_phases.merge_s += h2d_end - gpu_cursor[d];
                            self.cluster.per_device[d].phases.merge_s += h2d_end - gpu_cursor[d];
                            h2d_end_max = h2d_end_max.max(h2d_end);
                        }
                        rs.cpu_phases.blocked_s += h2d_end_max - cpu_cursor;
                        self.cpu_avail = h2d_end_max;
                        round_end = h2d_end_max;
                    }
                    discarded
                }
                Loser::Cpu => {
                    // favor-GPU: roll the CPU back to its round-start
                    // snapshot, then install every device's dirty regions.
                    // Inter-GPU write/write overlaps (possible only with
                    // cross-shard traffic) arbitrate deterministically by
                    // device order on install; every loser device is marked
                    // stale there and converges to the CPU truth at its
                    // next refresh.
                    let discarded = rs.cpu_commits;
                    self.cpu.rollback();
                    self.carry.clear();
                    self.router.truncate_to_carried();
                    let snap_cost = n_bytes as f64 / self.cost.cpu_snapshot_bytes_per_s;
                    let mut dth_end_max = cpu_cursor;
                    for d in 0..n_dev {
                        let coarse =
                            self.devices[d].ws_bmp().dirty_word_ranges_coarse(granule_words);
                        let mut dth_end = gpu_cursor[d] + snap_cost;
                        for &(s, e) in &coarse {
                            let bytes = ((e - s) * 4) as u64;
                            let dur = self.cost.bus_d2h.transfer_secs(bytes);
                            let (_, end) = self.d2h[d].schedule(dth_end, dur);
                            dth_end = end;
                        }
                        if n_dev == 1 {
                            for &(s, e) in &coarse {
                                let data = &self.devices[d].stmr()[s..e];
                                self.cpu.stmr().install_range(s, data);
                            }
                        } else {
                            let exact = self.devices[d].ws_bmp().dirty_word_ranges();
                            for &(s, e) in &exact {
                                let data = &self.devices[d].stmr()[s..e];
                                self.cpu.stmr().install_range(s, data);
                            }
                        }
                        dth_end_max = dth_end_max.max(dth_end);
                    }
                    rs.cpu_commits = 0;
                    rs.cpu_phases.merge_s += dth_end_max - cpu_cursor;
                    self.cpu_avail = dth_end_max;
                    round_end = gpu_cursor.iter().copied().fold(t0, f64::max);
                    discarded
                }
            };
        }

        // --- Round wrap-up -------------------------------------------------
        let cpu_lost = !ok && self.policy.loser() == Loser::Cpu;
        self.policy.on_round(ok);
        for d in 0..n_dev {
            self.gpus[d].on_round_end(ok);
        }

        // Delta-coherence bookkeeping: record what each device must pull
        // from the CPU truth before its next round. No-op at n_gpus = 1.
        if n_dev > 1 {
            if ok || cpu_lost {
                // Surviving device writes: every OTHER device is stale.
                for d in 0..n_dev {
                    let exact = self.devices[d].ws_bmp().dirty_word_ranges();
                    for &(s, e) in &exact {
                        for o in 0..n_dev {
                            if o == d {
                                continue;
                            }
                            let shift = self.stale[o].shift();
                            for g in (s >> shift)..=((e - 1) >> shift) {
                                self.stale[o].mark_granule(g);
                            }
                        }
                    }
                }
            }
            if !cpu_lost {
                // CPU writes applied on their owner: non-owners are stale.
                for e in &self.round_entries {
                    let owner = self.map.owner(e.addr as usize);
                    for d in 0..n_dev {
                        if d != owner {
                            self.stale[d].mark_word(e.addr as usize);
                        }
                    }
                }
                // Carry values land on the CPU only; every device is stale
                // until the carry re-ships through next round's validation.
                for e in &self.carry {
                    for bmp in &mut self.stale {
                        bmp.mark_word(e.addr as usize);
                    }
                }
            }
        }

        if !cpu_lost {
            self.router.reset_with_carry(&self.carry);
        }
        self.carry.clear();
        self.round_entries.clear();
        rs.t_end = round_end;
        self.t = round_end;
        self.stats.absorb(&rs);
        if self.round_log.len() < 10_000 {
            self.round_log.push(rs);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::synth::{SynthCpu, SynthGpu, SynthSpec};
    use crate::config::PolicyKind;
    use crate::gpu::Backend;
    use crate::stm::tinystm::TinyStm;
    use crate::stm::{GlobalClock, SharedStmr};
    use std::sync::Arc;

    fn cluster(n_gpus: usize, cross_shard_prob: f64) -> ClusterEngine<SynthCpu, SynthGpu> {
        let n = 1 << 14;
        let map = ShardMap::new(n, n_gpus, 8); // 256-word blocks
        let stmr = Arc::new(SharedStmr::new(n));
        let tm = Arc::new(TinyStm::with_clock(Arc::new(GlobalClock::new())));
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let cpu = SynthCpu::new(stmr, tm, cpu_spec, 8, 2e-6, 42);
        let mut devices = Vec::new();
        let mut gpus = Vec::new();
        for d in 0..n_gpus {
            let spec = SynthSpec::w1(n, 1.0)
                .partitioned(n / 2..n)
                .homed(map.clone(), d)
                .with_cross_shard(cross_shard_prob);
            devices.push(GpuDevice::new(n, 0, Backend::Native));
            gpus.push(SynthGpu::new(spec, 256, 20e-6, 230e-9, 7 + d as u64));
        }
        let cfg = EngineConfig {
            period_s: 0.004,
            early_validation: false,
            policy: PolicyKind::FavorCpu,
            ..Default::default()
        };
        let mut e = ClusterEngine::new(cfg, CostModel::default(), map, devices, cpu, gpus);
        e.align_replicas();
        e
    }

    #[test]
    fn partitioned_cluster_commits_cleanly() {
        for n_gpus in [1, 2, 4] {
            let mut e = cluster(n_gpus, 0.0);
            e.run_rounds(3).unwrap();
            assert_eq!(e.stats.rounds_committed, 3, "n_gpus={n_gpus}");
            assert!(e.stats.cpu_commits > 0);
            assert!(e.stats.gpu_commits > 0);
            assert_eq!(e.cluster.rounds_aborted_cross_shard, 0);
            // Every device produced work.
            for (d, dev) in e.cluster.per_device.iter().enumerate() {
                assert!(dev.commits > 0, "device {d} idle at n_gpus={n_gpus}");
            }
        }
    }

    #[test]
    fn shard_homing_keeps_writes_on_owned_granules() {
        let mut e = cluster(4, 0.0);
        e.run_rounds(1).unwrap();
        // Inspect each device's write bitmap: every dirty word must be
        // owned by that device (bmp_shift = 0 → word-exact).
        for (d, dev) in e.devices.iter().enumerate() {
            for (s, end) in dev.ws_bmp().dirty_word_ranges() {
                for w in s..end {
                    assert_eq!(e.map.owner(w), d, "device {d} wrote foreign word {w}");
                }
            }
        }
    }

    #[test]
    fn cross_shard_injection_aborts_rounds() {
        let mut e = cluster(2, 0.5);
        e.run_rounds(2).unwrap();
        assert!(e.stats.rounds_committed < 2, "cross-shard writes conflict");
        assert!(e.cluster.cross_checks > 0);
        assert!(e.cluster.cross_conflict_entries > 0);
        assert!(e.cluster.rounds_aborted_cross_shard > 0);
    }

    #[test]
    fn clean_cluster_replicas_converge_after_drain() {
        let mut e = cluster(2, 0.0);
        e.run_rounds(2).unwrap();
        e.drain().unwrap();
        // After a committed drain the CPU holds the global truth; each
        // device agrees on every granule it is NOT marked stale for.
        let truth = e.cpu.stmr().snapshot();
        for (d, dev) in e.devices.iter().enumerate() {
            for (w, &v) in truth.iter().enumerate() {
                if !e.stale[d].test_word(w) {
                    assert_eq!(dev.stmr()[w], v, "device {d} word {w} diverged");
                }
            }
        }
    }

    #[test]
    fn refresh_moves_bytes_only_in_real_clusters() {
        let mut solo = cluster(1, 0.0);
        solo.run_rounds(3).unwrap();
        assert_eq!(solo.cluster.refresh_bytes, 0, "no coherence traffic solo");
        let mut duo = cluster(2, 0.0);
        duo.run_rounds(3).unwrap();
        assert!(duo.cluster.refresh_bytes > 0, "cluster pulls deltas");
    }
}
