//! Multi-GPU cluster coordinator: a sharded STM region across N devices.
//!
//! The paper's SHeTM runs one CPU against one discrete GPU and names
//! multi-GPU support as its key scaling direction; this subsystem is that
//! step.  The STMR is cut into blocks striped across `N` simulated
//! devices, and the single-device synchronization round generalizes to a
//! per-device pipeline fleet under one CPU:
//!
//! * [`shard::ShardLayout`] — versioned word-range → device ownership
//!   (configurable via `cluster.n_gpus` / `cluster.shard_bits`): an
//!   explicit block → device table with a monotone layout epoch, striped
//!   by default, load-proportional under per-device speed factors
//!   (`cluster.dev_speed`), and rewritten online by the round-barrier
//!   rebalancer (`cluster.rebalance`, DESIGN.md §14);
//! * [`router::LogRouter`] — scatters the CPU write-set stream to owner
//!   shards, chunking per device over per-device bus channels;
//! * [`engine::ClusterEngine`] — drives the per-device round pipelines
//!   (sequentially, or concurrently on `cluster.threads` OS threads —
//!   bit-identical either way, DESIGN.md §8), reusing the single-device
//!   validation/merge machinery per shard and adding pairwise
//!   cross-shard conflict detection (granule bitmaps first, word-level
//!   escalation on a hit) plus a batched delta-coherence refresh —
//!   cross-device coherence is expensive (Hechtman & Sorin), so
//!   everything stays hierarchical and batched;
//! * [`stats::ClusterStats`] — per-device breakdowns and cross-shard
//!   abort accounting.
//!
//! `n_gpus = 1` degenerates to the existing single-device behavior
//! bit-for-bit (asserted by `rust/tests/cluster_equivalence.rs`), so all
//! paper-reproduction results are preserved.  See DESIGN.md §6.

pub mod engine;
pub mod router;
pub mod shard;
pub mod stats;

pub use engine::{ClusterEngine, RebalanceCfg};
pub use router::LogRouter;
pub use shard::{LayoutDesc, LayoutView, ShardLayout, ShardMap};
pub use stats::{ClusterStats, DeviceStats};
