//! Cluster-level metrics: per-device breakdowns and cross-shard accounting.
//!
//! The cluster engine keeps the single-device [`RunStats`] semantics for
//! everything the existing tooling consumes (totals across devices land in
//! `ClusterEngine::stats`, bit-identical to `RoundEngine` at `n_gpus = 1`),
//! and adds the numbers that only exist once the region is sharded: which
//! device did the work, how often the pairwise cross-shard checks fired
//! and escalated, how many aborts were caused purely by cross-shard
//! traffic, and what the delta-coherence refresh cost on the buses.
//!
//! [`RunStats`]: crate::coordinator::stats::RunStats

use crate::coordinator::stats::PhaseBreakdown;

/// Aggregate statistics for one device of the cluster.
#[derive(Debug, Clone, Default)]
pub struct DeviceStats {
    /// Transactions whose speculative commit survived, on this device.
    pub commits: u64,
    /// Execution attempts on this device.
    pub attempts: u64,
    /// Kernel activations on this device.
    pub batches: u64,
    /// CPU log chunks routed to and validated on this device.
    pub chunks: u64,
    /// Chunks this device skipped through the signature prefilter
    /// (`hetm.chunk_filter`).
    pub chunks_filtered: u64,
    /// Conflicting entries its own-shard validation found.
    pub conflict_entries: u64,
    /// Phase breakdown for this device.
    pub phases: PhaseBreakdown,
    /// Bytes pulled by the delta-coherence refresh.
    pub refresh_bytes: u64,
    /// Refresh DMAs issued.
    pub refresh_transfers: u64,
    /// CPU log entries shipped to this device (drained into chunks) —
    /// the load signal behind the `cluster_shard_imbalance` gauge and
    /// the elastic rebalancer's observation window.
    pub shipped_entries: u64,
}

/// Aggregate cluster statistics over a run.
#[derive(Debug, Clone, Default)]
pub struct ClusterStats {
    /// Per-device aggregates, indexed by shard id.
    pub per_device: Vec<DeviceStats>,
    /// Pairwise cross-shard probes performed (bitmap-level, cheap).
    pub cross_checks: u64,
    /// Probes whose granule bitmaps intersected, escalating to the
    /// word-level scan (the hierarchical scheme's expensive tier).
    pub cross_escalations: u64,
    /// Conflicting entries/granules found by cross-shard detection.
    pub cross_conflict_entries: u64,
    /// Rounds aborted ONLY because of cross-shard conflicts (their
    /// own-shard validations were clean).
    pub rounds_aborted_cross_shard: u64,
    /// Total bytes moved by the delta-coherence refresh.
    pub refresh_bytes: u64,
    /// Total refresh DMAs issued.
    pub refresh_transfers: u64,
    /// Layout migrations the round-barrier rebalancer installed.
    pub migrations: u64,
    /// Ownership blocks moved across those migrations.
    pub granules_moved: u64,
    /// Bytes the migration DMAs shipped (modeled bulk page copies).
    pub migrated_bytes: u64,
}

impl ClusterStats {
    /// Zeroed stats for an `n_shards`-device cluster.
    pub fn new(n_shards: usize) -> Self {
        ClusterStats {
            per_device: vec![DeviceStats::default(); n_shards],
            ..Default::default()
        }
    }

    /// Fraction of `rounds` aborted purely by cross-shard conflicts.
    pub fn cross_shard_abort_rate(&self, rounds: u64) -> f64 {
        if rounds == 0 {
            0.0
        } else {
            self.rounds_aborted_cross_shard as f64 / rounds as f64
        }
    }

    /// Max/mean ratio of per-device shipped entries (the
    /// `cluster_shard_imbalance` gauge): `1.0` is a perfectly balanced
    /// cluster, `n_shards` means every entry landed on one device, and
    /// `0.0` means nothing has shipped yet.
    pub fn shipped_imbalance(&self) -> f64 {
        let max = self
            .per_device
            .iter()
            .map(|d| d.shipped_entries)
            .max()
            .unwrap_or(0);
        let total: u64 = self.per_device.iter().map(|d| d.shipped_entries).sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / self.per_device.len() as f64;
        max as f64 / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sizes_per_device() {
        let s = ClusterStats::new(4);
        assert_eq!(s.per_device.len(), 4);
        assert_eq!(s.cross_checks, 0);
    }

    #[test]
    fn shipped_imbalance_is_max_over_mean() {
        let mut s = ClusterStats::new(4);
        assert_eq!(s.shipped_imbalance(), 0.0, "no traffic yet");
        for (d, n) in [(0usize, 70u64), (1, 10), (2, 10), (3, 10)] {
            s.per_device[d].shipped_entries = n;
        }
        // max = 70, mean = 25 -> 2.8
        assert!((s.shipped_imbalance() - 2.8).abs() < 1e-12);
        for d in &mut s.per_device {
            d.shipped_entries = 25;
        }
        assert!((s.shipped_imbalance() - 1.0).abs() < 1e-12, "balanced");
    }

    #[test]
    fn cross_shard_abort_rate_guards_zero() {
        let mut s = ClusterStats::new(2);
        assert_eq!(s.cross_shard_abort_rate(0), 0.0);
        s.rounds_aborted_cross_shard = 3;
        assert!((s.cross_shard_abort_rate(12) - 0.25).abs() < 1e-12);
    }
}
