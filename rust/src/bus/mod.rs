//! PCIe interconnect model (DESIGN.md §2 substitution table).
//!
//! The paper's CPU↔GPU traffic crosses PCIe 3.0; every SHeTM design choice
//! about synchronization rounds exists to amortize that bus.  Here the bus
//! is a latency + bandwidth cost model with explicit transfer scheduling:
//!
//! * [`BusModel::transfer_secs`] — the cost shape `latency + bytes/BW`;
//! * [`BusTimeline`] — a single-resource scheduler used by the
//!   discrete-event engine: transfers on the same direction serialize, and
//!   the *blocking* optimizations of §IV-D fall out of who waits on which
//!   completion time;
//! * chunking helpers reproducing the paper's coarse-grained transfers
//!   (48 KB write-log chunks, 16 KB bitmap-granularity merges).
//!
//! Defaults approximate PCIe 3.0 x16: ~12 GB/s effective, ~8 µs per-DMA
//! latency.

/// Cost model for one direction of the interconnect.
#[derive(Debug, Clone, Copy)]
pub struct BusModel {
    /// Fixed per-transfer latency in seconds (DMA setup + PCIe round trip).
    pub latency_s: f64,
    /// Sustained bandwidth in bytes/second.
    pub bytes_per_s: f64,
}

impl Default for BusModel {
    fn default() -> Self {
        BusModel {
            latency_s: 8e-6,
            bytes_per_s: 12.0e9,
        }
    }
}

impl BusModel {
    /// Time to move `bytes` in one DMA.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_s
    }

    /// Time to move `bytes` split into `ceil(bytes/chunk)` DMAs — each
    /// chunk pays the fixed latency, which is why the paper coalesces
    /// transfers (§IV-D).
    pub fn chunked_transfer_secs(&self, bytes: u64, chunk: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let n = bytes.div_ceil(chunk);
        n as f64 * self.latency_s + bytes as f64 / self.bytes_per_s
    }
}

/// A serially-reusable transfer resource with an availability time, for the
/// discrete-event engine.  Each direction of the bus gets its own timeline
/// (PCIe is full duplex), as does the GPU compute "stream".
#[derive(Debug, Clone, Default)]
pub struct BusTimeline {
    free_at: f64,
    busy_total: f64,
}

impl BusTimeline {
    /// New timeline, free at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Earliest time the resource is free.
    pub fn free_at(&self) -> f64 {
        self.free_at
    }

    /// Schedule a task of `dur` seconds no earlier than `earliest`;
    /// returns (start, end) and advances the availability time.
    pub fn schedule(&mut self, earliest: f64, dur: f64) -> (f64, f64) {
        let start = self.free_at.max(earliest);
        let end = start + dur;
        self.free_at = end;
        self.busy_total += dur;
        (start, end)
    }

    /// Total busy seconds accumulated (utilization accounting).
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }

    /// Reset to an idle timeline at time `t`.
    pub fn reset(&mut self, t: f64) {
        self.free_at = t;
        self.busy_total = 0.0;
    }
}

/// Paper constants for transfer granularities (§IV-D).
pub mod chunking {
    /// CPU write-set logs ship to the GPU in 48 KB chunks.
    pub const LOG_CHUNK_BYTES: u64 = 48 * 1024;
    /// The GPU write-set bitmap tracks updates at 16 KB granularity for
    /// merge-phase transfers.
    pub const MERGE_GRANULE_BYTES: u64 = 16 * 1024;
    /// Bytes of one CPU write-log record (addr + value + timestamp).
    pub const LOG_RECORD_BYTES: u64 = 12;

    /// Log entries per 48 KB chunk.
    pub const LOG_CHUNK_ENTRIES: usize = (LOG_CHUNK_BYTES / LOG_RECORD_BYTES) as usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_shape() {
        let bus = BusModel {
            latency_s: 1e-5,
            bytes_per_s: 1e9,
        };
        let small = bus.transfer_secs(1);
        let big = bus.transfer_secs(1_000_000);
        assert!(small >= 1e-5 && small < 1.1e-5, "latency-dominated");
        assert!((big - (1e-5 + 1e-3)).abs() < 1e-12, "bandwidth-dominated");
    }

    #[test]
    fn chunking_pays_latency_per_chunk() {
        let bus = BusModel {
            latency_s: 1e-5,
            bytes_per_s: 1e9,
        };
        let coalesced = bus.transfer_secs(10_000);
        let chunked = bus.chunked_transfer_secs(10_000, 1_000);
        assert!(chunked > coalesced);
        assert!((chunked - coalesced - 9e-5).abs() < 1e-12, "9 extra DMAs");
        assert_eq!(bus.chunked_transfer_secs(0, 1_000), 0.0);
    }

    #[test]
    fn timeline_serializes_and_tracks_busy() {
        let mut t = BusTimeline::new();
        let (s1, e1) = t.schedule(0.0, 2.0);
        assert_eq!((s1, e1), (0.0, 2.0));
        // Requested earlier than free -> waits.
        let (s2, e2) = t.schedule(1.0, 1.0);
        assert_eq!((s2, e2), (2.0, 3.0));
        // Requested later than free -> idles until then.
        let (s3, _) = t.schedule(10.0, 0.5);
        assert_eq!(s3, 10.0);
        assert!((t.busy_total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn paper_chunk_constants() {
        assert_eq!(chunking::LOG_CHUNK_ENTRIES, 4096);
    }
}
