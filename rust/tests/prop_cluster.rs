//! Property tests for the cluster sharding layer (ShardMap / LogRouter),
//! on the repo's own `util::prop` harness.
//!
//! Invariants:
//! * the shard map is a partition — every word has exactly one owner, and
//!   `owned_ranges` tiles the region without overlap;
//! * `rehome` always lands on the requested shard, in range;
//! * routing a write-entry stream and reassembling the per-shard chunks is
//!   lossless (same multiset of entries), places every entry on its
//!   owner's log, and preserves per-shard arrival order.

use shetm::cluster::{LayoutDesc, LogRouter, ShardMap};
use shetm::stm::WriteEntry;
use shetm::util::prop::{forall, Cases};
use shetm::util::Rng;

/// Draw a valid (n_words, n_shards, shard_bits) triple for the size hint.
fn draw_map(rng: &mut Rng, size: usize) -> ShardMap {
    let n_shards = 1 + rng.below_usize(8);
    let shard_bits = rng.below(5) as u32; // blocks of 1..16 words
    let min = n_shards << shard_bits;
    let n_words = min + rng.below_usize(min * (1 + size % 16) + 7);
    ShardMap::new(n_words, n_shards, shard_bits)
}

#[test]
fn shard_map_is_a_partition() {
    forall(Cases::new("shard_map_partition", 200), |rng, size| {
        let map = draw_map(rng, size);
        let mut owners = vec![usize::MAX; map.n_words()];
        for shard in 0..map.n_shards() {
            for (s, e) in map.owned_ranges(shard) {
                if e > map.n_words() || s >= e {
                    return Err(format!("bad range ({s},{e}) of {map:?}"));
                }
                for w in s..e {
                    if owners[w] != usize::MAX {
                        return Err(format!("word {w} owned twice in {map:?}"));
                    }
                    owners[w] = shard;
                }
            }
        }
        for (w, &o) in owners.iter().enumerate() {
            if o == usize::MAX {
                return Err(format!("word {w} unowned in {map:?}"));
            }
            if o != map.owner(w) {
                return Err(format!(
                    "word {w}: ranges say {o}, owner() says {} in {map:?}",
                    map.owner(w)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn rehome_lands_on_shard_in_range() {
    forall(Cases::new("rehome_on_shard", 300), |rng, size| {
        let map = draw_map(rng, size);
        for _ in 0..32 {
            let w = rng.below_usize(map.n_words());
            let d = rng.below_usize(map.n_shards());
            let r = map.rehome(w, d);
            if r >= map.n_words() {
                return Err(format!("rehome({w},{d}) = {r} out of range in {map:?}"));
            }
            if map.owner(r) != d {
                return Err(format!(
                    "rehome({w},{d}) = {r} owned by {} in {map:?}",
                    map.owner(r)
                ));
            }
            if map.n_shards() == 1 && r != w {
                return Err(format!("solo rehome must be identity: {w} -> {r}"));
            }
        }
        Ok(())
    });
}

fn key(e: &WriteEntry) -> (u32, i32, i32) {
    (e.addr, e.val, e.ts)
}

#[test]
fn routing_then_reassembly_is_lossless() {
    forall(Cases::new("router_lossless", 150), |rng, size| {
        let map = draw_map(rng, size);
        let chunk_entries = 1 + rng.below_usize(16);
        let mut router = LogRouter::new(map.clone(), chunk_entries);

        // A ts-ordered entry stream over random words.
        let n_entries = rng.below_usize(4 * size + 8);
        let entries: Vec<WriteEntry> = (0..n_entries)
            .map(|i| WriteEntry {
                addr: rng.below_usize(map.n_words()) as u32,
                val: rng.below(1 << 20) as i32,
                ts: i as i32 + 1,
            })
            .collect();
        router.append(&entries);
        if router.len_total() != entries.len() {
            return Err(format!(
                "routed {} of {} entries",
                router.len_total(),
                entries.len()
            ));
        }

        // Reassemble from the per-shard chunks.
        let mut got: Vec<WriteEntry> = Vec::new();
        for shard in 0..map.n_shards() {
            let mut chunks = Vec::new();
            router.drain_all(shard, &mut chunks);
            let mut last_ts = 0;
            for c in &chunks {
                for (i, &a) in c.addrs.iter().enumerate() {
                    if a < 0 {
                        continue;
                    }
                    let e = WriteEntry {
                        addr: a as u32,
                        val: c.vals[i],
                        ts: c.ts[i],
                    };
                    // Exactly one shard: the owner.
                    if map.owner(e.addr as usize) != shard {
                        return Err(format!(
                            "entry at word {} on shard {shard}, owner {}",
                            e.addr,
                            map.owner(e.addr as usize)
                        ));
                    }
                    // Per-shard order preserved (ts strictly increases).
                    if e.ts <= last_ts {
                        return Err(format!(
                            "shard {shard}: ts {} after {}",
                            e.ts, last_ts
                        ));
                    }
                    last_ts = e.ts;
                    got.push(e);
                }
            }
        }

        // Lossless: same multiset of entries.
        let mut want: Vec<_> = entries.iter().map(key).collect();
        let mut have: Vec<_> = got.iter().map(key).collect();
        want.sort_unstable();
        have.sort_unstable();
        if want != have {
            return Err(format!(
                "lost or invented entries: {} in, {} out",
                want.len(),
                have.len()
            ));
        }
        Ok(())
    });
}

/// Check the partition invariant on `map`'s current table: every word is
/// owned by exactly one shard, `owned_ranges` agrees with `owner()`, and
/// no shard has been starved of its last block.
fn check_partition(map: &ShardMap) -> Result<(), String> {
    let mut owners = vec![usize::MAX; map.n_words()];
    for shard in 0..map.n_shards() {
        let ranges = map.owned_ranges(shard);
        if ranges.is_empty() {
            return Err(format!("shard {shard} starved of blocks in {map:?}"));
        }
        for (s, e) in ranges {
            for w in s..e {
                if owners[w] != usize::MAX {
                    return Err(format!("word {w} owned twice in {map:?}"));
                }
                owners[w] = shard;
            }
        }
    }
    for (w, &o) in owners.iter().enumerate() {
        if o == usize::MAX {
            return Err(format!("word {w} unowned in {map:?}"));
        }
        if o != map.owner(w) {
            return Err(format!(
                "word {w}: ranges say {o}, owner() says {} in {map:?}",
                map.owner(w)
            ));
        }
    }
    Ok(())
}

/// Random migrations never break the partition, never starve a shard,
/// and bump the layout epoch monotonically (at most +1 per call; exactly
/// +0 when nothing moved).  Clones share the table, so an old handle must
/// observe every new epoch.
#[test]
fn migration_keeps_the_partition_and_epochs_monotone() {
    forall(Cases::new("migration_partition", 120), |rng, size| {
        let map = draw_map(rng, size);
        let old_handle = map.clone();
        if map.epoch() != 0 {
            return Err(format!("fresh layout at epoch {}", map.epoch()));
        }
        let mut last = 0u64;
        for _ in 0..8 {
            let n_moves = 1 + rng.below_usize(3);
            let blocks: Vec<usize> = (0..n_moves)
                .map(|_| rng.below_usize(map.n_blocks()))
                .collect();
            let to = rng.below_usize(map.n_shards());
            let epoch = map.migrate(&blocks, to);
            if epoch < last || epoch > last + 1 {
                return Err(format!(
                    "epoch jumped {last} -> {epoch} migrating {blocks:?} to {to}"
                ));
            }
            last = epoch;
            if old_handle.epoch() != epoch {
                return Err(format!(
                    "stale clone at epoch {} after install of {epoch}",
                    old_handle.epoch()
                ));
            }
            check_partition(&map)?;
        }
        Ok(())
    });
}

/// Scattering a stream through a migrated layout is indistinguishable
/// from scattering through a static layout with the same owner table:
/// every entry lands on the shard `desc().owners` names, losslessly.
/// The manifest RLE codec must round-trip that same table bit-exactly.
#[test]
fn migrated_scatter_matches_the_equivalent_static_table() {
    forall(Cases::new("migrated_scatter", 100), |rng, size| {
        let map = draw_map(rng, size);
        // The router's handle shares the table: migrations done after
        // construction govern the scatter of later appends.
        let mut router = LogRouter::new(map.clone(), 1 + rng.below_usize(8));
        for _ in 0..4 {
            let b = rng.below_usize(map.n_blocks());
            let to = rng.below_usize(map.n_shards());
            map.migrate(&[b], to);
        }
        let desc = map.desc();
        if LayoutDesc::parse_rle(&desc.to_rle()).as_ref() != Some(&desc.owners) {
            return Err(format!("RLE round-trip mangled {:?}", desc.owners));
        }

        let n_entries = rng.below_usize(4 * size + 8);
        let entries: Vec<WriteEntry> = (0..n_entries)
            .map(|i| WriteEntry {
                addr: rng.below_usize(map.n_words()) as u32,
                val: rng.below(1 << 20) as i32,
                ts: i as i32 + 1,
            })
            .collect();
        router.append(&entries);

        let mut seen = 0usize;
        for shard in 0..map.n_shards() {
            let mut chunks = Vec::new();
            router.drain_all(shard, &mut chunks);
            for c in &chunks {
                for &a in c.addrs.iter() {
                    if a < 0 {
                        continue;
                    }
                    seen += 1;
                    // The static equivalent: a plain table lookup on the
                    // frozen descriptor must name this exact shard.
                    let block = (a as usize) >> desc.shard_bits;
                    if desc.owners[block] as usize != shard {
                        return Err(format!(
                            "word {a} on shard {shard}, static table says {} \
                             (epoch {})",
                            desc.owners[block], desc.epoch
                        ));
                    }
                }
            }
        }
        if seen != entries.len() {
            return Err(format!("routed {seen} of {} entries", entries.len()));
        }
        Ok(())
    });
}

#[test]
fn carry_reroutes_after_reset() {
    forall(Cases::new("router_carry", 100), |rng, size| {
        let map = draw_map(rng, size);
        let mut router = LogRouter::new(map.clone(), 4);
        let carry: Vec<WriteEntry> = (0..rng.below_usize(size + 2))
            .map(|i| WriteEntry {
                addr: rng.below_usize(map.n_words()) as u32,
                val: i as i32,
                ts: i as i32 + 1,
            })
            .collect();
        router.reset_with_carry(&carry);
        if router.len_total() != carry.len() {
            return Err(format!(
                "carry of {} produced {} logged entries",
                carry.len(),
                router.len_total()
            ));
        }
        // A favor-GPU abort right after: the carried prefix must survive.
        router.truncate_to_carried();
        if router.len_total() != carry.len() {
            return Err("truncate dropped carried entries".to_string());
        }
        Ok(())
    });
}
