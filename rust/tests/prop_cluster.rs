//! Property tests for the cluster sharding layer (ShardMap / LogRouter),
//! on the repo's own `util::prop` harness.
//!
//! Invariants:
//! * the shard map is a partition — every word has exactly one owner, and
//!   `owned_ranges` tiles the region without overlap;
//! * `rehome` always lands on the requested shard, in range;
//! * routing a write-entry stream and reassembling the per-shard chunks is
//!   lossless (same multiset of entries), places every entry on its
//!   owner's log, and preserves per-shard arrival order.

use shetm::cluster::{LogRouter, ShardMap};
use shetm::stm::WriteEntry;
use shetm::util::prop::{forall, Cases};
use shetm::util::Rng;

/// Draw a valid (n_words, n_shards, shard_bits) triple for the size hint.
fn draw_map(rng: &mut Rng, size: usize) -> ShardMap {
    let n_shards = 1 + rng.below_usize(8);
    let shard_bits = rng.below(5) as u32; // blocks of 1..16 words
    let min = n_shards << shard_bits;
    let n_words = min + rng.below_usize(min * (1 + size % 16) + 7);
    ShardMap::new(n_words, n_shards, shard_bits)
}

#[test]
fn shard_map_is_a_partition() {
    forall(Cases::new("shard_map_partition", 200), |rng, size| {
        let map = draw_map(rng, size);
        let mut owners = vec![usize::MAX; map.n_words()];
        for shard in 0..map.n_shards() {
            for (s, e) in map.owned_ranges(shard) {
                if e > map.n_words() || s >= e {
                    return Err(format!("bad range ({s},{e}) of {map:?}"));
                }
                for w in s..e {
                    if owners[w] != usize::MAX {
                        return Err(format!("word {w} owned twice in {map:?}"));
                    }
                    owners[w] = shard;
                }
            }
        }
        for (w, &o) in owners.iter().enumerate() {
            if o == usize::MAX {
                return Err(format!("word {w} unowned in {map:?}"));
            }
            if o != map.owner(w) {
                return Err(format!(
                    "word {w}: ranges say {o}, owner() says {} in {map:?}",
                    map.owner(w)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn rehome_lands_on_shard_in_range() {
    forall(Cases::new("rehome_on_shard", 300), |rng, size| {
        let map = draw_map(rng, size);
        for _ in 0..32 {
            let w = rng.below_usize(map.n_words());
            let d = rng.below_usize(map.n_shards());
            let r = map.rehome(w, d);
            if r >= map.n_words() {
                return Err(format!("rehome({w},{d}) = {r} out of range in {map:?}"));
            }
            if map.owner(r) != d {
                return Err(format!(
                    "rehome({w},{d}) = {r} owned by {} in {map:?}",
                    map.owner(r)
                ));
            }
            if map.n_shards() == 1 && r != w {
                return Err(format!("solo rehome must be identity: {w} -> {r}"));
            }
        }
        Ok(())
    });
}

fn key(e: &WriteEntry) -> (u32, i32, i32) {
    (e.addr, e.val, e.ts)
}

#[test]
fn routing_then_reassembly_is_lossless() {
    forall(Cases::new("router_lossless", 150), |rng, size| {
        let map = draw_map(rng, size);
        let chunk_entries = 1 + rng.below_usize(16);
        let mut router = LogRouter::new(map.clone(), chunk_entries);

        // A ts-ordered entry stream over random words.
        let n_entries = rng.below_usize(4 * size + 8);
        let entries: Vec<WriteEntry> = (0..n_entries)
            .map(|i| WriteEntry {
                addr: rng.below_usize(map.n_words()) as u32,
                val: rng.below(1 << 20) as i32,
                ts: i as i32 + 1,
            })
            .collect();
        router.append(&entries);
        if router.len_total() != entries.len() {
            return Err(format!(
                "routed {} of {} entries",
                router.len_total(),
                entries.len()
            ));
        }

        // Reassemble from the per-shard chunks.
        let mut got: Vec<WriteEntry> = Vec::new();
        for shard in 0..map.n_shards() {
            let mut chunks = Vec::new();
            router.drain_all(shard, &mut chunks);
            let mut last_ts = 0;
            for c in &chunks {
                for (i, &a) in c.addrs.iter().enumerate() {
                    if a < 0 {
                        continue;
                    }
                    let e = WriteEntry {
                        addr: a as u32,
                        val: c.vals[i],
                        ts: c.ts[i],
                    };
                    // Exactly one shard: the owner.
                    if map.owner(e.addr as usize) != shard {
                        return Err(format!(
                            "entry at word {} on shard {shard}, owner {}",
                            e.addr,
                            map.owner(e.addr as usize)
                        ));
                    }
                    // Per-shard order preserved (ts strictly increases).
                    if e.ts <= last_ts {
                        return Err(format!(
                            "shard {shard}: ts {} after {}",
                            e.ts, last_ts
                        ));
                    }
                    last_ts = e.ts;
                    got.push(e);
                }
            }
        }

        // Lossless: same multiset of entries.
        let mut want: Vec<_> = entries.iter().map(key).collect();
        let mut have: Vec<_> = got.iter().map(key).collect();
        want.sort_unstable();
        have.sort_unstable();
        if want != have {
            return Err(format!(
                "lost or invented entries: {} in, {} out",
                want.len(),
                have.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn carry_reroutes_after_reset() {
    forall(Cases::new("router_carry", 100), |rng, size| {
        let map = draw_map(rng, size);
        let mut router = LogRouter::new(map.clone(), 4);
        let carry: Vec<WriteEntry> = (0..rng.below_usize(size + 2))
            .map(|i| WriteEntry {
                addr: rng.below_usize(map.n_words()) as u32,
                val: i as i32,
                ts: i as i32 + 1,
            })
            .collect();
        router.reset_with_carry(&carry);
        if router.len_total() != carry.len() {
            return Err(format!(
                "carry of {} produced {} logged entries",
                carry.len(),
                router.len_total()
            ));
        }
        // A favor-GPU abort right after: the carried prefix must survive.
        router.truncate_to_carried();
        if router.len_total() != carry.len() {
            return Err("truncate dropped carried entries".to_string());
        }
        Ok(())
    });
}
