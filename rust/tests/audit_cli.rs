//! Golden tests for the `shetm-audit` static-analysis binary.
//!
//! Two gates in one file:
//!
//! 1. The fixture corpus under `rust/tests/audit_fixtures/` — a
//!    miniature repo tree with at least one known-bad snippet per rule
//!    D1–D6 plus pragma'd, clean, whitelisted and test-exempt variants
//!    — must produce *exactly* the pinned diagnostics (rule id, file,
//!    line, message) and exit codes.  Any lexer or scoping change that
//!    shifts a single finding fails here first, not in CI on the real
//!    tree.
//! 2. The real tree itself must be audit-clean: `--deny` over this
//!    repository exits 0.  This is the same invocation the CI `audit`
//!    job runs, so a violation is caught by `cargo test` locally
//!    before it ever reaches CI.

use std::path::Path;
use std::process::{Command, Output};

/// Run the audit binary (built by cargo for this same package) with
/// the given arguments.
fn audit(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_shetm-audit"))
        .args(args)
        .output()
        .expect("spawn shetm-audit")
}

fn repo_root() -> &'static str {
    env!("CARGO_MANIFEST_DIR")
}

fn fixtures_root() -> String {
    Path::new(repo_root())
        .join("rust/tests/audit_fixtures")
        .to_string_lossy()
        .into_owned()
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8(out.stdout.clone()).expect("audit output is UTF-8")
}

/// The full, sorted diagnostic stream for the fixture corpus.  Pinned
/// verbatim: file, line, rule id and message, ordered by
/// (file, line, rule) exactly as the binary sorts them.
const EXPECTED: &[&str] = &[
    "rust/src/cluster/shard_math.rs:4: D5: unchecked shift in shard-layout arithmetic — overflow wraps in release; use checked_shl/checked_mul or pragma the proven-guarded site",
    "rust/src/cluster/shard_math.rs:8: D5: unchecked multiply in shard-layout arithmetic — overflow wraps in release; use checked_mul or pragma the proven-bounded site",
    "rust/src/cluster/shard_math.rs:12: D5: narrowing `as u32` cast in shard-layout arithmetic — use try_into or pragma the proven-bounded site",
    "rust/src/coordinator/d1_hash.rs:7: D1: HashMap in deterministic path — iteration order is ambient; use BTreeMap/BTreeSet or a sorted collect",
    "rust/src/coordinator/d1_hash.rs:10: D1: HashSet in deterministic path — iteration order is ambient; use BTreeMap/BTreeSet or a sorted collect",
    "rust/src/coordinator/d1_hash.rs:11: D1: HashSet in deterministic path — iteration order is ambient; use BTreeMap/BTreeSet or a sorted collect",
    "rust/src/coordinator/d2_clock.rs:4: D2: Instant::now outside util/bench.rs / rust/benches — wall clock leaks into deterministic state",
    "rust/src/coordinator/d2_clock.rs:8: D2: SystemTime read — wall clock leaks into deterministic state",
    "rust/src/coordinator/d3_float.rs:4: D3: .sum::<f64>() — float accumulation order must be fixed; use the ordered fold helpers",
    "rust/src/coordinator/d3_float.rs:8: D3: float fold — accumulation order must be fixed; use the ordered fold helpers",
    "rust/src/coordinator/d4_rand.rs:4: D4: RandomState — ambient entropy; seeds must flow from config",
    "rust/src/coordinator/pragma_bad.rs:6: PRAGMA: malformed audit:allow pragma — reason must be non-empty",
    "rust/src/coordinator/pragma_bad.rs:7: D1: HashMap in deterministic path — iteration order is ambient; use BTreeMap/BTreeSet or a sorted collect",
    "rust/src/coordinator/pragma_bad.rs:11: PRAGMA: unused audit:allow(D6) — the finding it suppressed is gone; remove it",
    "rust/src/coordinator/pragma_bad.rs:16: PRAGMA: malformed audit:allow pragma — expected `audit:allow(<rule>, reason = \"...\")`",
    "rust/src/session/d6_panic.rs:5: D6: .unwrap() in library code — return a typed error, restructure, or pragma with a reason",
    "rust/src/session/d6_panic.rs:9: D6: .expect() in library code — return a typed error, restructure, or pragma with a reason",
];

#[test]
fn fixtures_produce_exactly_the_pinned_diagnostics() {
    let out = audit(&["--root", &fixtures_root(), "--deny"]);
    assert_eq!(out.status.code(), Some(1), "--deny with findings must exit 1");

    let mut expected = EXPECTED.join("\n");
    expected.push_str("\nshetm-audit: 17 finding(s) in 9 files scanned\n");
    assert_eq!(stdout_of(&out), expected);
}

#[test]
fn every_rule_has_a_true_positive_in_the_corpus() {
    // Belt and braces over the verbatim pin above: if the corpus or
    // EXPECTED ever shrinks, this names the rule that lost coverage.
    for rule in ["D1", "D2", "D3", "D4", "D5", "D6", "PRAGMA"] {
        let tag = format!(": {rule}: ");
        assert!(
            EXPECTED.iter().any(|l| l.contains(&tag)),
            "no pinned true-positive diagnostic for rule {rule}"
        );
    }
}

#[test]
fn report_mode_exits_zero_but_still_prints_findings() {
    let out = audit(&["--root", &fixtures_root()]);
    assert_eq!(out.status.code(), Some(0), "without --deny findings only report");
    let text = stdout_of(&out);
    assert!(text.contains("17 finding(s) in 9 files scanned (report-only; use --deny to gate)"));
}

#[test]
fn whitelisted_and_test_tree_fixtures_are_clean() {
    // util/bench.rs may read Instant (D2 whitelist); the test tree is
    // exempt from the panic policy (D6 scope is rust/src only).
    let out = audit(&[
        "--root",
        &fixtures_root(),
        "--deny",
        "rust/src/util/bench.rs",
        "rust/tests/test_code_ok.rs",
    ]);
    assert_eq!(out.status.code(), Some(0));
    assert_eq!(stdout_of(&out), "shetm-audit: clean (2 files)\n");
}

#[test]
fn real_tree_is_audit_clean() {
    // The exact CI invocation: every finding on the live tree is
    // either fixed or carries a justified pragma.
    let out = audit(&["--root", repo_root(), "--deny"]);
    let text = stdout_of(&out);
    assert_eq!(out.status.code(), Some(0), "real tree has unsuppressed findings:\n{text}");
    assert!(
        text.starts_with("shetm-audit: clean ("),
        "unexpected audit output:\n{text}"
    );
}

#[test]
fn list_rules_names_the_full_catalog() {
    let out = audit(&["--list-rules"]);
    assert_eq!(out.status.code(), Some(0));
    let text = stdout_of(&out);
    for rule in ["D1", "D2", "D3", "D4", "D5", "D6"] {
        assert!(text.contains(rule), "--list-rules is missing {rule}");
    }
}

#[test]
fn unknown_flag_is_a_usage_error() {
    let out = audit(&["--frobnicate"]);
    assert_eq!(out.status.code(), Some(2), "unknown flags must exit 2 with usage");
    let err = String::from_utf8(out.stderr.clone()).expect("stderr is UTF-8");
    assert!(err.contains("shetm-audit [--root DIR]"), "usage text missing:\n{err}");
}
