//! Cross-backend integration tests: the PJRT-executed jax/Pallas artifacts
//! must agree BIT-EXACTLY with the native Rust mirrors on identical inputs.
//!
//! This is the load-bearing correctness check of the three-layer stack:
//! python/tests already pins the jax models to the sequential oracles
//! (ref.py); these tests pin the Rust mirrors to the compiled artifacts,
//! closing the loop.
//!
//! Skipped (with a notice) when `artifacts/` has not been built — run
//! `make artifacts` first.

use shetm::gpu::{Backend, GpuDevice, LogChunk, McBatch, TxnBatch};
use shetm::runtime::ArtifactStore;
use shetm::util::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("SHETM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if ArtifactStore::available(&dir) {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts/ missing; run `make artifacts` to enable PJRT tests");
        None
    }
}

fn store() -> Option<ArtifactStore> {
    artifacts_dir().map(|d| ArtifactStore::load(d).expect("artifact store loads"))
}

/// Random batch matching the `prstm_r4_g0` artifact shape (b=1024, r=4, w=4,
/// n=2^18) with unique write indices per transaction.
fn random_batch(rng: &mut Rng, n: usize, b: usize, r: usize, w: usize) -> TxnBatch {
    let mut batch = TxnBatch::empty(b, r, w);
    let mut widx = Vec::new();
    for i in 0..b {
        for j in 0..r {
            batch.read_idx[i * r + j] = rng.below_usize(n) as i32;
        }
        rng.distinct(n, w, &mut widx);
        for j in 0..w {
            batch.write_idx[i * w + j] = widx[j] as i32;
            batch.write_val[i * w + j] = rng.below(1000) as i32;
        }
        batch.op[i] = rng.below(2) as i32;
    }
    batch
}

fn pjrt_device(store: &ArtifactStore, n: usize, bmp_shift: u32, prstm: &str, validate: &str) -> GpuDevice {
    GpuDevice::new(
        n,
        bmp_shift,
        Backend::Pjrt {
            store: store.clone(),
            prstm: prstm.to_string(),
            validate: validate.to_string(),
            memcached: "memcached".to_string(),
        },
    )
}

#[test]
fn prstm_batch_pjrt_matches_native() {
    let Some(store) = store() else { return };
    let n = 1 << 18;
    for (art, shift) in [("prstm_r4_g0", 0u32), ("prstm_r4_g8", 8u32)] {
        let mut rng = Rng::new(0xBEEF);
        let mut native = GpuDevice::new(n, shift, Backend::Native);
        let mut pjrt = pjrt_device(&store, n, shift, art, "validate_synth_g0");
        native.begin_round();
        pjrt.begin_round();

        for round in 0..3 {
            let batch = random_batch(&mut rng, n, 1024, 4, 4);
            let on = native.run_txn_batch(&batch).expect("native");
            let op = pjrt.run_txn_batch(&batch).expect("pjrt");
            assert_eq!(on.commit, op.commit, "{art} round {round}: commit masks");
            assert_eq!(on.n_commits, op.n_commits);
            assert_eq!(native.stmr(), pjrt.stmr(), "{art} round {round}: STMR");
            // Packed representation is canonical, so Bitmap equality is
            // exact regardless of which backend produced it.
            assert_eq!(
                native.rs_bmp(),
                pjrt.rs_bmp(),
                "{art} round {round}: RS bitmap"
            );
            assert_eq!(
                native.ws_bmp(),
                pjrt.ws_bmp(),
                "{art} round {round}: WS bitmap"
            );
        }
    }
}

#[test]
fn prstm_wide_reads_pjrt_matches_native() {
    let Some(store) = store() else { return };
    let n = 1 << 18;
    let mut rng = Rng::new(0xCAFE);
    let mut native = GpuDevice::new(n, 0, Backend::Native);
    let mut pjrt = pjrt_device(&store, n, 0, "prstm_r40_g0", "validate_synth_g0");
    native.begin_round();
    pjrt.begin_round();
    let batch = random_batch(&mut rng, n, 1024, 40, 4);
    let on = native.run_txn_batch(&batch).expect("native");
    let op = pjrt.run_txn_batch(&batch).expect("pjrt");
    assert_eq!(on.commit, op.commit);
    assert_eq!(native.stmr(), pjrt.stmr());
}

#[test]
fn validate_chunk_pjrt_matches_native() {
    let Some(store) = store() else { return };
    let n = 1 << 18;
    let c = 4096;
    let mut rng = Rng::new(0xD00D);

    let mut native = GpuDevice::new(n, 0, Backend::Native);
    let mut pjrt = pjrt_device(&store, n, 0, "prstm_r4_g0", "validate_synth_g0");
    native.begin_round();
    pjrt.begin_round();

    // Populate the read-set bitmap via a real batch so conflicts can occur.
    let batch = random_batch(&mut rng, n, 1024, 4, 4);
    native.run_txn_batch(&batch).unwrap();
    pjrt.run_txn_batch(&batch).unwrap();

    for _ in 0..3 {
        let mut chunk = LogChunk::empty(c);
        // ~75% live entries, duplicated addresses and timestamp collisions
        // on purpose (exercises the freshness tie-break).
        for i in 0..c {
            if rng.chance(0.75) {
                chunk.addrs[i] = rng.below((n / 64) as u64) as i32; // dup-heavy
                chunk.vals[i] = rng.below(10_000) as i32;
                chunk.ts[i] = rng.below(50) as i32;
            }
        }
        let cn = native.validate_chunk(&chunk).expect("native");
        let cp = pjrt.validate_chunk(&chunk).expect("pjrt");
        assert_eq!(cn, cp, "conflict counts");
        assert_eq!(native.stmr(), pjrt.stmr(), "STMR after apply");
    }
}

#[test]
fn memcached_batch_pjrt_matches_native() {
    let Some(store) = store() else { return };
    let n_sets = 1 << 15;
    let n = n_sets * shetm::gpu::native::mc::WORDS_PER_SET;
    let q = 1024;
    let mut rng = Rng::new(0xF00D);

    let mut native = GpuDevice::new(n, 0, Backend::Native);
    let mut pjrt = pjrt_device(&store, n, 0, "prstm_r4_g0", "validate_mc_g0");

    // Empty cache: keys = -1 everywhere.
    for s in 0..n_sets {
        for wslot in 0..8 {
            let w = s * shetm::gpu::native::mc::WORDS_PER_SET + wslot;
            native.stmr_mut()[w] = -1;
            pjrt.stmr_mut()[w] = -1;
        }
    }
    native.begin_round();
    pjrt.begin_round();

    let mut clk = 1i32;
    for round in 0..3 {
        let mut b = McBatch::empty(q);
        for i in 0..q {
            b.op[i] = if rng.chance(0.3) { 1 } else { 0 };
            b.key[i] = rng.below(5_000) as i32;
            b.val[i] = rng.below(100_000) as i32;
        }
        b.clk0 = clk;
        clk += q as i32;

        let on = native.run_mc_batch(&b, n_sets).expect("native");
        let op = pjrt.run_mc_batch(&b, n_sets).expect("pjrt");
        assert_eq!(on.commit, op.commit, "round {round}: commit masks");
        assert_eq!(on.out_val, op.out_val, "round {round}: GET results");
        assert_eq!(native.stmr(), pjrt.stmr(), "round {round}: STMR");
        assert_eq!(native.rs_bmp(), pjrt.rs_bmp(), "round {round}: RS bitmap");
    }
}
