//! Whole-engine integration tests, including the PJRT production path:
//! the coordinator driving the compiled jax/Pallas artifacts end to end,
//! cross-checked against the native backend.  Engines are constructed
//! through the `Session` facade (`Hetm` builder) with an explicit backend.

use shetm::apps::memcached::McConfig;
use shetm::apps::synth::SynthSpec;
use shetm::config::{PolicyKind, Raw, SystemConfig};
use shetm::coordinator::round::Variant;
use shetm::gpu::Backend;
use shetm::runtime::ArtifactStore;
use shetm::session::Hetm;

fn cfg(n: usize) -> SystemConfig {
    let mut raw = Raw::new();
    raw.set("cpu.txn_ns=2000").unwrap();
    raw.set("gpu.txn_ns=230").unwrap();
    raw.set("hetm.period_ms=2").unwrap();
    raw.set("seed=99").unwrap();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = n;
    c
}

fn pjrt_backend(prstm: &str, validate: &str) -> Option<Backend> {
    let dir = std::env::var("SHETM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !ArtifactStore::available(&dir) {
        eprintln!("NOTE: artifacts/ missing; PJRT engine tests skipped");
        return None;
    }
    Some(Backend::Pjrt {
        store: ArtifactStore::load(dir).expect("store loads"),
        prstm: prstm.to_string(),
        validate: validate.to_string(),
        memcached: "memcached".to_string(),
    })
}

#[test]
fn synth_engine_pjrt_matches_native_run() {
    let n = 1 << 18; // must match the compiled artifacts
    let Some(backend) = pjrt_backend("prstm_r4_g0", "validate_synth_g0") else {
        return;
    };
    let c = cfg(n);
    let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
    let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);

    let mut pjrt = Hetm::from_config(&c)
        .synth(cpu_spec.clone(), gpu_spec.clone())
        .backend(backend)
        .build()
        .unwrap();
    pjrt.run_rounds(3).unwrap();

    let mut native = Hetm::from_config(&c)
        .synth(cpu_spec, gpu_spec)
        .backend(Backend::Native)
        .build()
        .unwrap();
    native.run_rounds(3).unwrap();

    assert_eq!(pjrt.stats().cpu_commits, native.stats().cpu_commits);
    assert_eq!(pjrt.stats().gpu_commits, native.stats().gpu_commits);
    assert_eq!(pjrt.stats().rounds_committed, 3);
    assert_eq!(pjrt.device_stmr(0), native.device_stmr(0));
    assert_eq!(
        pjrt.stmr().snapshot(),
        native.stmr().snapshot(),
        "CPU replicas"
    );
}

#[test]
fn synth_engine_pjrt_conflicting_round_rolls_back() {
    let n = 1 << 18;
    let Some(backend) = pjrt_backend("prstm_r4_g0", "validate_synth_g0") else {
        return;
    };
    let c = cfg(n);
    let cpu_spec = SynthSpec::w1(n, 1.0)
        .partitioned(0..n / 2)
        .with_conflicts(0.01, n / 2..n);
    let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
    let mut e = Hetm::from_config(&c)
        .synth(cpu_spec, gpu_spec)
        .backend(backend)
        .build()
        .unwrap();
    e.run_rounds(2).unwrap();
    assert_eq!(e.stats().rounds_committed, 0, "dense conflicts abort rounds");
    assert_eq!(e.stats().gpu_commits, 0);
    assert!(e.stats().discarded_commits > 0);
    // Rollback correctness: after a drain the replicas agree again.
    e.drain().unwrap();
    assert_eq!(e.stmr().snapshot(), e.device_stmr(0).to_vec());
}

#[test]
fn memcached_engine_pjrt_three_policies() {
    let Some(backend) = pjrt_backend("prstm_r4_g0", "validate_mc_g0") else {
        return;
    };
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        let c = cfg(1 << 18);
        let mc = McConfig::new(1 << 15);
        let mut e = Hetm::from_config(&c)
            .policy(policy)
            .memcached(mc)
            .backend(backend.clone())
            .build()
            .unwrap();
        e.run_rounds(2).unwrap();
        assert!(
            e.stats().cpu_commits + e.stats().gpu_commits > 0,
            "{policy:?}: some requests must be served"
        );
        assert_eq!(
            e.stats().rounds_committed, 2,
            "{policy:?}: parity workload must not conflict"
        );
    }
}

#[test]
fn basic_variant_pjrt_round_trips() {
    let n = 1 << 18;
    let Some(backend) = pjrt_backend("prstm_r4_g0", "validate_synth_g0") else {
        return;
    };
    let c = cfg(n);
    let cpu_spec = SynthSpec::w1(n, 0.1).partitioned(0..n / 2);
    let gpu_spec = SynthSpec::w1(n, 0.1).partitioned(n / 2..n);
    let mut e = Hetm::from_config(&c)
        .variant(Variant::Basic)
        .synth(cpu_spec, gpu_spec)
        .backend(backend)
        .build()
        .unwrap();
    e.run_rounds(2).unwrap();
    assert_eq!(e.stats().rounds_committed, 2);
    e.drain().unwrap();
    assert_eq!(e.stmr().snapshot(), e.device_stmr(0).to_vec());
}

#[test]
fn wide_read_artifact_drives_w2_workload() {
    let n = 1 << 18;
    let Some(backend) = pjrt_backend("prstm_r40_g0", "validate_synth_g0") else {
        return;
    };
    let c = cfg(n);
    let cpu_spec = SynthSpec::w2(n, 0.5).partitioned(0..n / 2);
    let gpu_spec = SynthSpec::w2(n, 0.5).partitioned(n / 2..n);
    let mut e = Hetm::from_config(&c)
        .synth(cpu_spec, gpu_spec)
        .backend(backend)
        .build()
        .unwrap();
    e.run_rounds(2).unwrap();
    assert_eq!(e.stats().rounds_committed, 2);
    assert!(e.stats().gpu_commits > 0);
}
