//! Audit fixture — the test tree is exempt from the panic policy (D6).

pub fn helper(v: Option<u32>) -> u32 {
    v.unwrap()
}
