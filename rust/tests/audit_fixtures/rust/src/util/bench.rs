//! Audit fixture — the wall-clock whitelist: util/bench.rs may read Instant.

pub fn timer() -> std::time::Instant {
    std::time::Instant::now()
}
