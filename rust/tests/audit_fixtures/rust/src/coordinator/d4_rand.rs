//! Audit fixture — D4: ambient randomness (seeds must flow from config).

pub fn bad_hasher() -> u64 {
    let state = std::collections::hash_map::RandomState::new();
    let _ = state;
    0
}

pub fn allowed_entropy() -> u64 {
    // audit:allow(D4, reason = "debug-only cache keying, never observable in results")
    let state = std::collections::hash_map::RandomState::new();
    let _ = state;
    0
}
