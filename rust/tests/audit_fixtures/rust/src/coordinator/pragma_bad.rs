//! Audit fixture — pragma hygiene: malformed and unused pragmas are findings.

use std::collections::HashMap;

pub struct BadReason {
    // audit:allow(D1, reason = "")
    pub index: HashMap<u32, usize>,
}

pub fn unused_pragma() -> u32 {
    // audit:allow(D6, reason = "suppresses nothing on the next line")
    41 + 1
}

pub mod nested {
    // audit:allow(D1)
    pub fn missing_reason_form() {}
}
