//! Audit fixture — D3: unordered float reductions in deterministic paths.

pub fn bad_sum(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}

pub fn bad_fold(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0, |a, b| a + b)
}

pub fn clean_integer_sum(xs: &[u64]) -> u64 {
    xs.iter().sum::<u64>()
}

pub fn allowed(xs: &[f64]) -> f64 {
    // audit:allow(D3, reason = "single-threaded slice order is the fixed order here")
    xs.iter().sum::<f64>()
}
