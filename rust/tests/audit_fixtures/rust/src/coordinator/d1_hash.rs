//! Audit fixture — D1: Default-hashed collections in deterministic paths.
//! Never compiled; scanned by `shetm-audit` via `--root`.

use std::collections::{BTreeMap, HashMap, HashSet};

pub struct Bad {
    pub index: HashMap<u32, usize>,
}

pub fn bad_set() -> HashSet<u32> {
    HashSet::new()
}

pub struct AllowedScratch {
    // audit:allow(D1, reason = "lookup-only scratch, never iterated")
    pub scratch: HashMap<u32, u32>,
}

pub fn clean(m: &BTreeMap<u32, u32>) -> u32 {
    m.values().copied().sum()
}
