//! Audit fixture — D2: wall-clock reads outside the bench whitelist.

pub fn bad_instant() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn bad_system_time() -> u64 {
    match std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
        Ok(d) => d.as_secs(),
        Err(_) => 0,
    }
}

pub fn allowed_wall_cost() -> std::time::Instant {
    // audit:allow(D2, reason = "wall-clock-only metric, excluded from deterministic snapshots")
    std::time::Instant::now()
}
