//! Audit fixture — D5: unchecked shard-layout arithmetic.

pub fn bad_shift(n_shards: usize, shard_bits: u32) -> usize {
    n_shards << shard_bits
}

pub fn bad_mul(a: usize, b: usize) -> usize {
    a * b
}

pub fn bad_narrow(block: usize) -> u32 {
    block as u32
}

pub fn allowed_shift(shard_bits: u32) -> usize {
    assert!(shard_bits < usize::BITS);
    // audit:allow(D5, reason = "shift guarded by the assert directly above")
    1usize << shard_bits
}

pub fn clean_checked(a: usize, b: usize) -> Option<usize> {
    a.checked_mul(b)
}
