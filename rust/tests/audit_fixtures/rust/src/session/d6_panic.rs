//! Audit fixture — D6: panic policy in library code.

/// Doc comments may show `.unwrap()` freely — the lexer strips them.
pub fn bad_unwrap(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn bad_expect(v: Option<u32>) -> u32 {
    v.expect("fixture")
}

pub fn allowed_unwrap(v: Option<u32>) -> u32 {
    // audit:allow(D6, reason = "fixture-proven invariant: caller checked is_some")
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
