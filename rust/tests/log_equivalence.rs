//! Golden equivalence: compacted + filtered log shipping is bit-identical
//! to raw shipping.
//!
//! `hetm.log_compaction` and `hetm.chunk_filter` change WHAT travels over
//! the bus and how much validation work the model charges — they must
//! never change what the system computes.  This suite pins that: for
//! every workload × policy × `n_gpus ∈ {1, 4}`, an engine with both knobs
//! on must produce the same final STMR state (CPU and every device), the
//! same conflict decisions (per-round commit flags), and the same commit
//! counts as the raw engine on the same seed.
//!
//! The runs use a **cost-neutralized** configuration: per-entry
//! validation, signature checks and bus time are zeroed (bandwidth is set
//! absurdly high so transfer durations vanish below one ulp of the
//! cursors).  That freezes the virtual-time schedule — which compaction
//! legitimately shortens, feeding back into the CPU's non-blocking bonus
//! window and the GPU budgets — so the comparison isolates exactly the
//! DATA semantics the optimization must preserve: last-write-wins dedup
//! against the `>=` freshness replay, the carried-prefix boundary under
//! favor-GPU truncation, signature conservativeness, per-shard scatter
//! windows, and the post-abort rollback replay.
//!
//! **Early validation** is pinned in two flavors (DESIGN.md §9):
//!
//! * filter × early validation is bit-identical (a provably-clean chunk
//!   contributes zero conflicts to the early scan either way), asserted
//!   over the full policy × n_gpus matrix;
//! * compaction × early validation preserves every round's commit/abort
//!   DECISION but may legitimately abort *later* (fewer full chunks are
//!   in flight mid-round, so an early point can see less — the conflict
//!   is still caught by that same round's final validation), asserted
//!   behaviorally rather than bitwise.
//!
//! Timing-visible behavior under real costs is exercised by
//! `benches/ablate_log.rs` and the engine unit tests.

// Drives the legacy `launch::build_*` constructors on purpose: this is a
// golden suite over the reference engines (Session is golden-tested
// against them separately, in rust/tests/session_api.rs).
#![allow(deprecated)]

use shetm::config::{PolicyKind, Raw, SystemConfig};
use shetm::coordinator::round::{CpuDriver, Variant};
use shetm::launch;

const POLICIES: [PolicyKind; 3] = [
    PolicyKind::FavorCpu,
    PolicyKind::FavorGpu,
    PolicyKind::CpuWithStarvationGuard,
];

fn neutral_raw() -> Raw {
    Raw::parse(
        "cpu.txn_ns = 2000\n\
         gpu.txn_ns = 230\n\
         hetm.period_ms = 2\n\
         seed = 13\n\
         # Neutralize every cost the compaction/filter path changes, so\n\
         # the virtual-time schedule (and with it all timing feedback into\n\
         # the data path) is identical between raw and compacted runs.\n\
         gpu.validate_entry_ns = 0\n\
         gpu.sig_check_ns = 0\n\
         bus.latency_us = 0\n\
         bus.gbps = 1e30\n\
         [synth]\n\
         conflict_prob = 0.01\n\
         [bank]\n\
         accounts = 16384\n\
         [kmeans]\n\
         points = 2048\n\
         [zipfkv]\n\
         keys = 2048\n\
         theta = 1.1\n\
         hot_prob = 0.2\n\
         [memcached]\n\
         n_sets = 1024\n",
    )
    .unwrap()
}

/// Everything the knobs must not change, in one comparable bundle.
struct Trace {
    summary: String,
    committed_flags: Vec<bool>,
    cpu_state: Vec<i32>,
    device_states: Vec<Vec<i32>>,
    raw_entries: u64,
    shipped_entries: u64,
    chunks_filtered: u64,
    rounds_committed: u64,
}

#[allow(clippy::too_many_arguments)]
fn run(
    name: &str,
    policy: PolicyKind,
    n_gpus: usize,
    variant: Variant,
    early_validation: bool,
    compaction: bool,
    filter: bool,
) -> Trace {
    let raw = neutral_raw();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = 1 << 14;
    c.policy = policy;
    c.n_gpus = n_gpus;
    // Align shard stripes with the apps' half-splits on small regions.
    c.shard_bits = 6;
    c.early_validation = early_validation;
    c.log_compaction = compaction;
    c.chunk_filter = filter;
    let w = shetm::apps::workload::from_raw(name, &raw, &c).unwrap();
    let mut e = launch::build_workload_cluster_engine(
        &c,
        variant,
        w.as_ref(),
        128,
        shetm::gpu::Backend::Native,
    );
    e.run_rounds(3).unwrap();
    e.drain().unwrap();
    w.check_invariants(e.cpu.stmr()).unwrap_or_else(|err| {
        panic!(
            "{name}/{policy:?}/n_gpus={n_gpus}/compaction={compaction}/filter={filter}: \
             oracle failed: {err}"
        )
    });
    Trace {
        summary: format!(
            "rounds={} committed={} early_aborted={} cpu={} gpu={} attempts={}/{} \
             discarded={} duration={:?}",
            e.stats.rounds,
            e.stats.rounds_committed,
            e.stats.rounds_early_aborted,
            e.stats.cpu_commits,
            e.stats.gpu_commits,
            e.stats.cpu_attempts,
            e.stats.gpu_attempts,
            e.stats.discarded_commits,
            e.stats.duration_s,
        ),
        committed_flags: e.round_log.iter().map(|r| r.committed).collect(),
        cpu_state: e.cpu.stmr().snapshot(),
        device_states: e.devices.iter().map(|d| d.stmr().to_vec()).collect(),
        raw_entries: e.stats.log_entries_raw,
        shipped_entries: e.stats.log_entries_shipped,
        chunks_filtered: e.stats.chunks_filtered,
        rounds_committed: e.stats.rounds_committed,
    }
}

/// Strict bit-identity of the data path: raw vs compacted+filtered, with
/// early validation off so mid-round chunk availability (which compaction
/// legitimately changes) cannot shift the abort point.
fn assert_equivalent(name: &str, policy: PolicyKind, n_gpus: usize, variant: Variant) {
    let base = run(name, policy, n_gpus, variant, false, false, false);
    let opt = run(name, policy, n_gpus, variant, false, true, true);
    let label = format!("{name}/{policy:?}/n_gpus={n_gpus}/{variant:?}");
    assert_eq!(base.summary, opt.summary, "{label}: commit counts diverged");
    assert_eq!(
        base.committed_flags, opt.committed_flags,
        "{label}: per-round conflict decisions diverged"
    );
    assert_eq!(base.cpu_state, opt.cpu_state, "{label}: CPU STMR diverged");
    for (d, (a, b)) in base
        .device_states
        .iter()
        .zip(&opt.device_states)
        .enumerate()
    {
        assert_eq!(a, b, "{label}: device {d} replica diverged");
    }
    // The knobs must actually have engaged (otherwise this suite is
    // vacuous): raw load identical, shipped load never larger.
    assert_eq!(base.raw_entries, opt.raw_entries, "{label}");
    assert!(
        opt.shipped_entries <= base.shipped_entries,
        "{label}: compaction grew the log"
    );
    assert_eq!(base.chunks_filtered, 0, "{label}: raw run must not filter");
}

#[test]
fn compacted_filtered_matches_raw_synth() {
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            assert_equivalent("synth", policy, n_gpus, Variant::Optimized);
        }
    }
}

#[test]
fn compacted_filtered_matches_raw_synth_basic_variant() {
    // The basic variant's blocking tail shipping takes a different
    // drain/cursor path; pin it too.
    for n_gpus in [1usize, 4] {
        assert_equivalent("synth", PolicyKind::FavorCpu, n_gpus, Variant::Basic);
    }
}

#[test]
fn compacted_filtered_matches_raw_memcached() {
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            assert_equivalent("memcached", policy, n_gpus, Variant::Optimized);
        }
    }
}

#[test]
fn compacted_filtered_matches_raw_bank() {
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            assert_equivalent("bank", policy, n_gpus, Variant::Optimized);
        }
    }
}

#[test]
fn compacted_filtered_matches_raw_kmeans() {
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            assert_equivalent("kmeans", policy, n_gpus, Variant::Optimized);
        }
    }
}

#[test]
fn compacted_filtered_matches_raw_zipfkv() {
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            assert_equivalent("zipfkv", policy, n_gpus, Variant::Optimized);
        }
    }
}

#[test]
fn filter_is_bit_identical_under_early_validation() {
    // The signature prefilter never changes WHEN chunks ship, and a
    // provably-clean chunk contributes zero conflicts to an early scan
    // either way — so with the filter alone, full bit-identity holds even
    // with early validation on (and the synth conflict injection makes
    // early aborts actually happen).
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            let base = run("synth", policy, n_gpus, Variant::Optimized, true, false, false);
            let filt = run("synth", policy, n_gpus, Variant::Optimized, true, false, true);
            let label = format!("synth/{policy:?}/n_gpus={n_gpus}/early-validation");
            assert_eq!(base.summary, filt.summary, "{label}: stats diverged");
            assert_eq!(base.committed_flags, filt.committed_flags, "{label}");
            assert_eq!(base.cpu_state, filt.cpu_state, "{label}: CPU STMR diverged");
            for (d, (a, b)) in base
                .device_states
                .iter()
                .zip(&filt.device_states)
                .enumerate()
            {
                assert_eq!(a, b, "{label}: device {d} replica diverged");
            }
        }
    }
}

#[test]
fn compaction_preserves_round_decisions_under_early_validation() {
    // Compaction can delay mid-round chunk availability, so an early
    // point may see less and the abort fires later — but every round's
    // final commit/abort DECISION must be preserved: the conflicting
    // entries still ship within the round and its final validation sees
    // them (DESIGN.md §9).  Compare the first round only — after an
    // abort whose timing differed, the traces legitimately diverge.
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            let base = run("synth", policy, n_gpus, Variant::Optimized, true, false, false);
            let comp = run("synth", policy, n_gpus, Variant::Optimized, true, true, true);
            let label = format!("synth/{policy:?}/n_gpus={n_gpus}/compaction+early");
            assert_eq!(
                base.committed_flags.first(),
                comp.committed_flags.first(),
                "{label}: first-round decision flipped"
            );
            // Both runs pass their oracles (checked inside run()) and the
            // abort-certain shape stays abort-certain end to end.
            assert_eq!(
                base.rounds_committed == 0,
                comp.rounds_committed == 0,
                "{label}: commit-ability diverged"
            );
        }
    }
}

#[test]
fn compaction_and_filter_actually_engage_on_zipfkv() {
    // Anti-vacuousness check for the suite: on the hot-key workload the
    // compacted run must ship measurably fewer entries than it logged,
    // and the partitioned chunks must hit the signature prefilter.
    let t = run(
        "zipfkv",
        PolicyKind::FavorCpu,
        1,
        Variant::Optimized,
        false,
        true,
        true,
    );
    assert!(t.raw_entries > 0);
    assert!(
        t.shipped_entries < t.raw_entries,
        "zipfkv hot keys must compact: shipped {} of {}",
        t.shipped_entries,
        t.raw_entries
    );
    assert!(
        t.chunks_filtered > 0,
        "partitioned zipfkv chunks must filter"
    );
}

#[test]
fn threaded_cluster_matches_sequential_with_compaction_and_filter() {
    // The new data path must stay lane-disjoint: threaded == sequential
    // with both knobs on, for a contended sharded workload.
    let raw = neutral_raw();
    let build = |threads: usize| {
        let mut c = SystemConfig::from_raw(&raw).unwrap();
        c.n_words = 1 << 14;
        c.policy = PolicyKind::FavorCpu;
        c.n_gpus = 4;
        c.shard_bits = 6;
        c.cluster_threads = threads;
        c.log_compaction = true;
        c.chunk_filter = true;
        let w = shetm::apps::workload::from_raw("zipfkv", &raw, &c).unwrap();
        let mut e = launch::build_workload_cluster_engine(
            &c,
            Variant::Optimized,
            w.as_ref(),
            128,
            shetm::gpu::Backend::Native,
        );
        e.run_rounds(3).unwrap();
        e.drain().unwrap();
        (format!("{:?}", e.stats), e.cpu.stmr().snapshot())
    };
    let seq = build(1);
    let thr = build(4);
    assert_eq!(seq.0, thr.0, "RunStats diverged across thread counts");
    assert_eq!(seq.1, thr.1, "CPU state diverged across thread counts");
}
