//! Property tests over the coordinator's core invariants (hand-rolled
//! harness, `shetm::util::prop` — proptest is unavailable offline).
//!
//! These are the safety arguments of the paper, checked mechanically:
//!   P1  — committed state is a serial merge: after a quiesced run the two
//!         replicas are bit-identical, under every policy/variant mix;
//!   P2† — speculative GPU work never leaks: a failed round leaves no GPU
//!         write visible on either replica (favor-CPU), and vice versa;
//!   PR-STM — intra-batch committers are conflict-free in priority order;
//!   validation — freshness-guarded apply equals a timestamp-ordered replay.

// Drives the legacy `launch::build_*` constructors on purpose: property
// tests over the reference engines (Session is golden-tested against
// them in rust/tests/session_api.rs).
#![allow(deprecated)]

use shetm::apps::synth::{SynthCpu, SynthGpu, SynthSpec};
use shetm::config::{PolicyKind, SystemConfig};
use shetm::coordinator::round::CpuDriver;
use shetm::coordinator::round::Variant;
use shetm::coordinator::{Affinity, Dispatcher, Loser, Policy, RoundLog};
use shetm::gpu::{native, Backend, Bitmap, GpuDevice, LogChunk, TxnBatch};
use shetm::launch;
use shetm::stm::WriteEntry;
use shetm::util::prop::{forall, Cases};
use shetm::util::Rng;

fn base_cfg(n: usize, seed: u64) -> SystemConfig {
    let mut cfg = SystemConfig::from_raw(&shetm::config::Raw::new()).unwrap();
    cfg.n_words = n;
    cfg.cpu_txn_s = 2e-6;
    cfg.seed = seed;
    cfg
}

#[test]
fn prop_replicas_converge_after_drain() {
    forall(Cases::new("replicas_converge", 24).max_size(64), |rng, size| {
        let n = 1 << (12 + rng.below_usize(3)); // 4K..16K words
        let mut cfg = base_cfg(n, rng.next_u64());
        cfg.period_s = 0.001 + 0.001 * (size % 8) as f64;
        cfg.early_validation = rng.chance(0.5);
        cfg.policy = match rng.below(3) {
            0 => PolicyKind::FavorCpu,
            1 => PolicyKind::FavorGpu,
            _ => PolicyKind::CpuWithStarvationGuard,
        };
        let variant = if rng.chance(0.5) {
            Variant::Optimized
        } else {
            Variant::Basic
        };
        let conflict = if rng.chance(0.4) { 1e-4 } else { 0.0 };
        let cpu_spec = SynthSpec::w1(n, 0.5)
            .partitioned(0..n / 2)
            .with_conflicts(conflict, n / 2..n);
        let gpu_spec = SynthSpec::w1(n, 0.5).partitioned(n / 2..n);
        let mut e = launch::build_synth_engine(
            &cfg, variant, cpu_spec, gpu_spec, 256, Backend::Native,
        );
        let rounds = 1 + size % 4;
        e.run_rounds(rounds).map_err(|e| e.to_string())?;
        e.drain().map_err(|e| e.to_string())?;
        // After the drain, the last round committed (the drain round has no
        // GPU work, so it cannot conflict) and the replicas must agree.
        let cpu = e.cpu.stmr().snapshot();
        if cpu != e.device.stmr() {
            let bad = (0..n).find(|&i| cpu[i] != e.device.stmr()[i]).unwrap();
            return Err(format!(
                "replicas diverge at word {bad} (policy {:?}, variant {:?}, \
                 conflict {conflict}): cpu={} gpu={}",
                cfg.policy, variant, cpu[bad], e.device.stmr()[bad]
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_failed_rounds_leak_no_loser_state() {
    forall(Cases::new("no_loser_leaks", 16).max_size(32), |rng, _size| {
        let n = 1 << 12;
        let mut cfg = base_cfg(n, rng.next_u64());
        cfg.period_s = 0.002;
        cfg.early_validation = rng.chance(0.5);
        cfg.policy = PolicyKind::FavorCpu;
        // Certain conflict: every CPU update writes into the GPU half.
        let cpu_spec = SynthSpec::w1(n, 1.0)
            .partitioned(0..n / 2)
            .with_conflicts(1.0, n / 2..n);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let variant = if rng.chance(0.5) {
            Variant::Optimized
        } else {
            Variant::Basic
        };
        let mut e = launch::build_synth_engine(
            &cfg, variant, cpu_spec, gpu_spec, 256, Backend::Native,
        );
        e.run_rounds(2).map_err(|e| e.to_string())?;
        if e.stats.rounds_committed != 0 {
            return Err("conflict injection must abort every round".into());
        }
        if e.stats.gpu_commits != 0 {
            return Err("discarded GPU commits leaked into stats".into());
        }
        if e.stats.discarded_commits == 0 {
            return Err("wasted work not accounted".into());
        }
        Ok(())
    });
}

#[test]
fn prop_policy_starvation_machine_matches_model() {
    // The Policy state machine against a transparent model: the streak
    // counts consecutive GPU-losing rounds, resets on ANY commit, and the
    // read-only restriction engages exactly when the streak reaches the
    // limit (and not one round earlier).
    forall(Cases::new("policy_machine", 120).max_size(64), |rng, size| {
        let limit = 1 + rng.below(6) as u32;
        let mut p = Policy::new(PolicyKind::CpuWithStarvationGuard, limit);
        if p.loser() != Loser::Gpu || p.conditional_apply() {
            return Err("starvation guard must favor the CPU".into());
        }
        let mut streak = 0u32;
        for round in 0..size {
            let committed = rng.chance(0.5);
            p.on_round(committed);
            streak = if committed { 0 } else { streak + 1 };
            if p.gpu_abort_streak() != streak {
                return Err(format!(
                    "round {round}: streak {} != model {streak} (limit {limit})",
                    p.gpu_abort_streak()
                ));
            }
            let expect_ro = streak >= limit;
            if p.cpu_read_only() != expect_ro {
                return Err(format!(
                    "round {round}: read_only {} != model {expect_ro} \
                     (streak {streak}, limit {limit})",
                    p.cpu_read_only()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plain_policies_never_restrict_the_cpu() {
    forall(Cases::new("policy_no_restrict", 60).max_size(64), |rng, size| {
        for kind in [PolicyKind::FavorCpu, PolicyKind::FavorGpu] {
            let mut p = Policy::new(kind, 1);
            for _ in 0..size {
                p.on_round(rng.chance(0.5));
                if p.cpu_read_only() {
                    return Err(format!("{kind:?} restricted the CPU"));
                }
            }
            // Favor-GPU never loses GPU rounds, so its streak stays zero.
            if kind == PolicyKind::FavorGpu && p.gpu_abort_streak() != 0 {
                return Err("favor-GPU accumulated a GPU abort streak".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_empty_cpu_write_set_always_validates() {
    // §IV-E's guarantee behind the starvation guard: a round in which the
    // CPU commits no writes cannot fail inter-device validation, whatever
    // the GPU does — there are no log entries to conflict.
    forall(Cases::new("empty_ws_validates", 12).max_size(16), |rng, size| {
        let n = 1 << 12;
        let mut cfg = base_cfg(n, rng.next_u64());
        cfg.period_s = 0.002;
        cfg.early_validation = rng.chance(0.5);
        let variant = if rng.chance(0.5) {
            Variant::Optimized
        } else {
            Variant::Basic
        };
        // Read-only CPU (update_frac = 0) spanning the WHOLE region, GPU
        // updating the whole region too: maximal overlap, zero CPU writes.
        let cpu_spec = SynthSpec::w1(n, 0.0);
        let gpu_spec = SynthSpec::w1(n, 1.0);
        let mut e = launch::build_synth_engine(
            &cfg, variant, cpu_spec, gpu_spec, 256, Backend::Native,
        );
        let rounds = 1 + size % 4;
        e.run_rounds(rounds).map_err(|e| e.to_string())?;
        if e.stats.rounds_committed != e.stats.rounds {
            return Err(format!(
                "{} of {} rounds failed validation with an empty CPU write-set",
                e.stats.rounds - e.stats.rounds_committed,
                e.stats.rounds
            ));
        }
        if e.stats.chunks != 0 {
            return Err("read-only CPU must ship no log chunks".into());
        }
        Ok(())
    });
}

#[test]
fn prop_prstm_committers_serialize_by_priority() {
    forall(Cases::new("prstm_serializable", 60).max_size(128), |rng, size| {
        let n = 256 + size * 4;
        let b = 16 + size;
        let (r, w) = (1 + rng.below_usize(4), 1 + rng.below_usize(4));
        let mut batch = TxnBatch::empty(b, r, w);
        let mut widx = Vec::new();
        for i in 0..b {
            for j in 0..r {
                batch.read_idx[i * r + j] = if rng.chance(0.1) {
                    -1
                } else {
                    rng.below_usize(n) as i32
                };
            }
            rng.distinct(n, w, &mut widx);
            for j in 0..w {
                batch.write_idx[i * w + j] = widx[j] as i32;
                batch.write_val[i * w + j] = rng.below(1000) as i32;
            }
            batch.op[i] = rng.below(2) as i32;
        }
        let mut stmr = vec![0i32; n];
        let mut rs = Bitmap::new(n, 0);
        let mut ws = Bitmap::new(n, 0);
        let out = native::prstm_step(&mut stmr, &mut rs, &mut ws, &batch, 0);

        // Committed write-sets must be pairwise disjoint.
        let mut writer: std::collections::HashMap<i32, usize> = Default::default();
        for i in 0..b {
            if out.commit[i] == 0 {
                continue;
            }
            for &a in &batch.write_idx[i * w..(i + 1) * w] {
                if a >= 0 {
                    if let Some(&j) = writer.get(&a) {
                        return Err(format!("txns {j} and {i} both wrote {a}"));
                    }
                    writer.insert(a, i);
                }
            }
        }
        // A committer may read another committer's written word only if
        // the writer serializes later (higher priority index).
        for i in 0..b {
            if out.commit[i] == 0 {
                continue;
            }
            for &a in &batch.read_idx[i * r..(i + 1) * r] {
                if a >= 0 {
                    if let Some(&j) = writer.get(&a) {
                        if j < i {
                            return Err(format!(
                                "committer {i} read word {a} written by earlier committer {j}"
                            ));
                        }
                    }
                }
            }
        }
        // WS ⊆ RS on the bitmaps.
        for g in ws.iter_marked() {
            if !rs.test_granule(g) {
                return Err(format!("granule {g}: WS set but RS clear"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_validation_equals_ts_ordered_replay() {
    forall(Cases::new("validation_replay", 60).max_size(128), |rng, size| {
        let n = 128 + size;
        let mut stmr = vec![0i32; n];
        let mut ts_arr = vec![0i32; n];
        let rs = Bitmap::new(n, 0);
        // Several chunks with duplicate addresses and colliding timestamps.
        let chunks = 1 + rng.below_usize(4);
        let mut all: Vec<LogChunk> = Vec::new();
        for _ in 0..chunks {
            let c = 16 + rng.below_usize(48);
            let mut chunk = LogChunk::empty(c);
            for i in 0..c {
                if rng.chance(0.85) {
                    chunk.addrs[i] = rng.below_usize(n / 4) as i32; // dup-heavy
                    chunk.vals[i] = rng.below(10_000) as i32;
                    chunk.ts[i] = rng.below(30) as i32;
                }
            }
            all.push(chunk);
        }
        // Oracle: max-(ts, global position) value per word.
        let mut pos = 0i64;
        let mut best: std::collections::HashMap<usize, (i32, i64, i32)> = Default::default();
        for chunk in &all {
            for i in 0..chunk.addrs.len() {
                let a = chunk.addrs[i];
                if a < 0 {
                    continue;
                }
                let e = best.entry(a as usize).or_insert((i32::MIN, -1, 0));
                if (chunk.ts[i], pos) >= (e.0, e.1) {
                    *e = (chunk.ts[i], pos, chunk.vals[i]);
                }
                pos += 1;
            }
        }
        for chunk in &all {
            native::validate_step(&mut stmr, &mut ts_arr, &rs, chunk);
        }
        for (a, (_ts, _pos, v)) in &best {
            if stmr[*a] != *v {
                return Err(format!("word {a}: got {} want {v}", stmr[*a]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dispatcher_conserves_requests() {
    forall(Cases::new("dispatcher_conserves", 60).max_size(256), |rng, size| {
        let mut d: Dispatcher<u32> = Dispatcher::new();
        d.gpu_steal_prob = if rng.chance(0.5) { 1.0 } else { 0.0 };
        let n = size + 1;
        for i in 0..n as u32 {
            let aff = match rng.below(3) {
                0 => Affinity::Cpu,
                1 => Affinity::Gpu,
                _ => Affinity::Shared,
            };
            d.submit(i, aff);
        }
        let mut seen = Vec::new();
        let mut batch = Vec::new();
        let mut rng2 = Rng::new(rng.next_u64());
        loop {
            let before = seen.len();
            if rng2.chance(0.5) {
                if let Some(x) = d.pop_cpu() {
                    seen.push(x);
                }
            } else {
                batch.clear();
                d.pop_gpu_batch(1 + rng2.below_usize(8), &mut rng2, &mut batch);
                seen.append(&mut batch);
            }
            let (c, g, s) = d.depths();
            if c + g + s == 0 {
                break;
            }
            if seen.len() == before {
                // Whatever remains is only reachable through the CPU side
                // (or the GPU side, under stealing): drain both.
                while let Some(x) = d.pop_cpu() {
                    seen.push(x);
                }
                batch.clear();
                d.pop_gpu_batch(usize::MAX - 1, &mut rng2, &mut batch);
                seen.append(&mut batch);
            }
        }
        seen.sort_unstable();
        seen.dedup();
        if seen.len() != n {
            return Err(format!("lost/duplicated requests: {} of {n}", seen.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_round_log_chunks_reconstruct_entries() {
    forall(Cases::new("roundlog_roundtrip", 60).max_size(300), |rng, size| {
        let chunk_entries = 1 + rng.below_usize(16);
        let mut log = RoundLog::with_chunk_entries(chunk_entries);
        let n = size;
        let entries: Vec<WriteEntry> = (0..n)
            .map(|i| WriteEntry {
                addr: rng.below(1000) as u32,
                val: rng.below(1 << 20) as i32,
                ts: i as i32 + 1,
            })
            .collect();
        // Append in random-sized batches, draining full chunks sometimes.
        let mut chunks = Vec::new();
        let mut off = 0;
        while off < n {
            let k = 1 + rng.below_usize(8).min(n - off - 1 + 1);
            log.append(&entries[off..(off + k).min(n)]);
            off = (off + k).min(n);
            if rng.chance(0.3) {
                log.drain_full_chunks(&mut chunks);
            }
        }
        log.drain_all(&mut chunks);
        // Reconstruct.
        let mut got = Vec::new();
        for c in &chunks {
            for i in 0..c.addrs.len() {
                if c.addrs[i] >= 0 {
                    got.push(WriteEntry {
                        addr: c.addrs[i] as u32,
                        val: c.vals[i],
                        ts: c.ts[i],
                    });
                }
            }
        }
        if got != entries {
            return Err(format!(
                "roundtrip mismatch: {} in, {} out (chunk={chunk_entries})",
                entries.len(),
                got.len()
            ));
        }
        Ok(())
    });
}

/// Drive two identically-fed round logs (raw vs compacted) through a
/// random append/drain schedule and return their chunks.
fn chunked_raw_and_compacted(
    rng: &mut Rng,
    entries: &[WriteEntry],
    chunk_entries: usize,
) -> (Vec<LogChunk>, Vec<LogChunk>) {
    let mut raw = RoundLog::with_chunk_entries(chunk_entries);
    let mut comp = RoundLog::with_chunk_entries(chunk_entries);
    comp.set_compaction(true);
    let (mut raw_chunks, mut comp_chunks) = (Vec::new(), Vec::new());
    let mut off = 0;
    while off < entries.len() {
        let k = (1 + rng.below_usize(8)).min(entries.len() - off);
        raw.append(&entries[off..off + k]);
        comp.append(&entries[off..off + k]);
        off += k;
        if rng.chance(0.3) {
            raw.drain_full_chunks(&mut raw_chunks);
            comp.drain_full_chunks(&mut comp_chunks);
        }
    }
    raw.drain_all(&mut raw_chunks);
    comp.drain_all(&mut comp_chunks);
    assert!(comp.shipped() <= raw.shipped(), "compaction never grows the log");
    (raw_chunks, comp_chunks)
}

/// Random dup-heavy entry stream; ts values collide on purpose so the
/// `>=` tie-break rule is exercised, not just monotonic clocks.
fn random_entries(rng: &mut Rng, n: usize, addr_space: u64) -> Vec<WriteEntry> {
    (0..n)
        .map(|_| WriteEntry {
            addr: rng.below(addr_space) as u32,
            val: rng.below(1 << 20) as i32,
            ts: rng.below(24) as i32,
        })
        .collect()
}

#[test]
fn prop_compacted_log_validates_and_applies_like_raw() {
    // Satellite coverage for `hetm.log_compaction`: a compacted log must
    // validate (same conflict DECISION) and apply (same final stmr and
    // ts_arr) exactly like the raw log, under arbitrary streaming drain
    // schedules, duplicate densities and colliding timestamps.
    forall(Cases::new("compaction_equiv", 80).max_size(400), |rng, size| {
        let n = 96;
        let entries = random_entries(rng, size, n as u64 / 2);
        let chunk_entries = 1 + rng.below_usize(24);
        let (raw_chunks, comp_chunks) =
            chunked_raw_and_compacted(rng, &entries, chunk_entries);
        // Random read-set bitmap to validate against.
        let mut rs = Bitmap::new(n, 0);
        for _ in 0..rng.below_usize(8) {
            rs.mark_word(rng.below_usize(n));
        }
        let apply = |chunks: &[LogChunk]| {
            let mut stmr = vec![0i32; n];
            let mut ts_arr = vec![0i32; n];
            let mut conf = 0u32;
            for c in chunks {
                conf += native::validate_step(&mut stmr, &mut ts_arr, &rs, c);
            }
            (stmr, ts_arr, conf)
        };
        let (stmr_r, ts_r, conf_r) = apply(&raw_chunks);
        let (stmr_c, ts_c, conf_c) = apply(&comp_chunks);
        if stmr_r != stmr_c {
            let w = (0..n).find(|&i| stmr_r[i] != stmr_c[i]).unwrap();
            return Err(format!(
                "stmr diverges at word {w}: raw={} comp={} (chunk={chunk_entries})",
                stmr_r[w], stmr_c[w]
            ));
        }
        if ts_r != ts_c {
            return Err("ts_arr diverges".into());
        }
        if (conf_r > 0) != (conf_c > 0) {
            return Err(format!(
                "conflict decision diverges: raw={conf_r} comp={conf_c}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_compacted_rollback_with_logs_matches_raw() {
    // Favor-CPU rollback replays the shipped chunks onto the shadow copy;
    // a compacted log must reproduce the raw replay bit for bit, even
    // when the device did speculative work that the rollback discards.
    forall(Cases::new("compaction_rollback", 40).max_size(300), |rng, size| {
        let n = 96;
        let entries = random_entries(rng, size, n as u64 / 2);
        let chunk_entries = 1 + rng.below_usize(24);
        let (raw_chunks, comp_chunks) =
            chunked_raw_and_compacted(rng, &entries, chunk_entries);
        let run = |chunks: &[LogChunk]| -> Result<Vec<i32>, String> {
            let mut d = GpuDevice::new(n, 0, Backend::Native);
            d.begin_round();
            // Speculative GPU writes the rollback must discard.
            let mut b = TxnBatch::empty(2, 1, 1);
            b.read_idx = vec![-1, -1];
            b.write_idx = vec![(n - 1) as i32, (n - 2) as i32];
            b.write_val = vec![777, 778];
            b.op = vec![1, 1];
            d.run_txn_batch(&b).map_err(|e| e.to_string())?;
            for c in chunks {
                d.validate_chunk(c).map_err(|e| e.to_string())?;
            }
            d.rollback_with_logs(chunks);
            Ok(d.stmr().to_vec())
        };
        let raw_state = run(&raw_chunks)?;
        let comp_state = run(&comp_chunks)?;
        if raw_state != comp_state {
            let w = (0..n).find(|&i| raw_state[i] != comp_state[i]).unwrap();
            return Err(format!(
                "rollback diverges at word {w}: raw={} comp={}",
                raw_state[w], comp_state[w]
            ));
        }
        if raw_state[n - 1] == 777 {
            return Err("rollback kept a speculative GPU write".into());
        }
        Ok(())
    });
}

#[test]
fn prop_compaction_preserves_carried_prefix_for_favor_gpu() {
    // The favor-GPU abort path truncates the log back to the carried
    // prefix; compaction must never merge across that boundary, so the
    // recovered prefix is the carry VERBATIM whatever was appended or
    // drained in between.
    forall(Cases::new("compaction_carry", 60).max_size(200), |rng, size| {
        let carry_len = rng.below_usize(20);
        let carry = random_entries(rng, carry_len, 16);
        let body = random_entries(rng, size, 16);
        let chunk_entries = 1 + rng.below_usize(16);
        let mut log = RoundLog::with_chunk_entries(chunk_entries);
        log.set_compaction(true);
        log.reset_with_carry(&carry);
        let mut chunks = Vec::new();
        let mut off = 0;
        while off < body.len() {
            let k = (1 + rng.below_usize(8)).min(body.len() - off);
            log.append(&body[off..off + k]);
            off += k;
            if rng.chance(0.3) {
                log.drain_full_chunks(&mut chunks);
            }
        }
        log.drain_all(&mut chunks);
        // Shipped chunks must begin with the carry verbatim (compaction
        // must not have merged this round's entries into it).
        let mut shipped = Vec::new();
        for c in &chunks {
            for i in 0..c.addrs.len() {
                if c.addrs[i] >= 0 {
                    shipped.push(WriteEntry {
                        addr: c.addrs[i] as u32,
                        val: c.vals[i],
                        ts: c.ts[i],
                    });
                }
            }
        }
        if shipped.len() < carry.len() || shipped[..carry.len()] != carry[..] {
            return Err(format!(
                "carry prefix not shipped verbatim ({} carried, {} shipped)",
                carry.len(),
                shipped.len()
            ));
        }
        // Favor-GPU abort: exactly the carry survives.
        log.truncate_to_carried();
        if log.entries() != &carry[..] {
            return Err(format!(
                "truncate recovered {} entries, carried {}",
                log.entries().len(),
                carry.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_router_scatter_compacts_per_shard_like_raw() {
    // Cluster path: scattering then compacting per shard must apply
    // identically to the raw scatter — per-shard windows only ever dedup
    // entries routed to the same device, and shards are address-disjoint.
    use shetm::cluster::{LogRouter, ShardMap};
    forall(Cases::new("router_compaction", 40).max_size(300), |rng, size| {
        let n = 128;
        let n_shards = 1 + rng.below_usize(4);
        let map = ShardMap::new(n, n_shards, 2); // 4-word blocks
        let entries = random_entries(rng, size, n as u64);
        let chunk_entries = 1 + rng.below_usize(16);
        let chunks_of = |compact: bool, rng: &mut Rng| {
            let mut r = LogRouter::new(map.clone(), chunk_entries);
            r.set_compaction(compact);
            let mut per_shard: Vec<Vec<LogChunk>> = vec![Vec::new(); n_shards];
            let mut off = 0;
            while off < entries.len() {
                let k = (1 + rng.below_usize(8)).min(entries.len() - off);
                r.append(&entries[off..off + k]);
                off += k;
                if rng.chance(0.3) {
                    for (s, out) in per_shard.iter_mut().enumerate() {
                        r.drain_full_chunks(s, out);
                    }
                }
            }
            for (s, out) in per_shard.iter_mut().enumerate() {
                r.drain_all(s, out);
            }
            per_shard
        };
        // Same drain schedule for both (fresh RNG clone via reseed).
        let seed = rng.next_u64();
        let raw = chunks_of(false, &mut Rng::new(seed));
        let comp = chunks_of(true, &mut Rng::new(seed));
        let apply = |per_shard: &[Vec<LogChunk>]| {
            let mut stmr = vec![0i32; n];
            let mut ts_arr = vec![0i32; n];
            let rs = Bitmap::new(n, 0);
            for chunks in per_shard {
                for c in chunks {
                    native::validate_step(&mut stmr, &mut ts_arr, &rs, c);
                }
            }
            (stmr, ts_arr)
        };
        let (stmr_r, ts_r) = apply(&raw);
        let (stmr_c, ts_c) = apply(&comp);
        if stmr_r != stmr_c || ts_r != ts_c {
            return Err(format!("sharded apply diverges (shards={n_shards})"));
        }
        // Ownership is respected after compaction.
        for (s, chunks) in comp.iter().enumerate() {
            for c in chunks {
                for &a in &c.addrs {
                    if a >= 0 && map.owner(a as usize) != s {
                        return Err(format!("shard {s} shipped foreign word {a}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_solo_baselines_bound_shetm() {
    // SHeTM on a clean partitioned workload must land between the best
    // single device and the ideal sum (sanity bound used by Fig. 3).
    forall(Cases::new("shetm_bounded", 6).max_size(8), |rng, _| {
        let n = 1 << 13;
        let mut cfg = base_cfg(n, rng.next_u64());
        cfg.period_s = 0.004;
        let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
        let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
        let mut e = launch::build_synth_engine(
            &cfg,
            Variant::Optimized,
            cpu_spec,
            gpu_spec,
            256,
            Backend::Native,
        );
        e.run_rounds(6).map_err(|e| e.to_string())?;
        let thr = e.stats.throughput();
        let cpu_rate = e.cpu.rate();
        let gpu_rate = e.gpu.rate();
        if thr < cpu_rate.max(gpu_rate) * 0.8 {
            return Err(format!(
                "SHeTM {thr:.0} below 0.8x best device {:.0}",
                cpu_rate.max(gpu_rate)
            ));
        }
        if thr > (cpu_rate + gpu_rate) * 1.05 {
            return Err(format!(
                "SHeTM {thr:.0} above ideal {:.0}",
                cpu_rate + gpu_rate
            ));
        }
        Ok(())
    });
}
