//! Property tests pinning the packed `u64` [`Bitmap`] to a naive
//! reference model of the legacy one-`i32`-per-granule layout.
//!
//! The packed representation (DESIGN.md §12) must be observationally
//! identical to the flat layout across every operation the engines use:
//! mark/test, intersection probes, counts, dirty-range scans (exact and
//! coarse), the marked-granule iterator, and the tensor interchange
//! boundary.  Cases sweep random shifts and STMR sizes that do NOT divide
//! evenly into granules or storage words, so the edge-of-STMR granule and
//! the partial final `u64` are exercised constantly.

use shetm::gpu::Bitmap;
use shetm::util::prop::{forall, Cases};
use shetm::util::Rng;

/// The pre-§12 reference: one `i32` per granule, scalar loops throughout.
/// Every method is a direct transcription of the documented semantics.
struct Model {
    shift: u32,
    n_words: usize,
    marks: Vec<i32>,
}

impl Model {
    fn new(n_words: usize, shift: u32) -> Self {
        Model {
            shift,
            n_words,
            marks: vec![0; n_words.div_ceil(1 << shift)],
        }
    }

    fn mark_word(&mut self, w: usize) {
        let g = w >> self.shift;
        self.marks[g] = 1;
    }

    fn mark_granule(&mut self, g: usize) {
        self.marks[g] = 1;
    }

    fn test_word(&self, w: usize) -> bool {
        self.marks[w >> self.shift] != 0
    }

    fn test_granule(&self, g: usize) -> bool {
        g < self.marks.len() && self.marks[g] != 0
    }

    fn count(&self) -> usize {
        self.marks.iter().filter(|&&m| m != 0).count()
    }

    fn is_empty(&self) -> bool {
        self.count() == 0
    }

    fn any_in_word_range(&self, start: usize, end: usize) -> bool {
        let end = end.min(self.n_words);
        (start..end).any(|w| self.test_word(w))
    }

    fn intersect_count(&self, other: &Model) -> usize {
        self.marks
            .iter()
            .zip(&other.marks)
            .filter(|(&a, &b)| a != 0 && b != 0)
            .count()
    }

    fn iter_marked(&self) -> Vec<usize> {
        (0..self.marks.len()).filter(|&g| self.marks[g] != 0).collect()
    }

    fn dirty_word_ranges(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for g in self.iter_marked() {
            let s = g << self.shift;
            let e = ((g + 1) << self.shift).min(self.n_words);
            match out.last_mut() {
                Some(last) if last.1 == s => last.1 = e,
                _ => out.push((s, e)),
            }
        }
        out
    }

    fn dirty_word_ranges_coarse(&self, granule_words: usize) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for (s, e) in self.dirty_word_ranges() {
            let s = (s / granule_words) * granule_words;
            let e = (e.div_ceil(granule_words) * granule_words).min(self.n_words);
            match out.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => out.push((s, e)),
            }
        }
        out
    }
}

/// Build a (Bitmap, Model) pair with random marks at an adversarial
/// shape: `n_words` is offset from granule and storage-word multiples so
/// the final granule is partial and the final `u64` holds a partial run.
fn random_pair(rng: &mut Rng, size: usize) -> (Bitmap, Model) {
    let shift = rng.below(9) as u32; // granules of 1..=256 words
    // Sizes straddling granule (1 << shift) and storage (64 << shift)
    // boundaries, including exact multiples.
    let base = (size.max(1)) * (1 << shift);
    let n_words = match rng.below(4) {
        0 => base,                           // exact granule multiple
        1 => base + 1 + rng.below_usize(1 << shift), // ragged tail
        2 => 64 << shift,                    // exactly one storage word
        _ => (64 << shift) + 1,              // one bit into the next word
    }
    .max(1);
    let mut bmp = Bitmap::new(n_words, shift);
    let mut model = Model::new(n_words, shift);
    assert_eq!(bmp.len(), model.marks.len(), "granule counts");
    let n_marks = rng.below_usize(size.max(1) * 2 + 1);
    for _ in 0..n_marks {
        if rng.chance(0.5) {
            let w = rng.below_usize(n_words);
            bmp.mark_word(w);
            model.mark_word(w);
        } else {
            let g = rng.below_usize(bmp.len());
            bmp.mark_granule(g);
            model.mark_granule(g);
        }
    }
    // Bias toward the edge-of-STMR granule: the representation invariant
    // (zero tail bits) lives or dies here.
    if rng.chance(0.5) {
        bmp.mark_word(n_words - 1);
        model.mark_word(n_words - 1);
    }
    (bmp, model)
}

#[test]
fn packed_bitmap_matches_flat_model_on_observers() {
    forall(Cases::new("bitmap_observers", 300).max_size(96), |rng, size| {
        let (bmp, model) = random_pair(rng, size);
        if bmp.count() != model.count() {
            return Err(format!("count {} != {}", bmp.count(), model.count()));
        }
        if bmp.is_empty() != model.is_empty() {
            return Err("is_empty diverged".into());
        }
        for w in 0..model.n_words.min(512) {
            if bmp.test_word(w) != model.test_word(w) {
                return Err(format!("test_word({w}) diverged"));
            }
        }
        // test_granule including past-the-end probes (coarse signature
        // rounding can ask for them; both sides must say "unmarked").
        for g in 0..model.marks.len() + 70 {
            if bmp.test_granule(g) != model.test_granule(g) {
                return Err(format!("test_granule({g}) diverged"));
            }
        }
        let got: Vec<usize> = bmp.iter_marked().collect();
        if got != model.iter_marked() {
            return Err(format!("iter_marked {:?} != {:?}", got, model.iter_marked()));
        }
        Ok(())
    });
}

#[test]
fn packed_bitmap_matches_flat_model_on_ranges() {
    forall(Cases::new("bitmap_ranges", 300).max_size(96), |rng, size| {
        let (bmp, model) = random_pair(rng, size);
        let got = bmp.dirty_word_ranges();
        let want = model.dirty_word_ranges();
        if got != want {
            return Err(format!("dirty_word_ranges {got:?} != {want:?}"));
        }
        let total: usize = got.iter().map(|&(s, e)| e - s).sum();
        if bmp.dirty_words() != total {
            return Err(format!("dirty_words {} != {total}", bmp.dirty_words()));
        }
        for granule_words in [1usize, 3, 64, 4096] {
            let got = bmp.dirty_word_ranges_coarse(granule_words);
            let want = model.dirty_word_ranges_coarse(granule_words);
            if got != want {
                return Err(format!(
                    "coarse({granule_words}) {got:?} != {want:?}"
                ));
            }
        }
        // Random probes, including ranges rounded past the end and empty
        // ranges — both clamp.
        for _ in 0..32 {
            let s = rng.below_usize(model.n_words + 8);
            let e = s + rng.below_usize(model.n_words + 8);
            if bmp.any_in_word_range(s, e) != model.any_in_word_range(s, e) {
                return Err(format!("any_in_word_range({s}, {e}) diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_bitmap_matches_flat_model_on_intersections() {
    forall(Cases::new("bitmap_intersect", 300).max_size(96), |rng, size| {
        let (mut a, ma) = random_pair(rng, size);
        // Second operand must share the shape; re-mark a fresh pair.
        let mut b = Bitmap::new(ma.n_words, ma.shift);
        let mut mb = Model::new(ma.n_words, ma.shift);
        for _ in 0..rng.below_usize(size.max(1) * 2 + 1) {
            let w = rng.below_usize(ma.n_words);
            b.mark_word(w);
            mb.mark_word(w);
        }
        let got = a.intersect_count(&b);
        let want = ma.intersect_count(&mb);
        if got != want {
            return Err(format!("intersect_count {got} != {want}"));
        }
        if a.intersects(&b) != (want > 0) {
            return Err("intersects diverged from intersect_count".into());
        }
        a.clear();
        if !a.is_empty() || a.intersect_count(&b) != 0 {
            return Err("clear left marks behind".into());
        }
        Ok(())
    });
}

#[test]
fn packed_bitmap_tensor_boundary_round_trips() {
    forall(Cases::new("bitmap_tensor", 200).max_size(96), |rng, size| {
        let (bmp, model) = random_pair(rng, size);
        let t = bmp.to_tensor();
        if t.len() != model.marks.len() {
            return Err(format!("tensor len {} != {}", t.len(), model.marks.len()));
        }
        for (g, (&got, &want)) in t.iter().zip(&model.marks).enumerate() {
            if (got != 0) != (want != 0) {
                return Err(format!("tensor granule {g}: {got} vs {want}"));
            }
        }
        // from_tensor canonicalizes any non-zero to a set bit, so a
        // round trip through arbitrary non-zero values is identity.
        let noisy: Vec<i32> = t
            .iter()
            .map(|&v| if v != 0 { 1 + rng.below(1000) as i32 } else { 0 })
            .collect();
        let mut back = Bitmap::new(model.n_words, model.shift);
        back.from_tensor(&noisy);
        if back != bmp {
            return Err("tensor round trip not identity".into());
        }
        // granule_words covers the STMR exactly, clamped at the edge.
        let (s0, _) = bmp.granule_words(0);
        let (_, e_last) = bmp.granule_words(bmp.len() - 1);
        if s0 != 0 || e_last != model.n_words {
            return Err(format!("granule_words cover [{s0}, {e_last})"));
        }
        Ok(())
    });
}
