//! Cluster ↔ single-device equivalence and cluster behavior tests.
//!
//! The load-bearing guarantee of the multi-GPU subsystem: with
//! `cluster.n_gpus = 1` the [`ClusterEngine`] must be **bit-identical** to
//! the existing [`RoundEngine`] on the same seed — same final replica
//! state on both sides of the bus AND the same `RunStats` down to every
//! f64 (compared through their `Debug` rendering, which prints full
//! precision).  That is what makes the cluster a strict generalization:
//! all paper-reproduction results are preserved.
//!
//! [`ClusterEngine`]: shetm::cluster::ClusterEngine
//! [`RoundEngine`]: shetm::coordinator::round::RoundEngine

// This suite deliberately drives the legacy `launch::build_*` engine
// constructors: they are the independent oracle the Session facade is
// golden-tested against (see rust/tests/session_api.rs).
#![allow(deprecated)]

use shetm::apps::synth::SynthSpec;
use shetm::config::{PolicyKind, Raw, SystemConfig};
use shetm::coordinator::round::{CpuDriver, Variant};
use shetm::gpu::Backend;
use shetm::launch;

fn cfg(n: usize, policy: PolicyKind) -> SystemConfig {
    let mut raw = Raw::new();
    raw.set("cpu.txn_ns=2000").unwrap();
    raw.set("gpu.txn_ns=230").unwrap();
    raw.set("hetm.period_ms=2").unwrap();
    raw.set("seed=99").unwrap();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = n;
    c.policy = policy;
    c
}

fn specs(n: usize, conflict: f64) -> (SynthSpec, SynthSpec) {
    let cpu = SynthSpec::w1(n, 1.0)
        .partitioned(0..n / 2)
        .with_conflicts(conflict, n / 2..n);
    let gpu = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
    (cpu, gpu)
}

/// Run both engines over the same seed/config and assert bit-identity.
fn assert_equivalent(variant: Variant, policy: PolicyKind, conflict: f64, rounds: usize) {
    let n = 1 << 14;
    let c = cfg(n, policy);
    assert_eq!(c.n_gpus, 1, "default config is single-device");
    let (cpu_spec, gpu_spec) = specs(n, conflict);

    let mut single = launch::build_synth_engine(
        &c,
        variant,
        cpu_spec.clone(),
        gpu_spec.clone(),
        256,
        Backend::Native,
    );
    single.run_rounds(rounds).unwrap();
    single.drain().unwrap();

    let mut cluster = launch::build_synth_cluster_engine(
        &c,
        variant,
        cpu_spec,
        gpu_spec,
        256,
        Backend::Native,
    );
    assert_eq!(cluster.n_gpus(), 1);
    cluster.run_rounds(rounds).unwrap();
    cluster.drain().unwrap();

    let label = format!("{variant:?}/{policy:?}/conflict={conflict}");

    // Virtual time and aggregate stats, every field at full precision.
    assert_eq!(
        format!("{:?}", single.stats),
        format!("{:?}", cluster.stats),
        "{label}: RunStats must be bit-identical"
    );
    assert!(
        (single.now() - cluster.now()).abs() == 0.0,
        "{label}: virtual clocks diverged: {} vs {}",
        single.now(),
        cluster.now()
    );
    // Per-round history too.
    assert_eq!(
        single.round_log.len(),
        cluster.round_log.len(),
        "{label}: round counts"
    );
    for (i, (a, b)) in single.round_log.iter().zip(&cluster.round_log).enumerate() {
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "{label}: round {i} stats diverged"
        );
    }
    // Final state: both replicas, word for word.
    assert_eq!(
        single.cpu.stmr().snapshot(),
        cluster.cpu.stmr().snapshot(),
        "{label}: CPU replicas diverged"
    );
    assert_eq!(
        single.device.stmr(),
        cluster.devices[0].stmr(),
        "{label}: device replicas diverged"
    );
    // Cluster-only machinery must have stayed inert.
    assert_eq!(cluster.cluster.cross_checks, 0, "{label}");
    assert_eq!(cluster.cluster.refresh_bytes, 0, "{label}");
    assert_eq!(cluster.cluster.rounds_aborted_cross_shard, 0, "{label}");
}

#[test]
fn n1_matches_round_engine_clean_optimized() {
    assert_equivalent(Variant::Optimized, PolicyKind::FavorCpu, 0.0, 4);
}

#[test]
fn n1_matches_round_engine_clean_basic() {
    assert_equivalent(Variant::Basic, PolicyKind::FavorCpu, 0.0, 4);
}

#[test]
fn n1_matches_round_engine_conflicting_favor_cpu() {
    // Dense enough that rounds abort and the rollback paths run.
    assert_equivalent(Variant::Optimized, PolicyKind::FavorCpu, 0.01, 4);
    assert_equivalent(Variant::Basic, PolicyKind::FavorCpu, 0.01, 3);
}

#[test]
fn n1_matches_round_engine_conflicting_favor_gpu() {
    assert_equivalent(Variant::Optimized, PolicyKind::FavorGpu, 0.01, 4);
}

#[test]
fn n1_matches_round_engine_starvation_guard() {
    assert_equivalent(
        Variant::Optimized,
        PolicyKind::CpuWithStarvationGuard,
        0.05,
        5,
    );
}

// ---------------------------------------------------------------------------
// Golden-trace determinism: same seed ⇒ identical RunStats (and state),
// across variants and cluster sizes. The whole virtual-time machinery is
// deterministic by construction; this pins it so refactors cannot
// accidentally introduce platform or ordering dependence.
// ---------------------------------------------------------------------------

fn run_trace(variant: Variant, n_gpus: usize, rounds: usize) -> (String, String, Vec<i32>) {
    let n = 1 << 14;
    let mut c = cfg(n, PolicyKind::FavorCpu);
    c.n_gpus = n_gpus;
    let (cpu_spec, gpu_spec) = specs(n, 0.005);
    let mut e = launch::build_synth_cluster_engine(
        &c,
        variant,
        cpu_spec,
        gpu_spec,
        256,
        Backend::Native,
    );
    e.run_rounds(rounds).unwrap();
    e.drain().unwrap();
    let rounds_dbg = e
        .round_log
        .iter()
        .map(|r| format!("{r:?}"))
        .collect::<Vec<_>>()
        .join("\n");
    (format!("{:?}", e.stats), rounds_dbg, e.cpu.stmr().snapshot())
}

#[test]
fn golden_trace_same_seed_same_stats() {
    for variant in [Variant::Basic, Variant::Optimized] {
        for n_gpus in [1usize, 2] {
            let a = run_trace(variant, n_gpus, 4);
            let b = run_trace(variant, n_gpus, 4);
            assert_eq!(
                a.0, b.0,
                "{variant:?}/n_gpus={n_gpus}: RunStats must be identical"
            );
            assert_eq!(
                a.1, b.1,
                "{variant:?}/n_gpus={n_gpus}: per-round stats must be identical"
            );
            assert_eq!(
                a.2, b.2,
                "{variant:?}/n_gpus={n_gpus}: final CPU state must be identical"
            );
        }
    }
}

#[test]
fn golden_trace_different_seeds_differ() {
    // The determinism test would pass vacuously if the seed were ignored.
    let n = 1 << 14;
    let (cpu_spec, gpu_spec) = specs(n, 0.0);
    let mut snaps = Vec::new();
    for seed in [99u64, 100] {
        let mut c = cfg(n, PolicyKind::FavorCpu);
        c.seed = seed;
        let mut e = launch::build_synth_cluster_engine(
            &c,
            Variant::Optimized,
            cpu_spec.clone(),
            gpu_spec.clone(),
            256,
            Backend::Native,
        );
        e.run_rounds(2).unwrap();
        e.drain().unwrap();
        snaps.push(e.cpu.stmr().snapshot());
    }
    assert_ne!(snaps[0], snaps[1], "seed must steer the trace");
}

// ---------------------------------------------------------------------------
// Real-cluster behavior (n_gpus > 1).
// ---------------------------------------------------------------------------

#[test]
fn sharded_cluster_scales_gpu_side_cleanly() {
    let n = 1 << 16;
    let (cpu_spec, gpu_spec) = specs(n, 0.0);
    let mut thr1 = 0.0;
    let mut gpu1 = 0;
    for n_gpus in [1usize, 4] {
        let mut c = cfg(n, PolicyKind::FavorCpu);
        c.n_gpus = n_gpus;
        let mut e = launch::build_synth_cluster_engine(
            &c,
            Variant::Optimized,
            cpu_spec.clone(),
            gpu_spec.clone(),
            256,
            Backend::Native,
        );
        e.run_rounds(4).unwrap();
        assert_eq!(
            e.stats.rounds_committed, 4,
            "partitioned + homed => clean rounds at n_gpus={n_gpus}"
        );
        if n_gpus == 1 {
            thr1 = e.stats.throughput();
            gpu1 = e.stats.gpu_commits;
        } else {
            assert!(
                e.stats.gpu_commits > 2 * gpu1,
                "4 devices must beat 2x one device's commits: {} vs {}",
                e.stats.gpu_commits,
                gpu1
            );
            assert!(
                e.stats.throughput() > thr1,
                "cluster throughput {} <= single {}",
                e.stats.throughput(),
                thr1
            );
        }
    }
}

#[test]
fn cpu_writes_route_to_owners_and_validate_there() {
    let n = 1 << 16;
    let mut c = cfg(n, PolicyKind::FavorCpu);
    c.n_gpus = 4;
    let (cpu_spec, gpu_spec) = specs(n, 0.0);
    let mut e = launch::build_synth_cluster_engine(
        &c,
        Variant::Optimized,
        cpu_spec,
        gpu_spec,
        256,
        Backend::Native,
    );
    e.run_rounds(3).unwrap();
    // The CPU writes its half; entries spread across all owner devices.
    let with_chunks = e
        .cluster
        .per_device
        .iter()
        .filter(|d| d.chunks > 0)
        .count();
    assert_eq!(with_chunks, 4, "every owner shard validated CPU chunks");
}

#[test]
fn cross_shard_cpu_conflicts_abort_cluster_rounds() {
    let n = 1 << 16;
    let mut c = cfg(n, PolicyKind::FavorCpu);
    c.n_gpus = 2;
    // CPU injects writes into the GPU half: they land on words the GPUs
    // read, and the owner-shard validation catches them exactly as the
    // single-device engine does.
    let (cpu_spec, gpu_spec) = specs(n, 0.02);
    let mut e = launch::build_synth_cluster_engine(
        &c,
        Variant::Optimized,
        cpu_spec,
        gpu_spec,
        256,
        Backend::Native,
    );
    e.run_rounds(3).unwrap();
    assert!(e.stats.rounds_committed < 3, "dense conflicts abort rounds");
    assert!(e.stats.discarded_commits > 0);
    // After a committed drain the CPU replica is the global truth and the
    // engine keeps running.
    e.drain().unwrap();
}

#[test]
fn cluster_memcached_serves_from_all_devices() {
    use shetm::apps::memcached::McConfig;
    let mut c = cfg(1 << 14, PolicyKind::FavorCpu);
    c.n_gpus = 2;
    let mc = McConfig::new(1 << 10);
    let mut e =
        launch::build_memcached_cluster_engine(&c, Variant::Optimized, mc, 256, Backend::Native);
    e.run_rounds(3).unwrap();
    assert!(e.stats.cpu_commits > 0);
    for (d, dev) in e.cluster.per_device.iter().enumerate() {
        assert!(dev.batches > 0, "device {d} never activated");
        assert!(dev.commits > 0, "device {d} never committed");
    }
}

// ---------------------------------------------------------------------------
// Sequential vs threaded engine: golden-trace equivalence.  The threaded
// ClusterEngine (`cluster.threads = N`) must be bit-identical to the
// sequential one (`cluster.threads = 1`) on the same seed — same RunStats
// at full f64 precision, same per-round history, same final CPU state —
// for EVERY workload × policy at n_gpus ∈ {1, 4}.  Each run also passes
// the workload's correctness oracle, so threading is checked against the
// application semantics, not just the trace.  (DESIGN.md §8.)
// ---------------------------------------------------------------------------

fn workload_trace(
    name: &str,
    policy: PolicyKind,
    n_gpus: usize,
    threads: usize,
) -> (String, String, Vec<i32>) {
    use shetm::apps::workload::from_raw;
    let raw = Raw::parse(
        "cpu.txn_ns = 2000\n\
         gpu.txn_ns = 230\n\
         hetm.period_ms = 2\n\
         seed = 11\n\
         [bank]\n\
         accounts = 16384\n\
         [kmeans]\n\
         points = 2048\n\
         [zipfkv]\n\
         keys = 2048\n\
         [memcached]\n\
         n_sets = 1024\n",
    )
    .unwrap();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = 1 << 14;
    c.policy = policy;
    c.n_gpus = n_gpus;
    c.cluster_threads = threads;
    // Align shard stripes with the apps' half-splits on small regions.
    c.shard_bits = 6;
    let w = from_raw(name, &raw, &c).unwrap();
    let mut e = launch::build_workload_cluster_engine(
        &c,
        Variant::Optimized,
        w.as_ref(),
        128,
        shetm::gpu::Backend::Native,
    );
    assert_eq!(e.threads(), threads);
    e.run_rounds(2).unwrap();
    e.drain().unwrap();
    w.check_invariants(e.cpu.stmr())
        .unwrap_or_else(|err| panic!("{name} oracle failed (threads={threads}): {err}"));
    let rounds_dbg = e
        .round_log
        .iter()
        .map(|r| format!("{r:?}"))
        .collect::<Vec<_>>()
        .join("\n");
    (format!("{:?}", e.stats), rounds_dbg, e.cpu.stmr().snapshot())
}

fn assert_threaded_equivalent(name: &str, policy: PolicyKind, n_gpus: usize) {
    // At n_gpus = 1 this still crosses a real thread boundary: run_lanes
    // spawns a worker for the single lane whenever threads > 1.
    let threads = n_gpus.max(2);
    let seq = workload_trace(name, policy, n_gpus, 1);
    let thr = workload_trace(name, policy, n_gpus, threads);
    let label = format!("{name}/{policy:?}/n_gpus={n_gpus}/threads={threads}");
    assert_eq!(seq.0, thr.0, "{label}: RunStats diverged");
    assert_eq!(seq.1, thr.1, "{label}: per-round stats diverged");
    assert_eq!(seq.2, thr.2, "{label}: final CPU state diverged");
}

#[test]
fn threaded_matches_sequential_synth() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            assert_threaded_equivalent("synth", policy, n_gpus);
        }
    }
}

#[test]
fn threaded_matches_sequential_memcached() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            assert_threaded_equivalent("memcached", policy, n_gpus);
        }
    }
}

#[test]
fn threaded_matches_sequential_bank() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            assert_threaded_equivalent("bank", policy, n_gpus);
        }
    }
}

#[test]
fn threaded_matches_sequential_kmeans() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            assert_threaded_equivalent("kmeans", policy, n_gpus);
        }
    }
}

#[test]
fn threaded_matches_sequential_zipfkv() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            assert_threaded_equivalent("zipfkv", policy, n_gpus);
        }
    }
}
