//! Golden equivalence: the `Session` facade is bit-identical to the
//! legacy `launch::build_*` construction paths.
//!
//! The `Hetm` builder replaces fourteen free constructors with one front
//! door; this suite is what lets it do so safely.  For every workload
//! (synth, memcached, bank, kmeans, zipfkv) × every conflict-resolution
//! policy × `n_gpus ∈ {1, 4}`, a `Session` run and a legacy-engine run on
//! the same configuration must agree on:
//!
//! * the full `RunStats` (compared through `Debug`, which prints every
//!   f64 at full precision),
//! * per-round commit/abort decisions,
//! * the final CPU STMR, and
//! * the final replica of every device.
//!
//! Since the legacy constructors are in turn pinned to each other by
//! `cluster_equivalence.rs` (n_gpus = 1 ≡ RoundEngine) and
//! `log_equivalence.rs`, this transitively extends every existing golden
//! guarantee to the new API.  The builder-misconfiguration matrix lives
//! with the builder (`rust/src/session/mod.rs` tests); the oracle-backed
//! behavior matrix in `workloads.rs` already runs through `Session`.

#![allow(deprecated)] // the legacy constructors ARE the reference here

use shetm::apps::memcached::McConfig;
use shetm::apps::synth::SynthSpec;
use shetm::apps::workload::from_raw;
use shetm::config::{PolicyKind, Raw, SystemConfig};
use shetm::coordinator::round::{CpuDriver, Variant};
use shetm::gpu::Backend;
use shetm::launch;
use shetm::session::{Hetm, Session};

const POLICIES: [PolicyKind; 3] = [
    PolicyKind::FavorCpu,
    PolicyKind::FavorGpu,
    PolicyKind::CpuWithStarvationGuard,
];

const ROUNDS: usize = 3;

fn cfg(policy: PolicyKind, n_gpus: usize) -> SystemConfig {
    let mut raw = Raw::new();
    raw.set("cpu.txn_ns=2000").unwrap();
    raw.set("gpu.txn_ns=230").unwrap();
    raw.set("hetm.period_ms=2").unwrap();
    raw.set("cluster.shard_bits=6").unwrap();
    raw.set("seed=77").unwrap();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = 1 << 14;
    c.policy = policy;
    c.n_gpus = n_gpus;
    c
}

/// Small app shapes (each app reads only its own section).
fn app_raw() -> Raw {
    Raw::parse(
        "[memcached]\nn_sets = 1024\n\
         [bank]\naccounts = 8192\ncross_prob = 0.002\n\
         [kmeans]\npoints = 4096\n\
         [zipfkv]\nkeys = 4096\nupdate_frac = 0.5\n",
    )
    .unwrap()
}

/// One run's full observable signature.
struct Sig {
    stats: String,
    decisions: Vec<bool>,
    cpu_stmr: Vec<i32>,
    device_stmrs: Vec<Vec<i32>>,
}

fn session_sig(mut s: Session) -> Sig {
    s.run_rounds(ROUNDS).unwrap();
    s.drain().unwrap();
    Sig {
        stats: format!("{:?}", s.stats()),
        decisions: s.round_log().iter().map(|r| r.committed).collect(),
        cpu_stmr: s.stmr().snapshot(),
        device_stmrs: (0..s.n_gpus()).map(|d| s.device_stmr(d).to_vec()).collect(),
    }
}

fn assert_sig_eq(label: &str, a: Sig, b: Sig) {
    assert_eq!(a.stats, b.stats, "{label}: RunStats diverged");
    assert_eq!(a.decisions, b.decisions, "{label}: round decisions diverged");
    assert_eq!(a.cpu_stmr, b.cpu_stmr, "{label}: CPU STMR diverged");
    assert_eq!(
        a.device_stmrs, b.device_stmrs,
        "{label}: device replicas diverged"
    );
}

/// The legacy construction for one (workload, cfg) point, as `main.rs`,
/// the examples and the benches used to write it by hand.
fn legacy_sig(name: &str, c: &SystemConfig) -> Sig {
    let raw = app_raw();
    match name {
        "synth" => {
            let n = c.n_words;
            let cpu_spec = SynthSpec::w1(n, 1.0).partitioned(0..n / 2);
            let gpu_spec = SynthSpec::w1(n, 1.0).partitioned(n / 2..n);
            if c.n_gpus > 1 {
                let mut e = launch::build_synth_cluster_engine(
                    c,
                    Variant::Optimized,
                    cpu_spec,
                    gpu_spec,
                    1024,
                    Backend::Native,
                );
                e.run_rounds(ROUNDS).unwrap();
                e.drain().unwrap();
                Sig {
                    stats: format!("{:?}", e.stats),
                    decisions: e.round_log.iter().map(|r| r.committed).collect(),
                    cpu_stmr: e.cpu.stmr().snapshot(),
                    device_stmrs: e.devices.iter().map(|d| d.stmr().to_vec()).collect(),
                }
            } else {
                let mut e = launch::build_synth_engine(
                    c,
                    Variant::Optimized,
                    cpu_spec,
                    gpu_spec,
                    1024,
                    Backend::Native,
                );
                e.run_rounds(ROUNDS).unwrap();
                e.drain().unwrap();
                Sig {
                    stats: format!("{:?}", e.stats),
                    decisions: e.round_log.iter().map(|r| r.committed).collect(),
                    cpu_stmr: e.cpu.stmr().snapshot(),
                    device_stmrs: vec![e.device.stmr().to_vec()],
                }
            }
        }
        "memcached" => {
            let mc = McConfig::new(1 << 10);
            if c.n_gpus > 1 {
                let mut e = launch::build_memcached_cluster_engine(
                    c,
                    Variant::Optimized,
                    mc,
                    1024,
                    Backend::Native,
                );
                e.run_rounds(ROUNDS).unwrap();
                e.drain().unwrap();
                Sig {
                    stats: format!("{:?}", e.stats),
                    decisions: e.round_log.iter().map(|r| r.committed).collect(),
                    cpu_stmr: e.cpu.stmr().snapshot(),
                    device_stmrs: e.devices.iter().map(|d| d.stmr().to_vec()).collect(),
                }
            } else {
                let mut e = launch::build_memcached_engine(
                    c,
                    Variant::Optimized,
                    mc,
                    1024,
                    Backend::Native,
                );
                e.run_rounds(ROUNDS).unwrap();
                e.drain().unwrap();
                Sig {
                    stats: format!("{:?}", e.stats),
                    decisions: e.round_log.iter().map(|r| r.committed).collect(),
                    cpu_stmr: e.cpu.stmr().snapshot(),
                    device_stmrs: vec![e.device.stmr().to_vec()],
                }
            }
        }
        _ => {
            let w = from_raw(name, &raw, c).unwrap();
            if c.n_gpus > 1 {
                let mut e = launch::build_workload_cluster_engine(
                    c,
                    Variant::Optimized,
                    w.as_ref(),
                    1024,
                    Backend::Native,
                );
                e.run_rounds(ROUNDS).unwrap();
                e.drain().unwrap();
                Sig {
                    stats: format!("{:?}", e.stats),
                    decisions: e.round_log.iter().map(|r| r.committed).collect(),
                    cpu_stmr: e.cpu.stmr().snapshot(),
                    device_stmrs: e.devices.iter().map(|d| d.stmr().to_vec()).collect(),
                }
            } else {
                let mut e = launch::build_workload_engine(
                    c,
                    Variant::Optimized,
                    w.as_ref(),
                    1024,
                    Backend::Native,
                );
                e.run_rounds(ROUNDS).unwrap();
                e.drain().unwrap();
                Sig {
                    stats: format!("{:?}", e.stats),
                    decisions: e.round_log.iter().map(|r| r.committed).collect(),
                    cpu_stmr: e.cpu.stmr().snapshot(),
                    device_stmrs: vec![e.device.stmr().to_vec()],
                }
            }
        }
    }
}

/// The same point through the builder.
fn session_for(name: &str, c: &SystemConfig) -> Session {
    let b = Hetm::from_config(c).app_config(app_raw());
    match name {
        "memcached" => b.memcached(McConfig::new(1 << 10)).build().unwrap(),
        _ => b.workload_named(name).build().unwrap(),
    }
}

fn golden(name: &str) {
    for policy in POLICIES {
        for n_gpus in [1usize, 4] {
            let c = cfg(policy, n_gpus);
            let label = format!("{name}/{policy:?}/n_gpus={n_gpus}");
            let legacy = legacy_sig(name, &c);
            let session = session_sig(session_for(name, &c));
            assert_sig_eq(&label, legacy, session);
        }
    }
}

#[test]
fn session_matches_legacy_synth() {
    golden("synth");
}

#[test]
fn session_matches_legacy_memcached() {
    golden("memcached");
}

#[test]
fn session_matches_legacy_bank() {
    golden("bank");
}

#[test]
fn session_matches_legacy_kmeans() {
    golden("kmeans");
}

#[test]
fn session_matches_legacy_zipfkv() {
    golden("zipfkv");
}

#[test]
fn session_threaded_equals_sequential() {
    // The facade preserves the PR-3 guarantee: `threads` is purely a
    // wall-clock lever.  (threads > 1 upgrades a 1-gpu session to the
    // cluster engine, which is itself bit-identical to the single-device
    // engine — both facts covered in one assertion.)
    for n_gpus in [1usize, 4] {
        let c = cfg(PolicyKind::FavorCpu, n_gpus);
        let seq = session_sig(
            Hetm::from_config(&c)
                .workload_named("bank")
                .app_config(app_raw())
                .force_cluster(true)
                .build()
                .unwrap(),
        );
        let thr = session_sig(
            Hetm::from_config(&c)
                .workload_named("bank")
                .app_config(app_raw())
                .threads(4)
                .build()
                .unwrap(),
        );
        assert_sig_eq(&format!("bank threaded n_gpus={n_gpus}"), seq, thr);
    }
}
