//! Crash-injection golden suite for the durability pipeline (DESIGN.md
//! §13).
//!
//! The contract under test: a run that checkpoints, crashes at an
//! arbitrary fault point, and recovers with [`Hetm::recover`] must end
//! **bit-identical** to a run that was never interrupted — the full
//! `RunStats` debug string, the per-round commit/abort decisions, the
//! final CPU STMR and every device replica.  Not "close", not
//! "equivalent modulo counters": identical.
//!
//! The driver injects external transactions ([`Session::txn`]) at fixed
//! round boundaries so the write-ahead journal is always load-bearing:
//! recovery must replay the journaled prefix and the driver must redo
//! the lost tail, exactly once each.  Every [`CrashPoint`] is exercised
//! on the synthetic workload for both engines (`n_gpus ∈ {1, 4}`) and
//! two policies; the oracle-backed workloads (bank, zipfkv) sweep all
//! three policies over the two highest-value points — a torn WAL
//! (forces fallback to the previous complete checkpoint) and a crash
//! just after a complete checkpoint (forces recovery at the latest
//! round).  `check_invariants` must pass after every recovery.

use std::sync::atomic::{AtomicU64, Ordering};

use shetm::config::{PolicyKind, Raw, SystemConfig};
use shetm::durability::{is_simulated_crash, CrashPoint};
use shetm::session::{BuildError, Hetm, Session};

const ROUNDS: usize = 6;
const INTERVAL: u64 = 2; // checkpoints at rounds 2, 4, 6
const CRASH_ROUND: u64 = 4; // round 2's checkpoint completes, 4 crashes

static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let n = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "shetm-recovery-{tag}-{}-{n}",
        std::process::id()
    ))
}

fn cfg(policy: PolicyKind, n_gpus: usize) -> SystemConfig {
    let mut raw = Raw::new();
    raw.set("cpu.txn_ns=2000").unwrap();
    raw.set("gpu.txn_ns=230").unwrap();
    raw.set("hetm.period_ms=2").unwrap();
    raw.set("cluster.shard_bits=6").unwrap();
    raw.set("seed=77").unwrap();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = 1 << 14;
    c.policy = policy;
    c.n_gpus = n_gpus;
    c
}

/// Small app shapes (each app reads only its own section).
fn app_raw() -> Raw {
    Raw::parse(
        "[bank]\naccounts = 8192\ncross_prob = 0.002\n\
         [zipfkv]\nkeys = 4096\nupdate_frac = 0.5\n",
    )
    .unwrap()
}

fn builder(name: &str, c: &SystemConfig) -> Hetm {
    Hetm::from_config(c).workload_named(name).app_config(app_raw())
}

/// One run's full observable signature.
#[derive(PartialEq)]
struct Sig {
    stats: String,
    decisions: Vec<bool>,
    cpu_stmr: Vec<i32>,
    device_stmrs: Vec<Vec<i32>>,
}

fn sig_of(s: &Session) -> Sig {
    Sig {
        stats: format!("{:?}", s.stats()),
        decisions: s.round_log().iter().map(|r| r.committed).collect(),
        cpu_stmr: s.stmr().snapshot(),
        device_stmrs: (0..s.n_gpus()).map(|d| s.device_stmr(d).to_vec()).collect(),
    }
}

fn assert_sig_eq(label: &str, a: &Sig, b: &Sig) {
    assert_eq!(a.stats, b.stats, "{label}: RunStats diverged");
    assert_eq!(a.decisions, b.decisions, "{label}: round decisions diverged");
    assert_eq!(a.cpu_stmr, b.cpu_stmr, "{label}: CPU STMR diverged");
    assert_eq!(
        a.device_stmrs, b.device_stmrs,
        "{label}: device replicas diverged"
    );
}

/// The driver's external-transaction schedule: a keep-value write after
/// rounds 1 and 3 (exercises write-set journaling and replay) and a
/// read-only transaction after round 2 (exercises the stats-only record
/// shape).  Keyed by absolute round number so a resumed driver redoes
/// exactly the boundaries the crash lost.
fn inject(s: &mut Session, r: usize) {
    match r {
        1 | 3 => {
            s.txn(|tx| {
                let v = tx.read(0)?;
                tx.write(0, v)
            })
            .unwrap();
        }
        2 => {
            s.txn(|tx| {
                tx.read(0)?;
                Ok(())
            })
            .unwrap();
        }
        _ => {}
    }
}

/// Run rounds `from+1 ..= to` one at a time with the injection schedule.
/// A resumed driver stands at the `from` boundary, so it first redoes
/// that boundary's transaction (the crash lost it: checkpoints happen
/// inside the round, before the boundary).
fn drive(s: &mut Session, from: usize, to: usize) -> anyhow::Result<()> {
    if from > 0 {
        inject(s, from);
    }
    for r in from + 1..=to {
        s.run_rounds(1)?;
        inject(s, r);
    }
    Ok(())
}

/// The uninterrupted reference run (no durability at all).
fn golden_sig(name: &str, c: &SystemConfig) -> Sig {
    let mut s = builder(name, c).build().unwrap();
    drive(&mut s, 0, ROUNDS).unwrap();
    s.drain().unwrap();
    s.check_invariants().unwrap();
    sig_of(&s)
}

/// Crash at `point` during round `CRASH_ROUND`'s checkpoint, recover,
/// finish the run, and compare bit-exactly against the golden run.
fn crash_recover_case(name: &str, c: &SystemConfig, point: CrashPoint, golden: &Sig) {
    let label = format!(
        "{name}/{:?}/n_gpus={}/{}",
        c.policy,
        c.n_gpus,
        point.as_str()
    );
    let dir = tmpdir(&label.replace('/', "-"));
    let dir_s = dir.to_string_lossy().into_owned();

    // The doomed run: checkpoint every INTERVAL rounds, crash armed.
    let mut cc = c.clone();
    cc.checkpoint_dir = dir_s.clone();
    cc.checkpoint_interval_rounds = INTERVAL;
    cc.crash_point = point.as_str().to_string();
    cc.crash_round = CRASH_ROUND;
    let mut doomed = builder(name, &cc).build().unwrap();
    let err = drive(&mut doomed, 0, ROUNDS).expect_err(&format!("{label}: crash never fired"));
    assert!(
        is_simulated_crash(&err),
        "{label}: expected a simulated crash, got: {err:#}"
    );
    drop(doomed);

    // Recover (crash disarmed) and finish the job.
    let mut rc = cc.clone();
    rc.crash_point = String::new();
    let mut s = builder(name, &rc)
        .recover(&dir_s)
        .unwrap_or_else(|e| panic!("{label}: recovery failed: {e:#}"));
    let resumed = s.stats().rounds as usize;
    assert!(
        resumed == 2 || resumed == 4,
        "{label}: recovered at unexpected round {resumed}"
    );
    if point.tears_checkpoint() {
        assert_eq!(resumed, 2, "{label}: torn checkpoint must fall back");
    } else {
        assert_eq!(resumed, 4, "{label}: complete checkpoint must win");
    }
    drive(&mut s, resumed, ROUNDS).unwrap();
    s.drain().unwrap();
    assert_sig_eq(&label, golden, &sig_of(&s));
    s.check_invariants()
        .unwrap_or_else(|e| panic!("{label}: oracle failed after recovery: {e:#}"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Every crash point, both engines, on the synthetic workload.
/// `MidMigration` is excluded: it only fires when the rebalancer decides
/// to move blocks, which needs a skewed workload — see
/// `cluster_crash_mid_migration_recovers_bit_identical` below.
#[test]
fn synth_survives_every_crash_point() {
    for policy in [PolicyKind::FavorCpu, PolicyKind::FavorGpu] {
        for n_gpus in [1usize, 4] {
            let c = cfg(policy, n_gpus);
            let golden = golden_sig("synth", &c);
            for point in CrashPoint::ALL {
                if point == CrashPoint::MidMigration {
                    continue;
                }
                crash_recover_case("synth", &c, point, &golden);
            }
        }
    }
}

/// Oracle-backed workloads over all policies at the two highest-value
/// points: a torn WAL (fallback path) and a crash right after a complete
/// checkpoint (latest-round path).
#[test]
fn bank_survives_crashes_under_every_policy() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            let c = cfg(policy, n_gpus);
            let golden = golden_sig("bank", &c);
            for point in [CrashPoint::MidWalAppend, CrashPoint::AfterCheckpoint] {
                crash_recover_case("bank", &c, point, &golden);
            }
        }
    }
}

/// Regression for the round-buffered zipfkv version oracle: recovery
/// rebuilds its state from the recovered carried log instead of
/// panicking on the crash gap.  `check_invariants` inside
/// `crash_recover_case` is the assertion.
#[test]
fn zipfkv_oracle_survives_recovery() {
    for policy in [
        PolicyKind::FavorCpu,
        PolicyKind::FavorGpu,
        PolicyKind::CpuWithStarvationGuard,
    ] {
        for n_gpus in [1usize, 4] {
            let c = cfg(policy, n_gpus);
            let golden = golden_sig("zipfkv", &c);
            for point in [CrashPoint::MidWalAppend, CrashPoint::AfterCheckpoint] {
                crash_recover_case("zipfkv", &c, point, &golden);
            }
        }
    }
}

/// Checkpoint I/O costs zero virtual time and touches no statistics:
/// durability on ≡ durability off, bit for bit, and the checkpoint files
/// actually appear.
#[test]
fn durability_is_invisible_to_the_simulation() {
    for n_gpus in [1usize, 4] {
        let c = cfg(PolicyKind::FavorCpu, n_gpus);
        let golden = golden_sig("bank", &c);
        let dir = tmpdir(&format!("invisible-{n_gpus}"));
        let mut cc = c.clone();
        cc.checkpoint_dir = dir.to_string_lossy().into_owned();
        cc.checkpoint_interval_rounds = INTERVAL;
        let mut s = builder("bank", &cc).build().unwrap();
        drive(&mut s, 0, ROUNDS).unwrap();
        s.drain().unwrap();
        assert_sig_eq(&format!("durability-on n_gpus={n_gpus}"), &golden, &sig_of(&s));
        let manifests = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .ends_with(".manifest")
            })
            .count();
        assert!(manifests >= 3, "expected checkpoints at rounds 2, 4, 6");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A crash before ANY checkpoint completed: recovery restarts from the
/// initial state, drops the stale journal, and the rerun still matches
/// the golden run.
#[test]
fn crash_before_first_checkpoint_restarts_fresh() {
    let c = cfg(PolicyKind::FavorCpu, 1);
    let golden = golden_sig("bank", &c);
    let dir = tmpdir("fresh");
    let dir_s = dir.to_string_lossy().into_owned();
    let mut cc = c.clone();
    cc.checkpoint_dir = dir_s.clone();
    cc.checkpoint_interval_rounds = INTERVAL;
    cc.crash_point = CrashPoint::MidPageWrite.as_str().to_string();
    cc.crash_round = 0; // fires at the FIRST checkpoint (round 2)
    let mut doomed = builder("bank", &cc).build().unwrap();
    let err = drive(&mut doomed, 0, ROUNDS).expect_err("crash never fired");
    assert!(is_simulated_crash(&err));
    drop(doomed);

    let mut rc = cc.clone();
    rc.crash_point = String::new();
    let mut s = builder("bank", &rc).recover(&dir_s).unwrap();
    assert_eq!(s.stats().rounds, 0, "nothing durable: must restart fresh");
    drive(&mut s, 0, ROUNDS).unwrap();
    s.drain().unwrap();
    assert_sig_eq("fresh-restart", &golden, &sig_of(&s));
    s.check_invariants().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash, recover, then crash AGAIN at a later checkpoint and recover
/// once more — the checkpoint chain keeps extending across incarnations.
#[test]
fn double_crash_double_recovery() {
    let c = cfg(PolicyKind::FavorGpu, 4);
    let golden = golden_sig("bank", &c);
    let dir = tmpdir("double");
    let dir_s = dir.to_string_lossy().into_owned();
    let mut cc = c.clone();
    cc.checkpoint_dir = dir_s.clone();
    cc.checkpoint_interval_rounds = INTERVAL;
    cc.crash_point = CrashPoint::AfterWal.as_str().to_string();
    cc.crash_round = 2;
    let mut doomed = builder("bank", &cc).build().unwrap();
    let err = drive(&mut doomed, 0, ROUNDS).expect_err("first crash never fired");
    assert!(is_simulated_crash(&err));
    drop(doomed);

    // Second incarnation: recovers (torn round-2 → fresh), crashes at 4.
    let mut cc2 = cc.clone();
    cc2.crash_point = CrashPoint::AfterCheckpoint.as_str().to_string();
    cc2.crash_round = 4;
    let mut doomed2 = builder("bank", &cc2).recover(&dir_s).unwrap();
    let from = doomed2.stats().rounds as usize;
    assert_eq!(from, 0, "manifest never committed: nothing durable");
    let err = drive(&mut doomed2, from, ROUNDS).expect_err("second crash never fired");
    assert!(is_simulated_crash(&err));
    drop(doomed2);

    let mut rc = cc.clone();
    rc.crash_point = String::new();
    let mut s = builder("bank", &rc).recover(&dir_s).unwrap();
    assert_eq!(s.stats().rounds, 4, "round-4 checkpoint completed");
    drive(&mut s, 4, ROUNDS).unwrap();
    s.drain().unwrap();
    assert_sig_eq("double-crash", &golden, &sig_of(&s));
    s.check_invariants().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A zipf-kv shape whose CPU hot pool strides one full stripe period
/// (`n_gpus << shard_bits` words = 128 keys at 64-word blocks), so ~90%
/// of CPU updates land on ONE device of the striped layout and the
/// rebalancer must keep migrating as `drift` walks the hotspot.
fn hot_zipf_raw() -> Raw {
    Raw::parse(
        "[zipfkv]\nkeys = 4096\nupdate_frac = 0.5\ntheta = 0.99\n\
         cpu_hot_prob = 0.9\nhot_keys = 16\nhot_stride = 128\ndrift = 32\n",
    )
    .unwrap()
}

/// A crash at the migration barrier — after the rebalancer picked its
/// blocks, before the DMA and the table install.  Nothing of the doomed
/// migration is durable, recovery falls back to the last complete
/// checkpoint, and the deterministic replay re-makes every migration
/// decision: the finished run is bit-identical to one never interrupted.
#[test]
fn cluster_crash_mid_migration_recovers_bit_identical() {
    let mut c = cfg(PolicyKind::FavorCpu, 4);
    c.rebalance = true;
    c.rebalance_interval = 1;
    let app = hot_zipf_raw();

    let golden = {
        let mut s = Hetm::from_config(&c)
            .workload_named("zipfkv")
            .app_config(app.clone())
            .build()
            .unwrap();
        drive(&mut s, 0, ROUNDS).unwrap();
        s.drain().unwrap();
        s.check_invariants().unwrap();
        let desc = s.layout_desc().expect("cluster session has a layout");
        assert!(
            desc.epoch >= 1,
            "hot workload must trigger migrations (epoch {})",
            desc.epoch
        );
        sig_of(&s)
    };

    let dir = tmpdir("mid-migration");
    let dir_s = dir.to_string_lossy().into_owned();
    let mut cc = c.clone();
    cc.checkpoint_dir = dir_s.clone();
    cc.checkpoint_interval_rounds = INTERVAL;
    cc.crash_point = CrashPoint::MidMigration.as_str().to_string();
    cc.crash_round = CRASH_ROUND;
    let mut doomed = Hetm::from_config(&cc)
        .workload_named("zipfkv")
        .app_config(app.clone())
        .build()
        .unwrap();
    let err = drive(&mut doomed, 0, ROUNDS).expect_err("migration crash never fired");
    assert!(
        is_simulated_crash(&err),
        "expected a simulated crash, got: {err:#}"
    );
    drop(doomed);

    let mut rc = cc.clone();
    rc.crash_point = String::new();
    let mut s = Hetm::from_config(&rc)
        .workload_named("zipfkv")
        .app_config(app)
        .recover(&dir_s)
        .unwrap();
    // The migration barrier precedes the round's checkpoint, so round 4's
    // checkpoint never happened: the round-2 one is the durable frontier.
    let resumed = s.stats().rounds as usize;
    assert_eq!(resumed, 2, "mid-migration death precedes the checkpoint");
    drive(&mut s, resumed, ROUNDS).unwrap();
    s.drain().unwrap();
    assert_sig_eq("mid-migration", &golden, &sig_of(&s));
    s.check_invariants().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--recover` with a device count or ownership-block size contradicting
/// the checkpoint fails fast with the typed
/// [`BuildError::LayoutMismatch`] instead of replaying into silently
/// diverged state.
#[test]
fn recover_rejects_contradicting_layout_flags() {
    let c = cfg(PolicyKind::FavorCpu, 4);
    let dir = tmpdir("layout-mismatch");
    let dir_s = dir.to_string_lossy().into_owned();
    let mut cc = c.clone();
    cc.checkpoint_dir = dir_s.clone();
    cc.checkpoint_interval_rounds = INTERVAL;
    let mut s = builder("bank", &cc).build().unwrap();
    drive(&mut s, 0, ROUNDS).unwrap();
    s.drain().unwrap();
    drop(s);

    // Wrong device count.
    let mut wrong_gpus = cc.clone();
    wrong_gpus.n_gpus = 2;
    let err = builder("bank", &wrong_gpus)
        .recover(&dir_s)
        .expect_err("2 devices must not recover a 4-device checkpoint");
    match err.downcast_ref::<BuildError>() {
        Some(BuildError::LayoutMismatch { gpus, ck_gpus, .. }) => {
            assert_eq!((*gpus, *ck_gpus), (2, 4));
        }
        _ => panic!("expected LayoutMismatch, got: {err:#}"),
    }

    // Wrong ownership-block size.
    let mut wrong_bits = cc.clone();
    wrong_bits.shard_bits = 7;
    let err = builder("bank", &wrong_bits)
        .recover(&dir_s)
        .expect_err("a different shard_bits must not recover");
    assert!(
        matches!(
            err.downcast_ref::<BuildError>(),
            Some(BuildError::LayoutMismatch { .. })
        ),
        "expected LayoutMismatch, got: {err:#}"
    );

    // The matching shape still recovers, at the final checkpoint.
    let mut s = builder("bank", &cc).recover(&dir_s).unwrap();
    assert_eq!(s.stats().rounds as usize, ROUNDS, "final checkpoint wins");
    s.check_invariants().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoint work is visible in telemetry (counters + duration
/// histogram) without perturbing the deterministic metrics.
#[test]
fn checkpoints_are_counted_in_telemetry() {
    let dir = tmpdir("telemetry");
    let mut c = cfg(PolicyKind::FavorCpu, 1);
    c.checkpoint_dir = dir.to_string_lossy().into_owned();
    c.checkpoint_interval_rounds = INTERVAL;
    let mut s = builder("bank", &c).telemetry(true).build().unwrap();
    drive(&mut s, 0, ROUNDS).unwrap();
    s.drain().unwrap();
    let reg = s.collector().expect("telemetry on").registry();
    assert!(
        reg.counter("hetm_checkpoints_total") >= 3,
        "checkpoints at rounds 2, 4, 6"
    );
    assert!(reg.counter("hetm_checkpoint_bytes_total") > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
