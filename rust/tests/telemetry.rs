//! Determinism of observation (DESIGN.md §11).
//!
//! Telemetry must never weaken the platform's determinism guarantees, so
//! this suite pins:
//!
//! * **Trace bit-identity across threading and engines** — for every
//!   workload (synth, memcached, bank, kmeans, zipfkv) × every
//!   conflict-resolution policy × `n_gpus ∈ {1, 4}`, the virtual-time
//!   trace stream and the metrics registry of a `--threads 4` run are
//!   byte-for-byte identical to the sequential run of the same
//!   configuration.  At `n_gpus = 1` the sequential run uses the
//!   single-device `RoundEngine` and the threaded run the
//!   `ClusterEngine`, so the same assertion also pins cross-engine
//!   identity of observation.
//! * **Histogram merge algebra** — merging per-lane histograms is
//!   order-insensitive (commutative + associative, exactly — the buckets
//!   are integers and the sum is fixed-point) and conserves bucket
//!   counts, so folding per-device series in any grouping yields one
//!   canonical registry.
//! * **Trace schema** — every emitted document passes the same validator
//!   the CI smoke runs (`telemetry::validate_trace`).

use shetm::config::{PolicyKind, Raw, SystemConfig};
use shetm::session::Hetm;
use shetm::telemetry::{validate_trace, Histogram, MetricsRegistry};
use shetm::util::prop::{forall, Cases};
use shetm::util::Rng;

const POLICIES: [PolicyKind; 3] = [
    PolicyKind::FavorCpu,
    PolicyKind::FavorGpu,
    PolicyKind::CpuWithStarvationGuard,
];

const WORKLOADS: [&str; 5] = ["synth", "memcached", "bank", "kmeans", "zipfkv"];

const ROUNDS: usize = 3;

fn cfg(policy: PolicyKind, n_gpus: usize) -> SystemConfig {
    let mut raw = Raw::new();
    raw.set("cpu.txn_ns=2000").unwrap();
    raw.set("gpu.txn_ns=230").unwrap();
    raw.set("hetm.period_ms=2").unwrap();
    raw.set("cluster.shard_bits=6").unwrap();
    raw.set("seed=77").unwrap();
    let mut c = SystemConfig::from_raw(&raw).unwrap();
    c.n_words = 1 << 14;
    c.policy = policy;
    c.n_gpus = n_gpus;
    c
}

/// Small app shapes (each app reads only its own section) — the same
/// fixture the `session_api.rs` golden suite uses.
fn app_raw() -> Raw {
    Raw::parse(
        "[memcached]\nn_sets = 1024\n\
         [bank]\naccounts = 8192\ncross_prob = 0.002\n\
         [kmeans]\npoints = 4096\n\
         [zipfkv]\nkeys = 4096\nupdate_frac = 0.5\n",
    )
    .unwrap()
}

/// Run one traced session and return (trace document, registry).
fn traced_run(name: &str, policy: PolicyKind, n_gpus: usize, threads: usize) -> (String, MetricsRegistry) {
    let mut c = cfg(policy, n_gpus);
    c.cluster_threads = threads;
    let mut s = Hetm::from_config(&c)
        .workload_named(name)
        .app_config(app_raw())
        .trace(true)
        .build()
        .unwrap();
    s.run_rounds(ROUNDS).unwrap();
    s.drain().unwrap();
    let doc = s.trace_json().expect("trace requested");
    let reg = s.collector().expect("collector active").registry().clone();
    (doc, reg)
}

#[test]
fn trace_is_bit_identical_across_threads_and_engines() {
    for name in WORKLOADS {
        for policy in POLICIES {
            for n_gpus in [1usize, 4] {
                let label = format!("{name}/{policy:?}/gpus={n_gpus}");
                let (seq_doc, seq_reg) = traced_run(name, policy, n_gpus, 1);
                let (thr_doc, thr_reg) = traced_run(name, policy, n_gpus, 4);
                assert_eq!(
                    seq_doc, thr_doc,
                    "{label}: trace stream diverged between --threads 1 and --threads 4"
                );
                assert_eq!(
                    seq_reg, thr_reg,
                    "{label}: metrics registry diverged between --threads 1 and --threads 4"
                );
                let events = validate_trace(&seq_doc)
                    .unwrap_or_else(|e| panic!("{label}: invalid trace: {e}"));
                assert!(
                    events >= ROUNDS,
                    "{label}: expected at least one event per round, got {events}"
                );
            }
        }
    }
}

#[test]
fn trace_carries_round_and_phase_spans() {
    let (doc, reg) = traced_run("synth", PolicyKind::FavorCpu, 1, 1);
    for needle in [
        "\"name\":\"round\"",
        "\"name\":\"processing\"",
        "\"name\":\"validate\"",
        "\"name\":\"epoch_reset\"",
        "\"name\":\"thread_name\"",
    ] {
        assert!(doc.contains(needle), "trace missing {needle}");
    }
    // The drain is a round too.
    assert_eq!(reg.counter("hetm_rounds_total"), ROUNDS as u64 + 1);
    assert!(reg
        .histogram("hetm_round_latency_seconds")
        .is_some_and(|h| h.count() == ROUNDS as u64 + 1));
}

/// Deterministic positive sample spanning ~24 orders of magnitude (the
/// histogram's log-linear buckets cover 2^-40..2^11).
fn sample(rng: &mut Rng) -> f64 {
    let mantissa = 1.0 + rng.below(1_000_000) as f64 / 1_000_000.0;
    let exp = rng.below(25) as i32 - 12;
    mantissa * 10f64.powi(exp)
}

#[test]
fn histogram_merge_is_order_insensitive_and_conserves_counts() {
    forall(Cases::new("hist_merge", 200).max_size(64), |rng, size| {
        // `parts` per-lane histograms with `size` observations each.
        let parts: Vec<Histogram> = (0..1 + rng.below(6) as usize)
            .map(|_| {
                let mut h = Histogram::new();
                for _ in 0..size {
                    h.observe(sample(rng));
                }
                h
            })
            .collect();
        let total: u64 = parts.iter().map(|h| h.count()).sum();

        // Fold forward, fold reverse, and fold as a balanced tree.
        let fold = |hs: &[Histogram]| {
            let mut acc = Histogram::new();
            for h in hs {
                acc.merge(h);
            }
            acc
        };
        let fwd = fold(&parts);
        let rev = {
            let mut r = parts.clone();
            r.reverse();
            fold(&r)
        };
        let tree = {
            let mut level = parts.clone();
            while level.len() > 1 {
                let mut next = Vec::new();
                for pair in level.chunks(2) {
                    let mut m = pair[0].clone();
                    if let Some(b) = pair.get(1) {
                        m.merge(b);
                    }
                    next.push(m);
                }
                level = next;
            }
            level.pop().unwrap_or_default()
        };

        if fwd != rev {
            return Err("forward and reverse folds differ".to_string());
        }
        if fwd != tree {
            return Err("sequential and tree folds differ".to_string());
        }
        if fwd.count() != total {
            return Err(format!(
                "merge lost observations: {} of {total}",
                fwd.count()
            ));
        }
        if fwd.bucket_total() != total {
            return Err(format!(
                "bucket counts not conserved: {} of {total}",
                fwd.bucket_total()
            ));
        }
        Ok(())
    });
}

#[test]
fn registry_histograms_survive_roundtrip_quantiles() {
    // Quantiles are monotone and bracketed by min/max — the properties
    // the snapshot's p50/p99/p999 columns rely on.
    forall(Cases::new("hist_quantiles", 100).max_size(128), |rng, size| {
        let mut h = Histogram::new();
        for _ in 0..size.max(1) {
            h.observe(sample(rng));
        }
        let (p50, p99, p999) = (h.quantile(0.5), h.quantile(0.99), h.quantile(0.999));
        if !(p50 <= p99 && p99 <= p999) {
            return Err(format!("quantiles not monotone: {p50} {p99} {p999}"));
        }
        if p999 > h.max() {
            return Err(format!("p999 {p999} above max {}", h.max()));
        }
        Ok(())
    });
}

/// Regression for the wall-clock exclusion rule (D2, DESIGN.md §15).
///
/// Checkpoints time their real disk writes into the
/// `hetm_checkpoint_write_wall_seconds` histogram — the one legitimate
/// wall-clock metric — so with durability armed, two identical runs
/// must still agree on the *deterministic* registry view
/// ([`MetricsRegistry::deterministic`]), and that view must strip the
/// wall family that `scripts/check_perf.py` is likewise forbidden from
/// gating.
#[test]
fn durability_runs_have_identical_deterministic_snapshots() {
    let run = |tag: &str| {
        let dir = std::env::temp_dir().join(format!(
            "shetm-telemetry-wall-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = cfg(PolicyKind::FavorCpu, 2);
        c.checkpoint_dir = dir.to_string_lossy().into_owned();
        c.checkpoint_interval_rounds = 1;
        let mut s = Hetm::from_config(&c)
            .workload_named("zipfkv")
            .app_config(app_raw())
            .trace(true)
            .build()
            .unwrap();
        s.run_rounds(ROUNDS).unwrap();
        s.drain().unwrap();
        let snap = s.metrics_snapshot("wall-test");
        drop(s);
        let _ = std::fs::remove_dir_all(&dir);
        snap
    };
    let a = run("a");
    let b = run("b");
    let ra = a.registry.clone().expect("telemetry was on");
    let rb = b.registry.clone().expect("telemetry was on");
    assert!(
        ra.histogram("hetm_checkpoint_write_wall_seconds").is_some(),
        "checkpoints ran, so the wall-clock write histogram must exist"
    );
    assert!(
        ra.deterministic()
            .histogram("hetm_checkpoint_write_wall_seconds")
            .is_none(),
        "the deterministic view must strip the wall-clock family"
    );
    assert_eq!(
        ra.deterministic(),
        rb.deterministic(),
        "identical durability-on runs diverged outside the wall-clock family"
    );
    assert!(
        a.deterministic()
            .registry
            .expect("telemetry was on")
            .histogram("hetm_checkpoint_write_wall_seconds")
            .is_none(),
        "MetricsSnapshot::deterministic must apply the same filter"
    );
}
